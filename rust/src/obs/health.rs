//! Lock-free heartbeat board: one packed atomic slot per rank, scanned
//! by the watchdog for ranks sitting inside a rendezvous too long.
//!
//! A rank thread publishes "I entered collective `op` at `t`" with two
//! relaxed atomic stores and clears it with one; the watchdog (or the
//! exit-path deadline check) reads the slot without taking any lock. The
//! packing keeps the whole heartbeat in one word — `busy` flag, op id,
//! and bucket intern id — so a torn read can at worst misreport for one
//! poll tick, never corrupt state. Stall findings are deduplicated per
//! incident via a compare-and-swap on the entry timestamp, so the
//! monitor thread and the synchronous exit check never double-report.

use std::sync::atomic::{AtomicU64, Ordering};

/// Collective-op name table; heartbeat slots store indices into it.
/// Index 0 is the idle sentinel.
pub const OPS: [&str; 6] =
    ["idle", "all_gather", "reduce_scatter", "all_reduce", "broadcast", "all_to_all"];

/// Phase name table for the board's step-schedule phase gauge.
pub const PHASES: [&str; 6] = ["idle", "gather", "compute", "reduce", "optim", "step"];

/// Index of `name` in [`OPS`] (0 — idle — when unknown).
pub fn op_id(name: &str) -> u64 {
    OPS.iter().position(|&o| o == name).unwrap_or(0) as u64
}

/// Index of `name` in [`PHASES`] (0 when unknown).
pub fn phase_id(name: &str) -> u64 {
    PHASES.iter().position(|&p| p == name).unwrap_or(0) as u64
}

const BUSY: u64 = 1 << 63;
const OP_SHIFT: u32 = 32;
const BUCKET_MASK: u64 = (1 << 32) - 1;

/// One rank's heartbeat slot.
///
/// `state` packs `busy(1) | op(8) | bucket_id+1(32)`; `since_ns` is the
/// collective entry time (nanoseconds on the observer clock);
/// `reported_ns` is the entry time of the last incident a stall
/// diagnostic was emitted for (the dedup token).
#[derive(Debug, Default)]
struct RankSlot {
    state: AtomicU64,
    since_ns: AtomicU64,
    reported_ns: AtomicU64,
}

/// A stalled-rank finding from one board scan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stall {
    pub rank: usize,
    /// Index into [`OPS`].
    pub op: u64,
    /// Bucket intern id + 1 (0 = no bucket context).
    pub bucket: u64,
    pub for_ns: u64,
}

/// One rank's decoded heartbeat for postmortem snapshots.
#[derive(Debug, Clone, Copy)]
pub struct RankHealth {
    pub rank: usize,
    pub busy: bool,
    /// Index into [`OPS`].
    pub op: u64,
    /// Bucket intern id + 1 (0 = none).
    pub bucket: u64,
    /// How long the rank has been in its current collective.
    pub in_op_ns: u64,
}

/// The shared health board: per-rank heartbeat slots plus the schedule
/// gauges (current step / phase / bucket) the executor publishes.
#[derive(Debug)]
pub struct HealthBoard {
    slots: Vec<RankSlot>,
    /// Current (1-based) training step.
    pub step: AtomicU64,
    /// Index into [`PHASES`].
    pub phase: AtomicU64,
    /// Bucket intern id + 1 the schedule is currently driving (0 = none).
    pub bucket: AtomicU64,
}

impl HealthBoard {
    pub fn new(ranks: usize) -> HealthBoard {
        HealthBoard {
            slots: (0..ranks).map(|_| RankSlot::default()).collect(),
            step: AtomicU64::new(0),
            phase: AtomicU64::new(0),
            bucket: AtomicU64::new(0),
        }
    }

    pub fn ranks(&self) -> usize {
        self.slots.len()
    }

    /// Rank `rank` entered collective `op` at `now_ns`. Lock-free; two
    /// relaxed stores.
    pub fn enter(&self, rank: usize, op: u64, now_ns: u64) {
        let Some(slot) = self.slots.get(rank) else { return };
        let bucket = self.bucket.load(Ordering::Relaxed) & BUCKET_MASK;
        slot.since_ns.store(now_ns, Ordering::Relaxed);
        slot.state.store(BUSY | (op << OP_SHIFT) | bucket, Ordering::Release);
    }

    /// Rank `rank` left its collective at `now_ns`. Returns the decoded
    /// heartbeat it held (op, bucket, dwell time) so the caller can
    /// account per-rank wait and run the exit-path deadline check.
    pub fn exit(&self, rank: usize, now_ns: u64) -> Option<RankHealth> {
        let slot = self.slots.get(rank)?;
        let state = slot.state.load(Ordering::Acquire);
        let since = slot.since_ns.load(Ordering::Relaxed);
        slot.state.store(0, Ordering::Release);
        if state & BUSY == 0 {
            return None;
        }
        Some(RankHealth {
            rank,
            busy: false,
            op: (state >> OP_SHIFT) & 0xff,
            bucket: state & BUCKET_MASK,
            in_op_ns: now_ns.saturating_sub(since),
        })
    }

    /// Claim the right to report a stall that began at `since_ns` on
    /// `rank`. Returns true exactly once per (rank, incident) — the CAS
    /// dedup between the monitor thread and the exit-path check.
    pub fn try_claim_report(&self, rank: usize, since_ns: u64) -> bool {
        let Some(slot) = self.slots.get(rank) else { return false };
        let prev = slot.reported_ns.load(Ordering::Relaxed);
        prev != since_ns
            && slot
                .reported_ns
                .compare_exchange(prev, since_ns, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
    }

    /// Scan for ranks that have been inside one rendezvous longer than
    /// `deadline_ns` as of `now_ns`. Each incident is yielded once
    /// (claimed via [`HealthBoard::try_claim_report`]).
    pub fn stalls(&self, now_ns: u64, deadline_ns: u64) -> Vec<Stall> {
        let mut out = Vec::new();
        for (rank, slot) in self.slots.iter().enumerate() {
            let state = slot.state.load(Ordering::Acquire);
            if state & BUSY == 0 {
                continue;
            }
            let since = slot.since_ns.load(Ordering::Relaxed);
            // re-read: if the slot changed underneath us the rank moved
            // on — skip it this tick rather than report a torn pair
            if slot.state.load(Ordering::Acquire) != state {
                continue;
            }
            let dwell = now_ns.saturating_sub(since);
            if dwell >= deadline_ns && self.try_claim_report(rank, since) {
                out.push(Stall {
                    rank,
                    op: (state >> OP_SHIFT) & 0xff,
                    bucket: state & BUCKET_MASK,
                    for_ns: dwell,
                });
            }
        }
        out
    }

    /// Decode every rank's current heartbeat (postmortem snapshot).
    pub fn snapshot(&self, now_ns: u64) -> Vec<RankHealth> {
        self.slots
            .iter()
            .enumerate()
            .map(|(rank, slot)| {
                let state = slot.state.load(Ordering::Acquire);
                let since = slot.since_ns.load(Ordering::Relaxed);
                let busy = state & BUSY != 0;
                RankHealth {
                    rank,
                    busy,
                    op: (state >> OP_SHIFT) & 0xff,
                    bucket: state & BUCKET_MASK,
                    in_op_ns: if busy { now_ns.saturating_sub(since) } else { 0 },
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_and_phase_tables_roundtrip() {
        for (i, op) in OPS.iter().enumerate() {
            assert_eq!(op_id(op), i as u64);
        }
        assert_eq!(op_id("nope"), 0);
        assert_eq!(phase_id("reduce"), 3);
    }

    #[test]
    fn enter_exit_roundtrips_heartbeat() {
        let b = HealthBoard::new(2);
        b.bucket.store(7, Ordering::Relaxed);
        b.enter(1, op_id("all_gather"), 1_000);
        let snap = b.snapshot(5_000);
        assert!(snap[1].busy && !snap[0].busy);
        assert_eq!(snap[1].op, op_id("all_gather"));
        assert_eq!(snap[1].bucket, 7);
        assert_eq!(snap[1].in_op_ns, 4_000);
        let h = b.exit(1, 6_000).unwrap();
        assert_eq!(h.in_op_ns, 5_000);
        assert_eq!(h.bucket, 7);
        assert!(!b.snapshot(7_000)[1].busy);
        // exit on an idle slot is a no-op
        assert!(b.exit(0, 7_000).is_none());
        // out-of-range ranks never panic
        b.enter(9, 1, 0);
        assert!(b.exit(9, 0).is_none());
    }

    #[test]
    fn stall_scan_detects_and_dedups() {
        let b = HealthBoard::new(3);
        b.enter(2, op_id("reduce_scatter"), 0);
        assert!(b.stalls(500, 1_000).is_empty(), "before the deadline");
        let s = b.stalls(2_000, 1_000);
        assert_eq!(s.len(), 1);
        assert_eq!((s[0].rank, s[0].op), (2, op_id("reduce_scatter")));
        assert_eq!(s[0].for_ns, 2_000);
        // same incident never reported twice
        assert!(b.stalls(3_000, 1_000).is_empty());
        // a new incident (new entry timestamp) reports again
        b.exit(2, 3_000);
        b.enter(2, op_id("all_gather"), 4_000);
        assert_eq!(b.stalls(6_000, 1_000).len(), 1);
    }
}
