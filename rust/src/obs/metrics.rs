//! Metrics registry: counters, gauges, histograms, and per-step series
//! with Prometheus text-format and JSON snapshot exporters.
//!
//! This promotes the PR 5 Chrome-trace counter machinery into a proper
//! registry the monitor can export live: the train session feeds one
//! sample per step (via [`crate::obs::Observer::observe_step`]) and the
//! registry keeps the
//! step-time / exposed-comm / overlap-efficiency / wire-byte /
//! peak-memory series the anomaly pass and `fsdp-report` consume.
//! Metric names are registered as `&'static str`, so the hot path never
//! allocates name strings; series and histogram storage grows by a few
//! machine words per step.

use std::sync::Mutex;

use crate::analysis::diag::{codes, Diagnostic};
use crate::util::json::Json;

/// Default histogram bucket bounds for second-valued observations
/// (1 ms … 60 s, roughly ×2.5 per step).
pub const SECONDS_BOUNDS: [f64; 12] =
    [0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 5.0, 60.0];

#[derive(Debug, Clone)]
pub struct Histogram {
    pub bounds: &'static [f64],
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

impl Histogram {
    fn new(bounds: &'static [f64]) -> Histogram {
        Histogram { bounds, counts: vec![0; bounds.len() + 1], sum: 0.0, count: 0 }
    }

    fn observe(&mut self, v: f64) {
        let i = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.sum += v;
        self.count += 1;
    }
}

#[derive(Debug, Clone, Default)]
struct Series {
    steps: Vec<u64>,
    values: Vec<f64>,
}

#[derive(Debug, Default)]
struct Reg {
    counters: Vec<(&'static str, f64)>,
    gauges: Vec<(&'static str, f64)>,
    histograms: Vec<(&'static str, Histogram)>,
    series: Vec<(&'static str, Series)>,
}

fn slot<'a, T>(list: &'a mut Vec<(&'static str, T)>, name: &'static str, init: impl FnOnce() -> T) -> &'a mut T {
    if let Some(i) = list.iter().position(|(n, _)| *n == name) {
        return &mut list[i].1;
    }
    list.push((name, init()));
    &mut list.last_mut().unwrap().1
}

/// Thread-safe metrics registry. Insertion order of first touch is the
/// export order, so snapshots are deterministic.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<Reg>,
}

fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `v` to a monotonically increasing counter.
    pub fn counter_add(&self, name: &'static str, v: f64) {
        *slot(&mut relock(&self.inner).counters, name, || 0.0) += v;
    }

    /// Set a gauge to its latest value.
    pub fn gauge_set(&self, name: &'static str, v: f64) {
        *slot(&mut relock(&self.inner).gauges, name, || 0.0) = v;
    }

    /// Record one observation into a seconds histogram.
    pub fn observe(&self, name: &'static str, v: f64) {
        slot(&mut relock(&self.inner).histograms, name, || Histogram::new(&SECONDS_BOUNDS))
            .observe(v);
    }

    /// Append one per-step sample to a named series.
    pub fn series_push(&self, name: &'static str, step: u64, v: f64) {
        let mut g = relock(&self.inner);
        let s = slot(&mut g.series, name, Series::default);
        s.steps.push(step);
        s.values.push(v);
    }

    /// Latest values of a series (test/report helper).
    pub fn series(&self, name: &str) -> Vec<f64> {
        relock(&self.inner)
            .series
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, s)| s.values.clone())
            .unwrap_or_default()
    }

    /// Rolling-window anomaly pass: flag step-time samples that exceed,
    /// and overlap-efficiency samples that undercut, the median of the
    /// preceding `window` samples by more than `pct` (fraction, e.g.
    /// 0.5 = 50%). Returns [`codes::METRIC_REGRESSION`] warnings.
    pub fn anomalies(&self, window: usize, pct: f64) -> Vec<Diagnostic> {
        let g = relock(&self.inner);
        let mut out = Vec::new();
        for (name, lower_is_better) in [("step_time_s", true), ("overlap_efficiency", false)] {
            let Some((_, s)) = g.series.iter().find(|(n, _)| *n == name) else { continue };
            for i in window..s.values.len() {
                let base = median(&s.values[i - window..i]);
                let v = s.values[i];
                let bad = if lower_is_better {
                    base > 0.0 && v > base * (1.0 + pct)
                } else {
                    base > 0.0 && v < base * (1.0 - pct)
                };
                if bad {
                    out.push(Diagnostic::warning(
                        codes::METRIC_REGRESSION,
                        format!("step {}", s.steps[i]),
                        format!(
                            "{name} {v:.6} vs rolling median {base:.6} \
                             (window {window}, tolerance {:.0}%)",
                            pct * 100.0
                        ),
                    ));
                }
            }
        }
        out
    }

    /// Prometheus text exposition format (`fsdp_` prefix, `.` → `_`;
    /// series export their latest value with a `step` label-free gauge).
    pub fn prometheus(&self) -> String {
        let g = relock(&self.inner);
        let mut out = String::new();
        for (name, v) in &g.counters {
            let n = prom_name(name);
            out.push_str(&format!(
                "# HELP {n}_total cumulative {name}\n# TYPE {n}_total counter\n{n}_total {v}\n"
            ));
        }
        for (name, v) in &g.gauges {
            let n = prom_name(name);
            out.push_str(&format!("# HELP {n} latest {name}\n# TYPE {n} gauge\n{n} {v}\n"));
        }
        for (name, h) in &g.histograms {
            let n = prom_name(name);
            out.push_str(&format!("# HELP {n} {name} distribution\n# TYPE {n} histogram\n"));
            let mut cum = 0u64;
            for (i, b) in h.bounds.iter().enumerate() {
                cum += h.counts[i];
                out.push_str(&format!("{n}_bucket{{le=\"{b}\"}} {cum}\n"));
            }
            out.push_str(&format!(
                "{n}_bucket{{le=\"+Inf\"}} {}\n{n}_sum {}\n{n}_count {}\n",
                h.count, h.sum, h.count
            ));
        }
        for (name, s) in &g.series {
            let n = prom_name(name);
            if let Some(v) = s.values.last() {
                out.push_str(&format!(
                    "# HELP {n} latest per-step {name}\n# TYPE {n} gauge\n{n} {v}\n"
                ));
            }
        }
        out
    }

    /// `fsdp-metrics-v1` JSON snapshot (the `fsdp-report` input shape).
    pub fn json(&self) -> Json {
        let g = relock(&self.inner);
        Json::obj(vec![
            ("schema", Json::str("fsdp-metrics-v1")),
            (
                "counters",
                Json::obj(g.counters.iter().map(|(n, v)| (*n, Json::num(*v))).collect()),
            ),
            ("gauges", Json::obj(g.gauges.iter().map(|(n, v)| (*n, Json::num(*v))).collect())),
            (
                "histograms",
                Json::obj(
                    g.histograms
                        .iter()
                        .map(|(n, h)| {
                            (
                                *n,
                                Json::obj(vec![
                                    ("sum", Json::num(h.sum)),
                                    ("count", Json::num(h.count as f64)),
                                    (
                                        "bounds",
                                        Json::arr(h.bounds.iter().map(|b| Json::num(*b))),
                                    ),
                                    (
                                        "counts",
                                        Json::arr(h.counts.iter().map(|c| Json::num(*c as f64))),
                                    ),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "series",
                Json::obj(
                    g.series
                        .iter()
                        .map(|(n, s)| {
                            (
                                *n,
                                Json::obj(vec![
                                    (
                                        "steps",
                                        Json::arr(s.steps.iter().map(|x| Json::num(*x as f64))),
                                    ),
                                    (
                                        "values",
                                        Json::arr(s.values.iter().map(|v| Json::num(*v))),
                                    ),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn prom_name(name: &str) -> String {
    let mut n = String::with_capacity(name.len() + 5);
    n.push_str("fsdp_");
    n.extend(name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }));
    n
}

/// Median of a non-empty slice (0.0 when empty).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let mid = v.len() / 2;
    if v.len() % 2 == 1 {
        v[mid]
    } else {
        (v[mid - 1] + v[mid]) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_export() {
        let m = MetricsRegistry::new();
        m.counter_add("wire.bytes", 100.0);
        m.counter_add("wire.bytes", 28.0);
        m.gauge_set("mem.peak_reserved", 4096.0);
        m.observe("step_time_s", 0.002);
        m.observe("step_time_s", 0.2);
        let prom = m.prometheus();
        assert!(prom.contains("fsdp_wire_bytes_total 128"), "{prom}");
        assert!(prom.contains("fsdp_mem_peak_reserved 4096"), "{prom}");
        assert!(prom.contains("fsdp_step_time_s_count 2"), "{prom}");
        assert!(prom.contains("fsdp_step_time_s_bucket{le=\"0.0025\"} 1"), "{prom}");
        let j = m.json();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("fsdp-metrics-v1"));
        assert_eq!(
            j.get("counters").and_then(|c| c.get("wire.bytes")).and_then(Json::as_f64),
            Some(128.0)
        );
        // snapshot parses back (fsdp-report round-trip)
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn series_and_anomaly_pass() {
        let m = MetricsRegistry::new();
        for step in 0..8 {
            m.series_push("step_time_s", step, 0.01);
            m.series_push("overlap_efficiency", step, 0.9);
        }
        assert!(m.anomalies(4, 0.5).is_empty());
        m.series_push("step_time_s", 8, 0.05); // 5x the median
        m.series_push("overlap_efficiency", 8, 0.2); // collapsed overlap
        let diags = m.anomalies(4, 0.5);
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags.iter().all(|d| d.code == codes::METRIC_REGRESSION));
        assert!(diags[0].subject.contains("step 8"));
    }

    #[test]
    fn median_behaves() {
        assert_eq!(median(&[]), 0.0);
        assert_eq!(median(&[3.0]), 3.0);
        assert_eq!(median(&[1.0, 3.0]), 2.0);
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }
}
