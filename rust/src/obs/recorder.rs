//! Flight recorder: a bounded per-rank ring of recent runtime events.
//!
//! Always on once the observer is armed, O(1) per push, and — after the
//! ring warms up to capacity — zero allocation in steady state: events
//! carry only `Copy` fields (`&'static str` kinds/labels, integer
//! payloads, bucket *intern ids* instead of owned names). The postmortem
//! dump resolves intern ids back to bucket names and serializes the last
//! N events per rank as `fsdp-postmortem-v1` JSON.

use crate::util::json::Json;

/// Bucket intern id sentinel: "no bucket context".
pub const NO_BUCKET: u64 = 0;

/// One recorded event. All fields are `Copy` so pushing never allocates.
#[derive(Debug, Clone, Copy)]
pub struct FlightEvent {
    /// Microseconds since the observer's origin instant.
    pub t_us: u64,
    /// Training step the event happened in.
    pub step: u64,
    /// Event class: `"coll"`, `"sched"`, `"alloc"`, `"step"`, `"watchdog"`.
    pub kind: &'static str,
    /// What happened within the class (`"all_gather"`, `"ag_issue"`, …).
    pub what: &'static str,
    /// Bucket intern id + 1 ([`NO_BUCKET`] = none).
    pub bucket: u64,
    /// Event payload (bytes, rank, elapsed µs — kind-specific).
    pub a: u64,
    /// Second payload slot.
    pub b: u64,
}

/// Fixed-capacity ring buffer of [`FlightEvent`]s.
#[derive(Debug)]
pub struct FlightRing {
    buf: Vec<FlightEvent>,
    next: usize,
    /// Total events ever pushed (so the dump can say how many were lost).
    total: u64,
}

impl FlightRing {
    pub fn new(capacity: usize) -> FlightRing {
        FlightRing { buf: Vec::with_capacity(capacity.max(1)), next: 0, total: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Record one event: O(1), and allocation-free once the ring has
    /// warmed to capacity (the backing `Vec` is pre-reserved, so even
    /// warm-up pushes never reallocate).
    pub fn push(&mut self, ev: FlightEvent) {
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
        }
        self.next = (self.next + 1) % self.buf.capacity();
        self.total += 1;
    }

    /// Events oldest → newest (allocates — dump path only).
    pub fn events(&self) -> Vec<FlightEvent> {
        if self.buf.len() < self.buf.capacity() {
            return self.buf.clone();
        }
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.next..]);
        out.extend_from_slice(&self.buf[..self.next]);
        out
    }

    /// JSON array of this ring's events, resolving bucket intern ids
    /// against `bucket_names` (id 1 → `bucket_names[0]`, …).
    pub fn json(&self, bucket_names: &[String]) -> Json {
        Json::arr(self.events().iter().map(|e| {
            let mut pairs = vec![
                ("t_us", Json::num(e.t_us as f64)),
                ("step", Json::num(e.step as f64)),
                ("kind", Json::str(e.kind)),
                ("what", Json::str(e.what)),
                ("a", Json::num(e.a as f64)),
                ("b", Json::num(e.b as f64)),
            ];
            if e.bucket != NO_BUCKET {
                let name = bucket_names
                    .get((e.bucket - 1) as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("?");
                pairs.push(("bucket", Json::str(name)));
            }
            Json::obj(pairs)
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u64) -> FlightEvent {
        FlightEvent { t_us: t, step: 1, kind: "coll", what: "all_gather", bucket: 0, a: t, b: 0 }
    }

    #[test]
    fn ring_wraps_and_keeps_newest() {
        let mut r = FlightRing::new(4);
        for t in 0..10 {
            r.push(ev(t));
        }
        assert_eq!(r.total(), 10);
        let ts: Vec<u64> = r.events().iter().map(|e| e.t_us).collect();
        assert_eq!(ts, vec![6, 7, 8, 9]);
    }

    #[test]
    fn ring_never_grows_past_capacity() {
        let mut r = FlightRing::new(8);
        let cap = r.capacity();
        for t in 0..1000 {
            r.push(ev(t));
        }
        assert_eq!(r.capacity(), cap, "steady state must not reallocate");
        assert_eq!(r.events().len(), cap);
    }

    #[test]
    fn json_resolves_bucket_names() {
        let mut r = FlightRing::new(4);
        let mut e = ev(5);
        e.bucket = 1;
        r.push(e);
        let names = vec!["layer0".to_string()];
        let j = r.json(&names);
        let first = j.idx(0).unwrap();
        assert_eq!(first.get("bucket").and_then(Json::as_str), Some("layer0"));
        assert_eq!(first.get("t_us").and_then(Json::as_f64), Some(5.0));
        // unknown intern ids degrade to "?" rather than panic
        let mut r2 = FlightRing::new(2);
        e.bucket = 9;
        r2.push(e);
        assert_eq!(r2.json(&names).idx(0).unwrap().get("bucket").and_then(Json::as_str), Some("?"));
    }
}
