//! Live runtime health monitor: heartbeats, collective watchdog, flight
//! recorder, and metrics export.
//!
//! The [`Observer`] is the single handle the cluster backends, the
//! executor, and the train session share. It is cheap to clone (an
//! `Option<Arc>`), and **when disarmed it costs at most one branch per
//! event**: every recording method starts with a single
//! `Option::is_none` check and returns immediately — no locks, no
//! atomics, no allocation, no clock reads. Monitoring is also *pure*:
//! armed or not, it never touches training state, so loss trajectories
//! are bit-identical with the monitor on and off (enforced by
//! `tests/health_monitor.rs`).
//!
//! Armed, the observer provides four surfaces:
//!
//! 1. **Heartbeats + watchdog** ([`health`]): rank threads publish
//!    lock-free heartbeats (step, phase, collective, bucket) into a
//!    shared [`HealthBoard`]; a monitor thread — plus a synchronous
//!    check on every collective exit, so detection does not depend on
//!    scheduler timing — reports ranks stalled in one rendezvous past
//!    `watchdog_ms` as [`codes::WATCHDOG_STALL`] diagnostics naming the
//!    rank, collective, and bucket. Rendezvous dwell times also feed
//!    per-step straggler attribution (max/median rank skew).
//! 2. **Flight recorder** ([`recorder`]): a bounded per-rank ring of
//!    recent events (collectives, allocator claims, step boundaries) —
//!    O(1) per event, allocation-free in steady state — dumped as an
//!    `fsdp-postmortem-v1` JSON on panic, watchdog firing, or
//!    `train --postmortem-on-exit`.
//! 3. **Metrics** ([`metrics`]): a [`MetricsRegistry`] of counters,
//!    gauges, histograms, and per-step series with Prometheus and JSON
//!    exporters plus a rolling-window anomaly pass
//!    ([`codes::METRIC_REGRESSION`]).
//! 4. **Postmortems**: [`Observer::postmortem`] assembles ring
//!    contents, a health-board snapshot, memory peaks, diagnostics, and
//!    the metrics snapshot into one structured document.

pub mod health;
pub mod metrics;
pub mod recorder;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, Once, Weak};
use std::time::{Duration, Instant};

use crate::analysis::diag::{codes, rt, Diagnostic};
use crate::util::json::Json;
pub use health::{HealthBoard, RankHealth, Stall, OPS, PHASES};
pub use metrics::MetricsRegistry;
pub use recorder::{FlightEvent, FlightRing, NO_BUCKET};

/// Observer knobs (the `[obs]` config section / `--watchdog-ms` family).
#[derive(Debug, Clone, PartialEq)]
pub struct ObsConfig {
    /// Watchdog deadline in milliseconds; 0 disables the watchdog (the
    /// board and recorder still run when the observer is armed).
    pub watchdog_ms: u64,
    /// Flight-recorder capacity per rank (events).
    pub ring_capacity: usize,
    /// Rolling-window length for the metric anomaly pass.
    pub anomaly_window: usize,
    /// Regression tolerance for the anomaly pass (fraction, 0.5 = 50%).
    pub anomaly_pct: f64,
    /// Where to write the postmortem JSON when the watchdog fires or the
    /// process panics (`None` = only on explicit request).
    pub postmortem_path: Option<String>,
}

impl Default for ObsConfig {
    fn default() -> ObsConfig {
        ObsConfig {
            watchdog_ms: 0,
            ring_capacity: 64,
            anomaly_window: 8,
            anomaly_pct: 0.5,
            postmortem_path: None,
        }
    }
}

#[derive(Debug)]
struct ObsInner {
    cfg: ObsConfig,
    origin: Instant,
    board: HealthBoard,
    rings: Vec<Mutex<FlightRing>>,
    /// Bucket-name intern table; ring events store `index + 1`.
    buckets: Mutex<Vec<String>>,
    /// Per-rank rendezvous dwell this step (ns), reset by `observe_step`.
    wait_ns: Vec<AtomicU64>,
    metrics: MetricsRegistry,
    diags: Mutex<Vec<Diagnostic>>,
    peak_reserved: AtomicU64,
    peak_allocated: AtomicU64,
    stop: AtomicBool,
    monitor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Shared handle to the runtime health monitor. `Observer::off()` (the
/// `Default`) is a true no-op: one branch per recording call.
#[derive(Debug, Clone, Default)]
pub struct Observer {
    inner: Option<Arc<ObsInner>>,
}

impl Observer {
    /// The disarmed observer — every method is a single-branch no-op.
    pub fn off() -> Observer {
        Observer { inner: None }
    }

    /// Arm the monitor for `ranks` rank threads. Spawns the watchdog
    /// monitor thread when `cfg.watchdog_ms > 0`.
    pub fn new(cfg: ObsConfig, ranks: usize) -> Observer {
        let ranks = ranks.max(1);
        let watchdog_ms = cfg.watchdog_ms;
        let inner = Arc::new(ObsInner {
            board: HealthBoard::new(ranks),
            rings: (0..ranks).map(|_| Mutex::new(FlightRing::new(cfg.ring_capacity))).collect(),
            buckets: Mutex::new(Vec::new()),
            wait_ns: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
            metrics: MetricsRegistry::new(),
            diags: Mutex::new(Vec::new()),
            peak_reserved: AtomicU64::new(0),
            peak_allocated: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            monitor: Mutex::new(None),
            origin: Instant::now(),
            cfg,
        });
        if watchdog_ms > 0 {
            let weak: Weak<ObsInner> = Arc::downgrade(&inner);
            let poll = Duration::from_millis((watchdog_ms / 4).max(1));
            // Sleep in short ticks between scans so `shutdown` joins
            // promptly even under a multi-second watchdog deadline.
            let tick = poll.min(Duration::from_millis(25));
            let handle = std::thread::Builder::new()
                .name("fsdp-watchdog".into())
                .spawn(move || {
                    let mut since_scan = Duration::ZERO;
                    loop {
                        std::thread::sleep(tick);
                        let Some(inner) = weak.upgrade() else { break };
                        if inner.stop.load(Ordering::Relaxed) {
                            break;
                        }
                        since_scan += tick;
                        if since_scan >= poll {
                            since_scan = Duration::ZERO;
                            ObsInner::scan(&inner);
                        }
                    }
                })
                .ok();
            *relock(&inner.monitor) = handle;
        }
        Observer { inner: Some(inner) }
    }

    /// Is the monitor armed? The off path of every recording method is
    /// exactly this branch.
    pub fn armed(&self) -> bool {
        self.inner.is_some()
    }

    pub fn ranks(&self) -> usize {
        self.inner.as_ref().map(|i| i.board.ranks()).unwrap_or(0)
    }

    pub fn config(&self) -> Option<&ObsConfig> {
        self.inner.as_deref().map(|i| &i.cfg)
    }

    /// The metrics registry, when armed.
    pub fn metrics(&self) -> Option<&MetricsRegistry> {
        self.inner.as_deref().map(|i| &i.metrics)
    }

    // ---- schedule context (executor / session side) ----------------------

    /// Publish the current (1-based) training step and record the step
    /// boundary on every rank's ring.
    pub fn set_step(&self, step: u64) {
        let Some(inner) = &self.inner else { return };
        inner.board.step.store(step, Ordering::Relaxed);
        inner.flight_all("step", "begin", step, 0);
    }

    /// Publish the executor phase (`"gather"`, `"compute"`, …).
    pub fn set_phase(&self, phase: &'static str) {
        let Some(inner) = &self.inner else { return };
        inner.board.phase.store(health::phase_id(phase), Ordering::Relaxed);
    }

    /// Publish the bucket the schedule is currently driving; heartbeats
    /// and ring events record its intern id until the next call.
    pub fn set_bucket(&self, name: &str) {
        let Some(inner) = &self.inner else { return };
        let id = inner.intern(name);
        inner.board.bucket.store(id, Ordering::Relaxed);
    }

    /// Clear the bucket context (between buckets / at step end).
    pub fn clear_bucket(&self) {
        let Some(inner) = &self.inner else { return };
        inner.board.bucket.store(NO_BUCKET, Ordering::Relaxed);
    }

    // ---- heartbeats (cluster backend side) -------------------------------

    /// Rank `rank` entered collective `op` (a [`health::OPS`] name).
    pub fn rank_enter(&self, rank: usize, op: &'static str) {
        let Some(inner) = &self.inner else { return };
        let now = inner.now_ns();
        inner.board.enter(rank, health::op_id(op), now);
        inner.flight(rank, "coll", op, rank as u64, 0);
    }

    /// Rank `rank` left its collective. Accounts the dwell toward the
    /// step's straggler attribution and runs the synchronous watchdog
    /// deadline check, so an injected stall is detected deterministically
    /// even if the monitor thread never got scheduled.
    pub fn rank_exit(&self, rank: usize) {
        let Some(inner) = &self.inner else { return };
        let now = inner.now_ns();
        let Some(h) = inner.board.exit(rank, now) else { return };
        if let Some(w) = inner.wait_ns.get(rank) {
            w.fetch_add(h.in_op_ns, Ordering::Relaxed);
        }
        let deadline_ns = inner.cfg.watchdog_ms.saturating_mul(1_000_000);
        if deadline_ns > 0 && h.in_op_ns >= deadline_ns {
            let since = now.saturating_sub(h.in_op_ns);
            if inner.board.try_claim_report(rank, since) {
                ObsInner::report_stall(
                    inner,
                    Stall { rank, op: h.op, bucket: h.bucket, for_ns: h.in_op_ns },
                );
            }
        }
    }

    // ---- flight recorder -------------------------------------------------

    /// Record one event on `rank`'s ring (O(1), no steady-state alloc).
    pub fn flight(&self, rank: usize, kind: &'static str, what: &'static str, a: u64, b: u64) {
        let Some(inner) = &self.inner else { return };
        inner.flight(rank, kind, what, a, b);
    }

    /// Record one schedule-wide event on every rank's ring.
    pub fn flight_all(&self, kind: &'static str, what: &'static str, a: u64, b: u64) {
        let Some(inner) = &self.inner else { return };
        inner.flight_all(kind, what, a, b);
    }

    // ---- metrics ---------------------------------------------------------

    /// Track allocator peaks for the postmortem memory section.
    pub fn note_memory(&self, peak_reserved: u64, peak_allocated: u64) {
        let Some(inner) = &self.inner else { return };
        inner.peak_reserved.fetch_max(peak_reserved, Ordering::Relaxed);
        inner.peak_allocated.fetch_max(peak_allocated, Ordering::Relaxed);
    }

    /// Feed one finished step into the registry: step-time / exposed /
    /// overlap / wire-byte / peak-memory series plus max-median rank
    /// skew derived from the rendezvous dwell accumulated since the last
    /// call. `wire_bytes` is this step's delta.
    #[allow(clippy::too_many_arguments)]
    pub fn observe_step(
        &self,
        step: u64,
        wall_s: f64,
        exposed_comm_s: f64,
        overlap_efficiency: f64,
        wire_bytes: u64,
        peak_reserved: u64,
        peak_allocated: u64,
    ) {
        let Some(inner) = &self.inner else { return };
        let m = &inner.metrics;
        m.series_push("step_time_s", step, wall_s);
        m.series_push("exposed_comm_s", step, exposed_comm_s);
        m.series_push("overlap_efficiency", step, overlap_efficiency);
        m.series_push("wire_bytes", step, wire_bytes as f64);
        m.series_push("peak_reserved_bytes", step, peak_reserved as f64);
        m.series_push("peak_allocated_bytes", step, peak_allocated as f64);
        m.observe("step_time_s", wall_s);
        m.counter_add("wire.bytes", wire_bytes as f64);
        m.gauge_set("mem.peak_reserved", peak_reserved as f64);
        m.gauge_set("mem.peak_allocated", peak_allocated as f64);
        let waits: Vec<f64> = inner
            .wait_ns
            .iter()
            .map(|w| w.swap(0, Ordering::Relaxed) as f64 / 1e9)
            .collect();
        let max = waits.iter().cloned().fold(0.0_f64, f64::max);
        let skew = (max - metrics::median(&waits)).max(0.0);
        m.series_push("rank_skew_s", step, skew);
        self.note_memory(peak_reserved, peak_allocated);
        inner.flight_all("step", "end", step, 0);
    }

    // ---- findings & dumps ------------------------------------------------

    /// All findings so far: watchdog stalls plus the metric anomaly pass.
    pub fn diagnostics(&self) -> Vec<Diagnostic> {
        let Some(inner) = &self.inner else { return Vec::new() };
        let mut out = relock(&inner.diags).clone();
        out.extend(inner.metrics.anomalies(inner.cfg.anomaly_window, inner.cfg.anomaly_pct));
        out
    }

    /// Did the collective watchdog report at least one stalled rank?
    pub fn watchdog_fired(&self) -> bool {
        let Some(inner) = &self.inner else { return false };
        relock(&inner.diags).iter().any(|d| d.code == codes::WATCHDOG_STALL)
    }

    /// Assemble the `fsdp-postmortem-v1` document: last-N events per
    /// rank, health-board snapshot, memory peaks, diagnostics, and the
    /// metrics snapshot.
    pub fn postmortem(&self) -> Json {
        let Some(inner) = &self.inner else {
            return Json::obj(vec![("schema", Json::str("fsdp-postmortem-v1"))]);
        };
        let now = inner.now_ns();
        let buckets = relock(&inner.buckets).clone();
        let health = inner.board.snapshot(now);
        let bucket_name = |id: u64| -> Json {
            if id == NO_BUCKET {
                Json::Null
            } else {
                Json::str(buckets.get((id - 1) as usize).map(|s| s.as_str()).unwrap_or("?"))
            }
        };
        Json::obj(vec![
            ("schema", Json::str("fsdp-postmortem-v1")),
            ("ranks", Json::num(inner.board.ranks() as f64)),
            ("t_us", Json::num((now / 1_000) as f64)),
            (
                "health",
                Json::obj(vec![
                    ("step", Json::num(inner.board.step.load(Ordering::Relaxed) as f64)),
                    (
                        "phase",
                        Json::str(
                            PHASES
                                .get(inner.board.phase.load(Ordering::Relaxed) as usize)
                                .unwrap_or(&"idle"),
                        ),
                    ),
                    ("bucket", bucket_name(inner.board.bucket.load(Ordering::Relaxed))),
                    (
                        "ranks",
                        Json::arr(health.iter().map(|h| {
                            Json::obj(vec![
                                ("rank", Json::num(h.rank as f64)),
                                ("busy", Json::Bool(h.busy)),
                                ("op", Json::str(OPS.get(h.op as usize).unwrap_or(&"idle"))),
                                ("bucket", bucket_name(h.bucket)),
                                ("in_op_ms", Json::num(h.in_op_ns as f64 / 1e6)),
                            ])
                        })),
                    ),
                ]),
            ),
            (
                "events",
                Json::arr(inner.rings.iter().map(|r| relock(r).json(&buckets))),
            ),
            (
                "memory",
                Json::obj(vec![
                    (
                        "peak_reserved",
                        Json::num(inner.peak_reserved.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "peak_allocated",
                        Json::num(inner.peak_allocated.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            ("diagnostics", Json::arr(self.diagnostics().iter().map(Diagnostic::json))),
            ("metrics", inner.metrics.json()),
        ])
    }

    /// Write the postmortem JSON to `path` ([`codes::EXPORT_IO`] on
    /// failure).
    pub fn write_postmortem(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, format!("{}\n", self.postmortem()))
            .map_err(|e| rt(codes::EXPORT_IO, format!("writing postmortem {path}: {e}")))
    }

    /// Stop and join the monitor thread (idempotent; dropping the last
    /// clone also ends it at its next poll tick).
    pub fn shutdown(&self) {
        let Some(inner) = &self.inner else { return };
        inner.stop.store(true, Ordering::Relaxed);
        if let Some(h) = relock(&inner.monitor).take() {
            let _ = h.join();
        }
    }
}

impl ObsInner {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    fn intern(&self, name: &str) -> u64 {
        let mut b = relock(&self.buckets);
        if let Some(i) = b.iter().position(|n| n == name) {
            return (i + 1) as u64;
        }
        b.push(name.to_string());
        b.len() as u64
    }

    fn flight(&self, rank: usize, kind: &'static str, what: &'static str, a: u64, b: u64) {
        let Some(ring) = self.rings.get(rank) else { return };
        let ev = FlightEvent {
            t_us: self.now_ns() / 1_000,
            step: self.board.step.load(Ordering::Relaxed),
            kind,
            what,
            bucket: self.board.bucket.load(Ordering::Relaxed),
            a,
            b,
        };
        relock(ring).push(ev);
    }

    fn flight_all(&self, kind: &'static str, what: &'static str, a: u64, b: u64) {
        for rank in 0..self.rings.len() {
            self.flight(rank, kind, what, a, b);
        }
    }

    /// One watchdog poll: report every newly stalled rank.
    fn scan(inner: &Arc<ObsInner>) {
        let deadline_ns = inner.cfg.watchdog_ms.saturating_mul(1_000_000);
        if deadline_ns == 0 {
            return;
        }
        for stall in inner.board.stalls(inner.now_ns(), deadline_ns) {
            ObsInner::report_stall(inner, stall);
        }
    }

    fn report_stall(inner: &Arc<ObsInner>, stall: Stall) {
        let op = OPS.get(stall.op as usize).unwrap_or(&"idle");
        let bucket = if stall.bucket == NO_BUCKET {
            "<none>".to_string()
        } else {
            relock(&inner.buckets)
                .get((stall.bucket - 1) as usize)
                .cloned()
                .unwrap_or_else(|| "?".to_string())
        };
        let d = Diagnostic::error(
            codes::WATCHDOG_STALL,
            format!("rank {}", stall.rank),
            format!(
                "rank {} stalled in {} (bucket {}) for {:.1} ms — watchdog deadline {} ms, step {}",
                stall.rank,
                op,
                bucket,
                stall.for_ns as f64 / 1e6,
                inner.cfg.watchdog_ms,
                inner.board.step.load(Ordering::Relaxed),
            ),
        );
        eprintln!("{d}");
        inner.flight(stall.rank, "watchdog", "stall", stall.rank as u64, stall.for_ns / 1_000);
        relock(&inner.diags).push(d);
        if let Some(path) = inner.cfg.postmortem_path.clone() {
            let obs = Observer { inner: Some(inner.clone()) };
            match obs.write_postmortem(&path) {
                Ok(()) => eprintln!("[obs] postmortem written to {path}"),
                Err(e) => eprintln!("[obs] {e}"),
            }
        }
    }
}

static PANIC_DUMP: Mutex<Option<Observer>> = Mutex::new(None);
static PANIC_HOOK: Once = Once::new();

/// Register `obs` as the panic-time postmortem target. The (chained)
/// hook is installed once per process; the most recently registered
/// armed observer with a `postmortem_path` wins.
pub fn install_panic_hook(obs: &Observer) {
    if obs.config().and_then(|c| c.postmortem_path.as_ref()).is_none() {
        return;
    }
    *relock(&PANIC_DUMP) = Some(obs.clone());
    PANIC_HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            prev(info);
            let obs = relock(&PANIC_DUMP).clone();
            if let Some(obs) = obs {
                if let Some(path) =
                    obs.config().and_then(|c| c.postmortem_path.clone())
                {
                    match obs.write_postmortem(&path) {
                        Ok(()) => eprintln!("[obs] postmortem written to {path}"),
                        Err(e) => eprintln!("[obs] {e}"),
                    }
                }
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_observer_is_inert() {
        let obs = Observer::off();
        assert!(!obs.armed());
        assert_eq!(obs.ranks(), 0);
        obs.set_step(3);
        obs.set_phase("compute");
        obs.set_bucket("layer0");
        obs.rank_enter(0, "all_gather");
        obs.rank_exit(0);
        obs.flight(0, "alloc", "staged", 1, 2);
        obs.observe_step(1, 0.1, 0.01, 0.9, 100, 10, 5);
        assert!(obs.diagnostics().is_empty());
        assert!(!obs.watchdog_fired());
        assert!(obs.metrics().is_none());
        assert_eq!(
            obs.postmortem().get("schema").and_then(Json::as_str),
            Some("fsdp-postmortem-v1")
        );
        obs.shutdown();
    }

    #[test]
    fn armed_observer_records_and_dumps() {
        let obs = Observer::new(ObsConfig::default(), 2);
        obs.set_step(1);
        obs.set_phase("gather");
        obs.set_bucket("embed");
        obs.rank_enter(0, "all_gather");
        obs.rank_enter(1, "all_gather");
        obs.rank_exit(0);
        obs.rank_exit(1);
        obs.clear_bucket();
        obs.observe_step(1, 0.01, 0.002, 0.8, 4096, 1 << 20, 1 << 19);
        let pm = obs.postmortem();
        assert_eq!(pm.get("schema").and_then(Json::as_str), Some("fsdp-postmortem-v1"));
        assert_eq!(pm.get("ranks").and_then(Json::as_f64), Some(2.0));
        let events = pm.get("events").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), 2);
        // each rank saw: step begin, its collective, step end
        for ring in events {
            let evs = ring.as_arr().unwrap();
            assert!(evs.len() >= 3, "{evs:?}");
            assert!(evs.iter().all(|e| {
                e.get("t_us").is_some() && e.get("kind").is_some() && e.get("what").is_some()
            }));
            assert!(evs
                .iter()
                .any(|e| e.get("kind").and_then(Json::as_str) == Some("coll")
                    && e.get("bucket").and_then(Json::as_str) == Some("embed")));
        }
        assert_eq!(
            pm.get("metrics").and_then(|m| m.get("schema")).and_then(Json::as_str),
            Some("fsdp-metrics-v1")
        );
        // parses back as strict JSON
        assert!(Json::parse(&pm.to_string()).is_ok());
        assert!(obs.diagnostics().is_empty());
        obs.shutdown();
    }

    #[test]
    fn exit_path_deadline_check_reports_stall() {
        let cfg = ObsConfig { watchdog_ms: 5, ..ObsConfig::default() };
        let obs = Observer::new(cfg, 2);
        obs.set_bucket("head");
        obs.rank_enter(1, "reduce_scatter");
        std::thread::sleep(Duration::from_millis(20));
        obs.rank_exit(1);
        assert!(obs.watchdog_fired());
        let diags = obs.diagnostics();
        let stall = diags.iter().find(|d| d.code == codes::WATCHDOG_STALL).unwrap();
        assert!(stall.message.contains("rank 1"), "{}", stall.message);
        assert!(stall.message.contains("reduce_scatter"), "{}", stall.message);
        assert!(stall.message.contains("head"), "{}", stall.message);
        obs.shutdown();
    }

    #[test]
    fn monitor_thread_detects_live_stall() {
        let cfg = ObsConfig { watchdog_ms: 4, ..ObsConfig::default() };
        let obs = Observer::new(cfg, 1);
        obs.rank_enter(0, "all_to_all");
        // never exits: only the monitor thread can see this one
        let mut fired = false;
        for _ in 0..200 {
            std::thread::sleep(Duration::from_millis(2));
            if obs.watchdog_fired() {
                fired = true;
                break;
            }
        }
        assert!(fired, "monitor thread never reported the stall");
        obs.shutdown();
    }

    #[test]
    fn rank_skew_attribution() {
        let obs = Observer::new(ObsConfig::default(), 4);
        // simulate dwell: rank 3 waited much longer than the others
        let inner = obs.inner.as_ref().unwrap();
        for (rank, ns) in [(0usize, 1_000_000u64), (1, 1_200_000), (2, 900_000), (3, 9_000_000)] {
            inner.wait_ns[rank].store(ns, Ordering::Relaxed);
        }
        obs.observe_step(1, 0.05, 0.01, 0.7, 0, 0, 0);
        let skew = obs.metrics().unwrap().series("rank_skew_s");
        assert_eq!(skew.len(), 1);
        assert!((skew[0] - (0.009 - 0.0011)).abs() < 1e-9, "{skew:?}");
        // accumulators reset after the step
        obs.observe_step(2, 0.05, 0.01, 0.7, 0, 0, 0);
        assert_eq!(obs.metrics().unwrap().series("rank_skew_s")[1], 0.0);
        obs.shutdown();
    }
}
