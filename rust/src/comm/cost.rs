//! Analytic fabric cost model (α–β with hierarchy, alignment and
//! fragmentation effects) — the timing half of the NCCL substitute.
//!
//! Calibrated against the paper's Table 1 (GPT-OSS-120B on 64 H800s):
//! AllGather 43.71 ms and interleaved Copy-Out 5.22 ms over the same
//! ~6.4 GB bf16 bucket imply an effective cross-node collective bandwidth
//! of ≈145 GB/s per rank-payload and a contiguous device-copy bandwidth of
//! ≈1.25 TB/s; ReduceScatter at 94.24 ms implies an RS/AG bandwidth ratio
//! of ≈0.46 (NCCL RS pays the reduction). The model reproduces the
//! *mechanisms* the paper measures:
//!
//! * unaligned buffer addresses degrade collective bandwidth
//!   (NCCL#413 — FSDP1/FSDP2 don't enforce alignment);
//! * many small collectives pay per-launch latency
//!   (DeepSpeed#5047 — fragmented AllGathers);
//! * interleaved (strided) copies run far below contiguous copy bandwidth
//!   (FSDP2's Copy-In/Copy-Out, Table 1's Shard(1) column);
//! * groups spanning nodes drop from NVLink to the IB tier.

/// Physical cluster shape for hierarchical collectives: `hosts` nodes of
/// `gpus_per_host` ranks each (rank r lives at host r / gpus_per_host —
/// host-major order), plus the segment count S of the intra-collective
/// chunk pipeline (inter-host transfers of segment s overlap intra-host
/// work on segment s+1).
///
/// `hosts == 1` is the flat degenerate case: every collective runs the
/// single-ring algorithms unchanged, so a flat `Topology` is free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Number of hosts (nodes).
    pub hosts: usize,
    /// Ranks per host.
    pub gpus_per_host: usize,
    /// Pipeline segments per collective (S >= 1).
    pub segments: usize,
}

impl Default for Topology {
    fn default() -> Topology {
        Topology::flat()
    }
}

impl Topology {
    /// The flat single-tier default: one host, collectives run the
    /// legacy ring algorithms.
    pub fn flat() -> Topology {
        Topology { hosts: 1, gpus_per_host: 8, segments: 1 }
    }

    /// Parse `"HxG"` or `"HxG:S"` (e.g. `2x4`, `4x8:2`). Hosts, GPUs and
    /// segments must all be >= 1.
    pub fn parse(s: &str) -> Option<Topology> {
        let (shape, segs) = match s.split_once(':') {
            Some((a, b)) => (a, b.trim().parse::<usize>().ok()?),
            None => (s, 2),
        };
        let (h, g) = shape.trim().split_once('x')?;
        let hosts = h.trim().parse::<usize>().ok()?;
        let gpus = g.trim().parse::<usize>().ok()?;
        if hosts == 0 || gpus == 0 || segs == 0 {
            return None;
        }
        Some(Topology { hosts, gpus_per_host: gpus, segments: segs })
    }

    /// `"HxG"` display form (step logs, trace metadata, bench JSON).
    pub fn label(&self) -> String {
        format!("{}x{}", self.hosts, self.gpus_per_host)
    }

    /// Total ranks the topology describes.
    pub fn total(&self) -> usize {
        self.hosts * self.gpus_per_host
    }

    /// More than one host => the two-level algorithms apply.
    pub fn is_hierarchical(&self) -> bool {
        self.hosts > 1
    }
}

/// Device-local copy flavors (Table 1's three copy regimes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyKind {
    /// DBuffer zero-copy: no bytes move at all.
    ZeroCopy,
    /// Contiguous device copy (cudaMemcpy-like).
    Contiguous,
    /// Row-interleaved gather/scatter (FSDP2 Shard(0) copy-out).
    InterleavedRows,
    /// Column-interleaved (FSDP2 Shard(1)): finer strides, worse bw.
    InterleavedCols,
}

#[derive(Debug, Clone)]
pub struct Fabric {
    /// Preset name (`--fabric` flag; recorded in step logs / bench JSON).
    pub name: &'static str,
    /// Effective per-rank collective bandwidth within one node (bytes/s).
    pub intra_bw: f64,
    /// Effective per-rank collective bandwidth when the group spans nodes.
    pub inter_bw: f64,
    /// ReduceScatter bandwidth ratio vs AllGather (reduction cost).
    pub rs_factor: f64,
    /// Per-collective launch latency (s) for flat single-ring ops.
    pub launch: f64,
    /// Launch latency of the intra-host (NVLink) phase of a hierarchical
    /// collective.
    pub intra_launch: f64,
    /// Launch latency of the inter-host (IB) phase — NIC doorbells and
    /// QP setup cost more than an NVLink kernel launch.
    pub inter_launch: f64,
    /// GPUs per node.
    pub devices_per_node: usize,
    /// Cluster shape for hierarchical dispatch (`hosts == 1` = flat).
    pub topology: Topology,
    /// Bandwidth multiplier when buffers are not NCCL-aligned.
    pub misalign_factor: f64,
    /// Contiguous device-copy bandwidth (bytes/s).
    pub copy_bw: f64,
    /// Relative copy bandwidth for interleaved rows / cols.
    pub interleave_rows_factor: f64,
    pub interleave_cols_factor: f64,
    /// Required address/size alignment (bytes) for full collective speed.
    pub align_bytes: u64,
}

impl Fabric {
    /// H800 cluster of the paper (§6 hardware), Table-1 calibrated.
    pub fn h800() -> Fabric {
        Fabric {
            name: "h800",
            intra_bw: 350e9,
            inter_bw: 145e9,
            rs_factor: 0.464,
            launch: 20e-6,
            intra_launch: 10e-6,
            inter_launch: 20e-6,
            devices_per_node: 8,
            topology: Topology::flat(),
            // average-case penalty: NCCL#413 shows up to ~2x degradation
            // on pathological alignments; typical buffers lose ~20%
            misalign_factor: 0.8,
            copy_bw: 1.25e12,
            interleave_rows_factor: 1.0,
            interleave_cols_factor: 0.38,
            align_bytes: 16,
        }
    }

    /// H100 SXM cluster: full-rate NVLink4 and 400 Gb/s IB per GPU
    /// (the export-unrestricted sibling of the H800 — same copy engines,
    /// faster inter-node tier).
    pub fn h100() -> Fabric {
        Fabric {
            name: "h100",
            intra_bw: 400e9,
            inter_bw: 190e9,
            rs_factor: 0.464,
            launch: 20e-6,
            intra_launch: 10e-6,
            inter_launch: 20e-6,
            devices_per_node: 8,
            topology: Topology::flat(),
            misalign_factor: 0.8,
            copy_bw: 1.35e12,
            interleave_rows_factor: 1.0,
            interleave_cols_factor: 0.38,
            align_bytes: 16,
        }
    }

    /// A100 SXM cluster: NVLink3 + 200 Gb/s IB, slower HBM2e copy engines
    /// and a slightly higher launch overhead (older driver stack).
    pub fn a100() -> Fabric {
        Fabric {
            name: "a100",
            intra_bw: 230e9,
            inter_bw: 90e9,
            rs_factor: 0.464,
            launch: 25e-6,
            intra_launch: 12e-6,
            inter_launch: 25e-6,
            devices_per_node: 8,
            topology: Topology::flat(),
            misalign_factor: 0.8,
            copy_bw: 0.9e12,
            interleave_rows_factor: 1.0,
            interleave_cols_factor: 0.38,
            align_bytes: 16,
        }
    }

    /// Look a fabric preset up by name (`--fabric h800|h100|a100`),
    /// optionally suffixed with a topology: `"h800:2x4"` /
    /// `"h800:2x4:2"` (hosts x gpus-per-host [: pipeline segments]).
    pub fn by_name(s: &str) -> Option<Fabric> {
        let (base, topo) = match s.split_once(':') {
            Some((b, t)) => (b, Some(Topology::parse(t)?)),
            None => (s, None),
        };
        let mut f = match base.to_ascii_lowercase().as_str() {
            "h800" => Fabric::h800(),
            "h100" => Fabric::h100(),
            "a100" => Fabric::a100(),
            _ => return None,
        };
        if let Some(t) = topo {
            f.topology = t;
        }
        Some(f)
    }

    /// The same fabric with a different cluster topology attached.
    pub fn with_topology(mut self, topology: Topology) -> Fabric {
        self.topology = topology;
        self
    }

    /// All preset names, for error messages.
    pub fn preset_names() -> [&'static str; 3] {
        ["h800", "h100", "a100"]
    }

    /// Collective bandwidth for a group of `m` ranks.
    fn coll_bw(&self, m: usize, aligned: bool) -> f64 {
        let base = if m <= self.devices_per_node {
            self.intra_bw
        } else {
            self.inter_bw
        };
        if aligned {
            base
        } else {
            base * self.misalign_factor
        }
    }

    /// Does a group of `m` ranks dispatch to the two-level algorithms?
    /// (Hierarchical topology attached and the group fills it exactly —
    /// smaller groups, e.g. the EP all-to-all or the HSDP replica
    /// AllReduce, keep the flat model.)
    pub fn is_hier(&self, m: usize) -> bool {
        self.topology.is_hierarchical() && m == self.topology.total() && m > 1
    }

    fn tier_bws(&self, aligned: bool) -> (f64, f64) {
        let k = if aligned { 1.0 } else { self.misalign_factor };
        (self.intra_bw * k, self.inter_bw * k)
    }

    /// Hierarchical cost: both launches, the slower tier in full, and the
    /// faster tier's tail — segment pipelining hides min(Ti, Te) up to
    /// one 1/S-sized segment.
    fn hier_time(&self, ti: f64, te: f64) -> f64 {
        let s = self.topology.segments.max(1) as f64;
        self.intra_launch + self.inter_launch + ti.max(te) + ti.min(te) / s
    }

    /// Ring AllGather: each rank receives (m-1) shards of
    /// `bytes_per_rank`. With a hierarchical topology covering the group,
    /// the two-level algorithm pays (g-1) intra-host shard hops plus
    /// (H-1)·g inter-host hops, overlapped by segment pipelining.
    pub fn all_gather_time(&self, m: usize, bytes_per_rank: u64, aligned: bool) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        if self.is_hier(m) {
            let (g, h) = (self.topology.gpus_per_host, self.topology.hosts);
            let (bwi, bwe) = self.tier_bws(aligned);
            let b = bytes_per_rank as f64;
            let ti = b * (g - 1) as f64 / bwi;
            let te = b * ((h - 1) * g) as f64 / bwe;
            return self.hier_time(ti, te);
        }
        self.launch
            + bytes_per_rank as f64 * (m - 1) as f64 / self.coll_bw(m, aligned)
    }

    /// Ring ReduceScatter: same volume as AG, lower effective bandwidth.
    /// Hierarchically, the intra-host pre-reduce collapses g contributions
    /// before anything crosses the NIC, so the inter tier moves only
    /// (H-1) shard hops — the g-fold volume reduction that makes
    /// hierarchy win at scale.
    pub fn reduce_scatter_time(&self, m: usize, bytes_per_rank: u64, aligned: bool) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        if self.is_hier(m) {
            let (g, h) = (self.topology.gpus_per_host, self.topology.hosts);
            let (bwi, bwe) = self.tier_bws(aligned);
            let b = bytes_per_rank as f64;
            let ti = b * (g - 1) as f64 / (bwi * self.rs_factor);
            let te = b * (h - 1) as f64 / (bwe * self.rs_factor);
            return self.hier_time(ti, te);
        }
        self.launch
            + bytes_per_rank as f64 * (m - 1) as f64
                / (self.coll_bw(m, aligned) * self.rs_factor)
    }

    /// Per-tier wire bytes one rank moves for `op` at group size `m`
    /// (`(intra, inter)`): the attribution half of the two-tier model.
    /// Flat groups charge everything to whichever single tier they run
    /// on; hierarchical AG/RS split by the two-level hop counts.
    pub fn tier_bytes(&self, op: &str, m: usize, bytes_per_rank: u64) -> (u64, u64) {
        if m <= 1 {
            return (0, 0);
        }
        let b = bytes_per_rank;
        if self.is_hier(m) && (op == "all_gather" || op == "reduce_scatter") {
            let (g, h) = (self.topology.gpus_per_host as u64, self.topology.hosts as u64);
            let inter = if op == "all_gather" { (h - 1) * g * b } else { (h - 1) * b };
            return ((g - 1) * b, inter);
        }
        let vol = match op {
            "all_gather" | "reduce_scatter" => (m as u64 - 1) * b,
            "all_reduce" => 2 * (m as u64 - 1) * b,
            "all_to_all" => (m as u64 - 1) * b / m as u64,
            _ => b,
        };
        if m <= self.devices_per_node {
            (vol, 0)
        } else {
            (0, vol)
        }
    }

    /// Per-tier serialized seconds for `op` (`(intra, inter)`), each
    /// including its tier's launch. These are attribution numbers — the
    /// headline `*_time` overlaps the faster tier behind the slower one,
    /// so the pair intentionally sums to more than the pipelined total.
    pub fn tier_times(&self, op: &str, m: usize, bytes_per_rank: u64, aligned: bool) -> (f64, f64) {
        if m <= 1 {
            return (0.0, 0.0);
        }
        if self.is_hier(m) && (op == "all_gather" || op == "reduce_scatter") {
            let (g, h) = (self.topology.gpus_per_host, self.topology.hosts);
            let (bwi, bwe) = self.tier_bws(aligned);
            let b = bytes_per_rank as f64;
            let rs = if op == "reduce_scatter" { self.rs_factor } else { 1.0 };
            let ti = self.intra_launch + b * (g - 1) as f64 / (bwi * rs);
            let inter_hops = if op == "all_gather" { (h - 1) * g } else { h - 1 };
            let te = self.inter_launch + b * inter_hops as f64 / (bwe * rs);
            return (ti, te);
        }
        let t = match op {
            "all_gather" => self.all_gather_time(m, bytes_per_rank, aligned),
            "reduce_scatter" => self.reduce_scatter_time(m, bytes_per_rank, aligned),
            "all_reduce" => self.all_reduce_time(m, bytes_per_rank, aligned),
            "all_to_all" => self.all_to_all_time(m, bytes_per_rank),
            _ => 0.0,
        };
        if m <= self.devices_per_node {
            (t, 0.0)
        } else {
            (0.0, t)
        }
    }

    /// AllReduce = RS + AG.
    pub fn all_reduce_time(&self, m: usize, bytes_per_rank: u64, aligned: bool) -> f64 {
        self.all_gather_time(m, bytes_per_rank, aligned)
            + self.reduce_scatter_time(m, bytes_per_rank, aligned)
    }

    /// All-to-all (EP token exchange): each rank exchanges (m-1)/m of its
    /// payload; inter-node groups bottleneck on the NIC tier.
    pub fn all_to_all_time(&self, m: usize, bytes_per_rank: u64) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        self.launch
            + bytes_per_rank as f64 * (m - 1) as f64 / m as f64
                / self.coll_bw(m, true)
    }

    /// Device-local copy of `bytes`.
    pub fn copy_time(&self, bytes: u64, kind: CopyKind) -> f64 {
        let factor = match kind {
            CopyKind::ZeroCopy => return 0.0,
            CopyKind::Contiguous => 1.0,
            CopyKind::InterleavedRows => self.interleave_rows_factor,
            CopyKind::InterleavedCols => self.interleave_cols_factor,
        };
        // interleaved copies also pay a kernel launch
        self.launch + bytes as f64 / (self.copy_bw * factor)
    }

    /// Is a buffer offset/size NCCL-aligned?
    pub fn is_aligned(&self, offset_bytes: u64, size_bytes: u64) -> bool {
        offset_bytes % self.align_bytes == 0 && size_bytes % self.align_bytes == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1 calibration: GPT-OSS-120B AllGather bucket on 64 H800s.
    /// ~6.44 GB bf16 total -> ~100.6 MB per rank.
    const T1_BYTES_PER_RANK: u64 = 100_600_000;

    #[test]
    fn table1_allgather_calibration() {
        let f = Fabric::h800();
        let t = f.all_gather_time(64, T1_BYTES_PER_RANK, true);
        // paper: 43.71 ms; accept ±10%
        assert!((t - 43.71e-3).abs() / 43.71e-3 < 0.10, "AG {t}");
    }

    #[test]
    fn table1_reducescatter_calibration() {
        let f = Fabric::h800();
        let t = f.reduce_scatter_time(64, T1_BYTES_PER_RANK, true);
        // paper: 94.24 ms
        assert!((t - 94.24e-3).abs() / 94.24e-3 < 0.10, "RS {t}");
    }

    #[test]
    fn table1_copy_out_calibration() {
        let f = Fabric::h800();
        let total = T1_BYTES_PER_RANK * 64;
        let rows = f.copy_time(total, CopyKind::InterleavedRows);
        let cols = f.copy_time(total, CopyKind::InterleavedCols);
        // paper: 5.22 ms (Shard(0)) and 13.72 ms (Shard(1))
        assert!((rows - 5.22e-3).abs() / 5.22e-3 < 0.10, "rows {rows}");
        assert!((cols - 13.72e-3).abs() / 13.72e-3 < 0.15, "cols {cols}");
    }

    #[test]
    fn misalignment_degrades_bandwidth() {
        let f = Fabric::h800();
        let a = f.all_gather_time(64, 1 << 26, true);
        let u = f.all_gather_time(64, 1 << 26, false);
        assert!(u > a * 1.15, "unaligned {u} vs aligned {a}");
    }

    #[test]
    fn fragmentation_pays_launches() {
        // one 64MB collective vs 64 fragmented 1MB collectives
        let f = Fabric::h800();
        let one = f.all_gather_time(8, 1 << 26, true);
        let frag: f64 = (0..64)
            .map(|_| f.all_gather_time(8, 1 << 20, true))
            .sum();
        assert!(frag > one, "fragmented {frag} vs bucketed {one}");
    }

    #[test]
    fn intra_node_faster() {
        let f = Fabric::h800();
        assert!(f.all_gather_time(8, 1 << 26, true)
                < f.all_gather_time(16, 1 << 26, true));
    }

    #[test]
    fn zero_copy_is_free() {
        let f = Fabric::h800();
        assert_eq!(f.copy_time(1 << 30, CopyKind::ZeroCopy), 0.0);
        assert!(f.copy_time(1 << 30, CopyKind::Contiguous) > 0.0);
    }

    #[test]
    fn alignment_predicate() {
        let f = Fabric::h800();
        assert!(f.is_aligned(0, 1024));
        assert!(f.is_aligned(16, 32));
        assert!(!f.is_aligned(4, 1024));
        assert!(!f.is_aligned(0, 1000));
    }

    #[test]
    fn single_rank_collectives_free() {
        let f = Fabric::h800();
        assert_eq!(f.all_gather_time(1, 1 << 30, true), 0.0);
        assert_eq!(f.reduce_scatter_time(1, 1 << 30, true), 0.0);
    }

    #[test]
    fn presets_parse_by_name() {
        for name in Fabric::preset_names() {
            let f = Fabric::by_name(name).unwrap();
            assert_eq!(f.name, name);
        }
        assert!(Fabric::by_name("H800").is_some(), "case-insensitive");
        assert!(Fabric::by_name("tpu").is_none());
    }

    #[test]
    fn topology_parse_roundtrip() {
        let t = Topology::parse("2x4").unwrap();
        assert_eq!((t.hosts, t.gpus_per_host, t.segments), (2, 4, 2));
        assert_eq!(t.label(), "2x4");
        assert_eq!(t.total(), 8);
        assert!(t.is_hierarchical());
        let t = Topology::parse("4x8:4").unwrap();
        assert_eq!((t.hosts, t.gpus_per_host, t.segments), (4, 8, 4));
        assert!(!Topology::flat().is_hierarchical());
        for bad in ["", "2", "0x4", "2x0", "2x4:0", "ax4", "2x4:x"] {
            assert!(Topology::parse(bad).is_none(), "{bad}");
        }
    }

    #[test]
    fn fabric_topology_suffix_parses() {
        let f = Fabric::by_name("h800:2x4").unwrap();
        assert_eq!(f.name, "h800");
        assert_eq!(f.topology.label(), "2x4");
        assert_eq!(f.topology.segments, 2);
        let f = Fabric::by_name("A100:4x8:1").unwrap();
        assert_eq!(f.name, "a100");
        assert_eq!(f.topology.segments, 1);
        assert!(Fabric::by_name("h800:2y4").is_none());
        assert!(Fabric::by_name("tpu:2x4").is_none());
        // no-suffix presets stay flat
        assert!(!Fabric::h800().topology.is_hierarchical());
    }

    #[test]
    fn hierarchy_beats_flat_at_scale() {
        // at 8k ranks, the intra-host pre-reduce keeps (g-1)/g of the RS
        // volume off the NIC and the AG pipelines its tiers
        let b = 64 << 20;
        let flat = Fabric::h800();
        let hier = Fabric::by_name("h800:1024x8:2").unwrap();
        let m = 8192;
        assert!(hier.reduce_scatter_time(m, b, true) < flat.reduce_scatter_time(m, b, true));
        assert!(hier.all_gather_time(m, b, true) < flat.all_gather_time(m, b, true));
    }

    #[test]
    fn hier_times_only_when_group_fills_topology() {
        // an m=8 group on a 2x4 fabric is hierarchical; m=4 (EP subgroup)
        // and m=16 fall back to the flat model
        let f = Fabric::by_name("h800:2x4").unwrap();
        assert!(f.is_hier(8));
        assert!(!f.is_hier(4));
        assert!(!f.is_hier(16));
        assert_eq!(
            f.all_gather_time(4, 1 << 20, true),
            Fabric::h800().all_gather_time(4, 1 << 20, true)
        );
    }

    #[test]
    fn tier_bytes_attribution() {
        let f = Fabric::by_name("h800:2x4").unwrap();
        let b = 1024u64;
        // hier AG: 3 intra hops + 1*4 inter hops of b each
        assert_eq!(f.tier_bytes("all_gather", 8, b), (3 * b, 4 * b));
        // hier RS: pre-reduce leaves one shard per host crossing the NIC
        assert_eq!(f.tier_bytes("reduce_scatter", 8, b), (3 * b, b));
        // flat fallback: small group all intra, large group all inter
        let flat = Fabric::h800();
        assert_eq!(flat.tier_bytes("all_gather", 8, b), (7 * b, 0));
        assert_eq!(flat.tier_bytes("all_gather", 16, b), (0, 15 * b));
        assert_eq!(flat.tier_bytes("all_gather", 1, b), (0, 0));
    }

    #[test]
    fn segment_pipelining_hides_fast_tier() {
        // more segments hide more of the faster tier's time
        let b = 256 << 20;
        let s1 = Fabric::by_name("h800:4x8:1").unwrap();
        let s4 = Fabric::by_name("h800:4x8:4").unwrap();
        let m = 32;
        assert!(s4.all_gather_time(m, b, true) < s1.all_gather_time(m, b, true));
        // and never below the slower tier alone
        let (ti, te) = s4.tier_times("all_gather", m, b, true);
        assert!(s4.all_gather_time(m, b, true) >= ti.max(te) - 1e-12);
    }

    #[test]
    fn preset_ordering_is_sane() {
        // h100 beats h800 inter-node; a100 is the slowest tier everywhere
        let big = 1 << 28;
        let h800 = Fabric::h800().all_gather_time(64, big, true);
        let h100 = Fabric::h100().all_gather_time(64, big, true);
        let a100 = Fabric::a100().all_gather_time(64, big, true);
        assert!(h100 < h800 && h800 < a100, "{h100} {h800} {a100}");
    }
}
