//! Analytic fabric cost model (α–β with hierarchy, alignment and
//! fragmentation effects) — the timing half of the NCCL substitute.
//!
//! Calibrated against the paper's Table 1 (GPT-OSS-120B on 64 H800s):
//! AllGather 43.71 ms and interleaved Copy-Out 5.22 ms over the same
//! ~6.4 GB bf16 bucket imply an effective cross-node collective bandwidth
//! of ≈145 GB/s per rank-payload and a contiguous device-copy bandwidth of
//! ≈1.25 TB/s; ReduceScatter at 94.24 ms implies an RS/AG bandwidth ratio
//! of ≈0.46 (NCCL RS pays the reduction). The model reproduces the
//! *mechanisms* the paper measures:
//!
//! * unaligned buffer addresses degrade collective bandwidth
//!   (NCCL#413 — FSDP1/FSDP2 don't enforce alignment);
//! * many small collectives pay per-launch latency
//!   (DeepSpeed#5047 — fragmented AllGathers);
//! * interleaved (strided) copies run far below contiguous copy bandwidth
//!   (FSDP2's Copy-In/Copy-Out, Table 1's Shard(1) column);
//! * groups spanning nodes drop from NVLink to the IB tier.

/// Device-local copy flavors (Table 1's three copy regimes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyKind {
    /// DBuffer zero-copy: no bytes move at all.
    ZeroCopy,
    /// Contiguous device copy (cudaMemcpy-like).
    Contiguous,
    /// Row-interleaved gather/scatter (FSDP2 Shard(0) copy-out).
    InterleavedRows,
    /// Column-interleaved (FSDP2 Shard(1)): finer strides, worse bw.
    InterleavedCols,
}

#[derive(Debug, Clone)]
pub struct Fabric {
    /// Preset name (`--fabric` flag; recorded in step logs / bench JSON).
    pub name: &'static str,
    /// Effective per-rank collective bandwidth within one node (bytes/s).
    pub intra_bw: f64,
    /// Effective per-rank collective bandwidth when the group spans nodes.
    pub inter_bw: f64,
    /// ReduceScatter bandwidth ratio vs AllGather (reduction cost).
    pub rs_factor: f64,
    /// Per-collective launch latency (s).
    pub launch: f64,
    /// GPUs per node.
    pub devices_per_node: usize,
    /// Bandwidth multiplier when buffers are not NCCL-aligned.
    pub misalign_factor: f64,
    /// Contiguous device-copy bandwidth (bytes/s).
    pub copy_bw: f64,
    /// Relative copy bandwidth for interleaved rows / cols.
    pub interleave_rows_factor: f64,
    pub interleave_cols_factor: f64,
    /// Required address/size alignment (bytes) for full collective speed.
    pub align_bytes: u64,
}

impl Fabric {
    /// H800 cluster of the paper (§6 hardware), Table-1 calibrated.
    pub fn h800() -> Fabric {
        Fabric {
            name: "h800",
            intra_bw: 350e9,
            inter_bw: 145e9,
            rs_factor: 0.464,
            launch: 20e-6,
            devices_per_node: 8,
            // average-case penalty: NCCL#413 shows up to ~2x degradation
            // on pathological alignments; typical buffers lose ~20%
            misalign_factor: 0.8,
            copy_bw: 1.25e12,
            interleave_rows_factor: 1.0,
            interleave_cols_factor: 0.38,
            align_bytes: 16,
        }
    }

    /// H100 SXM cluster: full-rate NVLink4 and 400 Gb/s IB per GPU
    /// (the export-unrestricted sibling of the H800 — same copy engines,
    /// faster inter-node tier).
    pub fn h100() -> Fabric {
        Fabric {
            name: "h100",
            intra_bw: 400e9,
            inter_bw: 190e9,
            rs_factor: 0.464,
            launch: 20e-6,
            devices_per_node: 8,
            misalign_factor: 0.8,
            copy_bw: 1.35e12,
            interleave_rows_factor: 1.0,
            interleave_cols_factor: 0.38,
            align_bytes: 16,
        }
    }

    /// A100 SXM cluster: NVLink3 + 200 Gb/s IB, slower HBM2e copy engines
    /// and a slightly higher launch overhead (older driver stack).
    pub fn a100() -> Fabric {
        Fabric {
            name: "a100",
            intra_bw: 230e9,
            inter_bw: 90e9,
            rs_factor: 0.464,
            launch: 25e-6,
            devices_per_node: 8,
            misalign_factor: 0.8,
            copy_bw: 0.9e12,
            interleave_rows_factor: 1.0,
            interleave_cols_factor: 0.38,
            align_bytes: 16,
        }
    }

    /// Look a fabric preset up by name (`--fabric h800|h100|a100`).
    pub fn by_name(s: &str) -> Option<Fabric> {
        Some(match s.to_ascii_lowercase().as_str() {
            "h800" => Fabric::h800(),
            "h100" => Fabric::h100(),
            "a100" => Fabric::a100(),
            _ => return None,
        })
    }

    /// All preset names, for error messages.
    pub fn preset_names() -> [&'static str; 3] {
        ["h800", "h100", "a100"]
    }

    /// Collective bandwidth for a group of `m` ranks.
    fn coll_bw(&self, m: usize, aligned: bool) -> f64 {
        let base = if m <= self.devices_per_node {
            self.intra_bw
        } else {
            self.inter_bw
        };
        if aligned {
            base
        } else {
            base * self.misalign_factor
        }
    }

    /// Ring AllGather: each rank receives (m-1) shards of
    /// `bytes_per_rank`.
    pub fn all_gather_time(&self, m: usize, bytes_per_rank: u64, aligned: bool) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        self.launch
            + bytes_per_rank as f64 * (m - 1) as f64 / self.coll_bw(m, aligned)
    }

    /// Ring ReduceScatter: same volume as AG, lower effective bandwidth.
    pub fn reduce_scatter_time(&self, m: usize, bytes_per_rank: u64, aligned: bool) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        self.launch
            + bytes_per_rank as f64 * (m - 1) as f64
                / (self.coll_bw(m, aligned) * self.rs_factor)
    }

    /// AllReduce = RS + AG.
    pub fn all_reduce_time(&self, m: usize, bytes_per_rank: u64, aligned: bool) -> f64 {
        self.all_gather_time(m, bytes_per_rank, aligned)
            + self.reduce_scatter_time(m, bytes_per_rank, aligned)
    }

    /// All-to-all (EP token exchange): each rank exchanges (m-1)/m of its
    /// payload; inter-node groups bottleneck on the NIC tier.
    pub fn all_to_all_time(&self, m: usize, bytes_per_rank: u64) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        self.launch
            + bytes_per_rank as f64 * (m - 1) as f64 / m as f64
                / self.coll_bw(m, true)
    }

    /// Device-local copy of `bytes`.
    pub fn copy_time(&self, bytes: u64, kind: CopyKind) -> f64 {
        let factor = match kind {
            CopyKind::ZeroCopy => return 0.0,
            CopyKind::Contiguous => 1.0,
            CopyKind::InterleavedRows => self.interleave_rows_factor,
            CopyKind::InterleavedCols => self.interleave_cols_factor,
        };
        // interleaved copies also pay a kernel launch
        self.launch + bytes as f64 / (self.copy_bw * factor)
    }

    /// Is a buffer offset/size NCCL-aligned?
    pub fn is_aligned(&self, offset_bytes: u64, size_bytes: u64) -> bool {
        offset_bytes % self.align_bytes == 0 && size_bytes % self.align_bytes == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1 calibration: GPT-OSS-120B AllGather bucket on 64 H800s.
    /// ~6.44 GB bf16 total -> ~100.6 MB per rank.
    const T1_BYTES_PER_RANK: u64 = 100_600_000;

    #[test]
    fn table1_allgather_calibration() {
        let f = Fabric::h800();
        let t = f.all_gather_time(64, T1_BYTES_PER_RANK, true);
        // paper: 43.71 ms; accept ±10%
        assert!((t - 43.71e-3).abs() / 43.71e-3 < 0.10, "AG {t}");
    }

    #[test]
    fn table1_reducescatter_calibration() {
        let f = Fabric::h800();
        let t = f.reduce_scatter_time(64, T1_BYTES_PER_RANK, true);
        // paper: 94.24 ms
        assert!((t - 94.24e-3).abs() / 94.24e-3 < 0.10, "RS {t}");
    }

    #[test]
    fn table1_copy_out_calibration() {
        let f = Fabric::h800();
        let total = T1_BYTES_PER_RANK * 64;
        let rows = f.copy_time(total, CopyKind::InterleavedRows);
        let cols = f.copy_time(total, CopyKind::InterleavedCols);
        // paper: 5.22 ms (Shard(0)) and 13.72 ms (Shard(1))
        assert!((rows - 5.22e-3).abs() / 5.22e-3 < 0.10, "rows {rows}");
        assert!((cols - 13.72e-3).abs() / 13.72e-3 < 0.15, "cols {cols}");
    }

    #[test]
    fn misalignment_degrades_bandwidth() {
        let f = Fabric::h800();
        let a = f.all_gather_time(64, 1 << 26, true);
        let u = f.all_gather_time(64, 1 << 26, false);
        assert!(u > a * 1.15, "unaligned {u} vs aligned {a}");
    }

    #[test]
    fn fragmentation_pays_launches() {
        // one 64MB collective vs 64 fragmented 1MB collectives
        let f = Fabric::h800();
        let one = f.all_gather_time(8, 1 << 26, true);
        let frag: f64 = (0..64)
            .map(|_| f.all_gather_time(8, 1 << 20, true))
            .sum();
        assert!(frag > one, "fragmented {frag} vs bucketed {one}");
    }

    #[test]
    fn intra_node_faster() {
        let f = Fabric::h800();
        assert!(f.all_gather_time(8, 1 << 26, true)
                < f.all_gather_time(16, 1 << 26, true));
    }

    #[test]
    fn zero_copy_is_free() {
        let f = Fabric::h800();
        assert_eq!(f.copy_time(1 << 30, CopyKind::ZeroCopy), 0.0);
        assert!(f.copy_time(1 << 30, CopyKind::Contiguous) > 0.0);
    }

    #[test]
    fn alignment_predicate() {
        let f = Fabric::h800();
        assert!(f.is_aligned(0, 1024));
        assert!(f.is_aligned(16, 32));
        assert!(!f.is_aligned(4, 1024));
        assert!(!f.is_aligned(0, 1000));
    }

    #[test]
    fn single_rank_collectives_free() {
        let f = Fabric::h800();
        assert_eq!(f.all_gather_time(1, 1 << 30, true), 0.0);
        assert_eq!(f.reduce_scatter_time(1, 1 << 30, true), 0.0);
    }

    #[test]
    fn presets_parse_by_name() {
        for name in Fabric::preset_names() {
            let f = Fabric::by_name(name).unwrap();
            assert_eq!(f.name, name);
        }
        assert!(Fabric::by_name("H800").is_some(), "case-insensitive");
        assert!(Fabric::by_name("tpu").is_none());
    }

    #[test]
    fn preset_ordering_is_sane() {
        // h100 beats h800 inter-node; a100 is the slowest tier everywhere
        let big = 1 << 28;
        let h800 = Fabric::h800().all_gather_time(64, big, true);
        let h100 = Fabric::h100().all_gather_time(64, big, true);
        let a100 = Fabric::a100().all_gather_time(64, big, true);
        assert!(h100 < h800 && h800 < a100, "{h100} {h800} {a100}");
    }
}
