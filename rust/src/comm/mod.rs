//! Simulated multi-device collectives (the NCCL substitute).
//!
//! Two halves, deliberately separate:
//!
//! * **Data movement** — collectives perform *real* copies/reductions
//!   between per-device host buffers, so every sharding decision (split
//!   blocks, padding, copy-in/out) manifests as real bytes and is checked
//!   element-wise by the tests. Devices are slices of host memory; the
//!   functions below own all of them for the duration of the op, which is
//!   exactly the SPMD synchronous-collective semantics.
//! * **Timing** — [`cost::Fabric`] models what the same op would cost on
//!   the paper's H800 fabric (α–β with hierarchy, NCCL alignment penalty,
//!   per-launch overhead). Engines accumulate `CommRecord`s into a
//!   simulated timeline; wall-clock on this 1-core box is never used as a
//!   performance proxy.

pub mod cost;

use std::sync::Mutex;

use anyhow::{bail, Result};

pub use cost::{CopyKind, Fabric, Topology};

/// Accounting record for one collective (or copy) on the simulated fabric.
#[derive(Debug, Clone, PartialEq)]
pub struct CommRecord {
    pub op: &'static str,
    /// Total wire bytes each rank contributes/receives (payload + quant
    /// scales + packing pad).
    pub bytes_per_rank: u64,
    /// Bytes per rank carrying tensor data (== `bytes_per_rank` for dense
    /// f32 collectives; the int8/bf16 payload for quantized ones).
    pub payload_bytes: u64,
    /// Per-block quantization-scale side-channel bytes per rank (0 for
    /// dense collectives).
    pub scale_bytes: u64,
    pub group_size: usize,
    /// Simulated seconds on the modeled fabric.
    pub sim_time: f64,
    /// Per-rank wire bytes attributed to the intra-host (NVLink) tier
    /// (0/0 with `intra_s`/`inter_s` = unattributed legacy record).
    pub intra_bytes: u64,
    /// Per-rank wire bytes attributed to the inter-host (IB) tier.
    pub inter_bytes: u64,
    /// Simulated serialized seconds on the intra-host tier.
    pub intra_s: f64,
    /// Simulated serialized seconds on the inter-host tier.
    pub inter_s: f64,
}

impl CommRecord {
    /// A dense full-precision record: every wire byte is payload, no
    /// per-tier attribution.
    pub fn dense(
        op: &'static str,
        bytes_per_rank: u64,
        group_size: usize,
        sim_time: f64,
    ) -> CommRecord {
        CommRecord {
            op,
            bytes_per_rank,
            payload_bytes: bytes_per_rank,
            scale_bytes: 0,
            group_size,
            sim_time,
            intra_bytes: 0,
            inter_bytes: 0,
            intra_s: 0.0,
            inter_s: 0.0,
        }
    }

    /// Attach the two-tier attribution a [`Fabric`] computed for this op
    /// (`fabric.tier_bytes` / `fabric.tier_times`).
    pub fn with_tiers(mut self, bytes: (u64, u64), times: (f64, f64)) -> CommRecord {
        self.intra_bytes = bytes.0;
        self.inter_bytes = bytes.1;
        self.intra_s = times.0;
        self.inter_s = times.1;
        self
    }

    /// Word-packing pad bytes per rank (wire total minus payload+scales).
    pub fn pad_bytes(&self) -> u64 {
        self.bytes_per_rank.saturating_sub(self.payload_bytes + self.scale_bytes)
    }
}

/// Cumulative comm statistics for a run.
#[derive(Debug, Clone, Default)]
pub struct CommStats {
    pub records: Vec<CommRecord>,
    // running wire totals maintained by push/merge so per-step accounting
    // reads them in O(1) instead of rescanning the record history
    wire_payload: u64,
    wire_scale: u64,
    wire_pad: u64,
}

impl CommStats {
    pub fn push(&mut self, r: CommRecord) {
        let g = r.group_size as u64;
        self.wire_payload += r.payload_bytes * g;
        self.wire_scale += r.scale_bytes * g;
        self.wire_pad += r.pad_bytes() * g;
        self.records.push(r);
    }

    /// Append another stats block (rank-order merging of per-rank local
    /// stats; see [`SharedStats`]).
    pub fn merge(&mut self, other: CommStats) {
        self.wire_payload += other.wire_payload;
        self.wire_scale += other.wire_scale;
        self.wire_pad += other.wire_pad;
        self.records.extend(other.records);
    }

    /// Drop every record and reset the running totals.
    pub fn clear(&mut self) {
        self.records.clear();
        self.wire_payload = 0;
        self.wire_scale = 0;
        self.wire_pad = 0;
    }

    pub fn total_time(&self) -> f64 {
        self.records.iter().map(|r| r.sim_time).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.records
            .iter()
            .map(|r| r.bytes_per_rank * r.group_size as u64)
            .sum()
    }

    pub fn count(&self, op: &str) -> usize {
        self.records.iter().filter(|r| r.op == op).count()
    }

    /// Measured wire bytes split as (payload, scale, pad), summed over
    /// records and multiplied by group size (the same convention as
    /// [`CommStats::total_bytes`]). This is what the per-step CSV and the
    /// quant bench report — measured from what the collectives actually
    /// shipped, not estimated. O(1): the totals are maintained by
    /// [`CommStats::push`]/[`CommStats::merge`].
    pub fn wire_breakdown(&self) -> (u64, u64, u64) {
        (self.wire_payload, self.wire_scale, self.wire_pad)
    }

    pub fn time_of(&self, op: &str) -> f64 {
        self.records
            .iter()
            .filter(|r| r.op == op)
            .map(|r| r.sim_time)
            .sum()
    }

    /// Simulated `(intra, inter)` seconds attributed to `op` (zeros for
    /// legacy unattributed records).
    pub fn tier_time_of(&self, op: &str) -> (f64, f64) {
        self.records
            .iter()
            .filter(|r| r.op == op)
            .fold((0.0, 0.0), |(i, e), r| (i + r.intra_s, e + r.inter_s))
    }

    /// Total `(intra, inter)` wire bytes across all records (per-rank
    /// bytes × group size, matching [`CommStats::total_bytes`]).
    pub fn tier_bytes_total(&self) -> (u64, u64) {
        self.records.iter().fold((0, 0), |(i, e), r| {
            let g = r.group_size as u64;
            (i + r.intra_bytes * g, e + r.inter_bytes * g)
        })
    }
}

/// Thread-safe [`CommStats`] aggregation for the cluster runtime.
///
/// The serial engine used to thread `&mut CommStats` through every call
/// site; the SPMD runtime records from many rank threads instead. Each
/// rank accumulates into a local `CommStats` and merges it here at the
/// join barrier (rank order, so the merged record stream is deterministic
/// across runs and backends), while god-view callers record directly.
#[derive(Debug, Default)]
pub struct SharedStats {
    inner: Mutex<CommStats>,
}

impl SharedStats {
    pub fn record(&self, r: CommRecord) {
        self.inner.lock().unwrap().push(r);
    }

    /// Merge a rank's local stats (called holding the join barrier).
    pub fn merge(&self, other: CommStats) {
        self.inner.lock().unwrap().merge(other);
    }

    pub fn snapshot(&self) -> CommStats {
        self.inner.lock().unwrap().clone()
    }

    /// Total simulated time without cloning the record history.
    pub fn total_time(&self) -> f64 {
        self.inner.lock().unwrap().total_time()
    }

    /// Cumulative (payload, scale, pad) wire bytes without cloning the
    /// record history — the hot-path counterpart of
    /// [`CommStats::wire_breakdown`].
    pub fn wire_totals(&self) -> (u64, u64, u64) {
        self.inner.lock().unwrap().wire_breakdown()
    }

    pub fn reset(&self) {
        self.inner.lock().unwrap().clear();
    }
}

/// AllGather over equal shards: device k owns `bufs[k][k*s..(k+1)*s]`;
/// afterwards every device holds every shard. Ring semantics, executed as
/// direct copies (host memory is the simulated HBM).
pub fn all_gather(bufs: &mut [Vec<f32>], s: usize) -> Result<()> {
    let m = bufs.len();
    for b in bufs.iter() {
        if b.len() < m * s {
            bail!("all_gather buffer too small: {} < {}", b.len(), m * s);
        }
    }
    // snapshot each rank's own shard, then publish to all
    let shards: Vec<Vec<f32>> = (0..m)
        .map(|k| bufs[k][k * s..(k + 1) * s].to_vec())
        .collect();
    for (dst, buf) in bufs.iter_mut().enumerate() {
        for (k, shard) in shards.iter().enumerate() {
            if k != dst {
                buf[k * s..(k + 1) * s].copy_from_slice(shard);
            }
        }
    }
    Ok(())
}

/// ReduceScatter (sum) over equal shards: each device starts with a full
/// `m*s` buffer of partial values; afterwards device k's shard region
/// holds the sum of everyone's shard-k region. `scale` is applied after
/// the reduction (1/m for gradient averaging).
pub fn reduce_scatter(bufs: &mut [Vec<f32>], s: usize, scale: f32) -> Result<()> {
    let m = bufs.len();
    for b in bufs.iter() {
        if b.len() < m * s {
            bail!("reduce_scatter buffer too small: {} < {}", b.len(), m * s);
        }
    }
    for k in 0..m {
        // sum shard k across all ranks into rank k
        let mut acc = vec![0.0f32; s];
        for buf in bufs.iter() {
            for (a, x) in acc.iter_mut().zip(&buf[k * s..(k + 1) * s]) {
                *a += x;
            }
        }
        for a in acc.iter_mut() {
            *a *= scale;
        }
        bufs[k][k * s..(k + 1) * s].copy_from_slice(&acc);
    }
    Ok(())
}

/// AllReduce (sum then scale) over whole equal-length buffers.
pub fn all_reduce(bufs: &mut [Vec<f32>], scale: f32) -> Result<()> {
    if bufs.is_empty() {
        return Ok(());
    }
    let n = bufs[0].len();
    for b in bufs.iter() {
        if b.len() != n {
            bail!("all_reduce length mismatch");
        }
    }
    let mut acc = vec![0.0f32; n];
    for buf in bufs.iter() {
        for (a, x) in acc.iter_mut().zip(buf.iter()) {
            *a += x;
        }
    }
    for a in acc.iter_mut() {
        *a *= scale;
    }
    for buf in bufs.iter_mut() {
        buf.copy_from_slice(&acc);
    }
    Ok(())
}

/// Broadcast rank `root`'s buffer to all.
pub fn broadcast(bufs: &mut [Vec<f32>], root: usize) -> Result<()> {
    if root >= bufs.len() {
        bail!("broadcast root {} out of range", root);
    }
    let src = bufs[root].clone();
    for (k, buf) in bufs.iter_mut().enumerate() {
        if k != root {
            if buf.len() != src.len() {
                bail!("broadcast length mismatch at rank {k}");
            }
            buf.copy_from_slice(&src);
        }
    }
    Ok(())
}

/// All-to-all over equal splits: device k sends `bufs[k][j*s..]` to device
/// j's slot k. (Expert-parallel token exchange.)
pub fn all_to_all(bufs: &mut [Vec<f32>], s: usize) -> Result<()> {
    let m = bufs.len();
    for b in bufs.iter() {
        if b.len() < m * s {
            bail!("all_to_all buffer too small");
        }
    }
    let snap: Vec<Vec<f32>> = bufs.iter().map(|b| b[..m * s].to_vec()).collect();
    for (j, buf) in bufs.iter_mut().enumerate() {
        for (k, src) in snap.iter().enumerate() {
            buf[k * s..(k + 1) * s].copy_from_slice(&src[j * s..(j + 1) * s]);
        }
    }
    Ok(())
}

/// Gather all ragged shards to `root` (Muon's unshard). `shards[k]` is
/// rank k's local slice; root receives the concatenation.
pub fn gather_to_root(shards: &[Vec<f32>], root: usize) -> Vec<f32> {
    let _ = root; // data lands on root; simulation keeps one copy
    let mut out = Vec::with_capacity(shards.iter().map(|s| s.len()).sum());
    for s in shards {
        out.extend_from_slice(s);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev_bufs(m: usize, s: usize) -> Vec<Vec<f32>> {
        (0..m)
            .map(|k| {
                let mut b = vec![0.0f32; m * s];
                for (i, x) in b[k * s..(k + 1) * s].iter_mut().enumerate() {
                    *x = (k * 100 + i) as f32;
                }
                b
            })
            .collect()
    }

    #[test]
    fn all_gather_replicates_all_shards() {
        let (m, s) = (4, 8);
        let mut bufs = dev_bufs(m, s);
        all_gather(&mut bufs, s).unwrap();
        for buf in &bufs {
            for k in 0..m {
                for i in 0..s {
                    assert_eq!(buf[k * s + i], (k * 100 + i) as f32);
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_sums_and_scales() {
        let (m, s) = (3, 4);
        let mut bufs: Vec<Vec<f32>> =
            (0..m).map(|k| vec![(k + 1) as f32; m * s]).collect();
        reduce_scatter(&mut bufs, s, 1.0 / m as f32).unwrap();
        // sum over ranks = 1+2+3 = 6; mean = 2
        for (k, buf) in bufs.iter().enumerate() {
            for i in 0..s {
                assert_eq!(buf[k * s + i], 2.0);
            }
        }
    }

    #[test]
    fn ag_rs_roundtrip_identity() {
        // ReduceScatter(1/m) then AllGather of identical inputs is identity
        let (m, s) = (4, 16);
        let base: Vec<f32> = (0..m * s).map(|i| i as f32 * 0.5).collect();
        let mut bufs: Vec<Vec<f32>> = (0..m).map(|_| base.clone()).collect();
        reduce_scatter(&mut bufs, s, 1.0 / m as f32).unwrap();
        all_gather(&mut bufs, s).unwrap();
        for buf in &bufs {
            assert_eq!(buf, &base);
        }
    }

    #[test]
    fn all_reduce_mean() {
        let mut bufs = vec![vec![1.0f32; 8], vec![3.0f32; 8]];
        all_reduce(&mut bufs, 0.5).unwrap();
        for b in &bufs {
            assert!(b.iter().all(|&x| x == 2.0));
        }
    }

    #[test]
    fn broadcast_from_root() {
        let mut bufs = vec![vec![0.0f32; 4], vec![7.0f32; 4], vec![0.0f32; 4]];
        broadcast(&mut bufs, 1).unwrap();
        for b in &bufs {
            assert!(b.iter().all(|&x| x == 7.0));
        }
    }

    #[test]
    fn all_to_all_transposes() {
        let (m, s) = (3, 2);
        let mut bufs: Vec<Vec<f32>> = (0..m)
            .map(|k| (0..m * s).map(|i| (k * 10 + i / s) as f32).collect())
            .collect();
        all_to_all(&mut bufs, s).unwrap();
        // device j slot k now holds device k's slot j = k*10 + j
        for (j, buf) in bufs.iter().enumerate() {
            for k in 0..m {
                assert_eq!(buf[k * s], (k * 10 + j) as f32);
            }
        }
    }

    #[test]
    fn size_validation() {
        let mut bufs = vec![vec![0.0f32; 4]; 2];
        assert!(all_gather(&mut bufs, 4).is_err()); // needs 8 per device
        assert!(broadcast(&mut bufs, 5).is_err());
    }

    #[test]
    fn stats_accumulate() {
        let mut st = CommStats::default();
        st.push(CommRecord::dense("all_gather", 100, 4, 0.5));
        st.push(CommRecord::dense("reduce_scatter", 50, 4, 0.25));
        assert_eq!(st.total_bytes(), 600);
        assert_eq!(st.total_time(), 0.75);
        assert_eq!(st.count("all_gather"), 1);
        // dense records are all payload
        assert_eq!(st.wire_breakdown(), (600, 0, 0));
    }

    #[test]
    fn wire_breakdown_splits_quantized_records() {
        let mut st = CommStats::default();
        st.push(CommRecord {
            op: "all_gather",
            bytes_per_rank: 40,
            payload_bytes: 32,
            scale_bytes: 4,
            group_size: 2,
            sim_time: 0.1,
            intra_bytes: 0,
            inter_bytes: 0,
            intra_s: 0.0,
            inter_s: 0.0,
        });
        assert_eq!(st.wire_breakdown(), (64, 8, 8));
        assert_eq!(st.total_bytes(), 80);
        // merge carries the running totals; clear resets them
        let mut other = CommStats::default();
        other.push(CommRecord::dense("all_gather", 10, 2, 0.0));
        st.merge(other);
        assert_eq!(st.wire_breakdown(), (84, 8, 8));
        st.clear();
        assert_eq!(st.wire_breakdown(), (0, 0, 0));
        assert!(st.records.is_empty());
    }

    #[test]
    fn tier_attribution_accumulates() {
        let f = Fabric::by_name("h800:2x4").unwrap();
        let mut st = CommStats::default();
        let b = 1024u64;
        st.push(
            CommRecord::dense("all_gather", b, 8, 0.5)
                .with_tiers(f.tier_bytes("all_gather", 8, b), f.tier_times("all_gather", 8, b, true)),
        );
        st.push(
            CommRecord::dense("reduce_scatter", b, 8, 0.25).with_tiers(
                f.tier_bytes("reduce_scatter", 8, b),
                f.tier_times("reduce_scatter", 8, b, true),
            ),
        );
        let (ag_i, ag_e) = st.tier_time_of("all_gather");
        assert!(ag_i > 0.0 && ag_e > 0.0);
        let (bi, be) = st.tier_bytes_total();
        // AG: (3b intra + 4b inter) * 8 ranks; RS: (3b + 1b) * 8
        assert_eq!(bi, (3 + 3) * b * 8);
        assert_eq!(be, (4 + 1) * b * 8);
        // legacy dense records stay unattributed
        let mut legacy = CommStats::default();
        legacy.push(CommRecord::dense("all_reduce", b, 4, 0.1));
        assert_eq!(legacy.tier_time_of("all_reduce"), (0.0, 0.0));
    }

    #[test]
    fn shared_stats_merge_from_threads() {
        let shared = SharedStats::default();
        std::thread::scope(|s| {
            for rank in 0..4u64 {
                let shared = &shared;
                s.spawn(move || {
                    let mut local = CommStats::default();
                    local.push(CommRecord::dense("all_gather", 10 * (rank + 1), 4, 0.1));
                    shared.merge(local);
                });
            }
        });
        let snap = shared.snapshot();
        assert_eq!(snap.count("all_gather"), 4);
        assert_eq!(snap.total_bytes(), (10 + 20 + 30 + 40) * 4);
        shared.reset();
        assert_eq!(shared.snapshot().records.len(), 0);
    }
}
