//! The unified collective launch pipeline: one typed descriptor —
//! [`CollectiveLaunch`] — describes every collective the runtime
//! executes *and* the static analyzer elaborates, so the two can never
//! drift.
//!
//! A launch flows through fixed, composable stages:
//!
//! ```text
//!   CollectiveLaunch (op, group, elems, precision, topology, mode)
//!        │
//!        ├─ precision codec      encode_wire / rs_encode   (Bf16/Q8 only)
//!        ├─ tier routing         serial_fallback / two_level / tier
//!        ├─ transport            Communicator::launch{,_async}
//!        │    ├─ serial loop collectives (reference bit order)
//!        │    └─ threaded rendezvous ring / two-level hierarchy
//!        ├─ trace span           fabric-timeline transport span(s)
//!        ├─ obs heartbeat        rank enter/exit around the body
//!        └─ wire accounting      comm_record → CommStats (payload/scale/pad)
//! ```
//!
//! The descriptor owns every decision input: the op kind, the logical
//! element count per slot, the wire [`CommPrecision`], the cluster
//! [`Topology`] (with its pipeline segment count), the serial-fallback
//! threshold, and the bucket/step/phase identity used by tracing and
//! observability. Backends read the descriptor; callers build it via
//! [`crate::cluster::Communicator::describe`] so backend-attached
//! topology and thresholds are stamped automatically.

use anyhow::Result;

use crate::comm::{CommRecord, Fabric, Topology};
use crate::quant::{self, CommPrecision, WireVolume};

use super::Communicator;

/// Below this many total elements a collective is cheaper single-threaded
/// than the ~tens-of-microseconds per OS thread spawn, and two-level
/// hierarchical dispatch is not worth its extra barriers. The serial path
/// is bit-identical, so falling back never changes results. This is the
/// single source of truth consulted by runtime dispatch
/// (`ThreadedComm`), the static verifier (`analysis` FS005), and the
/// `--hier-threshold` / `[comm] hier_threshold` overrides.
pub const DEFAULT_HIER_THRESHOLD: usize = 16 * 1024;

/// The collective operation a launch performs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LaunchOp {
    /// Parameter gather (dense or encoded wire).
    AllGather,
    /// Dense f32 gradient ReduceScatter.
    ReduceScatter,
    /// Slot transpose (EP token exchange; the encoded `Bf16`/`Q8`
    /// gradient wire move).
    AllToAll,
    /// AllReduce over whole equal-length buffers (HSDP replica sync).
    AllReduce,
    /// Broadcast from one root rank.
    Broadcast,
}

impl LaunchOp {
    /// Wire-protocol name: the key used by `CommStats`, the health
    /// board's heartbeats, and the transport span names.
    pub fn name(&self) -> &'static str {
        match self {
            LaunchOp::AllGather => "all_gather",
            LaunchOp::ReduceScatter => "reduce_scatter",
            LaunchOp::AllToAll => "all_to_all",
            LaunchOp::AllReduce => "all_reduce",
            LaunchOp::Broadcast => "broadcast",
        }
    }

    /// Logical span name the executor's tracer records for this op
    /// (`ag` for gathers, `rs` for either flavor of gradient reduction).
    pub fn span_name(&self) -> &'static str {
        match self {
            LaunchOp::AllGather => "ag",
            LaunchOp::ReduceScatter | LaunchOp::AllToAll => "rs",
            LaunchOp::AllReduce => "ar",
            LaunchOp::Broadcast => "bc",
        }
    }
}

/// Blocking shape of one launch (the executor's schedule position).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LaunchPhase {
    /// Blocking call (the sequential schedule).
    Sync,
    /// Nonblocking issue returning a handle.
    Issue,
    /// Wait on a previously issued handle.
    Wait,
}

impl LaunchPhase {
    pub fn name(&self) -> &'static str {
        match self {
            LaunchPhase::Sync => "sync",
            LaunchPhase::Issue => "issue",
            LaunchPhase::Wait => "wait",
        }
    }
}

/// Which rendezvous tier a launch dispatches on (the same decision the
/// threaded backend makes at run time; the static verifier elaborates
/// the identical predicate from the shared descriptor).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LaunchTier {
    /// Flat topology: the plain single-tier rendezvous.
    Flat,
    /// Hierarchical topology, group fits inside one host.
    Intra,
    /// Hierarchical topology, flat algorithm across hosts.
    Inter,
    /// Two-level dispatch: intra-host ring + rail-aligned inter-host.
    TwoLevel,
}

impl LaunchTier {
    pub fn name(&self) -> &'static str {
        match self {
            LaunchTier::Flat => "flat",
            LaunchTier::Intra => "intra",
            LaunchTier::Inter => "inter",
            LaunchTier::TwoLevel => "two-level",
        }
    }
}

/// Whether the launch blocks the caller or returns a waitable handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchMode {
    Sync,
    Async,
}

/// One fully-described collective: the single descriptor type flowing
/// through the launch pipeline (and elaborated, unchanged, by
/// `analysis::ir`). Construct with [`CollectiveLaunch::new`] or —
/// preferably — [`crate::cluster::Communicator::describe`], then refine
/// with the builder setters.
#[derive(Debug, Clone, PartialEq)]
pub struct CollectiveLaunch {
    /// The collective operation.
    pub op: LaunchOp,
    /// Group size `m` (ranks participating).
    pub group: usize,
    /// Logical f32 elements per slot (per-rank shard size for AG/RS,
    /// per-destination slot for A2A, whole-buffer length for AR/BC).
    pub elems: usize,
    /// Post-reduction scale (ReduceScatter / AllReduce; 1.0 otherwise).
    pub scale: f32,
    /// Source rank (Broadcast only; 0 otherwise).
    pub root: usize,
    /// Wire precision of the payload. Encoded precisions shrink the
    /// transport slot to [`CommPrecision::wire_words`] words; see
    /// [`CollectiveLaunch::transport`].
    pub precision: CommPrecision,
    /// Cluster shape for tier routing and chunk pipelining.
    pub topology: Topology,
    /// Total-element threshold under which the launch runs serially
    /// (and two-level dispatch is skipped).
    pub hier_threshold: usize,
    /// Blocking shape the caller requested.
    pub mode: LaunchMode,
    /// Schedule position (stamped by the executor; `Sync` by default).
    pub phase: LaunchPhase,
    /// Bucket (shard-group) label, when the launch belongs to one.
    pub bucket: Option<String>,
    /// Training step the launch belongs to (0 outside a step).
    pub step: u64,
}

impl CollectiveLaunch {
    /// A flat, full-precision, synchronous descriptor. Backends stamp
    /// their topology/threshold via `Communicator::describe`.
    pub fn new(op: LaunchOp, group: usize, elems: usize) -> CollectiveLaunch {
        CollectiveLaunch {
            op,
            group,
            elems,
            scale: 1.0,
            root: 0,
            precision: CommPrecision::F32,
            topology: Topology::flat(),
            hier_threshold: DEFAULT_HIER_THRESHOLD,
            mode: LaunchMode::Sync,
            phase: LaunchPhase::Sync,
            bucket: None,
            step: 0,
        }
    }

    /// Post-reduction scale (1/m for gradient averaging).
    pub fn scaled(mut self, scale: f32) -> CollectiveLaunch {
        self.scale = scale;
        self
    }

    /// Broadcast source rank.
    pub fn rooted(mut self, root: usize) -> CollectiveLaunch {
        self.root = root;
        self
    }

    /// Wire precision of the payload.
    pub fn with_precision(mut self, precision: CommPrecision) -> CollectiveLaunch {
        self.precision = precision;
        self
    }

    /// Cluster topology for tier routing.
    pub fn on_topology(mut self, topology: Topology) -> CollectiveLaunch {
        self.topology = topology;
        self
    }

    /// Serial-fallback / two-level eligibility threshold.
    pub fn with_hier_threshold(mut self, hier_threshold: usize) -> CollectiveLaunch {
        self.hier_threshold = hier_threshold;
        self
    }

    /// Mark the launch nonblocking.
    pub fn asynchronous(mut self) -> CollectiveLaunch {
        self.mode = LaunchMode::Async;
        self
    }

    /// Schedule position (issue/wait for pipelined executors).
    pub fn in_phase(mut self, phase: LaunchPhase) -> CollectiveLaunch {
        self.phase = phase;
        self
    }

    /// Attach the owning bucket's label.
    pub fn for_bucket(mut self, bucket: &str) -> CollectiveLaunch {
        self.bucket = Some(bucket.to_string());
        self
    }

    /// Attach the training step.
    pub fn at_step(mut self, step: u64) -> CollectiveLaunch {
        self.step = step;
        self
    }

    /// f32 words one slot occupies on the transport: the logical element
    /// count for dense f32, the packed word count for encoded wires.
    /// This is the slot size every backend algorithm sees — exactly what
    /// the legacy `_prec` paths passed to `all_gather(wire, w)`.
    pub fn comm_elems(&self) -> usize {
        if self.precision.is_f32() {
            self.elems
        } else {
            self.precision.wire_words(self.elems)
        }
    }

    /// Measured wire bytes of one slot (payload / scale / pad split) —
    /// the one accounting stage every record flows through.
    pub fn wire_volume(&self) -> WireVolume {
        self.precision.wire_volume(self.elems as u64)
    }

    /// Transient wire-buffer bytes an encoded gather or reduce claims
    /// from the caching allocator (1-byte floor so empty groups still
    /// exercise the claim/free discipline).
    pub fn wire_claim_bytes(&self) -> u64 {
        ((self.group * self.precision.wire_words(self.elems) * 4) as u64).max(1)
    }

    /// Logical wire bytes of the whole collective (per-slot volume
    /// summed across the group) — the executor's span-byte accounting.
    pub fn collective_bytes(&self) -> u64 {
        self.wire_volume().total() * self.group as u64
    }

    /// Would this launch take the bit-identical single-thread path
    /// instead of a rendezvous? Ring collectives compare the full
    /// exchanged volume (`m * m * slot`); whole-buffer collectives
    /// compare their total footprint (`m * len`).
    pub fn serial_fallback(&self) -> bool {
        let (m, e) = (self.group, self.comm_elems());
        match self.op {
            LaunchOp::AllGather | LaunchOp::ReduceScatter | LaunchOp::AllToAll => {
                m <= 1 || e == 0 || m * m * e < self.hier_threshold
            }
            LaunchOp::AllReduce | LaunchOp::Broadcast => m <= 1 || m * e < self.hier_threshold,
        }
    }

    /// Should the launch dispatch to the two-level hierarchical
    /// algorithms? Only AllGather/ReduceScatter on groups that exactly
    /// fill a multi-host topology and are big enough for the rendezvous
    /// path at all.
    pub fn two_level(&self) -> bool {
        matches!(self.op, LaunchOp::AllGather | LaunchOp::ReduceScatter)
            && self.topology.is_hierarchical()
            && self.group == self.topology.total()
            && !self.serial_fallback()
    }

    /// The tier this launch dispatches on. `two_level_capable` is
    /// whether the executing transport implements the two-level
    /// algorithms (the threaded backend does; the serial reference
    /// backend runs flat algorithms under any topology).
    pub fn tier(&self, two_level_capable: bool) -> LaunchTier {
        if !self.topology.is_hierarchical() {
            return LaunchTier::Flat;
        }
        if two_level_capable && self.two_level() {
            LaunchTier::TwoLevel
        } else if self.group <= self.topology.gpus_per_host {
            LaunchTier::Intra
        } else {
            LaunchTier::Inter
        }
    }

    /// Lower the logical launch to the descriptor the transport actually
    /// moves: dense launches pass through unchanged; encoded launches
    /// ship packed f32 words (an encoded ReduceScatter becomes the
    /// all-to-all of per-destination wire slots the error-feedback
    /// decode stage reduces at each owner).
    pub fn transport(&self) -> CollectiveLaunch {
        if self.precision.is_f32() {
            return self.clone();
        }
        let mut t = self.clone();
        t.elems = self.precision.wire_words(self.elems);
        t.precision = CommPrecision::F32;
        if self.op == LaunchOp::ReduceScatter {
            t.op = LaunchOp::AllToAll;
            t.scale = 1.0;
        }
        t
    }

    /// The accounting record this launch contributes to `CommStats`:
    /// measured wire volume split into payload/scale/pad, the modeled
    /// fabric time, and the per-tier attribution. This is the single
    /// wire-accounting stage — `DBuffer` and the engines record what the
    /// descriptor says, never a hand-computed copy.
    pub fn comm_record(&self, fabric: &Fabric) -> CommRecord {
        let vol = self.wire_volume();
        let bytes = vol.total();
        let m = self.group;
        let name = self.op.name();
        let aligned = fabric.is_aligned(0, (self.elems * 4) as u64);
        let sim_time = match self.op {
            LaunchOp::AllGather => fabric.all_gather_time(m, bytes, aligned),
            LaunchOp::ReduceScatter => fabric.reduce_scatter_time(m, bytes, aligned),
            LaunchOp::AllReduce => fabric.all_reduce_time(m, bytes, aligned),
            LaunchOp::AllToAll => fabric.all_to_all_time(m, bytes),
            LaunchOp::Broadcast => fabric.all_gather_time(m, bytes, aligned),
        };
        CommRecord {
            op: name,
            bytes_per_rank: bytes,
            payload_bytes: vol.payload,
            scale_bytes: vol.scale,
            group_size: m,
            sim_time,
            intra_bytes: 0,
            inter_bytes: 0,
            intra_s: 0.0,
            inter_s: 0.0,
        }
        .with_tiers(fabric.tier_bytes(name, m, bytes), fabric.tier_times(name, m, bytes, aligned))
    }
}

// ---- precision-codec pipeline stages ------------------------------------
//
// The codec itself lives in `crate::quant`; these are the launch
// pipeline's only entry points to it. Callers outside `cluster/` go
// through these stages (fsdp-lint FS012 enforces the boundary), so wire
// encode/decode composes with tier routing and accounting in one place.

/// Encode one logical slot into its wire slot
/// (`wire.len() == precision.wire_words(src.len())`).
pub fn encode_wire(prec: CommPrecision, src: &[f32], wire: &mut [f32]) {
    quant::encode_slot(prec, src, wire);
}

/// Decode one wire slot back into `dst` (the exact inverse layout of
/// [`encode_wire`]).
pub fn decode_wire(prec: CommPrecision, wire: &[f32], dst: &mut [f32]) {
    quant::decode_slot(prec, wire, dst);
}

/// ReduceScatter codec, phase 1: inject per-rank error-feedback
/// residuals (Q8) and encode every chunk into all-to-all wire buffers.
pub fn rs_encode(
    prec: CommPrecision,
    bufs: &mut [Vec<f32>],
    s: usize,
    ef: &mut Vec<Vec<f32>>,
) -> Result<Vec<Vec<f32>>> {
    quant::rs_inject_and_encode(prec, bufs, s, ef)
}

/// ReduceScatter codec, phase 2: after the wire move, decode and sum in
/// rank order at each destination, updating the residuals.
pub fn rs_decode(
    prec: CommPrecision,
    wire: &[Vec<f32>],
    bufs: &mut [Vec<f32>],
    s: usize,
    scale: f32,
    ef: &mut Vec<Vec<f32>>,
) -> Result<()> {
    quant::rs_decode_reduce(prec, wire, bufs, s, scale, ef)
}

/// Run a (possibly encoded) gradient ReduceScatter through the full
/// pipeline synchronously: dense f32 launches go straight to the
/// transport; encoded launches run codec phase 1, move the wire slots
/// via [`CollectiveLaunch::transport`], and reduce at each owner in
/// codec phase 2 — bit-identical to the legacy
/// `quant::reduce_scatter_prec` path by construction.
pub fn reduce_scatter_launch(
    comm: &dyn Communicator,
    l: &CollectiveLaunch,
    bufs: &mut [Vec<f32>],
    ef: &mut Vec<Vec<f32>>,
) -> Result<()> {
    if l.precision.is_f32() {
        return comm.launch(l, bufs);
    }
    let mut wire = rs_encode(l.precision, bufs, l.elems, ef)?;
    comm.launch(&l.transport(), &mut wire)?;
    rs_decode(l.precision, &wire, bufs, l.elems, l.scale, ef)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SerialComm;

    #[test]
    fn descriptor_slot_math_matches_precision() {
        let q8 = CommPrecision::Q8 { block: 32 };
        let l = CollectiveLaunch::new(LaunchOp::AllGather, 4, 96).with_precision(q8);
        assert_eq!(l.comm_elems(), q8.wire_words(96));
        assert_eq!(l.wire_claim_bytes(), (4 * q8.wire_words(96) * 4) as u64);
        assert_eq!(l.collective_bytes(), q8.wire_volume(96).total() * 4);
        let dense = CollectiveLaunch::new(LaunchOp::AllGather, 4, 96);
        assert_eq!(dense.comm_elems(), 96);
        assert_eq!(dense.collective_bytes(), 96 * 4 * 4);
    }

    #[test]
    fn serial_fallback_replicates_legacy_predicates() {
        // ring ops: m*m*s against the threshold
        let l = CollectiveLaunch::new(LaunchOp::AllGather, 4, 1024);
        assert!(!l.serial_fallback(), "4*4*1024 = 16Ki meets the threshold");
        let l = CollectiveLaunch::new(LaunchOp::AllGather, 4, 1023);
        assert!(l.serial_fallback());
        assert!(CollectiveLaunch::new(LaunchOp::AllToAll, 1, 1 << 20).serial_fallback());
        assert!(CollectiveLaunch::new(LaunchOp::ReduceScatter, 4, 0).serial_fallback());
        // whole-buffer ops: m*len against the threshold
        let l = CollectiveLaunch::new(LaunchOp::AllReduce, 4, 4096);
        assert!(!l.serial_fallback());
        let l = CollectiveLaunch::new(LaunchOp::AllReduce, 4, 4095);
        assert!(l.serial_fallback());
        // a zero threshold forces the rendezvous path
        let l = CollectiveLaunch::new(LaunchOp::AllGather, 4, 3).with_hier_threshold(0);
        assert!(!l.serial_fallback());
    }

    #[test]
    fn tier_routing_matches_runtime_dispatch() {
        let topo = Topology::parse("2x4:2").unwrap();
        let big = CollectiveLaunch::new(LaunchOp::AllGather, 8, 4096).on_topology(topo);
        assert!(big.two_level());
        assert_eq!(big.tier(true), LaunchTier::TwoLevel);
        // the serial backend runs flat algorithms under any topology
        assert_eq!(big.tier(false), LaunchTier::Inter);
        // groups that do not fill the topology keep the flat algorithms
        let ep = CollectiveLaunch::new(LaunchOp::AllGather, 4, 4096).on_topology(topo);
        assert!(!ep.two_level());
        assert_eq!(ep.tier(true), LaunchTier::Intra);
        // tiny launches fall back serially even when hierarchical
        let tiny = CollectiveLaunch::new(LaunchOp::AllGather, 8, 3).on_topology(topo);
        assert!(!tiny.two_level());
        // all-to-all never dispatches two-level
        let a2a = CollectiveLaunch::new(LaunchOp::AllToAll, 8, 4096).on_topology(topo);
        assert!(!a2a.two_level());
        let flat = CollectiveLaunch::new(LaunchOp::AllGather, 8, 4096);
        assert_eq!(flat.tier(true), LaunchTier::Flat);
    }

    #[test]
    fn transport_lowers_encoded_launches() {
        let q8 = CommPrecision::Q8 { block: 16 };
        let rs = CollectiveLaunch::new(LaunchOp::ReduceScatter, 4, 64)
            .with_precision(q8)
            .scaled(0.25);
        let t = rs.transport();
        assert_eq!(t.op, LaunchOp::AllToAll);
        assert_eq!(t.elems, q8.wire_words(64));
        assert!(t.precision.is_f32());
        assert_eq!(t.scale, 1.0);
        let ag = CollectiveLaunch::new(LaunchOp::AllGather, 4, 64).with_precision(q8);
        let t = ag.transport();
        assert_eq!(t.op, LaunchOp::AllGather);
        assert_eq!(t.elems, q8.wire_words(64));
        // dense launches pass through unchanged
        let dense = CollectiveLaunch::new(LaunchOp::ReduceScatter, 4, 64).scaled(0.25);
        assert_eq!(dense.transport(), dense);
    }

    #[test]
    fn comm_record_accounts_measured_wire_volume() {
        let fabric = Fabric::h800();
        let q8 = CommPrecision::Q8 { block: 32 };
        let l = CollectiveLaunch::new(LaunchOp::AllGather, 4, 96).with_precision(q8);
        let r = l.comm_record(&fabric);
        let vol = q8.wire_volume(96);
        assert_eq!(r.op, "all_gather");
        assert_eq!(r.bytes_per_rank, vol.total());
        assert_eq!(r.payload_bytes, vol.payload);
        assert_eq!(r.scale_bytes, vol.scale);
        assert_eq!(r.group_size, 4);
        assert!(r.sim_time > 0.0);
        let dense = CollectiveLaunch::new(LaunchOp::ReduceScatter, 4, 96).comm_record(&fabric);
        assert_eq!(dense.bytes_per_rank, 96 * 4);
        assert_eq!(dense.pad_bytes(), 0);
    }

    #[test]
    fn reduce_scatter_launch_matches_legacy_prec_path() {
        let (m, s) = (4usize, 32usize);
        let prec = CommPrecision::Q8 { block: 8 };
        let mk = || -> Vec<Vec<f32>> {
            let mut rng = crate::util::Rng::new(7);
            (0..m).map(|_| (0..m * s).map(|_| rng.normal_f32()).collect()).collect()
        };
        let comm = SerialComm::new();
        let mut legacy = mk();
        let mut ef_a = Vec::new();
        quant::reduce_scatter_prec(&comm, prec, &mut legacy, s, 0.25, &mut ef_a).unwrap();
        let mut unified = mk();
        let mut ef_b = Vec::new();
        let l = comm
            .describe(LaunchOp::ReduceScatter, m, s)
            .scaled(0.25)
            .with_precision(prec);
        reduce_scatter_launch(&comm, &l, &mut unified, &mut ef_b).unwrap();
        for (a, b) in legacy.iter().flatten().zip(unified.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ef_a.iter().flatten().zip(ef_b.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
