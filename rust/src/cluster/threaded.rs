//! ThreadedComm: every rank participates in a collective from its own OS
//! thread; the exchange happens over shared host buffers in barrier-phased
//! rendezvous steps.
//!
//! Algorithms (all over the same per-rank buffers the serial backend
//! uses, so call sites are backend-agnostic):
//!
//! * **AllGather** — chunked ring: at step `t` rank `k` pulls chunk
//!   `(k-1-t) mod m` from its left neighbor, the chunk the neighbor
//!   itself received one step earlier. A barrier separates steps; within
//!   a step every rank writes one chunk of its own buffer and reads a
//!   *different* chunk of its neighbor's, so regions never alias.
//! * **ReduceScatter** — each rank reduces *its own* chunk across all
//!   ranks' buffers **in rank order 0..m** (the serial backend's exact
//!   summation order, so results are bit-identical), then writes it back.
//!   Work parallelizes across chunks; regions are disjoint by chunk index.
//! * **AllReduce** — ReduceScatter over balanced element ranges followed
//!   by an AllGather-style publish phase, again reducing in rank order.
//! * **Broadcast / All2All** — parallel region copies with a snapshot
//!   phase where in-place overwrite would race.
//!
//! Safety model: raw per-rank buffer pointers are shared for the duration
//! of one collective; every access goes through `region`/`region_mut`,
//! which materialize *disjoint* slices, and phases that would otherwise
//! conflict are separated by `std::sync::Barrier`. Each algorithm's
//! disjointness argument is spelled out inline.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Barrier;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::comm::{self, CommRecord, CommStats, SharedStats, Topology};
use crate::obs::Observer;
use crate::trace::{Cat, Span, Tracer};

use super::hierarchy::{hier_all_gather, hier_reduce_scatter};
use super::launch::{CollectiveLaunch, LaunchOp, DEFAULT_HIER_THRESHOLD};
use super::{CommBackend, Communicator, PendingOp};

#[derive(Debug)]
pub struct ThreadedComm {
    stats: SharedStats,
    /// Total-element threshold under which collectives run serially
    /// (see [`DEFAULT_HIER_THRESHOLD`]).
    hier_threshold: usize,
    tracer: Tracer,
    /// Cluster shape: groups that exactly fill a multi-host topology
    /// dispatch to the two-level algorithms in [`super::hierarchy`].
    topology: Topology,
    /// Health monitor handle. Disarmed (the default) this costs one
    /// branch per collective; armed, every rank thread publishes
    /// heartbeats into the shared [`crate::obs::HealthBoard`] around its
    /// rendezvous body.
    obs: Observer,
}

impl Default for ThreadedComm {
    fn default() -> Self {
        ThreadedComm::new()
    }
}

impl ThreadedComm {
    pub fn new() -> ThreadedComm {
        ThreadedComm::configured(
            Tracer::off(),
            Topology::flat(),
            Observer::off(),
            DEFAULT_HIER_THRESHOLD,
        )
    }

    /// Construct with a trace sink: every collective — blocking, eager
    /// fallback, or background comm thread — emits one transport span on
    /// the `fabric` timeline, with the rendezvous time split into
    /// `wait_s` (barrier waits) and `copy_s` (region transfers) attrs.
    pub fn with_tracer(tracer: Tracer) -> ThreadedComm {
        ThreadedComm::with_topology(tracer, Topology::flat())
    }

    /// Construct with a trace sink and a cluster topology. With a
    /// hierarchical topology, AllGather/ReduceScatter over groups that
    /// span the whole cluster run the two-level pipelined algorithms
    /// (bit-identical to the flat rings) and emit one transport span per
    /// wire tier (`intra`/`inter`); all other collectives keep the flat
    /// algorithms and tag their single span with the tier the group
    /// lands on.
    pub fn with_topology(tracer: Tracer, topology: Topology) -> ThreadedComm {
        ThreadedComm::with_obs(tracer, topology, Observer::off())
    }

    /// [`ThreadedComm::with_topology`] plus a health-monitor handle:
    /// every rank thread entering a rendezvous collective publishes a
    /// lock-free heartbeat (collective, bucket, entry time) into the
    /// observer's board and clears it on exit — on both the blocking
    /// path and the background comm threads — so the collective watchdog
    /// can name exactly which rank is stuck where.
    pub fn with_obs(tracer: Tracer, topology: Topology, obs: Observer) -> ThreadedComm {
        ThreadedComm::configured(tracer, topology, obs, DEFAULT_HIER_THRESHOLD)
    }

    /// The fully-specified constructor — what
    /// [`CommBuilder`](super::CommBuilder) builds: trace sink, cluster
    /// topology, health-monitor handle, and serial-fallback threshold.
    pub fn configured(
        tracer: Tracer,
        topology: Topology,
        obs: Observer,
        hier_threshold: usize,
    ) -> ThreadedComm {
        ThreadedComm { stats: SharedStats::default(), hier_threshold, tracer, topology, obs }
    }

    /// Override the serial-fallback threshold (0 forces the rendezvous
    /// algorithms even for tiny buffers — used by the equivalence tests).
    pub fn with_min_parallel_elems(min_parallel_elems: usize) -> ThreadedComm {
        ThreadedComm::configured(
            Tracer::off(),
            Topology::flat(),
            Observer::off(),
            min_parallel_elems,
        )
    }

    fn serial_faster(&self, total_elems: usize) -> bool {
        total_elems < self.hier_threshold
    }

    /// Should this AllGather/ReduceScatter take the two-level path? Only
    /// when the group exactly fills a multi-host topology and is big
    /// enough for the rendezvous algorithms at all (the tiny-buffer
    /// serial fallback is flat and bit-identical either way).
    fn hier_eligible(&self, m: usize, s: usize) -> bool {
        self.topology.is_hierarchical()
            && m == self.topology.total()
            && !(m <= 1 || s == 0 || m * m * s < self.hier_threshold)
    }

    /// Wire-tier label for a flat-algorithm collective under a
    /// hierarchical topology: groups that fit inside one host ride
    /// NVLink, anything wider crosses the IB tier. `None` on flat
    /// topologies (spans stay exactly as before).
    fn tier_label(&self, m: usize) -> Option<&'static str> {
        if !self.topology.is_hierarchical() {
            return None;
        }
        Some(if m <= self.topology.gpus_per_host { "intra" } else { "inter" })
    }

    /// Bracket a collective with a transport span. When tracing is off
    /// this is a direct call with no timing state at all; when on, a
    /// [`RendezvousTiming`] is handed to the algorithm so barrier-wait
    /// vs region-copy time lands on the span as attributes.
    fn traced<F>(&self, name: &'static str, tier: Option<&'static str>, bytes: u64, f: F) -> Result<()>
    where
        F: FnOnce(Option<&RendezvousTiming>) -> Result<()>,
    {
        obs_scoped(&self.obs, name, || spawned_traced(&self.tracer, name, tier, bytes, f))
    }
}

thread_local! {
    /// The observer + collective name [`fan_out`] should publish
    /// heartbeats under, scoped to the current collective call by
    /// [`obs_scoped`]. `None` (the default, and always when the observer
    /// is disarmed) keeps `fan_out` on its plain path.
    static OBS_CTX: std::cell::RefCell<Option<(Observer, &'static str)>> =
        const { std::cell::RefCell::new(None) };
}

/// Run `f` with [`OBS_CTX`] naming this collective, so every
/// [`fan_out`] it performs — directly or via the hierarchical
/// algorithms — brackets each rank body with heartbeat enter/exit.
/// Disarmed observers skip the thread-local entirely (one branch).
fn obs_scoped<R>(obs: &Observer, op: &'static str, f: impl FnOnce() -> R) -> R {
    if !obs.armed() {
        return f();
    }
    OBS_CTX.with(|c| *c.borrow_mut() = Some((obs.clone(), op)));
    // clear on unwind too: a panicking collective must not leave a stale
    // observer attached to this thread's later collectives
    struct ClearCtx;
    impl Drop for ClearCtx {
        fn drop(&mut self) {
            OBS_CTX.with(|c| *c.borrow_mut() = None);
        }
    }
    let _clear = ClearCtx;
    f()
}

/// Per-rank wire bytes each tier moves in a hierarchical collective
/// (same attribution as `Fabric::tier_bytes`): the intra-host phase of
/// an AllGather forwards `g-1` shards per rank, the rail ring forwards
/// `H-1` host super-chunks of `g` shards; the ReduceScatter hand-off
/// chain moves one partial per host hop.
fn hier_span_bytes(is_gather: bool, topo: Topology, s: usize) -> (u64, u64) {
    let b = (s * 4) as u64;
    let (h, g) = (topo.hosts as u64, topo.gpus_per_host as u64);
    if is_gather {
        ((g - 1) * b, (h - 1) * g * b)
    } else {
        ((g - 1) * b, (h - 1) * b)
    }
}

/// Bracket a hierarchically-dispatched collective: one measured wall
/// interval, two adjacent transport spans — the interval is split
/// between the `intra` and `inter` tiers in proportion to the time the
/// rank threads actually spent in each tier's waits and copies, so the
/// spans still sum to the measured wall time (`TraceSummary`'s
/// `total_comm_s` is unchanged by the split).
fn hier_traced<F>(
    tracer: &Tracer,
    name: &'static str,
    tier_bytes: (u64, u64),
    f: F,
) -> Result<()>
where
    F: FnOnce(Option<&RendezvousTiming>, Option<&RendezvousTiming>) -> Result<()>,
{
    if !tracer.enabled(Cat::Comm) {
        return f(None, None);
    }
    let tm_intra = RendezvousTiming::default();
    let tm_inter = RendezvousTiming::default();
    let t = tracer.timer();
    let r = f(Some(&tm_intra), Some(&tm_inter));
    let dur = t.elapsed_s();
    let (wi, ci) = tm_intra.totals();
    let (we, ce) = tm_inter.totals();
    let (ti, te) = (wi + ci, we + ce);
    let frac = if ti + te > 0.0 { ti / (ti + te) } else { 0.5 };
    let intra_s = dur * frac;
    tracer.push_window(&t, 0.0, intra_s, Cat::Comm, || {
        Span::new(name)
            .fabric()
            .bytes(tier_bytes.0)
            .attr("tier", "intra")
            .attr("wait_s", format!("{wi:.9}"))
            .attr("copy_s", format!("{ci:.9}"))
    });
    tracer.push_window(&t, intra_s, dur - intra_s, Cat::Comm, || {
        Span::new(name)
            .fabric()
            .bytes(tier_bytes.1)
            .attr("tier", "inter")
            .attr("wait_s", format!("{we:.9}"))
            .attr("copy_s", format!("{ce:.9}"))
    });
    r
}

/// Per-collective rendezvous time split, accumulated across rank threads
/// (sums over ranks; an m-rank barrier wait therefore contributes up to
/// m× the wall time it occupied).
#[derive(Debug, Default)]
pub(crate) struct RendezvousTiming {
    wait_ns: AtomicU64,
    copy_ns: AtomicU64,
}

impl RendezvousTiming {
    pub(crate) fn totals(&self) -> (f64, f64) {
        (
            self.wait_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.copy_ns.load(Ordering::Relaxed) as f64 / 1e9,
        )
    }
}

/// Run `f`, accumulating its duration into the wait or copy counter when
/// timing is enabled. With `tm == None` this compiles down to the bare
/// call — the disabled-tracing hot path takes no clock samples.
pub(crate) fn timed<R>(tm: Option<&RendezvousTiming>, is_wait: bool, f: impl FnOnce() -> R) -> R {
    match tm {
        None => f(),
        Some(tm) => {
            let t0 = Instant::now();
            let r = f();
            let ns = t0.elapsed().as_nanos() as u64;
            let ctr = if is_wait { &tm.wait_ns } else { &tm.copy_ns };
            ctr.fetch_add(ns, Ordering::Relaxed);
            r
        }
    }
}

/// [`ThreadedComm::traced`] for the background comm thread: same span,
/// recorded from inside the spawned closure so the span's wall time is
/// the transfer itself, not the issue site.
fn spawned_traced<F>(
    tracer: &Tracer,
    name: &'static str,
    tier: Option<&'static str>,
    bytes: u64,
    f: F,
) -> Result<()>
where
    F: FnOnce(Option<&RendezvousTiming>) -> Result<()>,
{
    if !tracer.enabled(Cat::Comm) {
        return f(None);
    }
    let tm = RendezvousTiming::default();
    let t = tracer.timer();
    let r = f(Some(&tm));
    let (wait_s, copy_s) = tm.totals();
    tracer.finish_with(t, Cat::Comm, || {
        let mut span = Span::new(name)
            .fabric()
            .bytes(bytes)
            .attr("wait_s", format!("{wait_s:.9}"))
            .attr("copy_s", format!("{copy_s:.9}"));
        if let Some(tier) = tier {
            span = span.attr("tier", tier);
        }
        span
    });
    r
}

impl ThreadedComm {
    /// Async collectives from the tests force the rendezvous path too.
    #[cfg(test)]
    fn forced() -> ThreadedComm {
        ThreadedComm::with_min_parallel_elems(0)
    }
}

/// Raw shared view of every rank's buffer for one rendezvous collective.
/// The pointers stay valid for the whole call: the caller's `&mut [Vec]`
/// is borrowed across the scoped threads, which all join before return.
pub(crate) struct SharedBufs {
    ptrs: Vec<*mut f32>,
    lens: Vec<usize>,
}

unsafe impl Send for SharedBufs {}
unsafe impl Sync for SharedBufs {}

impl SharedBufs {
    pub(crate) fn new(bufs: &mut [Vec<f32>]) -> SharedBufs {
        SharedBufs {
            ptrs: bufs.iter_mut().map(|b| b.as_mut_ptr()).collect(),
            lens: bufs.iter().map(|b| b.len()).collect(),
        }
    }

    /// Element range `[lo, hi)` of rank `k`'s buffer as a shared slice.
    ///
    /// Safety: the range must be in bounds, and the protocol must
    /// guarantee no concurrent `region_mut` overlaps it in this phase.
    pub(crate) unsafe fn region(&self, k: usize, lo: usize, hi: usize) -> &[f32] {
        debug_assert!(hi <= self.lens[k]);
        std::slice::from_raw_parts(self.ptrs[k].add(lo), hi - lo)
    }

    /// Mutable element range `[lo, hi)` of rank `k`'s buffer.
    ///
    /// Safety: in bounds, and this phase's unique writer for the range.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn region_mut(&self, k: usize, lo: usize, hi: usize) -> &mut [f32] {
        debug_assert!(hi <= self.lens[k]);
        std::slice::from_raw_parts_mut(self.ptrs[k].add(lo), hi - lo)
    }
}

thread_local! {
    /// Test-only rendezvous fault injection: per-rank arrival delays in
    /// microseconds, applied by [`fan_out`] before each rank enters the
    /// collective body. Empty (the default) is a no-op on every hot path
    /// beyond one thread-local read per collective.
    static ARRIVAL_STAGGER: std::cell::RefCell<Vec<u64>> =
        std::cell::RefCell::new(Vec::new());
}

/// Stagger rank arrival into subsequent *blocking* collectives issued
/// from the calling thread: rank `r` sleeps `delays_us[r]` microseconds
/// before entering each collective's rendezvous. The rendezvous protocol
/// must produce bit-identical results under any arrival permutation and
/// must never deadlock — `tests/threaded_stress.rs` drives seeded
/// permutations through this hook to prove it. Thread-local: it does not
/// reach collectives issued from background comm threads (async
/// begin/finish pairs), and `set_arrival_stagger(&[])` clears it.
pub fn set_arrival_stagger(delays_us: &[u64]) {
    ARRIVAL_STAGGER.with(|s| *s.borrow_mut() = delays_us.to_vec());
}

/// Run `f(rank)` on `m` concurrent ranks; rank 0 runs on the caller's
/// thread. Returns after every rank finished (scoped join). Honors the
/// caller thread's [`set_arrival_stagger`] delays, and — when the
/// enclosing collective ran under [`obs_scoped`] — publishes each rank's
/// heartbeat around its body, *after* the injected arrival delay, so a
/// staggered straggler shows up on the health board exactly as the
/// waiting ranks it starves do.
pub(crate) fn fan_out<F: Fn(usize) + Sync>(m: usize, f: F) {
    let stagger = ARRIVAL_STAGGER.with(|s| s.borrow().clone());
    let obs_ctx = OBS_CTX.with(|c| c.borrow().clone());
    let delay = |rank: usize| {
        if let Some(&us) = stagger.get(rank) {
            if us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(us));
            }
        }
    };
    let run = |rank: usize| {
        if let Some((obs, op)) = &obs_ctx {
            obs.rank_enter(rank, *op);
            f(rank);
            obs.rank_exit(rank);
        } else {
            f(rank);
        }
    };
    std::thread::scope(|s| {
        for rank in 1..m {
            let delay = &delay;
            let run = &run;
            s.spawn(move || {
                delay(rank);
                run(rank)
            });
        }
        delay(0);
        run(0);
    });
}

/// The rendezvous ring AllGather, as a free function so the sync path and
/// the background comm thread of `all_gather_async` run the exact same
/// algorithm (bit-identical either way).
fn ring_all_gather(
    bufs: &mut [Vec<f32>],
    s: usize,
    min_parallel_elems: usize,
    tm: Option<&RendezvousTiming>,
) -> Result<()> {
    let m = bufs.len();
    if m <= 1 || s == 0 || m * m * s < min_parallel_elems {
        return timed(tm, false, || comm::all_gather(bufs, s));
    }
    for b in bufs.iter() {
        if b.len() < m * s {
            bail!("all_gather buffer too small: {} < {}", b.len(), m * s);
        }
    }
    let shared = SharedBufs::new(bufs);
    let barrier = Barrier::new(m);
    fan_out(m, |rank| {
        // Chunked ring: after step t, rank k holds chunks k..=k-t-1
        // (mod m). Step t: rank k writes its own chunk (k-1-t) while
        // its right neighbor reads chunk (k-t) — disjoint; the
        // barrier orders step t's writes before step t+1's reads.
        let left = (rank + m - 1) % m;
        for step in 0..m - 1 {
            let c = (rank + m - 1 - step) % m;
            timed(tm, false, || unsafe {
                let src = shared.region(left, c * s, (c + 1) * s);
                shared.region_mut(rank, c * s, (c + 1) * s).copy_from_slice(src);
            });
            timed(tm, true, || barrier.wait());
        }
    });
    Ok(())
}

/// The rendezvous ReduceScatter (rank-order summation), shared by the
/// sync path and the background comm thread of `reduce_scatter_async`.
fn rendezvous_reduce_scatter(
    bufs: &mut [Vec<f32>],
    s: usize,
    scale: f32,
    min_parallel_elems: usize,
    tm: Option<&RendezvousTiming>,
) -> Result<()> {
    let m = bufs.len();
    if m <= 1 || s == 0 || m * m * s < min_parallel_elems {
        return timed(tm, false, || comm::reduce_scatter(bufs, s, scale));
    }
    for b in bufs.iter() {
        if b.len() < m * s {
            bail!("reduce_scatter buffer too small: {} < {}", b.len(), m * s);
        }
    }
    let shared = SharedBufs::new(bufs);
    fan_out(m, |rank| {
        // Rank k reduces chunk k across all ranks in rank order (the
        // serial summation order — bit-identical results), then
        // overwrites only its own chunk-k region. Rank j only ever
        // reads chunk j, so the single write per buffer is disjoint
        // from every concurrent read (j != k ⇒ different chunk).
        timed(tm, false, || {
            let mut acc = vec![0.0f32; s];
            unsafe {
                for r in 0..m {
                    let src = shared.region(r, rank * s, (rank + 1) * s);
                    for (a, &x) in acc.iter_mut().zip(src) {
                        *a += x;
                    }
                }
            }
            for a in acc.iter_mut() {
                *a *= scale;
            }
            unsafe {
                shared.region_mut(rank, rank * s, (rank + 1) * s).copy_from_slice(&acc);
            }
        });
    });
    Ok(())
}

/// The rendezvous all-to-all, as a free function so the sync path and the
/// background comm thread of `all_to_all_async` run the exact same
/// algorithm (pure region copies — bit patterns are preserved, which the
/// quantized collectives' packed int8 wire format relies on).
fn rendezvous_all_to_all(
    bufs: &mut [Vec<f32>],
    s: usize,
    min_parallel_elems: usize,
    tm: Option<&RendezvousTiming>,
) -> Result<()> {
    let m = bufs.len();
    if m <= 1 || s == 0 || m * m * s < min_parallel_elems {
        return timed(tm, false, || comm::all_to_all(bufs, s));
    }
    for b in bufs.iter() {
        if b.len() < m * s {
            bail!("all_to_all buffer too small");
        }
    }
    let shared = SharedBufs::new(bufs);
    let barrier = Barrier::new(m);
    fan_out(m, |rank| {
        // phase 1 (reads only): pull slot `rank` from every sender —
        // the incoming column of the transpose
        let mut incoming = vec![0.0f32; m * s];
        timed(tm, false, || unsafe {
            for r in 0..m {
                incoming[r * s..(r + 1) * s]
                    .copy_from_slice(shared.region(r, rank * s, (rank + 1) * s));
            }
        });
        timed(tm, true, || barrier.wait());
        // phase 2 (writes only): overwrite own buffer in place
        timed(tm, false, || unsafe {
            shared.region_mut(rank, 0, m * s).copy_from_slice(&incoming);
        });
    });
    Ok(())
}

impl Communicator for ThreadedComm {
    fn backend(&self) -> CommBackend {
        CommBackend::Threaded
    }

    fn describe(&self, op: LaunchOp, group: usize, elems: usize) -> CollectiveLaunch {
        CollectiveLaunch::new(op, group, elems)
            .on_topology(self.topology)
            .with_hier_threshold(self.hier_threshold)
    }

    /// The blocking transport stage. Tier routing comes first —
    /// AllGather/ReduceScatter over groups that exactly fill a
    /// multi-host topology dispatch to the two-level algorithms (one
    /// span per wire tier); everything else takes the flat rendezvous
    /// with the descriptor-driven serial fallback inside, bracketed by
    /// one transport span and the obs heartbeat scope.
    fn launch(&self, l: &CollectiveLaunch, bufs: &mut [Vec<f32>]) -> Result<()> {
        let m = bufs.len();
        let s = l.comm_elems();
        match l.op {
            LaunchOp::AllGather | LaunchOp::ReduceScatter if self.hier_eligible(m, s) => {
                let topo = self.topology;
                let name = l.op.name();
                let is_gather = l.op == LaunchOp::AllGather;
                let scale = l.scale;
                obs_scoped(&self.obs, name, || {
                    hier_traced(
                        &self.tracer,
                        name,
                        hier_span_bytes(is_gather, topo, s),
                        |tm_intra, tm_inter| {
                            if is_gather {
                                hier_all_gather(bufs, s, topo, tm_intra, tm_inter)
                            } else {
                                hier_reduce_scatter(bufs, s, scale, topo, tm_intra, tm_inter)
                            }
                        },
                    )
                })
            }
            LaunchOp::AllGather => {
                let bytes = (m * s * 4) as u64;
                self.traced("all_gather", self.tier_label(m), bytes, |tm| {
                    ring_all_gather(bufs, s, self.hier_threshold, tm)
                })
            }
            LaunchOp::ReduceScatter => {
                let bytes = (m * s * 4) as u64;
                self.traced("reduce_scatter", self.tier_label(m), bytes, |tm| {
                    rendezvous_reduce_scatter(bufs, s, l.scale, self.hier_threshold, tm)
                })
            }
            LaunchOp::AllToAll => {
                let bytes = (m * s * 4) as u64;
                self.traced("all_to_all", self.tier_label(m), bytes, |tm| {
                    rendezvous_all_to_all(bufs, s, self.hier_threshold, tm)
                })
            }
            LaunchOp::AllReduce => self.launch_all_reduce(bufs, l.scale),
            LaunchOp::Broadcast => self.launch_broadcast(bufs, l.root),
        }
    }

    /// The nonblocking transport stage. Below the threading threshold a
    /// comm-thread spawn costs more than the exchange itself — complete
    /// eagerly, same as the blocking path's serial fallback
    /// (bit-identical either way; the blocking launch emits the
    /// transport span). Whole-buffer ops (AllReduce/Broadcast) always
    /// complete eagerly. Everything else runs on a background comm
    /// thread — two-level when the tier routing says so, flat otherwise.
    fn launch_async(&self, l: &CollectiveLaunch, mut bufs: Vec<Vec<f32>>) -> PendingOp {
        let m = bufs.len();
        let s = l.comm_elems();
        let ring_op =
            matches!(l.op, LaunchOp::AllGather | LaunchOp::ReduceScatter | LaunchOp::AllToAll);
        if !ring_op || m <= 1 || s == 0 || m * m * s < self.hier_threshold {
            let r = self.launch(l, &mut bufs).map(|()| bufs);
            return PendingOp::done(r);
        }
        if matches!(l.op, LaunchOp::AllGather | LaunchOp::ReduceScatter)
            && self.hier_eligible(m, s)
        {
            let topo = self.topology;
            let tracer = self.tracer.clone();
            let obs = self.obs.clone();
            let name = l.op.name();
            let is_gather = l.op == LaunchOp::AllGather;
            let scale = l.scale;
            return PendingOp::spawn(move || {
                obs_scoped(&obs, name, || {
                    hier_traced(
                        &tracer,
                        name,
                        hier_span_bytes(is_gather, topo, s),
                        |tm_intra, tm_inter| {
                            if is_gather {
                                hier_all_gather(&mut bufs, s, topo, tm_intra, tm_inter)
                            } else {
                                hier_reduce_scatter(&mut bufs, s, scale, topo, tm_intra, tm_inter)
                            }
                        },
                    )
                })?;
                Ok(bufs)
            });
        }
        let min = self.hier_threshold;
        let tier = self.tier_label(m);
        let tracer = self.tracer.clone();
        let obs = self.obs.clone();
        let bytes = (m * s * 4) as u64;
        let op = l.op;
        let name = op.name();
        let scale = l.scale;
        PendingOp::spawn(move || {
            obs_scoped(&obs, name, || {
                spawned_traced(&tracer, name, tier, bytes, |tm| match op {
                    LaunchOp::AllGather => ring_all_gather(&mut bufs, s, min, tm),
                    LaunchOp::ReduceScatter => {
                        rendezvous_reduce_scatter(&mut bufs, s, scale, min, tm)
                    }
                    _ => rendezvous_all_to_all(&mut bufs, s, min, tm),
                })
            })?;
            Ok(bufs)
        })
    }

    fn record(&self, rec: CommRecord) {
        self.stats.record(rec);
    }

    fn stats(&self) -> CommStats {
        self.stats.snapshot()
    }

    fn sim_time(&self) -> f64 {
        self.stats.total_time()
    }

    fn wire_totals(&self) -> (u64, u64, u64) {
        self.stats.wire_totals()
    }

    fn reset_stats(&self) {
        self.stats.reset();
    }
}

impl ThreadedComm {
    /// The rendezvous AllReduce body (balanced ranges, rank-order
    /// summation), kept private to the transport stage.
    fn launch_all_reduce(&self, bufs: &mut [Vec<f32>], scale: f32) -> Result<()> {
        let m = bufs.len();
        let bytes = (bufs.first().map_or(0, Vec::len) * m * 4) as u64;
        self.traced("all_reduce", self.tier_label(m), bytes, |tm| {
            if m <= 1 || self.serial_faster(m * bufs[0].len()) {
                return timed(tm, false, || comm::all_reduce(bufs, scale));
            }
            let n = bufs[0].len();
            for b in bufs.iter() {
                if b.len() != n {
                    bail!("all_reduce length mismatch");
                }
            }
            if n == 0 {
                return Ok(());
            }
            let shared = SharedBufs::new(bufs);
            let barrier = Barrier::new(m);
            // balanced contiguous element ranges, one per rank (may be
            // empty when n < m); per element the reduction order is rank
            // 0..m, so any partition gives bit-identical results
            let range = |k: usize| -> (usize, usize) {
                let base = n / m;
                let extra = n % m;
                let lo = k * base + k.min(extra);
                (lo, lo + base + usize::from(k < extra))
            };
            fan_out(m, |rank| {
                // phase 1: reduce own range across all ranks (reads only)
                let (lo, hi) = range(rank);
                let mut acc = vec![0.0f32; hi - lo];
                timed(tm, false, || {
                    unsafe {
                        for r in 0..m {
                            let src = shared.region(r, lo, hi);
                            for (a, &x) in acc.iter_mut().zip(src) {
                                *a += x;
                            }
                        }
                    }
                    for a in acc.iter_mut() {
                        *a *= scale;
                    }
                });
                timed(tm, true, || barrier.wait());
                // phase 2: publish own range into every buffer (writes
                // only; unique writer per (buffer, range) pair)
                timed(tm, false, || unsafe {
                    for r in 0..m {
                        shared.region_mut(r, lo, hi).copy_from_slice(&acc);
                    }
                });
            });
            Ok(())
        })
    }

    /// The rendezvous Broadcast body (root validation before any span is
    /// emitted, exactly like the loop reference), kept private to the
    /// transport stage.
    fn launch_broadcast(&self, bufs: &mut [Vec<f32>], root: usize) -> Result<()> {
        let m = bufs.len();
        if root >= m {
            bail!("broadcast root {root} out of range");
        }
        let bytes = (bufs[root].len() * m * 4) as u64;
        self.traced("broadcast", self.tier_label(m), bytes, |tm| {
            if m <= 1 || self.serial_faster(m * bufs[root].len()) {
                return timed(tm, false, || comm::broadcast(bufs, root));
            }
            let n = bufs[root].len();
            for (k, b) in bufs.iter().enumerate() {
                if b.len() != n {
                    bail!("broadcast length mismatch at rank {k}");
                }
            }
            let shared = SharedBufs::new(bufs);
            fan_out(m, |rank| {
                // concurrent reads of root's buffer; each non-root rank
                // is the unique writer of its own buffer
                if rank != root {
                    timed(tm, false, || unsafe {
                        let src = shared.region(root, 0, n);
                        shared.region_mut(rank, 0, n).copy_from_slice(src);
                    });
                }
            });
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev_bufs(m: usize, s: usize) -> Vec<Vec<f32>> {
        (0..m)
            .map(|k| {
                let mut b = vec![0.0f32; m * s];
                for (i, x) in b[k * s..(k + 1) * s].iter_mut().enumerate() {
                    *x = (k * 100 + i) as f32;
                }
                b
            })
            .collect()
    }

    #[test]
    fn ring_all_gather_replicates_all_shards() {
        for m in [1usize, 2, 3, 4, 8] {
            let s = 5;
            let mut bufs = dev_bufs(m, s);
            ThreadedComm::with_min_parallel_elems(0).all_gather(&mut bufs, s).unwrap();
            for buf in &bufs {
                for k in 0..m {
                    for i in 0..s {
                        assert_eq!(buf[k * s + i], (k * 100 + i) as f32);
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_matches_serial_bitwise() {
        let (m, s) = (4, 7);
        // magnitudes spread over many exponents so a different summation
        // order would actually change the bits
        let mk = |seed: u64| -> Vec<Vec<f32>> {
            let mut rng = crate::util::Rng::new(seed);
            (0..m)
                .map(|_| {
                    (0..m * s)
                        .map(|_| rng.normal_f32() * 10f32.powi(rng.below(7) as i32 - 3))
                        .collect()
                })
                .collect()
        };
        let mut a = mk(9);
        let mut b = a.clone();
        comm::reduce_scatter(&mut a, s, 1.0 / m as f32).unwrap();
        ThreadedComm::with_min_parallel_elems(0).reduce_scatter(&mut b, s, 1.0 / m as f32).unwrap();
        for (x, y) in a.iter().flatten().zip(b.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn all_reduce_ragged_length() {
        // n = 10 not divisible by m = 4: ranges 3/3/2/2
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|k| vec![(k + 1) as f32; 10]).collect();
        ThreadedComm::with_min_parallel_elems(0).all_reduce(&mut bufs, 0.25).unwrap();
        for b in &bufs {
            assert!(b.iter().all(|&x| (x - 2.5).abs() < 1e-6));
        }
    }

    #[test]
    fn broadcast_and_all_to_all() {
        let c = ThreadedComm::with_min_parallel_elems(0);
        let mut bufs = vec![vec![0.0f32; 4], vec![7.0f32; 4], vec![0.0f32; 4]];
        c.broadcast(&mut bufs, 1).unwrap();
        for b in &bufs {
            assert!(b.iter().all(|&x| x == 7.0));
        }
        let (m, s) = (3, 2);
        let mut bufs: Vec<Vec<f32>> = (0..m)
            .map(|k| (0..m * s).map(|i| (k * 10 + i / s) as f32).collect())
            .collect();
        c.all_to_all(&mut bufs, s).unwrap();
        for (j, buf) in bufs.iter().enumerate() {
            for k in 0..m {
                assert_eq!(buf[k * s], (k * 10 + j) as f32);
            }
        }
    }

    #[test]
    fn async_rendezvous_bit_identical_to_sync() {
        let (m, s) = (4, 6);
        let mk = |seed: u64| -> Vec<Vec<f32>> {
            let mut rng = crate::util::Rng::new(seed);
            (0..m)
                .map(|_| {
                    (0..m * s)
                        .map(|_| rng.normal_f32() * 10f32.powi(rng.below(7) as i32 - 3))
                        .collect()
                })
                .collect()
        };
        let comm = ThreadedComm::forced();
        let mut sync_ag = mk(3);
        comm.all_gather(&mut sync_ag, s).unwrap();
        let async_ag = comm.all_gather_async(mk(3), s).wait().unwrap();
        for (a, b) in sync_ag.iter().flatten().zip(async_ag.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mut sync_rs = mk(4);
        comm.reduce_scatter(&mut sync_rs, s, 0.25).unwrap();
        let async_rs = comm.reduce_scatter_async(mk(4), s, 0.25).wait().unwrap();
        for (a, b) in sync_rs.iter().flatten().zip(async_rs.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mut sync_a2a = mk(5);
        comm.all_to_all(&mut sync_a2a, s).unwrap();
        let async_a2a = comm.all_to_all_async(mk(5), s).wait().unwrap();
        for (a, b) in sync_a2a.iter().flatten().zip(async_a2a.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // errors surface at wait(), not at issue
        let bad = vec![vec![0.0f32; 2]; 4];
        assert!(comm.all_gather_async(bad, 6).wait().is_err());
    }

    #[test]
    fn every_path_emits_one_transport_span() {
        use crate::trace::{TraceLevel, Tracer};
        let tracer = Tracer::new(TraceLevel::Comm, 4);
        let mut c = ThreadedComm::with_tracer(tracer.clone());
        c.hier_threshold = 0; // force the rendezvous algorithms
        let (m, s) = (4usize, 3usize);
        let mk = || dev_bufs(m, s);
        // sync, eager-async (threshold), and background-async paths must
        // each record exactly one span per collective call
        let mut bufs = mk();
        c.all_gather(&mut bufs, s).unwrap();
        assert_eq!(tracer.span_count(), 1);
        c.all_gather_async(mk(), s).wait().unwrap();
        assert_eq!(tracer.span_count(), 2);
        let eager = ThreadedComm::with_tracer(tracer.clone()); // default threshold -> eager
        eager.all_gather_async(mk(), s).wait().unwrap();
        assert_eq!(tracer.span_count(), 3);
        let ids = tracer.span_identities();
        assert!(ids.iter().all(|(name, _, bytes)| name == "all_gather" && *bytes > 0));
    }

    #[test]
    fn validation_matches_serial() {
        let c = ThreadedComm::with_min_parallel_elems(0);
        let mut bufs = vec![vec![0.0f32; 4]; 2];
        assert!(c.all_gather(&mut bufs, 4).is_err());
        assert!(c.broadcast(&mut bufs, 5).is_err());
        let mut uneven = vec![vec![0.0f32; 4], vec![0.0f32; 5]];
        assert!(c.all_reduce(&mut uneven, 1.0).is_err());
    }

    fn wild_bufs(m: usize, s: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::Rng::new(seed);
        (0..m)
            .map(|_| {
                (0..m * s)
                    .map(|_| rng.normal_f32() * 10f32.powi(rng.below(7) as i32 - 3))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn hierarchical_dispatch_bit_identical_to_flat() {
        let (m, s) = (8usize, 6usize);
        let topo = Topology::parse("2x4:2").unwrap();
        let mut want_ag = wild_bufs(m, s, 11);
        comm::all_gather(&mut want_ag, s).unwrap();
        let mut want_rs = wild_bufs(m, s, 12);
        comm::reduce_scatter(&mut want_rs, s, 0.125).unwrap();

        let mut c = ThreadedComm::with_topology(Tracer::off(), topo);
        c.hier_threshold = 0;
        let mut got_ag = wild_bufs(m, s, 11);
        c.all_gather(&mut got_ag, s).unwrap();
        for (a, b) in want_ag.iter().flatten().zip(got_ag.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mut got_rs = wild_bufs(m, s, 12);
        c.reduce_scatter(&mut got_rs, s, 0.125).unwrap();
        for (a, b) in want_rs.iter().flatten().zip(got_rs.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // the background comm thread dispatches hierarchically too
        let async_ag = c.all_gather_async(wild_bufs(m, s, 11), s).wait().unwrap();
        for (a, b) in want_ag.iter().flatten().zip(async_ag.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let async_rs =
            c.reduce_scatter_async(wild_bufs(m, s, 12), s, 0.125).wait().unwrap();
        for (a, b) in want_rs.iter().flatten().zip(async_rs.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn hierarchical_ops_emit_one_span_per_tier() {
        use crate::trace::TraceLevel;
        let (m, s) = (8usize, 3usize);
        let tracer = Tracer::new(TraceLevel::Comm, m);
        let mut c =
            ThreadedComm::with_topology(tracer.clone(), Topology::parse("2x4:2").unwrap());
        c.hier_threshold = 0;
        let mut bufs = dev_bufs(m, s);
        c.all_gather(&mut bufs, s).unwrap();
        assert_eq!(tracer.span_count(), 2, "hier AG = intra span + inter span");
        let mut bufs = wild_bufs(m, s, 3);
        c.reduce_scatter(&mut bufs, s, 0.125).unwrap();
        assert_eq!(tracer.span_count(), 4);
        // a group that does not fill the topology keeps the flat ring
        // and its single (tier-tagged) span
        let mut small = dev_bufs(4, s);
        c.all_gather(&mut small, s).unwrap();
        assert_eq!(tracer.span_count(), 5);
        // per-tier byte attribution: AG intra (g-1)·sb, inter (H-1)·g·sb;
        // RS intra (g-1)·sb, inter (H-1)·sb
        let sb = (s * 4) as u64;
        let ids = tracer.span_identities();
        let ag_bytes: Vec<u64> = ids
            .iter()
            .filter(|(n, _, _)| n == "all_gather")
            .map(|(_, _, b)| *b)
            .collect();
        assert!(ag_bytes.contains(&(3 * sb)), "intra AG bytes: {ag_bytes:?}");
        assert!(ag_bytes.contains(&(4 * sb)), "inter AG bytes: {ag_bytes:?}");
    }
}
