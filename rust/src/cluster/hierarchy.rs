//! Topology-aware two-level collectives with intra-collective chunk
//! pipelining.
//!
//! A [`Topology`](crate::comm::Topology) of `H` hosts × `g` ranks (rank
//! `r` lives at host `r / g`, local index `l = r % g` — host-major) turns
//! the flat single-ring algorithms of `cluster/threaded.rs` into
//! two-tier ones:
//!
//! * **AllGather** — phase A: each host runs the chunked intra-host ring
//!   over its own `g` chunks (`g-1` NVLink-tier hops). Phase B: a
//!   *rail-aligned* inter-host ring — the `H` ranks sharing local index
//!   `l` form rail `l` and exchange whole host *super-chunks* (`g`
//!   chunks) in `H-1` IB-tier steps, every rail in parallel. Total
//!   volume per rank is `(g-1) + (H-1)·g = m-1` chunks — identical to
//!   the flat ring — but only `(H-1)·g` of them cross hosts and the
//!   long-haul step count drops from `m-1` to `O(g + H)`.
//! * **ReduceScatter** — a host-chained prefix fold: host 0 sums its `g`
//!   contributions to chunk `k` (in rank order, starting from `0.0`),
//!   hands the partial to host 1, which adds its `g` contributions, …;
//!   host `H-1` applies the scale and writes chunk `k`'s owner region.
//!   The chain performs *exactly* the serial reference's left-to-right
//!   f32 additions (`comm::reduce_scatter`), so results are bit-identical
//!   to the flat path by construction — while only one partial (not `g`)
//!   per chunk crosses each host boundary: the intra-host pre-reduce
//!   that shrinks inter-host volume `g`-fold.
//!
//! **Chunk pipelining**: each collective is split into `S` segments
//! (`off(σ) = σ·s/S` sub-ranges of every chunk). AllGather interleaves
//! phase B of segment `σ` with phase A of segment `σ+1` in a wave
//! schedule; ReduceScatter staggers the host chain one wave per host, so
//! host `h` folds segment `σ` while host `h-1` is already folding
//! segment `σ+1`. Segment boundaries only re-slice pure copies and the
//! exact same addition chain, so results are invariant in `S`.
//!
//! Safety model (same discipline as `threaded.rs`, arguments inline):
//! disjoint `region`/`region_mut` slices per phase, with per-host
//! barriers (`g` participants) ordering intra-host ring steps and
//! per-rail barriers (`H` participants) ordering inter-host steps and
//! the scratch handoff. Every rank executes the identical wave/barrier
//! sequence, so the schedule cannot deadlock.

use std::sync::Barrier;

use anyhow::{bail, Result};

use crate::comm::Topology;

use super::threaded::{fan_out, timed, RendezvousTiming, SharedBufs};

/// Hierarchical AllGather: intra-host ring + rail-aligned inter-host
/// super-chunk ring, pipelined over `topo.segments` segments. Pure region
/// copies — bit patterns are preserved, so the result is bit-identical to
/// the flat ring (and to the serial reference) for any topology.
///
/// `tm_intra`/`tm_inter` accumulate the per-tier wait/copy split when
/// tracing is on (`None` = no clock samples at all).
pub(crate) fn hier_all_gather(
    bufs: &mut [Vec<f32>],
    s: usize,
    topo: Topology,
    tm_intra: Option<&RendezvousTiming>,
    tm_inter: Option<&RendezvousTiming>,
) -> Result<()> {
    let m = bufs.len();
    let (hosts, g, segs) = (topo.hosts, topo.gpus_per_host, topo.segments.max(1));
    if m != hosts * g || hosts < 2 {
        bail!("hier_all_gather: {m} ranks don't fill topology {}", topo.label());
    }
    for b in bufs.iter() {
        if b.len() < m * s {
            bail!("all_gather buffer too small: {} < {}", b.len(), m * s);
        }
    }
    if s == 0 {
        return Ok(());
    }
    let shared = SharedBufs::new(bufs);
    let host_barrier: Vec<Barrier> = (0..hosts).map(|_| Barrier::new(g)).collect();
    let rail_barrier: Vec<Barrier> = (0..g).map(|_| Barrier::new(hosts)).collect();
    let off = |sigma: usize| sigma * s / segs;
    fan_out(m, |rank| {
        let (h, l) = (rank / g, rank % g);
        let left_local = h * g + (l + g - 1) % g;
        let left_host = ((h + hosts - 1) % hosts) * g + l;
        // Wave w: phase A gathers segment w inside the host while phase B
        // relays the already-host-complete segment w-1 across the rail.
        for wave in 0..=segs {
            if wave < segs {
                let (lo, hi) = (off(wave), off(wave + 1));
                // Phase A — intra-host chunked ring over the host's own g
                // chunks (global h·g..h·g+g), segment `wave` only. Step t:
                // local rank l writes local chunk (l-1-t) mod g of its own
                // buffer while its right neighbor reads a different chunk
                // of it; the host barrier orders step t's writes before
                // step t+1's reads (the flat ring's argument, per host).
                for step in 0..g.saturating_sub(1) {
                    let c = h * g + (l + g - 1 - step) % g;
                    timed(tm_intra, false, || unsafe {
                        let src = shared.region(left_local, c * s + lo, c * s + hi);
                        shared.region_mut(rank, c * s + lo, c * s + hi).copy_from_slice(src);
                    });
                    timed(tm_intra, true, || host_barrier[h].wait());
                }
            }
            // Orders phase A(w) writes on every host of the rail before
            // phase B(w) reads them one wave later. Phase A touches only
            // same-host buffers and phase B only rail-l buffers at
            // other-host chunk regions, so cross-phase slices of the same
            // wave never alias.
            timed(tm_inter, true, || rail_barrier[l].wait());
            if wave >= 1 {
                let (lo, hi) = (off(wave - 1), off(wave));
                // Phase B — inter-host ring along rail l over host
                // super-chunks, segment `wave-1`. Step t: copy host
                // (h-1-t) mod H's super-chunk (its g chunks' segment
                // sub-ranges) from the rail-left neighbor. Writers and
                // readers of one buffer always touch different
                // super-chunks within a step (H >= 2), and rail barriers
                // order consecutive steps.
                for step in 0..hosts - 1 {
                    let ch = (h + hosts - 1 - step) % hosts;
                    timed(tm_inter, false, || unsafe {
                        for c in ch * g..(ch + 1) * g {
                            let src = shared.region(left_host, c * s + lo, c * s + hi);
                            shared
                                .region_mut(rank, c * s + lo, c * s + hi)
                                .copy_from_slice(src);
                        }
                    });
                    timed(tm_inter, true, || rail_barrier[l].wait());
                }
            }
        }
    });
    Ok(())
}

/// Hierarchical ReduceScatter: host-chained prefix fold, pipelined by
/// staggering hosts one wave apart. Chunk `k`'s fold step on host `h`
/// runs on rank `(h, k mod g)`; the partial travels host 0 → 1 → … →
/// H-1 through a shared per-chunk scratch buffer, accumulating every
/// rank's contribution **in rank order 0..m** — the serial reference's
/// exact f32 addition chain, so results are bit-identical to
/// [`comm::reduce_scatter`](crate::comm::reduce_scatter) (and the flat
/// threaded path) while only the folded partial crosses each host
/// boundary.
pub(crate) fn hier_reduce_scatter(
    bufs: &mut [Vec<f32>],
    s: usize,
    scale: f32,
    topo: Topology,
    tm_intra: Option<&RendezvousTiming>,
    tm_inter: Option<&RendezvousTiming>,
) -> Result<()> {
    let m = bufs.len();
    let (hosts, g, segs) = (topo.hosts, topo.gpus_per_host, topo.segments.max(1));
    if m != hosts * g || hosts < 2 {
        bail!("hier_reduce_scatter: {m} ranks don't fill topology {}", topo.label());
    }
    for b in bufs.iter() {
        if b.len() < m * s {
            bail!("reduce_scatter buffer too small: {} < {}", b.len(), m * s);
        }
    }
    if s == 0 {
        return Ok(());
    }
    // Per-chunk partial-sum handoff buffers (the simulated inter-host
    // wire). scratch[k] segment σ is written by host h at wave h+σ and
    // read by host h+1 at wave h+1+σ — always one rail barrier apart.
    let mut scratch: Vec<Vec<f32>> = vec![vec![0.0f32; s]; m];
    let hand_off = SharedBufs::new(&mut scratch);
    let shared = SharedBufs::new(bufs);
    let rail_barrier: Vec<Barrier> = (0..g).map(|_| Barrier::new(hosts)).collect();
    let off = |sigma: usize| sigma * s / segs;
    fan_out(m, |rank| {
        let (h, l) = (rank / g, rank % g);
        // Wave t: host h folds segment t-h of its chunks (when in
        // range), so the chain pipelines — host h works on segment σ
        // while host h-1 is already on σ+1. Every rank hits the rail
        // barrier every wave, in or out of range: deadlock-free.
        for wave in 0..hosts + segs - 1 {
            if wave >= h && wave - h < segs {
                let (lo, hi) = (off(wave - h), off(wave - h + 1));
                // all chunks k ≡ l (mod g) — one fold thread per chunk
                // per host, H chunks per thread
                let mut k = l;
                while k < m {
                    // receive the prefix over hosts 0..h (inter tier;
                    // host 0 starts the serial reference's 0.0 init)
                    let mut acc: Vec<f32> = if h == 0 {
                        vec![0.0f32; hi - lo]
                    } else {
                        timed(tm_inter, false, || unsafe {
                            hand_off.region(k, lo, hi).to_vec()
                        })
                    };
                    // add this host's g contributions in rank order
                    // (reads of chunk-k regions only; the single write
                    // below goes to a different chunk on every other
                    // concurrent thread, so slices never alias)
                    timed(tm_intra, false, || unsafe {
                        for j in 0..g {
                            let src = shared.region(h * g + j, k * s + lo, k * s + hi);
                            for (a, &x) in acc.iter_mut().zip(src) {
                                *a += x;
                            }
                        }
                    });
                    if h == hosts - 1 {
                        // chain complete: scale once (the serial
                        // reference's epilogue) and deliver to the owner
                        timed(tm_intra, false, || unsafe {
                            for a in acc.iter_mut() {
                                *a *= scale;
                            }
                            shared
                                .region_mut(k, k * s + lo, k * s + hi)
                                .copy_from_slice(&acc);
                        });
                    } else {
                        // forward the partial to the next host
                        timed(tm_inter, false, || unsafe {
                            hand_off.region_mut(k, lo, hi).copy_from_slice(&acc);
                        });
                    }
                    k += g;
                }
            }
            timed(tm_inter, true, || rail_barrier[l].wait());
        }
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::util::Rng;

    fn topo(h: usize, g: usize, s: usize) -> Topology {
        Topology { hosts: h, gpus_per_host: g, segments: s }
    }

    /// Buffers with magnitudes spread over many exponents, so any change
    /// in f32 summation order actually changes the bits.
    fn wild_bufs(m: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| {
                (0..len)
                    .map(|_| rng.normal_f32() * 10f32.powi(rng.below(7) as i32 - 3))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn hier_all_gather_replicates_all_shards() {
        for (h, g) in [(2, 1), (2, 2), (2, 4), (4, 1), (4, 2), (2, 3)] {
            let m = h * g;
            for s in [1usize, 5, 8] {
                for segs in [1usize, 2, 4] {
                    let mut bufs: Vec<Vec<f32>> = (0..m)
                        .map(|k| {
                            let mut b = vec![0.0f32; m * s];
                            for (i, x) in b[k * s..(k + 1) * s].iter_mut().enumerate() {
                                *x = (k * 100 + i) as f32;
                            }
                            b
                        })
                        .collect();
                    hier_all_gather(&mut bufs, s, topo(h, g, segs), None, None).unwrap();
                    for buf in &bufs {
                        for k in 0..m {
                            for i in 0..s {
                                assert_eq!(
                                    buf[k * s + i],
                                    (k * 100 + i) as f32,
                                    "h={h} g={g} s={s} segs={segs}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn hier_reduce_scatter_bitwise_matches_serial() {
        for (h, g) in [(2, 1), (2, 2), (2, 4), (4, 2), (2, 3)] {
            let m = h * g;
            for s in [1usize, 7, 16] {
                for segs in [1usize, 2, 4] {
                    let mut a = wild_bufs(m, m * s, 11);
                    let mut b = a.clone();
                    comm::reduce_scatter(&mut a, s, 1.0 / m as f32).unwrap();
                    hier_reduce_scatter(&mut b, s, 1.0 / m as f32, topo(h, g, segs), None, None)
                        .unwrap();
                    for (x, y) in a.iter().flatten().zip(b.iter().flatten()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "h={h} g={g} s={s} segs={segs}");
                    }
                }
            }
        }
    }

    #[test]
    fn segment_count_never_changes_bits() {
        let (h, g, s) = (2, 4, 13);
        let m = h * g;
        let base = wild_bufs(m, m * s, 23);
        let mut want_ag = base.clone();
        hier_all_gather(&mut want_ag, s, topo(h, g, 1), None, None).unwrap();
        let mut want_rs = base.clone();
        hier_reduce_scatter(&mut want_rs, s, 0.125, topo(h, g, 1), None, None).unwrap();
        // segment counts beyond the chunk size produce empty tail
        // segments and still agree
        for segs in [2usize, 4, 32] {
            let mut ag = base.clone();
            hier_all_gather(&mut ag, s, topo(h, g, segs), None, None).unwrap();
            let mut rs = base.clone();
            hier_reduce_scatter(&mut rs, s, 0.125, topo(h, g, segs), None, None).unwrap();
            for (x, y) in want_ag.iter().flatten().zip(ag.iter().flatten()) {
                assert_eq!(x.to_bits(), y.to_bits(), "AG segs={segs}");
            }
            for (x, y) in want_rs.iter().flatten().zip(rs.iter().flatten()) {
                assert_eq!(x.to_bits(), y.to_bits(), "RS segs={segs}");
            }
        }
    }

    #[test]
    fn rejects_mismatched_topology_and_sizes() {
        let mut bufs = vec![vec![0.0f32; 8]; 4];
        // 4 ranks on a 2x4 topology
        assert!(hier_all_gather(&mut bufs, 2, topo(2, 4, 1), None, None).is_err());
        assert!(hier_reduce_scatter(&mut bufs, 2, 1.0, topo(2, 4, 1), None, None).is_err());
        // flat topology is not hierarchical
        assert!(hier_all_gather(&mut bufs, 2, topo(1, 4, 1), None, None).is_err());
        // short buffers
        let mut small = vec![vec![0.0f32; 2]; 4];
        assert!(hier_all_gather(&mut small, 2, topo(2, 2, 1), None, None).is_err());
        assert!(hier_reduce_scatter(&mut small, 2, 1.0, topo(2, 2, 1), None, None).is_err());
    }
}
