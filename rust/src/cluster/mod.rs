//! SPMD cluster runtime: the execution layer under every collective.
//!
//! The seed executed every simulated rank serially on one thread — the
//! `comm::*` functions are plain loops over all ranks' buffers, so nothing
//! about overlap, contention, or parallel speedup was actually exercised
//! and wall-clock grew linearly with mesh size. This module turns the
//! simulated cluster into a real one:
//!
//! * [`CollectiveLaunch`] — the one typed descriptor every collective is
//!   expressed as (op kind, group, element count, wire precision,
//!   topology, hierarchy threshold, sync/async mode, bucket/step/phase
//!   identity). The whole launch pipeline — precision codec → tier
//!   routing → transport → trace span → obs heartbeat → wire
//!   accounting — is driven by this type; see [`launch`].
//! * [`Communicator`] — the backend-neutral collective interface: a core
//!   [`Communicator::launch`] / [`Communicator::launch_async`] pair over
//!   descriptors, codec-free legacy shims (`all_gather`,
//!   `reduce_scatter`, …) built on that pair, and thread-safe
//!   [`CommStats`](crate::comm::CommStats) recording. The FSDP engine,
//!   DBuffer, DTensor redistribution, and both trainers all go through
//!   this trait.
//! * [`SerialComm`] — wraps the original loop-based collectives (the
//!   reference semantics; also the fastest choice for tiny buffers).
//! * [`ThreadedComm`] — each rank participates from its own OS thread;
//!   collectives are rendezvous operations over shared buffers, phased by
//!   `std::sync::Barrier` so disjoint regions are exchanged without locks.
//!   Every algorithm preserves the serial backend's exact floating-point
//!   reduction order, so results are **bit-identical** across backends.
//! * [`CommBuilder`] — the one constructor for either backend, with
//!   topology, tracer, observer, and hierarchy threshold as optional
//!   setters (replaces the deprecated `make_comm*` family).
//! * [`Cluster::run_spmd`] — run a per-rank closure on every rank
//!   concurrently (the compute fan-out the trainers use), with per-rank
//!   local stats merged in rank order at the join barrier.
//!
//! Built on `std::thread` + `Barrier` only — no new dependencies.

mod hierarchy;
pub mod launch;
mod serial;
mod threaded;

use std::cell::RefCell;
use std::sync::{Arc, Barrier};

use anyhow::Result;

use crate::comm::{CommRecord, CommStats, Topology};
use crate::obs::Observer;
use crate::trace::Tracer;

pub use launch::{
    CollectiveLaunch, LaunchMode, LaunchOp, LaunchPhase, LaunchTier, DEFAULT_HIER_THRESHOLD,
};
pub use serial::SerialComm;
pub use threaded::{set_arrival_stagger, ThreadedComm};

/// Deprecated name of the serial-fallback / two-level eligibility
/// threshold, which now lives in [`launch`] as the single source of
/// truth for runtime dispatch, static analysis, and config overrides.
#[deprecated(note = "renamed to DEFAULT_HIER_THRESHOLD (cluster::launch)")]
pub const DEFAULT_MIN_PARALLEL_ELEMS: usize = DEFAULT_HIER_THRESHOLD;

/// A waitable in-flight collective. Returned by the nonblocking
/// `*_async` methods of [`Communicator`]: the operation owns its buffers
/// for the duration of the exchange and hands them back from
/// [`PendingOp::wait`]. Two completion models, one handle:
///
/// * **eager** (serial backend) — the collective already ran inline;
///   `wait` is free. Exposed-communication accounting therefore charges
///   the *issue* site, which is exactly where the serial backend blocks.
/// * **background** (threaded backend) — the collective runs on a
///   dedicated comm thread; `wait` joins it. Compute issued between
///   `*_async` and `wait` overlaps with the exchange.
///
/// Both paths execute the same algorithm on the same data, so results
/// are bit-identical regardless of which side of the handle they ran on.
pub struct PendingOp {
    inner: PendingInner,
}

enum PendingInner {
    /// Completed eagerly at issue time (serial backend).
    Done(Result<Vec<Vec<f32>>>),
    /// Running on a background comm thread (threaded backend).
    Thread(std::thread::JoinHandle<Result<Vec<Vec<f32>>>>),
}

impl PendingOp {
    /// Wrap an already-completed result (eager backends).
    pub fn done(result: Result<Vec<Vec<f32>>>) -> PendingOp {
        PendingOp { inner: PendingInner::Done(result) }
    }

    /// Run `f` on a background comm thread; `wait` joins it.
    pub fn spawn<F>(f: F) -> PendingOp
    where
        F: FnOnce() -> Result<Vec<Vec<f32>>> + Send + 'static,
    {
        PendingOp { inner: PendingInner::Thread(std::thread::spawn(f)) }
    }

    /// Whether `wait` would return without blocking.
    pub fn is_done(&self) -> bool {
        match &self.inner {
            PendingInner::Done(_) => true,
            PendingInner::Thread(h) => h.is_finished(),
        }
    }

    /// Block until the collective finishes and take back the buffers.
    pub fn wait(self) -> Result<Vec<Vec<f32>>> {
        match self.inner {
            PendingInner::Done(r) => r,
            PendingInner::Thread(h) => {
                h.join().map_err(|_| anyhow::anyhow!("comm thread panicked"))?
            }
        }
    }
}

/// Which cluster backend executes the collectives (`--backend` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommBackend {
    /// Single-thread loop collectives (the seed behavior).
    Serial,
    /// One OS thread per rank, rendezvous collectives.
    Threaded,
}

impl CommBackend {
    pub fn name(&self) -> &'static str {
        match self {
            CommBackend::Serial => "serial",
            CommBackend::Threaded => "threaded",
        }
    }

    pub fn parse(s: &str) -> Option<CommBackend> {
        Some(match s.to_ascii_lowercase().as_str() {
            "serial" | "loop" => CommBackend::Serial,
            "threaded" | "thread" | "spmd" => CommBackend::Threaded,
            _ => return None,
        })
    }

    pub fn all() -> [CommBackend; 2] {
        [CommBackend::Serial, CommBackend::Threaded]
    }
}

/// Backend-neutral collective interface over per-rank host buffers.
///
/// Calls are "god-view": the caller hands every rank's buffer at once
/// (matching the engine's data layout, where a DBuffer owns all ranks'
/// shards). The core surface is descriptor-driven: build a
/// [`CollectiveLaunch`] with [`Communicator::describe`], refine it with
/// the builder setters, and hand it to [`Communicator::launch`]
/// (blocking) or [`Communicator::launch_async`] (waitable). The familiar
/// per-op methods remain as thin codec-free shims over that pair. The
/// backend decides how the exchange actually executes — serially in
/// place, or concurrently with one thread per rank. All implementations
/// must be bit-identical to [`SerialComm`]: reductions sum contributions
/// in rank order 0..m before scaling.
pub trait Communicator: Send + Sync {
    fn backend(&self) -> CommBackend;

    /// Start a descriptor for one collective on this backend, stamped
    /// with the backend's attached topology and hierarchy threshold so
    /// tier routing decisions match the live configuration. `elems` is
    /// the logical f32 element count per slot (shard size for
    /// AllGather/ReduceScatter, per-destination slot for AllToAll,
    /// whole-buffer length for AllReduce/Broadcast).
    fn describe(&self, op: LaunchOp, group: usize, elems: usize) -> CollectiveLaunch {
        CollectiveLaunch::new(op, group, elems)
    }

    /// Execute one collective, blocking: the single transport entry
    /// point every launch funnels through. The descriptor's
    /// [`CollectiveLaunch::comm_elems`] is the slot width actually
    /// moved; the implementation derives its serial-fallback and
    /// two-level routing, transport span, and obs heartbeats from the
    /// descriptor alone.
    fn launch(&self, l: &CollectiveLaunch, bufs: &mut [Vec<f32>]) -> Result<()>;

    /// Nonblocking launch: takes ownership of the buffers and returns a
    /// waitable handle that hands them back exchanged. The default
    /// implementation completes eagerly (correct for any backend; the
    /// threaded backend overrides it to run on a background comm
    /// thread). Must be bit-identical to [`Communicator::launch`].
    fn launch_async(&self, l: &CollectiveLaunch, mut bufs: Vec<Vec<f32>>) -> PendingOp {
        let r = self.launch(l, &mut bufs).map(|()| bufs);
        PendingOp::done(r)
    }

    // ---- codec-free legacy shims over the launch pair -----------------

    /// AllGather over equal shards: rank k owns `bufs[k][k*s..(k+1)*s]`;
    /// afterwards every rank holds every shard.
    fn all_gather(&self, bufs: &mut [Vec<f32>], s: usize) -> Result<()> {
        self.launch(&self.describe(LaunchOp::AllGather, bufs.len(), s), bufs)
    }

    /// ReduceScatter (sum then `scale`): rank k's shard region ends up
    /// holding the rank-ordered sum of everyone's shard-k region.
    fn reduce_scatter(&self, bufs: &mut [Vec<f32>], s: usize, scale: f32) -> Result<()> {
        self.launch(&self.describe(LaunchOp::ReduceScatter, bufs.len(), s).scaled(scale), bufs)
    }

    /// AllReduce (sum then `scale`) over whole equal-length buffers.
    fn all_reduce(&self, bufs: &mut [Vec<f32>], scale: f32) -> Result<()> {
        let elems = bufs.first().map_or(0, Vec::len);
        self.launch(&self.describe(LaunchOp::AllReduce, bufs.len(), elems).scaled(scale), bufs)
    }

    /// Broadcast rank `root`'s buffer to all.
    fn broadcast(&self, bufs: &mut [Vec<f32>], root: usize) -> Result<()> {
        let elems = bufs.get(root).map_or(0, Vec::len);
        self.launch(&self.describe(LaunchOp::Broadcast, bufs.len(), elems).rooted(root), bufs)
    }

    /// All-to-all over equal splits: rank k's slot j goes to rank j's
    /// slot k.
    fn all_to_all(&self, bufs: &mut [Vec<f32>], s: usize) -> Result<()> {
        self.launch(&self.describe(LaunchOp::AllToAll, bufs.len(), s), bufs)
    }

    /// Nonblocking AllGather; must be bit-identical to
    /// [`Communicator::all_gather`].
    fn all_gather_async(&self, bufs: Vec<Vec<f32>>, s: usize) -> PendingOp {
        self.launch_async(&self.describe(LaunchOp::AllGather, bufs.len(), s).asynchronous(), bufs)
    }

    /// Nonblocking ReduceScatter (sum then `scale`); same contract as
    /// [`Communicator::all_gather_async`].
    fn reduce_scatter_async(&self, bufs: Vec<Vec<f32>>, s: usize, scale: f32) -> PendingOp {
        let l = self.describe(LaunchOp::ReduceScatter, bufs.len(), s).scaled(scale).asynchronous();
        self.launch_async(&l, bufs)
    }

    /// Nonblocking All-to-all; same contract as
    /// [`Communicator::all_gather_async`]. The quantized ReduceScatter
    /// transport (see [`launch::reduce_scatter_launch`]) rides on this:
    /// encoded chunk slots are exchanged here and dequant-reduced at
    /// each destination.
    fn all_to_all_async(&self, bufs: Vec<Vec<f32>>, s: usize) -> PendingOp {
        self.launch_async(&self.describe(LaunchOp::AllToAll, bufs.len(), s).asynchronous(), bufs)
    }

    /// Record one collective in the backend's thread-safe stats.
    fn record(&self, rec: CommRecord);

    /// Snapshot of the accumulated stats.
    fn stats(&self) -> CommStats;

    /// Total simulated seconds so far — cheap (no record-history clone),
    /// for per-step accounting on hot paths.
    fn sim_time(&self) -> f64;

    /// Cumulative measured wire bytes as (payload, scale, pad) — cheap
    /// (no record-history clone), for per-step accounting on hot paths.
    fn wire_totals(&self) -> (u64, u64, u64);

    fn reset_stats(&self);
}

/// The one constructor for collective backends: pick a [`CommBackend`],
/// optionally attach a cluster topology, a trace sink, a health
/// observer, and a hierarchy threshold, then [`CommBuilder::build`].
///
/// * A **tracer** makes both backends emit a transport span on the
///   `fabric` timeline for every collective in every code path —
///   blocking, eager-async, and background comm thread — so serial and
///   threaded runs record the same span set.
/// * A hierarchical **topology** (`hosts > 1`) makes the threaded
///   backend dispatch AllGather/ReduceScatter on groups that exactly
///   fill it to the two-level pipelined algorithms — still bit-identical
///   to the flat path — and makes both backends tag their transport
///   spans with the `tier` the bytes predominantly crossed.
/// * An **observer** publishes per-rank heartbeats into the health
///   board and flight rings; a disarmed observer adds exactly one branch
///   per collective.
/// * The **hier_threshold** overrides [`DEFAULT_HIER_THRESHOLD`] for the
///   threaded backend's serial-fallback / two-level eligibility checks
///   (the serial backend executes every launch serially regardless).
///
/// ```
/// use vescale_fsdp::cluster::{CommBackend, CommBuilder};
/// use vescale_fsdp::comm::Topology;
///
/// let comm = CommBuilder::new(CommBackend::Threaded)
///     .topology(Topology::parse("2x4:2").unwrap())
///     .build();
/// assert_eq!(comm.backend(), CommBackend::Threaded);
/// ```
#[derive(Clone)]
pub struct CommBuilder {
    backend: CommBackend,
    topology: Topology,
    tracer: Tracer,
    obs: Observer,
    hier_threshold: usize,
}

impl CommBuilder {
    /// A builder with flat topology, no tracing, no monitoring, and the
    /// default hierarchy threshold — `build` on this is byte-for-byte
    /// the legacy untraced communicator.
    pub fn new(backend: CommBackend) -> CommBuilder {
        CommBuilder {
            backend,
            topology: Topology::flat(),
            tracer: Tracer::off(),
            obs: Observer::off(),
            hier_threshold: DEFAULT_HIER_THRESHOLD,
        }
    }

    /// Attach a cluster topology for tier routing and span tier tags.
    pub fn topology(mut self, topology: Topology) -> CommBuilder {
        self.topology = topology;
        self
    }

    /// Attach a trace sink for transport spans.
    pub fn tracer(mut self, tracer: Tracer) -> CommBuilder {
        self.tracer = tracer;
        self
    }

    /// Attach a health-monitor handle for heartbeats and flight rings.
    pub fn observer(mut self, obs: Observer) -> CommBuilder {
        self.obs = obs;
        self
    }

    /// Override the serial-fallback / two-level eligibility threshold
    /// (total f32 elements; see [`DEFAULT_HIER_THRESHOLD`]).
    pub fn hier_threshold(mut self, elems: usize) -> CommBuilder {
        self.hier_threshold = elems;
        self
    }

    /// Construct the communicator.
    pub fn build(self) -> Arc<dyn Communicator> {
        match self.backend {
            CommBackend::Serial => {
                Arc::new(SerialComm::with_obs(self.tracer, self.topology, self.obs))
            }
            CommBackend::Threaded => Arc::new(ThreadedComm::configured(
                self.tracer,
                self.topology,
                self.obs,
                self.hier_threshold,
            )),
        }
    }
}

/// Construct the communicator for a backend selection.
#[deprecated(note = "use CommBuilder::new(backend).build()")]
pub fn make_comm(backend: CommBackend) -> Arc<dyn Communicator> {
    CommBuilder::new(backend).build()
}

/// Construct the communicator with a trace sink.
#[deprecated(note = "use CommBuilder::new(backend).tracer(tracer).build()")]
pub fn make_comm_traced(backend: CommBackend, tracer: Tracer) -> Arc<dyn Communicator> {
    CommBuilder::new(backend).tracer(tracer).build()
}

/// Construct the communicator with a trace sink and a cluster topology.
#[deprecated(note = "use CommBuilder::new(backend).tracer(tracer).topology(topology).build()")]
pub fn make_comm_topo(
    backend: CommBackend,
    tracer: Tracer,
    topology: Topology,
) -> Arc<dyn Communicator> {
    CommBuilder::new(backend).tracer(tracer).topology(topology).build()
}

/// Construct the communicator with a trace sink, a cluster topology,
/// and a health-monitor handle.
#[deprecated(
    note = "use CommBuilder::new(backend).tracer(tracer).topology(topology).observer(obs).build()"
)]
pub fn make_comm_obs(
    backend: CommBackend,
    tracer: Tracer,
    topology: Topology,
    obs: Observer,
) -> Arc<dyn Communicator> {
    CommBuilder::new(backend).tracer(tracer).topology(topology).observer(obs).build()
}

/// Per-rank context handed to [`Cluster::run_spmd`] closures: rank id,
/// world size, a rendezvous barrier, and a rank-local stats sink that is
/// merged (in rank order, deterministically) when the ranks join.
pub struct RankCtx<'a> {
    pub rank: usize,
    pub world: usize,
    barrier: Option<&'a Barrier>,
    local: RefCell<CommStats>,
}

impl RankCtx<'_> {
    /// Rendezvous with every other rank (no-op on a 1-rank cluster).
    pub fn barrier(&self) {
        if let Some(b) = self.barrier {
            b.wait();
        }
    }

    /// Record into this rank's local stats (merged at the join barrier).
    pub fn record(&self, rec: CommRecord) {
        self.local.borrow_mut().push(rec);
    }
}

/// The SPMD entry point: execute a per-rank closure on `m` concurrent
/// ranks and collect the per-rank results in rank order.
pub struct Cluster;

impl Cluster {
    /// Run `f(rank, ctx)` once per rank, each on its own OS thread
    /// (rank 0 runs on the calling thread for `m == 1`). Returns the
    /// results in rank order plus the rank-order merge of every rank's
    /// local [`CommStats`].
    pub fn run_spmd<T, F>(m: usize, f: F) -> (Vec<T>, CommStats)
    where
        T: Send,
        F: Fn(usize, &RankCtx) -> T + Sync,
    {
        assert!(m > 0, "run_spmd needs at least one rank");
        if m == 1 {
            let ctx = RankCtx {
                rank: 0,
                world: 1,
                barrier: None,
                local: RefCell::new(CommStats::default()),
            };
            let out = f(0, &ctx);
            return (vec![out], ctx.local.into_inner());
        }
        let barrier = Barrier::new(m);
        let per_rank: Vec<(T, CommStats)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..m)
                .map(|rank| {
                    let f = &f;
                    let barrier = &barrier;
                    s.spawn(move || {
                        let ctx = RankCtx {
                            rank,
                            world: m,
                            barrier: Some(barrier),
                            local: RefCell::new(CommStats::default()),
                        };
                        let out = f(rank, &ctx);
                        (out, ctx.local.into_inner())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("SPMD rank panicked"))
                .collect()
        });
        let mut outs = Vec::with_capacity(m);
        let mut stats = CommStats::default();
        for (out, local) in per_rank {
            outs.push(out);
            stats.merge(local);
        }
        (outs, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn backend_parse_roundtrip() {
        for b in CommBackend::all() {
            assert_eq!(CommBackend::parse(b.name()), Some(b));
        }
        assert_eq!(CommBackend::parse("spmd"), Some(CommBackend::Threaded));
        assert_eq!(CommBackend::parse("nope"), None);
    }

    #[test]
    fn comm_builder_selects_backend_and_threshold() {
        for b in CommBackend::all() {
            assert_eq!(CommBuilder::new(b).build().backend(), b);
        }
        // a zero threshold forces even tiny exchanges onto the
        // rendezvous path; the result must be unchanged
        let comm = CommBuilder::new(CommBackend::Threaded)
            .topology(Topology::parse("2x4:2").unwrap())
            .hier_threshold(0)
            .build();
        let mut bufs: Vec<Vec<f32>> = (0..2).map(|k| vec![(k + 1) as f32; 4]).collect();
        comm.all_gather(&mut bufs, 2).unwrap();
        assert_eq!(bufs[0], vec![1.0, 1.0, 2.0, 2.0]);
        assert_eq!(bufs[1], vec![1.0, 1.0, 2.0, 2.0]);
    }

    #[test]
    fn run_spmd_executes_every_rank_concurrently() {
        // all ranks must be alive at once to pass the barrier
        let (outs, _) = Cluster::run_spmd(4, |rank, ctx| {
            ctx.barrier();
            rank * 10
        });
        assert_eq!(outs, vec![0, 10, 20, 30]);
    }

    #[test]
    fn run_spmd_single_rank_inline() {
        let (outs, _) = Cluster::run_spmd(1, |rank, ctx| {
            ctx.barrier(); // no-op
            rank + 7
        });
        assert_eq!(outs, vec![7]);
    }

    #[test]
    fn rank_local_stats_merge_in_rank_order() {
        let (_, stats) = Cluster::run_spmd(4, |rank, ctx| {
            ctx.record(CommRecord::dense("all_gather", rank as u64, 4, 0.0));
        });
        let bytes: Vec<u64> = stats.records.iter().map(|r| r.bytes_per_rank).collect();
        assert_eq!(bytes, vec![0, 1, 2, 3]);
    }

    #[test]
    fn pending_op_eager_and_background_agree() {
        let bufs = vec![vec![1.0f32, 2.0], vec![3.0f32, 4.0]];
        let eager = PendingOp::done(Ok(bufs.clone()));
        assert!(eager.is_done());
        assert_eq!(eager.wait().unwrap(), bufs);
        let moved = bufs.clone();
        let bg = PendingOp::spawn(move || Ok(moved));
        assert_eq!(bg.wait().unwrap(), bufs);
    }

    #[test]
    fn async_default_matches_sync_collective() {
        // the trait's default async methods are the eager sync algorithms
        let comm = SerialComm::new();
        let (m, s) = (4usize, 3usize);
        let mk = || -> Vec<Vec<f32>> {
            (0..m)
                .map(|k| {
                    let mut b = vec![0.0f32; m * s];
                    for (i, x) in b[k * s..(k + 1) * s].iter_mut().enumerate() {
                        *x = (k * 10 + i) as f32;
                    }
                    b
                })
                .collect()
        };
        let mut sync_bufs = mk();
        comm.all_gather(&mut sync_bufs, s).unwrap();
        let async_bufs = comm.all_gather_async(mk(), s).wait().unwrap();
        assert_eq!(sync_bufs, async_bufs);
        let mut sync_rs = mk();
        comm.reduce_scatter(&mut sync_rs, s, 0.25).unwrap();
        let async_rs = comm.reduce_scatter_async(mk(), s, 0.25).wait().unwrap();
        assert_eq!(sync_rs, async_rs);
        let mut sync_a2a = mk();
        comm.all_to_all(&mut sync_a2a, s).unwrap();
        let async_a2a = comm.all_to_all_async(mk(), s).wait().unwrap();
        assert_eq!(sync_a2a, async_a2a);
    }

    #[test]
    fn run_spmd_ranks_share_state_via_sync() {
        let counter = AtomicUsize::new(0);
        let (_, _) = Cluster::run_spmd(8, |_, ctx| {
            counter.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
            // after the barrier every rank must observe all increments
            assert_eq!(counter.load(Ordering::SeqCst), 8);
        });
    }
}
