//! SPMD cluster runtime: the execution layer under every collective.
//!
//! The seed executed every simulated rank serially on one thread — the
//! `comm::*` functions are plain loops over all ranks' buffers, so nothing
//! about overlap, contention, or parallel speedup was actually exercised
//! and wall-clock grew linearly with mesh size. This module turns the
//! simulated cluster into a real one:
//!
//! * [`Communicator`] — the backend-neutral collective interface
//!   (AllGather / ReduceScatter / AllReduce / Broadcast / All2All) plus
//!   thread-safe [`CommStats`](crate::comm::CommStats) recording. The
//!   FSDP engine, DBuffer, DTensor redistribution, and both trainers all
//!   go through this trait.
//! * [`SerialComm`] — wraps the original loop-based collectives (the
//!   reference semantics; also the fastest choice for tiny buffers).
//! * [`ThreadedComm`] — each rank participates from its own OS thread;
//!   collectives are rendezvous operations over shared buffers, phased by
//!   `std::sync::Barrier` so disjoint regions are exchanged without locks.
//!   Every algorithm preserves the serial backend's exact floating-point
//!   reduction order, so results are **bit-identical** across backends.
//! * [`Cluster::run_spmd`] — run a per-rank closure on every rank
//!   concurrently (the compute fan-out the trainers use), with per-rank
//!   local stats merged in rank order at the join barrier.
//!
//! Built on `std::thread` + `Barrier` only — no new dependencies.

mod hierarchy;
mod serial;
mod threaded;

use std::cell::RefCell;
use std::sync::{Arc, Barrier};

use anyhow::Result;

use crate::comm::{CommRecord, CommStats};

pub use serial::SerialComm;
pub use threaded::{set_arrival_stagger, ThreadedComm, DEFAULT_MIN_PARALLEL_ELEMS};

/// A waitable in-flight collective. Returned by the nonblocking
/// `*_async` methods of [`Communicator`]: the operation owns its buffers
/// for the duration of the exchange and hands them back from
/// [`PendingOp::wait`]. Two completion models, one handle:
///
/// * **eager** (serial backend) — the collective already ran inline;
///   `wait` is free. Exposed-communication accounting therefore charges
///   the *issue* site, which is exactly where the serial backend blocks.
/// * **background** (threaded backend) — the collective runs on a
///   dedicated comm thread; `wait` joins it. Compute issued between
///   `*_async` and `wait` overlaps with the exchange.
///
/// Both paths execute the same algorithm on the same data, so results
/// are bit-identical regardless of which side of the handle they ran on.
pub struct PendingOp {
    inner: PendingInner,
}

enum PendingInner {
    /// Completed eagerly at issue time (serial backend).
    Done(Result<Vec<Vec<f32>>>),
    /// Running on a background comm thread (threaded backend).
    Thread(std::thread::JoinHandle<Result<Vec<Vec<f32>>>>),
}

impl PendingOp {
    /// Wrap an already-completed result (eager backends).
    pub fn done(result: Result<Vec<Vec<f32>>>) -> PendingOp {
        PendingOp { inner: PendingInner::Done(result) }
    }

    /// Run `f` on a background comm thread; `wait` joins it.
    pub fn spawn<F>(f: F) -> PendingOp
    where
        F: FnOnce() -> Result<Vec<Vec<f32>>> + Send + 'static,
    {
        PendingOp { inner: PendingInner::Thread(std::thread::spawn(f)) }
    }

    /// Whether `wait` would return without blocking.
    pub fn is_done(&self) -> bool {
        match &self.inner {
            PendingInner::Done(_) => true,
            PendingInner::Thread(h) => h.is_finished(),
        }
    }

    /// Block until the collective finishes and take back the buffers.
    pub fn wait(self) -> Result<Vec<Vec<f32>>> {
        match self.inner {
            PendingInner::Done(r) => r,
            PendingInner::Thread(h) => {
                h.join().map_err(|_| anyhow::anyhow!("comm thread panicked"))?
            }
        }
    }
}

/// Which cluster backend executes the collectives (`--backend` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommBackend {
    /// Single-thread loop collectives (the seed behavior).
    Serial,
    /// One OS thread per rank, rendezvous collectives.
    Threaded,
}

impl CommBackend {
    pub fn name(&self) -> &'static str {
        match self {
            CommBackend::Serial => "serial",
            CommBackend::Threaded => "threaded",
        }
    }

    pub fn parse(s: &str) -> Option<CommBackend> {
        Some(match s.to_ascii_lowercase().as_str() {
            "serial" | "loop" => CommBackend::Serial,
            "threaded" | "thread" | "spmd" => CommBackend::Threaded,
            _ => return None,
        })
    }

    pub fn all() -> [CommBackend; 2] {
        [CommBackend::Serial, CommBackend::Threaded]
    }
}

/// Backend-neutral collective interface over per-rank host buffers.
///
/// Calls are "god-view": the caller hands every rank's buffer at once
/// (matching the engine's data layout, where a DBuffer owns all ranks'
/// shards). The backend decides how the exchange actually executes —
/// serially in place, or concurrently with one thread per rank. All
/// implementations must be bit-identical to [`SerialComm`]: reductions
/// sum contributions in rank order 0..m before scaling.
pub trait Communicator: Send + Sync {
    fn backend(&self) -> CommBackend;

    /// AllGather over equal shards: rank k owns `bufs[k][k*s..(k+1)*s]`;
    /// afterwards every rank holds every shard.
    fn all_gather(&self, bufs: &mut [Vec<f32>], s: usize) -> Result<()>;

    /// ReduceScatter (sum then `scale`): rank k's shard region ends up
    /// holding the rank-ordered sum of everyone's shard-k region.
    fn reduce_scatter(&self, bufs: &mut [Vec<f32>], s: usize, scale: f32) -> Result<()>;

    /// AllReduce (sum then `scale`) over whole equal-length buffers.
    fn all_reduce(&self, bufs: &mut [Vec<f32>], scale: f32) -> Result<()>;

    /// Broadcast rank `root`'s buffer to all.
    fn broadcast(&self, bufs: &mut [Vec<f32>], root: usize) -> Result<()>;

    /// All-to-all over equal splits: rank k's slot j goes to rank j's
    /// slot k.
    fn all_to_all(&self, bufs: &mut [Vec<f32>], s: usize) -> Result<()>;

    /// Nonblocking AllGather: takes ownership of the buffers, returns a
    /// waitable handle that hands them back gathered. The default
    /// implementation completes eagerly (correct for any backend; the
    /// threaded backend overrides it to run on a background comm thread).
    /// Must be bit-identical to [`Communicator::all_gather`].
    fn all_gather_async(&self, mut bufs: Vec<Vec<f32>>, s: usize) -> PendingOp {
        let r = self.all_gather(&mut bufs, s).map(|()| bufs);
        PendingOp::done(r)
    }

    /// Nonblocking ReduceScatter (sum then `scale`); same contract as
    /// [`Communicator::all_gather_async`].
    fn reduce_scatter_async(&self, mut bufs: Vec<Vec<f32>>, s: usize, scale: f32) -> PendingOp {
        let r = self.reduce_scatter(&mut bufs, s, scale).map(|()| bufs);
        PendingOp::done(r)
    }

    /// Nonblocking All-to-all; same contract as
    /// [`Communicator::all_gather_async`]. The quantized ReduceScatter
    /// (`quant::reduce_scatter_prec`) rides on this: encoded chunk slots
    /// are exchanged here and dequant-reduced at each destination.
    fn all_to_all_async(&self, mut bufs: Vec<Vec<f32>>, s: usize) -> PendingOp {
        let r = self.all_to_all(&mut bufs, s).map(|()| bufs);
        PendingOp::done(r)
    }

    /// Record one collective in the backend's thread-safe stats.
    fn record(&self, rec: CommRecord);

    /// Snapshot of the accumulated stats.
    fn stats(&self) -> CommStats;

    /// Total simulated seconds so far — cheap (no record-history clone),
    /// for per-step accounting on hot paths.
    fn sim_time(&self) -> f64;

    /// Cumulative measured wire bytes as (payload, scale, pad) — cheap
    /// (no record-history clone), for per-step accounting on hot paths.
    fn wire_totals(&self) -> (u64, u64, u64);

    fn reset_stats(&self);
}

/// Construct the communicator for a backend selection.
pub fn make_comm(backend: CommBackend) -> Arc<dyn Communicator> {
    make_comm_traced(backend, crate::trace::Tracer::off())
}

/// Construct the communicator with a trace sink: both backends emit a
/// transport span on the `fabric` timeline for every collective they
/// execute (in every code path — blocking, eager-async, and background
/// comm thread — so serial and threaded runs record the same span set).
pub fn make_comm_traced(
    backend: CommBackend,
    tracer: crate::trace::Tracer,
) -> Arc<dyn Communicator> {
    make_comm_topo(backend, tracer, crate::comm::Topology::flat())
}

/// Construct the communicator with a trace sink *and* a cluster
/// topology. A hierarchical topology (`hosts > 1`) makes the threaded
/// backend dispatch AllGather/ReduceScatter on groups that exactly fill
/// it to the two-level pipelined algorithms of [`hierarchy`] — still
/// bit-identical to the flat path — and makes both backends tag their
/// transport spans with the `tier` the bytes predominantly crossed.
/// `Topology::flat()` is byte-for-byte the legacy behavior.
pub fn make_comm_topo(
    backend: CommBackend,
    tracer: crate::trace::Tracer,
    topology: crate::comm::Topology,
) -> Arc<dyn Communicator> {
    make_comm_obs(backend, tracer, topology, crate::obs::Observer::off())
}

/// [`make_comm_topo`] plus a health-monitor handle: every collective on
/// either backend — blocking, eager-async, or background comm thread —
/// publishes per-rank heartbeats into the observer's
/// [`crate::obs::HealthBoard`] and records into its flight rings. A
/// disarmed observer ([`crate::obs::Observer::off`]) adds exactly one
/// branch per collective, so this is byte-for-byte the
/// [`make_comm_topo`] behavior when monitoring is off.
pub fn make_comm_obs(
    backend: CommBackend,
    tracer: crate::trace::Tracer,
    topology: crate::comm::Topology,
    obs: crate::obs::Observer,
) -> Arc<dyn Communicator> {
    match backend {
        CommBackend::Serial => Arc::new(SerialComm::with_obs(tracer, topology, obs)),
        CommBackend::Threaded => Arc::new(ThreadedComm::with_obs(tracer, topology, obs)),
    }
}

/// Per-rank context handed to [`Cluster::run_spmd`] closures: rank id,
/// world size, a rendezvous barrier, and a rank-local stats sink that is
/// merged (in rank order, deterministically) when the ranks join.
pub struct RankCtx<'a> {
    pub rank: usize,
    pub world: usize,
    barrier: Option<&'a Barrier>,
    local: RefCell<CommStats>,
}

impl RankCtx<'_> {
    /// Rendezvous with every other rank (no-op on a 1-rank cluster).
    pub fn barrier(&self) {
        if let Some(b) = self.barrier {
            b.wait();
        }
    }

    /// Record into this rank's local stats (merged at the join barrier).
    pub fn record(&self, rec: CommRecord) {
        self.local.borrow_mut().push(rec);
    }
}

/// The SPMD entry point: execute a per-rank closure on `m` concurrent
/// ranks and collect the per-rank results in rank order.
pub struct Cluster;

impl Cluster {
    /// Run `f(rank, ctx)` once per rank, each on its own OS thread
    /// (rank 0 runs on the calling thread for `m == 1`). Returns the
    /// results in rank order plus the rank-order merge of every rank's
    /// local [`CommStats`].
    pub fn run_spmd<T, F>(m: usize, f: F) -> (Vec<T>, CommStats)
    where
        T: Send,
        F: Fn(usize, &RankCtx) -> T + Sync,
    {
        assert!(m > 0, "run_spmd needs at least one rank");
        if m == 1 {
            let ctx = RankCtx {
                rank: 0,
                world: 1,
                barrier: None,
                local: RefCell::new(CommStats::default()),
            };
            let out = f(0, &ctx);
            return (vec![out], ctx.local.into_inner());
        }
        let barrier = Barrier::new(m);
        let per_rank: Vec<(T, CommStats)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..m)
                .map(|rank| {
                    let f = &f;
                    let barrier = &barrier;
                    s.spawn(move || {
                        let ctx = RankCtx {
                            rank,
                            world: m,
                            barrier: Some(barrier),
                            local: RefCell::new(CommStats::default()),
                        };
                        let out = f(rank, &ctx);
                        (out, ctx.local.into_inner())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("SPMD rank panicked"))
                .collect()
        });
        let mut outs = Vec::with_capacity(m);
        let mut stats = CommStats::default();
        for (out, local) in per_rank {
            outs.push(out);
            stats.merge(local);
        }
        (outs, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn backend_parse_roundtrip() {
        for b in CommBackend::all() {
            assert_eq!(CommBackend::parse(b.name()), Some(b));
        }
        assert_eq!(CommBackend::parse("spmd"), Some(CommBackend::Threaded));
        assert_eq!(CommBackend::parse("nope"), None);
    }

    #[test]
    fn run_spmd_executes_every_rank_concurrently() {
        // all ranks must be alive at once to pass the barrier
        let (outs, _) = Cluster::run_spmd(4, |rank, ctx| {
            ctx.barrier();
            rank * 10
        });
        assert_eq!(outs, vec![0, 10, 20, 30]);
    }

    #[test]
    fn run_spmd_single_rank_inline() {
        let (outs, _) = Cluster::run_spmd(1, |rank, ctx| {
            ctx.barrier(); // no-op
            rank + 7
        });
        assert_eq!(outs, vec![7]);
    }

    #[test]
    fn rank_local_stats_merge_in_rank_order() {
        let (_, stats) = Cluster::run_spmd(4, |rank, ctx| {
            ctx.record(CommRecord::dense("all_gather", rank as u64, 4, 0.0));
        });
        let bytes: Vec<u64> = stats.records.iter().map(|r| r.bytes_per_rank).collect();
        assert_eq!(bytes, vec![0, 1, 2, 3]);
    }

    #[test]
    fn pending_op_eager_and_background_agree() {
        let bufs = vec![vec![1.0f32, 2.0], vec![3.0f32, 4.0]];
        let eager = PendingOp::done(Ok(bufs.clone()));
        assert!(eager.is_done());
        assert_eq!(eager.wait().unwrap(), bufs);
        let moved = bufs.clone();
        let bg = PendingOp::spawn(move || Ok(moved));
        assert_eq!(bg.wait().unwrap(), bufs);
    }

    #[test]
    fn async_default_matches_sync_collective() {
        // the trait's default async methods are the eager sync algorithms
        let comm = SerialComm::new();
        let (m, s) = (4usize, 3usize);
        let mk = || -> Vec<Vec<f32>> {
            (0..m)
                .map(|k| {
                    let mut b = vec![0.0f32; m * s];
                    for (i, x) in b[k * s..(k + 1) * s].iter_mut().enumerate() {
                        *x = (k * 10 + i) as f32;
                    }
                    b
                })
                .collect()
        };
        let mut sync_bufs = mk();
        comm.all_gather(&mut sync_bufs, s).unwrap();
        let async_bufs = comm.all_gather_async(mk(), s).wait().unwrap();
        assert_eq!(sync_bufs, async_bufs);
        let mut sync_rs = mk();
        comm.reduce_scatter(&mut sync_rs, s, 0.25).unwrap();
        let async_rs = comm.reduce_scatter_async(mk(), s, 0.25).wait().unwrap();
        assert_eq!(sync_rs, async_rs);
        let mut sync_a2a = mk();
        comm.all_to_all(&mut sync_a2a, s).unwrap();
        let async_a2a = comm.all_to_all_async(mk(), s).wait().unwrap();
        assert_eq!(sync_a2a, async_a2a);
    }

    #[test]
    fn run_spmd_ranks_share_state_via_sync() {
        let counter = AtomicUsize::new(0);
        let (_, _) = Cluster::run_spmd(8, |_, ctx| {
            counter.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
            // after the barrier every rank must observe all increments
            assert_eq!(counter.load(Ordering::SeqCst), 8);
        });
    }
}
