//! SerialComm: the reference backend — wraps the original single-thread
//! loop collectives from [`crate::comm`]. Defines the semantics (and the
//! exact floating-point reduction order) every other backend must match.

use anyhow::Result;

use crate::comm::{self, CommRecord, CommStats, SharedStats};

use super::{CommBackend, Communicator};

#[derive(Debug, Default)]
pub struct SerialComm {
    stats: SharedStats,
}

impl SerialComm {
    pub fn new() -> SerialComm {
        SerialComm::default()
    }
}

impl Communicator for SerialComm {
    fn backend(&self) -> CommBackend {
        CommBackend::Serial
    }

    fn all_gather(&self, bufs: &mut [Vec<f32>], s: usize) -> Result<()> {
        comm::all_gather(bufs, s)
    }

    fn reduce_scatter(&self, bufs: &mut [Vec<f32>], s: usize, scale: f32) -> Result<()> {
        comm::reduce_scatter(bufs, s, scale)
    }

    fn all_reduce(&self, bufs: &mut [Vec<f32>], scale: f32) -> Result<()> {
        comm::all_reduce(bufs, scale)
    }

    fn broadcast(&self, bufs: &mut [Vec<f32>], root: usize) -> Result<()> {
        comm::broadcast(bufs, root)
    }

    fn all_to_all(&self, bufs: &mut [Vec<f32>], s: usize) -> Result<()> {
        comm::all_to_all(bufs, s)
    }

    fn record(&self, rec: CommRecord) {
        self.stats.record(rec);
    }

    fn stats(&self) -> CommStats {
        self.stats.snapshot()
    }

    fn sim_time(&self) -> f64 {
        self.stats.total_time()
    }

    fn wire_totals(&self) -> (u64, u64, u64) {
        self.stats.wire_totals()
    }

    fn reset_stats(&self) {
        self.stats.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delegates_to_loop_collectives() {
        let c = SerialComm::new();
        let mut bufs = vec![vec![1.0f32; 8], vec![3.0f32; 8]];
        c.all_reduce(&mut bufs, 0.5).unwrap();
        for b in &bufs {
            assert!(b.iter().all(|&x| x == 2.0));
        }
        assert_eq!(c.backend(), CommBackend::Serial);
    }

    #[test]
    fn records_are_thread_safe() {
        let c = SerialComm::new();
        c.record(CommRecord::dense("all_gather", 4, 2, 0.1));
        assert_eq!(c.stats().count("all_gather"), 1);
        c.reset_stats();
        assert_eq!(c.stats().records.len(), 0);
    }
}
