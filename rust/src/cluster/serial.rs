//! SerialComm: the reference backend — wraps the original single-thread
//! loop collectives from [`crate::comm`]. Defines the semantics (and the
//! exact floating-point reduction order) every other backend must match.
//! Every collective is bracketed by a transport span on the tracer's
//! `fabric` timeline (a no-op when tracing is off).

use anyhow::Result;

use crate::comm::{self, CommRecord, CommStats, SharedStats};
use crate::trace::{Cat, Span, Tracer};

use super::{CommBackend, Communicator};

#[derive(Debug, Default)]
pub struct SerialComm {
    stats: SharedStats,
    tracer: Tracer,
}

impl SerialComm {
    pub fn new() -> SerialComm {
        SerialComm::default()
    }

    /// Construct with a trace sink for per-collective transport spans.
    pub fn with_tracer(tracer: Tracer) -> SerialComm {
        SerialComm { stats: SharedStats::default(), tracer }
    }
}

impl Communicator for SerialComm {
    fn backend(&self) -> CommBackend {
        CommBackend::Serial
    }

    fn all_gather(&self, bufs: &mut [Vec<f32>], s: usize) -> Result<()> {
        let bytes = (bufs.len() * s * 4) as u64;
        let t = self.tracer.timer();
        let r = comm::all_gather(bufs, s);
        self.tracer
            .finish_with(t, Cat::Comm, || Span::new("all_gather").fabric().bytes(bytes));
        r
    }

    fn reduce_scatter(&self, bufs: &mut [Vec<f32>], s: usize, scale: f32) -> Result<()> {
        let bytes = (bufs.len() * s * 4) as u64;
        let t = self.tracer.timer();
        let r = comm::reduce_scatter(bufs, s, scale);
        self.tracer
            .finish_with(t, Cat::Comm, || Span::new("reduce_scatter").fabric().bytes(bytes));
        r
    }

    fn all_reduce(&self, bufs: &mut [Vec<f32>], scale: f32) -> Result<()> {
        let bytes = (bufs.first().map_or(0, Vec::len) * bufs.len() * 4) as u64;
        let t = self.tracer.timer();
        let r = comm::all_reduce(bufs, scale);
        self.tracer
            .finish_with(t, Cat::Comm, || Span::new("all_reduce").fabric().bytes(bytes));
        r
    }

    fn broadcast(&self, bufs: &mut [Vec<f32>], root: usize) -> Result<()> {
        let bytes = (bufs.first().map_or(0, Vec::len) * bufs.len() * 4) as u64;
        let t = self.tracer.timer();
        let r = comm::broadcast(bufs, root);
        self.tracer
            .finish_with(t, Cat::Comm, || Span::new("broadcast").fabric().bytes(bytes));
        r
    }

    fn all_to_all(&self, bufs: &mut [Vec<f32>], s: usize) -> Result<()> {
        let bytes = (bufs.len() * s * 4) as u64;
        let t = self.tracer.timer();
        let r = comm::all_to_all(bufs, s);
        self.tracer
            .finish_with(t, Cat::Comm, || Span::new("all_to_all").fabric().bytes(bytes));
        r
    }

    fn record(&self, rec: CommRecord) {
        self.stats.record(rec);
    }

    fn stats(&self) -> CommStats {
        self.stats.snapshot()
    }

    fn sim_time(&self) -> f64 {
        self.stats.total_time()
    }

    fn wire_totals(&self) -> (u64, u64, u64) {
        self.stats.wire_totals()
    }

    fn reset_stats(&self) {
        self.stats.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceLevel;

    #[test]
    fn delegates_to_loop_collectives() {
        let c = SerialComm::new();
        let mut bufs = vec![vec![1.0f32; 8], vec![3.0f32; 8]];
        c.all_reduce(&mut bufs, 0.5).unwrap();
        for b in &bufs {
            assert!(b.iter().all(|&x| x == 2.0));
        }
        assert_eq!(c.backend(), CommBackend::Serial);
    }

    #[test]
    fn records_are_thread_safe() {
        let c = SerialComm::new();
        c.record(CommRecord::dense("all_gather", 4, 2, 0.1));
        assert_eq!(c.stats().count("all_gather"), 1);
        c.reset_stats();
        assert_eq!(c.stats().records.len(), 0);
    }

    #[test]
    fn collectives_emit_transport_spans() {
        let tracer = Tracer::new(TraceLevel::Comm, 2);
        let c = SerialComm::with_tracer(tracer.clone());
        let mut bufs = vec![vec![0.0f32; 4], vec![0.0f32; 4]];
        bufs[0][0] = 1.0;
        bufs[1][2] = 2.0;
        c.all_gather(&mut bufs, 2).unwrap();
        c.reduce_scatter(&mut bufs, 2, 0.5).unwrap();
        assert_eq!(tracer.span_count(), 2);
    }
}
