//! SerialComm: the reference backend — wraps the original single-thread
//! loop collectives from [`crate::comm`]. Defines the semantics (and the
//! exact floating-point reduction order) every other backend must match.
//! Every collective is bracketed by a transport span on the tracer's
//! `fabric` timeline (a no-op when tracing is off).

use anyhow::Result;

use crate::comm::{self, CommRecord, CommStats, SharedStats, Topology};
use crate::obs::Observer;
use crate::trace::{Cat, Span, Tracer};

use super::launch::{CollectiveLaunch, LaunchOp};
use super::{CommBackend, Communicator};

#[derive(Debug, Default)]
pub struct SerialComm {
    stats: SharedStats,
    tracer: Tracer,
    /// Cluster shape. The serial backend always runs the flat loop
    /// algorithms (it is the bit-identity oracle the hierarchical path
    /// is validated against), but under a multi-host topology its
    /// transport spans still carry the wire-tier attr so hierarchical
    /// traces validate regardless of backend.
    topology: Topology,
    /// Health monitor handle. Disarmed (the default) this costs one
    /// branch per collective; armed, every simulated rank's heartbeat is
    /// published around the loop-collective body so health artifacts
    /// have the same shape on both backends.
    obs: Observer,
}

impl SerialComm {
    pub fn new() -> SerialComm {
        SerialComm::default()
    }

    /// Construct with a trace sink for per-collective transport spans.
    pub fn with_tracer(tracer: Tracer) -> SerialComm {
        SerialComm::with_topology(tracer, Topology::flat())
    }

    /// Construct with a trace sink and a cluster topology (tier-tags
    /// transport spans when the topology is hierarchical).
    pub fn with_topology(tracer: Tracer, topology: Topology) -> SerialComm {
        SerialComm::with_obs(tracer, topology, Observer::off())
    }

    /// [`SerialComm::with_topology`] plus a health-monitor handle: every
    /// simulated rank publishes a heartbeat for the duration of each
    /// loop collective, so flight-recorder rings and board snapshots
    /// look the same as the threaded backend's (the loop body cannot
    /// stall mid-rendezvous, but a pathologically slow collective still
    /// trips the watchdog's exit-path deadline check).
    pub fn with_obs(tracer: Tracer, topology: Topology, obs: Observer) -> SerialComm {
        SerialComm { stats: SharedStats::default(), tracer, topology, obs }
    }

    /// Wire tier a `m`-rank group lands on; `None` on flat topologies.
    fn tier_label(&self, m: usize) -> Option<&'static str> {
        if !self.topology.is_hierarchical() {
            return None;
        }
        Some(if m <= self.topology.gpus_per_host { "intra" } else { "inter" })
    }

    /// Bracket one loop collective with a (tier-tagged) transport span
    /// and, when the observer is armed, with per-rank heartbeats.
    fn traced(
        &self,
        name: &'static str,
        m: usize,
        bytes: u64,
        f: impl FnOnce() -> Result<()>,
    ) -> Result<()> {
        let tier = self.tier_label(m);
        let t = self.tracer.timer();
        let armed = self.obs.armed();
        if armed {
            for rank in 0..m.min(self.obs.ranks()) {
                self.obs.rank_enter(rank, name);
            }
        }
        let r = f();
        if armed {
            for rank in 0..m.min(self.obs.ranks()) {
                self.obs.rank_exit(rank);
            }
        }
        self.tracer.finish_with(t, Cat::Comm, || {
            let mut span = Span::new(name).fabric().bytes(bytes);
            if let Some(tier) = tier {
                span = span.attr("tier", tier);
            }
            span
        });
        r
    }
}

impl Communicator for SerialComm {
    fn backend(&self) -> CommBackend {
        CommBackend::Serial
    }

    fn describe(&self, op: LaunchOp, group: usize, elems: usize) -> CollectiveLaunch {
        CollectiveLaunch::new(op, group, elems).on_topology(self.topology)
    }

    /// The blocking transport stage: every launch runs the flat loop
    /// algorithm (the serial backend ignores tier routing — it *is* the
    /// bit-identity oracle), bracketed by one tier-tagged transport span
    /// and, when armed, per-rank heartbeats. Ring-style ops account
    /// `m·slot` wire bytes; whole-buffer ops account `m·len`.
    fn launch(&self, l: &CollectiveLaunch, bufs: &mut [Vec<f32>]) -> Result<()> {
        let m = bufs.len();
        let s = l.comm_elems();
        let bytes = match l.op {
            LaunchOp::AllGather | LaunchOp::ReduceScatter | LaunchOp::AllToAll => {
                (m * s * 4) as u64
            }
            LaunchOp::AllReduce | LaunchOp::Broadcast => {
                (bufs.first().map_or(0, Vec::len) * m * 4) as u64
            }
        };
        self.traced(l.op.name(), m, bytes, || match l.op {
            LaunchOp::AllGather => comm::all_gather(bufs, s),
            LaunchOp::ReduceScatter => comm::reduce_scatter(bufs, s, l.scale),
            LaunchOp::AllReduce => comm::all_reduce(bufs, l.scale),
            LaunchOp::Broadcast => comm::broadcast(bufs, l.root),
            LaunchOp::AllToAll => comm::all_to_all(bufs, s),
        })
    }

    fn record(&self, rec: CommRecord) {
        self.stats.record(rec);
    }

    fn stats(&self) -> CommStats {
        self.stats.snapshot()
    }

    fn sim_time(&self) -> f64 {
        self.stats.total_time()
    }

    fn wire_totals(&self) -> (u64, u64, u64) {
        self.stats.wire_totals()
    }

    fn reset_stats(&self) {
        self.stats.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceLevel;

    #[test]
    fn delegates_to_loop_collectives() {
        let c = SerialComm::new();
        let mut bufs = vec![vec![1.0f32; 8], vec![3.0f32; 8]];
        c.all_reduce(&mut bufs, 0.5).unwrap();
        for b in &bufs {
            assert!(b.iter().all(|&x| x == 2.0));
        }
        assert_eq!(c.backend(), CommBackend::Serial);
    }

    #[test]
    fn records_are_thread_safe() {
        let c = SerialComm::new();
        c.record(CommRecord::dense("all_gather", 4, 2, 0.1));
        assert_eq!(c.stats().count("all_gather"), 1);
        c.reset_stats();
        assert_eq!(c.stats().records.len(), 0);
    }

    #[test]
    fn hierarchical_topology_tier_tags_spans() {
        use crate::util::json::Json;
        let tracer = Tracer::new(TraceLevel::Comm, 8);
        let c = SerialComm::with_topology(tracer.clone(), Topology::parse("2x4").unwrap());
        // 8 ranks span both hosts -> inter tier
        let mut bufs: Vec<Vec<f32>> = (0..8).map(|k| vec![k as f32; 16]).collect();
        c.all_gather(&mut bufs, 2).unwrap();
        // a 2-rank group fits inside one host -> intra tier
        let mut pair: Vec<Vec<f32>> = (0..2).map(|k| vec![k as f32; 4]).collect();
        c.all_reduce(&mut pair, 0.5).unwrap();
        let json = tracer.export(&CommStats::default());
        let events = json.get("traceEvents").unwrap().as_arr().unwrap();
        let tiers: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .filter_map(|e| {
                e.get("args").and_then(|a| a.get("tier")).and_then(Json::as_str)
            })
            .collect();
        assert_eq!(tiers, vec!["inter", "intra"]);
    }

    #[test]
    fn collectives_emit_transport_spans() {
        let tracer = Tracer::new(TraceLevel::Comm, 2);
        let c = SerialComm::with_tracer(tracer.clone());
        let mut bufs = vec![vec![0.0f32; 4], vec![0.0f32; 4]];
        bufs[0][0] = 1.0;
        bufs[1][2] = 2.0;
        c.all_gather(&mut bufs, 2).unwrap();
        c.reduce_scatter(&mut bufs, 2, 0.5).unwrap();
        assert_eq!(tracer.span_count(), 2);
    }
}
