//! Caching-allocator simulator (the CUDACachingAllocator substitute).
//!
//! The paper's 16–30% memory savings are allocator *mechanics*, not
//! arithmetic: non-deterministic `record_stream` frees block reuse and
//! inflate peak reserved memory (DeepSpeed/FSDP1, ~+20%); per-parameter
//! eager allocation fragments the pool (FSDP2, ~+12% vs batched); and
//! under memory pressure the allocator issues device frees (cudaFree)
//! that synchronize the device and stall training. This module implements
//! those mechanics faithfully over simulated segments so the deltas
//! *emerge* in the Fig-8 memory rows rather than being asserted.
//!
//! Model (PyTorch-accurate where it matters):
//! * reserved memory grows in segments (2 MiB small pool / exact-size
//!   large pool, 2 MiB rounding);
//! * blocks are split from segments, best-fit, and coalesced on free;
//! * `FreePolicy::RecordStream` defers a block's reusability to the next
//!   stream sync (end of iteration) — the PyTorch `record_stream` hazard;
//! * `FreePolicy::Deterministic` (veScale DBuffer) makes frees reusable
//!   immediately (explicit stream-dependency management);
//! * exceeding the device limit triggers `empty_cache` device frees, each
//!   recorded (they stall the device for ~ms).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::analysis::diag::{codes, rt};

/// Shared handle to one device's allocator: the FSDP engine and every
/// DBuffer it owns account their storage against the same simulated
/// device (rank 0's HBM view), so peak reserved/allocated bytes are
/// *measured* across the whole step schedule rather than asserted.
pub type SharedAllocator = Arc<Mutex<CachingAllocator>>;

/// Construct a shared allocator handle.
pub fn shared_allocator(policy: FreePolicy, limit: u64) -> SharedAllocator {
    Arc::new(Mutex::new(CachingAllocator::new(policy, limit)))
}

const SMALL_ALLOC: u64 = 1 << 20; // <1 MiB goes to the small pool
const SMALL_SEGMENT: u64 = 2 << 20; // 2 MiB small-pool segments
const LARGE_ROUND: u64 = 2 << 20; // large allocs round to 2 MiB
const MIN_SPLIT: u64 = 512; // don't leave slivers below this

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FreePolicy {
    /// Frees become reusable immediately (explicit stream deps — veScale).
    Deterministic,
    /// Frees become reusable only after the next `sync()` (record_stream).
    RecordStream,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(u64);

#[derive(Debug, Clone)]
struct Block {
    segment: u64,
    offset: u64,
    size: u64,
}

#[derive(Debug, Clone)]
struct Segment {
    size: u64,
    /// Free intervals (offset -> len), coalesced.
    free: BTreeMap<u64, u64>,
}

/// Simulated caching allocator for one device.
#[derive(Debug)]
pub struct CachingAllocator {
    policy: FreePolicy,
    limit: u64,
    segments: Vec<Segment>,
    live: BTreeMap<BlockId, Block>,
    /// Blocks freed but not yet reusable (record_stream hazard).
    pending: Vec<Block>,
    next_id: u64,
    pub allocated: u64,
    pub reserved: u64,
    pub peak_allocated: u64,
    pub peak_reserved: u64,
    /// cudaFree-style device frees issued under pressure (each stalls).
    pub device_frees: u64,
    /// cudaMalloc calls (segment creations).
    pub segment_allocs: u64,
}

impl CachingAllocator {
    pub fn new(policy: FreePolicy, limit: u64) -> CachingAllocator {
        CachingAllocator {
            policy,
            limit,
            segments: Vec::new(),
            live: BTreeMap::new(),
            pending: Vec::new(),
            next_id: 0,
            allocated: 0,
            reserved: 0,
            peak_allocated: 0,
            peak_reserved: 0,
            device_frees: 0,
            segment_allocs: 0,
        }
    }

    fn rounded(size: u64) -> u64 {
        if size < SMALL_ALLOC {
            size.next_multiple_of(MIN_SPLIT)
        } else {
            size.next_multiple_of(LARGE_ROUND)
        }
    }

    /// Try to carve `size` out of an existing segment (best fit).
    fn carve(&mut self, size: u64) -> Option<Block> {
        let mut best: Option<(usize, u64, u64)> = None; // (seg, off, len)
        for (si, seg) in self.segments.iter().enumerate() {
            for (&off, &len) in &seg.free {
                if len >= size && best.map(|(_, _, bl)| len < bl).unwrap_or(true) {
                    best = Some((si, off, len));
                }
            }
        }
        let (si, off, len) = best?;
        let seg = &mut self.segments[si];
        seg.free.remove(&off);
        if len - size >= MIN_SPLIT {
            seg.free.insert(off + size, len - size);
        }
        Some(Block { segment: si as u64, offset: off, size })
    }

    fn new_segment(&mut self, size: u64) -> Result<usize> {
        let seg_size = if size < SMALL_ALLOC { SMALL_SEGMENT } else { size };
        if self.reserved + seg_size > self.limit {
            // pressure: empty cache (device frees), then retry
            self.empty_cache();
            if self.reserved + seg_size > self.limit {
                bail!(
                    "{}",
                    rt(
                        codes::PEAK_OVER_LIMIT,
                        format_args!(
                            "OOM: reserved {} + segment {} exceeds limit {}",
                            self.reserved, seg_size, self.limit
                        )
                    )
                );
            }
        }
        let mut free = BTreeMap::new();
        free.insert(0, seg_size);
        self.segments.push(Segment { size: seg_size, free });
        self.reserved += seg_size;
        self.peak_reserved = self.peak_reserved.max(self.reserved);
        self.segment_allocs += 1;
        Ok(self.segments.len() - 1)
    }

    pub fn alloc(&mut self, size: u64) -> Result<BlockId> {
        let size = Self::rounded(size.max(1));
        let block = match self.carve(size) {
            Some(b) => b,
            None => {
                let si = self.new_segment(size)?;
                let seg = &mut self.segments[si];
                let (&off, &len) = seg.free.iter().next().expect("fresh segment");
                seg.free.remove(&off);
                if len - size >= MIN_SPLIT {
                    seg.free.insert(off + size, len - size);
                }
                Block { segment: si as u64, offset: off, size }
            }
        };
        self.allocated += block.size;
        self.peak_allocated = self.peak_allocated.max(self.allocated);
        let id = BlockId(self.next_id);
        self.next_id += 1;
        self.live.insert(id, block);
        Ok(id)
    }

    /// Batched allocation (DBuffer policy): one segment sized for the sum,
    /// carved sequentially — no fragmentation between the blocks.
    pub fn alloc_batch(&mut self, sizes: &[u64]) -> Result<Vec<BlockId>> {
        let total: u64 = sizes.iter().map(|&s| Self::rounded(s.max(1))).sum();
        let si = self.new_segment(total.max(LARGE_ROUND))?;
        let mut ids = Vec::with_capacity(sizes.len());
        let mut off = 0u64;
        for &s in sizes {
            let size = Self::rounded(s.max(1));
            let id = BlockId(self.next_id);
            self.next_id += 1;
            self.live.insert(id, Block { segment: si as u64, offset: off, size });
            off += size;
            self.allocated += size;
            ids.push(id);
        }
        // shrink the segment's free list to the remainder
        let seg = &mut self.segments[si];
        seg.free.clear();
        if seg.size > off {
            seg.free.insert(off, seg.size - off);
        }
        self.peak_allocated = self.peak_allocated.max(self.allocated);
        Ok(ids)
    }

    pub fn free(&mut self, id: BlockId) -> Result<()> {
        let block = self
            .live
            .remove(&id)
            .ok_or_else(|| anyhow::anyhow!("double free or unknown block"))?;
        self.allocated -= block.size;
        match self.policy {
            FreePolicy::Deterministic => self.release(block),
            FreePolicy::RecordStream => self.pending.push(block),
        }
        Ok(())
    }

    /// Return a block's bytes to its segment's free list, coalescing.
    fn release(&mut self, block: Block) {
        let seg = &mut self.segments[block.segment as usize];
        let (mut off, mut len) = (block.offset, block.size);
        // coalesce with successor
        if let Some(&nlen) = seg.free.get(&(off + len)) {
            seg.free.remove(&(off + len));
            len += nlen;
        }
        // coalesce with predecessor
        if let Some((&poff, &plen)) = seg.free.range(..off).next_back() {
            if poff + plen == off {
                seg.free.remove(&poff);
                off = poff;
                len += plen;
            }
        }
        seg.free.insert(off, len);
    }

    /// Stream sync point (end of iteration): pending record_stream frees
    /// become reusable.
    pub fn sync(&mut self) {
        let pending = std::mem::take(&mut self.pending);
        for b in pending {
            self.release(b);
        }
    }

    /// Release fully-free cached segments back to the device (cudaFree).
    pub fn empty_cache(&mut self) {
        let mut kept = Vec::new();
        for seg in self.segments.drain(..) {
            let fully_free =
                seg.free.len() == 1 && seg.free.get(&0) == Some(&seg.size);
            if fully_free {
                self.reserved -= seg.size;
                self.device_frees += 1;
                kept.push(Segment { size: 0, free: BTreeMap::new() }); // tombstone keeps indices stable
            } else {
                kept.push(seg);
            }
        }
        self.segments = kept;
    }

    /// Fragmentation ratio: reserved-but-unallocatable share.
    pub fn fragmentation(&self) -> f64 {
        if self.reserved == 0 {
            return 0.0;
        }
        1.0 - self.allocated as f64 / self.reserved as f64
    }

    pub fn live_blocks(&self) -> usize {
        self.live.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GIB: u64 = 1 << 30;

    #[test]
    fn alloc_free_reuse_deterministic() {
        let mut a = CachingAllocator::new(FreePolicy::Deterministic, GIB);
        let b1 = a.alloc(10 << 20).unwrap();
        let reserved_after_first = a.reserved;
        a.free(b1).unwrap();
        let b2 = a.alloc(10 << 20).unwrap();
        // reuse: no new segment
        assert_eq!(a.reserved, reserved_after_first);
        a.free(b2).unwrap();
        assert_eq!(a.allocated, 0);
    }

    #[test]
    fn record_stream_blocks_reuse_until_sync() {
        let mut a = CachingAllocator::new(FreePolicy::RecordStream, GIB);
        let b1 = a.alloc(10 << 20).unwrap();
        let r1 = a.reserved;
        a.free(b1).unwrap();
        let _b2 = a.alloc(10 << 20).unwrap();
        // no sync yet -> the freed block is not reusable -> reserved grew
        assert!(a.reserved > r1, "record_stream must inflate reserved");
        a.sync();
        let b3 = a.alloc(10 << 20).unwrap();
        let r3 = a.reserved;
        a.free(b3).unwrap();
        a.sync();
        let _b4 = a.alloc(10 << 20).unwrap();
        assert_eq!(a.reserved, r3); // after sync, reuse works
    }

    #[test]
    fn record_stream_peak_exceeds_deterministic() {
        // the paper's +20% mechanism: same workload, higher peak reserved
        // FSDP-like per-layer pattern: allgather layer i+1's buffer while
        // freeing layer i's — frees and allocs interleave within the
        // iteration, syncs only at iteration end.
        let run = |policy| {
            let mut a = CachingAllocator::new(policy, GIB);
            for _ in 0..8 {
                let mut prev: Option<BlockId> = None;
                for _layer in 0..4 {
                    let b = a.alloc(20 << 20).unwrap();
                    if let Some(p) = prev.take() {
                        a.free(p).unwrap();
                    }
                    prev = Some(b);
                }
                if let Some(p) = prev {
                    a.free(p).unwrap();
                }
                a.sync(); // iteration boundary
            }
            a.peak_reserved
        };
        let det = run(FreePolicy::Deterministic);
        let rs = run(FreePolicy::RecordStream);
        assert!(rs > det, "rs {rs} det {det}");
    }

    #[test]
    fn batched_alloc_reduces_fragmentation() {
        let sizes: Vec<u64> = (0..32).map(|i| (3 + i % 5) << 20).collect();
        let mut eager = CachingAllocator::new(FreePolicy::Deterministic, GIB);
        // interleave allocs with temporaries to fragment the pool
        let mut tmp = Vec::new();
        let mut ids = Vec::new();
        for &s in &sizes {
            ids.push(eager.alloc(s).unwrap());
            tmp.push(eager.alloc(5 << 20).unwrap());
        }
        for t in tmp {
            eager.free(t).unwrap();
        }
        let mut batched = CachingAllocator::new(FreePolicy::Deterministic, GIB);
        let _ids2 = batched.alloc_batch(&sizes).unwrap();
        assert!(batched.reserved <= eager.reserved);
        assert!(batched.segment_allocs < eager.segment_allocs);
    }

    #[test]
    fn pressure_triggers_device_frees() {
        let mut a = CachingAllocator::new(FreePolicy::Deterministic, 100 << 20);
        let b1 = a.alloc(60 << 20).unwrap();
        a.free(b1).unwrap();
        // 60 MiB cached; asking for 80 MiB must empty the cache first
        let _b2 = a.alloc(80 << 20).unwrap();
        assert!(a.device_frees > 0);
    }

    #[test]
    fn oom_when_truly_exhausted() {
        let mut a = CachingAllocator::new(FreePolicy::Deterministic, 10 << 20);
        let _b1 = a.alloc(8 << 20).unwrap();
        assert!(a.alloc(8 << 20).is_err());
    }

    #[test]
    fn double_free_rejected() {
        let mut a = CachingAllocator::new(FreePolicy::Deterministic, GIB);
        let b = a.alloc(1024).unwrap();
        a.free(b).unwrap();
        assert!(a.free(b).is_err());
    }

    #[test]
    fn coalescing_allows_big_realloc() {
        let mut a = CachingAllocator::new(FreePolicy::Deterministic, GIB);
        let ids = a.alloc_batch(&[10 << 20, 10 << 20, 10 << 20]).unwrap();
        let seg_count = a.segment_allocs;
        for id in ids {
            a.free(id).unwrap();
        }
        // freed neighbors coalesce -> a 30 MiB alloc fits the same segment
        let _big = a.alloc(30 << 20).unwrap();
        assert_eq!(a.segment_allocs, seg_count);
    }

    #[test]
    fn small_pool_segments() {
        let mut a = CachingAllocator::new(FreePolicy::Deterministic, GIB);
        for _ in 0..100 {
            a.alloc(100 << 10).unwrap(); // 100 KiB allocs share 2 MiB segments
        }
        assert!(a.segment_allocs < 100, "{} segments", a.segment_allocs);
    }

    #[test]
    fn peak_tracking() {
        let mut a = CachingAllocator::new(FreePolicy::Deterministic, GIB);
        let b1 = a.alloc(50 << 20).unwrap();
        let peak = a.peak_allocated;
        a.free(b1).unwrap();
        assert_eq!(a.allocated, 0);
        assert_eq!(a.peak_allocated, peak);
    }
}
