//! Host tensor: the numeric storage behind simulated devices.
//!
//! Real FSDP state (parameter shards, gradients, quantized optimizer
//! state) lives in these. Only what the coordinator needs is implemented:
//! typed flat storage, shapes, flat-range views, and a few host-side ops
//! used by optimizers and tests. Heavy compute goes through PJRT (L2).

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    Bf16, // stored as u16 bit patterns; used for comm-volume realism
    I8,
    I32,
}

impl DType {
    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::Bf16 => 2,
            DType::I8 => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::Bf16 => "bf16",
            DType::I8 => "i8",
            DType::I32 => "i32",
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    I8(Vec<i8>),
    I32(Vec<i32>),
}

impl Data {
    pub fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::Bf16(v) => v.len(),
            Data::I8(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Data::F32(_) => DType::F32,
            Data::Bf16(_) => DType::Bf16,
            Data::I8(_) => DType::I8,
            Data::I32(_) => DType::I32,
        }
    }
}

/// bf16 conversion (round-to-nearest-even on truncate is enough here).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    let rounding = 0x7FFF + ((bits >> 16) & 1);
    ((bits + rounding) >> 16) as u16
}

pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn zeros(shape: &[usize], dtype: DType) -> HostTensor {
        let n: usize = shape.iter().product();
        let data = match dtype {
            DType::F32 => Data::F32(vec![0.0; n]),
            DType::Bf16 => Data::Bf16(vec![0; n]),
            DType::I8 => Data::I8(vec![0; n]),
            DType::I32 => Data::I32(vec![0; n]),
        };
        HostTensor { shape: shape.to_vec(), data }
    }

    pub fn from_f32(shape: &[usize], v: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        HostTensor { shape: shape.to_vec(), data: Data::F32(v) }
    }

    pub fn from_i32(shape: &[usize], v: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        HostTensor { shape: shape.to_vec(), data: Data::I32(v) }
    }

    pub fn randn(shape: &[usize], rng: &mut crate::util::Rng, scale: f32) -> HostTensor {
        let n: usize = shape.iter().product();
        let v = (0..n).map(|_| rng.normal_f32() * scale).collect();
        HostTensor::from_f32(shape, v)
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn bytes(&self) -> usize {
        self.numel() * self.dtype().size()
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            _ => panic!("tensor is {:?}, not f32", self.dtype()),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            other => panic!("tensor is {:?}, not f32", other.dtype()),
        }
    }

    pub fn as_i8(&self) -> &[i8] {
        match &self.data {
            Data::I8(v) => v,
            _ => panic!("tensor is {:?}, not i8", self.dtype()),
        }
    }

    pub fn as_i8_mut(&mut self) -> &mut [i8] {
        match &mut self.data {
            Data::I8(v) => v,
            other => panic!("tensor is {:?}, not i8", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            _ => panic!("tensor is {:?}, not i32", self.dtype()),
        }
    }

    /// Reinterpret as 2-D (rows, cols). Errors unless shape is 2-D.
    pub fn dims2(&self) -> Result<(usize, usize)> {
        if self.shape.len() != 2 {
            bail!("expected 2-D tensor, got {:?}", self.shape);
        }
        Ok((self.shape[0], self.shape[1]))
    }

    /// Host matmul (f32, naive) — used by optimizer fallbacks and tests.
    pub fn matmul(&self, rhs: &HostTensor) -> Result<HostTensor> {
        let (m, k) = self.dims2()?;
        let (k2, n) = rhs.dims2()?;
        if k != k2 {
            bail!("matmul shape mismatch {:?} @ {:?}", self.shape, rhs.shape);
        }
        let a = self.as_f32();
        let b = rhs.as_f32();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let aik = a[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += aik * brow[j];
                }
            }
        }
        Ok(HostTensor::from_f32(&[m, n], out))
    }

    pub fn transpose2(&self) -> Result<HostTensor> {
        let (m, n) = self.dims2()?;
        let a = self.as_f32();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = a[i * n + j];
            }
        }
        Ok(HostTensor::from_f32(&[n, m], out))
    }

    pub fn frob_norm(&self) -> f32 {
        self.as_f32().iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn scale_inplace(&mut self, s: f32) {
        for x in self.as_f32_mut() {
            *x *= s;
        }
    }

    pub fn add_scaled(&mut self, other: &HostTensor, s: f32) {
        let o = other.as_f32().to_vec();
        let a = self.as_f32_mut();
        assert_eq!(a.len(), o.len());
        for (x, y) in a.iter_mut().zip(o) {
            *x += s * y;
        }
    }

    pub fn max_abs_diff(&self, other: &HostTensor) -> f32 {
        self.as_f32()
            .iter()
            .zip(other.as_f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zeros_and_bytes() {
        let t = HostTensor::zeros(&[4, 8], DType::F32);
        assert_eq!(t.numel(), 32);
        assert_eq!(t.bytes(), 128);
        let q = HostTensor::zeros(&[32], DType::I8);
        assert_eq!(q.bytes(), 32); // 8-bit state really is 1 byte/elem
    }

    #[test]
    fn matmul_identity() {
        let mut eye = HostTensor::zeros(&[3, 3], DType::F32);
        for i in 0..3 {
            eye.as_f32_mut()[i * 3 + i] = 1.0;
        }
        let x = HostTensor::from_f32(&[3, 3], (0..9).map(|i| i as f32).collect());
        let y = eye.matmul(&x).unwrap();
        assert_eq!(y.as_f32(), x.as_f32());
    }

    #[test]
    fn matmul_known_values() {
        let a = HostTensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::from_f32(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.as_f32(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_shape_mismatch_errors() {
        let a = HostTensor::zeros(&[2, 3], DType::F32);
        let b = HostTensor::zeros(&[2, 3], DType::F32);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(0);
        let a = HostTensor::randn(&[5, 7], &mut rng, 1.0);
        let t2 = a.transpose2().unwrap().transpose2().unwrap();
        assert_eq!(a.as_f32(), t2.as_f32());
    }

    #[test]
    fn bf16_roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = rng.normal_f32() * 10.0;
            let y = bf16_to_f32(f32_to_bf16(x));
            assert!((x - y).abs() <= x.abs() * 0.01 + 1e-30, "{x} -> {y}");
        }
    }

    #[test]
    fn bf16_exact_values() {
        for x in [0.0f32, 1.0, -2.0, 0.5] {
            assert_eq!(bf16_to_f32(f32_to_bf16(x)), x);
        }
    }

    #[test]
    fn add_scaled() {
        let mut a = HostTensor::from_f32(&[3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::from_f32(&[3], vec![10.0, 20.0, 30.0]);
        a.add_scaled(&b, 0.1);
        assert_eq!(a.as_f32(), &[2.0, 4.0, 6.0]);
    }
}
