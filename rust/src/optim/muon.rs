//! Distributed Muon (paper §6.3, Algorithm 2) over RaggedShard DTensors.
//!
//! Muon's Newton–Schulz preconditioner needs each 2-D parameter matrix
//! *whole* on some device. RaggedShard makes the gather a plain
//! `redistribute(u, RaggedShard(root))`: after redistribution only the
//! root rank holds data, so Newton–Schulz is a no-op elsewhere — clean
//! SPMD, no hand-written collectives. Root selection is load-balanced
//! round-robin (SelectRoot of Alg 2).
//!
//! The Newton–Schulz math mirrors `python/compile/kernels/newton_schulz.py`
//! (same quintic coefficients); the runtime can execute the AOT
//! `newton_schulz_{r}x{c}` artifact instead of the host matmuls.

use std::collections::HashMap;

use anyhow::Result;

use crate::cluster::Communicator;
use crate::comm::Fabric;
use crate::dtensor::DTensor;
use crate::placement::{Placement, RaggedSpec};
use crate::tensor::HostTensor;

/// Quintic Newton–Schulz coefficients (Jordan et al. 2024) — must match
/// `kernels/ref.py::NS_COEFFS`.
pub const NS_COEFFS: (f32, f32, f32) = (3.4445, -4.7750, 2.0315);
pub const NS_STEPS: usize = 5;

/// Host Newton–Schulz: orthogonalize a (r x c) matrix.
pub fn newton_schulz(g: &HostTensor, steps: usize) -> Result<HostTensor> {
    let (r, c) = g.dims2()?;
    let (a, b, cc) = NS_COEFFS;
    let transposed = r > c;
    let mut x = if transposed { g.transpose2()? } else { g.clone() };
    let norm = x.frob_norm() + 1e-7;
    x.scale_inplace(1.0 / norm);
    for _ in 0..steps {
        let xt = x.transpose2()?;
        let gram = x.matmul(&xt)?; // (min, min)
        let gram2 = gram.matmul(&gram)?;
        // a*x + (b*gram + c*gram^2) @ x
        let mut mix = gram;
        mix.scale_inplace(b);
        mix.add_scaled(&gram2, cc);
        let mut out = mix.matmul(&x)?;
        out.add_scaled(&x, a);
        x = out;
    }
    if transposed {
        x.transpose2()
    } else {
        Ok(x)
    }
}

/// Distributed Muon state: per-parameter sharded momentum.
#[derive(Debug)]
pub struct Muon {
    pub lr: f32,
    pub momentum: f32,
    pub wd: f32,
    /// Nesterov-style update (u = g + mu*m after m update), as in Muon.
    pub nesterov: bool,
    /// name -> per-rank momentum shard.
    momenta: HashMap<String, Vec<Vec<f32>>>,
    /// Round-robin root cursor (SelectRoot load balancing).
    next_root: usize,
}

impl Muon {
    pub fn new(lr: f32, momentum: f32, wd: f32) -> Muon {
        Muon {
            lr,
            momentum,
            wd,
            nesterov: true,
            momenta: HashMap::new(),
            next_root: 0,
        }
    }

    /// Alg 2 SelectRoot: balance Newton-Schulz work across ranks.
    pub fn select_root(&mut self, m: usize) -> usize {
        let r = self.next_root % m;
        self.next_root += 1;
        r
    }

    /// One Muon step for a 2-D parameter held as a RaggedShard DTensor.
    /// `param` and `grad` share the same spec; returns updated param.
    pub fn step_matrix(
        &mut self,
        name: &str,
        shape2: (usize, usize),
        param: &DTensor,
        grad: &DTensor,
        fabric: &Fabric,
        comm: &dyn Communicator,
    ) -> Result<DTensor> {
        let spec = param
            .placement
            .ragged_spec()
            .ok_or_else(|| anyhow::anyhow!("muon needs RaggedShard params"))?
            .clone();
        let m = param.num_ranks();
        let numel = param.numel();

        // ---- momentum update on the sharded state (element-wise) ----
        let mom = self
            .momenta
            .entry(name.to_string())
            .or_insert_with(|| (0..m).map(|k| vec![0.0; grad.locals[k].len()]).collect());
        let mut u_locals = Vec::with_capacity(m);
        for k in 0..m {
            let g = &grad.locals[k];
            let mk = &mut mom[k];
            let mut u = vec![0.0f32; g.len()];
            for i in 0..g.len() {
                mk[i] = self.momentum * mk[i] + g[i];
                u[i] = if self.nesterov {
                    g[i] + self.momentum * mk[i]
                } else {
                    mk[i]
                };
            }
            u_locals.push(u);
        }
        let u = DTensor {
            global_shape: param.global_shape.clone(),
            placement: Placement::RaggedShard(spec.clone()),
            locals: u_locals,
        };

        // ---- unshard to root via redistribute (Alg 2 lines 5-8) ----
        let root = self.select_root(m);
        let root_spec = RaggedSpec::on_root(numel, spec.granularity, m, root);
        let gathered = u.redistribute(Placement::RaggedShard(root_spec), comm, fabric)?;

        // ---- Newton-Schulz on the root's full tensor (lines 9-10) ----
        let (r, c) = shape2;
        let full = HostTensor::from_f32(&[r, c], gathered.locals[root].clone());
        let mut orth = newton_schulz(&full, NS_STEPS)?;
        // Muon RMS-matching scale: sqrt(max(r, c) / min(r, c)) ~ Jordan's
        // 0.2 * sqrt(max(1, r/c)) variants; use max/min^0.5 normalization.
        let scale = ((r.max(c)) as f32 / (r.min(c)) as f32).sqrt();
        orth.scale_inplace(scale);

        // ---- redistribute back (lines 11-12) ----
        let o_root = DTensor {
            global_shape: param.global_shape.clone(),
            placement: Placement::RaggedShard(RaggedSpec::on_root(
                numel,
                spec.granularity,
                m,
                root,
            )),
            locals: (0..m)
                .map(|k| if k == root { orth.as_f32().to_vec() } else { Vec::new() })
                .collect(),
        };
        let o = o_root.redistribute(Placement::RaggedShard(spec.clone()), comm, fabric)?;

        // ---- apply: w <- w - lr * (o + wd * w), sharded (line 13) ----
        let mut new_locals = Vec::with_capacity(m);
        for k in 0..m {
            let mut p = param.locals[k].clone();
            for i in 0..p.len() {
                p[i] -= self.lr * (o.locals[k][i] + self.wd * p[i]);
            }
            new_locals.push(p);
        }
        Ok(DTensor {
            global_shape: param.global_shape.clone(),
            placement: Placement::RaggedShard(spec),
            locals: new_locals,
        })
    }

    pub fn state_bytes(&self) -> u64 {
        self.momenta
            .values()
            .map(|per_rank| per_rank.iter().map(|v| v.len() as u64 * 4).sum::<u64>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SerialComm;
    use crate::util::Rng;

    fn rand_mat(r: usize, c: usize, seed: u64) -> HostTensor {
        let mut rng = Rng::new(seed);
        HostTensor::randn(&[r, c], &mut rng, 1.0)
    }

    #[test]
    fn newton_schulz_orthogonalizes() {
        let g = rand_mat(32, 64, 0);
        let o = newton_schulz(&g, NS_STEPS).unwrap();
        // rows should be near-orthonormal: O @ O^T ~ I (32x32)
        let gram = o.matmul(&o.transpose2().unwrap()).unwrap();
        let mut max_off = 0.0f32;
        let mut diag_err = 0.0f32;
        for i in 0..32 {
            for j in 0..32 {
                let v = gram.as_f32()[i * 32 + j];
                if i == j {
                    diag_err = diag_err.max((v - 1.0).abs());
                } else {
                    max_off = max_off.max(v.abs());
                }
            }
        }
        assert!(diag_err < 0.6, "diag err {diag_err}");
        assert!(max_off < 0.3, "off-diag {max_off}");
    }

    #[test]
    fn newton_schulz_tall_matrix() {
        let g = rand_mat(64, 16, 1);
        let o = newton_schulz(&g, NS_STEPS).unwrap();
        assert_eq!(o.shape, vec![64, 16]);
        // columns near-orthonormal: O^T O ~ I
        let gram = o.transpose2().unwrap().matmul(&o).unwrap();
        for i in 0..16 {
            let v = gram.as_f32()[i * 16 + i];
            assert!((v - 1.0).abs() < 0.7, "diag {v}");
        }
    }

    #[test]
    fn distributed_step_matches_single_device() {
        // Muon over 4 ranks must produce the same update as on 1 rank
        let (r, c) = (16, 32);
        let numel = (r * c) as u64;
        let pdata = rand_mat(r, c, 2);
        let gdata = rand_mat(r, c, 3);
        let fabric = Fabric::h800();

        let run = |m: usize| {
            let spec = RaggedSpec::balanced(numel, c as u64, m);
            let p = DTensor::ragged_from_full(&[r, c], pdata.as_f32(), spec.clone()).unwrap();
            let g = DTensor::ragged_from_full(&[r, c], gdata.as_f32(), spec).unwrap();
            let mut muon = Muon::new(0.02, 0.95, 0.0);
            let comm = SerialComm::new();
            let out = muon
                .step_matrix("w", (r, c), &p, &g, &fabric, &comm)
                .unwrap();
            out.to_full()
        };
        let single = run(1);
        let multi = run(4);
        for (a, b) in single.iter().zip(&multi) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn momentum_state_persists_across_steps() {
        let (r, c) = (8, 8);
        let numel = 64u64;
        let spec = RaggedSpec::balanced(numel, 8, 2);
        let fabric = Fabric::h800();
        let mut muon = Muon::new(0.1, 0.9, 0.0);
        let comm = SerialComm::new();
        let mut p = DTensor::ragged_from_full(
            &[r, c],
            rand_mat(r, c, 4).as_f32(),
            spec.clone(),
        )
        .unwrap();
        let g = DTensor::ragged_from_full(&[r, c], rand_mat(r, c, 5).as_f32(), spec).unwrap();
        let p1 = muon.step_matrix("w", (r, c), &p, &g, &fabric, &comm).unwrap();
        let before = muon.state_bytes();
        p = p1;
        let _p2 = muon.step_matrix("w", (r, c), &p, &g, &fabric, &comm).unwrap();
        assert_eq!(muon.state_bytes(), before);
        assert!(before > 0);
    }

    #[test]
    fn root_rotates_for_load_balance() {
        let mut muon = Muon::new(0.1, 0.9, 0.0);
        let roots: Vec<usize> = (0..6).map(|_| muon.select_root(4)).collect();
        assert_eq!(roots, vec![0, 1, 2, 3, 0, 1]);
    }

    #[test]
    fn update_is_orthogonalized_not_raw_grad() {
        // Muon's update direction differs from the raw gradient
        let (r, c) = (16, 16);
        let spec = RaggedSpec::balanced(256, 16, 2);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        let p0 = rand_mat(r, c, 6);
        let p = DTensor::ragged_from_full(&[r, c], p0.as_f32(), spec.clone()).unwrap();
        let g = DTensor::ragged_from_full(&[r, c], rand_mat(r, c, 7).as_f32(), spec).unwrap();
        let mut muon = Muon::new(1.0, 0.0, 0.0);
        let out = muon.step_matrix("w", (r, c), &p, &g, &fabric, &comm).unwrap();
        let delta: Vec<f32> = out
            .to_full()
            .iter()
            .zip(p0.as_f32())
            .map(|(a, b)| b - a)
            .collect();
        // delta should be ~orthogonal matrix (singular values ~1), very
        // different from the raw gradient's norm profile
        let d = HostTensor::from_f32(&[r, c], delta);
        let gram = d.matmul(&d.transpose2().unwrap()).unwrap();
        let trace: f32 = (0..r).map(|i| gram.as_f32()[i * r + i]).sum();
        assert!((trace / r as f32 - 1.0).abs() < 0.5, "trace/n {}", trace / r as f32);
    }
}
