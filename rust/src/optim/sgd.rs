//! Plain SGD (the paper's OOM-fallback baseline for GPT-OSS, §6 workloads).

use super::ShardOptimizer;

#[derive(Debug)]
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    /// Per-rank momentum buffers (allocated lazily; empty when momentum=0).
    vel: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32, ranks: usize) -> Sgd {
        Sgd { lr, momentum, vel: vec![Vec::new(); ranks] }
    }
}

impl ShardOptimizer for Sgd {
    fn step(&mut self, rank: usize, _t: u64, param: &mut [f32], grad: &[f32]) {
        assert_eq!(param.len(), grad.len());
        if self.momentum == 0.0 {
            for (p, g) in param.iter_mut().zip(grad) {
                *p -= self.lr * g;
            }
            return;
        }
        let vel = &mut self.vel[rank];
        if vel.len() != param.len() {
            vel.resize(param.len(), 0.0);
        }
        for ((p, g), v) in param.iter_mut().zip(grad).zip(vel.iter_mut()) {
            *v = self.momentum * *v + g;
            *p -= self.lr * *v;
        }
    }

    fn state_bytes(&self, rank: usize) -> u64 {
        self.vel[rank].len() as u64 * 4
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vanilla_sgd_step() {
        let mut o = Sgd::new(0.1, 0.0, 1);
        let mut p = vec![1.0f32, 2.0];
        o.step(0, 1, &mut p, &[1.0, -1.0]);
        assert_eq!(p, vec![0.9, 2.1]);
        assert_eq!(o.state_bytes(0), 0);
    }

    #[test]
    fn momentum_accumulates() {
        let mut o = Sgd::new(0.1, 0.9, 1);
        let mut p = vec![0.0f32];
        o.step(0, 1, &mut p, &[1.0]); // v=1, p=-0.1
        o.step(0, 2, &mut p, &[1.0]); // v=1.9, p=-0.29
        assert!((p[0] + 0.29).abs() < 1e-6);
        assert_eq!(o.state_bytes(0), 4);
    }

    #[test]
    fn independent_ranks() {
        let mut o = Sgd::new(0.1, 0.9, 2);
        let mut p0 = vec![0.0f32];
        let mut p1 = vec![0.0f32];
        o.step(0, 1, &mut p0, &[1.0]);
        o.step(1, 1, &mut p1, &[2.0]);
        assert!((p0[0] + 0.1).abs() < 1e-7);
        assert!((p1[0] + 0.2).abs() < 1e-7);
    }
}
