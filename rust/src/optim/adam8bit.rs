//! 8-bit Adam (Dettmers et al.) on flat shards — block-wise INT8 state
//! with per-block absmax scales, the paper's §6.3 case study.
//!
//! The critical system property: every quantization block must live
//! entirely on one device, or the absmax reduction needs cross-device
//! metadata exchange. RaggedShard with granularity = `block` guarantees
//! this; the engine asserts it. The quantization math mirrors
//! `python/compile/kernels/blockwise_quant.py` exactly (symmetric linear
//! absmax code — see DESIGN.md for the dynamic-tree-code substitution).

use std::sync::OnceLock;

use super::{AdamHyper, ShardOptimizer};

pub const QMAX: f32 = 127.0;

/// Dettmers' dynamic quantization map (8-bit, 7 exponent bits): values
/// spanning ~7 orders of magnitude, which is what keeps the second-moment
/// state usable at 8 bits (linear codes zero out small v and diverge).
/// Port of bitsandbytes `create_dynamic_map`.
pub fn create_dynamic_map(signed: bool) -> Vec<f32> {
    let max_exp_bits = 7i32;
    let non_sign_bits = 7i32;
    let mut data: Vec<f32> = Vec::with_capacity(256);
    for i in 0..max_exp_bits {
        let fraction_items = if signed {
            (1usize << i) + 1
        } else {
            (1usize << (i + 1)) + 1
        };
        // linspace(0.1, 1, fraction_items) midpoints
        let n = fraction_items;
        let step = 0.9 / (n - 1) as f64;
        let mult = 10f64.powi(-(max_exp_bits - 1) + i);
        for k in 0..n - 1 {
            let lo = 0.1 + step * k as f64;
            let hi = 0.1 + step * (k + 1) as f64;
            let mean = ((lo + hi) / 2.0 * mult) as f32;
            data.push(mean);
            if signed {
                data.push(-mean);
            }
        }
    }
    let _ = non_sign_bits;
    data.push(0.0);
    data.push(1.0); // bnb appends only +1.0 (asymmetric, as upstream)
    data.sort_by(|a, b| a.partial_cmp(b).unwrap());
    data
}

static SIGNED_MAP: OnceLock<Vec<f32>> = OnceLock::new();
static UNSIGNED_MAP: OnceLock<Vec<f32>> = OnceLock::new();

fn signed_map() -> &'static [f32] {
    SIGNED_MAP.get_or_init(|| create_dynamic_map(true))
}

fn unsigned_map() -> &'static [f32] {
    UNSIGNED_MAP.get_or_init(|| create_dynamic_map(false))
}

fn nearest_code(map: &[f32], x: f32) -> u8 {
    // binary search for the nearest codebook entry
    let mut lo = 0usize;
    let mut hi = map.len() - 1;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if map[mid] <= x {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    if (x - map[lo]).abs() <= (map[hi] - x).abs() {
        lo as u8
    } else {
        hi as u8
    }
}

/// Dynamic-code block quantization: returns scale (absmax).
pub fn quant_block_dyn(x: &[f32], q: &mut [u8], signed: bool) -> f32 {
    let map: &[f32] = if signed { signed_map() } else { unsigned_map() };
    let absmax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let scale = if absmax > 0.0 { absmax } else { 1.0 };
    for (qi, &v) in q.iter_mut().zip(x) {
        *qi = nearest_code(map, v / scale);
    }
    scale
}

pub fn dequant_block_dyn(q: &[u8], scale: f32, out: &mut [f32], signed: bool) {
    let map: &[f32] = if signed { signed_map() } else { unsigned_map() };
    for (o, &c) in out.iter_mut().zip(q) {
        *o = map[c as usize] * scale;
    }
}

/// Quantize a block with the symmetric linear absmax code: returns the
/// scale. Delegates to the canonical kernel in [`crate::quant`], which
/// rounds half to even exactly like the Pallas reference (`jnp.round`) —
/// golden-vector parity between the two is asserted by
/// `tests/quant_parity.rs`.
pub fn quant_block(x: &[f32], q: &mut [i8]) -> f32 {
    crate::quant::quant_block(x, q)
}

pub fn dequant_block(q: &[i8], scale: f32, out: &mut [f32]) {
    crate::quant::dequant_block(q, scale, out)
}

/// Per-rank quantized Adam state (dynamic-code u8 indices).
#[derive(Debug, Default)]
struct QState {
    m_q: Vec<u8>,
    m_scale: Vec<f32>,
    v_q: Vec<u8>,
    v_scale: Vec<f32>,
}

#[derive(Debug)]
pub struct Adam8bit {
    pub hyper: AdamHyper,
    /// Quantization block (elements). The shard length must be a multiple
    /// (RaggedShard granularity guarantees it).
    pub block: usize,
    states: Vec<QState>,
}

impl Adam8bit {
    pub fn new(hyper: AdamHyper, block: usize, ranks: usize) -> Adam8bit {
        assert!(block > 0);
        Adam8bit {
            hyper,
            block,
            states: (0..ranks).map(|_| QState::default()).collect(),
        }
    }

    /// Number of independent state slots this instance was created with.
    pub fn num_slots(&self) -> usize {
        self.states.len()
    }
}

impl ShardOptimizer for Adam8bit {
    fn step(&mut self, rank: usize, t: u64, param: &mut [f32], grad: &[f32]) {
        assert_eq!(param.len(), grad.len());
        assert_eq!(
            param.len() % self.block,
            0,
            "shard length {} not a multiple of quant block {} — the \
             sharding format failed to preserve block boundaries",
            param.len(),
            self.block
        );
        let nb = param.len() / self.block;
        let st = &mut self.states[rank];
        if st.m_q.len() != param.len() {
            st.m_q = vec![signed_map().iter().position(|&x| x == 0.0).unwrap() as u8; param.len()];
            st.v_q = vec![0; param.len()]; // unsigned map code 0 == 0.0
            st.m_scale = vec![1.0; nb];
            st.v_scale = vec![1.0; nb];
        }
        let h = &self.hyper;
        let bc1 = 1.0 - h.beta1.powi(t as i32);
        let bc2 = 1.0 - h.beta2.powi(t as i32);
        let mut m = vec![0.0f32; self.block];
        let mut v = vec![0.0f32; self.block];
        for b in 0..nb {
            let r = b * self.block..(b + 1) * self.block;
            dequant_block_dyn(&st.m_q[r.clone()], st.m_scale[b], &mut m, true);
            dequant_block_dyn(&st.v_q[r.clone()], st.v_scale[b], &mut v, false);
            let (p, g) = (&mut param[r.clone()], &grad[r.clone()]);
            for i in 0..self.block {
                m[i] = h.beta1 * m[i] + (1.0 - h.beta1) * g[i];
                v[i] = (h.beta2 * v[i] + (1.0 - h.beta2) * g[i] * g[i]).max(0.0);
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                p[i] -= h.lr * (mhat / (vhat.sqrt() + h.eps) + h.wd * p[i]);
            }
            st.m_scale[b] = quant_block_dyn(&m, &mut st.m_q[r.clone()], true);
            st.v_scale[b] = quant_block_dyn(&v, &mut st.v_q[r], false);
        }
    }

    fn state_bytes(&self, rank: usize) -> u64 {
        let st = &self.states[rank];
        (st.m_q.len() + st.v_q.len()) as u64
            + (st.m_scale.len() + st.v_scale.len()) as u64 * 4
    }

    fn name(&self) -> &'static str {
        "adam8bit"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::AdamW;
    use crate::util::Rng;

    #[test]
    fn quant_dequant_roundtrip_bounded() {
        let mut rng = Rng::new(0);
        let x: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
        let mut q = vec![0i8; 256];
        let scale = quant_block(&x, &mut q);
        let mut y = vec![0.0f32; 256];
        dequant_block(&q, scale, &mut y);
        let step = scale / QMAX;
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= step * 0.5 + 1e-6);
        }
    }

    #[test]
    fn zero_block_stable() {
        let x = vec![0.0f32; 64];
        let mut q = vec![0i8; 64];
        let scale = quant_block(&x, &mut q);
        assert_eq!(scale, 1.0);
        assert!(q.iter().all(|&c| c == 0));
    }

    #[test]
    fn tracks_fp32_adam_closely() {
        let mut rng = Rng::new(1);
        let n = 1024;
        let block = 128;
        let h = AdamHyper { wd: 0.0, ..Default::default() };
        let mut q = Adam8bit::new(h, block, 1);
        let mut full = AdamW::new(h, 1);
        let mut p8: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let mut p32 = p8.clone();
        for t in 1..=20 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.1).collect();
            q.step(0, t, &mut p8, &g);
            full.step(0, t, &mut p32, &g);
        }
        let max_diff = p8
            .iter()
            .zip(&p32)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 0.05, "8-bit drifted too far: {max_diff}");
    }

    #[test]
    fn state_memory_is_quarter_of_fp32() {
        let h = AdamHyper::default();
        let mut q = Adam8bit::new(h, 128, 1);
        let mut full = AdamW::new(h, 1);
        let mut p1 = vec![0.1f32; 4096];
        let mut p2 = p1.clone();
        let g = vec![0.01f32; 4096];
        q.step(0, 1, &mut p1, &g);
        full.step(0, 1, &mut p2, &g);
        // int8 m+v + scales vs fp32 m+v: ~4x smaller
        assert!(q.state_bytes(0) * 3 < full.state_bytes(0));
    }

    #[test]
    #[should_panic(expected = "block boundaries")]
    fn misaligned_shard_rejected() {
        // a shard that splits a quant block must be rejected — this is the
        // failure existing FSDP systems hit (paper Table 2, RaggedShard N/A)
        let mut q = Adam8bit::new(AdamHyper::default(), 128, 1);
        let mut p = vec![0.0f32; 100];
        let g = vec![0.0f32; 100];
        q.step(0, 1, &mut p, &g);
    }

    #[test]
    fn blocks_quantize_independently() {
        let h = AdamHyper { wd: 0.0, ..Default::default() };
        let mut q = Adam8bit::new(h, 64, 1);
        let mut p = vec![0.0f32; 128];
        // huge grad in block 0, tiny in block 1: block 1 retains precision
        let mut g = vec![0.0f32; 128];
        g[..64].iter_mut().for_each(|x| *x = 100.0);
        g[64..].iter_mut().for_each(|x| *x = 1e-4);
        q.step(0, 1, &mut p, &g);
        let st = &q.states[0];
        assert!(st.m_scale[0] > 1.0);
        assert!(st.m_scale[1] < 1e-3);
    }
}
