//! Per-wrap-unit optimizer dispatch: the uniform interface the spec API
//! binds optimizers through (`OptimBinding` in [`crate::fsdp::spec`]).
//!
//! A [`GroupOptimizer`] steps one whole FSDP shard group (bucket) at a
//! time, given a [`GroupEnv`] view of that bucket's sharded parameters,
//! reduced gradient shards, and comm/fabric context. Three adapters cover
//! the optimizer families:
//!
//! * [`FlatGroup`] — any element-wise [`ShardOptimizer`] (AdamW / SGD /
//!   raw 8-bit Adam) applied to each rank's flat shard;
//! * [`MuonGroup`] — Muon's Algorithm 2 on the group's 2-D hidden
//!   matrices (redistribute-to-root + Newton–Schulz through the cluster
//!   backend), an element-wise fallback on everything else;
//! * [`Adam8bitGroup`] — block-wise quantized state on >=2-D parameters
//!   whose shard slices preserve quant-block boundaries, fp32 AdamW on
//!   1-D parameters — the paper's §6.3 structure-aware setup.
//!
//! The bucket-step free functions (`flat_bucket_step`,
//! `muon_bucket_step`, `adam8bit_bucket_step`) are shared with the
//! engine's legacy `optimizer_step` / `muon_step` / `adam8bit_step`
//! methods, so the legacy and spec paths execute the identical float
//! operations in the identical order — the bit-identity the equivalence
//! tests assert.

use anyhow::Result;

use crate::cluster::Communicator;
use crate::comm::Fabric;
use crate::dbuffer::DBuffer;
use crate::dtensor::DTensor;
use crate::mesh::DeviceMesh;
use crate::placement::Placement;

use super::{Adam8bit, AdamW, Muon, ShardOptimizer};

/// Everything an optimizer may need about one shard group for one step.
/// All references borrow from the engine's bucket; the env is rebuilt per
/// step (it is a bundle of borrows, not state).
pub struct GroupEnv<'a> {
    /// (name, shape) of each tensor in the bucket, bucket-position order.
    pub params: &'a [(String, Vec<usize>)],
    /// The group's sharded parameter storage (mutated in place).
    pub dbuffer: &'a mut DBuffer,
    /// Per-rank reduced gradient shards (same layout as the DBuffer
    /// shards).
    pub grad_shards: &'a [Vec<f32>],
    /// The group's mesh (fsdp + optional replica dims).
    pub mesh: &'a DeviceMesh,
    /// The group's fabric (timing model for optimizer collectives).
    pub fabric: &'a Fabric,
    /// Cluster backend for structure-aware optimizer collectives.
    pub comm: &'a dyn Communicator,
}

/// One shard group's optimizer: the uniform per-group dispatch interface.
/// `t` is the 1-based step.
pub trait GroupOptimizer {
    fn step_group(&mut self, env: GroupEnv<'_>, t: u64) -> Result<()>;

    /// Optimizer-state bytes currently held across all ranks.
    fn state_bytes(&self) -> u64;

    fn name(&self) -> &'static str;
}

/// Element-wise step over every rank's flat shard (the legacy
/// `FsdpEngine::optimizer_step` body for one bucket).
pub fn flat_bucket_step(
    opt: &mut dyn ShardOptimizer,
    env: GroupEnv<'_>,
    t: u64,
) -> Result<()> {
    let GroupEnv { dbuffer, grad_shards, .. } = env;
    for rank in 0..dbuffer.num_devices() {
        opt.step(rank, t, &mut dbuffer.shards[rank], &grad_shards[rank]);
    }
    Ok(())
}

/// Muon step over one bucket: 2-D hidden matrices go through Alg 2
/// (redistribute-to-root + Newton–Schulz); everything else through the
/// element-wise `fallback` on its local slices.
pub fn muon_bucket_step(
    muon: &mut Muon,
    fallback: &mut dyn ShardOptimizer,
    env: GroupEnv<'_>,
    t: u64,
) -> Result<()> {
    let GroupEnv { params, dbuffer, grad_shards, fabric, comm, .. } = env;
    let m = dbuffer.num_devices();
    for pos in 0..params.len() {
        let (name, shape) = &params[pos];
        let is_hidden_matrix =
            shape.len() == 2 && !name.contains("embed") && !name.contains("head");
        if is_hidden_matrix {
            let spec = dbuffer.layout.ragged_spec(pos);
            let numel: u64 = shape.iter().map(|&s| s as u64).product();
            spec.validate(numel)?;
            let p_locals: Vec<Vec<f32>> = (0..m)
                .map(|rank| {
                    dbuffer
                        .local_view(rank, pos)
                        .map(|(_, v)| v.to_vec())
                        .unwrap_or_default()
                })
                .collect();
            let g_locals: Vec<Vec<f32>> = (0..m)
                .map(|rank| {
                    dbuffer
                        .local_view(rank, pos)
                        .map(|((lo, hi), _)| {
                            let off = dbuffer.layout.offsets[pos];
                            let s = dbuffer.layout.shard_size;
                            let a = (off + lo - rank as u64 * s) as usize;
                            grad_shards[rank][a..a + (hi - lo) as usize].to_vec()
                        })
                        .unwrap_or_default()
                })
                .collect();
            let param = DTensor {
                global_shape: shape.clone(),
                placement: Placement::RaggedShard(spec.clone()),
                locals: p_locals,
            };
            let grad = DTensor {
                global_shape: shape.clone(),
                placement: Placement::RaggedShard(spec),
                locals: g_locals,
            };
            let updated =
                muon.step_matrix(name, (shape[0], shape[1]), &param, &grad, fabric, comm)?;
            for rank in 0..m {
                if let Some((_, view)) = dbuffer.local_view_mut(rank, pos) {
                    view.copy_from_slice(&updated.locals[rank]);
                }
            }
        } else {
            // element-wise fallback on this tensor's local slices
            // (split borrow — no gradient clone)
            for rank in 0..m {
                if let Some((lo, hi)) = dbuffer.layout.local_slice(pos, rank) {
                    let off = dbuffer.layout.offsets[pos];
                    let s = dbuffer.layout.shard_size;
                    let a = (off + lo - rank as u64 * s) as usize;
                    let len = (hi - lo) as usize;
                    let grad = &grad_shards[rank][a..a + len];
                    let shard = &mut dbuffer.shards[rank][a..a + len];
                    fallback.step(rank, t, shard, grad);
                }
            }
        }
    }
    Ok(())
}

/// 8-bit Adam step over one bucket (paper §6.3): quantized state on >=2-D
/// parameters whose shard slices keep every quant block local, fp32
/// fallback otherwise. `slot_base[pos] + rank` keys the state slot of the
/// bucket's pos-th tensor on `rank` (the caller chooses global vs
/// group-local keying; state is independent per slot either way).
pub fn adam8bit_bucket_step(
    a8: &mut Adam8bit,
    fallback: &mut AdamW,
    env: GroupEnv<'_>,
    slot_base: &[usize],
    t: u64,
) -> Result<()> {
    let GroupEnv { params, dbuffer, grad_shards, .. } = env;
    let m = dbuffer.num_devices();
    let block = a8.block as u64;
    for pos in 0..params.len() {
        let shape = &params[pos].1;
        for rank in 0..m {
            let Some((lo, hi)) = dbuffer.layout.local_slice(pos, rank) else {
                continue;
            };
            let off = dbuffer.layout.offsets[pos];
            let s = dbuffer.layout.shard_size;
            let a = (off + lo - rank as u64 * s) as usize;
            let len = (hi - lo) as usize;
            let grad = &grad_shards[rank][a..a + len];
            let slice = &mut dbuffer.shards[rank][a..a + len];
            let slot = slot_base[pos] + rank;
            let blocks_ok = lo % block == 0 && (len as u64) % block == 0;
            if shape.len() >= 2 && blocks_ok {
                a8.step(slot, t, slice, grad);
            } else {
                fallback.step(slot, t, slice, grad);
            }
        }
    }
    Ok(())
}

/// Adapter: any element-wise [`ShardOptimizer`] as a group optimizer.
pub struct FlatGroup {
    inner: Box<dyn ShardOptimizer>,
    ranks: usize,
}

impl FlatGroup {
    pub fn new(inner: Box<dyn ShardOptimizer>, ranks: usize) -> FlatGroup {
        FlatGroup { inner, ranks }
    }
}

impl GroupOptimizer for FlatGroup {
    fn step_group(&mut self, env: GroupEnv<'_>, t: u64) -> Result<()> {
        flat_bucket_step(self.inner.as_mut(), env, t)
    }

    fn state_bytes(&self) -> u64 {
        (0..self.ranks).map(|r| self.inner.state_bytes(r)).sum()
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

/// Adapter: Muon on the group's 2-D hidden matrices, an element-wise
/// fallback (AdamW unless the caller picks otherwise) on the rest.
pub struct MuonGroup {
    muon: Muon,
    fallback: Box<dyn ShardOptimizer>,
    ranks: usize,
}

impl MuonGroup {
    pub fn new(muon: Muon, fallback: Box<dyn ShardOptimizer>, ranks: usize) -> MuonGroup {
        MuonGroup { muon, fallback, ranks }
    }
}

impl GroupOptimizer for MuonGroup {
    fn step_group(&mut self, env: GroupEnv<'_>, t: u64) -> Result<()> {
        muon_bucket_step(&mut self.muon, self.fallback.as_mut(), env, t)
    }

    fn state_bytes(&self) -> u64 {
        self.muon.state_bytes()
            + (0..self.ranks).map(|r| self.fallback.state_bytes(r)).sum::<u64>()
    }

    fn name(&self) -> &'static str {
        "muon"
    }
}

/// Adapter: block-wise 8-bit Adam with the fp32 fallback pair, state
/// keyed per (group tensor, rank).
pub struct Adam8bitGroup {
    a8: Adam8bit,
    fallback: AdamW,
    ranks: usize,
}

impl Adam8bitGroup {
    /// `n_params` is the number of tensors in the group (state slots are
    /// `n_params * ranks`).
    pub fn new(
        hyper: super::AdamHyper,
        qblock: usize,
        n_params: usize,
        ranks: usize,
    ) -> Adam8bitGroup {
        let slots = n_params.max(1) * ranks;
        Adam8bitGroup {
            a8: Adam8bit::new(hyper, qblock, slots),
            fallback: AdamW::new(hyper, slots),
            ranks,
        }
    }
}

impl GroupOptimizer for Adam8bitGroup {
    fn step_group(&mut self, env: GroupEnv<'_>, t: u64) -> Result<()> {
        let slot_base: Vec<usize> =
            (0..env.params.len()).map(|pos| pos * self.ranks).collect();
        adam8bit_bucket_step(&mut self.a8, &mut self.fallback, env, &slot_base, t)
    }

    fn state_bytes(&self) -> u64 {
        let slots = self.a8.num_slots();
        (0..slots)
            .map(|s| self.a8.state_bytes(s) + self.fallback.state_bytes(s))
            .sum()
    }

    fn name(&self) -> &'static str {
        "adam8bit"
    }
}
