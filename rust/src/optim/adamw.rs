//! AdamW on flat shards — host mirror of the fused Pallas kernel
//! (`python/compile/kernels/fused_adamw.py`); same update equations, so
//! the PJRT `adamw_chunk` artifact and this implementation agree to f32
//! rounding (checked by `rust/tests/runtime_artifacts.rs`).

use super::{AdamHyper, ShardOptimizer};

#[derive(Debug)]
pub struct AdamW {
    pub hyper: AdamHyper,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl AdamW {
    pub fn new(hyper: AdamHyper, ranks: usize) -> AdamW {
        AdamW { hyper, m: vec![Vec::new(); ranks], v: vec![Vec::new(); ranks] }
    }

    /// The update on raw slices (shared with tests / the Muon fallback).
    pub fn apply(
        h: &AdamHyper,
        t: u64,
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
    ) {
        let (b1, b2) = (h.beta1, h.beta2);
        let bc1 = 1.0 - b1.powi(t as i32);
        let bc2 = 1.0 - b2.powi(t as i32);
        for i in 0..p.len() {
            m[i] = b1 * m[i] + (1.0 - b1) * g[i];
            v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            p[i] -= h.lr * (mhat / (vhat.sqrt() + h.eps) + h.wd * p[i]);
        }
    }
}

impl ShardOptimizer for AdamW {
    fn step(&mut self, rank: usize, t: u64, param: &mut [f32], grad: &[f32]) {
        assert_eq!(param.len(), grad.len());
        let m = &mut self.m[rank];
        let v = &mut self.v[rank];
        if m.len() != param.len() {
            m.resize(param.len(), 0.0);
            v.resize(param.len(), 0.0);
        }
        AdamW::apply(&self.hyper, t, param, grad, m, v);
    }

    fn state_bytes(&self, rank: usize) -> u64 {
        (self.m[rank].len() + self.v[rank].len()) as u64 * 4
    }

    fn name(&self) -> &'static str {
        "adamw"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_matches_hand_calc() {
        // t=1: m=0.1*g... with beta1=0.9: m=(1-0.9)*g=0.1g; mhat=m/(1-0.9)=g
        // v=0.001*g^2; vhat=g^2; update = lr*(g/(|g|+eps) + wd*p)
        let h = AdamHyper { lr: 0.01, beta1: 0.9, beta2: 0.999, eps: 0.0, wd: 0.0 };
        let mut o = AdamW::new(h, 1);
        let mut p = vec![1.0f32];
        o.step(0, 1, &mut p, &[0.5]);
        // sign-like first step: p -= lr * sign(g)
        assert!((p[0] - (1.0 - 0.01)).abs() < 1e-5, "{}", p[0]);
    }

    #[test]
    fn weight_decay_pure() {
        let h = AdamHyper { lr: 0.1, wd: 0.1, ..Default::default() };
        let mut o = AdamW::new(h, 1);
        let mut p = vec![2.0f32];
        o.step(0, 1, &mut p, &[0.0]);
        assert!((p[0] - (2.0 - 0.1 * 0.1 * 2.0)).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize (p-3)^2 -> p should approach 3
        let h = AdamHyper { lr: 0.1, ..Default::default() };
        let mut o = AdamW::new(AdamHyper { wd: 0.0, ..h }, 1);
        let mut p = vec![0.0f32];
        for t in 1..=200 {
            let g = [2.0 * (p[0] - 3.0)];
            o.step(0, t, &mut p, &g);
        }
        assert!((p[0] - 3.0).abs() < 0.1, "{}", p[0]);
    }

    #[test]
    fn state_grows_with_shard() {
        let mut o = AdamW::new(AdamHyper::default(), 2);
        let mut p = vec![0.0f32; 100];
        o.step(0, 1, &mut p, &vec![0.1; 100]);
        assert_eq!(o.state_bytes(0), 800);
        assert_eq!(o.state_bytes(1), 0);
    }
}
