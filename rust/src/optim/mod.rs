//! Sharded optimizers. Each operates on a device's *local shard* of the
//! flat parameter/gradient state — exactly what FSDP hands it — so
//! structure-aware optimizers (8-bit Adam's quant blocks, Muon's 2-D
//! matrices) only work when the sharding format preserves their structure,
//! which is the paper's whole point (§6.3).
//!
//! Host implementations mirror the L1 Pallas kernels bit-for-bit in math
//! (same update equations as `python/compile/kernels/`); the runtime can
//! swap in the AOT `adamw_chunk` / `adam8bit_chunk` HLO artifacts and the
//! integration tests check host-vs-artifact agreement.
//!
//! [`group`] layers the uniform per-wrap-unit dispatch on top: a
//! [`GroupOptimizer`] steps one whole shard group, with adapters that put
//! Muon and block-wise 8-bit Adam behind the same interface as the
//! element-wise family — what the spec API's per-group `OptimBinding`
//! resolves to.

pub mod adam8bit;
pub mod adamw;
pub mod group;
pub mod muon;
pub mod sgd;

pub use adam8bit::Adam8bit;
pub use adamw::AdamW;
pub use group::{Adam8bitGroup, FlatGroup, GroupEnv, GroupOptimizer, MuonGroup};
pub use muon::Muon;
pub use sgd::Sgd;

/// Hyper-parameters shared by the Adam family.
#[derive(Debug, Clone, Copy)]
pub struct AdamHyper {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub wd: f32,
}

impl Default for AdamHyper {
    fn default() -> Self {
        AdamHyper { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.01 }
    }
}

/// Flat-shard optimizer interface (element-wise family).
pub trait ShardOptimizer {
    /// One step over the rank's local shard. `t` is the 1-based step.
    fn step(&mut self, rank: usize, t: u64, param: &mut [f32], grad: &[f32]);

    /// Optimizer-state bytes currently held for `rank`.
    fn state_bytes(&self, rank: usize) -> u64;

    fn name(&self) -> &'static str;
}
