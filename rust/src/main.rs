//! veScale-FSDP launcher.
//!
//!     vescale-fsdp train  [--config-file cfg.toml] [--model tiny] [--mesh 4]
//!                         [--opt adamw|adam8bit|muon|sgd] [--steps 50]
//!                         [--backend serial|threaded] [--prefetch N]
//!                         [--fabric h800|h100|a100[:HxG[:S]]]
//!                         [--topology HxG[:S]]
//!                         [--comm-precision f32|bf16|q8[:block]]
//!                         [--hier-threshold ELEMS]  (serial-fallback /
//!                          two-level dispatch threshold in total elements;
//!                          also `[comm] hier_threshold` in the config file)
//!                         [--trace out.json] [--trace-level off|comm|full]
//!                         [--watchdog-ms N] [--metrics out.prom|out.json]
//!                         [--postmortem-on-exit [path]]
//!                         [--inject-stall us[,us...]]  (testing: stagger
//!                          rank arrivals into rendezvous collectives so the
//!                          watchdog has something to catch)
//!                         [--lint]  (static schedule pre-flight: abort on
//!                          any `fsdp-lint` diagnostic before training)
//!                         (N=0: sequential step loop; N>=1: bucket-pipelined
//!                          executor with up to N in-flight bucket collectives;
//!                          --topology HxG dispatches whole-cluster collectives
//!                          hierarchically: intra-host ring + rail-aligned
//!                          inter-host exchange, S pipeline segments)
//!     vescale-fsdp plan   [--preset gptoss120b] [--devices 64] [--rows 128]
//!     vescale-fsdp sim    [--preset llama70b] [--system vescale] [--fsdp 128]
//!                         [--topology HxG[:S]]
//!     vescale-fsdp bench  (points at `cargo bench`)
//!
//! Config files additionally support `[group.<name>]` sections (per-group
//! optimizer / granularity / reshard-after-forward / lr on the layerwise
//! wrapping), deserialized straight into the `fsdp::spec` API — see
//! `config::file`.

use anyhow::{anyhow, Result};

use vescale_fsdp::analysis::diag::{codes, rt};
use vescale_fsdp::baselines;
use vescale_fsdp::cluster::{set_arrival_stagger, CommBackend};
use vescale_fsdp::comm::{Fabric, Topology};
use vescale_fsdp::config::file::ConfigFile;
use vescale_fsdp::config::{presets, OptimKind, ParallelConfig, System, TrainConfig};
use vescale_fsdp::fsdp::sim::{simulate_step, GpuSpec};
use vescale_fsdp::fsdp::spec::OptimBinding;
use vescale_fsdp::fsdp::{ExecMode, ShardingPolicy};
use vescale_fsdp::obs::ObsConfig;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::planner::{plan, TensorDecl};
use vescale_fsdp::quant::CommPrecision;
use vescale_fsdp::trace::TraceLevel;
use vescale_fsdp::train::{save_log, TrainSession};
use vescale_fsdp::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("plan") => cmd_plan(&args),
        Some("sim") => cmd_sim(&args),
        Some("bench") => {
            println!("run `cargo bench` — one harness per paper table/figure");
            Ok(())
        }
        _ => {
            println!("veScale-FSDP reproduction launcher");
            println!("usage: vescale-fsdp <train|plan|sim|bench> [--flags]");
            println!("see README.md for details");
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let base: TrainConfig = match args.get("config-file") {
        Some(path) => ConfigFile::load(path)?.train_config()?,
        None => TrainConfig::default(),
    };
    let model = args.str_or("model", &base.model);
    let mesh = args.usize_or("mesh", base.parallel.fsdp);
    let steps = args.usize_or("steps", base.steps);
    let opt = match args.get("opt") {
        Some(s) => OptimKind::parse(s).ok_or_else(|| anyhow!("unknown --opt {s}"))?,
        None => base.optimizer,
    };
    let lr = args.f64_or("lr", base.lr) as f32;
    let backend = match args.get("backend") {
        Some(s) => CommBackend::parse(s).ok_or_else(|| anyhow!("unknown --backend {s}"))?,
        None => base.backend,
    };
    let exec = ExecMode::from_prefetch(args.usize_or("prefetch", base.prefetch));
    let fabric_name = args.str_or("fabric", &base.fabric);
    let fabric = Fabric::by_name(&fabric_name).ok_or_else(|| {
        anyhow!(
            "unknown --fabric '{fabric_name}' (expected one of {:?})",
            Fabric::preset_names()
        )
    })?;
    let topo_str = args.str_or("topology", &base.topology);
    let fabric = if topo_str.is_empty() {
        fabric
    } else {
        fabric.with_topology(Topology::parse(&topo_str).ok_or_else(|| {
            anyhow!("bad --topology '{topo_str}' (expected HxG[:S], e.g. 2x4 or 4x8:2)")
        })?)
    };
    let prec_name = args.str_or("comm-precision", &base.comm_precision);
    let comm_precision = CommPrecision::parse(&prec_name).ok_or_else(|| {
        anyhow!("unknown --comm-precision '{prec_name}' (expected f32, bf16, or q8[:block])")
    })?;
    let hier_threshold = args.usize_or("hier-threshold", base.hier_threshold);
    // A bare trailing `--trace` parses as the value "true"; treat that as
    // "trace to the default filename".
    let trace_path: Option<String> = args
        .get("trace")
        .map(|p| if p == "true" { "trace.json" } else { p })
        .map(str::to_string)
        .or_else(|| base.trace.clone());
    let level_name = args.str_or("trace-level", &base.trace_level);
    let trace_level = TraceLevel::parse(&level_name).ok_or_else(|| {
        anyhow!("unknown --trace-level '{level_name}' (expected off, comm, or full)")
    })?;
    // Tracing only arms when an output path is requested; otherwise the
    // tracer stays Off and every span site is a single untaken branch.
    let level = if trace_path.is_some() {
        trace_level
    } else {
        TraceLevel::Off
    };
    // Health monitor: any of --watchdog-ms / --metrics /
    // --postmortem-on-exit (or the [obs] config section) arms it;
    // otherwise every instrumentation site is a single untaken branch.
    let watchdog_ms = args.u64_or("watchdog-ms", base.watchdog_ms);
    let metrics_path: Option<String> = args
        .get("metrics")
        .map(|p| if p == "true" { "metrics.json" } else { p })
        .map(str::to_string)
        .or_else(|| base.metrics.clone());
    let postmortem_path: Option<String> = match args.get("postmortem-on-exit") {
        Some("true") | Some("1") | Some("yes") => Some("postmortem.json".to_string()),
        Some(p) => Some(p.to_string()),
        None => base.postmortem.then(|| "postmortem.json".to_string()),
    };
    let monitor_on = watchdog_ms > 0 || metrics_path.is_some() || postmortem_path.is_some();
    let policy = if opt == OptimKind::Adam8bit {
        ShardingPolicy::uniform_rows(32)
    } else if base.granularity > 1 {
        ShardingPolicy { default_granularity: base.granularity, ..ShardingPolicy::element_wise() }
    } else {
        ShardingPolicy::element_wise()
    };
    let hyper = AdamHyper { lr, ..AdamHyper::default() };
    println!(
        "train: model={model} mesh={mesh} opt={} steps={steps} backend={} exec={} fabric={} wire={}",
        opt.name(),
        backend.name(),
        exec.name(),
        fabric.name,
        comm_precision.name()
    );
    let mut builder = TrainSession::builder(&model)
        .devices(mesh)
        .replicas(base.parallel.replicas)
        .optimizer(OptimBinding::from_kind(opt))
        .policy(policy)
        .hyper(hyper)
        .seed(base.seed)
        .backend(backend)
        .exec(exec)
        .fabric(fabric)
        .comm_precision(comm_precision)
        .hier_threshold(hier_threshold)
        .trace(level)
        .overrides(base.groups.clone());
    if monitor_on {
        builder = builder.observer(ObsConfig {
            watchdog_ms,
            postmortem_path: postmortem_path.clone(),
            ..ObsConfig::default()
        });
    }
    if args.bool("lint") {
        // static pre-flight: elaborate the full per-rank schedule and run
        // every analyzer check before touching any shard memory
        let report = builder.analyze()?;
        for d in &report.diagnostics {
            eprintln!("lint: {d}");
        }
        if !report.diagnostics.is_empty() {
            anyhow::bail!(
                "--lint found {} diagnostic(s); aborting before training",
                report.diagnostics.len()
            );
        }
        println!(
            "lint: clean ({} collectives/rank, peak bound {:.2} MB reserved)",
            report.collectives_per_rank,
            report.peak_reserved_bound as f64 / 1e6
        );
    }
    let mut trainer = builder.build()?;
    if let Some(spec) = args.get("inject-stall") {
        // deterministic fault injection: delay rank k's arrival into every
        // rendezvous collective by delays[k] microseconds (testing only)
        let delays: Vec<u64> = spec
            .split(',')
            .map(|s| s.trim().parse::<u64>())
            .collect::<Result<_, _>>()
            .map_err(|_| anyhow!("bad --inject-stall '{spec}' (expected us[,us...])"))?;
        eprintln!("fault injection: arrival stagger {delays:?} us");
        set_arrival_stagger(&delays);
    }
    println!("compute runtime: {}", trainer.runtime.backend_name());
    println!(
        "shard groups: {}",
        trainer
            .engine
            .buckets
            .iter()
            .zip(&trainer.optimizers)
            .map(|(b, o)| format!("{}:{}", b.name, o.name()))
            .collect::<Vec<_>>()
            .join(" ")
    );
    for step in 1..=steps {
        let loss = trainer.train_step()?;
        if step % 10 == 0 || step == 1 {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    if let Some(r) = &trainer.last_report {
        let (peak_res, _) = trainer.engine.memory_stats();
        println!(
            "executor: exposed comm {:.1}% of step wall, peak reserved {:.2} MB \
             (fabric {})",
            100.0 * r.exposed_comm_s / r.wall_s.max(1e-12),
            peak_res as f64 / 1e6,
            trainer.engine.fabric.name
        );
    }
    if let Some(last) = trainer.log.last() {
        println!(
            "wire/step: {:.3} MB payload + {:.3} MB scales + {:.3} MB pad ({})",
            last.wire_payload as f64 / 1e6,
            last.wire_scale as f64 / 1e6,
            last.wire_pad as f64 / 1e6,
            comm_precision.name()
        );
    }
    if let Some(out) = &trace_path {
        trainer.write_trace(std::path::Path::new(out))?;
        let s = trainer.trace_summary();
        println!(
            "trace: {out} ({} spans, level {}) — overlap efficiency {:.1}% \
             (hidden {:.3}s of {:.3}s comm)",
            trainer.tracer.span_count(),
            level.name(),
            100.0 * s.overlap_efficiency,
            s.hidden_comm_s,
            s.total_comm_s
        );
    }
    if trainer.obs.armed() {
        for d in trainer.obs.diagnostics() {
            eprintln!("health: {d}");
        }
        if let Some(out) = &metrics_path {
            if let Some(m) = trainer.obs.metrics() {
                let body = if out.ends_with(".prom") {
                    m.prometheus()
                } else {
                    format!("{}\n", m.json())
                };
                std::fs::write(out, body).map_err(|e| {
                    anyhow!("{}", rt(codes::EXPORT_IO, format_args!("writing metrics {out}: {e}")))
                })?;
                println!("metrics: {out}");
            }
        }
        if let Some(out) = &postmortem_path {
            trainer.obs.write_postmortem(out).map_err(|e| anyhow!(e))?;
            println!("postmortem: {out}");
        }
        trainer.obs.shutdown();
    }
    let path = save_log(
        &format!("train_{model}_{}_{}", opt.name(), backend.name()),
        &trainer.log,
    )?;
    println!("loss log: {}", path.display());
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let name = args.str_or("preset", "gptoss120b");
    let m = args.usize_or("devices", 64);
    let rows = args.u64_or("rows", 128);
    let preset =
        presets::by_name(&name).ok_or_else(|| anyhow!("unknown preset '{name}'"))?;
    let decls: Vec<TensorDecl> = preset
        .all_params()
        .iter()
        .map(|p| {
            let row = *p.shape.last().unwrap() as u64;
            let g = if p.name.contains("expert") || p.name.contains("mlp") {
                (rows * row).min(p.numel()).max(1)
            } else {
                1
            };
            TensorDecl::new(&p.name, p.numel(), g)
        })
        .collect();
    let t0 = std::time::Instant::now();
    let layout = plan(&decls, m, 4)?;
    layout.verify()?;
    println!(
        "{name} on {m} devices, {rows}-row granularity: S={} elems, padding {:.4}%, planned in {:.3}s",
        layout.shard_size,
        layout.padding_ratio() * 100.0,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let name = args.str_or("preset", "llama70b");
    let preset =
        presets::by_name(&name).ok_or_else(|| anyhow!("unknown preset '{name}'"))?;
    let system = System::parse(&args.str_or("system", "vescale"))
        .ok_or_else(|| anyhow!("unknown --system"))?;
    let parallel = ParallelConfig {
        fsdp: args.usize_or("fsdp", 128),
        replicas: args.usize_or("replicas", 1),
        ep: args.usize_or("ep", 1),
    };
    let tokens = args.u64_or("tokens", preset.seq_default as u64);
    let fabric = Fabric::by_name(&args.str_or("fabric", "h800"))
        .ok_or_else(|| anyhow!("unknown --fabric"))?;
    let fabric = match args.get("topology") {
        Some(t) => fabric.with_topology(
            Topology::parse(t)
                .ok_or_else(|| anyhow!("bad --topology '{t}' (expected HxG[:S])"))?,
        ),
        None => fabric,
    };
    let r = simulate_step(
        &preset,
        &parallel,
        OptimKind::parse(&args.str_or("opt", "adamw")).ok_or_else(|| anyhow!("bad --opt"))?,
        tokens,
        &fabric,
        &GpuSpec::h800(),
        &baselines::behavior_for(system, args.u64_or("granularity", 1)),
    )?;
    println!("{} on {} ({}):", system.name(), name, parallel.label());
    println!("  step time     {:.3} s", r.step_time);
    println!("  tokens/s      {:.3e} (global)", r.tokens_per_sec);
    println!("  exposed comm  {:.3} s", r.exposed_comm);
    println!("  copy overhead {:.3} s", r.copy_time);
    println!("  peak reserved {:.2} GB{}", r.peak_reserved as f64 / 1e9,
             if r.oom { "  ** OOM **" } else { "" });
    println!("  padding       {:.3}%", r.padding_ratio * 100.0);
    println!("  MFU           {:.1}%", r.mfu * 100.0);
    Ok(())
}
