//! # veScale-FSDP (reproduction)
//!
//! A three-layer reproduction of *veScale-FSDP: Flexible and
//! High-Performance FSDP at Scale* (ByteDance Seed, 2026):
//!
//! * **L3 (this crate)** — the coordinator: RaggedShard placements, the
//!   structure-aware planner (Algorithm 1), DBuffer, the FSDP engine, the
//!   four baseline systems, optimizers (AdamW / SGD / 8-bit Adam / Muon),
//!   and a simulated multi-device cluster with real data movement plus an
//!   analytic fabric cost model.
//! * **L2** — `python/compile/model.py`: the transformer fwd/bwd,
//!   AOT-compiled to HLO artifacts; `runtime` executes them through PJRT
//!   when built with `--features pjrt`, and otherwise runs the built-in
//!   native Rust reference implementation of the same compute graph
//!   (`runtime::native`), so the full train path works with no Python
//!   and no artifacts.
//! * **L1** — `python/compile/kernels/`: Pallas kernels (block-wise quant,
//!   fused AdamW, Newton-Schulz, MXU-tiled matmul).
//!
//! ## The declarative spec API
//!
//! The user-facing surface is [`fsdp::spec`]: a `fully_shard`-style
//! [`fsdp::ModelSpec`] graph of [`fsdp::ShardGroupSpec`] wrap units, each
//! declaring its own sharding-granularity policy, optimizer binding
//! ([`fsdp::OptimBinding`] — so Muon matrices train next to AdamW
//! embeddings in one run), reshard-after-forward toggle, and optional
//! mesh/fabric override. [`fsdp::FsdpEngine::from_spec`] plans each group
//! with its group-local policy; `train::TrainSession::builder` replaces
//! the old 8-argument trainer constructor (the legacy
//! `Trainer::{new,with_backend,with_exec}` shims remain, bit-identical);
//! optimizers dispatch uniformly per group through
//! [`optim::GroupOptimizer`]. Config files deserialize `[group.*]`
//! sections straight into the spec, and `--fabric h800|h100|a100`
//! selects the cost model (recorded in `train::StepLog`).
//!
//! ## Execution model
//!
//! The `cluster` module is the SPMD execution layer: a [`cluster::Communicator`]
//! trait with two backends — `SerialComm` (single-thread loop collectives,
//! the reference semantics) and `ThreadedComm` (one OS thread per rank,
//! barrier-phased rendezvous collectives over shared buffers), assembled
//! through one [`cluster::CommBuilder`] (backend + topology + tracer +
//! observer + dispatch threshold). Every collective is described by a
//! typed [`cluster::CollectiveLaunch`] descriptor — op, group, element
//! count, wire precision, topology routing, sync/async mode, bucket/step
//! identity — that flows through a single pipeline: precision codec →
//! tier routing (flat / intra / inter / two-level, gated by
//! [`cluster::DEFAULT_HIER_THRESHOLD`] or `--hier-threshold`) → transport
//! → trace spans → obs heartbeats → wire-byte accounting. Collectives
//! come in blocking and nonblocking forms: `all_gather_async` /
//! `reduce_scatter_async` return a waitable [`cluster::PendingOp`] that the
//! threaded backend services on background comm threads (the serial
//! backend completes eagerly — results are bit-identical either way). The
//! FSDP engine, DBuffer, DTensor redistribution, and both trainers are
//! wired through the trait; `--backend serial|threaded` selects at run
//! time and the two produce bit-identical results (reductions preserve the
//! serial rank-order summation). Under the threaded backend, per-rank
//! fwd/bwd compute also fans out across threads via
//! `cluster::Cluster::run_spmd`. The static analyzer elaborates schedules
//! from the *same* descriptor type the runtime executes
//! (`analysis::ir::PlanModel::launch_for`), so lint verdicts and runtime
//! dispatch can never disagree on tiers or bytes.
//!
//! ## Step schedule
//!
//! The training step loop is driven by [`fsdp::exec`] — a `Schedule` over
//! the engine's FSDP buckets selected with `--prefetch N`. N = 0 is the
//! sequential loop (gather everything, compute monolithically, reduce
//! everything); N >= 1 is the paper's bucket-pipelined overlap schedule:
//! bucket l+1's AllGather prefetches under bucket l's forward compute (up
//! to N in flight), buckets reshard immediately after their forward and
//! re-gather in backward, and bucket l's ReduceScatter overlaps bucket
//! l-1's backward. Compute is driven layer-wise through the split native
//! fwd/bwd (`runtime::native::{embed,layer,head}_{fwd,bwd}` — the
//! monolithic `train_step` composes the same functions), and every
//! DBuffer's storage is accounted against a `memory::CachingAllocator`,
//! so peak reserved bytes and exposed-communication time are *measured*
//! per step (`fsdp::ExecReport`). Trajectories are bit-identical across
//! {serial, threaded} x {sequential, pipelined} x prefetch depth
//! (`tests/schedule_equivalence.rs`).
//!
//! Timing is split in two: wall-clock speedup comes from the threaded
//! runtime (see `benches/table3_backend_speedup.rs` and
//! `benches/overlap_pipeline.rs`, which also compares the measured
//! exposed-comm fraction against the `fsdp::sim` prediction), while the
//! paper's H800 fabric numbers come from the analytic `comm::cost::Fabric`
//! model, accumulated thread-safely in `comm::SharedStats`.
//!
//! ## Quantized communication
//!
//! The [`quant`] module is the block-wise quantized communication
//! subsystem (§6.3): a per-shard-group [`quant::CommPrecision`] wire
//! policy (`F32` | `Bf16` | `Q8 { block }`) declared on the spec /
//! builder / config / `--comm-precision`. `Q8` groups cast-before-comm
//! their parameter AllGathers to `{packed int8 codes, per-block f32
//! absmax scales}` (quant math bit-for-bit equal to
//! `python/compile/kernels/blockwise_quant.py` and `optim::adam8bit`) and
//! run their gradient ReduceScatter as an encoded all-to-all with
//! rank-ordered dequant-reduction plus **shard-held error-feedback
//! residuals**, so quantization error is re-injected the next step
//! instead of biasing training. Choosing `Q8` feeds the quant block into
//! the planner's granularity (lcm with the group's row granularity), so
//! every quant block and its scale live entirely on one device — the
//! paper's structure-aware planning put to work on the wire. True wire
//! bytes (payload vs scale vs packing pad) are measured into
//! `comm::CommRecord`/`train::StepLog` and priced identically by the
//! `fsdp::sim` cost model; `benches/fig12_quant_comm.rs` compares F32 /
//! Bf16 / Q8 wire volume and wall-clock across rank counts
//! (`BENCH_quant.json`). `F32` bypasses the subsystem entirely —
//! bit-identical to the pre-quantization engine (`tests/quant_comm.rs`).
//!
//! ## Observability
//!
//! The [`trace`] module is the always-compiled tracing + metrics layer:
//! a per-rank [`trace::Tracer`] threaded through the executor, both
//! communicator backends, the DBuffer gather/reduce paths, the quant
//! codecs, and the per-group optimizer steps. `--trace out.json
//! [--trace-level off|comm|full]` exports the merged rank-ordered spans
//! as Chrome trace-event JSON (one pid per rank plus a `fabric` pid,
//! compute vs comm lanes as tids — open in Perfetto) with allocator and
//! wire-byte counter tracks, plus a [`trace::TraceSummary`]: per-bucket
//! exposed-comm attribution, overlap efficiency (hidden/total comm),
//! per-rank skew, and measured-vs-`fsdp::sim` time per collective.
//! `ExecReport::exposed_comm_s` is *derived from* the exposed spans
//! (one clock, one sink — the accounting cannot drift from the trace),
//! and with `--trace-level off` each site reduces to the same
//! `Instant` pair the old ad-hoc timers paid, so disabled tracing
//! changes neither math (bit-identical losses) nor, materially,
//! wall-clock (`tests/trace_validity.rs`).
//!
//! ## Runtime health
//!
//! Where [`trace`] explains runs after the fact, the [`obs`] module
//! watches them live: rank threads publish lock-free heartbeats into a
//! shared [`obs::HealthBoard`]; a collective watchdog (`--watchdog-ms`)
//! reports ranks stalled in a rendezvous as typed `FS204` diagnostics
//! naming the rank, collective, and bucket; a bounded per-rank flight
//! recorder dumps the last events per rank as a structured postmortem
//! JSON on panic, watchdog firing, or `--postmortem-on-exit`; and an
//! [`obs::MetricsRegistry`] exports per-step step-time / exposed-comm /
//! overlap / wire-byte / peak-memory series as Prometheus text or JSON
//! (`--metrics out.prom|out.json`), with a rolling-window anomaly pass
//! and the `fsdp-report` bin as a CI regression gate. Disarmed (the
//! default), the observer costs one branch per event and training is
//! bit-identical to monitor-on (`tests/health_monitor.rs`).

pub mod analysis;
pub mod checkpoint;
pub mod cluster;
pub mod comm;
pub mod baselines;
pub mod config;
pub mod memory;
pub mod dbuffer;
pub mod dtensor;
pub mod fsdp;
pub mod mesh;
pub mod obs;
pub mod optim;
pub mod placement;
pub mod planner;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod trace;
pub mod train;
pub mod util;
