//! # veScale-FSDP (reproduction)
//!
//! A three-layer reproduction of *veScale-FSDP: Flexible and
//! High-Performance FSDP at Scale* (ByteDance Seed, 2026):
//!
//! * **L3 (this crate)** — the coordinator: RaggedShard placements, the
//!   structure-aware planner (Algorithm 1), DBuffer, the FSDP engine, the
//!   four baseline systems, optimizers (AdamW / SGD / 8-bit Adam / Muon),
//!   a simulated multi-device cluster with real data movement plus an
//!   analytic fabric cost model, and a PJRT runtime that executes the
//!   AOT-compiled JAX/Pallas compute.
//! * **L2** — `python/compile/model.py`: the transformer fwd/bwd.
//! * **L1** — `python/compile/kernels/`: Pallas kernels (block-wise quant,
//!   fused AdamW, Newton-Schulz, MXU-tiled matmul).
//!
//! Python runs once at build time (`make artifacts`); the request path is
//! pure Rust + PJRT.

pub mod checkpoint;
pub mod comm;
pub mod baselines;
pub mod config;
pub mod memory;
pub mod dbuffer;
pub mod dtensor;
pub mod fsdp;
pub mod mesh;
pub mod optim;
pub mod placement;
pub mod planner;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
