//! The four baseline FSDP systems of the paper's evaluation, plus veScale
//! itself, expressed as [`SystemBehavior`]s for the symbolic engine. Each
//! behavior encodes the *mechanism* the paper attributes to that system
//! (§2.3, §6.1) — the performance and memory gaps then emerge from the
//! shared simulator rather than being asserted.

use crate::config::System;
use crate::fsdp::sim::{ShardingFormat, SystemBehavior};
use crate::memory::FreePolicy;
use crate::quant::CommPrecision;

/// DeepSpeed ZeRO-3: element-wise concatenated shards, fragmented
/// per-parameter AllGathers (issue #5047), unaligned buffers,
/// record_stream frees.
pub fn deepspeed() -> SystemBehavior {
    SystemBehavior {
        name: "DeepSpeed",
        format: ShardingFormat::ElementWiseConcat,
        aligned: false,
        per_param_collectives: true,
        copy_in_out: false,
        copy_blocks_comm: false,
        free_policy: FreePolicy::RecordStream,
        batched_alloc: false,
        persist_lp_buffers: false,
        granularity: 1,
        comm_precision: CommPrecision::Bf16,
    }
}

/// PyTorch FSDP1: FlatParameter (element-wise concat), bucketed
/// collectives but copies that block NCCL progress (communication
/// bubbles), unaligned buffers, record_stream frees.
pub fn fsdp1() -> SystemBehavior {
    SystemBehavior {
        name: "FSDP1",
        format: ShardingFormat::ElementWiseConcat,
        aligned: false,
        per_param_collectives: false,
        copy_in_out: false,
        copy_blocks_comm: true,
        free_policy: FreePolicy::RecordStream,
        batched_alloc: false,
        persist_lp_buffers: false,
        granularity: 1,
        comm_precision: CommPrecision::Bf16,
    }
}

/// PyTorch FSDP2 (fully_shard): per-parameter Shard(0) DTensors —
/// interleaved Copy-Out after AllGather and Copy-In before ReduceScatter
/// (Fig 2 / Table 1), per-parameter even-split padding, eager per-param
/// allocation, unaligned buffers; deterministic frees (its improvement
/// over FSDP1).
pub fn fsdp2() -> SystemBehavior {
    SystemBehavior {
        name: "FSDP2",
        format: ShardingFormat::PerParamShard0,
        aligned: false,
        per_param_collectives: false,
        copy_in_out: true,
        copy_blocks_comm: false,
        free_policy: FreePolicy::Deterministic,
        batched_alloc: false,
        persist_lp_buffers: false,
        granularity: 1,
        comm_precision: CommPrecision::Bf16,
    }
}

/// Megatron-FSDP: zero-copy concatenated buffer, but row-padding so shards
/// land on tensor-row boundaries (Shard(0)-compatible checkpointing) —
/// padding inflates memory and communication (33% on fused-expert MoE);
/// persists low-precision buffers (+24% memory on LLaMA-3).
pub fn megatron() -> SystemBehavior {
    SystemBehavior {
        name: "Megatron-FSDP",
        format: ShardingFormat::ConcatPadRows,
        aligned: true,
        per_param_collectives: false,
        copy_in_out: false,
        copy_blocks_comm: false,
        free_policy: FreePolicy::Deterministic,
        batched_alloc: true,
        persist_lp_buffers: true,
        granularity: 1,
        comm_precision: CommPrecision::Bf16,
    }
}

/// veScale-FSDP: planner-laid-out RaggedShard buckets, aligned zero-copy
/// DBuffer collectives, batched deterministic allocation. `granularity`
/// is the RaggedShard block size (1 = element-wise, the §6 default).
pub fn vescale(granularity: u64) -> SystemBehavior {
    SystemBehavior {
        name: "veScale-FSDP",
        format: ShardingFormat::Planned,
        aligned: true,
        per_param_collectives: false,
        copy_in_out: false,
        copy_blocks_comm: false,
        free_policy: FreePolicy::Deterministic,
        batched_alloc: true,
        persist_lp_buffers: false,
        granularity,
        comm_precision: CommPrecision::Bf16,
    }
}

/// veScale with a quantized (or full-precision) wire: the §6.3
/// block-wise-quantized-communication scenario the `quant/` subsystem
/// executes numerically; the simulator prices its comm with the same
/// payload + scale + pad arithmetic the engine measures.
pub fn vescale_with_precision(granularity: u64, prec: CommPrecision) -> SystemBehavior {
    SystemBehavior { comm_precision: prec, ..vescale(granularity) }
}

/// Ablations for Table 2.
pub fn vescale_no_dbuffer(granularity: u64) -> SystemBehavior {
    SystemBehavior {
        name: "veScale w/o DBuffer",
        copy_in_out: true,     // falls back to copy-in/out around collectives
        batched_alloc: false,  // and per-buffer eager allocation
        ..vescale(granularity)
    }
}

pub fn vescale_no_planner(granularity: u64) -> SystemBehavior {
    SystemBehavior {
        name: "veScale w/o Planner",
        // naive concatenation: element-wise boundaries that split quant
        // blocks -> DTensor redistribution to reassemble optimizer state
        // (costed by the ablation bench), plus unaligned buffers
        format: ShardingFormat::ElementWiseConcat,
        aligned: false,
        ..vescale(granularity)
    }
}

pub fn behavior_for(system: System, granularity: u64) -> SystemBehavior {
    match system {
        System::VeScale => vescale(granularity),
        System::DeepSpeed => deepspeed(),
        System::Fsdp1 => fsdp1(),
        System::Fsdp2 => fsdp2(),
        System::MegatronFsdp => megatron(),
        System::Ddp => vescale(granularity), // DDP handled by the numeric engine
    }
}

pub fn all_baselines() -> Vec<SystemBehavior> {
    vec![deepspeed(), fsdp1(), fsdp2(), megatron()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn behaviors_are_distinct() {
        let names: Vec<&str> = all_baselines().iter().map(|b| b.name).collect();
        assert_eq!(names.len(), 4);
        assert!(deepspeed().per_param_collectives);
        assert!(!fsdp1().per_param_collectives);
        assert!(fsdp2().copy_in_out);
        assert!(megatron().persist_lp_buffers);
        assert!(vescale(1).aligned);
    }

    #[test]
    fn ablations_degrade_specific_axes() {
        let full = vescale(32);
        let no_db = vescale_no_dbuffer(32);
        let no_plan = vescale_no_planner(32);
        assert!(!full.copy_in_out && no_db.copy_in_out);
        assert_eq!(no_plan.format, ShardingFormat::ElementWiseConcat);
    }

    #[test]
    fn behavior_for_lookup() {
        assert_eq!(behavior_for(System::Fsdp2, 1).name, "FSDP2");
        assert_eq!(behavior_for(System::VeScale, 64).granularity, 64);
    }
}
