//! `fsdp-report`: the CI perf gate over metrics snapshots.
//!
//!     fsdp-report baseline.json current.json [--tolerance 0.1] [--list]
//!     fsdp-report --self-check file [file ...]
//!
//! Compares two `fsdp-metrics-v1` (or any numeric JSON, e.g. BENCH
//! snapshot) documents: every numeric leaf is flattened to a dotted
//! path (arrays of numbers collapse to their mean), and paths whose
//! names imply a direction are gated —
//!
//! * **lower is better**: names containing `time`, `seconds`, `_s`,
//!   `bytes`, `exposed`, or `skew` — flagged when current exceeds
//!   baseline by more than `--tolerance` (fraction, default 0.1);
//! * **higher is better**: names containing `efficiency`, `overlap`,
//!   `hidden`, or `throughput` — flagged when current undercuts
//!   baseline by more than the tolerance;
//! * everything else is informational.
//!
//! Exit code 0 = within tolerance, 1 = regression(s) found (each
//! printed as a `[FS206]` diagnostic), 2 = usage / IO / parse error.
//!
//! `--self-check` instead validates each file in place: `.prom` files
//! must be well-formed Prometheus text exposition with at least one
//! sample; anything else must parse as JSON with at least one numeric
//! leaf. Exit 0 = all valid, 2 = any invalid.

use std::process::ExitCode;

use vescale_fsdp::analysis::diag::{codes, rt};
use vescale_fsdp::util::args::Args;
use vescale_fsdp::util::json::Json;

fn main() -> ExitCode {
    let args = Args::from_env();
    if args.bool("self-check") {
        return self_check(&args.positional);
    }
    let [base_path, cur_path] = args.positional.as_slice() else {
        eprintln!("usage: fsdp-report <baseline.json> <current.json> [--tolerance 0.1]");
        eprintln!("       fsdp-report --self-check <file> [file ...]");
        return ExitCode::from(2);
    };
    let tolerance = args.f64_or("tolerance", 0.1);
    let (base, cur) = match (load_json(base_path), load_json(cur_path)) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return ExitCode::from(2);
        }
    };
    let report = compare(&base, &cur, tolerance);
    for line in &report.regressions {
        eprintln!("{line}");
    }
    if args.bool("list") {
        for (path, b, c) in &report.compared {
            println!("{path}: {b} -> {c}");
        }
    }
    println!(
        "fsdp-report: {} metrics compared ({} gated), {} regression(s) at {:.0}% tolerance",
        report.compared.len(),
        report.gated,
        report.regressions.len(),
        tolerance * 100.0
    );
    if report.regressions.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn load_json(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| rt(codes::EXPORT_IO, format!("reading {path}: {e}")))?;
    Json::parse(&text).map_err(|e| rt(codes::EXPORT_IO, format!("parsing {path}: {e}")))
}

struct Report {
    /// (path, baseline, current) for every shared numeric leaf.
    compared: Vec<(String, f64, f64)>,
    /// How many compared paths had a gating direction.
    gated: usize,
    /// One rendered `[FS206]` line per out-of-tolerance gated path.
    regressions: Vec<String>,
}

/// Direction a metric name implies: `Some(true)` = lower is better,
/// `Some(false)` = higher is better, `None` = informational only.
fn direction(path: &str) -> Option<bool> {
    let p = path.to_ascii_lowercase();
    let higher = ["efficiency", "overlap", "hidden", "throughput"];
    if higher.iter().any(|k| p.contains(k)) {
        return Some(false);
    }
    let lower = ["time", "seconds", "_s", "bytes", "exposed", "skew"];
    if lower.iter().any(|k| p.contains(k)) {
        return Some(true);
    }
    None
}

/// Flatten every numeric leaf of `j` into `out` as a dotted path.
/// Arrays of numbers collapse to their mean (a series' shape, not its
/// length, is what the gate cares about); bookkeeping keys that would
/// gate nonsense (`steps`, `bounds`, `counts`) are skipped.
fn flatten(j: &Json, prefix: &str, out: &mut Vec<(String, f64)>) {
    match j {
        Json::Num(n) => out.push((prefix.to_string(), *n)),
        Json::Arr(v) => {
            let nums: Vec<f64> = v.iter().filter_map(Json::as_f64).collect();
            if !nums.is_empty() && nums.len() == v.len() {
                out.push((prefix.to_string(), nums.iter().sum::<f64>() / nums.len() as f64));
            } else {
                for (i, x) in v.iter().enumerate() {
                    flatten(x, &format!("{prefix}.{i}"), out);
                }
            }
        }
        Json::Obj(m) => {
            for (k, v) in m {
                if matches!(k.as_str(), "steps" | "bounds" | "counts") {
                    continue;
                }
                let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten(v, &p, out);
            }
        }
        _ => {}
    }
}

fn compare(base: &Json, cur: &Json, tolerance: f64) -> Report {
    let mut b = Vec::new();
    let mut c = Vec::new();
    flatten(base, "", &mut b);
    flatten(cur, "", &mut c);
    let mut compared = Vec::new();
    let mut gated = 0;
    let mut regressions = Vec::new();
    for (path, bv) in &b {
        let Some((_, cv)) = c.iter().find(|(p, _)| p == path) else {
            continue;
        };
        compared.push((path.clone(), *bv, *cv));
        let Some(lower_is_better) = direction(path) else {
            continue;
        };
        gated += 1;
        // a zero baseline cannot anchor a relative gate
        if bv.abs() < 1e-12 {
            continue;
        }
        let rel = (cv - bv) / bv.abs();
        let bad = if lower_is_better { rel > tolerance } else { rel < -tolerance };
        if bad {
            regressions.push(rt(
                codes::METRIC_REGRESSION,
                format!(
                    "{path}: {cv:.6} vs baseline {bv:.6} ({:+.1}%, tolerance {:.0}%)",
                    rel * 100.0,
                    tolerance * 100.0
                ),
            ));
        }
    }
    Report { compared, gated, regressions }
}

// ---- --self-check -------------------------------------------------------

fn self_check(files: &[String]) -> ExitCode {
    if files.is_empty() {
        eprintln!("usage: fsdp-report --self-check <file> [file ...]");
        return ExitCode::from(2);
    }
    let mut ok = true;
    for path in files {
        match check_file(path) {
            Ok(desc) => println!("fsdp-report: {path}: ok ({desc})"),
            Err(e) => {
                eprintln!("fsdp-report: {path}: {e}");
                ok = false;
            }
        }
    }
    if ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(2)
    }
}

fn check_file(path: &str) -> Result<String, String> {
    if path.ends_with(".prom") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| rt(codes::EXPORT_IO, format!("reading: {e}")))?;
        let samples = check_prometheus(&text)?;
        Ok(format!("prometheus text, {samples} samples"))
    } else {
        let j = load_json(path)?;
        let mut leaves = Vec::new();
        flatten(&j, "", &mut leaves);
        if leaves.is_empty() {
            return Err(rt(codes::EXPORT_IO, "no numeric leaves".to_string()));
        }
        let schema = j.get("schema").and_then(Json::as_str).unwrap_or("untyped json");
        Ok(format!("{schema}, {} numeric leaves", leaves.len()))
    }
}

/// Validate Prometheus text exposition: every non-comment line must be
/// `name[{labels}] value` with a finite numeric value. Returns the
/// sample count (must be >= 1).
fn check_prometheus(text: &str) -> Result<usize, String> {
    let mut samples = 0;
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((name, value)) = line.rsplit_once(' ') else {
            return Err(format!("line {}: expected 'name value'", ln + 1));
        };
        if name.is_empty() || name.starts_with(|c: char| c.is_ascii_digit()) {
            return Err(format!("line {}: bad metric name '{name}'", ln + 1));
        }
        let v: f64 = value
            .parse()
            .map_err(|_| format!("line {}: bad sample value '{value}'", ln + 1))?;
        if !v.is_finite() {
            return Err(format!("line {}: non-finite sample", ln + 1));
        }
        samples += 1;
    }
    if samples == 0 {
        return Err("no samples".to_string());
    }
    Ok(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(step_time: f64, overlap: f64) -> Json {
        Json::obj(vec![
            ("schema", Json::str("fsdp-metrics-v1")),
            ("counters", Json::obj(vec![("wire.bytes", Json::num(1024))])),
            (
                "series",
                Json::obj(vec![
                    (
                        "step_time_s",
                        Json::obj(vec![
                            ("steps", Json::arr(vec![Json::num(1), Json::num(2)])),
                            (
                                "values",
                                Json::arr(vec![Json::num(step_time), Json::num(step_time)]),
                            ),
                        ]),
                    ),
                    (
                        "overlap_efficiency",
                        Json::obj(vec![
                            ("steps", Json::arr(vec![Json::num(1), Json::num(2)])),
                            ("values", Json::arr(vec![Json::num(overlap), Json::num(overlap)])),
                        ]),
                    ),
                ]),
            ),
        ])
    }

    #[test]
    fn identical_snapshots_pass() {
        let a = metrics(0.01, 0.9);
        let r = compare(&a, &a, 0.05);
        assert!(r.regressions.is_empty(), "{:?}", r.regressions);
        assert!(r.gated >= 3); // step_time, overlap, wire.bytes
    }

    #[test]
    fn slower_steps_and_lost_overlap_are_regressions() {
        let base = metrics(0.01, 0.9);
        let cur = metrics(0.02, 0.5);
        let r = compare(&base, &cur, 0.1);
        assert_eq!(r.regressions.len(), 2, "{:?}", r.regressions);
        assert!(r.regressions.iter().all(|m| m.contains(codes::METRIC_REGRESSION)));
        assert!(r.regressions.iter().any(|m| m.contains("step_time_s")));
        assert!(r.regressions.iter().any(|m| m.contains("overlap_efficiency")));
    }

    #[test]
    fn improvements_never_flag() {
        let base = metrics(0.01, 0.5);
        let cur = metrics(0.002, 0.95);
        assert!(compare(&base, &cur, 0.1).regressions.is_empty());
    }

    #[test]
    fn direction_table() {
        assert_eq!(direction("series.step_time_s.values"), Some(true));
        assert_eq!(direction("counters.wire.bytes"), Some(true));
        assert_eq!(direction("series.overlap_efficiency.values"), Some(false));
        assert_eq!(direction("health.ranks"), None);
    }

    #[test]
    fn flatten_skips_bookkeeping_and_means_arrays() {
        let j = metrics(0.01, 0.9);
        let mut out = Vec::new();
        flatten(&j, "", &mut out);
        assert!(out.iter().all(|(p, _)| !p.contains("steps")));
        let st = out.iter().find(|(p, _)| p == "series.step_time_s.values").unwrap();
        assert!((st.1 - 0.01).abs() < 1e-12);
    }

    #[test]
    fn prometheus_checker() {
        let good = "# HELP x y\n# TYPE x counter\nfsdp_x_total 12\nfsdp_b{le=\"0.1\"} 3\n";
        assert_eq!(check_prometheus(good), Ok(2));
        assert!(check_prometheus("").is_err());
        assert!(check_prometheus("just words with no numeric tail at all?").is_err());
        assert!(check_prometheus("name nan_is_fine nan").is_err());
    }
}
