//! Chrome-trace validator CLI (CI gate for `--trace` output).
//!
//!     trace-check trace_a.json trace_b.json ...
//!
//! Each file must parse as JSON and pass `trace::check::validate`:
//! non-empty `traceEvents`, bucket + byte attribution on collective
//! spans, and strict per-lane span nesting. Exits non-zero if any file
//! fails, printing one line per file.

use std::process::ExitCode;

use vescale_fsdp::trace::check::validate;
use vescale_fsdp::util::json::Json;

fn check_file(path: &str) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read failed: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("JSON parse failed: {e}"))?;
    validate(&doc)?;
    let n = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .map(|a| a.len())
        .unwrap_or(0);
    println!("ok: {path} ({n} events)");
    Ok(())
}

fn main() -> ExitCode {
    let files: Vec<String> = std::env::args().skip(1).collect();
    if files.is_empty() {
        eprintln!("usage: trace-check <trace.json> [more.json ...]");
        return ExitCode::from(2);
    }
    let mut failed = false;
    for path in &files {
        if let Err(e) = check_file(path) {
            eprintln!("FAIL: {path}: {e}");
            failed = true;
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
