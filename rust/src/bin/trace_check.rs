//! Chrome-trace validator CLI (CI gate for `--trace` output).
//!
//!     trace-check [--json out.json] trace_a.json trace_b.json ...
//!
//! Each file must parse as JSON and pass `trace::check::diagnostics`:
//! non-empty `traceEvents`, bucket + byte attribution on collective
//! spans, and strict per-lane span nesting. Findings print one line per
//! diagnostic (`FS2xx` codes from the shared `analysis::diag` catalog);
//! `--json` additionally writes all findings to a machine-readable
//! artifact. Exit code: 0 all clean, 1 diagnostics found, 2 usage error.

use std::process::ExitCode;

use vescale_fsdp::analysis::diag::{self, Diagnostic};
use vescale_fsdp::trace::check::diagnostics;
use vescale_fsdp::util::json::Json;

fn check_file(path: &str) -> Result<Vec<Diagnostic>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read failed: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("JSON parse failed: {e}"))?;
    let ds = diagnostics(&doc);
    if ds.is_empty() {
        let n = doc
            .get("traceEvents")
            .and_then(|e| e.as_arr())
            .map(|a| a.len())
            .unwrap_or(0);
        println!("ok: {path} ({n} events)");
    }
    Ok(ds)
}

fn main() -> ExitCode {
    let mut json_out: Option<String> = None;
    let mut files: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_out = Some(p),
                None => {
                    eprintln!("error: --json requires an output path");
                    return ExitCode::from(2);
                }
            },
            other => files.push(other.to_string()),
        }
    }
    if files.is_empty() {
        eprintln!("usage: trace-check [--json out.json] <trace.json> [more.json ...]");
        return ExitCode::from(2);
    }

    let mut all: Vec<Diagnostic> = Vec::new();
    let mut io_failed = false;
    for path in &files {
        match check_file(path) {
            Ok(ds) => {
                for d in &ds {
                    eprintln!("FAIL: {path}: {d}");
                }
                all.extend(ds);
            }
            Err(e) => {
                eprintln!("FAIL: {path}: {e}");
                io_failed = true;
            }
        }
    }

    if let Some(out) = &json_out {
        let doc = diag::to_json(&all);
        if let Err(e) = std::fs::write(out, doc.to_string()) {
            eprintln!("error: failed to write {out}: {e}");
            return ExitCode::from(2);
        }
        println!("wrote {} diagnostics to {out}", all.len());
    }

    if io_failed || !all.is_empty() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
