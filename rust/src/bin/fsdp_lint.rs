//! fsdp-lint: static plan & protocol verifier.
//!
//!     fsdp-lint --preset llama70b [--devices 8] [--replicas 1]
//!               [--prefetch N] [--backend serial|threaded]
//!               [--topology HxG[:S]] [--comm-precision f32|bf16|q8[:block]]
//!               [--mem-limit BYTES] [--json out.json]
//!     fsdp-lint --model tiny   (same flags; lints a trainable manifest
//!                               config through `SessionBuilder::analyze`,
//!                               wrap-ABI check included)
//!     fsdp-lint --matrix [--json out.json]
//!               (every shipped preset x backend x exec x precision x
//!                topology combo; the CI `plan-lint` job runs this)
//!     fsdp-lint --scan DIR     (FS012 comm-encapsulation source scan:
//!                               flags backend construction or codec
//!                               calls outside the `cluster/` pipeline)
//!     fsdp-lint --codes        (print the diagnostic-code catalog)
//!
//! Elaborates the full per-rank FSDP schedule — gathers, computes,
//! reductions, reshards, allocator claims — into the `analysis` IR
//! without running any compute, then checks SPMD conformance, async
//! handle discipline, allocator lifetime balance, quant-block layout,
//! and hierarchical-dispatch preconditions. Plan flags accept
//! `--hier-threshold ELEMS` so the lint models the same dispatch
//! threshold an overridden runtime would use. Exit code: 0 clean,
//! 1 diagnostics found, 2 usage error.

use std::path::Path;
use std::process::ExitCode;

use vescale_fsdp::analysis::diag::{self, codes, Diagnostic};
use vescale_fsdp::analysis::{catalog, lint, AnalysisReport, LintRequest};
use vescale_fsdp::cluster::{CommBackend, DEFAULT_HIER_THRESHOLD};
use vescale_fsdp::comm::Topology;
use vescale_fsdp::config::presets;
use vescale_fsdp::fsdp::{ExecMode, DEVICE_MEM_LIMIT};
use vescale_fsdp::quant::CommPrecision;
use vescale_fsdp::train::TrainSession;
use vescale_fsdp::util::args::Args;
use vescale_fsdp::util::json::Json;

fn usage() -> ExitCode {
    eprintln!(
        "usage: fsdp-lint (--preset NAME | --model NAME | --matrix | --scan DIR | --codes)\n\
         \x20      [--devices M] [--replicas R] [--prefetch N]\n\
         \x20      [--backend serial|threaded] [--topology HxG[:S]]\n\
         \x20      [--comm-precision f32|bf16|q8[:block]] [--mem-limit BYTES]\n\
         \x20      [--hier-threshold ELEMS] [--json out.json]"
    );
    ExitCode::from(2)
}

fn print_report(r: &AnalysisReport) {
    println!(
        "lint: {} devices={} replicas={} backend={} exec={} topology={} — \
         {} collectives/rank, peak bound {:.2} MB reserved",
        r.model,
        r.devices,
        r.replicas,
        r.backend,
        r.exec,
        r.topology,
        r.collectives_per_rank,
        r.peak_reserved_bound as f64 / 1e6
    );
    for d in &r.diagnostics {
        println!("  {d}");
    }
    if r.diagnostics.is_empty() {
        println!("  clean");
    }
}

/// Lint one raw preset (no manifest/runtime needed): the preset's wrap
/// units become the spec, the uniform wire precision is applied to every
/// group, and the wrap-ABI check stays disabled (`native_layers: None` —
/// presets are planning artifacts, not trainable configs).
#[allow(clippy::too_many_arguments)]
fn lint_preset(
    name: &str,
    devices: usize,
    replicas: usize,
    backend: CommBackend,
    exec: ExecMode,
    topology: Topology,
    prec: CommPrecision,
    mem_limit: u64,
    hier_threshold: usize,
) -> Option<AnalysisReport> {
    let preset = presets::by_name(name)?;
    let params = preset.param_table();
    let mut spec = preset.shard_spec();
    for g in spec.groups.iter_mut() {
        g.comm_precision = prec;
    }
    Some(lint(&LintRequest {
        model: name,
        params: &params,
        spec: &spec,
        devices,
        replicas,
        backend,
        exec,
        topology,
        hier_threshold,
        native_layers: None,
        mem_limit,
    }))
}

// ---- FS012: comm-encapsulation source scan ------------------------------

/// Tokens whose appearance outside `cluster/` means a call site bypasses
/// the launch pipeline. Assembled with `concat!` so this scanner's own
/// source never matches itself. The codec primitives are additionally
/// legal inside `quant/`, where they are defined.
const BACKEND_TOKENS: [&str; 2] =
    [concat!("Serial", "Comm::"), concat!("Threaded", "Comm::")];
const CODEC_TOKENS: [&str; 4] = [
    concat!("encode_", "slot("),
    concat!("decode_", "slot("),
    concat!("rs_inject_", "and_encode("),
    concat!("rs_decode_", "reduce("),
];

/// Is this path inside a directory named `dir` (e.g. `cluster`, `quant`)?
fn under_dir(path: &Path, dir: &str) -> bool {
    path.components().any(|c| c.as_os_str() == dir)
}

/// Scan one source file for FS012 violations. Lines from the first
/// `#[cfg(test)]` marker on are exempt (tests may drive backends
/// directly), as are comment lines (docs may *name* the internals).
fn scan_file(path: &Path, diags: &mut Vec<Diagnostic>) {
    let Ok(text) = std::fs::read_to_string(path) else {
        return;
    };
    let in_cluster = under_dir(path, "cluster");
    let in_quant = under_dir(path, "quant");
    if in_cluster {
        return;
    }
    for (i, line) in text.lines().enumerate() {
        let t = line.trim_start();
        if t.starts_with("#[cfg(test)]") {
            break;
        }
        if t.starts_with("//") {
            continue;
        }
        let mut flag = |token: &str, what: &str| {
            diags.push(Diagnostic::error(
                codes::COMM_ENCAPSULATION,
                format!("{}:{}", path.display(), i + 1),
                format!(
                    "{what} `{token}` outside cluster/ — route through \
                     CommBuilder / the CollectiveLaunch pipeline stages"
                ),
            ));
        };
        for token in BACKEND_TOKENS {
            if t.contains(token) {
                flag(token, "direct backend construction");
            }
        }
        if !in_quant {
            for token in CODEC_TOKENS {
                if t.contains(token) {
                    flag(token, "raw codec call");
                }
            }
        }
    }
}

/// Recursively scan `dir` for `.rs` sources violating the comm-stack
/// encapsulation boundary (FS012).
fn scan_tree(dir: &Path, diags: &mut Vec<Diagnostic>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let mut paths: Vec<_> = entries.filter_map(Result::ok).map(|e| e.path()).collect();
    paths.sort();
    for path in paths {
        if path.is_dir() {
            scan_tree(&path, diags);
        } else if path.extension().is_some_and(|e| e == "rs") {
            scan_file(&path, diags);
        }
    }
}

fn run_scan(root: &str, json_out: Option<&str>) -> ExitCode {
    let root = Path::new(root);
    if !root.exists() {
        eprintln!("error: scan root '{}' does not exist", root.display());
        return ExitCode::from(2);
    }
    let mut diags = Vec::new();
    scan_tree(root, &mut diags);
    for d in &diags {
        println!("{d}");
    }
    println!("scan: {} — {} encapsulation finding(s)", root.display(), diags.len());
    if let Some(out) = json_out {
        if let Err(e) = std::fs::write(out, diag::to_json(&diags).to_string()) {
            eprintln!("error: failed to write {out}: {e}");
            return ExitCode::from(2);
        }
    }
    if diags.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Mesh size for one matrix entry: the smallest power-of-two device
/// count (>= 8) whose persistent per-rank footprint — param + grad
/// shards, 8 bytes per parameter spread over the mesh — stays within a
/// quarter of the simulated device budget, leaving the rest for
/// transient gather/staging buffers. Mirrors how the presets deploy in
/// practice: a 2.4T model never runs on an 8-GPU mesh.
fn matrix_devices(total_params: u64) -> usize {
    let mut devices = 8usize;
    while total_params.saturating_mul(8) / devices as u64 > DEVICE_MEM_LIMIT / 4 {
        devices *= 2;
    }
    devices
}

/// The shipped combo matrix the CI `plan-lint` job sweeps. The mesh is
/// sized to the preset by [`matrix_devices`], and sequential mode —
/// which gathers every bucket at once regardless of mesh size — is
/// linted only where the full parameters fit half the device budget;
/// each skip is reported, never silent.
fn run_matrix(json_out: Option<&str>) -> ExitCode {
    const PRESETS: [&str; 9] = [
        "tiny", "small", "llama70b", "gptoss120b", "dsv3_671b", "moe400b", "moe800b",
        "moe1200b", "moe2400b",
    ];
    const BACKENDS: [CommBackend; 2] = [CommBackend::Serial, CommBackend::Threaded];
    const PRECS: [&str; 3] = ["f32", "bf16", "q8"];

    let mut rows: Vec<Json> = Vec::new();
    let mut combos = 0usize;
    let mut dirty = 0usize;
    let mut skipped_seq = 0usize;
    for preset_name in PRESETS {
        let Some(preset) = presets::by_name(preset_name) else {
            eprintln!("error: preset '{preset_name}' disappeared from the registry");
            return ExitCode::from(2);
        };
        let devices = matrix_devices(preset.total_params());
        let topos: [(String, Topology); 2] = [
            ("flat".to_string(), Topology::flat()),
            (
                format!("{}x4:2", devices / 4),
                Topology { hosts: devices / 4, gpus_per_host: 4, segments: 2 },
            ),
        ];
        // full-gather footprint of the sequential schedule (all buckets
        // resident at once) vs the simulated per-device budget
        let full_bytes = preset.total_params().saturating_mul(4);
        let seq_fits = full_bytes < DEVICE_MEM_LIMIT / 2;
        if !seq_fits {
            skipped_seq += 1;
            println!(
                "skip: {preset_name} sequential (full gather {:.1} GB exceeds the \
                 {:.0} GB device budget; pipelined combos still linted)",
                full_bytes as f64 / 1e9,
                DEVICE_MEM_LIMIT as f64 / 1e9
            );
        }
        for backend in BACKENDS {
            for prefetch in [0usize, 2] {
                if prefetch == 0 && !seq_fits {
                    continue;
                }
                let exec = ExecMode::from_prefetch(prefetch);
                for prec_name in PRECS {
                    let prec = CommPrecision::parse(prec_name).expect("shipped precision");
                    for (topo_name, topo) in &topos {
                        let Some(report) = lint_preset(
                            preset_name,
                            devices,
                            1,
                            backend,
                            exec,
                            *topo,
                            prec,
                            DEVICE_MEM_LIMIT,
                            DEFAULT_HIER_THRESHOLD,
                        ) else {
                            return ExitCode::from(2);
                        };
                        combos += 1;
                        let clean = report.diagnostics.is_empty();
                        if !clean {
                            dirty += 1;
                            println!(
                                "DIRTY: {preset_name} devices={devices} backend={} \
                                 exec={} prec={prec_name} topo={topo_name}",
                                backend.name(),
                                exec.name()
                            );
                            for d in &report.diagnostics {
                                println!("  {d}");
                            }
                        }
                        rows.push(report.json());
                    }
                }
            }
        }
    }
    println!(
        "matrix: {combos} combos linted, {dirty} with diagnostics, \
         {skipped_seq} sequential presets skipped"
    );
    if let Some(out) = json_out {
        let doc = Json::obj(vec![
            ("combos", Json::num(combos as f64)),
            ("dirty", Json::num(dirty as f64)),
            ("reports", Json::Arr(rows)),
        ]);
        if let Err(e) = std::fs::write(out, doc.to_string()) {
            eprintln!("error: failed to write {out}: {e}");
            return ExitCode::from(2);
        }
        println!("wrote {combos} reports to {out}");
    }
    if dirty > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

fn main() -> ExitCode {
    let args = Args::from_env();
    if args.bool("codes") {
        println!("{:<6} title", "code");
        for (code, title) in catalog() {
            println!("{code:<6} {title}");
        }
        return ExitCode::SUCCESS;
    }
    let json_out = args.get("json").map(str::to_string);
    if let Some(root) = args.get("scan") {
        return run_scan(root, json_out.as_deref());
    }
    if args.bool("matrix") {
        return run_matrix(json_out.as_deref());
    }

    let devices = args.usize_or("devices", 8);
    let replicas = args.usize_or("replicas", 1);
    let exec = ExecMode::from_prefetch(args.usize_or("prefetch", 0));
    let backend = match args.get("backend") {
        None => CommBackend::Serial,
        Some(s) => match CommBackend::parse(s) {
            Some(b) => b,
            None => {
                eprintln!("error: unknown --backend '{s}'");
                return usage();
            }
        },
    };
    let topology = match args.get("topology") {
        None => Topology::flat(),
        Some(t) => match Topology::parse(t) {
            Some(t) => t,
            None => {
                eprintln!("error: bad --topology '{t}' (expected HxG[:S])");
                return usage();
            }
        },
    };
    let prec_name = args.str_or("comm-precision", "f32");
    let Some(prec) = CommPrecision::parse(&prec_name) else {
        eprintln!("error: unknown --comm-precision '{prec_name}'");
        return usage();
    };
    let mem_limit = args.u64_or("mem-limit", DEVICE_MEM_LIMIT);
    let hier_threshold = args.usize_or("hier-threshold", DEFAULT_HIER_THRESHOLD);

    let report = if let Some(name) = args.get("preset") {
        match lint_preset(
            name,
            devices,
            replicas,
            backend,
            exec,
            topology,
            prec,
            mem_limit,
            hier_threshold,
        ) {
            Some(r) => r,
            None => {
                eprintln!("error: unknown preset '{name}'");
                return usage();
            }
        }
    } else if let Some(model) = args.get("model") {
        let mut fabric = vescale_fsdp::comm::Fabric::h800();
        fabric = fabric.with_topology(topology);
        match TrainSession::builder(model)
            .devices(devices)
            .replicas(replicas)
            .backend(backend)
            .exec(exec)
            .fabric(fabric)
            .comm_precision(prec)
            .hier_threshold(hier_threshold)
            .analyze()
        {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: {e:#}");
                return ExitCode::from(2);
            }
        }
    } else {
        return usage();
    };

    print_report(&report);
    if let Some(out) = &json_out {
        if let Err(e) = std::fs::write(out, report.json().to_string()) {
            eprintln!("error: failed to write {out}: {e}");
            return ExitCode::from(2);
        }
        println!("wrote report to {out}");
    }
    if report.diagnostics.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
