//! The checkable IR: a symbolic per-rank elaboration of one FSDP
//! training step.
//!
//! [`PlanModel`] mirrors exactly the planning the engine performs in
//! `FsdpEngine::from_spec` (same group assignment, same granularity
//! lcm's, same `planner::plan` call), and [`elaborate`] unrolls the
//! step schedule the executor would run — [`crate::fsdp::exec`]'s
//! sequential or bucket-pipelined loop — into typed [`Event`] streams:
//! collectives with (op, bucket, mesh, tier, bytes), compute slots, and
//! every allocator claim/free the DBuffer and staging paths would make,
//! in program order. No tensors are touched and no threads spawn; the
//! result is a finite object `analysis::checks` can verify exhaustively.
//!
//! Claim/free placement follows the runtime paths line by line:
//! construction claims each group's shard block then one batched
//! grad-shard segment; a gather claims the full buffer (plus an encoded
//! wire buffer for `Bf16`/`Q8`, freed at decode); a reduction claims the
//! staged full-size gradient buffer (plus a wire buffer on encoded
//! precisions) and frees both when the collective retires. The pipelined
//! elaboration retires in-flight reductions *lazily* (only when the
//! `prefetch` window overflows, never opportunistically), so its peak
//! derived by `checks::check_ledger` is an upper bound for both comm
//! backends.

use std::collections::VecDeque;

use crate::cluster::{CollectiveLaunch, CommBackend};
use crate::comm::Topology;
use crate::fsdp::spec::ModelSpec;
use crate::fsdp::ExecMode;
use crate::planner::{self, Layout, TensorDecl};
use crate::quant::CommPrecision;
use crate::util::lcm;

use super::diag::{codes, Diagnostic};

/// The collective vocabulary of the IR *is* the runtime's launch
/// vocabulary: the analyzer elaborates the same [`CollectiveLaunch`]
/// descriptor the backends execute, so op kinds, phases, and tier
/// routing are shared types that cannot drift. The `CollOp` / `Phase` /
/// `Tier` names are kept as aliases for the analysis-side dialect
/// (record-only ops such as the HSDP replica AllReduce never rendezvous
/// and so never appear in an elaborated stream).
pub use crate::cluster::launch::{LaunchOp as CollOp, LaunchPhase as Phase, LaunchTier as Tier};

/// Identity of one allocator claim, stable across ranks and steps so the
/// ledger can pair claims with frees and name leaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ClaimId {
    /// Bucket `b`'s persistent parameter-shard block.
    Shard(usize),
    /// Bucket `b`'s persistent gradient-shard block (batched segment).
    GradShard(usize),
    /// Bucket `b`'s transient full (gathered) buffer.
    Full(usize),
    /// Bucket `b`'s transient encoded gather wire buffer.
    Wire(usize),
    /// Bucket `b`'s transient staged-gradient buffer.
    Staged(usize),
    /// Bucket `b`'s transient encoded reduce wire buffer.
    RsWire(usize),
}

impl ClaimId {
    pub fn bucket(&self) -> usize {
        match self {
            ClaimId::Shard(b)
            | ClaimId::GradShard(b)
            | ClaimId::Full(b)
            | ClaimId::Wire(b)
            | ClaimId::Staged(b)
            | ClaimId::RsWire(b) => *b,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            ClaimId::Shard(_) => "shard",
            ClaimId::GradShard(_) => "grad-shard",
            ClaimId::Full(_) => "full",
            ClaimId::Wire(_) => "gather-wire",
            ClaimId::Staged(_) => "staged-grads",
            ClaimId::RsWire(_) => "reduce-wire",
        }
    }

    /// Claims that live for the whole session (made at construction).
    pub fn is_persistent(&self) -> bool {
        matches!(self, ClaimId::Shard(_) | ClaimId::GradShard(_))
    }
}

/// One collective in a rank's event stream. SPMD conformance compares
/// these tuples in order across ranks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CollEvent {
    pub op: CollOp,
    pub phase: Phase,
    pub bucket: usize,
    /// Logical wire bytes of the whole collective (payload + scales +
    /// packing pad, summed across ranks) — the executor's span bytes.
    pub bytes: u64,
    /// Label of the group-local mesh the collective runs on.
    pub mesh: String,
    pub tier: Tier,
}

/// One event in a rank's elaborated stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    Coll(CollEvent),
    /// A compute slot. `bucket: None` is the monolithic fwd/bwd (reads
    /// every gathered buffer); `Some(b)` reads bucket `b` only. Phases
    /// `fwd` / `bwd` / `fwd_bwd` require the buffer gathered; `optim`
    /// runs on shards and requires nothing.
    Compute {
        bucket: Option<usize>,
        phase: &'static str,
    },
    /// `CachingAllocator::alloc(bytes)`.
    Claim { id: ClaimId, bytes: u64 },
    /// `CachingAllocator::alloc_batch(sizes)` — one segment, no
    /// inter-claim fragmentation.
    ClaimBatch { ids: Vec<ClaimId>, sizes: Vec<u64> },
    /// `CachingAllocator::free` of a previous claim.
    Free { id: ClaimId },
    /// The bucket's full buffer is dropped back to shard-only residency.
    Reshard { bucket: usize },
}

/// One logical collective span the executor's tracer is expected to
/// record for this plan (name `ag`/`rs`, attr `phase`, bucket label,
/// wire bytes) — the static side of the trace cross-validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExpectedSpan {
    pub name: &'static str,
    pub phase: &'static str,
    /// Bucket (group) name, or `"*"` for the sequential all-bucket span.
    pub bucket: String,
    pub bytes: u64,
}

/// The elaborated program: one event stream per fsdp rank (construction
/// claims, one steady-state step, optimizer), the set of claims that
/// legitimately outlive the step, and one step's expected trace spans.
#[derive(Debug, Clone)]
pub struct Program {
    pub ranks: Vec<Vec<Event>>,
    pub persistent: Vec<ClaimId>,
    pub expected_spans: Vec<ExpectedSpan>,
}

impl Program {
    /// The (op, bucket, mesh, tier) collective sequence of one rank —
    /// the object SPMD conformance compares.
    pub fn collective_sequence(&self, rank: usize) -> Vec<&CollEvent> {
        self.ranks[rank]
            .iter()
            .filter_map(|e| match e {
                Event::Coll(c) => Some(c),
                _ => None,
            })
            .collect()
    }
}

/// One shard group's planned layout plus the spec choices that shape its
/// schedule (the static mirror of `fsdp::engine::Bucket`).
#[derive(Debug, Clone)]
pub struct GroupPlan {
    pub name: String,
    pub layout: Layout,
    pub comm_precision: CommPrecision,
    pub reshard_after_forward: bool,
    /// Group-local mesh label (collectives rendezvous per mesh).
    pub mesh: String,
    pub optim: &'static str,
}

impl GroupPlan {
    pub fn shard_elems(&self) -> u64 {
        self.layout.shard_size
    }

    pub fn shard_bytes(&self) -> u64 {
        self.layout.shard_size * 4
    }

    pub fn full_bytes(&self) -> u64 {
        self.layout.shard_size * self.layout.num_devices as u64 * 4
    }

    /// f32 words one rank's encoded shard occupies on the wire.
    pub fn wire_words(&self) -> usize {
        self.comm_precision.wire_words(self.layout.shard_size as usize)
    }

    /// The bytes-only launch descriptor for one collective on this group
    /// (topology and threshold are stamped by [`PlanModel::launch_for`],
    /// which routes tiers; byte accounting needs neither).
    fn describe(&self, op: CollOp) -> CollectiveLaunch {
        CollectiveLaunch::new(op, self.layout.num_devices, self.layout.shard_size as usize)
            .with_precision(self.comm_precision)
    }

    /// Transient wire-buffer bytes a gather or encoded reduce claims —
    /// the descriptor's allocator-claim accounting.
    pub fn wire_claim_bytes(&self) -> u64 {
        self.describe(CollOp::AllGather).wire_claim_bytes()
    }

    /// Logical wire bytes of one collective on this bucket — the
    /// descriptor's span-byte accounting, identical to the executor's
    /// `bucket_wire_bytes`.
    pub fn coll_bytes(&self) -> u64 {
        self.describe(CollOp::AllGather).collective_bytes()
    }
}

/// Everything the analyzer needs to elaborate a plan — the same inputs
/// `FsdpEngine::from_spec` + `fsdp::exec::run_step` would consume.
pub struct LintRequest<'a> {
    /// Model or preset name (for report labeling only).
    pub model: &'a str,
    /// The full parameter table, model order.
    pub params: &'a [(String, Vec<usize>)],
    pub spec: &'a ModelSpec,
    /// fsdp group size m.
    pub devices: usize,
    pub replicas: usize,
    pub backend: CommBackend,
    pub exec: ExecMode,
    pub topology: Topology,
    /// Serial-fallback / two-level eligibility threshold the runtime
    /// will dispatch with ([`crate::cluster::DEFAULT_HIER_THRESHOLD`]
    /// unless overridden via `[comm] hier_threshold` or
    /// `--hier-threshold`).
    pub hier_threshold: usize,
    /// `Some(n_layers)` when the plan will drive the native runtime's
    /// embed|layer|head ABI (enables the wrapping check); `None` for raw
    /// preset plans with no runtime binding.
    pub native_layers: Option<usize>,
    /// Device memory limit the ledger checks the peak bound against.
    pub mem_limit: u64,
}

/// The static mirror of a fully planned engine: per-group layouts plus
/// the session-level execution choices.
#[derive(Debug, Clone)]
pub struct PlanModel {
    pub model: String,
    /// fsdp group size m.
    pub devices: usize,
    pub replicas: usize,
    pub backend: CommBackend,
    pub exec: ExecMode,
    pub topology: Topology,
    /// Threshold runtime dispatch (and therefore tier modeling) uses.
    pub hier_threshold: usize,
    pub groups: Vec<GroupPlan>,
    /// Parameter index -> group index (the spec's wrap assignment).
    pub group_of: Vec<usize>,
    pub n_params: usize,
    pub native_layers: Option<usize>,
    pub mem_limit: u64,
}

impl PlanModel {
    /// Plan every shard group exactly the way `FsdpEngine::from_spec`
    /// would: same assignment, same granularity lcm with the group's
    /// wire precision, same `planner::plan` collective alignment. Any
    /// planning failure comes back as a typed diagnostic instead of an
    /// error, so `lint` can always produce a report.
    pub fn build(req: &LintRequest) -> Result<PlanModel, Diagnostic> {
        let m = req.devices;
        let group_of = req.spec.assign(req.params).map_err(|e| {
            Diagnostic::error(codes::LAYOUT_INVALID, req.model, format!("spec assignment failed: {e:#}"))
        })?;
        let session_mesh = mesh_label(req.replicas, m);
        let mut groups = Vec::with_capacity(req.spec.groups.len());
        for (b, g) in req.spec.groups.iter().enumerate() {
            let mesh = match &g.mesh {
                Some(gm) => {
                    if gm.dim_size("fsdp") != Some(m) {
                        return Err(Diagnostic::error(
                            codes::BAD_TOPOLOGY,
                            &g.name,
                            format!(
                                "group mesh fsdp dim {:?} must match the session's fsdp dim {m}",
                                gm.dim_size("fsdp")
                            ),
                        ));
                    }
                    gm.dim_names()
                        .iter()
                        .zip(gm.sizes())
                        .map(|(n, s)| format!("{n}{s}"))
                        .collect::<Vec<_>>()
                        .join("x")
                }
                None => session_mesh.clone(),
            };
            let prec_align = g.comm_precision.align_elems();
            let decls: Vec<TensorDecl> = (0..req.params.len())
                .filter(|&i| group_of[i] == b)
                .map(|i| {
                    let (name, shape) = &req.params[i];
                    let numel: u64 = shape.iter().map(|&s| s as u64).product();
                    let base = g.policy.granularity_of(name, shape).max(1);
                    let gran = lcm(base, prec_align).min(numel).max(1);
                    TensorDecl::new(name, numel, gran)
                })
                .collect();
            let layout = planner::plan(&decls, m, lcm(4, prec_align)).map_err(|e| {
                Diagnostic::error(
                    codes::LAYOUT_INVALID,
                    &g.name,
                    format!("planning shard group failed: {e:#}"),
                )
            })?;
            groups.push(GroupPlan {
                name: g.name.clone(),
                layout,
                comm_precision: g.comm_precision,
                reshard_after_forward: g.reshard_after_forward,
                mesh,
                optim: g.optim.name(),
            });
        }
        Ok(PlanModel {
            model: req.model.to_string(),
            devices: m,
            replicas: req.replicas,
            backend: req.backend,
            exec: req.exec,
            topology: req.topology,
            hier_threshold: req.hier_threshold,
            groups,
            group_of,
            n_params: req.params.len(),
            native_layers: req.native_layers,
            mem_limit: req.mem_limit,
        })
    }

    /// The full launch descriptor one collective on bucket `b`
    /// elaborates to — the identical [`CollectiveLaunch`] the runtime
    /// builds via `Communicator::describe`, with the session topology
    /// and dispatch threshold stamped. Every derived quantity the IR
    /// records (span bytes, tier, wire claims) is read off this value.
    pub fn launch_for(&self, op: CollOp, b: usize) -> CollectiveLaunch {
        self.groups[b]
            .describe(op)
            .on_topology(self.topology)
            .with_hier_threshold(self.hier_threshold)
    }

    fn coll(&self, op: CollOp, phase: Phase, b: usize) -> Event {
        let l = self.launch_for(op, b);
        // the serial backend is tierless but modelled identically — tier
        // only has to be rank-consistent, and fixtures perturb it to
        // model divergence
        Event::Coll(CollEvent {
            op,
            phase,
            bucket: b,
            bytes: l.collective_bytes(),
            mesh: self.groups[b].mesh.clone(),
            tier: l.tier(self.backend == CommBackend::Threaded),
        })
    }

    /// The op a gradient reduction uses on bucket `b`: the dense
    /// ReduceScatter for f32, the encoded all-to-all otherwise.
    fn reduce_op(&self, b: usize) -> CollOp {
        if self.groups[b].comm_precision.is_f32() {
            CollOp::ReduceScatter
        } else {
            CollOp::AllToAll
        }
    }
}

fn mesh_label(replicas: usize, m: usize) -> String {
    if replicas > 1 {
        format!("replica{replicas}xfsdp{m}")
    } else {
        format!("fsdp{m}")
    }
}

/// Elaborate one rank's template stream (construction + one step +
/// optimizer), then clone it per rank: the schedule is SPMD by
/// construction, so the template *is* every rank's stream. Defect
/// fixtures mutate individual ranks afterwards.
pub fn elaborate(pm: &PlanModel) -> Program {
    let nb = pm.groups.len();
    let mut ev: Vec<Event> = Vec::new();
    let mut persistent = Vec::new();

    // ---- construction: FsdpEngine::from_spec's claims ----
    for (b, g) in pm.groups.iter().enumerate() {
        ev.push(Event::ClaimBatch {
            ids: vec![ClaimId::Shard(b)],
            sizes: vec![g.shard_bytes().max(1)],
        });
        persistent.push(ClaimId::Shard(b));
    }
    if nb > 0 {
        let ids: Vec<ClaimId> = (0..nb).map(ClaimId::GradShard).collect();
        let sizes: Vec<u64> = pm.groups.iter().map(|g| g.shard_bytes().max(1)).collect();
        ev.push(Event::ClaimBatch { ids, sizes });
        persistent.extend((0..nb).map(ClaimId::GradShard));
    }

    // ---- one steady-state step ----
    match pm.exec {
        ExecMode::Sequential => elaborate_sequential(pm, &mut ev),
        ExecMode::Pipelined { prefetch } => {
            elaborate_pipelined(pm, prefetch.max(1), &mut ev)
        }
    }

    // ---- per-group optimizer step (shard-local, no allocator traffic) ----
    for b in 0..nb {
        ev.push(Event::Compute { bucket: Some(b), phase: "optim" });
    }

    let expected_spans = expected_spans(pm, &ev);
    Program {
        ranks: vec![ev; pm.devices],
        persistent,
        expected_spans,
    }
}

/// The sequential schedule (`fsdp::exec::run_sequential` +
/// `FsdpEngine::{gather_params, release_params, reduce_grads}`).
fn elaborate_sequential(pm: &PlanModel, ev: &mut Vec<Event>) {
    let nb = pm.groups.len();
    // gather_params: per bucket, blocking all_gather_params_prec
    for (b, g) in pm.groups.iter().enumerate() {
        ev.push(Event::Claim { id: ClaimId::Full(b), bytes: g.full_bytes().max(1) });
        if !g.comm_precision.is_f32() {
            ev.push(Event::Claim { id: ClaimId::Wire(b), bytes: g.wire_claim_bytes() });
        }
        ev.push(pm.coll(CollOp::AllGather, Phase::Sync, b));
        if !g.comm_precision.is_f32() {
            ev.push(Event::Free { id: ClaimId::Wire(b) });
        }
    }
    // monolithic fwd/bwd over every gathered bucket
    ev.push(Event::Compute { bucket: None, phase: "fwd_bwd" });
    // release_params before the reductions
    for b in 0..nb {
        ev.push(Event::Free { id: ClaimId::Full(b) });
        ev.push(Event::Reshard { bucket: b });
    }
    // reduce_grads: per bucket, stage -> blocking reduce -> unstage
    for (b, g) in pm.groups.iter().enumerate() {
        ev.push(Event::Claim {
            id: ClaimId::Staged(b),
            bytes: g.full_bytes().max(1),
        });
        if g.comm_precision.is_f32() {
            ev.push(pm.coll(CollOp::ReduceScatter, Phase::Sync, b));
        } else {
            ev.push(Event::Claim { id: ClaimId::RsWire(b), bytes: g.wire_claim_bytes() });
            ev.push(pm.coll(CollOp::AllToAll, Phase::Sync, b));
            ev.push(Event::Free { id: ClaimId::RsWire(b) });
        }
        ev.push(Event::Free { id: ClaimId::Staged(b) });
    }
}

/// The bucket-pipelined schedule (`fsdp::exec::run_pipelined`), with
/// in-flight reductions retired lazily (only when the window overflows)
/// so the derived peak upper-bounds both comm backends.
fn elaborate_pipelined(pm: &PlanModel, prefetch: usize, ev: &mut Vec<Event>) {
    let nb = pm.groups.len();
    let mut gathered = vec![false; nb];
    let mut inflight: VecDeque<usize> = VecDeque::new();

    let issue = |ev: &mut Vec<Event>,
                 inflight: &mut VecDeque<usize>,
                 order: &mut VecDeque<usize>| {
        while inflight.len() < prefetch {
            let Some(b) = order.pop_front() else { return };
            let g = &pm.groups[b];
            ev.push(Event::Claim { id: ClaimId::Full(b), bytes: g.full_bytes().max(1) });
            if !g.comm_precision.is_f32() {
                ev.push(Event::Claim { id: ClaimId::Wire(b), bytes: g.wire_claim_bytes() });
            }
            ev.push(pm.coll(CollOp::AllGather, Phase::Issue, b));
            inflight.push_back(b);
        }
    };
    let wait = |ev: &mut Vec<Event>,
                inflight: &mut VecDeque<usize>,
                gathered: &mut Vec<bool>,
                b: usize| {
        if gathered[b] {
            return;
        }
        while let Some(x) = inflight.pop_front() {
            ev.push(pm.coll(CollOp::AllGather, Phase::Wait, x));
            if !pm.groups[x].comm_precision.is_f32() {
                ev.push(Event::Free { id: ClaimId::Wire(x) });
            }
            gathered[x] = true;
            if x == b {
                return;
            }
        }
    };

    // ---- forward: prefetch AG(l+1..) under compute of bucket l ----
    let mut fwd_order: VecDeque<usize> = (0..nb).collect();
    for l in 0..nb {
        issue(ev, &mut inflight, &mut fwd_order);
        wait(ev, &mut inflight, &mut gathered, l);
        issue(ev, &mut inflight, &mut fwd_order);
        ev.push(Event::Compute { bucket: Some(l), phase: "fwd" });
        if pm.groups[l].reshard_after_forward {
            ev.push(Event::Free { id: ClaimId::Full(l) });
            ev.push(Event::Reshard { bucket: l });
            gathered[l] = false;
        }
    }

    // ---- backward: re-gather in reverse; RS overlaps earlier backward ----
    let mut bwd_order: VecDeque<usize> = (0..nb).rev().filter(|&b| !gathered[b]).collect();
    let mut rs_pending: VecDeque<usize> = VecDeque::new();
    let retire = |ev: &mut Vec<Event>, b: usize| {
        ev.push(pm.coll(pm.reduce_op(b), Phase::Wait, b));
        ev.push(Event::Free { id: ClaimId::Staged(b) });
        if !pm.groups[b].comm_precision.is_f32() {
            ev.push(Event::Free { id: ClaimId::RsWire(b) });
        }
    };
    for b in (0..nb).rev() {
        issue(ev, &mut inflight, &mut bwd_order);
        wait(ev, &mut inflight, &mut gathered, b);
        issue(ev, &mut inflight, &mut bwd_order);
        ev.push(Event::Compute { bucket: Some(b), phase: "bwd" });
        ev.push(Event::Free { id: ClaimId::Full(b) });
        ev.push(Event::Reshard { bucket: b });
        gathered[b] = false;
        // begin_reduce: stage, (encode + wire claim), issue
        let g = &pm.groups[b];
        ev.push(Event::Claim { id: ClaimId::Staged(b), bytes: g.full_bytes().max(1) });
        if !g.comm_precision.is_f32() {
            ev.push(Event::Claim { id: ClaimId::RsWire(b), bytes: g.wire_claim_bytes() });
        }
        ev.push(pm.coll(pm.reduce_op(b), Phase::Issue, b));
        rs_pending.push_back(b);
        while rs_pending.len() > prefetch {
            let x = rs_pending.pop_front().unwrap();
            retire(ev, x);
        }
    }
    while let Some(x) = rs_pending.pop_front() {
        retire(ev, x);
    }
}

/// Project the logical `ag`/`rs` spans the executor's tracer would
/// record for one step of this plan: the sequential schedule collapses
/// each direction to a single all-bucket span; the pipelined schedule
/// records per-bucket issue/wait spans in schedule order.
fn expected_spans(pm: &PlanModel, ev: &[Event]) -> Vec<ExpectedSpan> {
    match pm.exec {
        ExecMode::Sequential => {
            let total: u64 = pm.groups.iter().map(GroupPlan::coll_bytes).sum();
            vec![
                ExpectedSpan { name: "ag", phase: "sync", bucket: "*".into(), bytes: total },
                ExpectedSpan { name: "rs", phase: "sync", bucket: "*".into(), bytes: total },
            ]
        }
        ExecMode::Pipelined { .. } => ev
            .iter()
            .filter_map(|e| match e {
                Event::Coll(c) => Some(ExpectedSpan {
                    name: c.op.span_name(),
                    phase: c.phase.name(),
                    bucket: pm.groups[c.bucket].name.clone(),
                    bytes: c.bytes,
                }),
                _ => None,
            })
            .collect(),
    }
}
