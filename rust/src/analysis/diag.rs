//! Shared diagnostic vocabulary for the static analyzer, the trace
//! validator, and the runtime invariant checks.
//!
//! Every invariant the project enforces — statically in
//! [`crate::analysis::checks`], structurally in [`crate::trace::check`],
//! or dynamically via `bail!`/`debug_assert!` in the planner, the
//! DBuffer, and the executor — names one stable code from the `FS`
//! catalog below. A violation found by `fsdp-lint` before a run and the
//! panic message the runtime would have produced mid-run therefore point
//! at the same catalog entry, so CI logs, lint output, and trace-check
//! findings can be correlated mechanically.
//!
//! `FS0xx` codes are plan/protocol invariants; `FS2xx` codes are
//! structural properties of exported Chrome-trace documents and runtime
//! health findings from the [`crate::obs`] monitor (watchdog stalls,
//! counter-track violations, metric regressions, artifact I/O).

use std::fmt;

use crate::util::json::Json;

/// Stable diagnostic codes. Never renumber — tooling keys on them.
pub mod codes {
    /// Ranks disagree on the (op, bucket, mesh, tier) collective
    /// sequence — the barrier-phased rendezvous would deadlock.
    pub const SPMD_DIVERGENCE: &str = "FS001";
    /// Async-handle discipline: a collective handle issued twice, waited
    /// out of issue order, never issued, or never awaited.
    pub const HANDLE_DISCIPLINE: &str = "FS002";
    /// Allocator lifetime imbalance: a transient claim (gather buffer,
    /// staged grads, wire buffer) leaks past step end, is freed twice,
    /// or is released while its collective is still in flight.
    pub const LIFETIME_IMBALANCE: &str = "FS003";
    /// A quantization block (or its scale) straddles a device boundary,
    /// or the shard size breaks the planner's collective-alignment lcm.
    pub const QUANT_MISALIGNED: &str = "FS004";
    /// Hierarchical-dispatch precondition: `topology.total()` must equal
    /// the fsdp group size, and segment/host/GPU counts must be valid.
    pub const BAD_TOPOLOGY: &str = "FS005";
    /// Compute reads a gathered buffer before its AllGather completed.
    pub const READ_BEFORE_GATHER: &str = "FS006";
    /// A gradient ReduceScatter issued before that bucket's backward.
    pub const REDUCE_BEFORE_BACKWARD: &str = "FS007";
    /// Reshard-after-forward pairing violation: gather/reshard counts
    /// disagree with the group's `reshard_after_forward` choice, or a
    /// bucket is still gathered at step end.
    pub const RESHARD_UNPAIRED: &str = "FS008";
    /// The statically derived peak-reserved bound exceeds (or crowds)
    /// the device memory limit — the run would OOM.
    pub const PEAK_OVER_LIMIT: &str = "FS009";
    /// Pipelined-executor wrapping ABI mismatch (embed|layer|head).
    pub const WRAPPING_ABI: &str = "FS010";
    /// The planner produced (or was asked to verify) an invalid layout:
    /// overlap, out-of-buffer extent, or a granularity-block split.
    pub const LAYOUT_INVALID: &str = "FS011";
    /// Comm-stack encapsulation breach: source outside `cluster/`
    /// constructs a backend directly (`SerialComm::` / `ThreadedComm::`)
    /// or calls the quant codec primitives instead of going through the
    /// `CollectiveLaunch` pipeline stages (`encode_wire` / `rs_encode`).
    pub const COMM_ENCAPSULATION: &str = "FS012";
    /// Trace document malformed: missing/empty `traceEvents`, an event
    /// without `ph`, or an unknown event kind.
    pub const TRACE_MALFORMED: &str = "FS201";
    /// A trace span is missing required args (`bucket`/`bytes`/`tier`).
    pub const TRACE_SPAN_ARGS: &str = "FS202";
    /// Two spans on one (pid, tid) lane partially overlap — the timeline
    /// is not strictly nested.
    pub const TRACE_OVERLAP: &str = "FS203";
    /// A rank heartbeat sat inside one rendezvous past the watchdog
    /// deadline — the collective watchdog's stalled-rank finding.
    pub const WATCHDOG_STALL: &str = "FS204";
    /// A counter track violates its value invariant: a cumulative
    /// (`wire.*`) series decreased, or a memory sample went negative.
    pub const COUNTER_TRACK: &str = "FS205";
    /// A metric series regressed beyond the rolling-window (or
    /// `fsdp-report`) tolerance.
    pub const METRIC_REGRESSION: &str = "FS206";
    /// A trace/metrics/postmortem artifact could not be written.
    pub const EXPORT_IO: &str = "FS207";
}

/// `(code, title)` rows of the full catalog, in code order — rendered by
/// the README table and `fsdp-lint --codes`.
pub fn catalog() -> &'static [(&'static str, &'static str)] {
    &[
        (codes::SPMD_DIVERGENCE, "rank-divergent collective sequence (rendezvous deadlock)"),
        (codes::HANDLE_DISCIPLINE, "async collective handle issued/awaited out of discipline"),
        (codes::LIFETIME_IMBALANCE, "allocator claim leaked, double-freed, or freed in flight"),
        (codes::QUANT_MISALIGNED, "quant block/scale not co-located on one device"),
        (codes::BAD_TOPOLOGY, "hierarchical-dispatch precondition violated"),
        (codes::READ_BEFORE_GATHER, "compute touches a bucket before its AllGather lands"),
        (codes::REDUCE_BEFORE_BACKWARD, "ReduceScatter issued before the bucket's backward"),
        (codes::RESHARD_UNPAIRED, "gather/reshard pairing violates the group's spec"),
        (codes::PEAK_OVER_LIMIT, "static peak-memory bound exceeds the device limit"),
        (codes::WRAPPING_ABI, "pipelined executor wrapping ABI mismatch"),
        (codes::LAYOUT_INVALID, "planner layout invalid"),
        (codes::COMM_ENCAPSULATION, "backend/codec use bypasses the launch pipeline"),
        (codes::TRACE_MALFORMED, "trace document malformed"),
        (codes::TRACE_SPAN_ARGS, "trace span missing required args"),
        (codes::TRACE_OVERLAP, "trace spans partially overlap without nesting"),
        (codes::WATCHDOG_STALL, "rank stalled in a rendezvous past the watchdog deadline"),
        (codes::COUNTER_TRACK, "counter track non-monotonic or negative"),
        (codes::METRIC_REGRESSION, "metric series regressed beyond tolerance"),
        (codes::EXPORT_IO, "trace/metrics artifact could not be written"),
    ]
}

/// Catalog title for a code, if it is a known code.
pub fn title(code: &str) -> Option<&'static str> {
    catalog().iter().find(|(c, _)| *c == code).map(|(_, t)| *t)
}

/// Prefix a runtime error/assert message with its diagnostic code, so
/// dynamic violations and static findings correlate on the same catalog
/// entry (`[FS002] bucket 3 gather was never issued`).
pub fn rt(code: &'static str, msg: impl fmt::Display) -> String {
    format!("[{code}] {msg}")
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Worth surfacing, but the plan can run.
    Warning,
    /// The plan violates an invariant; `fsdp-lint` exits nonzero and the
    /// `--lint` pre-flight aborts the run.
    Error,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding: a stable code, a severity, the offending subject
/// (group/bucket/rank/span), and a human-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub code: &'static str,
    pub severity: Severity,
    /// What the finding is about — a shard-group or bucket name, a rank,
    /// or a trace-event locator.
    pub subject: String,
    pub message: String,
}

impl Diagnostic {
    pub fn error(code: &'static str, subject: impl Into<String>, message: impl Into<String>) -> Diagnostic {
        Diagnostic { code, severity: Severity::Error, subject: subject.into(), message: message.into() }
    }

    pub fn warning(code: &'static str, subject: impl Into<String>, message: impl Into<String>) -> Diagnostic {
        Diagnostic { code, severity: Severity::Warning, subject: subject.into(), message: message.into() }
    }

    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::str(self.code)),
            ("severity", Json::str(self.severity.name())),
            ("subject", Json::str(&self.subject)),
            ("message", Json::str(&self.message)),
        ])
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} {} [{}]: {}",
            self.code,
            self.severity.name(),
            self.subject,
            self.message
        )
    }
}

/// JSON document for a diagnostic list (the `--json` artifact shape both
/// `fsdp-lint` and `trace-check` emit).
pub fn to_json(diags: &[Diagnostic]) -> Json {
    Json::obj(vec![
        ("errors", Json::num(diags.iter().filter(|d| d.severity == Severity::Error).count() as f64)),
        ("warnings", Json::num(diags.iter().filter(|d| d.severity == Severity::Warning).count() as f64)),
        ("diagnostics", Json::arr(diags.iter().map(Diagnostic::json))),
    ])
}

/// Do any error-severity findings exist?
pub fn has_errors(diags: &[Diagnostic]) -> bool {
    diags.iter().any(|d| d.severity == Severity::Error)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_codes_unique_and_titled() {
        let cat = catalog();
        for (i, (code, t)) in cat.iter().enumerate() {
            assert!(code.starts_with("FS"), "{code}");
            assert!(!t.is_empty());
            assert!(cat.iter().skip(i + 1).all(|(c, _)| c != code), "dup {code}");
        }
        assert_eq!(title(codes::SPMD_DIVERGENCE), Some(cat[0].1));
        assert_eq!(title("FS999"), None);
    }

    #[test]
    fn display_and_json_roundtrip() {
        let d = Diagnostic::error(codes::QUANT_MISALIGNED, "layer0", "shard size 130 % block 64 != 0");
        let s = d.to_string();
        assert!(s.contains("FS004") && s.contains("layer0") && s.contains("error"), "{s}");
        let j = to_json(&[d.clone(), Diagnostic::warning(codes::PEAK_OVER_LIMIT, "plan", "crowded")]);
        assert_eq!(j.get("errors").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("warnings").and_then(Json::as_f64), Some(1.0));
        let arr = j.get("diagnostics").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[0].get("code").and_then(Json::as_str), Some("FS004"));
        assert!(has_errors(&[d]));
    }

    #[test]
    fn rt_prefixes_code() {
        assert_eq!(rt(codes::HANDLE_DISCIPLINE, "bucket 3 never issued"), "[FS002] bucket 3 never issued");
    }
}
