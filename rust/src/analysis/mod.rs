//! Static plan & protocol verification (`fsdp-lint`).
//!
//! veScale-FSDP's correctness rests on invariants that are otherwise
//! only enforced mid-run: every rank must issue the same collective
//! sequence (or the barrier-phased `ThreadedComm` rendezvous deadlocks),
//! every Q8 quant block and its scale must land on one device, every
//! transient gather/staging buffer must be freed at reshard, and the
//! pipelined schedule must never touch a bucket before its AllGather
//! lands. This module checks all of that *before any thread spawns*:
//!
//! 1. [`ir::PlanModel`] mirrors `FsdpEngine::from_spec`'s planning
//!    (same group assignment, granularity lcm's, and `planner::plan`
//!    collective alignment) without allocating a single tensor;
//! 2. [`ir::elaborate`] unrolls the exact schedule `fsdp::exec` would
//!    run — sequential or bucket-pipelined — into a typed per-rank
//!    [`ir::Event`] stream: collectives with (op, bucket, mesh, tier,
//!    bytes), compute slots, and every allocator claim/free;
//! 3. [`checks::run_checks`] verifies SPMD conformance (deadlock
//!    freedom by construction), async-handle discipline, happens-before
//!    ordering, allocator lifetime balance with a statically derived
//!    peak-memory bound (replayed through a real `CachingAllocator`),
//!    quant-block co-location, hierarchical-dispatch preconditions, and
//!    the pipelined executor's wrapping ABI.
//!
//! Findings are [`diag::Diagnostic`]s with stable `FS0xx` codes shared
//! with the runtime's own invariant checks and with the trace validator
//! (`trace::check`, `FS2xx`). Entry points: the `fsdp-lint` binary, the
//! `--lint` pre-flight on `vescale-fsdp train`, and
//! `train::SessionBuilder::analyze`. The report also carries the
//! statically predicted `ag`/`rs` span sequence, which
//! `tests/static_vs_trace.rs` cross-validates against the tracer's
//! recorded spans on live runs.

pub mod checks;
pub mod diag;
pub mod ir;

pub use checks::{lint, run_checks, AnalysisReport};
pub use diag::{catalog, Diagnostic, Severity};
pub use ir::{elaborate, Event, ExpectedSpan, LintRequest, PlanModel, Program};
