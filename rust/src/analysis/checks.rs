//! The static check suite over the elaborated IR.
//!
//! Five families, one per protocol the runtime relies on:
//!
//! * **SPMD conformance** (`FS001`) — every rank's collective sequence
//!   identical in (op, bucket, mesh, tier) order. The barrier-phased
//!   rendezvous of `ThreadedComm` completes iff all ranks arrive at the
//!   same collective, so conformance proves deadlock-freedom of the
//!   whole schedule by construction.
//! * **Happens-before discipline** (`FS002`/`FS006`/`FS007`/`FS008`,
//!   plus the in-flight `FS003` case) — a small state machine walks each
//!   rank's stream: handles are awaited exactly once in FIFO order,
//!   compute never reads a buffer before its AllGather lands, a bucket's
//!   reduction never precedes its backward, and gather/reshard pairing
//!   honors each group's `reshard_after_forward` choice.
//! * **Allocator lifetime balance** (`FS003`/`FS009`) — rank 0's
//!   claim/free stream replays through a real [`CachingAllocator`]
//!   (same rounding, same segments, same OOM path as the engine's),
//!   yielding the static peak-reserved/-allocated bounds and flagging
//!   leaked or double-freed claims.
//! * **Quant co-location** (`FS004`, `FS011`) — every Q8 group's shard
//!   size holds a whole number of quant blocks (and the planner's
//!   `lcm(4, block)` collective alignment), every tensor granularity
//!   keeps device boundaries on block edges, and the layout verifies.
//! * **Dispatch preconditions** (`FS005`, `FS010`) — hierarchical
//!   topology shape (`total() == m`, segments >= 1) and, when the plan
//!   binds to the native runtime, the pipelined executor's
//!   embed|layer|head wrapping ABI.

use std::collections::{HashMap, VecDeque};

use crate::memory::{BlockId, CachingAllocator, FreePolicy};
use crate::util::json::Json;
use crate::util::lcm;

use super::diag::{codes, Diagnostic, Severity};
use super::ir::{
    elaborate, ClaimId, CollEvent, CollOp, Event, ExpectedSpan, LintRequest, Phase, PlanModel,
    Program,
};

/// Fraction of the device limit above which the peak bound draws a
/// `FS009` warning even though the plan still fits.
const PEAK_WARN_FRACTION: f64 = 0.8;

/// The analyzer's output: plan identity, all findings, the statically
/// derived memory bounds, and the expected trace spans for
/// cross-validation against a live run.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    pub model: String,
    pub devices: usize,
    pub replicas: usize,
    pub backend: String,
    pub exec: String,
    pub topology: String,
    pub diagnostics: Vec<Diagnostic>,
    /// Static upper bound on allocator peak reserved bytes (>= any
    /// measured `ExecReport::peak_reserved` of the same plan).
    pub peak_reserved_bound: u64,
    pub peak_allocated_bound: u64,
    /// Collective events per rank per step (issue/wait pairs count 2).
    pub collectives_per_rank: usize,
    pub expected_spans: Vec<ExpectedSpan>,
}

impl AnalysisReport {
    /// No error-severity findings (warnings allowed).
    pub fn ok(&self) -> bool {
        !self.diagnostics.iter().any(|d| d.severity == Severity::Error)
    }

    /// The statically predicted (bucket, bytes) subsequence of spans
    /// with the given name and phase — compare against the tracer's
    /// recorded subsequence for one step.
    pub fn expected_subsequence(&self, name: &str, phase: &str) -> Vec<(String, u64)> {
        self.expected_spans
            .iter()
            .filter(|s| s.name == name && s.phase == phase)
            .map(|s| (s.bucket.clone(), s.bytes))
            .collect()
    }

    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("devices", Json::num(self.devices as f64)),
            ("replicas", Json::num(self.replicas as f64)),
            ("backend", Json::str(&self.backend)),
            ("exec", Json::str(&self.exec)),
            ("topology", Json::str(&self.topology)),
            ("collectives_per_rank", Json::num(self.collectives_per_rank as f64)),
            ("peak_reserved_bound", Json::num(self.peak_reserved_bound as f64)),
            ("peak_allocated_bound", Json::num(self.peak_allocated_bound as f64)),
            (
                "errors",
                Json::num(
                    self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
                        as f64,
                ),
            ),
            (
                "warnings",
                Json::num(
                    self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
                        as f64,
                ),
            ),
            ("diagnostics", Json::arr(self.diagnostics.iter().map(Diagnostic::json))),
        ])
    }
}

/// Lint one plan end to end: mirror the engine's planning, elaborate the
/// schedule, run every check. Never fails — planning errors come back as
/// diagnostics in the report.
pub fn lint(req: &LintRequest) -> AnalysisReport {
    match PlanModel::build(req) {
        Ok(pm) => {
            let prog = elaborate(&pm);
            run_checks(&pm, &prog)
        }
        Err(d) => AnalysisReport {
            model: req.model.to_string(),
            devices: req.devices,
            replicas: req.replicas,
            backend: req.backend.name().to_string(),
            exec: req.exec.name(),
            topology: topo_label(&req.topology),
            diagnostics: vec![d],
            peak_reserved_bound: 0,
            peak_allocated_bound: 0,
            collectives_per_rank: 0,
            expected_spans: Vec::new(),
        },
    }
}

fn topo_label(t: &crate::comm::Topology) -> String {
    if t.is_hierarchical() {
        t.label()
    } else {
        "flat".to_string()
    }
}

/// Run the full check suite over an already elaborated program (exposed
/// separately so defect fixtures can mutate the program first).
pub fn run_checks(pm: &PlanModel, prog: &Program) -> AnalysisReport {
    let mut diags = Vec::new();
    check_topology(pm, &mut diags);
    check_quant(pm, &mut diags);
    check_wrapping(pm, &mut diags);
    check_spmd(pm, prog, &mut diags);
    check_protocol(pm, prog, &mut diags);
    let (peak_reserved, peak_allocated) = check_ledger(pm, prog, &mut diags);
    AnalysisReport {
        model: pm.model.clone(),
        devices: pm.devices,
        replicas: pm.replicas,
        backend: pm.backend.name().to_string(),
        exec: pm.exec.name(),
        topology: topo_label(&pm.topology),
        diagnostics: diags,
        peak_reserved_bound: peak_reserved,
        peak_allocated_bound: peak_allocated,
        collectives_per_rank: prog.ranks.first().map_or(0, |r| {
            r.iter().filter(|e| matches!(e, Event::Coll(_))).count()
        }),
        expected_spans: prog.expected_spans.clone(),
    }
}

fn bucket_name(pm: &PlanModel, b: usize) -> String {
    pm.groups.get(b).map_or_else(|| format!("bucket{b}"), |g| g.name.clone())
}

fn coll_tuple(pm: &PlanModel, c: &CollEvent) -> String {
    format!(
        "{}:{}({}, mesh {}, tier {}, {} B)",
        c.op.name(),
        c.phase.name(),
        bucket_name(pm, c.bucket),
        c.mesh,
        c.tier.name(),
        c.bytes
    )
}

// ---- FS001: SPMD conformance -------------------------------------------

/// All ranks must issue the identical collective sequence; any
/// divergence stalls a barrier phase forever on the rendezvous backend.
fn check_spmd(pm: &PlanModel, prog: &Program, diags: &mut Vec<Diagnostic>) {
    let base = prog.collective_sequence(0);
    for r in 1..prog.ranks.len() {
        let seq = prog.collective_sequence(r);
        let div = base
            .iter()
            .zip(&seq)
            .position(|(a, b)| a != b)
            .or_else(|| (base.len() != seq.len()).then_some(base.len().min(seq.len())));
        if let Some(i) = div {
            let what = |s: &[&CollEvent]| {
                s.get(i).map_or("<end of sequence>".to_string(), |c| coll_tuple(pm, c))
            };
            diags.push(Diagnostic::error(
                codes::SPMD_DIVERGENCE,
                format!("rank {r}"),
                format!(
                    "collective sequence diverges from rank 0 at position {i}: \
                     rank 0 issues {} but rank {r} issues {} — the rendezvous \
                     barrier would never fill",
                    what(&base),
                    what(&seq)
                ),
            ));
            return; // one witness suffices; later ranks repeat it
        }
    }
}

// ---- FS002/FS003/FS006/FS007/FS008: happens-before discipline ----------

/// Per-rank protocol walk. Ranks are elaborated as clones, so identical
/// findings collapse to one diagnostic annotated with the rank set;
/// a fixture-mutated rank surfaces its own finding.
fn check_protocol(pm: &PlanModel, prog: &Program, diags: &mut Vec<Diagnostic>) {
    let mut merged: Vec<(Diagnostic, Vec<usize>)> = Vec::new();
    for (rank, events) in prog.ranks.iter().enumerate() {
        for d in walk_rank(pm, events) {
            match merged.iter_mut().find(|(m, _)| *m == d) {
                Some((_, ranks)) => ranks.push(rank),
                None => merged.push((d, vec![rank])),
            }
        }
    }
    let m = prog.ranks.len();
    for (mut d, ranks) in merged {
        if ranks.len() < m {
            let list =
                ranks.iter().map(ToString::to_string).collect::<Vec<_>>().join(",");
            d.message = format!("{} (rank {list})", d.message);
        }
        diags.push(d);
    }
}

fn walk_rank(pm: &PlanModel, events: &[Event]) -> Vec<Diagnostic> {
    let nb = pm.groups.len();
    let mut out = Vec::new();
    let mut gathered = vec![false; nb];
    let mut bwd_done = vec![false; nb];
    let mut gather_count = vec![0usize; nb];
    let mut reshard_count = vec![0usize; nb];
    let mut ag_inflight: VecDeque<usize> = VecDeque::new();
    let mut rs_inflight: VecDeque<usize> = VecDeque::new();
    for e in events {
        match e {
            Event::Coll(c) => match (c.op, c.phase) {
                (CollOp::AllGather, Phase::Sync) | (CollOp::AllGather, Phase::Issue) => {
                    if gathered[c.bucket] || ag_inflight.contains(&c.bucket) {
                        out.push(Diagnostic::error(
                            codes::HANDLE_DISCIPLINE,
                            bucket_name(pm, c.bucket),
                            "gather issued while the bucket is already gathered or in flight",
                        ));
                    }
                    if c.phase == Phase::Issue {
                        ag_inflight.push_back(c.bucket);
                    } else {
                        gathered[c.bucket] = true;
                        gather_count[c.bucket] += 1;
                    }
                }
                (CollOp::AllGather, Phase::Wait) => {
                    if ag_inflight.front() == Some(&c.bucket) {
                        ag_inflight.pop_front();
                        gathered[c.bucket] = true;
                        gather_count[c.bucket] += 1;
                    } else if let Some(pos) =
                        ag_inflight.iter().position(|&b| b == c.bucket)
                    {
                        out.push(Diagnostic::error(
                            codes::HANDLE_DISCIPLINE,
                            bucket_name(pm, c.bucket),
                            format!(
                                "gather waited out of issue order ({pos} earlier \
                                 handles still pending)"
                            ),
                        ));
                        let _ = ag_inflight.remove(pos);
                        gathered[c.bucket] = true;
                        gather_count[c.bucket] += 1;
                    } else {
                        out.push(Diagnostic::error(
                            codes::HANDLE_DISCIPLINE,
                            bucket_name(pm, c.bucket),
                            "gather was never issued (stale handle wait)",
                        ));
                    }
                }
                (_, Phase::Sync) | (_, Phase::Issue) => {
                    if !bwd_done[c.bucket] {
                        out.push(Diagnostic::error(
                            codes::REDUCE_BEFORE_BACKWARD,
                            bucket_name(pm, c.bucket),
                            "gradient reduction issued before the bucket's backward ran",
                        ));
                    }
                    if c.phase == Phase::Issue {
                        rs_inflight.push_back(c.bucket);
                    }
                }
                (_, Phase::Wait) => {
                    if rs_inflight.front() == Some(&c.bucket) {
                        rs_inflight.pop_front();
                    } else if let Some(pos) =
                        rs_inflight.iter().position(|&b| b == c.bucket)
                    {
                        out.push(Diagnostic::error(
                            codes::HANDLE_DISCIPLINE,
                            bucket_name(pm, c.bucket),
                            format!(
                                "reduction waited out of issue order ({pos} earlier \
                                 handles still pending)"
                            ),
                        ));
                        let _ = rs_inflight.remove(pos);
                    } else {
                        out.push(Diagnostic::error(
                            codes::HANDLE_DISCIPLINE,
                            bucket_name(pm, c.bucket),
                            "reduction was never issued (stale handle wait)",
                        ));
                    }
                }
            },
            Event::Compute { bucket, phase } => match (bucket, *phase) {
                (Some(b), "fwd") | (Some(b), "bwd") => {
                    if !gathered[*b] {
                        out.push(Diagnostic::error(
                            codes::READ_BEFORE_GATHER,
                            bucket_name(pm, *b),
                            format!("{phase} compute reads the bucket before its AllGather completed"),
                        ));
                    }
                    if *phase == "bwd" {
                        bwd_done[*b] = true;
                    }
                }
                (None, "fwd_bwd") => {
                    if let Some(b) = (0..nb).find(|&b| !gathered[b]) {
                        out.push(Diagnostic::error(
                            codes::READ_BEFORE_GATHER,
                            bucket_name(pm, b),
                            "monolithic fwd/bwd runs before every bucket is gathered",
                        ));
                    }
                    bwd_done.iter_mut().for_each(|d| *d = true);
                }
                _ => {}
            },
            Event::Free { id } => {
                let b = id.bucket();
                let in_gather = ag_inflight.contains(&b)
                    && matches!(id, ClaimId::Full(_) | ClaimId::Wire(_));
                let in_reduce = rs_inflight.contains(&b)
                    && matches!(id, ClaimId::Staged(_) | ClaimId::RsWire(_));
                if in_gather || in_reduce {
                    out.push(Diagnostic::error(
                        codes::LIFETIME_IMBALANCE,
                        bucket_name(pm, b),
                        format!(
                            "{} buffer released while the bucket's collective is in flight",
                            id.kind()
                        ),
                    ));
                }
            }
            Event::Reshard { bucket } => {
                gathered[*bucket] = false;
                reshard_count[*bucket] += 1;
            }
            Event::Claim { .. } | Event::ClaimBatch { .. } => {}
        }
    }
    for (q, what) in [(&ag_inflight, "gather"), (&rs_inflight, "reduction")] {
        for &b in q.iter() {
            out.push(Diagnostic::error(
                codes::HANDLE_DISCIPLINE,
                bucket_name(pm, b),
                format!("{what} handle never awaited"),
            ));
        }
    }
    // ---- FS008: reshard-after-forward pairing ----
    for b in 0..nb {
        if gathered[b] {
            out.push(Diagnostic::error(
                codes::RESHARD_UNPAIRED,
                bucket_name(pm, b),
                "bucket still gathered at step end (transient full buffer kept)",
            ));
            continue;
        }
        if gather_count[b] != reshard_count[b] {
            out.push(Diagnostic::error(
                codes::RESHARD_UNPAIRED,
                bucket_name(pm, b),
                format!(
                    "{} gathers but {} reshards in one step",
                    gather_count[b], reshard_count[b]
                ),
            ));
            continue;
        }
        let expect = match pm.exec {
            crate::fsdp::ExecMode::Sequential => 1,
            crate::fsdp::ExecMode::Pipelined { .. } => {
                if pm.groups[b].reshard_after_forward {
                    2
                } else {
                    1
                }
            }
        };
        if gather_count[b] != expect {
            out.push(Diagnostic::error(
                codes::RESHARD_UNPAIRED,
                bucket_name(pm, b),
                format!(
                    "{} gather/reshard cycles per step, but reshard_after_forward={} \
                     under the {} schedule implies {expect}",
                    gather_count[b],
                    pm.groups[b].reshard_after_forward,
                    pm.exec.name()
                ),
            ));
        }
    }
    out
}

// ---- FS003/FS009: allocator lifetime balance + peak bound ---------------

/// Replay rank 0's claim stream through a real `CachingAllocator` (same
/// rounding/segment/OOM behavior as the engine's) and return the static
/// (peak_reserved, peak_allocated) bounds.
fn check_ledger(pm: &PlanModel, prog: &Program, diags: &mut Vec<Diagnostic>) -> (u64, u64) {
    let Some(events) = prog.ranks.first() else {
        return (0, 0);
    };
    let mut alloc = CachingAllocator::new(FreePolicy::Deterministic, pm.mem_limit);
    let mut live: HashMap<ClaimId, BlockId> = HashMap::new();
    let mut oom = false;
    for e in events {
        match e {
            Event::Claim { id, bytes } => match alloc.alloc(*bytes) {
                Ok(block) => {
                    live.insert(*id, block);
                }
                Err(err) => {
                    diags.push(Diagnostic::error(
                        codes::PEAK_OVER_LIMIT,
                        bucket_name(pm, id.bucket()),
                        format!("claiming the {} buffer fails: {err:#}", id.kind()),
                    ));
                    oom = true;
                    break;
                }
            },
            Event::ClaimBatch { ids, sizes } => match alloc.alloc_batch(sizes) {
                Ok(blocks) => {
                    for (id, block) in ids.iter().zip(blocks) {
                        live.insert(*id, block);
                    }
                }
                Err(err) => {
                    diags.push(Diagnostic::error(
                        codes::PEAK_OVER_LIMIT,
                        pm.model.clone(),
                        format!("persistent shard claims fail: {err:#}"),
                    ));
                    oom = true;
                    break;
                }
            },
            Event::Free { id } => match live.remove(id) {
                Some(block) => {
                    if let Err(err) = alloc.free(block) {
                        diags.push(Diagnostic::error(
                            codes::LIFETIME_IMBALANCE,
                            bucket_name(pm, id.bucket()),
                            format!("freeing the {} buffer fails: {err:#}", id.kind()),
                        ));
                    }
                }
                None => {
                    diags.push(Diagnostic::error(
                        codes::LIFETIME_IMBALANCE,
                        bucket_name(pm, id.bucket()),
                        format!(
                            "{} buffer freed while not live (double free or never claimed)",
                            id.kind()
                        ),
                    ));
                }
            },
            _ => {}
        }
    }
    if !oom {
        for id in live.keys() {
            if !prog.persistent.contains(id) {
                diags.push(Diagnostic::error(
                    codes::LIFETIME_IMBALANCE,
                    bucket_name(pm, id.bucket()),
                    format!(
                        "transient {} buffer still claimed at step end (leaked \
                         {} reshard)",
                        id.kind(),
                        bucket_name(pm, id.bucket())
                    ),
                ));
            }
        }
        let frac = alloc.peak_reserved as f64 / pm.mem_limit.max(1) as f64;
        if frac > PEAK_WARN_FRACTION {
            diags.push(Diagnostic::warning(
                codes::PEAK_OVER_LIMIT,
                pm.model.clone(),
                format!(
                    "static peak-reserved bound {} B is {:.0}% of the {} B device \
                     limit",
                    alloc.peak_reserved,
                    100.0 * frac,
                    pm.mem_limit
                ),
            ));
        }
    }
    (alloc.peak_reserved, alloc.peak_allocated)
}

// ---- FS004/FS011: quant co-location + layout validity -------------------

fn check_quant(pm: &PlanModel, diags: &mut Vec<Diagnostic>) {
    for g in &pm.groups {
        if let Err(e) = g.layout.verify() {
            diags.push(Diagnostic::error(
                codes::LAYOUT_INVALID,
                &g.name,
                format!("planned layout fails verification: {e:#}"),
            ));
        }
        let align = g.comm_precision.align_elems();
        if align <= 1 {
            continue;
        }
        let s = g.layout.shard_size;
        if s % align != 0 {
            diags.push(Diagnostic::error(
                codes::QUANT_MISALIGNED,
                &g.name,
                format!(
                    "shard size {s} is not a whole number of {align}-element quant \
                     blocks — a block and its scale would straddle two devices"
                ),
            ));
        }
        let g_coll = lcm(4, align);
        if s % g_coll != 0 {
            diags.push(Diagnostic::error(
                codes::QUANT_MISALIGNED,
                &g.name,
                format!(
                    "shard size {s} breaks the planner's collective alignment \
                     lcm(4, {align}) = {g_coll}"
                ),
            ));
        }
        for t in &g.layout.tensors {
            if t.granularity % align != 0 && t.granularity != t.numel {
                diags.push(Diagnostic::error(
                    codes::QUANT_MISALIGNED,
                    &g.name,
                    format!(
                        "tensor '{}' granularity {} is not block-aligned ({align}) — \
                         a device boundary inside it could split a quant block",
                        t.name, t.granularity
                    ),
                ));
            }
        }
    }
}

// ---- FS005: hierarchical-dispatch preconditions -------------------------

fn check_topology(pm: &PlanModel, diags: &mut Vec<Diagnostic>) {
    let t = &pm.topology;
    if !t.is_hierarchical() {
        return;
    }
    let subject = t.label();
    if t.hosts == 0 || t.gpus_per_host == 0 {
        diags.push(Diagnostic::error(
            codes::BAD_TOPOLOGY,
            subject,
            "topology has zero hosts or zero GPUs per host",
        ));
        return;
    }
    if t.segments == 0 {
        diags.push(Diagnostic::error(
            codes::BAD_TOPOLOGY,
            subject.clone(),
            "hierarchical dispatch needs at least one pipeline segment",
        ));
    }
    if t.total() != pm.devices {
        diags.push(Diagnostic::error(
            codes::BAD_TOPOLOGY,
            subject,
            format!(
                "topology spans {} ranks but the fsdp group has {} — hierarchical \
                 dispatch would silently fall back to the flat path",
                t.total(),
                pm.devices
            ),
        ));
        return;
    }
    // The dispatch threshold gates two-level routing per launch: a shard
    // group whose descriptor elects the serial fallback never reaches
    // the hierarchical algorithms, so a topology where *every* group
    // falls under the threshold is configured for nothing.
    let all_serial = !pm.groups.is_empty()
        && (0..pm.groups.len()).all(|b| pm.launch_for(CollOp::AllGather, b).serial_fallback());
    if all_serial {
        diags.push(Diagnostic::warning(
            codes::BAD_TOPOLOGY,
            t.label(),
            format!(
                "every shard group falls under the dispatch threshold \
                 (hier_threshold = {}) — hierarchical dispatch will never engage",
                pm.hier_threshold
            ),
        ));
    }
}

// ---- FS010: pipelined wrapping ABI --------------------------------------

/// Only checked when the plan is known to bind the native runtime
/// (`native_layers` set) *and* the pipelined executor will drive it —
/// raw preset plans carry no runtime ABI to violate.
fn check_wrapping(pm: &PlanModel, diags: &mut Vec<Diagnostic>) {
    let Some(nl) = pm.native_layers else { return };
    if !matches!(pm.exec, crate::fsdp::ExecMode::Pipelined { .. }) {
        return;
    }
    let nb = pm.groups.len();
    if nb != nl + 2 {
        diags.push(Diagnostic::error(
            codes::WRAPPING_ABI,
            pm.model.clone(),
            format!(
                "pipelined executor expects embed|layer|head wrapping: {nb} shard \
                 groups for {nl} layers (want {})",
                nl + 2
            ),
        ));
        return;
    }
    if pm.n_params != 3 + 8 * nl {
        diags.push(Diagnostic::error(
            codes::WRAPPING_ABI,
            pm.model.clone(),
            format!("parameter ABI mismatch: {} params (want {})", pm.n_params, 3 + 8 * nl),
        ));
        return;
    }
    let mut expect = |i: usize, bucket: usize| {
        if pm.group_of[i] != bucket {
            diags.push(Diagnostic::error(
                codes::WRAPPING_ABI,
                bucket_name(pm, bucket),
                format!(
                    "param {i} assigned to group '{}' but the executor's ABI places \
                     it in '{}'",
                    bucket_name(pm, pm.group_of[i]),
                    bucket_name(pm, bucket)
                ),
            ));
        }
    };
    expect(0, 0);
    for l in 0..nl {
        for k in 0..8 {
            expect(1 + 8 * l + k, 1 + l);
        }
    }
    expect(1 + 8 * nl, nl + 1);
    expect(2 + 8 * nl, nl + 1);
}
