//! Miniature property-based testing harness (no `proptest` offline).
//!
//! `check(name, iters, f)` runs `f` against `iters` seeded RNGs; on the
//! first failure it retries with a binary-shrunk "size hint" so failures
//! reproduce from the printed seed. Used by the planner / placement /
//! dbuffer invariant tests.

use super::prng::Rng;

/// Per-case context handed to the property closure.
pub struct Case {
    pub rng: Rng,
    /// Size hint in [1, 100]; generators should scale instance size by it
    /// so shrinking produces smaller counterexamples.
    pub size: usize,
    pub seed: u64,
}

impl Case {
    /// Scale `max` by the case size (at least 1).
    pub fn scaled(&self, max: usize) -> usize {
        (max * self.size / 100).max(1)
    }
}

/// Run a property. `f` returns Err(description) on violation.
/// Panics with seed + shrink info on failure.
pub fn check<F>(name: &str, iters: u64, mut f: F)
where
    F: FnMut(&mut Case) -> Result<(), String>,
{
    let base = 0xC0FFEE_u64;
    for i in 0..iters {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut case = Case { rng: Rng::new(seed), size: 100, seed };
        if let Err(msg) = f(&mut case) {
            // shrink: halve the size hint while the property still fails
            let mut best = (100, msg.clone());
            let mut size = 50;
            while size >= 1 {
                let mut c = Case { rng: Rng::new(seed), size, seed };
                match f(&mut c) {
                    Err(m) => {
                        best = (size, m);
                        size /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, iter={i}, \
                 shrunk size={}): {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        let mut n = 0;
        check("always-true", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |c| {
            if c.rng.below(4) == 0 {
                Err("hit zero".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn shrinking_reduces_size() {
        let result = std::panic::catch_unwind(|| {
            check("size-sensitive", 5, |c| {
                // fails for any size >= 1 -> shrinks to 1
                Err(format!("n={}", c.scaled(1000)))
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk size=1"), "{msg}");
    }
}
