//! Deterministic PRNG (SplitMix64 core) — replacement for the `rand` crate.
//!
//! Every stochastic component in the repo (workload generators, property
//! tests, synthetic corpus, load-balancing tie breaks) draws from this so
//! runs are reproducible from a single seed.

/// SplitMix64 generator. Passes BigCrush for the use cases here; chosen for
/// a 2-line next() and trivially seedable streams.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (for per-device / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from a Zipf(s) distribution over [0, n) (synthetic corpus).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF on a precomputable harmonic sum would be faster; this
        // rejection-free approximation is fine for data generation.
        let u = self.f64();
        let hmax = ((n as f64).powf(1.0 - s) - 1.0) / (1.0 - s) + 1.0;
        let x = ((u * hmax - u) * (1.0 - s) + 1.0).powf(1.0 / (1.0 - s));
        (x as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Rng::new(11);
        let mut low = 0;
        for _ in 0..1000 {
            if r.zipf(1000, 1.2) < 10 {
                low += 1;
            }
        }
        assert!(low > 300, "zipf not skewed: {low}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
