//! Self-built substrate utilities.
//!
//! The offline crate universe has no `rand`, `serde`, `clap`, `criterion`
//! or `proptest`, so this module provides from-scratch replacements used
//! throughout the coordinator: a PRNG, a JSON value + parser/serializer,
//! integer math (LCM/alignment), a CLI argument parser, a table printer
//! for the paper-figure benches, and a miniature property-testing harness.

pub mod args;
pub mod json;
pub mod math;
pub mod prng;
pub mod prop;
pub mod table;

pub use math::{ceil_div, gcd, lcm, round_up};
pub use prng::Rng;
