//! Minimal JSON value, parser, and serializer (no serde in the offline
//! crate universe). Used for the artifact manifest, run metrics, and
//! checkpoint metadata. Supports the full JSON grammar except `\u` escapes
//! beyond the BMP (sufficient for machine-generated files).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders --------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| "bad \\u".to_string())?;
                            let n = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u".to_string())?;
                            out.push(char::from_u32(n).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "bad utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.'
                       || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().get("b").unwrap(),
                   &Json::Str("x".into()));
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("name", Json::str("train_step_tiny")),
            ("shape", Json::arr(vec![Json::num(4), Json::num(64)])),
            ("ok", Json::Bool(true)),
        ]);
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert!(m.get("artifacts").unwrap().as_arr().unwrap().len() >= 4);
        }
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse("\"caf\\u00e9 ≈\"").unwrap();
        assert_eq!(j, Json::Str("café ≈".into()));
    }
}
