//! Aligned table printer for the paper-figure bench harnesses — every
//! bench prints the same rows/series the paper reports, in this format.

pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowv(&mut self, cells: Vec<String>) {
        self.row(&cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format helpers shared by benches.
pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{:.*}", prec, x)
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{:.2} {}", v, UNITS[u])
}

pub fn fmt_si(x: f64) -> String {
    let (v, suffix) = if x >= 1e12 {
        (x / 1e12, "T")
    } else if x >= 1e9 {
        (x / 1e9, "G")
    } else if x >= 1e6 {
        (x / 1e6, "M")
    } else if x >= 1e3 {
        (x / 1e3, "K")
    } else {
        (x, "")
    };
    format!("{v:.2}{suffix}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["system", "tok/s"]);
        t.row(&["veScale-FSDP".into(), "123".into()]);
        t.row(&["FSDP2".into(), "7".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        let lines: Vec<&str> = s.lines().collect();
        // all table lines equal width
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512), "512.00 B");
        assert_eq!(fmt_bytes(1536), "1.50 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024 * 1024), "3.00 GiB");
    }

    #[test]
    fn si_formatting() {
        assert_eq!(fmt_si(1234.0), "1.23K");
        assert_eq!(fmt_si(2.4e12), "2.40T");
    }
}
