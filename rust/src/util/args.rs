//! Tiny CLI argument parser (no `clap` offline). Supports
//! `--key value`, `--key=value`, boolean `--flag`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("--mesh 8 --preset=llama70b train");
        assert_eq!(a.usize_or("mesh", 0), 8);
        assert_eq!(a.str_or("preset", ""), "llama70b");
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("--verbose --steps 10");
        assert!(a.bool("verbose"));
        assert_eq!(a.usize_or("steps", 0), 10);
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("run --dry-run");
        assert!(a.bool("dry-run"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.f64_or("lr", 1e-3), 1e-3);
        assert_eq!(a.str_or("opt", "adamw"), "adamw");
    }
}
