//! Integer math used by the planner and placement layers: GCD/LCM (the
//! paper's granularity-composition rule, §4), alignment rounding (NCCL
//! even-input alignment, §5).

/// Greatest common divisor (Euclid). gcd(0, n) == n.
pub fn gcd(a: u64, b: u64) -> u64 {
    let (mut a, mut b) = (a, b);
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Least common multiple; saturates on overflow (planner treats saturation
/// as "infeasible granularity", which is the correct semantics).
pub fn lcm(a: u64, b: u64) -> u64 {
    if a == 0 || b == 0 {
        return 0;
    }
    (a / gcd(a, b)).saturating_mul(b)
}

/// Round `x` up to the next multiple of `unit` (unit > 0).
pub fn round_up(x: u64, unit: u64) -> u64 {
    debug_assert!(unit > 0);
    x.div_ceil(unit) * unit
}

/// Ceiling division.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcd_basic() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(17, 5), 1);
        assert_eq!(gcd(0, 7), 7);
        assert_eq!(gcd(7, 0), 7);
    }

    #[test]
    fn lcm_basic() {
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(1, 9), 9);
        assert_eq!(lcm(0, 9), 0);
        // the paper's example: granularity = LCM(stride, user granularity)
        assert_eq!(lcm(128, 96), 384);
    }

    #[test]
    fn lcm_saturates() {
        assert_eq!(lcm(u64::MAX - 1, u64::MAX), u64::MAX);
    }

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(ceil_div(9, 4), 3);
    }
}
