//! TOML-subset config-file parser for the launcher (no `toml` crate
//! offline). Supports `[sections]`, `key = value` with string / integer /
//! float / bool values, `#` comments, and flat key lookup as
//! `section.key`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::{CommBackend, OptimKind, ParallelConfig, System, TrainConfig};

#[derive(Debug, Default, Clone)]
pub struct ConfigFile {
    values: BTreeMap<String, String>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<ConfigFile> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(s) = line.strip_prefix('[') {
                let Some(name) = s.strip_suffix(']') else {
                    bail!("line {}: bad section header", ln + 1);
                };
                section = name.trim().to_string();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = if section.is_empty() {
                    k.trim().to_string()
                } else {
                    format!("{section}.{}", k.trim())
                };
                let val = v.trim().trim_matches('"').to_string();
                values.insert(key, val);
            } else {
                bail!("line {}: expected key = value", ln + 1);
            }
        }
        Ok(ConfigFile { values })
    }

    pub fn load(path: &str) -> Result<ConfigFile> {
        ConfigFile::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Materialize a TrainConfig (missing keys fall back to defaults).
    pub fn train_config(&self) -> Result<TrainConfig> {
        let d = TrainConfig::default();
        let system = match self.get("run.system") {
            Some(s) => System::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown system '{s}'"))?,
            None => d.system,
        };
        let optimizer = match self.get("run.optimizer") {
            Some(s) => OptimKind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown optimizer '{s}'"))?,
            None => d.optimizer,
        };
        let backend = match self.get("run.backend") {
            Some(s) => CommBackend::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown backend '{s}'"))?,
            None => d.backend,
        };
        Ok(TrainConfig {
            model: self.str_or("model.preset", &d.model),
            parallel: ParallelConfig {
                fsdp: self.usize_or("parallel.fsdp", d.parallel.fsdp),
                replicas: self.usize_or("parallel.replicas", 1),
                ep: self.usize_or("parallel.ep", 1),
            },
            optimizer,
            system,
            steps: self.usize_or("run.steps", d.steps),
            seq_len: self.usize_or("model.seq_len", d.seq_len),
            micro_batch: self.usize_or("model.micro_batch", d.micro_batch),
            lr: self.f64_or("run.lr", d.lr),
            seed: self.usize_or("run.seed", 0) as u64,
            granularity: self.usize_or("run.granularity", 1) as u64,
            backend,
            prefetch: self.usize_or("run.prefetch", d.prefetch),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# sample launcher config
[model]
preset = "small"
seq_len = 128

[parallel]
fsdp = 8
replicas = 2

[run]
system = "vescale"
optimizer = "adam8bit"
backend = "threaded"
steps = 100
lr = 0.0003
prefetch = 2
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(c.get("model.preset"), Some("small"));
        assert_eq!(c.usize_or("parallel.fsdp", 0), 8);
        assert_eq!(c.f64_or("run.lr", 0.0), 0.0003);
    }

    #[test]
    fn train_config_materializes() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        let tc = c.train_config().unwrap();
        assert_eq!(tc.model, "small");
        assert_eq!(tc.parallel.total_devices(), 16);
        assert_eq!(tc.optimizer, OptimKind::Adam8bit);
        assert_eq!(tc.system, System::VeScale);
        assert_eq!(tc.steps, 100);
        assert_eq!(tc.backend, CommBackend::Threaded);
        assert_eq!(tc.prefetch, 2);
    }

    #[test]
    fn defaults_apply() {
        let tc = ConfigFile::parse("").unwrap().train_config().unwrap();
        assert_eq!(tc.model, "tiny");
        assert_eq!(tc.parallel.fsdp, 4);
    }

    #[test]
    fn rejects_garbage() {
        assert!(ConfigFile::parse("[unclosed").is_err());
        assert!(ConfigFile::parse("no equals here").is_err());
        let bad = ConfigFile::parse("[run]\nsystem = \"bogus\"").unwrap();
        assert!(bad.train_config().is_err());
    }

    #[test]
    fn comments_ignored() {
        let c = ConfigFile::parse("a = 1 # trailing\n# full line\n").unwrap();
        assert_eq!(c.usize_or("a", 0), 1);
    }
}
