//! TOML-subset config-file parser for the launcher (no `toml` crate
//! offline). Supports `[sections]`, `key = value` with string / integer /
//! float / bool values, `#` comments, and flat key lookup as
//! `section.key`.
//!
//! Beyond the flat `[model]` / `[parallel]` / `[run]` sections, the
//! launcher config deserializes `[group.<name>]` sections straight into
//! the spec API's per-group overrides — e.g. the paper's mixed-optimizer
//! setup is just a config file:
//!
//! ```toml
//! [model]
//! preset = "tiny"
//!
//! [run]
//! optimizer = "adamw"     # session default: embed/head
//! fabric = "h800"
//!
//! [group.layers]          # every layer group
//! optimizer = "muon"
//! lr = 0.02
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::comm::{Fabric, Topology};
use crate::fsdp::spec::OptimBinding;
use crate::quant::CommPrecision;

use super::{CommBackend, GroupOverride, OptimKind, ParallelConfig, System, TrainConfig};

#[derive(Debug, Default, Clone)]
pub struct ConfigFile {
    values: BTreeMap<String, String>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<ConfigFile> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(s) = line.strip_prefix('[') {
                let Some(name) = s.strip_suffix(']') else {
                    bail!("line {}: bad section header", ln + 1);
                };
                section = name.trim().to_string();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = if section.is_empty() {
                    k.trim().to_string()
                } else {
                    format!("{section}.{}", k.trim())
                };
                let val = v.trim().trim_matches('"').to_string();
                values.insert(key, val);
            } else {
                bail!("line {}: expected key = value", ln + 1);
            }
        }
        Ok(ConfigFile { values })
    }

    pub fn load(path: &str) -> Result<ConfigFile> {
        ConfigFile::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Collect the `[group.<name>]` sections into per-group overrides.
    /// Unknown fields and unknown optimizer names are errors (a config
    /// typo must not silently train the wrong setup).
    pub fn group_overrides(&self) -> Result<Vec<GroupOverride>> {
        let mut by_name: BTreeMap<String, GroupOverride> = BTreeMap::new();
        for (key, val) in &self.values {
            let Some(rest) = key.strip_prefix("group.") else {
                continue;
            };
            let Some((which, field)) = rest.rsplit_once('.') else {
                bail!("bad group key '{key}': expected [group.<name>] field = value");
            };
            let o = by_name.entry(which.to_string()).or_insert_with(|| GroupOverride {
                which: which.to_string(),
                ..GroupOverride::default()
            });
            match field {
                "optimizer" => {
                    o.optim = Some(OptimBinding::parse(val).ok_or_else(|| {
                        anyhow::anyhow!("[group.{which}]: unknown optimizer '{val}'")
                    })?);
                }
                "rows" => {
                    o.rows = Some(val.parse().map_err(|_| {
                        anyhow::anyhow!("[group.{which}]: rows = '{val}' is not an integer")
                    })?);
                }
                "granularity" => {
                    o.granularity = Some(val.parse().map_err(|_| {
                        anyhow::anyhow!(
                            "[group.{which}]: granularity = '{val}' is not an integer"
                        )
                    })?);
                }
                "reshard_after_forward" => {
                    o.reshard = Some(match val.to_ascii_lowercase().as_str() {
                        "true" | "1" | "yes" => true,
                        "false" | "0" | "no" => false,
                        _ => bail!(
                            "[group.{which}]: reshard_after_forward = '{val}' is not a bool"
                        ),
                    });
                }
                "lr" => {
                    o.lr = Some(val.parse().map_err(|_| {
                        anyhow::anyhow!("[group.{which}]: lr = '{val}' is not a number")
                    })?);
                }
                "comm_precision" => {
                    o.comm = Some(CommPrecision::parse(val).ok_or_else(|| {
                        anyhow::anyhow!(
                            "[group.{which}]: unknown comm_precision '{val}' \
                             (expected f32, bf16, or q8[:block])"
                        )
                    })?);
                }
                _ => bail!(
                    "[group.{which}]: unknown field '{field}' (expected optimizer, \
                     rows, granularity, reshard_after_forward, lr, or comm_precision)"
                ),
            }
        }
        Ok(by_name.into_values().collect())
    }

    /// Read the `[obs]` section (runtime health-monitor knobs) as
    /// `(watchdog_ms, metrics path, postmortem-on-exit)`. Unknown fields
    /// are errors, mirroring `[group.*]` — a typo in a monitoring config
    /// must not silently train unmonitored.
    pub fn obs_overrides(&self) -> Result<(u64, Option<String>, bool)> {
        let mut watchdog_ms = 0u64;
        let mut metrics = None;
        let mut postmortem = false;
        for (key, val) in &self.values {
            let Some(field) = key.strip_prefix("obs.") else {
                continue;
            };
            match field {
                "watchdog_ms" => {
                    watchdog_ms = val.parse().map_err(|_| {
                        anyhow::anyhow!("[obs]: watchdog_ms = '{val}' is not an integer")
                    })?;
                }
                "metrics" => metrics = Some(val.to_string()),
                "postmortem" => {
                    postmortem = match val.to_ascii_lowercase().as_str() {
                        "true" | "1" | "yes" => true,
                        "false" | "0" | "no" => false,
                        _ => bail!("[obs]: postmortem = '{val}' is not a bool"),
                    };
                }
                _ => bail!(
                    "[obs]: unknown field '{field}' (expected watchdog_ms, metrics, \
                     or postmortem)"
                ),
            }
        }
        Ok((watchdog_ms, metrics, postmortem))
    }

    /// Materialize a TrainConfig (missing keys fall back to defaults).
    pub fn train_config(&self) -> Result<TrainConfig> {
        let d = TrainConfig::default();
        let system = match self.get("run.system") {
            Some(s) => System::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown system '{s}'"))?,
            None => d.system,
        };
        let optimizer = match self.get("run.optimizer") {
            Some(s) => OptimKind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown optimizer '{s}'"))?,
            None => d.optimizer,
        };
        let backend = match self.get("run.backend") {
            Some(s) => CommBackend::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown backend '{s}'"))?,
            None => d.backend,
        };
        let fabric = self.str_or("run.fabric", &d.fabric);
        if Fabric::by_name(&fabric).is_none() {
            bail!(
                "unknown fabric '{fabric}' (expected one of {:?})",
                Fabric::preset_names()
            );
        }
        let comm_precision = self.str_or("run.comm_precision", &d.comm_precision);
        if CommPrecision::parse(&comm_precision).is_none() {
            bail!(
                "unknown comm_precision '{comm_precision}' (expected f32, bf16, or q8[:block])"
            );
        }
        // `run.topology = "HxG[:S]"` or a `[topology]` section with
        // shape = "HxG" and an optional segments = S
        let topology = match self.get("run.topology").or_else(|| self.get("topology.shape")) {
            Some(t) => {
                let spec = if t.contains(':') {
                    t.to_string()
                } else {
                    match self.get("topology.segments") {
                        Some(s) => format!("{t}:{s}"),
                        None => t.to_string(),
                    }
                };
                if Topology::parse(&spec).is_none() {
                    bail!(
                        "bad topology '{spec}' (expected HxG or HxG:S, \
                         e.g. 2x4 or 4x8:2, all parts >= 1)"
                    );
                }
                spec
            }
            None => d.topology.clone(),
        };
        // `run.trace = "out.json"` or a `[trace]` section with out/level
        let trace = self
            .get("run.trace")
            .or_else(|| self.get("trace.out"))
            .map(str::to_string);
        let trace_level = self.str_or("trace.level", &d.trace_level);
        if crate::trace::TraceLevel::parse(&trace_level).is_none() {
            bail!("unknown trace level '{trace_level}' (expected off, comm, or full)");
        }
        let (watchdog_ms, metrics, postmortem) = self.obs_overrides()?;
        Ok(TrainConfig {
            model: self.str_or("model.preset", &d.model),
            parallel: ParallelConfig {
                fsdp: self.usize_or("parallel.fsdp", d.parallel.fsdp),
                replicas: self.usize_or("parallel.replicas", 1),
                ep: self.usize_or("parallel.ep", 1),
            },
            optimizer,
            system,
            steps: self.usize_or("run.steps", d.steps),
            seq_len: self.usize_or("model.seq_len", d.seq_len),
            micro_batch: self.usize_or("model.micro_batch", d.micro_batch),
            lr: self.f64_or("run.lr", d.lr),
            seed: self.usize_or("run.seed", 0) as u64,
            granularity: self.usize_or("run.granularity", 1) as u64,
            backend,
            prefetch: self.usize_or("run.prefetch", d.prefetch),
            fabric,
            topology,
            comm_precision,
            hier_threshold: self.usize_or("comm.hier_threshold", d.hier_threshold),
            trace,
            trace_level,
            watchdog_ms,
            metrics,
            postmortem,
            groups: self.group_overrides()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# sample launcher config
[model]
preset = "small"
seq_len = 128

[parallel]
fsdp = 8
replicas = 2

[run]
system = "vescale"
optimizer = "adam8bit"
backend = "threaded"
steps = 100
lr = 0.0003
prefetch = 2
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(c.get("model.preset"), Some("small"));
        assert_eq!(c.usize_or("parallel.fsdp", 0), 8);
        assert_eq!(c.f64_or("run.lr", 0.0), 0.0003);
    }

    #[test]
    fn comm_section_overrides_hier_threshold() {
        let c = ConfigFile::parse("[comm]\nhier_threshold = 4096\n").unwrap();
        assert_eq!(c.train_config().unwrap().hier_threshold, 4096);
        let d = ConfigFile::parse("").unwrap().train_config().unwrap();
        assert_eq!(d.hier_threshold, crate::cluster::DEFAULT_HIER_THRESHOLD);
    }

    #[test]
    fn train_config_materializes() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        let tc = c.train_config().unwrap();
        assert_eq!(tc.model, "small");
        assert_eq!(tc.parallel.total_devices(), 16);
        assert_eq!(tc.optimizer, OptimKind::Adam8bit);
        assert_eq!(tc.system, System::VeScale);
        assert_eq!(tc.steps, 100);
        assert_eq!(tc.backend, CommBackend::Threaded);
        assert_eq!(tc.prefetch, 2);
    }

    #[test]
    fn defaults_apply() {
        let tc = ConfigFile::parse("").unwrap().train_config().unwrap();
        assert_eq!(tc.model, "tiny");
        assert_eq!(tc.parallel.fsdp, 4);
    }

    #[test]
    fn rejects_garbage() {
        assert!(ConfigFile::parse("[unclosed").is_err());
        assert!(ConfigFile::parse("no equals here").is_err());
        let bad = ConfigFile::parse("[run]\nsystem = \"bogus\"").unwrap();
        assert!(bad.train_config().is_err());
    }

    #[test]
    fn comments_ignored() {
        let c = ConfigFile::parse("a = 1 # trailing\n# full line\n").unwrap();
        assert_eq!(c.usize_or("a", 0), 1);
    }

    const MIXED: &str = r#"
[model]
preset = "tiny"

[run]
optimizer = "adamw"
fabric = "h100"
comm_precision = "bf16"

[group.layers]
optimizer = "muon"
lr = 0.02

[group.head]
rows = 32
reshard_after_forward = false
comm_precision = "q8:128"
"#;

    #[test]
    fn group_sections_deserialize_into_overrides() {
        let c = ConfigFile::parse(MIXED).unwrap();
        let tc = c.train_config().unwrap();
        assert_eq!(tc.fabric, "h100");
        assert_eq!(tc.groups.len(), 2);
        let layers = tc.groups.iter().find(|o| o.which == "layers").unwrap();
        assert_eq!(layers.optim, Some(crate::fsdp::spec::OptimBinding::Muon));
        assert_eq!(layers.lr, Some(0.02));
        let head = tc.groups.iter().find(|o| o.which == "head").unwrap();
        assert_eq!(head.rows, Some(32));
        assert_eq!(head.reshard, Some(false));
        assert!(head.optim.is_none());
        assert_eq!(tc.comm_precision, "bf16");
        assert_eq!(head.comm, Some(CommPrecision::Q8 { block: 128 }));
        assert!(tc.groups.iter().find(|o| o.which == "layers").unwrap().comm.is_none());
    }

    #[test]
    fn topology_section_parses_and_validates() {
        let c = ConfigFile::parse("[topology]\nshape = \"2x4\"\nsegments = 4").unwrap();
        assert_eq!(c.train_config().unwrap().topology, "2x4:4");
        let r = ConfigFile::parse("[run]\ntopology = \"4x8:2\"").unwrap();
        assert_eq!(r.train_config().unwrap().topology, "4x8:2");
        let bad = ConfigFile::parse("[topology]\nshape = \"0x4\"").unwrap();
        assert!(bad.train_config().is_err());
        let word = ConfigFile::parse("[run]\ntopology = \"ring\"").unwrap();
        assert!(word.train_config().is_err());
        // default stays flat (empty)
        assert_eq!(ConfigFile::parse("").unwrap().train_config().unwrap().topology, "");
    }

    #[test]
    fn obs_section_parses_and_rejects_typos() {
        let c = ConfigFile::parse(
            "[obs]\nwatchdog_ms = 250\nmetrics = \"m.prom\"\npostmortem = true",
        )
        .unwrap();
        let tc = c.train_config().unwrap();
        assert_eq!(tc.watchdog_ms, 250);
        assert_eq!(tc.metrics.as_deref(), Some("m.prom"));
        assert!(tc.postmortem);
        // defaults: monitor fully off
        let d = ConfigFile::parse("").unwrap().train_config().unwrap();
        assert_eq!(d.watchdog_ms, 0);
        assert!(d.metrics.is_none());
        assert!(!d.postmortem);
        // typos and bad values are errors
        let bad_field = ConfigFile::parse("[obs]\nwatchdog = 250").unwrap();
        assert!(bad_field.train_config().is_err());
        let bad_ms = ConfigFile::parse("[obs]\nwatchdog_ms = \"soon\"").unwrap();
        assert!(bad_ms.train_config().is_err());
        let bad_pm = ConfigFile::parse("[obs]\npostmortem = \"maybe\"").unwrap();
        assert!(bad_pm.train_config().is_err());
    }

    #[test]
    fn group_section_rejects_typos() {
        let bad_field = ConfigFile::parse("[group.embed]\nrowz = 32").unwrap();
        assert!(bad_field.group_overrides().is_err());
        let bad_opt = ConfigFile::parse("[group.embed]\noptimizer = \"lion\"").unwrap();
        assert!(bad_opt.group_overrides().is_err());
        let bad_fabric = ConfigFile::parse("[run]\nfabric = \"tpu\"").unwrap();
        assert!(bad_fabric.train_config().is_err());
        let bad_prec = ConfigFile::parse("[run]\ncomm_precision = \"int3\"").unwrap();
        assert!(bad_prec.train_config().is_err());
        let bad_group_prec =
            ConfigFile::parse("[group.embed]\ncomm_precision = \"q8:0\"").unwrap();
        assert!(bad_group_prec.group_overrides().is_err());
    }
}
