//! Model-shape presets: the paper's evaluation workloads.
//!
//! Shapes are taken from the public architectures (LLaMA-3-70B,
//! GPT-OSS-120B, DeepSeek-V3-671B); the "internal" MoE models of §6.2 are
//! reconstructed from the stated totals (800B weak/strong scaling,
//! 400B–2.4T model scaling) with constant sparsity. Only *shapes* are
//! consumed by the planner / memory / comm layers, so these presets are
//! exact where the paper's effects live (expert fusion vs per-expert
//! tensors, row sizes, layer structure).

use crate::tensor::DType;

/// One named parameter tensor (symbolic — no data).
#[derive(Debug, Clone)]
pub struct ParamDecl {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ParamDecl {
    pub fn new(name: String, shape: &[usize]) -> ParamDecl {
        ParamDecl { name, shape: shape.to_vec(), dtype: DType::F32 }
    }

    pub fn numel(&self) -> u64 {
        self.shape.iter().map(|&s| s as u64).product()
    }

    /// Row size (elements) — the natural RaggedShard granularity unit.
    pub fn row_size(&self) -> u64 {
        if self.shape.len() >= 2 {
            self.shape[1..].iter().map(|&s| s as u64).product()
        } else {
            1
        }
    }
}

/// FSDP wrapping unit: one communication bucket (a transformer layer, or
/// the embedding/head). Mirrors user-defined `fully_shard` wrapping.
#[derive(Debug, Clone)]
pub struct ParamGroup {
    pub name: String,
    pub params: Vec<ParamDecl>,
}

impl ParamGroup {
    pub fn numel(&self) -> u64 {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

#[derive(Debug, Clone)]
pub struct MoeInfo {
    pub experts: usize,
    pub top_k: usize,
    /// GPT-OSS fuses all experts into one tensor; DSv3 keeps them separate.
    pub fused_experts: bool,
}

#[derive(Debug, Clone)]
pub struct ModelPreset {
    pub name: String,
    pub groups: Vec<ParamGroup>,
    pub n_layers: usize,
    pub d_model: usize,
    pub seq_default: usize,
    pub moe: Option<MoeInfo>,
}

impl ModelPreset {
    pub fn total_params(&self) -> u64 {
        self.groups.iter().map(|g| g.numel()).sum()
    }

    pub fn all_params(&self) -> Vec<&ParamDecl> {
        self.groups.iter().flat_map(|g| g.params.iter()).collect()
    }

    /// Active parameters per token (MoE activates top_k of experts).
    pub fn active_params(&self) -> f64 {
        match &self.moe {
            None => self.total_params() as f64,
            Some(moe) => {
                let expert: u64 = self
                    .all_params()
                    .iter()
                    .filter(|p| p.name.contains("expert"))
                    .map(|p| p.numel())
                    .sum();
                let dense = self.total_params() - expert;
                dense as f64
                    + expert as f64 * moe.top_k as f64 / moe.experts as f64
            }
        }
    }

    /// FLOPs per token (fwd+bwd ~ 6 * active params).
    pub fn flops_per_token(&self) -> f64 {
        6.0 * self.active_params()
    }

    /// The preset's wrap units as a declarative shard spec: one
    /// [`crate::fsdp::spec::ShardGroupSpec`] per [`ParamGroup`], filtered
    /// by exact parameter names (so `plan`-style tooling and the numeric
    /// engine consume the same `fully_shard` graph).
    pub fn shard_spec(&self) -> crate::fsdp::spec::ModelSpec {
        use crate::fsdp::spec::{GroupFilter, ModelSpec, ShardGroupSpec};
        let mut spec = ModelSpec::new();
        for g in &self.groups {
            spec = spec.group(ShardGroupSpec::new(
                g.name.clone(),
                GroupFilter::Names(g.params.iter().map(|p| p.name.clone()).collect()),
            ));
        }
        spec
    }

    /// The preset's parameter table in the engine's (name, shape) form.
    pub fn param_table(&self) -> Vec<(String, Vec<usize>)> {
        self.all_params()
            .iter()
            .map(|p| (p.name.clone(), p.shape.clone()))
            .collect()
    }
}

fn p(name: String, shape: &[usize]) -> ParamDecl {
    ParamDecl::new(name, shape)
}

/// LLaMA-3-70B (dense): 80 layers, d=8192, ffn=28672, GQA 64/8 heads,
/// vocab 128256. ~70.6B params.
pub fn llama70b() -> ModelPreset {
    let (d, ff, vocab, layers) = (8192usize, 28672usize, 128256usize, 80usize);
    let kv = d / 8; // 8 KV heads of 128
    let mut groups = vec![ParamGroup {
        name: "embed".into(),
        params: vec![p("embed.weight".into(), &[vocab, d])],
    }];
    for i in 0..layers {
        let n = |s: &str| format!("layers.{i}.{s}");
        groups.push(ParamGroup {
            name: format!("layers.{i}"),
            params: vec![
                p(n("input_norm"), &[d]),
                p(n("attn.wq"), &[d, d]),
                p(n("attn.wk"), &[kv, d]),
                p(n("attn.wv"), &[kv, d]),
                p(n("attn.wo"), &[d, d]),
                p(n("post_norm"), &[d]),
                p(n("mlp.gate"), &[ff, d]),
                p(n("mlp.up"), &[ff, d]),
                p(n("mlp.down"), &[d, ff]),
            ],
        });
    }
    groups.push(ParamGroup {
        name: "head".into(),
        params: vec![p("final_norm".into(), &[d]), p("head.weight".into(), &[vocab, d])],
    });
    ModelPreset {
        name: "llama70b".into(),
        groups,
        n_layers: layers,
        d_model: d,
        seq_default: 4096,
        moe: None,
    }
}

/// GPT-OSS-120B (sparse): 36 layers, d=2880, 128 experts fused into one
/// tensor per projection per layer, top-4. ~117B params.
pub fn gptoss120b() -> ModelPreset {
    let (d, layers, experts, vocab) = (2880usize, 36usize, 128usize, 201088usize);
    let eff = 2880usize; // expert ffn width
    let mut groups = vec![ParamGroup {
        name: "embed".into(),
        params: vec![p("embed.weight".into(), &[vocab, d])],
    }];
    for i in 0..layers {
        let n = |s: &str| format!("layers.{i}.{s}");
        groups.push(ParamGroup {
            name: format!("layers.{i}"),
            params: vec![
                p(n("norm1"), &[d]),
                p(n("attn.wqkv"), &[d + 2 * (d / 8), d]),
                p(n("attn.wo"), &[d, d]),
                p(n("norm2"), &[d]),
                p(n("router"), &[experts, d]),
                // all experts fused into single tensors (the Fig-11 culprit)
                p(n("experts.mlp1"), &[experts, 2 * eff, d]),
                p(n("experts.mlp2"), &[experts, d, eff]),
            ],
        });
    }
    groups.push(ParamGroup {
        name: "head".into(),
        params: vec![p("final_norm".into(), &[d]), p("head.weight".into(), &[vocab, d])],
    });
    ModelPreset {
        name: "gptoss120b".into(),
        groups,
        n_layers: layers,
        d_model: d,
        seq_default: 8192,
        moe: Some(MoeInfo { experts, top_k: 4, fused_experts: true }),
    }
}

/// DeepSeek-V3-671B: 61 layers (3 dense + 58 MoE), d=7168, 256 routed
/// experts + 1 shared, expert ffn=2048, **per-expert separate tensors**.
pub fn dsv3_671b() -> ModelPreset {
    let (d, layers, experts, eff, vocab) = (7168usize, 61usize, 256usize, 2048usize, 129280usize);
    let dense_ff = 18432usize;
    let mut groups = vec![ParamGroup {
        name: "embed".into(),
        params: vec![p("embed.weight".into(), &[vocab, d])],
    }];
    for i in 0..layers {
        let n = |s: &str| format!("layers.{i}.{s}");
        let mut params = vec![
            p(n("norm1"), &[d]),
            // MLA attention (compressed projections, approximated shapes)
            p(n("attn.q_a"), &[1536, d]),
            p(n("attn.q_b"), &[24576, 1536]),
            p(n("attn.kv_a"), &[576, d]),
            p(n("attn.kv_b"), &[32768, 512]),
            p(n("attn.wo"), &[d, 16384]),
            p(n("norm2"), &[d]),
        ];
        if i < 3 {
            params.push(p(n("mlp.gate"), &[dense_ff, d]));
            params.push(p(n("mlp.up"), &[dense_ff, d]));
            params.push(p(n("mlp.down"), &[d, dense_ff]));
        } else {
            params.push(p(n("router"), &[experts, d]));
            // shared expert
            params.push(p(n("shared_expert.gate"), &[eff, d]));
            params.push(p(n("shared_expert.up"), &[eff, d]));
            params.push(p(n("shared_expert.down"), &[d, eff]));
            // each routed expert is its own parameter (per-expert padding
            // is legal between them — the Fig-11 contrast with GPT-OSS)
            for e in 0..experts {
                params.push(p(n(&format!("experts.{e}.gate")), &[eff, d]));
                params.push(p(n(&format!("experts.{e}.up")), &[eff, d]));
                params.push(p(n(&format!("experts.{e}.down")), &[d, eff]));
            }
        }
        groups.push(ParamGroup { name: format!("layers.{i}"), params });
    }
    groups.push(ParamGroup {
        name: "head".into(),
        params: vec![p("final_norm".into(), &[d]), p("head.weight".into(), &[vocab, d])],
    });
    ModelPreset {
        name: "dsv3_671b".into(),
        groups,
        n_layers: layers,
        d_model: d,
        seq_default: 4096,
        moe: Some(MoeInfo { experts, top_k: 8, fused_experts: false }),
    }
}

/// Reconstructed "internal MoE" family (§6.2): constant sparsity, scaled
/// depth x width. `total_b` is the target total parameters in billions
/// (800 for weak/strong scaling; 400..2400 for model scaling).
pub fn moe_internal(total_b: f64) -> ModelPreset {
    // base point: 800B <- 64 layers, d=6144, 128 experts, eff=5120, top-8
    // (128 * 3 * 5120 * 6144 ≈ 12.1B expert params/layer x 64 ≈ 774B).
    // scale depth and width with total^(1/3) each (proportional scaling,
    // paper §6.2 "we scale both depth and width proportionally").
    let scale = (total_b / 800.0).powf(1.0 / 3.0);
    let layers = ((64.0 * scale).round() as usize).max(8);
    let d = (((6144.0 * scale) / 128.0).round() as usize * 128).max(512);
    let experts = 128usize;
    let eff = (((5120.0 * scale) / 128.0).round() as usize * 128).max(256);
    let vocab = 131072usize;
    let mut groups = vec![ParamGroup {
        name: "embed".into(),
        params: vec![p("embed.weight".into(), &[vocab, d])],
    }];
    for i in 0..layers {
        let n = |s: &str| format!("layers.{i}.{s}");
        let mut params = vec![
            p(n("norm1"), &[d]),
            p(n("attn.wqkv"), &[d + 2 * (d / 8), d]),
            p(n("attn.wo"), &[d, d]),
            p(n("norm2"), &[d]),
            p(n("router"), &[experts, d]),
        ];
        for e in 0..experts {
            params.push(p(n(&format!("experts.{e}.w1")), &[2 * eff, d]));
            params.push(p(n(&format!("experts.{e}.w2")), &[d, eff]));
        }
        groups.push(ParamGroup { name: format!("layers.{i}"), params });
    }
    groups.push(ParamGroup {
        name: "head".into(),
        params: vec![p("final_norm".into(), &[d]), p("head.weight".into(), &[vocab, d])],
    });
    ModelPreset {
        name: format!("moe{}b", total_b as u64),
        groups,
        n_layers: layers,
        d_model: d,
        seq_default: 8192,
        moe: Some(MoeInfo { experts, top_k: 8, fused_experts: false }),
    }
}

/// Tiny dense preset matching `python/compile/model.py` `tiny`/`small`
/// (the numeric-path configs); shapes must agree with the manifest ABI.
pub fn tiny_like(name: &str, vocab: usize, d: usize, layers: usize, ff: usize) -> ModelPreset {
    let mut groups = vec![ParamGroup {
        name: "embed".into(),
        params: vec![p("embed.weight".into(), &[vocab, d])],
    }];
    for i in 0..layers {
        let n = |s: &str| format!("layers.{i}.{s}");
        groups.push(ParamGroup {
            name: format!("layers.{i}"),
            params: vec![
                p(n("ln1.scale"), &[d]),
                p(n("attn.wq"), &[d, d]),
                p(n("attn.wk"), &[d, d]),
                p(n("attn.wv"), &[d, d]),
                p(n("attn.wo"), &[d, d]),
                p(n("ln2.scale"), &[d]),
                p(n("mlp.w1"), &[d, ff]),
                p(n("mlp.w2"), &[ff, d]),
            ],
        });
    }
    groups.push(ParamGroup {
        name: "head".into(),
        params: vec![p("final_ln.scale".into(), &[d]), p("head.weight".into(), &[d, vocab])],
    });
    ModelPreset {
        name: name.into(),
        groups,
        n_layers: layers,
        d_model: d,
        seq_default: 64,
        moe: None,
    }
}

/// Look up a preset by name.
pub fn by_name(name: &str) -> Option<ModelPreset> {
    Some(match name {
        "llama70b" => llama70b(),
        "gptoss120b" => gptoss120b(),
        "dsv3_671b" | "dsv3" => dsv3_671b(),
        "moe800b" => moe_internal(800.0),
        "moe400b" => moe_internal(400.0),
        "moe1200b" => moe_internal(1200.0),
        "moe2400b" => moe_internal(2400.0),
        "tiny" => tiny_like("tiny", 512, 128, 2, 512),
        "small" => tiny_like("small", 2048, 256, 4, 1024),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama70b_param_count() {
        let m = llama70b();
        let b = m.total_params() as f64 / 1e9;
        assert!((69.0..73.0).contains(&b), "llama70b = {b}B");
        assert!(m.moe.is_none());
        assert_eq!(m.groups.len(), 82); // embed + 80 layers + head
    }

    #[test]
    fn preset_shard_spec_covers_every_parameter() {
        let m = tiny_like("t", 512, 64, 3, 256);
        let spec = m.shard_spec();
        assert_eq!(spec.groups.len(), m.groups.len());
        let table = m.param_table();
        let group_of = spec.assign(&table).unwrap();
        // wrap-unit order is preserved and every parameter is claimed
        assert_eq!(group_of.len(), table.len());
        for (gi, g) in m.groups.iter().enumerate() {
            for p in &g.params {
                let i = table.iter().position(|(n, _)| n == &p.name).unwrap();
                assert_eq!(group_of[i], gi, "{}", p.name);
            }
        }
    }

    #[test]
    fn gptoss120b_param_count_and_fusion() {
        let m = gptoss120b();
        let b = m.total_params() as f64 / 1e9;
        assert!((110.0..125.0).contains(&b), "gptoss = {b}B");
        let moe = m.moe.as_ref().unwrap();
        assert!(moe.fused_experts);
        // fused expert tensor has the expert dim leading
        let fused = m
            .all_params()
            .into_iter()
            .find(|p| p.name.contains("experts.mlp1"))
            .unwrap();
        assert_eq!(fused.shape[0], 128);
    }

    #[test]
    fn gptoss_active_params_sparse() {
        let m = gptoss120b();
        let active = m.active_params() / 1e9;
        // paper-card: ~5.1B active
        assert!((3.0..9.0).contains(&active), "active = {active}B");
    }

    #[test]
    fn dsv3_param_count_and_per_expert() {
        let m = dsv3_671b();
        let b = m.total_params() as f64 / 1e9;
        assert!((620.0..700.0).contains(&b), "dsv3 = {b}B");
        assert!(!m.moe.as_ref().unwrap().fused_experts);
        // experts are separate tensors
        let n_expert_tensors = m
            .all_params()
            .iter()
            .filter(|p| p.name.contains("experts."))
            .count();
        assert_eq!(n_expert_tensors, 58 * 256 * 3);
    }

    #[test]
    fn moe_internal_scales() {
        let m800 = moe_internal(800.0);
        let b800 = m800.total_params() as f64 / 1e9;
        assert!((600.0..1000.0).contains(&b800), "moe800 = {b800}B");
        let m2400 = moe_internal(2400.0);
        assert!(m2400.total_params() > 2 * m800.total_params());
        let m400 = moe_internal(400.0);
        assert!(m400.total_params() < m800.total_params());
    }

    #[test]
    fn tiny_matches_python_abi_count() {
        // must agree with python/compile/model.py param_specs('tiny')
        let m = by_name("tiny").unwrap();
        let expected = 2 * 512 * 128
            + 2 * (4 * 128 * 128 + 2 * 128 * 512 + 2 * 128)
            + 128;
        assert_eq!(m.total_params(), expected as u64);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("llama70b").is_some());
        assert!(by_name("unknown").is_none());
    }

    #[test]
    fn row_sizes() {
        let m = llama70b();
        let wq = m.all_params().into_iter().find(|p| p.name.contains("wq")).unwrap();
        assert_eq!(wq.row_size(), 8192);
        let norm = m.all_params().into_iter().find(|p| p.name.contains("norm")).unwrap();
        assert_eq!(norm.row_size(), 1);
    }
}
