//! Config system: model presets (the paper's workloads, with exact public
//! parameter shapes), parallelism / training configs, and a TOML-subset
//! config-file parser for the launcher.
//!
//! The presets matter because the paper's planner/memory/communication
//! results depend only on tensor *shapes*: GPT-OSS-120B fuses all 128
//! experts into one parameter tensor per layer (which is why its 128-row
//! granularity padding spikes in Fig 11 and why FSDP2 OOMs at 256 devices),
//! while DeepSeek-V3 materializes each expert separately (per-expert
//! padding relaxes the constraint). LLaMA-3-70B is the dense baseline.

pub mod file;
pub mod presets;

pub use crate::cluster::CommBackend;
pub use presets::{ModelPreset, MoeInfo, ParamDecl, ParamGroup};

use crate::fsdp::spec::OptimBinding;
use crate::quant::CommPrecision;

/// One `[group.<which>]` config-file section: per-group edits applied on
/// top of the layerwise wrapping at session build time. `which` is a
/// group name (`embed`, `head`, `layer3`, ...) or `layers`, which targets
/// every layer group.
#[derive(Debug, Clone, Default)]
pub struct GroupOverride {
    pub which: String,
    /// Optimizer binding for the group(s).
    pub optim: Option<OptimBinding>,
    /// Row sharding granularity (0 = element-wise).
    pub rows: Option<u64>,
    /// Element sharding granularity (overrides the policy default).
    pub granularity: Option<u64>,
    /// Reshard-after-forward toggle.
    pub reshard: Option<bool>,
    /// Group-local learning rate.
    pub lr: Option<f32>,
    /// Wire precision of the group's collectives
    /// (`comm_precision = "f32" | "bf16" | "q8[:block]"`).
    pub comm: Option<CommPrecision>,
}

/// Which FSDP implementation to run (paper §6 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    VeScale,
    DeepSpeed,
    Fsdp1,
    Fsdp2,
    MegatronFsdp,
    /// Plain data parallel (Fig 10 convergence baseline).
    Ddp,
}

impl System {
    pub fn name(&self) -> &'static str {
        match self {
            System::VeScale => "veScale-FSDP",
            System::DeepSpeed => "DeepSpeed",
            System::Fsdp1 => "FSDP1",
            System::Fsdp2 => "FSDP2",
            System::MegatronFsdp => "Megatron-FSDP",
            System::Ddp => "DDP",
        }
    }

    pub fn parse(s: &str) -> Option<System> {
        Some(match s.to_ascii_lowercase().as_str() {
            "vescale" | "vescale-fsdp" => System::VeScale,
            "deepspeed" | "zero" => System::DeepSpeed,
            "fsdp1" => System::Fsdp1,
            "fsdp2" => System::Fsdp2,
            "megatron" | "megatron-fsdp" => System::MegatronFsdp,
            "ddp" => System::Ddp,
            _ => return None,
        })
    }

    pub fn all() -> [System; 5] {
        [
            System::DeepSpeed,
            System::Fsdp1,
            System::Fsdp2,
            System::MegatronFsdp,
            System::VeScale,
        ]
    }
}

/// Optimizer selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimKind {
    Sgd,
    AdamW,
    Adam8bit,
    Muon,
}

impl OptimKind {
    pub fn name(&self) -> &'static str {
        match self {
            OptimKind::Sgd => "sgd",
            OptimKind::AdamW => "adamw",
            OptimKind::Adam8bit => "adam8bit",
            OptimKind::Muon => "muon",
        }
    }

    pub fn parse(s: &str) -> Option<OptimKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "sgd" => OptimKind::Sgd,
            "adamw" | "adam" => OptimKind::AdamW,
            "adam8bit" | "8bit" | "adam8" => OptimKind::Adam8bit,
            "muon" => OptimKind::Muon,
            _ => return None,
        })
    }

    /// Optimizer state bytes per (fp32-master) parameter element, on top
    /// of the master weight itself.
    pub fn state_bytes_per_param(&self) -> f64 {
        match self {
            OptimKind::Sgd => 0.0,
            OptimKind::AdamW => 8.0,             // m + v fp32
            OptimKind::Adam8bit => 2.0 + 8.0 / 1024.0, // int8 m+v + scales
            OptimKind::Muon => 4.0,              // momentum fp32
        }
    }
}

/// Parallelism layout for a run (paper Fig 8/9 sweeps).
#[derive(Debug, Clone)]
pub struct ParallelConfig {
    /// FSDP shard-group size.
    pub fsdp: usize,
    /// HSDP replication factor (1 = plain FSDP).
    pub replicas: usize,
    /// Expert-parallel group size (1 = no EP).
    pub ep: usize,
}

impl ParallelConfig {
    pub fn fsdp_only(m: usize) -> ParallelConfig {
        ParallelConfig { fsdp: m, replicas: 1, ep: 1 }
    }

    pub fn total_devices(&self) -> usize {
        self.fsdp * self.replicas
    }

    pub fn label(&self) -> String {
        if self.replicas > 1 {
            format!("HSDP {}x{}", self.replicas, self.fsdp)
        } else if self.ep > 1 {
            format!("FSDP {} xEP {}", self.fsdp, self.ep)
        } else {
            format!("FSDP {}", self.fsdp)
        }
    }
}

/// Full training-run config consumed by the launcher.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub parallel: ParallelConfig,
    pub optimizer: OptimKind,
    pub system: System,
    pub steps: usize,
    pub seq_len: usize,
    pub micro_batch: usize,
    pub lr: f64,
    pub seed: u64,
    /// Sharding granularity override (elements; 0 = element-wise).
    pub granularity: u64,
    /// Cluster backend executing collectives + per-rank compute.
    pub backend: CommBackend,
    /// In-flight bucket-collective cap for the pipelined executor
    /// (`--prefetch`): 0 = sequential step loop, N >= 1 = bucket-wise
    /// schedule with up to N prefetched gathers.
    pub prefetch: usize,
    /// Fabric preset name (`run.fabric` / `--fabric`): h800 | h100 | a100.
    pub fabric: String,
    /// Cluster topology (`run.topology` / `[topology]` / `--topology`):
    /// `"HxG"` or `"HxG:S"` (hosts x gpus-per-host, S pipeline segments).
    /// Empty = flat single-tier collectives.
    pub topology: String,
    /// Session-default wire precision (`run.comm_precision` /
    /// `--comm-precision`): f32 | bf16 | q8[:block].
    pub comm_precision: String,
    /// Serial-fallback / two-level dispatch threshold in total elements
    /// (`[comm] hier_threshold` / `--hier-threshold`), consulted by both
    /// runtime dispatch and the static analyzer's tier modeling.
    /// Defaults to [`crate::cluster::DEFAULT_HIER_THRESHOLD`].
    pub hier_threshold: usize,
    /// Chrome-trace output path (`run.trace` / `[trace] out` / `--trace`).
    /// `None` = tracing off.
    pub trace: Option<String>,
    /// Trace detail (`[trace] level` / `--trace-level`): off | comm | full.
    pub trace_level: String,
    /// Collective-watchdog deadline in milliseconds (`[obs] watchdog_ms`
    /// / `--watchdog-ms`); 0 keeps the watchdog off. Any nonzero value
    /// (or `metrics` / `postmortem` below) arms the health monitor.
    pub watchdog_ms: u64,
    /// Metrics snapshot path (`[obs] metrics` / `--metrics`): a `.prom`
    /// extension writes Prometheus text format, anything else the
    /// `fsdp-metrics-v1` JSON. `None` = no export.
    pub metrics: Option<String>,
    /// Write a postmortem JSON on exit, watchdog firing, or panic
    /// (`[obs] postmortem` / `--postmortem-on-exit`).
    pub postmortem: bool,
    /// Per-group `[group.*]` overrides, applied on the layerwise wrapping.
    pub groups: Vec<GroupOverride>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "tiny".into(),
            parallel: ParallelConfig::fsdp_only(4),
            optimizer: OptimKind::AdamW,
            system: System::VeScale,
            steps: 50,
            seq_len: 64,
            micro_batch: 4,
            lr: 3e-4,
            seed: 0,
            granularity: 1,
            backend: CommBackend::Serial,
            prefetch: 0,
            fabric: "h800".into(),
            topology: String::new(),
            comm_precision: "f32".into(),
            hier_threshold: crate::cluster::DEFAULT_HIER_THRESHOLD,
            trace: None,
            trace_level: "comm".into(),
            watchdog_ms: 0,
            metrics: None,
            postmortem: false,
            groups: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_parse_roundtrip() {
        for s in System::all() {
            assert_eq!(System::parse(s.name()), Some(s));
        }
        assert_eq!(System::parse("nonsense"), None);
    }

    #[test]
    fn optim_state_bytes() {
        assert_eq!(OptimKind::AdamW.state_bytes_per_param(), 8.0);
        assert!(OptimKind::Adam8bit.state_bytes_per_param() < 2.1);
        assert_eq!(OptimKind::Sgd.state_bytes_per_param(), 0.0);
    }

    #[test]
    fn parallel_labels() {
        assert_eq!(ParallelConfig::fsdp_only(128).label(), "FSDP 128");
        let h = ParallelConfig { fsdp: 256, replicas: 4, ep: 1 };
        assert_eq!(h.label(), "HSDP 4x256");
        assert_eq!(h.total_devices(), 1024);
    }
}
