//! DTensor placements, including the paper's contribution: **RaggedShard**
//! (paper §4) and its Shard(0)-composition variant **StridedRaggedShard**.
//!
//! A `RaggedSpec` describes arbitrary sharding granularity (the atomic
//! non-shardable block, in contiguous elements) and arbitrary distribution
//! (number of such blocks per device). `Placement::Shard` / `Replicate` /
//! `Partial` mirror PyTorch DTensor; RaggedShard generalizes them all
//! (Fig 4): element-wise shard = granularity 1, row-wise even shard =
//! granularity row-stride with equal distribution.

use anyhow::{bail, Result};

use crate::util::{ceil_div, lcm};

/// Ragged sharding spec over a flat (contiguous) view of a tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RaggedSpec {
    /// Elements per atomic block (never split across devices).
    pub granularity: u64,
    /// Number of blocks owned by each device, in rank order. May be 0 for
    /// some devices — that is the flexibility matrix optimizers need.
    pub blocks_per_device: Vec<u64>,
}

impl RaggedSpec {
    /// Validate against a tensor of `numel` elements. The final block may
    /// be a tail block (shorter than `granularity`) — everything before it
    /// must be full blocks.
    pub fn validate(&self, numel: u64) -> Result<()> {
        if self.granularity == 0 {
            bail!("granularity must be > 0");
        }
        let total_blocks: u64 = self.blocks_per_device.iter().sum();
        let need = ceil_div(numel, self.granularity);
        if total_blocks != need {
            bail!(
                "RaggedSpec covers {total_blocks} blocks but tensor of \
                 {numel} elements needs {need} (granularity {})",
                self.granularity
            );
        }
        Ok(())
    }

    pub fn num_devices(&self) -> usize {
        self.blocks_per_device.len()
    }

    /// Balanced distribution of ceil(numel/g) blocks over m devices — the
    /// layout the planner starts from.
    pub fn balanced(numel: u64, granularity: u64, m: usize) -> RaggedSpec {
        let blocks = ceil_div(numel, granularity);
        let base = blocks / m as u64;
        let extra = (blocks % m as u64) as usize;
        let blocks_per_device = (0..m)
            .map(|k| base + if k < extra { 1 } else { 0 })
            .collect();
        RaggedSpec { granularity, blocks_per_device }
    }

    /// Everything on one root device (Muon's unshard target, Alg 2 line 8).
    pub fn on_root(numel: u64, granularity: u64, m: usize, root: usize) -> RaggedSpec {
        let blocks = ceil_div(numel, granularity);
        let mut blocks_per_device = vec![0u64; m];
        blocks_per_device[root] = blocks;
        RaggedSpec { granularity, blocks_per_device }
    }

    /// Element range `[lo, hi)` of the global flat tensor owned by `rank`.
    pub fn local_range(&self, rank: usize, numel: u64) -> (u64, u64) {
        let mut block_start = 0u64;
        for k in 0..rank {
            block_start += self.blocks_per_device[k];
        }
        let block_end = block_start + self.blocks_per_device[rank];
        let lo = (block_start * self.granularity).min(numel);
        let hi = (block_end * self.granularity).min(numel);
        (lo, hi)
    }

    pub fn local_numel(&self, rank: usize, numel: u64) -> u64 {
        let (lo, hi) = self.local_range(rank, numel);
        hi - lo
    }

    /// Max elements any device owns (drives buffer sizing).
    pub fn max_local_numel(&self, numel: u64) -> u64 {
        (0..self.num_devices())
            .map(|k| self.local_numel(k, numel))
            .max()
            .unwrap_or(0)
    }
}

/// DTensor placements. The list order follows the PyTorch convention the
/// paper discusses (§4 Fig 5): placements apply mesh-dim by mesh-dim, and
/// the *written* order is the reverse of conceptual application (EP/TP is
/// applied before FSDP but appears after RaggedShard in the list).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Placement {
    /// Full copy on every device of the mesh dim.
    Replicate,
    /// Even shard along tensor dim `d` (PyTorch Shard(d)).
    Shard(usize),
    /// Unreduced partial values (pending sum).
    Partial,
    /// The paper's format: arbitrary granularity + distribution.
    RaggedShard(RaggedSpec),
    /// RaggedShard composed under an inner Shard(0): carries the reorder
    /// stride needed to reshuffle when materializing the full tensor
    /// (paper §4, composition rule (i)).
    StridedRaggedShard(RaggedSpec, u64),
}

impl Placement {
    pub fn is_ragged(&self) -> bool {
        matches!(self, Placement::RaggedShard(_) | Placement::StridedRaggedShard(_, _))
    }

    pub fn ragged_spec(&self) -> Option<&RaggedSpec> {
        match self {
            Placement::RaggedShard(s) | Placement::StridedRaggedShard(s, _) => Some(s),
            _ => None,
        }
    }
}

/// The paper's composition rule (§4): when a tensor is already Shard(d)
/// along an inner mesh dim, the ragged granularity must never cut into
/// dim `d`.
///
/// * `Shard(0)` (rule i): the local tensor is a contiguous row-slab, so any
///   granularity is legal, but materialization needs a reshuffle — we
///   return a `StridedRaggedShard` carrying the original dim-0 stride.
/// * `Shard(d>0)` (rule ii): adapt granularity to
///   `LCM(stride(d-1 of local tensor), user granularity)` so blocks always
///   cover whole slices of the sharded dim.
pub fn compose_with_shard(
    user_granularity: u64,
    local_shape: &[usize],
    inner_shard_dim: usize,
) -> Result<(u64, bool)> {
    if local_shape.is_empty() {
        bail!("scalar tensors cannot compose with Shard");
    }
    if inner_shard_dim >= local_shape.len() {
        bail!(
            "Shard({inner_shard_dim}) out of range for {:?}",
            local_shape
        );
    }
    if inner_shard_dim == 0 {
        // rule (i): StridedRaggedShard with the row stride for reshuffle.
        Ok((user_granularity, true))
    } else {
        // rule (ii): a ragged block must never cut *into* the sharded dim,
        // so it has to cover whole slices of dim (inner_shard_dim - 1);
        // one such slice is `prod(local_shape[inner_shard_dim..])` elements
        // of the local tensor. Granularity = LCM(slice, user granularity).
        let slice: u64 = local_shape[inner_shard_dim..]
            .iter()
            .map(|&s| s as u64)
            .product();
        Ok((lcm(slice, user_granularity), false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_distribution() {
        let s = RaggedSpec::balanced(100, 10, 4);
        assert_eq!(s.blocks_per_device, vec![3, 3, 2, 2]);
        s.validate(100).unwrap();
    }

    #[test]
    fn balanced_with_tail_block() {
        // 105 elements, granularity 10 -> 11 blocks, last is a 5-elem tail
        let s = RaggedSpec::balanced(105, 10, 4);
        assert_eq!(s.blocks_per_device.iter().sum::<u64>(), 11);
        s.validate(105).unwrap();
        let total: u64 = (0..4).map(|k| s.local_numel(k, 105)).sum();
        assert_eq!(total, 105);
    }

    #[test]
    fn local_ranges_partition_tensor() {
        let s = RaggedSpec {
            granularity: 16,
            blocks_per_device: vec![1, 0, 3, 2],
        };
        s.validate(96).unwrap();
        let mut covered = 0;
        for k in 0..4 {
            let (lo, hi) = s.local_range(k, 96);
            assert_eq!(lo, covered);
            covered = hi;
        }
        assert_eq!(covered, 96);
        assert_eq!(s.local_numel(1, 96), 0); // zero-block device is legal
    }

    #[test]
    fn on_root_concentrates() {
        let s = RaggedSpec::on_root(64, 8, 4, 2);
        assert_eq!(s.local_numel(2, 64), 64);
        assert_eq!(s.local_numel(0, 64), 0);
        s.validate(64).unwrap();
    }

    #[test]
    fn validate_rejects_wrong_counts() {
        let s = RaggedSpec { granularity: 10, blocks_per_device: vec![5, 5] };
        assert!(s.validate(100).is_ok());
        assert!(s.validate(110).is_err());
        let z = RaggedSpec { granularity: 0, blocks_per_device: vec![1] };
        assert!(z.validate(1).is_err());
    }

    #[test]
    fn compose_shard0_gives_strided() {
        let (g, strided) = compose_with_shard(32, &[128, 512], 0).unwrap();
        assert_eq!(g, 32);
        assert!(strided);
    }

    #[test]
    fn compose_shard1_lcm_granularity() {
        // local tensor (64, 256) sharded along dim 1: ragged blocks must
        // cover whole rows -> granularity = LCM(256, user)
        let (g, strided) = compose_with_shard(96, &[64, 256], 1).unwrap();
        assert_eq!(g, lcm(256, 96));
        assert!(!strided);
    }

    #[test]
    fn compose_shard1_already_aligned() {
        let (g, _) = compose_with_shard(512, &[64, 256], 1).unwrap();
        assert_eq!(g, 512); // LCM(256, 512) = 512
    }

    #[test]
    fn generalizes_existing_formats() {
        // element-wise shard == granularity 1 (Fig 4)
        let elem = RaggedSpec::balanced(10, 1, 3);
        assert_eq!(elem.blocks_per_device, vec![4, 3, 3]);
        // row-wise even shard == granularity = row stride, equal blocks
        let row = RaggedSpec::balanced(8 * 4, 4, 4);
        assert_eq!(row.blocks_per_device, vec![2, 2, 2, 2]);
    }

    #[test]
    fn ragged_max_local() {
        let s = RaggedSpec { granularity: 8, blocks_per_device: vec![1, 4, 0] };
        assert_eq!(s.max_local_numel(40), 32);
    }
}
