//! Block-wise quantized mixed-precision communication (paper §6.3; Markov
//! et al., *Quantized Distributed Training of Large Models with
//! Convergence Guarantees*).
//!
//! The paper's flexibility claim is that RaggedShard "empowers block-wise
//! quantization": because the planner can keep every quantization block on
//! exactly one device, casting a shard to `{int8 codes, per-block f32
//! absmax scales}` needs no cross-device metadata. This module puts that
//! to work on the *wire*, not just in optimizer state:
//!
//! * [`CommPrecision`] — the per-shard-group wire policy (`F32` | `Bf16` |
//!   `Q8 { block }`), declared on `ShardGroupSpec`, selected via
//!   `SessionBuilder::comm_precision`, config `[group.*] comm_precision`,
//!   or `--comm-precision`. Choosing `Q8` feeds the block into the
//!   planner's granularity (lcm with the group's row granularity), so
//!   every quant block and its scale live entirely on one device.
//! * [`QBlockTensor`] + [`quant_block`]/[`dequant_block`] — symmetric
//!   linear int8 quantization over flat RaggedShard slices, matching
//!   `python/compile/kernels/blockwise_quant.py` bit-for-bit (absmax
//!   scale, round **half to even** like `jnp.round`, clip to ±127,
//!   zero blocks quantize with scale 1.0). Golden-vector parity with the
//!   Pallas kernel and `optim/adam8bit.rs` is asserted by
//!   `tests/quant_parity.rs` over shared JSON fixtures.
//! * [`encode_slot`]/[`decode_slot`] — the wire codec: codes are packed
//!   four per f32 word (scales ride behind them), so the simulated
//!   collectives genuinely move fewer words and the recorded
//!   [`WireVolume`] (payload vs scale vs packing pad) is *measured* from
//!   buffer sizes, not estimated.
//! * Cast-before-comm **AllGather** (implemented in
//!   [`DBuffer`](crate::dbuffer::DBuffer) over this codec): each rank
//!   encodes its own shard, the collective ships the packed wire buffers,
//!   and every rank — including the owner — decodes on arrival, so all
//!   ranks compute on identical dequantized parameters while the fp32
//!   master shards stay exact.
//! * Quantized **ReduceScatter with error feedback**
//!   ([`reduce_scatter_prec`]) — implemented as an all-to-all of encoded
//!   chunks plus a rank-ordered dequantize-and-sum at each destination
//!   (bit-identical across serial/threaded backends and across
//!   sequential/pipelined schedules). Per-rank residuals are held *in the
//!   shard* (one `S`-element f32 vector per rank per group): the residual
//!   is the aggregate quantization error of the rank's owned chunk,
//!   re-injected into the next step's reduction — the classic
//!   error-feedback operator `ĝ = C(g + e)`, `e ← (g + e) − ĝ` applied to
//!   the aggregated shard gradient. (A physical implementation would hold
//!   the same information as per-destination residuals at each sender; the
//!   simulation's god-view collective lets us keep the memory cost at one
//!   extra shard per rank, which is what `StepReport`/README account.)
//!
//! `F32` bypasses every code path in this module — trajectories are
//! bit-identical to the pre-quantization engine, enforced by
//! `tests/quant_comm.rs`.

use anyhow::{bail, Result};

use crate::cluster::Communicator;
use crate::util::ceil_div;

/// Quantization range of the int8 code (±127; −128 is unused, as in the
/// Pallas kernel and bitsandbytes).
pub const QMAX: f32 = 127.0;

/// Default quant block for `--comm-precision q8` when no `:block` suffix
/// is given. 64 elements keep the scale overhead at 1/16 of the payload
/// while staying fine-grained enough for gradient outliers.
pub const DEFAULT_Q8_BLOCK: usize = 64;

/// Wire precision of a shard group's parameter AllGather and gradient
/// ReduceScatter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommPrecision {
    /// Full-precision f32 wire — the legacy path, bit-identical to the
    /// pre-quantization engine.
    F32,
    /// Cast-before-comm bf16 (round-to-nearest-even truncation), two
    /// bytes per element, no scales.
    Bf16,
    /// Block-wise symmetric int8: one byte per element plus one f32
    /// absmax scale per `block` elements (~`1 + 4/block` bytes/element).
    /// Gradient ReduceScatter runs with shard-held error feedback.
    Q8 {
        /// Quantization block in elements; fed into the planner's
        /// granularity so blocks and scales never straddle devices.
        block: usize,
    },
}

impl CommPrecision {
    /// Parse `f32 | bf16 | q8 | q8:<block>` (case-insensitive).
    pub fn parse(s: &str) -> Option<CommPrecision> {
        let t = s.to_ascii_lowercase();
        match t.as_str() {
            "f32" | "fp32" | "full" => Some(CommPrecision::F32),
            "bf16" => Some(CommPrecision::Bf16),
            "q8" | "int8" => Some(CommPrecision::Q8 { block: DEFAULT_Q8_BLOCK }),
            _ => {
                let rest = t.strip_prefix("q8:").or_else(|| t.strip_prefix("int8:"))?;
                let block: usize = rest.parse().ok()?;
                if block == 0 {
                    return None;
                }
                Some(CommPrecision::Q8 { block })
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            CommPrecision::F32 => "f32".to_string(),
            CommPrecision::Bf16 => "bf16".to_string(),
            CommPrecision::Q8 { block } => format!("q8:{block}"),
        }
    }

    pub fn is_f32(&self) -> bool {
        matches!(self, CommPrecision::F32)
    }

    /// Sharding-granularity alignment this precision demands of the
    /// planner: `Q8` requires every per-device shard to hold a whole
    /// number of quant blocks (the engine lcm's this into both the tensor
    /// granularities and the collective alignment).
    pub fn align_elems(&self) -> u64 {
        match self {
            CommPrecision::Q8 { block } => *block as u64,
            _ => 1,
        }
    }

    /// f32 words one `elems`-element slot occupies on the wire.
    pub fn wire_words(&self, elems: usize) -> usize {
        match self {
            CommPrecision::F32 => elems,
            CommPrecision::Bf16 => elems.div_ceil(2),
            CommPrecision::Q8 { block } => elems.div_ceil(4) + elems.div_ceil(*block),
        }
    }

    /// Exact wire volume of one `elems`-element slot: payload bytes that
    /// carry tensor data, scale side-channel bytes, and word-packing pad.
    pub fn wire_volume(&self, elems: u64) -> WireVolume {
        match self {
            CommPrecision::F32 => WireVolume { payload: elems * 4, scale: 0, pad: 0 },
            CommPrecision::Bf16 => {
                let total = ceil_div(elems, 2) * 4;
                WireVolume { payload: elems * 2, scale: 0, pad: total - elems * 2 }
            }
            CommPrecision::Q8 { block } => {
                let scale = ceil_div(elems, *block as u64) * 4;
                let total = ceil_div(elems, 4) * 4 + scale;
                WireVolume { payload: elems, scale, pad: total - elems - scale }
            }
        }
    }
}

/// Measured wire bytes of one encoded slot, split the way the per-step
/// CSV and `BENCH_quant.json` report them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireVolume {
    /// Bytes carrying tensor data (4/elem f32, 2/elem bf16, 1/elem int8).
    pub payload: u64,
    /// Per-block f32 scale bytes (Q8 only).
    pub scale: u64,
    /// Word-packing remainder (tails of the 4-codes-per-word packing).
    pub pad: u64,
}

impl WireVolume {
    pub fn total(&self) -> u64 {
        self.payload + self.scale + self.pad
    }
}

/// `jnp.round` semantics — round half to **even** — which is what the
/// Pallas kernel applies; `f32::round` rounds half away from zero
/// instead. (Implemented by hand so the crate keeps building on older
/// stable toolchains without `f32::round_ties_even`.)
pub fn round_half_even(x: f32) -> f32 {
    let f = x.floor();
    let d = x - f;
    match d.partial_cmp(&0.5) {
        Some(std::cmp::Ordering::Less) => f,
        Some(std::cmp::Ordering::Greater) => f + 1.0,
        // exact tie (or NaN, which callers never pass): pick the even
        // neighbor, like jnp.round
        _ => {
            if (f as i64) % 2 == 0 {
                f
            } else {
                f + 1.0
            }
        }
    }
}

/// Quantize one block: symmetric linear absmax code, exactly the Pallas
/// `_quant_kernel` math (absmax scale with 1.0 fallback for zero blocks,
/// round half to even, clip to ±127). Returns the scale.
pub fn quant_block(x: &[f32], q: &mut [i8]) -> f32 {
    let absmax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let scale = if absmax > 0.0 { absmax } else { 1.0 };
    for (qi, &v) in q.iter_mut().zip(x) {
        *qi = round_half_even(v / scale * QMAX).clamp(-QMAX, QMAX) as i8;
    }
    scale
}

/// Dequantize one block: `q * scale / 127`, the Pallas `_dequant_kernel`.
pub fn dequant_block(q: &[i8], scale: f32, out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(q) {
        *o = c as f32 * scale / QMAX;
    }
}

/// A block-wise quantized tensor: int8 payload + per-block f32 absmax
/// scales over a flat (RaggedShard) slice. The final block may be a tail.
#[derive(Debug, Clone, PartialEq)]
pub struct QBlockTensor {
    pub codes: Vec<i8>,
    pub scales: Vec<f32>,
    pub block: usize,
    /// Original element count (== `codes.len()`).
    pub len: usize,
}

impl QBlockTensor {
    pub fn quantize(x: &[f32], block: usize) -> QBlockTensor {
        assert!(block > 0, "quant block must be positive");
        let nb = x.len().div_ceil(block);
        let mut codes = vec![0i8; x.len()];
        let mut scales = vec![1.0f32; nb];
        for (b, s) in scales.iter_mut().enumerate() {
            let lo = b * block;
            let hi = (lo + block).min(x.len());
            *s = quant_block(&x[lo..hi], &mut codes[lo..hi]);
        }
        QBlockTensor { codes, scales, block, len: x.len() }
    }

    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        for (b, &s) in self.scales.iter().enumerate() {
            let lo = b * self.block;
            let hi = (lo + self.block).min(self.len);
            dequant_block(&self.codes[lo..hi], s, &mut out[lo..hi]);
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.dequantize_into(&mut out);
        out
    }

    /// Measured wire bytes of this tensor under the packed codec.
    pub fn wire_volume(&self) -> WireVolume {
        CommPrecision::Q8 { block: self.block }.wire_volume(self.len as u64)
    }
}

// ---- bf16 helpers -------------------------------------------------------

/// f32 → bf16 bits with round-to-nearest-even (the standard truncation
/// used by cast-before-comm mixed precision).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let rounded = b.wrapping_add(0x7FFF + ((b >> 16) & 1));
    (rounded >> 16) as u16
}

pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

// ---- wire codec ---------------------------------------------------------

/// Encode `src` into its wire slot. `wire.len()` must equal
/// `prec.wire_words(src.len())`. Q8 packs four int8 codes per f32 word
/// (little-endian) followed by the per-block scales; Bf16 packs two
/// half-words per f32 word. Words are moved as raw bit patterns only
/// (memcpy'd by the collectives, never arithmetically touched).
pub fn encode_slot(prec: CommPrecision, src: &[f32], wire: &mut [f32]) {
    debug_assert_eq!(wire.len(), prec.wire_words(src.len()));
    match prec {
        CommPrecision::F32 => wire.copy_from_slice(src),
        CommPrecision::Bf16 => {
            for (i, w) in wire.iter_mut().enumerate() {
                let lo = f32_to_bf16_bits(src[2 * i]) as u32;
                let hi = if 2 * i + 1 < src.len() {
                    f32_to_bf16_bits(src[2 * i + 1]) as u32
                } else {
                    0
                };
                *w = f32::from_bits(lo | (hi << 16));
            }
        }
        CommPrecision::Q8 { block } => {
            let qt = QBlockTensor::quantize(src, block);
            let pw = src.len().div_ceil(4);
            for (i, w) in wire.iter_mut().take(pw).enumerate() {
                let mut bytes = [0u8; 4];
                for (j, byte) in bytes.iter_mut().enumerate() {
                    let idx = 4 * i + j;
                    if idx < qt.codes.len() {
                        *byte = qt.codes[idx] as u8;
                    }
                }
                *w = f32::from_bits(u32::from_le_bytes(bytes));
            }
            wire[pw..pw + qt.scales.len()].copy_from_slice(&qt.scales);
        }
    }
}

/// Decode a wire slot back into `dst` (the exact inverse layout of
/// [`encode_slot`]; for Q8 the result is the dequantized values).
pub fn decode_slot(prec: CommPrecision, wire: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(wire.len(), prec.wire_words(dst.len()));
    match prec {
        CommPrecision::F32 => dst.copy_from_slice(wire),
        CommPrecision::Bf16 => {
            for (i, d) in dst.iter_mut().enumerate() {
                let w = wire[i / 2].to_bits();
                let half = if i % 2 == 0 { w & 0xFFFF } else { w >> 16 };
                *d = bf16_bits_to_f32(half as u16);
            }
        }
        CommPrecision::Q8 { block } => {
            let n = dst.len();
            let pw = n.div_ceil(4);
            let nb = n.div_ceil(block);
            let scales = &wire[pw..pw + nb];
            for (i, d) in dst.iter_mut().enumerate() {
                let code = wire[i / 4].to_bits().to_le_bytes()[i % 4] as i8;
                *d = code as f32 * scales[i / block] / QMAX;
            }
        }
    }
}

// ---- quantized ReduceScatter with error feedback ------------------------

/// Phase 1 of the quantized ReduceScatter: inject the per-rank residuals
/// into each rank's *own* chunk (Q8 only), then encode every chunk of
/// every rank's buffer into wire buffers laid out for `all_to_all` (rank
/// r's slot k holds its encoded contribution to destination k). `bufs`
/// keeps the (residual-injected) originals — [`rs_decode_reduce`] needs
/// them to update the residuals.
pub fn rs_inject_and_encode(
    prec: CommPrecision,
    bufs: &mut [Vec<f32>],
    s: usize,
    ef: &mut Vec<Vec<f32>>,
) -> Result<Vec<Vec<f32>>> {
    let m = bufs.len();
    if prec.is_f32() {
        bail!("rs_inject_and_encode: F32 takes the dense reduce_scatter path");
    }
    for b in bufs.iter() {
        if b.len() < m * s {
            bail!("quantized reduce_scatter buffer too small: {} < {}", b.len(), m * s);
        }
    }
    if matches!(prec, CommPrecision::Q8 { .. }) {
        if ef.len() != m || ef.iter().any(|e| e.len() != s) {
            *ef = vec![vec![0.0; s]; m];
        }
        for (k, buf) in bufs.iter_mut().enumerate() {
            for (x, e) in buf[k * s..(k + 1) * s].iter_mut().zip(&ef[k]) {
                *x += *e;
            }
        }
    }
    let w = prec.wire_words(s);
    let mut wire: Vec<Vec<f32>> = vec![vec![0.0; m * w]; m];
    for (buf, wb) in bufs.iter().zip(wire.iter_mut()) {
        for k in 0..m {
            encode_slot(prec, &buf[k * s..(k + 1) * s], &mut wb[k * w..(k + 1) * w]);
        }
    }
    Ok(wire)
}

/// Phase 2: after `all_to_all(wire, w)` delivered every sender's encoded
/// chunk-k slot to destination k, decode and sum in **rank order 0..m**
/// (the serial backend's exact summation order — results are
/// bit-identical across backends and schedules), apply `scale`, and write
/// the reduced chunk into each rank's own chunk region of `bufs` (the
/// same output convention as the dense `reduce_scatter`). For Q8 the
/// residuals are replaced with the aggregate quantization error of each
/// owned chunk: `e' = Σ_r (g'_r − DQ(Q(g'_r)))`, unscaled, so next step's
/// injection telescopes the error away.
pub fn rs_decode_reduce(
    prec: CommPrecision,
    wire: &[Vec<f32>],
    bufs: &mut [Vec<f32>],
    s: usize,
    scale: f32,
    ef: &mut Vec<Vec<f32>>,
) -> Result<()> {
    let m = bufs.len();
    let w = prec.wire_words(s);
    if wire.len() != m {
        bail!("rs_decode_reduce: {} wire buffers != {m}", wire.len());
    }
    let update_ef = matches!(prec, CommPrecision::Q8 { .. });
    if update_ef && (ef.len() != m || ef.iter().any(|e| e.len() != s)) {
        bail!("rs_decode_reduce: residuals not initialized by rs_inject_and_encode");
    }
    let mut dec = vec![0.0f32; s];
    for k in 0..m {
        let mut acc = vec![0.0f32; s];
        let mut err = vec![0.0f32; s];
        for (r, buf) in bufs.iter().enumerate() {
            decode_slot(prec, &wire[k][r * w..(r + 1) * w], &mut dec);
            for (a, &d) in acc.iter_mut().zip(dec.iter()) {
                *a += d;
            }
            if update_ef {
                for i in 0..s {
                    err[i] += buf[k * s + i] - dec[i];
                }
            }
        }
        for a in acc.iter_mut() {
            *a *= scale;
        }
        bufs[k][k * s..(k + 1) * s].copy_from_slice(&acc);
        if update_ef {
            ef[k] = err;
        }
    }
    Ok(())
}

/// Synchronous quantized ReduceScatter (sum then `scale`) over the
/// cluster backend: inject + encode, one `all_to_all` of the packed wire
/// buffers, rank-ordered dequantize-and-sum at each destination. `F32`
/// delegates to the dense collective (bit-identical legacy path). The
/// pipelined executor runs the same three phases with the `all_to_all`
/// issued asynchronously — same functions, same bits.
pub fn reduce_scatter_prec(
    comm: &dyn Communicator,
    prec: CommPrecision,
    bufs: &mut [Vec<f32>],
    s: usize,
    scale: f32,
    ef: &mut Vec<Vec<f32>>,
) -> Result<()> {
    if prec.is_f32() {
        return comm.reduce_scatter(bufs, s, scale);
    }
    let mut wire = rs_inject_and_encode(prec, bufs, s, ef)?;
    comm.all_to_all(&mut wire, prec.wire_words(s))?;
    rs_decode_reduce(prec, &wire, bufs, s, scale, ef)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SerialComm, ThreadedComm};
    use crate::util::Rng;

    #[test]
    fn parse_name_roundtrip() {
        for p in [
            CommPrecision::F32,
            CommPrecision::Bf16,
            CommPrecision::Q8 { block: 64 },
            CommPrecision::Q8 { block: 32 },
        ] {
            assert_eq!(CommPrecision::parse(&p.name()), Some(p));
        }
        assert_eq!(CommPrecision::parse("q8"), Some(CommPrecision::Q8 { block: DEFAULT_Q8_BLOCK }));
        assert_eq!(CommPrecision::parse("FP32"), Some(CommPrecision::F32));
        assert_eq!(CommPrecision::parse("q8:0"), None);
        assert_eq!(CommPrecision::parse("int4"), None);
    }

    #[test]
    fn round_half_even_matches_jnp_round() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(62.5), 62.0);
        assert_eq!(round_half_even(63.5), 64.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(1.25), 1.0);
        assert_eq!(round_half_even(1.75), 2.0);
    }

    #[test]
    fn wire_volume_math() {
        // f32: identity
        let v = CommPrecision::F32.wire_volume(100);
        assert_eq!((v.payload, v.scale, v.pad), (400, 0, 0));
        // bf16: 2 B/elem, odd length pads half a word
        let v = CommPrecision::Bf16.wire_volume(101);
        assert_eq!(v.payload, 202);
        assert_eq!(v.total(), 51 * 4);
        // q8: 1 B/elem + scales, code tail pads to a word
        let p = CommPrecision::Q8 { block: 32 };
        let v = p.wire_volume(96);
        assert_eq!((v.payload, v.scale, v.pad), (96, 12, 0));
        let v = p.wire_volume(97);
        assert_eq!(v.payload, 97);
        assert_eq!(v.scale, 4 * 4);
        assert_eq!(v.total() % 4, 0);
        // wire_words agrees with wire_volume for every precision
        for prec in [CommPrecision::F32, CommPrecision::Bf16, p] {
            for n in [1usize, 4, 31, 32, 97, 1024] {
                assert_eq!(
                    prec.wire_words(n) as u64 * 4,
                    prec.wire_volume(n as u64).total(),
                    "{} n={n}",
                    prec.name()
                );
            }
        }
    }

    #[test]
    fn quantize_roundtrip_bounded_and_zero_block() {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..300).map(|_| rng.normal_f32() * 2.0).collect();
        let qt = QBlockTensor::quantize(&x, 64); // 300 = 4 blocks + tail 44
        assert_eq!(qt.scales.len(), 5);
        let y = qt.dequantize();
        for (b, &s) in qt.scales.iter().enumerate() {
            let lo = b * 64;
            let hi = (lo + 64).min(300);
            for i in lo..hi {
                assert!((x[i] - y[i]).abs() <= s / QMAX * 0.5 + 1e-6);
            }
        }
        let z = QBlockTensor::quantize(&[0.0; 16], 16);
        assert_eq!(z.scales, vec![1.0]);
        assert!(z.codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn codec_roundtrip_equals_quantize_dequantize() {
        let mut rng = Rng::new(4);
        for n in [7usize, 32, 65, 128] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            for prec in [CommPrecision::Bf16, CommPrecision::Q8 { block: 16 }] {
                let mut wire = vec![0.0f32; prec.wire_words(n)];
                encode_slot(prec, &x, &mut wire);
                let mut back = vec![0.0f32; n];
                decode_slot(prec, &wire, &mut back);
                match prec {
                    CommPrecision::Q8 { block } => {
                        let expect = QBlockTensor::quantize(&x, block).dequantize();
                        for (a, b) in back.iter().zip(&expect) {
                            assert_eq!(a.to_bits(), b.to_bits());
                        }
                    }
                    CommPrecision::Bf16 => {
                        for (a, &orig) in back.iter().zip(&x) {
                            let expect = bf16_bits_to_f32(f32_to_bf16_bits(orig));
                            assert_eq!(a.to_bits(), expect.to_bits());
                            assert!((a - orig).abs() <= orig.abs() * 0.01 + 1e-6);
                        }
                    }
                    CommPrecision::F32 => unreachable!(),
                }
            }
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between bf16(1.0) and the next
        // code; round-to-nearest-even keeps the even mantissa (1.0)
        let x = 1.0f32 + 2f32.powi(-8);
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(x)), 1.0);
        // values already representable pass through exactly
        for v in [0.0f32, 1.0, -2.5, 0.375] {
            assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(v)), v);
        }
    }

    fn mk_grads(m: usize, s: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| (0..m * s).map(|_| rng.normal_f32()).collect())
            .collect()
    }

    #[test]
    fn quantized_rs_close_to_dense_and_backend_bit_identical() {
        let (m, s) = (4usize, 32usize);
        let prec = CommPrecision::Q8 { block: 8 };
        let mut dense = mk_grads(m, s, 7);
        SerialComm::new().reduce_scatter(&mut dense, s, 0.25).unwrap();

        let mut ef_a = Vec::new();
        let mut a = mk_grads(m, s, 7);
        reduce_scatter_prec(&SerialComm::new(), prec, &mut a, s, 0.25, &mut ef_a).unwrap();
        let mut ef_b = Vec::new();
        let mut b = mk_grads(m, s, 7);
        reduce_scatter_prec(
            &ThreadedComm::with_min_parallel_elems(0),
            prec,
            &mut b,
            s,
            0.25,
            &mut ef_b,
        )
        .unwrap();
        for k in 0..m {
            for i in 0..s {
                let x = a[k][k * s + i];
                let y = b[k][k * s + i];
                assert_eq!(x.to_bits(), y.to_bits(), "backends diverged");
                // close to the dense reduction: m block errors, scaled
                let d = dense[k][k * s + i];
                assert!((x - d).abs() < 0.25 * m as f32 * 4.0 / QMAX + 1e-4);
            }
        }
        for (ea, eb) in ef_a.iter().flatten().zip(ef_b.iter().flatten()) {
            assert_eq!(ea.to_bits(), eb.to_bits());
        }
    }

    #[test]
    fn error_feedback_recovers_sub_quantile_gradients() {
        // every rank contributes a block whose absmax (1.0) drowns a tiny
        // constant signal (0.003 < one quant step): without feedback the
        // tiny elements quantize to 0 forever; with the shard-held
        // residual their time-average converges to the true mean
        let (m, s, block) = (2usize, 8usize, 8usize);
        let prec = CommPrecision::Q8 { block };
        let scale = 1.0 / m as f32;
        let tiny = 0.003f32;
        let mk = || -> Vec<Vec<f32>> {
            (0..m)
                .map(|_| {
                    let mut b = vec![tiny; m * s];
                    for k in 0..m {
                        b[k * s] = 1.0; // pins each block's absmax
                    }
                    b
                })
                .collect()
        };
        let comm = SerialComm::new();
        let rounds = 64;
        let mut with_ef = vec![0.0f64; s];
        let mut without_ef = vec![0.0f64; s];
        let mut ef = Vec::new();
        for _ in 0..rounds {
            let mut bufs = mk();
            reduce_scatter_prec(&comm, prec, &mut bufs, s, scale, &mut ef).unwrap();
            for i in 0..s {
                with_ef[i] += bufs[0][i] as f64;
            }
            let mut bufs = mk();
            let mut fresh = Vec::new(); // zeroed residual every round
            reduce_scatter_prec(&comm, prec, &mut bufs, s, scale, &mut fresh).unwrap();
            for i in 0..s {
                without_ef[i] += bufs[0][i] as f64;
            }
        }
        // element 1..s of chunk 0 carries the tiny signal (element 0 is
        // the absmax pin)
        let truth = tiny as f64;
        for i in 1..s {
            let avg_ef = with_ef[i] / rounds as f64;
            let avg_no = without_ef[i] / rounds as f64;
            assert_eq!(avg_no, 0.0, "tiny signal should vanish without EF");
            assert!(
                (avg_ef - truth).abs() < truth * 0.35,
                "EF average {avg_ef} should approach {truth}"
            );
        }
    }

    #[test]
    fn error_feedback_telescopes() {
        // sum of T quantized-RS outputs == T * dense output + e_0 - e_T:
        // the cumulative deviation is bounded by one step's residual
        let (m, s) = (2usize, 16usize);
        let prec = CommPrecision::Q8 { block: 16 };
        let scale = 1.0 / m as f32;
        let comm = SerialComm::new();
        let mut dense = mk_grads(m, s, 11);
        comm.reduce_scatter(&mut dense, s, scale).unwrap();
        let mut ef = Vec::new();
        let t_rounds = 32;
        let mut acc = vec![0.0f64; s];
        for _ in 0..t_rounds {
            let mut bufs = mk_grads(m, s, 11);
            reduce_scatter_prec(&comm, prec, &mut bufs, s, scale, &mut ef).unwrap();
            for i in 0..s {
                acc[i] += bufs[0][i] as f64;
            }
        }
        for i in 0..s {
            let drift = (acc[i] - t_rounds as f64 * dense[0][i] as f64).abs();
            // |e_T| * scale, loosely bounded by m quant steps of the
            // largest block absmax (~3 sigma)
            let bound = (m as f32 * 6.0 / QMAX * scale) as f64 + 1e-5;
            assert!(drift <= bound, "elem {i}: drift {drift} > {bound}");
        }
    }

    #[test]
    fn f32_reduce_scatter_prec_is_the_dense_path() {
        let (m, s) = (3usize, 8usize);
        let mut a = mk_grads(m, s, 13);
        let mut b = a.clone();
        let comm = SerialComm::new();
        comm.reduce_scatter(&mut a, s, 1.0 / 3.0).unwrap();
        let mut ef = Vec::new();
        reduce_scatter_prec(&comm, CommPrecision::F32, &mut b, s, 1.0 / 3.0, &mut ef).unwrap();
        for (x, y) in a.iter().flatten().zip(b.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(ef.is_empty(), "F32 must not materialize residuals");
    }
}
