//! Training loop: synthetic corpus + the FSDP trainer that wires the
//! numeric engine (DBuffer shards + collectives) to the compute runtime
//! (PJRT or native L2 fwd/bwd). Also a DDP reference trainer for the
//! Fig-10 convergence comparisons (bucketed AllReduce instead of
//! layer-wise ReduceScatter — the schedule difference the paper calls
//! out).
//!
//! Both trainers run on either cluster backend (`--backend
//! serial|threaded`). Under the threaded backend the per-rank compute
//! fans out across OS threads via [`Cluster::run_spmd`] (native runtime
//! only — PJRT's executable cache is single-threaded) and every
//! collective runs as a rendezvous operation; batches are drawn from the
//! corpus on the coordinator thread in rank order first, so the token
//! stream — and therefore the whole loss trajectory — is bit-identical
//! across backends.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::cluster::{make_comm, Cluster, CommBackend};
use crate::comm::{CommRecord, Fabric};
use crate::config::OptimKind;
use crate::fsdp::{exec, ExecMode, ExecReport, FsdpEngine, ShardingPolicy};
use crate::mesh::DeviceMesh;
use crate::optim::{Adam8bit, AdamHyper, AdamW, Muon, Sgd, ShardOptimizer};
use crate::runtime::Engine;
use crate::util::Rng;

/// Synthetic corpus with learnable structure: a deterministic successor
/// map followed with high probability, Zipf-distributed restarts
/// otherwise. Cross-entropy floor is well below ln(V), so a training
/// model shows a real loss curve.
pub struct Corpus {
    vocab: usize,
    succ: Vec<u32>,
    p_follow: f64,
    rng: Rng,
    state: u32,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed ^ 0xC0D0);
        let mut succ: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut succ);
        Corpus { vocab, succ, p_follow: 0.8, rng, state: 0 }
    }

    pub fn next_token(&mut self) -> u32 {
        self.state = if self.rng.chance(self.p_follow) {
            self.succ[self.state as usize]
        } else {
            self.rng.zipf(self.vocab, 1.1) as u32
        };
        self.state
    }

    /// (tokens, targets) pair of shape batch x seq (targets shifted by 1).
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            for _ in 0..=seq {
                toks.push(self.next_token() as i32);
            }
        }
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let row = &toks[b * (seq + 1)..(b + 1) * (seq + 1)];
            tokens.extend_from_slice(&row[..seq]);
            targets.extend_from_slice(&row[1..]);
        }
        (tokens, targets)
    }

    /// Entropy floor estimate (nats/token) of this source.
    pub fn entropy_floor(&self) -> f64 {
        // H ~ p*log(1/p) + (1-p)*(log(1/(1-p)) + H_zipf); rough bound
        let p = self.p_follow;
        -(p * p.ln() + (1.0 - p) * ((1.0 - p) / self.vocab as f64).ln())
    }
}

/// Build the per-bucket optimizer set for the engine.
pub fn make_optimizers(
    kind: OptimKind,
    hyper: AdamHyper,
    qblock: usize,
    n_buckets: usize,
    ranks: usize,
) -> Vec<Box<dyn ShardOptimizer>> {
    (0..n_buckets)
        .map(|_| -> Box<dyn ShardOptimizer> {
            match kind {
                OptimKind::Sgd => Box::new(Sgd::new(hyper.lr, 0.9, ranks)),
                OptimKind::AdamW => Box::new(AdamW::new(hyper, ranks)),
                OptimKind::Adam8bit => Box::new(Adam8bit::new(hyper, qblock, ranks)),
                OptimKind::Muon => Box::new(AdamW::new(hyper, ranks)), // fallback set
            }
        })
        .collect()
}

/// Initialize full parameters on the host, matching the L2 init scheme
/// (scaled normal; ones for norm scales) so loss starts near ln(V).
pub fn init_full_params(abi: &[(String, Vec<usize>)], seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    abi.iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            if name.ends_with("scale") {
                vec![1.0; n]
            } else if name == "embed.weight" {
                (0..n).map(|_| rng.normal_f32() * 0.02).collect()
            } else {
                let fan_in = shape[0] as f32;
                (0..n).map(|_| rng.normal_f32() * fan_in.powf(-0.5)).collect()
            }
        })
        .collect()
}

/// One logged training step.
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: u64,
    pub loss: f32,
    pub comm_time: f64,
    /// Wall seconds this step spent blocked on collectives (the measured
    /// exposed communication; 0 for the DDP trainer).
    pub exposed_s: f64,
    pub wall_s: f64,
}

/// FSDP trainer over the numeric engine + compute runtime.
pub struct Trainer {
    pub engine: FsdpEngine,
    pub runtime: Engine,
    pub config: String,
    pub corpus: Corpus,
    pub optimizers: Vec<Box<dyn ShardOptimizer>>,
    pub muon: Option<Muon>,
    /// 8-bit Adam pair: quantized optimizer for matrices, fp32 fallback
    /// for 1-D params (state keyed per parameter x rank).
    pub adam8: Option<(Adam8bit, AdamW)>,
    /// Step-loop schedule (`--prefetch` flag): sequential, or the
    /// bucket-pipelined overlap executor.
    pub exec: ExecMode,
    /// Measured timeline of the most recent step.
    pub last_report: Option<ExecReport>,
    pub step: u64,
    pub log: Vec<StepLog>,
}

impl Trainer {
    /// Serial-backend trainer (the seed behavior).
    pub fn new(
        config: &str,
        m: usize,
        optim: OptimKind,
        policy: &ShardingPolicy,
        hyper: AdamHyper,
        seed: u64,
    ) -> Result<Trainer> {
        Trainer::with_backend(config, m, optim, policy, hyper, seed, CommBackend::Serial)
    }

    pub fn with_backend(
        config: &str,
        m: usize,
        optim: OptimKind,
        policy: &ShardingPolicy,
        hyper: AdamHyper,
        seed: u64,
        backend: CommBackend,
    ) -> Result<Trainer> {
        Trainer::with_exec(config, m, optim, policy, hyper, seed, backend, ExecMode::Sequential)
    }

    /// Full constructor: cluster backend + executor schedule.
    #[allow(clippy::too_many_arguments)]
    pub fn with_exec(
        config: &str,
        m: usize,
        optim: OptimKind,
        policy: &ShardingPolicy,
        hyper: AdamHyper,
        seed: u64,
        backend: CommBackend,
        exec: ExecMode,
    ) -> Result<Trainer> {
        let runtime = Engine::load_default().context("loading compute runtime")?;
        let cfg = runtime
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow::anyhow!("config '{config}' not in manifest"))?
            .clone();
        // FSDP wrapping: embed | each layer | head (group by name prefix)
        let group_of: Vec<usize> = cfg
            .params
            .iter()
            .map(|(name, _)| {
                if name.starts_with("embed") {
                    0
                } else if let Some(rest) = name.strip_prefix("layers.") {
                    1 + rest.split('.').next().unwrap().parse::<usize>().unwrap()
                } else {
                    1 + cfg.n_layers
                }
            })
            .collect();
        let mut engine = FsdpEngine::new_with_comm(
            cfg.params.clone(),
            &group_of,
            DeviceMesh::flat("fsdp", m),
            policy,
            Fabric::h800(),
            make_comm(backend),
        )?;
        let full = init_full_params(&cfg.params, seed);
        engine.init_params(&full)?;
        let n_buckets = engine.buckets.len();
        let qblock = runtime.manifest.qblock;
        let optimizers = make_optimizers(optim, hyper, qblock, n_buckets, m);
        let muon = if optim == OptimKind::Muon {
            Some(Muon::new(hyper.lr, 0.95, hyper.wd))
        } else {
            None
        };
        let adam8 = if optim == OptimKind::Adam8bit {
            let slots = cfg.params.len() * m;
            Some((Adam8bit::new(hyper, qblock, slots), AdamW::new(hyper, slots)))
        } else {
            None
        };
        // the pipelined executor drives compute layer-wise, which only the
        // native runtime supports; PJRT falls back to the sequential path
        let exec = if runtime.is_native() {
            exec
        } else {
            if exec != ExecMode::Sequential {
                eprintln!(
                    "note: the pipelined executor requires the native runtime; \
                     falling back to the sequential schedule"
                );
            }
            ExecMode::Sequential
        };
        Ok(Trainer {
            engine,
            runtime,
            config: config.to_string(),
            corpus: Corpus::new(cfg.vocab, seed + 1),
            optimizers,
            muon,
            adam8,
            exec,
            last_report: None,
            step: 0,
            log: Vec::new(),
        })
    }

    /// One synchronous training step across all simulated devices, driven
    /// by the executor schedule (`self.exec`).
    pub fn train_step(&mut self) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let (batch, seq) = {
            let cfg = &self.runtime.manifest.configs[&self.config];
            (cfg.batch, cfg.seq)
        };
        let m = self.engine.num_devices();
        let comm_before = self.engine.comm.sim_time();

        // draw every rank's batch on the coordinator in rank order so the
        // token stream is identical no matter how compute executes
        let batches: Vec<(Vec<i32>, Vec<i32>)> =
            (0..m).map(|_| self.corpus.batch(batch, seq)).collect();
        let outcome = exec::run_step(
            &mut self.engine,
            &mut self.runtime,
            &self.config,
            &batches,
            self.exec,
        )?;
        self.step += 1;
        if let Some(muon) = self.muon.as_mut() {
            self.engine.muon_step(muon, &mut self.optimizers, self.step)?;
        } else if let Some((a8, fallback)) = self.adam8.as_mut() {
            self.engine.adam8bit_step(a8, fallback, self.step)?;
        } else {
            self.engine.optimizer_step(&mut self.optimizers, self.step)?;
        }
        let loss = outcome.losses.iter().sum::<f32>() / m as f32;
        self.log.push(StepLog {
            step: self.step,
            loss,
            // simulated comm this step, including optimizer collectives
            comm_time: self.engine.comm.sim_time() - comm_before,
            exposed_s: outcome.report.exposed_comm_s,
            wall_s: t0.elapsed().as_secs_f64(),
        });
        self.last_report = Some(outcome.report);
        Ok(loss)
    }

    pub fn run(&mut self, steps: usize) -> Result<Vec<StepLog>> {
        for _ in 0..steps {
            self.train_step()?;
        }
        Ok(self.log.clone())
    }
}

/// DDP reference trainer (Fig 10): replicated parameters, bucketed
/// AllReduce gradient averaging (through the cluster backend), and a
/// full-parameter optimizer.
pub struct DdpTrainer {
    pub runtime: Engine,
    pub config: String,
    pub comm: Arc<dyn crate::cluster::Communicator>,
    pub fabric: Fabric,
    pub params: Vec<Vec<f32>>,
    pub corpus: Corpus,
    pub optimizer: Box<dyn ShardOptimizer>,
    pub devices: usize,
    pub step: u64,
    pub log: Vec<StepLog>,
}

impl DdpTrainer {
    pub fn new(
        config: &str,
        devices: usize,
        optim: OptimKind,
        hyper: AdamHyper,
        seed: u64,
    ) -> Result<DdpTrainer> {
        DdpTrainer::with_backend(config, devices, optim, hyper, seed, CommBackend::Serial)
    }

    pub fn with_backend(
        config: &str,
        devices: usize,
        optim: OptimKind,
        hyper: AdamHyper,
        seed: u64,
        backend: CommBackend,
    ) -> Result<DdpTrainer> {
        let runtime = Engine::load_default()?;
        let cfg = runtime
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow::anyhow!("config '{config}' not in manifest"))?
            .clone();
        if optim == OptimKind::Muon {
            bail!("use Trainer (FSDP) for Muon");
        }
        let qblock = runtime.manifest.qblock;
        // one state slot per tensor (the ShardOptimizer "rank" index keys
        // independent state vectors)
        let slots = cfg.params.len();
        let optimizer: Box<dyn ShardOptimizer> = match optim {
            OptimKind::Sgd => Box::new(Sgd::new(hyper.lr, 0.9, slots)),
            OptimKind::AdamW => Box::new(AdamW::new(hyper, slots)),
            OptimKind::Adam8bit => Box::new(Adam8bit::new(hyper, qblock, slots)),
            OptimKind::Muon => unreachable!(),
        };
        let params = init_full_params(&cfg.params, seed);
        Ok(DdpTrainer {
            runtime,
            config: config.to_string(),
            comm: make_comm(backend),
            fabric: Fabric::h800(),
            params,
            corpus: Corpus::new(cfg.vocab, seed + 1),
            optimizer,
            devices,
            step: 0,
            log: Vec::new(),
        })
    }

    pub fn train_step(&mut self) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let cfg = self.runtime.manifest.configs[&self.config].clone();
        let m = self.devices;
        // per-device microbatches (drawn in rank order on the coordinator)
        let batches: Vec<(Vec<i32>, Vec<i32>)> =
            (0..m).map(|_| self.corpus.batch(cfg.batch, cfg.seq)).collect();
        let mut losses = Vec::with_capacity(m);
        let mut all_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(m);
        if self.comm.backend() == CommBackend::Threaded && self.runtime.is_native() {
            // direct native call for the same &Engine-Sync reason as Trainer
            let params = &self.params;
            let (outs, _) = Cluster::run_spmd(m, |rank, _ctx| {
                let (tokens, targets) = &batches[rank];
                crate::runtime::native::train_step(&cfg, params, tokens, targets)
            });
            for out in outs {
                let (loss, grads) = out?;
                losses.push(loss);
                all_grads.push(grads);
            }
        } else {
            for rank in 0..m {
                let (tokens, targets) = &batches[rank];
                let (loss, grads) =
                    self.runtime.train_step(&self.config, &self.params, tokens, targets)?;
                losses.push(loss);
                all_grads.push(grads);
            }
        }
        // bucketed AllReduce through the cluster backend (sum in rank
        // order then scale by 1/m — identical on every backend)
        let mut mean_grads: Vec<Vec<f32>> = Vec::with_capacity(self.params.len());
        for ti in 0..self.params.len() {
            let mut bufs: Vec<Vec<f32>> = all_grads
                .iter_mut()
                .map(|g| std::mem::take(&mut g[ti]))
                .collect();
            self.comm.all_reduce(&mut bufs, 1.0 / m as f32)?;
            let bytes = (bufs[0].len() * 4) as u64;
            self.comm.record(CommRecord {
                op: "all_reduce",
                bytes_per_rank: bytes,
                group_size: m,
                sim_time: self.fabric.all_reduce_time(m, bytes, true),
            });
            mean_grads.push(bufs.into_iter().next().unwrap());
        }
        self.step += 1;
        // 8-bit Adam quant blocks: DDP holds full params, every block is
        // trivially local — pad params to the quant block? The flat param
        // per tensor may not be a block multiple; DDP quantizes per tensor
        // padded to the block, matching common implementations.
        for (i, p) in self.params.iter_mut().enumerate() {
            let g = &mean_grads[i];
            if self.optimizer.name() == "adam8bit" {
                let block = self.runtime.manifest.qblock;
                let n = p.len();
                let padded = n.div_ceil(block) * block;
                let mut pp = vec![0.0f32; padded];
                pp[..n].copy_from_slice(p);
                let mut gp = g.clone();
                gp.resize(padded, 0.0);
                self.optimizer.step(i, self.step, &mut pp, &gp);
                p.copy_from_slice(&pp[..n]);
            } else {
                self.optimizer.step(i, self.step, p, g);
            }
        }
        let loss = losses.iter().sum::<f32>() / self.devices as f32;
        self.log.push(StepLog {
            step: self.step,
            loss,
            comm_time: 0.0,
            exposed_s: 0.0,
            wall_s: t0.elapsed().as_secs_f64(),
        });
        Ok(loss)
    }

    pub fn run(&mut self, steps: usize) -> Result<Vec<StepLog>> {
        for _ in 0..steps {
            self.train_step()?;
        }
        Ok(self.log.clone())
    }
}

/// Write a loss log as CSV under `runs/`.
pub fn save_log(name: &str, log: &[StepLog]) -> Result<std::path::PathBuf> {
    let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/runs"));
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.csv"));
    let mut out = String::from("step,loss,comm_time,exposed_s,wall_s\n");
    for l in log {
        out.push_str(&format!(
            "{},{},{},{},{}\n",
            l.step, l.loss, l.comm_time, l.exposed_s, l.wall_s
        ));
    }
    std::fs::write(&path, out)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_learnable_structure() {
        let mut c = Corpus::new(512, 0);
        let (tokens, targets) = c.batch(2, 64);
        assert_eq!(tokens.len(), 128);
        assert_eq!(targets.len(), 128);
        // shifted-by-one property within each row
        for b in 0..2 {
            for i in 0..63 {
                assert_eq!(tokens[b * 64 + i + 1], targets[b * 64 + i]);
            }
        }
        // successor structure: the most frequent bigram follows succ map
        let mut follows = 0;
        let mut total = 0;
        let mut c2 = Corpus::new(512, 1);
        let mut prev = c2.next_token();
        for _ in 0..5000 {
            let nxt = c2.next_token();
            if nxt == c2.succ[prev as usize] {
                follows += 1;
            }
            total += 1;
            prev = nxt;
        }
        let frac = follows as f64 / total as f64;
        assert!(frac > 0.75, "successor fraction {frac}");
    }

    #[test]
    fn corpus_deterministic_per_seed() {
        let mut a = Corpus::new(128, 7);
        let mut b = Corpus::new(128, 7);
        assert_eq!(a.batch(1, 32), b.batch(1, 32));
        let mut c = Corpus::new(128, 8);
        assert_ne!(a.batch(1, 32), c.batch(1, 32));
    }

    #[test]
    fn entropy_floor_below_uniform() {
        let c = Corpus::new(512, 0);
        assert!(c.entropy_floor() < (512.0f64).ln());
    }

    #[test]
    fn init_params_match_abi() {
        let abi = vec![
            ("embed.weight".to_string(), vec![16, 8]),
            ("layers.0.ln1.scale".to_string(), vec![8]),
            ("layers.0.attn.wq".to_string(), vec![8, 8]),
        ];
        let full = init_full_params(&abi, 0);
        assert_eq!(full[0].len(), 128);
        assert!(full[1].iter().all(|&x| x == 1.0));
        // wq ~ N(0, 1/sqrt(8)): std within loose bounds
        let std: f32 =
            (full[2].iter().map(|x| x * x).sum::<f32>() / 64.0).sqrt();
        assert!((0.1..0.8).contains(&std), "std {std}");
    }

    #[test]
    fn optimizer_factory_kinds() {
        let opts = make_optimizers(OptimKind::Adam8bit, AdamHyper::default(), 64, 3, 2);
        assert_eq!(opts.len(), 3);
        assert_eq!(opts[0].name(), "adam8bit");
    }

    // End-to-end Trainer tests (need artifacts + PJRT) live in
    // rust/tests/integration.rs.
}
