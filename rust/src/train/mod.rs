//! Training loop: synthetic corpus + the FSDP trainer that wires the
//! numeric engine (DBuffer shards + collectives) to the compute runtime
//! (PJRT or native L2 fwd/bwd). Also a DDP reference trainer for the
//! Fig-10 convergence comparisons (bucketed AllReduce instead of
//! layer-wise ReduceScatter — the schedule difference the paper calls
//! out).
//!
//! Both trainers run on either cluster backend (`--backend
//! serial|threaded`). Under the threaded backend the per-rank compute
//! fans out across OS threads via [`Cluster::run_spmd`] (native runtime
//! only — PJRT's executable cache is single-threaded) and every
//! collective runs as a rendezvous operation; batches are drawn from the
//! corpus on the coordinator thread in rank order first, so the token
//! stream — and therefore the whole loss trajectory — is bit-identical
//! across backends.

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::analysis::diag::{codes, rt};
use crate::cluster::{Cluster, CommBackend, CommBuilder};
use crate::comm::{CommRecord, Fabric};
use crate::config::{GroupOverride, OptimKind};
use crate::obs::{ObsConfig, Observer};
use crate::fsdp::spec::{ModelSpec, OptimBinding, ShardGroupSpec};
use crate::fsdp::{exec, ExecMode, ExecReport, FsdpEngine, ShardingPolicy};
use crate::mesh::DeviceMesh;
use crate::optim::{Adam8bit, AdamHyper, AdamW, GroupOptimizer, Sgd, ShardOptimizer};
use crate::quant::CommPrecision;
use crate::runtime::Engine;
use crate::trace::{TraceLevel, TraceSummary, Tracer};
use crate::util::json::Json;
use crate::util::Rng;

/// Synthetic corpus with learnable structure: a deterministic successor
/// map followed with high probability, Zipf-distributed restarts
/// otherwise. Cross-entropy floor is well below ln(V), so a training
/// model shows a real loss curve.
pub struct Corpus {
    vocab: usize,
    succ: Vec<u32>,
    p_follow: f64,
    rng: Rng,
    state: u32,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed ^ 0xC0D0);
        let mut succ: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut succ);
        Corpus { vocab, succ, p_follow: 0.8, rng, state: 0 }
    }

    pub fn next_token(&mut self) -> u32 {
        self.state = if self.rng.chance(self.p_follow) {
            self.succ[self.state as usize]
        } else {
            self.rng.zipf(self.vocab, 1.1) as u32
        };
        self.state
    }

    /// (tokens, targets) pair of shape batch x seq (targets shifted by 1).
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            for _ in 0..=seq {
                toks.push(self.next_token() as i32);
            }
        }
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let row = &toks[b * (seq + 1)..(b + 1) * (seq + 1)];
            tokens.extend_from_slice(&row[..seq]);
            targets.extend_from_slice(&row[1..]);
        }
        (tokens, targets)
    }

    /// Entropy floor estimate (nats/token) of this source.
    pub fn entropy_floor(&self) -> f64 {
        // H ~ p*log(1/p) + (1-p)*(log(1/(1-p)) + H_zipf); rough bound
        let p = self.p_follow;
        -(p * p.ln() + (1.0 - p) * ((1.0 - p) / self.vocab as f64).ln())
    }
}

/// Build the per-bucket optimizer set for the engine.
pub fn make_optimizers(
    kind: OptimKind,
    hyper: AdamHyper,
    qblock: usize,
    n_buckets: usize,
    ranks: usize,
) -> Vec<Box<dyn ShardOptimizer>> {
    (0..n_buckets)
        .map(|_| -> Box<dyn ShardOptimizer> {
            match kind {
                OptimKind::Sgd => Box::new(Sgd::new(hyper.lr, 0.9, ranks)),
                OptimKind::AdamW => Box::new(AdamW::new(hyper, ranks)),
                OptimKind::Adam8bit => Box::new(Adam8bit::new(hyper, qblock, ranks)),
                OptimKind::Muon => Box::new(AdamW::new(hyper, ranks)), // fallback set
            }
        })
        .collect()
}

/// Initialize full parameters on the host, matching the L2 init scheme
/// (scaled normal; ones for norm scales) so loss starts near ln(V).
pub fn init_full_params(abi: &[(String, Vec<usize>)], seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    abi.iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            if name.ends_with("scale") {
                vec![1.0; n]
            } else if name == "embed.weight" {
                (0..n).map(|_| rng.normal_f32() * 0.02).collect()
            } else {
                let fan_in = shape[0] as f32;
                (0..n).map(|_| rng.normal_f32() * fan_in.powf(-0.5)).collect()
            }
        })
        .collect()
}

/// One logged training step.
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: u64,
    pub loss: f32,
    pub comm_time: f64,
    /// Wall seconds this step spent blocked on collectives (the measured
    /// exposed communication; 0 for the DDP trainer).
    pub exposed_s: f64,
    pub wall_s: f64,
    /// Session-default fabric preset this step was timed on.
    pub fabric: &'static str,
    /// Cluster topology the collectives ran under: `"HxG"` for
    /// hierarchical runs, `"flat"` otherwise.
    pub topology: String,
    /// Measured wire bytes this step shipped carrying tensor data
    /// (summed over collectives x group size; int8/bf16 payload for
    /// quantized groups, full f32 otherwise).
    pub wire_payload: u64,
    /// Quantization-scale side-channel bytes this step shipped.
    pub wire_scale: u64,
    /// Word-packing pad bytes this step shipped.
    pub wire_pad: u64,
    /// Allocator peak reserved bytes (cumulative over the run; 0 for the
    /// DDP trainer, which bypasses the caching allocator).
    pub peak_reserved: u64,
    /// Allocator peak allocated bytes (cumulative; 0 for DDP).
    pub peak_allocated: u64,
}

/// Legacy alias: the FSDP trainer is now [`TrainSession`]; every old
/// constructor (`Trainer::{new,with_backend,with_exec}`) remains as a
/// thin shim over [`SessionBuilder`].
pub type Trainer = TrainSession;

/// FSDP training session over the numeric engine + compute runtime.
/// Construct one with [`TrainSession::builder`] (or the legacy
/// constructor shims).
pub struct TrainSession {
    pub engine: FsdpEngine,
    pub runtime: Engine,
    pub config: String,
    pub corpus: Corpus,
    /// One optimizer per shard group — the uniform per-group dispatch
    /// (`OptimBinding` resolved per wrap unit; Muon / 8-bit Adam run
    /// behind the same trait as AdamW / SGD).
    pub optimizers: Vec<Box<dyn GroupOptimizer>>,
    /// Step-loop schedule (`--prefetch` flag): sequential, or the
    /// bucket-pipelined overlap executor.
    pub exec: ExecMode,
    /// Measured timeline of the most recent step.
    pub last_report: Option<ExecReport>,
    /// The session's trace sink (off unless the builder enabled it) —
    /// the same instance threaded through the engine, the DBuffers, and
    /// the communicator backend.
    pub tracer: Tracer,
    /// Runtime health monitor (disarmed unless the builder enabled it) —
    /// the same handle the communicator backend and the executor publish
    /// heartbeats and flight-recorder events through.
    pub obs: Observer,
    pub step: u64,
    pub log: Vec<StepLog>,
}

/// Builder for a [`TrainSession`] — replaces the old 8-positional-argument
/// `Trainer::with_exec`. Every knob has a default; `.group(..)` /
/// `.spec(..)` switch from the canonical layerwise wrapping to a custom
/// declarative [`ModelSpec`] with per-group policies and optimizers.
///
/// ```no_run
/// use vescale_fsdp::cluster::CommBackend;
/// use vescale_fsdp::comm::Fabric;
/// use vescale_fsdp::fsdp::spec::OptimBinding;
/// use vescale_fsdp::fsdp::ExecMode;
/// use vescale_fsdp::train::TrainSession;
///
/// let mut session = TrainSession::builder("tiny")
///     .devices(8)
///     .backend(CommBackend::Threaded)
///     .exec(ExecMode::Pipelined { prefetch: 2 })
///     .fabric(Fabric::h800())
///     .optimizer(OptimBinding::AdamW)
///     .build()?;
/// session.run(10)?;
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct SessionBuilder {
    config: String,
    devices: usize,
    replicas: usize,
    optim: OptimBinding,
    policy: ShardingPolicy,
    hyper: AdamHyper,
    seed: u64,
    backend: CommBackend,
    exec: ExecMode,
    fabric: Fabric,
    comm_precision: CommPrecision,
    hier_threshold: usize,
    trace: TraceLevel,
    obs: Option<ObsConfig>,
    groups: Vec<ShardGroupSpec>,
    spec: Option<ModelSpec>,
    overrides: Vec<GroupOverride>,
}

impl SessionBuilder {
    pub fn new(config: &str) -> SessionBuilder {
        SessionBuilder {
            config: config.to_string(),
            devices: 4,
            replicas: 1,
            optim: OptimBinding::AdamW,
            policy: ShardingPolicy::element_wise(),
            hyper: AdamHyper::default(),
            seed: 0,
            backend: CommBackend::Serial,
            exec: ExecMode::Sequential,
            fabric: Fabric::h800(),
            comm_precision: CommPrecision::F32,
            hier_threshold: crate::cluster::DEFAULT_HIER_THRESHOLD,
            trace: TraceLevel::Off,
            obs: None,
            groups: Vec::new(),
            spec: None,
            overrides: Vec::new(),
        }
    }

    /// FSDP shard-group size (the mesh's fsdp dim).
    pub fn devices(mut self, m: usize) -> Self {
        self.devices = m;
        self
    }

    /// HSDP replication factor (default 1 = plain FSDP).
    pub fn replicas(mut self, r: usize) -> Self {
        self.replicas = r.max(1);
        self
    }

    /// Optimizer binding applied to every group of the *layerwise
    /// default* wrapping. Ignored once `.group(..)` / `.spec(..)`
    /// declares explicit wrap units — each declared [`ShardGroupSpec`]
    /// carries its own binding.
    pub fn optimizer(mut self, optim: OptimBinding) -> Self {
        self.optim = optim;
        self
    }

    /// Sharding policy applied to every group of the *layerwise default*
    /// wrapping. Like [`SessionBuilder::optimizer`], ignored once
    /// explicit wrap units are declared.
    pub fn policy(mut self, policy: ShardingPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn hyper(mut self, hyper: AdamHyper) -> Self {
        self.hyper = hyper;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Cluster backend executing collectives + per-rank compute.
    pub fn backend(mut self, backend: CommBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Step-loop schedule (sequential or bucket-pipelined).
    pub fn exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    /// Fabric cost model the session (and its step logs) runs on.
    pub fn fabric(mut self, fabric: Fabric) -> Self {
        self.fabric = fabric;
        self
    }

    /// Wire precision applied to every group of the *layerwise default*
    /// wrapping (`--comm-precision f32|bf16|q8[:block]`). Like
    /// [`SessionBuilder::optimizer`], ignored once explicit wrap units
    /// are declared — each [`ShardGroupSpec`] carries its own precision.
    pub fn comm_precision(mut self, prec: CommPrecision) -> Self {
        self.comm_precision = prec;
        self
    }

    /// Serial-fallback / two-level dispatch threshold in total elements
    /// (`[comm] hier_threshold` / `--hier-threshold`). Consulted by the
    /// runtime's collective dispatch and by [`SessionBuilder::analyze`]'s
    /// tier modeling, so the lint verdict always matches what would run.
    pub fn hier_threshold(mut self, elems: usize) -> Self {
        self.hier_threshold = elems;
        self
    }

    /// Tracing level (`--trace-level off|comm|full`): `Off` keeps every
    /// instrumentation site down to a bare timer read, `Comm` records
    /// collective + exposed-comm spans, `Full` adds per-rank compute
    /// spans. Tracing never changes the math — trajectories are
    /// bit-identical at every level.
    pub fn trace(mut self, level: TraceLevel) -> Self {
        self.trace = level;
        self
    }

    /// Arm the runtime health monitor (heartbeats, collective watchdog,
    /// flight recorder, metrics) with the given [`ObsConfig`]. Disarmed
    /// by default — the off path costs at most one branch per event, and
    /// monitoring never changes the math (trajectories stay bit-identical,
    /// enforced by `tests/health_monitor.rs`).
    pub fn observer(mut self, cfg: ObsConfig) -> Self {
        self.obs = Some(cfg);
        self
    }

    /// Shorthand for [`SessionBuilder::observer`]: arm the monitor with
    /// default knobs and this watchdog deadline (`--watchdog-ms`; 0 keeps
    /// the watchdog off while still recording heartbeats and metrics).
    pub fn watchdog_ms(mut self, ms: u64) -> Self {
        let mut cfg = self.obs.take().unwrap_or_default();
        cfg.watchdog_ms = ms;
        self.obs = Some(cfg);
        self
    }

    /// Append a custom wrap unit. The first `.group(..)` call switches
    /// the builder from the layerwise default to fully explicit wrapping
    /// — declare every group (declaration order = bucket order), each
    /// with its own policy and optimizer binding
    /// ([`SessionBuilder::optimizer`] / [`SessionBuilder::policy`] no
    /// longer apply).
    pub fn group(mut self, g: ShardGroupSpec) -> Self {
        self.groups.push(g);
        self
    }

    /// Use a complete [`ModelSpec`] (e.g.
    /// [`ModelSpec::layerwise_mixed_muon`]) instead of the layerwise
    /// default; takes precedence over `.group(..)` calls.
    pub fn spec(mut self, spec: ModelSpec) -> Self {
        self.spec = Some(spec);
        self
    }

    /// Apply config-file `[group.*]` sections on top of the resolved spec
    /// (per-group optimizer / granularity / reshard / lr edits).
    pub fn overrides(mut self, overrides: Vec<GroupOverride>) -> Self {
        self.overrides = overrides;
        self
    }

    /// The wrap spec this builder resolves to (explicit spec > custom
    /// groups > layerwise default with the builder's uniform
    /// policy/optimizer).
    pub fn resolve_spec(&self, n_layers: usize) -> ModelSpec {
        match &self.spec {
            Some(s) => s.clone(),
            None if !self.groups.is_empty() => ModelSpec { groups: self.groups.clone() },
            None => {
                let mut s = ModelSpec::layerwise(n_layers);
                for g in s.groups.iter_mut() {
                    g.policy = self.policy.clone();
                    g.optim = self.optim;
                    g.comm_precision = self.comm_precision;
                }
                s
            }
        }
    }

    /// Statically lint the session this builder would construct, without
    /// building it: resolve the wrap spec exactly as [`SessionBuilder::build`]
    /// does (config manifest, overrides, PJRT executor fallback), then
    /// elaborate the full per-rank schedule into the analysis IR and run
    /// every check. This is the `--lint` pre-flight on `train` and the
    /// `fsdp-lint --model` path; it performs no compute and allocates no
    /// shards.
    pub fn analyze(&self) -> Result<crate::analysis::AnalysisReport> {
        let runtime = Engine::load_default().context("loading compute runtime")?;
        let cfg = runtime
            .manifest
            .configs
            .get(&self.config)
            .ok_or_else(|| anyhow!("config '{}' not in manifest", self.config))?
            .clone();
        let mut spec = self.resolve_spec(cfg.n_layers);
        let (blanket, specific): (Vec<&GroupOverride>, Vec<&GroupOverride>) =
            self.overrides.iter().partition(|o| o.which == "layers");
        for o in blanket.into_iter().chain(specific) {
            apply_group_override(&mut spec, o, self.hyper)?;
        }
        // mirror build(): PJRT can only drive the sequential schedule
        let exec = if runtime.is_native() { self.exec } else { ExecMode::Sequential };
        Ok(crate::analysis::lint(&crate::analysis::LintRequest {
            model: &self.config,
            params: &cfg.params,
            spec: &spec,
            devices: self.devices,
            replicas: self.replicas,
            backend: self.backend,
            exec,
            topology: self.fabric.topology,
            hier_threshold: self.hier_threshold,
            native_layers: Some(cfg.n_layers),
            mem_limit: crate::fsdp::DEVICE_MEM_LIMIT,
        }))
    }

    pub fn build(self) -> Result<TrainSession> {
        let runtime = Engine::load_default().context("loading compute runtime")?;
        let cfg = runtime
            .manifest
            .configs
            .get(&self.config)
            .ok_or_else(|| anyhow!("config '{}' not in manifest", self.config))?
            .clone();
        let mut spec = self.resolve_spec(cfg.n_layers);
        // blanket sections ([group.layers]) first, then specific ones, so
        // a [group.layer0] exception survives a [group.layers] default no
        // matter how the config file (or the BTreeMap) ordered them
        let (blanket, specific): (Vec<&GroupOverride>, Vec<&GroupOverride>) =
            self.overrides.iter().partition(|o| o.which == "layers");
        for o in blanket.into_iter().chain(specific) {
            apply_group_override(&mut spec, o, self.hyper)?;
        }
        let mesh = if self.replicas > 1 {
            DeviceMesh::new(&[("replica", self.replicas), ("fsdp", self.devices)])?
        } else {
            DeviceMesh::flat("fsdp", self.devices)
        };
        let tracer = Tracer::new(self.trace, self.devices);
        let topology = self.fabric.topology;
        if topology.is_hierarchical() {
            // stamps the exported trace metadata, which in turn makes
            // `trace::check::validate` demand per-tier span attribution
            tracer.set_topology(&topology.label());
        }
        let obs = match &self.obs {
            Some(c) => Observer::new(c.clone(), self.devices),
            None => Observer::off(),
        };
        crate::obs::install_panic_hook(&obs);
        let comm = CommBuilder::new(self.backend)
            .tracer(tracer.clone())
            .topology(topology)
            .observer(obs.clone())
            .hier_threshold(self.hier_threshold)
            .build();
        let mut engine = FsdpEngine::from_spec(
            cfg.params.clone(),
            &spec,
            mesh,
            self.fabric.clone(),
            comm,
        )?;
        engine.set_tracer(tracer.clone());
        engine.set_observer(obs.clone());
        engine.init_params(&init_full_params(&cfg.params, self.seed))?;
        let qblock = runtime.manifest.qblock;
        let m = engine.num_devices();
        let optimizers: Vec<Box<dyn GroupOptimizer>> = spec
            .groups
            .iter()
            .enumerate()
            .map(|(b, g)| {
                let n_params = engine.buckets[b].param_ids.len();
                g.optim.build(g.hyper.unwrap_or(self.hyper), qblock, n_params, m)
            })
            .collect();
        // the pipelined executor drives compute layer-wise, which only the
        // native runtime supports; PJRT falls back to the sequential path
        let exec = if runtime.is_native() {
            self.exec
        } else {
            if self.exec != ExecMode::Sequential {
                eprintln!(
                    "note: the pipelined executor requires the native runtime; \
                     falling back to the sequential schedule"
                );
            }
            ExecMode::Sequential
        };
        Ok(TrainSession {
            engine,
            runtime,
            config: self.config,
            corpus: Corpus::new(cfg.vocab, self.seed + 1),
            optimizers,
            exec,
            last_report: None,
            tracer,
            obs,
            step: 0,
            log: Vec::new(),
        })
    }
}

/// Apply one `[group.<which>]` override to the resolved spec. Errors
/// (naming the section) when it matches no group — a typo in a config
/// file must not silently train the wrong setup.
fn apply_group_override(
    spec: &mut ModelSpec,
    o: &GroupOverride,
    base_hyper: AdamHyper,
) -> Result<()> {
    let mut applied = false;
    for g in spec.groups.iter_mut() {
        let hit = if o.which == "layers" {
            g.name.starts_with("layer")
        } else {
            g.name == o.which
        };
        if !hit {
            continue;
        }
        applied = true;
        if let Some(b) = o.optim {
            g.optim = b;
        }
        if let Some(rows) = o.rows {
            g.policy = if rows > 0 {
                ShardingPolicy::uniform_rows(rows)
            } else {
                ShardingPolicy::element_wise()
            };
        }
        if let Some(gran) = o.granularity {
            g.policy.default_granularity = gran.max(1);
        }
        if let Some(r) = o.reshard {
            g.reshard_after_forward = r;
        }
        if let Some(lr) = o.lr {
            let mut h = g.hyper.unwrap_or(base_hyper);
            h.lr = lr;
            g.hyper = Some(h);
        }
        if let Some(p) = o.comm {
            g.comm_precision = p;
        }
    }
    if !applied {
        let names: Vec<&str> = spec.groups.iter().map(|g| g.name.as_str()).collect();
        bail!(
            "[group.{}] matched no shard group (groups: {names:?})",
            o.which
        );
    }
    Ok(())
}

impl TrainSession {
    /// Start a [`SessionBuilder`] for `config`.
    pub fn builder(config: &str) -> SessionBuilder {
        SessionBuilder::new(config)
    }

    /// Serial-backend trainer (the seed behavior). Legacy shim over
    /// [`SessionBuilder`].
    pub fn new(
        config: &str,
        m: usize,
        optim: OptimKind,
        policy: &ShardingPolicy,
        hyper: AdamHyper,
        seed: u64,
    ) -> Result<TrainSession> {
        TrainSession::with_backend(config, m, optim, policy, hyper, seed, CommBackend::Serial)
    }

    /// Legacy shim over [`SessionBuilder`].
    pub fn with_backend(
        config: &str,
        m: usize,
        optim: OptimKind,
        policy: &ShardingPolicy,
        hyper: AdamHyper,
        seed: u64,
        backend: CommBackend,
    ) -> Result<TrainSession> {
        TrainSession::with_exec(
            config,
            m,
            optim,
            policy,
            hyper,
            seed,
            backend,
            ExecMode::Sequential,
        )
    }

    /// Legacy 8-argument constructor: a thin shim over the builder (one
    /// uniform optimizer binding + one global policy on the layerwise
    /// wrapping). Bit-identical to the builder path — asserted by
    /// `tests/spec_api.rs`.
    #[allow(clippy::too_many_arguments)]
    pub fn with_exec(
        config: &str,
        m: usize,
        optim: OptimKind,
        policy: &ShardingPolicy,
        hyper: AdamHyper,
        seed: u64,
        backend: CommBackend,
        exec: ExecMode,
    ) -> Result<TrainSession> {
        TrainSession::builder(config)
            .devices(m)
            .optimizer(OptimBinding::from_kind(optim))
            .policy(policy.clone())
            .hyper(hyper)
            .seed(seed)
            .backend(backend)
            .exec(exec)
            .build()
    }

    /// One synchronous training step across all simulated devices, driven
    /// by the executor schedule (`self.exec`).
    pub fn train_step(&mut self) -> Result<f32> {
        let t0 = std::time::Instant::now();
        self.tracer.set_step(self.step + 1);
        self.obs.set_step(self.step + 1);
        let (batch, seq) = {
            let cfg = &self.runtime.manifest.configs[&self.config];
            (cfg.batch, cfg.seq)
        };
        let m = self.engine.num_devices();
        let comm_before = self.engine.comm.sim_time();
        let wire_before = self.engine.comm.wire_totals();

        // draw every rank's batch on the coordinator in rank order so the
        // token stream is identical no matter how compute executes
        let batches: Vec<(Vec<i32>, Vec<i32>)> =
            (0..m).map(|_| self.corpus.batch(batch, seq)).collect();
        let outcome = exec::run_step(
            &mut self.engine,
            &mut self.runtime,
            &self.config,
            &batches,
            self.exec,
        )?;
        self.step += 1;
        // uniform per-group dispatch — Muon / 8-bit Adam / AdamW / SGD all
        // step through the same trait, group by group
        self.engine.optimizer_step_groups(&mut self.optimizers, self.step)?;
        let loss = outcome.losses.iter().sum::<f32>() / m as f32;
        let wire_after = self.engine.comm.wire_totals();
        if self.tracer.is_enabled() {
            // counter tracks: allocator levels + cumulative wire bytes,
            // sampled once per step at a fixed schedule point
            let (reserved, allocated) = {
                let a = self.engine.alloc.lock().unwrap();
                (a.reserved, a.allocated)
            };
            self.tracer.counter("mem.reserved", reserved as f64);
            self.tracer.counter("mem.allocated", allocated as f64);
            self.tracer.counter("wire.payload", wire_after.0 as f64);
            self.tracer.counter("wire.scale", wire_after.1 as f64);
            self.tracer.counter("wire.pad", wire_after.2 as f64);
        }
        if self.obs.armed() {
            let r = &outcome.report;
            // overlap efficiency: the fraction of this step's (simulated)
            // comm the schedule hid under compute
            let overlap = if r.sim_comm_s > 0.0 {
                (r.sim_comm_s - r.exposed_comm_s).max(0.0) / r.sim_comm_s
            } else {
                0.0
            };
            let wire_delta = (wire_after.0 - wire_before.0)
                + (wire_after.1 - wire_before.1)
                + (wire_after.2 - wire_before.2);
            self.obs.observe_step(
                self.step,
                r.wall_s,
                r.exposed_comm_s,
                overlap,
                wire_delta,
                r.peak_reserved,
                r.peak_allocated,
            );
        }
        self.log.push(StepLog {
            step: self.step,
            loss,
            // simulated comm this step, including optimizer collectives
            comm_time: self.engine.comm.sim_time() - comm_before,
            exposed_s: outcome.report.exposed_comm_s,
            wall_s: t0.elapsed().as_secs_f64(),
            fabric: self.engine.fabric.name,
            topology: topology_column(&self.engine.fabric),
            // measured per-step wire volume (payload vs scales vs pad)
            wire_payload: wire_after.0 - wire_before.0,
            wire_scale: wire_after.1 - wire_before.1,
            wire_pad: wire_after.2 - wire_before.2,
            peak_reserved: outcome.report.peak_reserved,
            peak_allocated: outcome.report.peak_allocated,
        });
        self.last_report = Some(outcome.report);
        Ok(loss)
    }

    pub fn run(&mut self, steps: usize) -> Result<Vec<StepLog>> {
        for _ in 0..steps {
            self.train_step()?;
        }
        Ok(self.log.clone())
    }

    /// Machine-readable summary of the traced run: per-bucket exposed
    /// comm, overlap efficiency, per-rank skew, measured-vs-simulated
    /// time per collective.
    pub fn trace_summary(&self) -> TraceSummary {
        self.tracer.summary(&self.engine.comm.stats())
    }

    /// The full Chrome trace-event document for the traced run
    /// (Perfetto / `chrome://tracing` loadable).
    pub fn trace_json(&self) -> Json {
        self.tracer.export(&self.engine.comm.stats())
    }

    /// Write the Chrome trace JSON to `path`. IO failures surface as
    /// typed [`codes::EXPORT_IO`] diagnostics (not bare panics), so the
    /// postmortem hook still runs on export errors.
    pub fn write_trace(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.trace_json().to_string()).map_err(|e| {
            anyhow!(
                "{}",
                rt(codes::EXPORT_IO, format_args!("writing trace to {}: {e}", path.display()))
            )
        })?;
        Ok(())
    }
}

/// DDP reference trainer (Fig 10): replicated parameters, bucketed
/// AllReduce gradient averaging (through the cluster backend), and a
/// full-parameter optimizer.
pub struct DdpTrainer {
    pub runtime: Engine,
    pub config: String,
    pub comm: Arc<dyn crate::cluster::Communicator>,
    pub fabric: Fabric,
    pub params: Vec<Vec<f32>>,
    pub corpus: Corpus,
    pub optimizer: Box<dyn ShardOptimizer>,
    pub devices: usize,
    pub step: u64,
    pub log: Vec<StepLog>,
}

impl DdpTrainer {
    pub fn new(
        config: &str,
        devices: usize,
        optim: OptimKind,
        hyper: AdamHyper,
        seed: u64,
    ) -> Result<DdpTrainer> {
        DdpTrainer::with_backend(config, devices, optim, hyper, seed, CommBackend::Serial)
    }

    pub fn with_backend(
        config: &str,
        devices: usize,
        optim: OptimKind,
        hyper: AdamHyper,
        seed: u64,
        backend: CommBackend,
    ) -> Result<DdpTrainer> {
        let runtime = Engine::load_default()?;
        let cfg = runtime
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow::anyhow!("config '{config}' not in manifest"))?
            .clone();
        if optim == OptimKind::Muon {
            bail!("use Trainer (FSDP) for Muon");
        }
        let qblock = runtime.manifest.qblock;
        // one state slot per tensor (the ShardOptimizer "rank" index keys
        // independent state vectors)
        let slots = cfg.params.len();
        let optimizer: Box<dyn ShardOptimizer> = match optim {
            OptimKind::Sgd => Box::new(Sgd::new(hyper.lr, 0.9, slots)),
            OptimKind::AdamW => Box::new(AdamW::new(hyper, slots)),
            OptimKind::Adam8bit => Box::new(Adam8bit::new(hyper, qblock, slots)),
            OptimKind::Muon => unreachable!(),
        };
        let params = init_full_params(&cfg.params, seed);
        Ok(DdpTrainer {
            runtime,
            config: config.to_string(),
            comm: CommBuilder::new(backend).build(),
            fabric: Fabric::h800(),
            params,
            corpus: Corpus::new(cfg.vocab, seed + 1),
            optimizer,
            devices,
            step: 0,
            log: Vec::new(),
        })
    }

    pub fn train_step(&mut self) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let cfg = self.runtime.manifest.configs[&self.config].clone();
        let m = self.devices;
        let wire_before = self.comm.wire_totals();
        // per-device microbatches (drawn in rank order on the coordinator)
        let batches: Vec<(Vec<i32>, Vec<i32>)> =
            (0..m).map(|_| self.corpus.batch(cfg.batch, cfg.seq)).collect();
        let mut losses = Vec::with_capacity(m);
        let mut all_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(m);
        if self.comm.backend() == CommBackend::Threaded && self.runtime.is_native() {
            // direct native call for the same &Engine-Sync reason as Trainer
            let params = &self.params;
            let (outs, _) = Cluster::run_spmd(m, |rank, _ctx| {
                let (tokens, targets) = &batches[rank];
                crate::runtime::native::train_step(&cfg, params, tokens, targets)
            });
            for out in outs {
                let (loss, grads) = out?;
                losses.push(loss);
                all_grads.push(grads);
            }
        } else {
            for rank in 0..m {
                let (tokens, targets) = &batches[rank];
                let (loss, grads) =
                    self.runtime.train_step(&self.config, &self.params, tokens, targets)?;
                losses.push(loss);
                all_grads.push(grads);
            }
        }
        // bucketed AllReduce through the cluster backend (sum in rank
        // order then scale by 1/m — identical on every backend)
        let mut mean_grads: Vec<Vec<f32>> = Vec::with_capacity(self.params.len());
        for ti in 0..self.params.len() {
            let mut bufs: Vec<Vec<f32>> = all_grads
                .iter_mut()
                .map(|g| std::mem::take(&mut g[ti]))
                .collect();
            self.comm.all_reduce(&mut bufs, 1.0 / m as f32)?;
            let bytes = (bufs[0].len() * 4) as u64;
            self.comm.record(CommRecord::dense(
                "all_reduce",
                bytes,
                m,
                self.fabric.all_reduce_time(m, bytes, true),
            ));
            mean_grads.push(bufs.into_iter().next().unwrap());
        }
        self.step += 1;
        // 8-bit Adam quant blocks: DDP holds full params, every block is
        // trivially local — pad params to the quant block? The flat param
        // per tensor may not be a block multiple; DDP quantizes per tensor
        // padded to the block, matching common implementations.
        for (i, p) in self.params.iter_mut().enumerate() {
            let g = &mean_grads[i];
            if self.optimizer.name() == "adam8bit" {
                let block = self.runtime.manifest.qblock;
                let n = p.len();
                let padded = n.div_ceil(block) * block;
                let mut pp = vec![0.0f32; padded];
                pp[..n].copy_from_slice(p);
                let mut gp = g.clone();
                gp.resize(padded, 0.0);
                self.optimizer.step(i, self.step, &mut pp, &gp);
                p.copy_from_slice(&pp[..n]);
            } else {
                self.optimizer.step(i, self.step, p, g);
            }
        }
        let loss = losses.iter().sum::<f32>() / self.devices as f32;
        let wire_after = self.comm.wire_totals();
        self.log.push(StepLog {
            step: self.step,
            loss,
            comm_time: 0.0,
            exposed_s: 0.0,
            wall_s: t0.elapsed().as_secs_f64(),
            fabric: self.fabric.name,
            topology: topology_column(&self.fabric),
            wire_payload: wire_after.0 - wire_before.0,
            wire_scale: wire_after.1 - wire_before.1,
            wire_pad: wire_after.2 - wire_before.2,
            peak_reserved: 0,
            peak_allocated: 0,
        });
        Ok(loss)
    }

    pub fn run(&mut self, steps: usize) -> Result<Vec<StepLog>> {
        for _ in 0..steps {
            self.train_step()?;
        }
        Ok(self.log.clone())
    }
}

/// StepLog/CSV form of a fabric's topology: `"HxG"` when hierarchical,
/// `"flat"` for single-host runs.
fn topology_column(fabric: &Fabric) -> String {
    if fabric.topology.is_hierarchical() {
        fabric.topology.label()
    } else {
        "flat".to_string()
    }
}

/// Write a loss log as CSV under `runs/`. IO failures surface as typed
/// [`codes::EXPORT_IO`] diagnostics instead of bare `?`-bubbled OS
/// errors, so callers (and postmortem dumps) see a stable code.
pub fn save_log(name: &str, log: &[StepLog]) -> Result<std::path::PathBuf> {
    let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/runs"));
    std::fs::create_dir_all(dir).map_err(|e| {
        anyhow!("{}", rt(codes::EXPORT_IO, format_args!("creating {}: {e}", dir.display())))
    })?;
    let path = dir.join(format!("{name}.csv"));
    let mut out = String::from(
        "step,loss,comm_time,exposed_s,wall_s,fabric,topology,wire_payload,wire_scale,\
         wire_pad,peak_reserved,peak_allocated\n",
    );
    for l in log {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{}\n",
            l.step,
            l.loss,
            l.comm_time,
            l.exposed_s,
            l.wall_s,
            l.fabric,
            l.topology,
            l.wire_payload,
            l.wire_scale,
            l.wire_pad,
            l.peak_reserved,
            l.peak_allocated
        ));
    }
    std::fs::write(&path, out).map_err(|e| {
        anyhow!("{}", rt(codes::EXPORT_IO, format_args!("writing {}: {e}", path.display())))
    })?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_learnable_structure() {
        let mut c = Corpus::new(512, 0);
        let (tokens, targets) = c.batch(2, 64);
        assert_eq!(tokens.len(), 128);
        assert_eq!(targets.len(), 128);
        // shifted-by-one property within each row
        for b in 0..2 {
            for i in 0..63 {
                assert_eq!(tokens[b * 64 + i + 1], targets[b * 64 + i]);
            }
        }
        // successor structure: the most frequent bigram follows succ map
        let mut follows = 0;
        let mut total = 0;
        let mut c2 = Corpus::new(512, 1);
        let mut prev = c2.next_token();
        for _ in 0..5000 {
            let nxt = c2.next_token();
            if nxt == c2.succ[prev as usize] {
                follows += 1;
            }
            total += 1;
            prev = nxt;
        }
        let frac = follows as f64 / total as f64;
        assert!(frac > 0.75, "successor fraction {frac}");
    }

    #[test]
    fn corpus_deterministic_per_seed() {
        let mut a = Corpus::new(128, 7);
        let mut b = Corpus::new(128, 7);
        assert_eq!(a.batch(1, 32), b.batch(1, 32));
        let mut c = Corpus::new(128, 8);
        assert_ne!(a.batch(1, 32), c.batch(1, 32));
    }

    #[test]
    fn entropy_floor_below_uniform() {
        let c = Corpus::new(512, 0);
        assert!(c.entropy_floor() < (512.0f64).ln());
    }

    #[test]
    fn init_params_match_abi() {
        let abi = vec![
            ("embed.weight".to_string(), vec![16, 8]),
            ("layers.0.ln1.scale".to_string(), vec![8]),
            ("layers.0.attn.wq".to_string(), vec![8, 8]),
        ];
        let full = init_full_params(&abi, 0);
        assert_eq!(full[0].len(), 128);
        assert!(full[1].iter().all(|&x| x == 1.0));
        // wq ~ N(0, 1/sqrt(8)): std within loose bounds
        let std: f32 =
            (full[2].iter().map(|x| x * x).sum::<f32>() / 64.0).sqrt();
        assert!((0.1..0.8).contains(&std), "std {std}");
    }

    #[test]
    fn optimizer_factory_kinds() {
        let opts = make_optimizers(OptimKind::Adam8bit, AdamHyper::default(), 64, 3, 2);
        assert_eq!(opts.len(), 3);
        assert_eq!(opts[0].name(), "adam8bit");
    }

    #[test]
    fn builder_resolves_layerwise_spec_with_defaults() {
        let b = TrainSession::builder("tiny")
            .optimizer(OptimBinding::Muon)
            .policy(ShardingPolicy::uniform_rows(4));
        let spec = b.resolve_spec(2);
        assert_eq!(spec.groups.len(), 4); // embed | layer0 | layer1 | head
        assert!(spec.groups.iter().all(|g| g.optim == OptimBinding::Muon));
        assert!(spec
            .groups
            .iter()
            .all(|g| g.policy.row_granularity.contains_key("*")));
    }

    #[test]
    fn explicit_groups_replace_layerwise_default() {
        use crate::fsdp::spec::GroupFilter;
        let b = TrainSession::builder("tiny")
            .group(ShardGroupSpec::new("all", GroupFilter::Rest));
        let spec = b.resolve_spec(2);
        assert_eq!(spec.groups.len(), 1);
        assert_eq!(spec.groups[0].name, "all");
    }

    #[test]
    fn group_override_targets_layer_groups() {
        let mut spec = ModelSpec::layerwise(2);
        let o = GroupOverride {
            which: "layers".into(),
            optim: Some(OptimBinding::Muon),
            lr: Some(0.02),
            ..GroupOverride::default()
        };
        apply_group_override(&mut spec, &o, AdamHyper::default()).unwrap();
        assert_eq!(spec.group_named("layer0").unwrap().optim, OptimBinding::Muon);
        assert_eq!(spec.group_named("layer1").unwrap().optim, OptimBinding::Muon);
        assert_eq!(spec.group_named("embed").unwrap().optim, OptimBinding::AdamW);
        let h = spec.group_named("layer0").unwrap().hyper.unwrap();
        assert_eq!(h.lr, 0.02);
    }

    #[test]
    fn group_override_rows_and_reshard() {
        let mut spec = ModelSpec::layerwise(1);
        let o = GroupOverride {
            which: "head".into(),
            rows: Some(32),
            reshard: Some(false),
            ..GroupOverride::default()
        };
        apply_group_override(&mut spec, &o, AdamHyper::default()).unwrap();
        let head = spec.group_named("head").unwrap();
        assert!(!head.reshard_after_forward);
        assert_eq!(head.policy.row_granularity.get("*"), Some(&32));
    }

    #[test]
    fn specific_layer_override_survives_blanket_layers_section() {
        // [group.layers] (blanket) + [group.layer0] (exception): build
        // applies blanket first so the exception wins, regardless of the
        // config map's alphabetical section order ("layer0" < "layers")
        let t = TrainSession::builder("tiny")
            .devices(2)
            .overrides(vec![
                GroupOverride {
                    which: "layer0".into(),
                    optim: Some(OptimBinding::AdamW),
                    ..GroupOverride::default()
                },
                GroupOverride {
                    which: "layers".into(),
                    optim: Some(OptimBinding::Muon),
                    ..GroupOverride::default()
                },
            ])
            .build()
            .unwrap();
        let names: Vec<&str> = t.optimizers.iter().map(|o| o.name()).collect();
        assert_eq!(names, vec!["adamw", "adamw", "muon", "adamw"]);
    }

    #[test]
    fn group_override_typo_is_an_error() {
        let mut spec = ModelSpec::layerwise(1);
        let o = GroupOverride { which: "embedd".into(), ..GroupOverride::default() };
        let err = apply_group_override(&mut spec, &o, AdamHyper::default()).unwrap_err();
        assert!(err.to_string().contains("embedd"), "{err}");
    }

    // End-to-end Trainer tests (need artifacts + PJRT) live in
    // rust/tests/integration.rs.
}
