//! DTensor: a logical global tensor distributed over a mesh dim, with
//! `redistribute` between placements (the PyTorch primitive the paper
//! builds RaggedShard into; §2.2 Fig 1, §4).
//!
//! The simulation keeps every rank's local tensor in host memory;
//! `redistribute` moves real data and accounts the implied collective on
//! the fabric model. Supported conversions cover everything the paper's
//! algorithms use:
//!
//! * `RaggedShard -> RaggedShard(root)` — Muon's unshard (Alg 2 line 8);
//! * `RaggedShard -> Replicate` — AllGather materialization;
//! * `Replicate -> RaggedShard` — shard (communication-free slicing);
//! * `Partial -> RaggedShard` — ReduceScatter;
//! * `Partial -> Replicate` — AllReduce;
//! * `RaggedShard -> RaggedShard` (arbitrary respec) — All2All-style.

use anyhow::{bail, Result};

use crate::cluster::Communicator;
use crate::comm::{CommRecord, Fabric};
use crate::placement::{Placement, RaggedSpec};

#[derive(Debug, Clone)]
pub struct DTensor {
    pub global_shape: Vec<usize>,
    pub placement: Placement,
    /// Per-rank local tensor (flat). For Replicate every rank holds the
    /// full tensor; for Partial every rank holds an unreduced term.
    pub locals: Vec<Vec<f32>>,
}

impl DTensor {
    pub fn numel(&self) -> u64 {
        self.global_shape.iter().map(|&s| s as u64).product()
    }

    pub fn num_ranks(&self) -> usize {
        self.locals.len()
    }

    /// Build a replicated DTensor from full data.
    pub fn replicate(global_shape: &[usize], data: &[f32], m: usize) -> DTensor {
        assert_eq!(data.len(), global_shape.iter().product::<usize>());
        DTensor {
            global_shape: global_shape.to_vec(),
            placement: Placement::Replicate,
            locals: vec![data.to_vec(); m],
        }
    }

    /// Build a RaggedShard DTensor from full data (communication-free).
    pub fn ragged_from_full(
        global_shape: &[usize],
        data: &[f32],
        spec: RaggedSpec,
    ) -> Result<DTensor> {
        let numel = data.len() as u64;
        spec.validate(numel)?;
        let locals = (0..spec.num_devices())
            .map(|k| {
                let (lo, hi) = spec.local_range(k, numel);
                data[lo as usize..hi as usize].to_vec()
            })
            .collect();
        Ok(DTensor {
            global_shape: global_shape.to_vec(),
            placement: Placement::RaggedShard(spec),
            locals,
        })
    }

    /// Build a Partial DTensor (each rank holds one term of a pending sum).
    pub fn partial(global_shape: &[usize], terms: Vec<Vec<f32>>) -> DTensor {
        DTensor {
            global_shape: global_shape.to_vec(),
            placement: Placement::Partial,
            locals: terms,
        }
    }

    /// Materialize the full tensor (uses rank data as placement dictates).
    pub fn to_full(&self) -> Vec<f32> {
        match &self.placement {
            Placement::Replicate => self.locals[0].clone(),
            Placement::RaggedShard(_) | Placement::StridedRaggedShard(_, _) => {
                let mut out = Vec::with_capacity(self.numel() as usize);
                for l in &self.locals {
                    out.extend_from_slice(l);
                }
                out
            }
            Placement::Partial => {
                let mut out = vec![0.0f32; self.numel() as usize];
                for l in &self.locals {
                    for (o, x) in out.iter_mut().zip(l) {
                        *o += x;
                    }
                }
                out
            }
            Placement::Shard(0) => {
                let mut out = Vec::with_capacity(self.numel() as usize);
                for l in &self.locals {
                    out.extend_from_slice(l);
                }
                out
            }
            Placement::Shard(d) => panic!("to_full unsupported for Shard({d})"),
        }
    }

    /// Redistribute to a new placement, moving real data through the
    /// cluster backend and accounting the implied collective. Pending-sum
    /// (`Partial`) conversions execute as genuine collectives on `comm`,
    /// so the threaded backend reduces them with one thread per rank;
    /// ragged respecs are owner-change copies (order-independent), so
    /// every backend produces bit-identical locals.
    pub fn redistribute(
        &self,
        to: Placement,
        comm: &dyn Communicator,
        fabric: &Fabric,
    ) -> Result<DTensor> {
        let m = self.num_ranks();
        let numel = self.numel();
        let bytes = numel * 4;
        match (&self.placement, &to) {
            (a, b) if a == b => Ok(self.clone()),

            // ---- RaggedShard -> RaggedShard' (incl. gather-to-root) ----
            (Placement::RaggedShard(_), Placement::RaggedShard(spec2)) => {
                spec2.validate(numel)?;
                let full = self.to_full();
                let out = DTensor::ragged_from_full(&self.global_shape, &full, spec2.clone())?;
                // cost: each element moving ranks crosses the wire once;
                // worst case (gather to root) ~ AllGather of others' shards
                let moved = self.moved_bytes(spec2, numel);
                comm.record(CommRecord::dense(
                    "redistribute",
                    moved / m as u64,
                    m,
                    fabric.all_gather_time(m, moved / m as u64, true),
                ));
                Ok(out)
            }

            // ---- RaggedShard -> Replicate (AllGather) ----
            (Placement::RaggedShard(spec), Placement::Replicate) => {
                let full = self.to_full();
                comm.record(CommRecord::dense(
                    "all_gather",
                    spec.max_local_numel(numel) * 4,
                    m,
                    fabric.all_gather_time(m, spec.max_local_numel(numel) * 4, true),
                ));
                Ok(DTensor::replicate(&self.global_shape, &full, m))
            }

            // ---- Replicate -> RaggedShard (free slicing) ----
            (Placement::Replicate, Placement::RaggedShard(spec2)) => {
                DTensor::ragged_from_full(&self.global_shape, &self.locals[0], spec2.clone())
            }

            // ---- Partial -> RaggedShard (ReduceScatter) ----
            (Placement::Partial, Placement::RaggedShard(spec2)) => {
                spec2.validate(numel)?;
                let mut bufs = self.locals.clone();
                comm.all_reduce(&mut bufs, 1.0)?;
                let out =
                    DTensor::ragged_from_full(&self.global_shape, &bufs[0], spec2.clone())?;
                comm.record(CommRecord::dense(
                    "reduce_scatter",
                    bytes / m as u64,
                    m,
                    fabric.reduce_scatter_time(m, bytes / m as u64, true),
                ));
                Ok(out)
            }

            // ---- Partial -> Replicate (AllReduce) ----
            (Placement::Partial, Placement::Replicate) => {
                let mut bufs = self.locals.clone();
                comm.all_reduce(&mut bufs, 1.0)?;
                comm.record(CommRecord::dense(
                    "all_reduce",
                    bytes / m as u64,
                    m,
                    fabric.all_reduce_time(m, bytes / m as u64, true),
                ));
                Ok(DTensor {
                    global_shape: self.global_shape.clone(),
                    placement: Placement::Replicate,
                    locals: bufs,
                })
            }

            (from, to) => bail!("unsupported redistribute {from:?} -> {to:?}"),
        }
    }

    /// Bytes that change owner going from the current ragged spec to
    /// `spec2` (cost of an arbitrary respec).
    fn moved_bytes(&self, spec2: &RaggedSpec, numel: u64) -> u64 {
        let spec1 = match self.placement.ragged_spec() {
            Some(s) => s,
            None => return numel * 4,
        };
        let mut moved = 0u64;
        for k in 0..self.num_ranks() {
            let (a1, b1) = spec1.local_range(k, numel);
            let (a2, b2) = spec2.local_range(k, numel);
            let overlap = b1.min(b2).saturating_sub(a1.max(a2));
            moved += (b2 - a2) - overlap; // elements k must receive
        }
        moved * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SerialComm, ThreadedComm};
    use crate::util::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn ragged_from_full_roundtrip() {
        let data = randvec(100, 1);
        let spec = RaggedSpec::balanced(100, 10, 4);
        let dt = DTensor::ragged_from_full(&[10, 10], &data, spec).unwrap();
        assert_eq!(dt.to_full(), data);
    }

    #[test]
    fn gather_to_root_muon_pattern() {
        // Alg 2 lines 5-8: redistribute(u, RaggedShard(root))
        let data = randvec(96, 2);
        let spec = RaggedSpec::balanced(96, 8, 4);
        let dt = DTensor::ragged_from_full(&[96], &data, spec).unwrap();
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        let root_spec = RaggedSpec::on_root(96, 8, 4, 2);
        let rooted = dt
            .redistribute(Placement::RaggedShard(root_spec), &comm, &fabric)
            .unwrap();
        // only root holds data -> SPMD no-op on other ranks
        assert_eq!(rooted.locals[2].len(), 96);
        assert_eq!(rooted.locals[0].len(), 0);
        assert_eq!(rooted.locals[2], data);
        assert_eq!(comm.stats().count("redistribute"), 1);
    }

    #[test]
    fn roundtrip_root_and_back_preserves() {
        let data = randvec(64, 3);
        let spec = RaggedSpec::balanced(64, 4, 4);
        let dt = DTensor::ragged_from_full(&[64], &data, spec.clone()).unwrap();
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        let rooted = dt
            .redistribute(
                Placement::RaggedShard(RaggedSpec::on_root(64, 4, 4, 0)),
                &comm,
                &fabric,
            )
            .unwrap();
        let back = rooted
            .redistribute(Placement::RaggedShard(spec), &comm, &fabric)
            .unwrap();
        assert_eq!(back.to_full(), data);
    }

    #[test]
    fn partial_reduce_scatter() {
        // 3 ranks each contribute ones -> reduced value 3.0 everywhere
        let terms: Vec<Vec<f32>> = (0..3).map(|_| vec![1.0f32; 30]).collect();
        let dt = DTensor::partial(&[30], terms);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        let spec = RaggedSpec::balanced(30, 5, 3);
        let out = dt
            .redistribute(Placement::RaggedShard(spec), &comm, &fabric)
            .unwrap();
        assert!(out.to_full().iter().all(|&x| (x - 3.0).abs() < 1e-6));
        assert_eq!(comm.stats().count("reduce_scatter"), 1);
    }

    #[test]
    fn partial_all_reduce() {
        let terms: Vec<Vec<f32>> = (0..4).map(|k| vec![k as f32; 8]).collect();
        let dt = DTensor::partial(&[8], terms);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        let out = dt.redistribute(Placement::Replicate, &comm, &fabric).unwrap();
        assert!(out.locals.iter().all(|l| l.iter().all(|&x| x == 6.0)));
        // the threaded backend reduces to identical bits (threshold 0
        // forces the rendezvous all_reduce on this small tensor)
        let tout = dt
            .redistribute(
                Placement::Replicate,
                &ThreadedComm::with_min_parallel_elems(0),
                &fabric,
            )
            .unwrap();
        for (a, b) in out.locals.iter().flatten().zip(tout.locals.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn replicate_to_ragged_is_free() {
        let data = randvec(48, 4);
        let dt = DTensor::replicate(&[48], &data, 4);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        let spec = RaggedSpec::balanced(48, 6, 4);
        let out = dt
            .redistribute(Placement::RaggedShard(spec), &comm, &fabric)
            .unwrap();
        assert_eq!(out.to_full(), data);
        assert_eq!(comm.stats().records.len(), 0); // no comm
    }

    #[test]
    fn unsupported_conversion_errors() {
        let dt = DTensor::replicate(&[8], &randvec(8, 5), 2);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        assert!(dt.redistribute(Placement::Partial, &comm, &fabric).is_err());
    }

    #[test]
    fn identity_redistribute_no_comm() {
        let data = randvec(32, 6);
        let spec = RaggedSpec::balanced(32, 4, 2);
        let dt = DTensor::ragged_from_full(&[32], &data, spec.clone()).unwrap();
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        let same = dt
            .redistribute(Placement::RaggedShard(spec), &comm, &fabric)
            .unwrap();
        assert_eq!(same.to_full(), data);
        assert_eq!(comm.stats().records.len(), 0);
    }
}
