//! Native reference execution of the L2 compute graph: the decoder-only
//! transformer fwd/bwd from `python/compile/model.py`, re-implemented in
//! pure Rust with hand-written backprop.
//!
//! This is the artifact-free fallback behind [`super::Engine`]: when the
//! PJRT feature is off (the offline crate universe has no `xla` bindings)
//! or `make artifacts` has not run, the whole training path — train CLI,
//! Fig-10 convergence, backend-equivalence tests, the table-3 speedup
//! bench — executes through these functions. The math mirrors the JAX
//! model exactly (RMSNorm eps 1e-6, tanh-approx GELU, causal softmax
//! attention, mean token cross-entropy); numerics agree with the AOT
//! artifacts to f32 rounding but are not bit-identical to XLA, which is
//! fine: every cross-backend comparison in the repo runs both sides on
//! the same engine.
//!
//! All functions take `&self`-free shared inputs, so ranks can execute
//! concurrently under [`crate::cluster::Cluster::run_spmd`].

use anyhow::{bail, Result};

use super::ModelCfg;

const RMS_EPS: f32 = 1e-6;
const GELU_C: f32 = 0.044_715;
const SQRT_2_OVER_PI: f32 = 0.797_884_56;

// ---- flat row-major matmul kernels ------------------------------------

/// (m, k) @ (k, n) -> (m, n)
fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    out
}

/// a^T @ b where a is (k, m), b is (k, n) -> (m, n)
fn mm_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    out
}

/// a @ b^T where a is (m, k), b is (n, k) -> (m, n)
fn mm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, ov) in orow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            *ov = acc;
        }
    }
    out
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

// ---- layer primitives ---------------------------------------------------

/// RMSNorm forward over `rows` rows of width `d`. Returns (y, 1/rms).
fn rmsnorm_fwd(x: &[f32], scale: &[f32], rows: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; rows * d];
    let mut rinv = vec![0.0f32; rows];
    for row in 0..rows {
        let xr = &x[row * d..(row + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        rinv[row] = r;
        let yr = &mut y[row * d..(row + 1) * d];
        for i in 0..d {
            yr[i] = xr[i] * r * scale[i];
        }
    }
    (y, rinv)
}

/// RMSNorm backward: accumulates dL/dx into `dx` and dL/dscale into
/// `dscale` (both `+=`, so residual-branch gradients compose).
fn rmsnorm_bwd(
    dy: &[f32],
    x: &[f32],
    scale: &[f32],
    rinv: &[f32],
    rows: usize,
    d: usize,
    dx: &mut [f32],
    dscale: &mut [f32],
) {
    for row in 0..rows {
        let r = rinv[row];
        let xr = &x[row * d..(row + 1) * d];
        let dyr = &dy[row * d..(row + 1) * d];
        let mut dot = 0.0f32;
        for i in 0..d {
            dot += dyr[i] * scale[i] * xr[i];
        }
        let c = r * r * r * dot / d as f32;
        let dxr = &mut dx[row * d..(row + 1) * d];
        for i in 0..d {
            dxr[i] += r * scale[i] * dyr[i] - c * xr[i];
            dscale[i] += dyr[i] * xr[i] * r;
        }
    }
}

fn gelu(x: f32) -> f32 {
    let u = SQRT_2_OVER_PI * (x + GELU_C * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

fn gelu_grad(x: f32) -> f32 {
    let u = SQRT_2_OVER_PI * (x + GELU_C * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * x * x)
}

struct AttnCache {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// softmax probabilities, (b, h, t, t); strictly-upper entries are 0
    probs: Vec<f32>,
    /// merged head outputs before the output projection, (b*t, d)
    o: Vec<f32>,
}

/// Multi-head causal self-attention forward on normed input (b*t, d).
fn attn_fwd(
    n1: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    b: usize,
    t: usize,
    d: usize,
    h: usize,
) -> (Vec<f32>, AttnCache) {
    let n = b * t;
    let hd = d / h;
    let sc = (hd as f32).powf(-0.5);
    let q = mm(n1, wq, n, d, d);
    let k = mm(n1, wk, n, d, d);
    let v = mm(n1, wv, n, d, d);
    let mut probs = vec![0.0f32; b * h * t * t];
    let mut o = vec![0.0f32; n * d];
    let mut row = vec![0.0f32; t];
    for bb in 0..b {
        for hh in 0..h {
            let pbase = (bb * h + hh) * t * t;
            for ti in 0..t {
                let qrow = &q[(bb * t + ti) * d + hh * hd..][..hd];
                let mut mx = f32::NEG_INFINITY;
                for tj in 0..=ti {
                    let krow = &k[(bb * t + tj) * d + hh * hd..][..hd];
                    let mut s = 0.0f32;
                    for x in 0..hd {
                        s += qrow[x] * krow[x];
                    }
                    s *= sc;
                    row[tj] = s;
                    if s > mx {
                        mx = s;
                    }
                }
                let mut sum = 0.0f32;
                for cell in row.iter_mut().take(ti + 1) {
                    *cell = (*cell - mx).exp();
                    sum += *cell;
                }
                let inv = 1.0 / sum;
                for tj in 0..=ti {
                    let p = row[tj] * inv;
                    probs[pbase + ti * t + tj] = p;
                    let orow = &mut o[(bb * t + ti) * d + hh * hd..][..hd];
                    let vrow = &v[(bb * t + tj) * d + hh * hd..][..hd];
                    for x in 0..hd {
                        orow[x] += p * vrow[x];
                    }
                }
            }
        }
    }
    let y = mm(&o, wo, n, d, d);
    (y, AttnCache { q, k, v, probs, o })
}

/// Attention backward. Returns (dwq, dwk, dwv, dwo, dn1).
#[allow(clippy::too_many_arguments)]
fn attn_bwd(
    dy: &[f32],
    n1: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    cache: &AttnCache,
    b: usize,
    t: usize,
    d: usize,
    h: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = b * t;
    let hd = d / h;
    let sc = (hd as f32).powf(-0.5);
    let dwo = mm_tn(&cache.o, dy, n, d, d);
    let do_ = mm_nt(dy, wo, n, d, d);
    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dv = vec![0.0f32; n * d];
    let mut dprow = vec![0.0f32; t];
    for bb in 0..b {
        for hh in 0..h {
            let pbase = (bb * h + hh) * t * t;
            for ti in 0..t {
                let dorow = &do_[(bb * t + ti) * d + hh * hd..][..hd];
                let prow = &cache.probs[pbase + ti * t..][..t];
                // dprobs = do @ v^T (per head row)
                for tj in 0..=ti {
                    let vrow = &cache.v[(bb * t + tj) * d + hh * hd..][..hd];
                    let mut acc = 0.0f32;
                    for x in 0..hd {
                        acc += dorow[x] * vrow[x];
                    }
                    dprow[tj] = acc;
                }
                // softmax backward with the q/k scale folded in
                let mut sdot = 0.0f32;
                for tj in 0..=ti {
                    sdot += dprow[tj] * prow[tj];
                }
                let qrow = &cache.q[(bb * t + ti) * d + hh * hd..][..hd];
                for tj in 0..=ti {
                    let ds = prow[tj] * (dprow[tj] - sdot) * sc;
                    let krow = &cache.k[(bb * t + tj) * d + hh * hd..][..hd];
                    {
                        let dqrow = &mut dq[(bb * t + ti) * d + hh * hd..][..hd];
                        for x in 0..hd {
                            dqrow[x] += ds * krow[x];
                        }
                    }
                    {
                        let dkrow = &mut dk[(bb * t + tj) * d + hh * hd..][..hd];
                        for x in 0..hd {
                            dkrow[x] += ds * qrow[x];
                        }
                    }
                    {
                        let dvrow = &mut dv[(bb * t + tj) * d + hh * hd..][..hd];
                        let p = prow[tj];
                        for x in 0..hd {
                            dvrow[x] += p * dorow[x];
                        }
                    }
                }
            }
        }
    }
    let dwq = mm_tn(n1, &dq, n, d, d);
    let dwk = mm_tn(n1, &dk, n, d, d);
    let dwv = mm_tn(n1, &dv, n, d, d);
    let mut dn1 = mm_nt(&dq, wq, n, d, d);
    add_into(&mut dn1, &mm_nt(&dk, wk, n, d, d));
    add_into(&mut dn1, &mm_nt(&dv, wv, n, d, d));
    (dwq, dwk, dwv, dwo, dn1)
}

// ---- layer-wise compute API --------------------------------------------
//
// The model is drivable one FSDP bucket at a time: embed | layer 0..L-1 |
// final-norm+head, each with its own fwd/bwd entry point. The monolithic
// `train_step`/`eval_loss` below are thin compositions of these functions,
// so the bucket-pipelined executor (`fsdp::exec`) and the one-shot path
// execute the *same* float operations in the same order — trajectories
// are bit-identical by construction.

/// Backward cache of one decoder layer (opaque: produced by
/// [`layer_fwd`], consumed by [`layer_bwd`]).
pub struct LayerCache {
    x_in: Vec<f32>,
    n1: Vec<f32>,
    r1: Vec<f32>,
    attn: AttnCache,
    x_mid: Vec<f32>,
    n2: Vec<f32>,
    r2: Vec<f32>,
    h1: Vec<f32>,
    g: Vec<f32>,
}

/// One decoder layer's parameter slices, in layer ABI order.
pub struct LayerParams<'a> {
    pub ln1: &'a [f32],
    pub wq: &'a [f32],
    pub wk: &'a [f32],
    pub wv: &'a [f32],
    pub wo: &'a [f32],
    pub ln2: &'a [f32],
    pub w1: &'a [f32],
    pub w2: &'a [f32],
}

/// Layer `l`'s parameter slices out of the ABI-ordered parameter list.
pub fn layer_params(params: &[Vec<f32>], l: usize) -> LayerParams<'_> {
    let base = 1 + 8 * l;
    LayerParams {
        ln1: &params[base],
        wq: &params[base + 1],
        wk: &params[base + 2],
        wv: &params[base + 3],
        wo: &params[base + 4],
        ln2: &params[base + 5],
        w1: &params[base + 6],
        w2: &params[base + 7],
    }
}

/// Embedding lookup (bucket 0 of the layer-wise schedule).
pub fn embed_fwd(cfg: &ModelCfg, embed: &[f32], tokens: &[i32]) -> Vec<f32> {
    let d = cfg.d_model;
    let mut x = vec![0.0f32; tokens.len() * d];
    for (row, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        x[row * d..(row + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
    x
}

/// One decoder layer's forward on the running activation (in place);
/// returns the cache its backward needs.
pub fn layer_fwd(cfg: &ModelCfg, lp: &LayerParams, x: &mut Vec<f32>) -> LayerCache {
    let (b, t, d, h, f) = (cfg.batch, cfg.seq, cfg.d_model, cfg.n_heads, cfg.d_ff);
    let n = b * t;
    let x_in = x.clone();
    let (n1, r1) = rmsnorm_fwd(x, lp.ln1, n, d);
    let (y, attn) = attn_fwd(&n1, lp.wq, lp.wk, lp.wv, lp.wo, b, t, d, h);
    add_into(x, &y);
    let x_mid = x.clone();
    let (n2, r2) = rmsnorm_fwd(x, lp.ln2, n, d);
    let h1 = mm(&n2, lp.w1, n, d, f);
    let g: Vec<f32> = h1.iter().map(|&z| gelu(z)).collect();
    let y2 = mm(&g, lp.w2, n, f, d);
    add_into(x, &y2);
    LayerCache { x_in, n1, r1, attn, x_mid, n2, r2, h1, g }
}

/// Final norm + head projection; returns (nf, 1/rms, logits).
pub fn head_fwd(
    cfg: &ModelCfg,
    final_ln: &[f32],
    head: &[f32],
    x: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = cfg.batch * cfg.seq;
    let (nf, rf) = rmsnorm_fwd(x, final_ln, n, cfg.d_model);
    let logits = mm(&nf, head, n, cfg.d_model, cfg.vocab);
    (nf, rf, logits)
}

/// Mean next-token cross-entropy and dL/dlogits.
pub fn loss_grad(cfg: &ModelCfg, logits: &[f32], targets: &[i32]) -> (f32, Vec<f32>) {
    ce_loss(logits, targets, cfg.batch * cfg.seq, cfg.vocab, true)
}

/// Head-bucket backward: returns (d final_ln, d head, dL/dx).
pub fn head_bwd(
    cfg: &ModelCfg,
    dlogits: &[f32],
    x: &[f32],
    nf: &[f32],
    rf: &[f32],
    final_ln: &[f32],
    head: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (n, d, v) = (cfg.batch * cfg.seq, cfg.d_model, cfg.vocab);
    let d_head = mm_tn(nf, dlogits, n, d, v);
    let dnf = mm_nt(dlogits, head, n, v, d);
    let mut dx = vec![0.0f32; n * d];
    let mut d_ln = vec![0.0f32; d];
    rmsnorm_bwd(&dnf, x, final_ln, rf, n, d, &mut dx, &mut d_ln);
    (d_ln, d_head, dx)
}

/// One decoder layer's backward. `dx` holds dL/d(layer output) on entry
/// and dL/d(layer input) on return; the 8 parameter gradients come back
/// in layer ABI order (ln1, wq, wk, wv, wo, ln2, w1, w2).
pub fn layer_bwd(
    cfg: &ModelCfg,
    lp: &LayerParams,
    c: &LayerCache,
    dx: &mut Vec<f32>,
) -> [Vec<f32>; 8] {
    let (b, t, d, h, f) = (cfg.batch, cfg.seq, cfg.d_model, cfg.n_heads, cfg.d_ff);
    let n = b * t;
    // ---- MLP branch: x_out = x_mid + w2·gelu(w1·rms(x_mid)) ----
    let mut dh1 = mm_nt(dx, lp.w2, n, d, f);
    let d_w2 = mm_tn(&c.g, dx, n, f, d);
    for (z, &pre) in dh1.iter_mut().zip(&c.h1) {
        *z *= gelu_grad(pre);
    }
    let d_w1 = mm_tn(&c.n2, &dh1, n, d, f);
    let dn2 = mm_nt(&dh1, lp.w1, n, f, d);
    // residual: dx becomes dL/dx_mid (pass-through + norm branch)
    let mut d_ln2 = vec![0.0f32; d];
    rmsnorm_bwd(&dn2, &c.x_mid, lp.ln2, &c.r2, n, d, dx, &mut d_ln2);
    // ---- attention branch: x_mid = x_in + attn(rms(x_in)) ----
    let (d_wq, d_wk, d_wv, d_wo, dn1) =
        attn_bwd(dx, &c.n1, lp.wq, lp.wk, lp.wv, lp.wo, &c.attn, b, t, d, h);
    let mut d_ln1 = vec![0.0f32; d];
    rmsnorm_bwd(&dn1, &c.x_in, lp.ln1, &c.r1, n, d, dx, &mut d_ln1);
    [d_ln1, d_wq, d_wk, d_wv, d_wo, d_ln2, d_w1, d_w2]
}

/// Embedding backward: scatter-add of dL/dx rows into token rows.
pub fn embed_bwd(cfg: &ModelCfg, tokens: &[i32], dx: &[f32]) -> Vec<f32> {
    let d = cfg.d_model;
    let mut ge = vec![0.0f32; cfg.vocab * d];
    for (row, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        let gr = &mut ge[tok * d..(tok + 1) * d];
        for (g, &dxi) in gr.iter_mut().zip(&dx[row * d..(row + 1) * d]) {
            *g += dxi;
        }
    }
    ge
}

// ---- whole-model forward / backward ------------------------------------

pub(crate) fn validate(cfg: &ModelCfg, params: &[Vec<f32>], tokens: &[i32], targets: &[i32]) -> Result<()> {
    // embed + 8 per layer + final_ln + head
    let expect = 3 + 8 * cfg.n_layers;
    if cfg.params.len() != expect {
        bail!("config ABI has {} params, expected {expect}", cfg.params.len());
    }
    if params.len() != cfg.params.len() {
        bail!("param count {} != ABI {}", params.len(), cfg.params.len());
    }
    for (p, (name, shape)) in params.iter().zip(&cfg.params) {
        let numel: usize = shape.iter().product();
        if p.len() != numel {
            bail!("param '{name}': {} elements, shape {shape:?} wants {numel}", p.len());
        }
    }
    let n = cfg.batch * cfg.seq;
    if tokens.len() != n || targets.len() != n {
        bail!("tokens/targets must be batch*seq = {n} elements");
    }
    if cfg.n_heads == 0 || cfg.d_model % cfg.n_heads != 0 {
        bail!("n_heads {} must divide d_model {}", cfg.n_heads, cfg.d_model);
    }
    for &tok in tokens.iter().chain(targets) {
        if tok < 0 || tok as usize >= cfg.vocab {
            bail!("token {tok} out of vocab {}", cfg.vocab);
        }
    }
    Ok(())
}

/// Forward pass with per-layer caches; returns (final x, caches, nf, rf,
/// logits). Composed from the layer-wise API above so the monolithic and
/// bucket-pipelined paths run identical float operations.
#[allow(clippy::type_complexity)]
fn forward(
    cfg: &ModelCfg,
    params: &[Vec<f32>],
    tokens: &[i32],
    keep_caches: bool,
) -> (Vec<f32>, Vec<LayerCache>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let nl = cfg.n_layers;
    let mut x = embed_fwd(cfg, &params[0], tokens);
    let mut caches = Vec::with_capacity(if keep_caches { nl } else { 0 });
    for l in 0..nl {
        let lp = layer_params(params, l);
        let c = layer_fwd(cfg, &lp, &mut x);
        if keep_caches {
            caches.push(c);
        }
    }
    let (nf, rf, logits) = head_fwd(cfg, &params[1 + 8 * nl], &params[2 + 8 * nl], &x);
    (x, caches, nf, rf, logits)
}

/// Mean next-token cross-entropy and (optionally) dL/dlogits.
fn ce_loss(logits: &[f32], targets: &[i32], n: usize, v: usize, want_grad: bool) -> (f32, Vec<f32>) {
    let inv_n = 1.0 / n as f32;
    let mut loss = 0.0f32;
    let mut dlogits = vec![0.0f32; if want_grad { n * v } else { 0 }];
    for row in 0..n {
        let lrow = &logits[row * v..(row + 1) * v];
        let tgt = targets[row] as usize;
        let mx = lrow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &z in lrow {
            sum += (z - mx).exp();
        }
        let lse = mx + sum.ln();
        loss += (lse - lrow[tgt]) * inv_n;
        if want_grad {
            let drow = &mut dlogits[row * v..(row + 1) * v];
            let inv_sum = 1.0 / sum;
            for j in 0..v {
                drow[j] = (lrow[j] - mx).exp() * inv_sum * inv_n;
            }
            drow[tgt] -= inv_n;
        }
    }
    (loss, dlogits)
}

/// The per-device step: (loss, grads in ABI order). Gradients are
/// unscaled, as with the PJRT artifact — the coordinator averages them
/// across devices via ReduceScatter.
pub fn train_step(
    cfg: &ModelCfg,
    params: &[Vec<f32>],
    tokens: &[i32],
    targets: &[i32],
) -> Result<(f32, Vec<Vec<f32>>)> {
    validate(cfg, params, tokens, targets)?;
    let nl = cfg.n_layers;
    let (x, caches, nf, rf, logits) = forward(cfg, params, tokens, true);
    let (loss, dlogits) = loss_grad(cfg, &logits, targets);

    let (d_final_ln, d_head, mut dx) = head_bwd(
        cfg, &dlogits, &x, &nf, &rf, &params[1 + 8 * nl], &params[2 + 8 * nl],
    );
    let mut layer_grads: Vec<[Vec<f32>; 8]> = Vec::with_capacity(nl);
    for l in (0..nl).rev() {
        let lp = layer_params(params, l);
        layer_grads.push(layer_bwd(cfg, &lp, &caches[l], &mut dx));
    }
    let d_embed = embed_bwd(cfg, tokens, &dx);

    // assemble in ABI order: embed | layers 0..nl | final_ln | head
    let mut grads: Vec<Vec<f32>> = Vec::with_capacity(params.len());
    grads.push(d_embed);
    layer_grads.reverse();
    for lg in layer_grads {
        grads.extend(lg);
    }
    grads.push(d_final_ln);
    grads.push(d_head);
    Ok((loss, grads))
}

/// Forward-only evaluation loss.
pub fn eval_loss(
    cfg: &ModelCfg,
    params: &[Vec<f32>],
    tokens: &[i32],
    targets: &[i32],
) -> Result<f32> {
    validate(cfg, params, tokens, targets)?;
    let n = cfg.batch * cfg.seq;
    let (_, _, _, _, logits) = forward(cfg, params, tokens, false);
    Ok(ce_loss(&logits, targets, n, cfg.vocab, false).0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Tiny config so finite differences stay cheap.
    fn micro_cfg() -> ModelCfg {
        ModelCfg::with_abi(16, 8, 1, 2, 16, 4, 1)
    }

    fn micro_params(cfg: &ModelCfg, seed: u64) -> Vec<Vec<f32>> {
        crate::train::init_full_params(&cfg.params, seed)
    }

    fn micro_batch(cfg: &ModelCfg, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let n = cfg.batch * cfg.seq;
        let toks = (0..n).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
        let tgts = (0..n).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
        (toks, tgts)
    }

    #[test]
    fn matmul_kernels_agree() {
        let mut rng = Rng::new(0);
        let (m, k, n) = (3, 5, 4);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let c = mm(&a, &b, m, k, n);
        // a^T laid out as (k, m), b^T as (n, k)
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for kk in 0..k {
                at[kk * m + i] = a[i * k + kk];
            }
        }
        let mut bt = vec![0.0f32; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        let c_tn = mm_tn(&at, &b, k, m, n);
        let c_nt = mm_nt(&a, &bt, m, k, n);
        for i in 0..m * n {
            assert!((c[i] - c_tn[i]).abs() < 1e-5);
            assert!((c[i] - c_nt[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn fresh_model_loss_near_ln_vocab() {
        let cfg = micro_cfg();
        let params = micro_params(&cfg, 0);
        let (tokens, targets) = micro_batch(&cfg, 1);
        let (loss, grads) = train_step(&cfg, &params, &tokens, &targets).unwrap();
        let lnv = (cfg.vocab as f32).ln();
        assert!((loss - lnv).abs() < 1.0, "loss {loss} vs ln(V) {lnv}");
        assert_eq!(grads.len(), params.len());
        let norm: f32 = grads.iter().flat_map(|g| g.iter()).map(|x| x * x).sum();
        assert!(norm > 0.0 && norm.is_finite());
    }

    #[test]
    fn eval_matches_train_loss() {
        let cfg = micro_cfg();
        let params = micro_params(&cfg, 2);
        let (tokens, targets) = micro_batch(&cfg, 3);
        let (lt, _) = train_step(&cfg, &params, &tokens, &targets).unwrap();
        let le = eval_loss(&cfg, &params, &tokens, &targets).unwrap();
        assert!((lt - le).abs() < 1e-6, "{lt} vs {le}");
    }

    #[test]
    fn gradients_match_finite_differences() {
        let cfg = micro_cfg();
        let params = micro_params(&cfg, 4);
        let (tokens, targets) = micro_batch(&cfg, 5);
        let (_, grads) = train_step(&cfg, &params, &tokens, &targets).unwrap();
        // probe a few coordinates in every distinct tensor role
        let probes: Vec<(usize, usize)> = vec![
            (0, 3),  // embed (a token actually present would be better; 3 is)
            (1, 2),  // ln1.scale
            (2, 11), // wq
            (4, 5),  // wv
            (5, 17), // wo
            (7, 31), // w1
            (8, 40), // w2
            (9, 1),  // final_ln.scale
            (10, 25), // head
        ];
        let eps = 3e-3f32;
        for (pi, ei) in probes {
            let ei = ei % params[pi].len();
            let mut plus = params.clone();
            plus[pi][ei] += eps;
            let mut minus = params.clone();
            minus[pi][ei] -= eps;
            let lp = eval_loss(&cfg, &plus, &tokens, &targets).unwrap();
            let lm = eval_loss(&cfg, &minus, &tokens, &targets).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            let ana = grads[pi][ei];
            assert!(
                (ana - fd).abs() < 3e-3 + 0.08 * fd.abs().max(ana.abs()),
                "param {pi}[{ei}]: analytic {ana} vs fd {fd}"
            );
        }
    }

    #[test]
    fn embed_grad_zero_for_unused_tokens() {
        let cfg = micro_cfg();
        let params = micro_params(&cfg, 6);
        let n = cfg.batch * cfg.seq;
        let tokens = vec![1i32; n]; // only token 1 appears as input
        let targets = vec![2i32; n];
        let (_, grads) = train_step(&cfg, &params, &tokens, &targets).unwrap();
        let d = cfg.d_model;
        // token 5 never embedded -> zero embedding gradient
        assert!(grads[0][5 * d..6 * d].iter().all(|&g| g == 0.0));
        // token 1 used -> nonzero gradient
        assert!(grads[0][d..2 * d].iter().any(|&g| g != 0.0));
    }

    #[test]
    fn training_reduces_loss_with_sgd() {
        // a few plain gradient steps on a fixed batch must overfit it
        let cfg = micro_cfg();
        let mut params = micro_params(&cfg, 7);
        let (tokens, targets) = micro_batch(&cfg, 8);
        let (first, _) = train_step(&cfg, &params, &tokens, &targets).unwrap();
        let mut last = first;
        for _ in 0..30 {
            let (loss, grads) = train_step(&cfg, &params, &tokens, &targets).unwrap();
            last = loss;
            for (p, g) in params.iter_mut().zip(&grads) {
                for (pv, &gv) in p.iter_mut().zip(g) {
                    *pv -= 0.5 * gv;
                }
            }
        }
        assert!(last < first - 0.5, "loss did not drop: {first} -> {last}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let cfg = micro_cfg();
        let params = micro_params(&cfg, 9);
        let (tokens, targets) = micro_batch(&cfg, 10);
        assert!(train_step(&cfg, &params[1..], &tokens, &targets).is_err());
        assert!(train_step(&cfg, &params, &tokens[1..], &targets).is_err());
        let bad = vec![cfg.vocab as i32; tokens.len()];
        assert!(train_step(&cfg, &params, &bad, &targets).is_err());
    }
}
