//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them from the Rust hot path. Python never runs here.
//!
//! Interchange is HLO *text* — jax >= 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see `python/compile/aot.py` and DESIGN.md).
//!
//! The runtime compiles each artifact once (`Engine::exec` caches the
//! loaded executable) and exposes typed wrappers for the model train
//! step, the fused optimizer chunks, and Newton-Schulz.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub chunk: usize,
    pub qblock: usize,
    pub hyper_len: usize,
    pub configs: BTreeMap<String, ModelCfg>,
    pub artifacts: Vec<ArtifactSig>,
}

#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    /// Parameter ABI: (name, shape) in canonical order.
    pub params: Vec<(String, Vec<usize>)>,
}

impl ModelCfg {
    pub fn total_params(&self) -> u64 {
        self.params
            .iter()
            .map(|(_, s)| s.iter().map(|&d| d as u64).product::<u64>())
            .sum()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    pub file: String,
    pub n_inputs: usize,
    pub n_outputs: usize,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let usize_of = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let mut configs = BTreeMap::new();
        if let Some(cfgs) = j.get("configs").and_then(|c| c.as_obj()) {
            for (name, c) in cfgs {
                let f = |k: &str| c.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
                let params = c
                    .get("params")
                    .and_then(|p| p.as_arr())
                    .ok_or_else(|| anyhow!("config {name} missing params"))?
                    .iter()
                    .map(|p| {
                        let pname = p.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
                        let shape = p
                            .get("shape")
                            .and_then(|s| s.as_arr())
                            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                            .unwrap_or_default();
                        (pname, shape)
                    })
                    .collect();
                configs.insert(
                    name.clone(),
                    ModelCfg {
                        vocab: f("vocab"),
                        d_model: f("d_model"),
                        n_layers: f("n_layers"),
                        n_heads: f("n_heads"),
                        d_ff: f("d_ff"),
                        seq: f("seq"),
                        batch: f("batch"),
                        params,
                    },
                );
            }
        }
        let artifacts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|a| ArtifactSig {
                name: a.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string(),
                file: a.get("file").and_then(|n| n.as_str()).unwrap_or("").to_string(),
                n_inputs: a.get("inputs").and_then(|i| i.as_arr()).map(|v| v.len()).unwrap_or(0),
                n_outputs: a.get("outputs").and_then(|o| o.as_arr()).map(|v| v.len()).unwrap_or(0),
            })
            .collect();
        Ok(Manifest {
            chunk: usize_of("chunk")?,
            qblock: usize_of("qblock")?,
            hyper_len: usize_of("hyper_len")?,
            configs,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSig> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// Typed input for `Engine::exec`.
pub enum In<'a> {
    F32(&'a [f32], Vec<i64>),
    I32(&'a [i32], Vec<i64>),
}

impl<'a> In<'a> {
    fn literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            In::F32(v, shape) => xla::Literal::vec1(v).reshape(shape)?,
            In::I32(v, shape) => xla::Literal::vec1(v).reshape(shape)?,
        })
    }
}

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions per artifact (perf accounting).
    pub exec_counts: HashMap<String, u64>,
}

impl Engine {
    /// Load the artifact directory (default `artifacts/` at the repo root).
    pub fn load(dir: &Path) -> Result<Engine> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} — run `make artifacts`"))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            dir: dir.to_path_buf(),
            cache: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    /// Default artifact directory relative to the crate root.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    pub fn load_default() -> Result<Engine> {
        Engine::load(&Engine::default_dir())
    }

    fn compile(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let sig = self
                .manifest
                .artifact(name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
                .clone();
            let path = self.dir.join(&sig.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact; outputs are the flattened f32 tuple members.
    pub fn exec(&mut self, name: &str, inputs: &[In]) -> Result<Vec<Vec<f32>>> {
        let sig = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if inputs.len() != sig.n_inputs {
            bail!("{name}: {} inputs given, {} expected", inputs.len(), sig.n_inputs);
        }
        let n_outputs = sig.n_outputs;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|i| i.literal()).collect::<Result<_>>()?;
        let exe = self.compile(name)?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync {name}: {e:?}"))?;
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        let items = result.to_tuple().map_err(|e| anyhow!("tuple {name}: {e:?}"))?;
        if items.len() != n_outputs {
            bail!("{name}: {} outputs, expected {}", items.len(), n_outputs);
        }
        items
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Run the model train step: returns (loss, grads in ABI order).
    pub fn train_step(
        &mut self,
        config: &str,
        params: &[Vec<f32>],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let cfg = self
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow!("unknown config '{config}'"))?
            .clone();
        if params.len() != cfg.params.len() {
            bail!("param count {} != ABI {}", params.len(), cfg.params.len());
        }
        let mut inputs: Vec<In> = Vec::with_capacity(params.len() + 2);
        for (p, (_, shape)) in params.iter().zip(&cfg.params) {
            inputs.push(In::F32(p, shape.iter().map(|&s| s as i64).collect()));
        }
        let tok_shape = vec![cfg.batch as i64, cfg.seq as i64];
        inputs.push(In::I32(tokens, tok_shape.clone()));
        inputs.push(In::I32(targets, tok_shape));
        let mut out = self.exec(&format!("train_step_{config}"), &inputs)?;
        let grads = out.split_off(1);
        Ok((out[0][0], grads))
    }

    /// Evaluation loss only.
    pub fn eval_loss(
        &mut self,
        config: &str,
        params: &[Vec<f32>],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f32> {
        let cfg = self
            .manifest
            .configs
            .get(config)
            .ok_or_else(|| anyhow!("unknown config '{config}'"))?
            .clone();
        let mut inputs: Vec<In> = Vec::with_capacity(params.len() + 2);
        for (p, (_, shape)) in params.iter().zip(&cfg.params) {
            inputs.push(In::F32(p, shape.iter().map(|&s| s as i64).collect()));
        }
        let tok_shape = vec![cfg.batch as i64, cfg.seq as i64];
        inputs.push(In::I32(tokens, tok_shape.clone()));
        inputs.push(In::I32(targets, tok_shape));
        let out = self.exec(&format!("eval_loss_{config}"), &inputs)?;
        Ok(out[0][0])
    }

    /// Fused AdamW over one padded chunk. `h = [t, lr, b1, b2, eps, wd]`.
    /// Slices shorter than the chunk are zero-padded (zero grad = pure
    /// decay on padding, which is discarded).
    pub fn adamw_chunk(
        &mut self,
        h: &[f32; 6],
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
    ) -> Result<()> {
        let chunk = self.manifest.chunk;
        let n = p.len();
        let mut pp = pad(p, chunk);
        let gp = pad(g, chunk);
        let mut mp = pad(m, chunk);
        let mut vp = pad(v, chunk);
        for c in 0..pp.len() / chunk {
            let r = c * chunk..(c + 1) * chunk;
            let out = self.exec(
                "adamw_chunk",
                &[
                    In::F32(h, vec![6]),
                    In::F32(&pp[r.clone()], vec![chunk as i64]),
                    In::F32(&gp[r.clone()], vec![chunk as i64]),
                    In::F32(&mp[r.clone()], vec![chunk as i64]),
                    In::F32(&vp[r.clone()], vec![chunk as i64]),
                ],
            )?;
            pp[r.clone()].copy_from_slice(&out[0]);
            mp[r.clone()].copy_from_slice(&out[1]);
            vp[r].copy_from_slice(&out[2]);
        }
        p.copy_from_slice(&pp[..n]);
        m.copy_from_slice(&mp[..n]);
        v.copy_from_slice(&vp[..n]);
        Ok(())
    }

    /// Newton-Schulz on a (r x c) matrix via the per-shape artifact.
    pub fn newton_schulz(&mut self, r: usize, c: usize, g: &[f32]) -> Result<Vec<f32>> {
        let name = format!("newton_schulz_{r}x{c}");
        let out = self.exec(&name, &[In::F32(g, vec![r as i64, c as i64])])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Block-wise quantization via the L1 kernel artifact (codes as f32
    /// carriers; storage stays int8 on the Rust side).
    pub fn quant_chunk(&mut self, x: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let chunk = self.manifest.chunk;
        if x.len() != chunk {
            bail!("quant_chunk wants exactly {chunk} elements");
        }
        let mut out = self.exec("quant_chunk", &[In::F32(x, vec![chunk as i64])])?;
        let scales = out.pop().unwrap();
        let codes = out.pop().unwrap();
        Ok((codes, scales))
    }
}

fn pad(x: &[f32], chunk: usize) -> Vec<f32> {
    let n = x.len().div_ceil(chunk).max(1) * chunk;
    let mut out = x.to_vec();
    out.resize(n, 0.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "chunk": 65536, "qblock": 1024, "hyper_len": 6,
      "configs": {"tiny": {"vocab": 512, "d_model": 128, "n_layers": 2,
        "n_heads": 4, "d_ff": 512, "seq": 64, "batch": 4,
        "params": [{"name": "embed.weight", "shape": [512, 128]}]}},
      "artifacts": [{"name": "adamw_chunk", "file": "adamw_chunk.hlo.txt",
        "inputs": [{"shape": [6], "dtype": "float32"}],
        "outputs": [{"shape": [65536], "dtype": "float32"}]}]
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.chunk, 65536);
        assert_eq!(m.configs["tiny"].vocab, 512);
        assert_eq!(m.configs["tiny"].params[0].0, "embed.weight");
        assert_eq!(m.artifact("adamw_chunk").unwrap().n_inputs, 1);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn pad_helper() {
        assert_eq!(pad(&[1.0; 10], 8).len(), 16);
        assert_eq!(pad(&[1.0; 8], 8).len(), 8);
        assert_eq!(pad(&[], 8).len(), 8);
    }

    // PJRT-backed tests live in rust/tests/runtime_artifacts.rs (they need
    // `make artifacts` to have run).
}
