//! Compute runtime: executes the L2 train step per simulated device.
//!
//! Two interchangeable backends behind one [`Engine`]:
//!
//! * **PJRT** (`--features pjrt` + `make artifacts`) — loads the AOT
//!   artifacts (`artifacts/*.hlo.txt`) and executes them through
//!   `xla_extension`; Python never runs on the request path. Interchange
//!   is HLO *text* — jax >= 0.5 serialized protos carry 64-bit
//!   instruction ids that xla_extension 0.5.1 rejects; the text parser
//!   reassigns ids (see `python/compile/aot.py`). Executables compile
//!   once and are cached.
//! * **Native** (default) — the pure-Rust reference implementation of the
//!   same compute graph ([`native`]), used when the `xla` bindings are
//!   unavailable (they are not in the offline crate universe) or the
//!   artifacts have not been built. Because every rank's step is a pure
//!   function, the native path is what the threaded SPMD cluster runtime
//!   parallelizes across rank threads.
//!
//! The manifest (model configs + parameter ABI) comes from
//! `artifacts/manifest.json` when present, otherwise from the built-in
//! mirror of `python/compile/model.py::CONFIGS`.

pub mod native;

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Parsed `artifacts/manifest.json` (or the built-in native manifest).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub chunk: usize,
    pub qblock: usize,
    pub hyper_len: usize,
    pub configs: BTreeMap<String, ModelCfg>,
    pub artifacts: Vec<ArtifactSig>,
}

#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    /// Parameter ABI: (name, shape) in canonical order.
    pub params: Vec<(String, Vec<usize>)>,
}

impl ModelCfg {
    pub fn total_params(&self) -> u64 {
        self.params
            .iter()
            .map(|(_, s)| s.iter().map(|&d| d as u64).product::<u64>())
            .sum()
    }

    /// Mirror of `python/compile/model.py::param_specs` — the canonical
    /// (name, shape) ABI both layers agree on.
    pub fn with_abi(
        vocab: usize,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        d_ff: usize,
        seq: usize,
        batch: usize,
    ) -> ModelCfg {
        let mut params = vec![("embed.weight".to_string(), vec![vocab, d_model])];
        for i in 0..n_layers {
            let p = format!("layers.{i}");
            params.push((format!("{p}.ln1.scale"), vec![d_model]));
            params.push((format!("{p}.attn.wq"), vec![d_model, d_model]));
            params.push((format!("{p}.attn.wk"), vec![d_model, d_model]));
            params.push((format!("{p}.attn.wv"), vec![d_model, d_model]));
            params.push((format!("{p}.attn.wo"), vec![d_model, d_model]));
            params.push((format!("{p}.ln2.scale"), vec![d_model]));
            params.push((format!("{p}.mlp.w1"), vec![d_model, d_ff]));
            params.push((format!("{p}.mlp.w2"), vec![d_ff, d_model]));
        }
        params.push(("final_ln.scale".to_string(), vec![d_model]));
        params.push(("head.weight".to_string(), vec![d_model, vocab]));
        ModelCfg { vocab, d_model, n_layers, n_heads, d_ff, seq, batch, params }
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    pub file: String,
    pub n_inputs: usize,
    pub n_outputs: usize,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let usize_of = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let mut configs = BTreeMap::new();
        if let Some(cfgs) = j.get("configs").and_then(|c| c.as_obj()) {
            for (name, c) in cfgs {
                let f = |k: &str| c.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
                let params = c
                    .get("params")
                    .and_then(|p| p.as_arr())
                    .ok_or_else(|| anyhow!("config {name} missing params"))?
                    .iter()
                    .map(|p| {
                        let pname = p.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
                        let shape = p
                            .get("shape")
                            .and_then(|s| s.as_arr())
                            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                            .unwrap_or_default();
                        (pname, shape)
                    })
                    .collect();
                configs.insert(
                    name.clone(),
                    ModelCfg {
                        vocab: f("vocab"),
                        d_model: f("d_model"),
                        n_layers: f("n_layers"),
                        n_heads: f("n_heads"),
                        d_ff: f("d_ff"),
                        seq: f("seq"),
                        batch: f("batch"),
                        params,
                    },
                );
            }
        }
        let artifacts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|a| ArtifactSig {
                name: a.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string(),
                file: a.get("file").and_then(|n| n.as_str()).unwrap_or("").to_string(),
                n_inputs: a.get("inputs").and_then(|i| i.as_arr()).map(|v| v.len()).unwrap_or(0),
                n_outputs: a.get("outputs").and_then(|o| o.as_arr()).map(|v| v.len()).unwrap_or(0),
            })
            .collect();
        Ok(Manifest {
            chunk: usize_of("chunk")?,
            qblock: usize_of("qblock")?,
            hyper_len: usize_of("hyper_len")?,
            configs,
            artifacts,
        })
    }

    /// Built-in manifest for the native backend: same model configs as
    /// `python/compile/model.py::CONFIGS`, no artifacts.
    pub fn builtin() -> Manifest {
        let mut configs = BTreeMap::new();
        configs.insert("tiny".to_string(), ModelCfg::with_abi(512, 128, 2, 4, 512, 64, 4));
        configs.insert("small".to_string(), ModelCfg::with_abi(2048, 256, 4, 4, 1024, 128, 4));
        configs.insert(
            "mid100m".to_string(),
            ModelCfg::with_abi(32768, 768, 12, 12, 3072, 256, 2),
        );
        Manifest {
            chunk: 65536,
            qblock: 1024,
            hyper_len: 6,
            configs,
            artifacts: Vec::new(),
        }
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSig> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// Typed input for `Engine::exec`.
pub enum In<'a> {
    F32(&'a [f32], Vec<i64>),
    I32(&'a [i32], Vec<i64>),
}

// The `pjrt` feature requires the `xla` bindings, which are NOT declared
// in Cargo.toml (absent from the offline crate universe). Unresolved
// `xla` imports below mean: vendor the xla crate and add it under
// [dependencies] before building with --features pjrt.
#[cfg(feature = "pjrt")]
impl<'a> In<'a> {
    fn literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            In::F32(v, shape) => xla::Literal::vec1(v).reshape(shape)?,
            In::I32(v, shape) => xla::Literal::vec1(v).reshape(shape)?,
        })
    }
}

#[cfg(feature = "pjrt")]
struct PjrtState {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

enum Inner {
    /// Pure-Rust reference compute (src/runtime/native.rs).
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtState),
}

pub struct Engine {
    pub manifest: Manifest,
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    dir: PathBuf,
    inner: Inner,
    /// Executions per artifact / native kernel (perf accounting).
    pub exec_counts: HashMap<String, u64>,
}

impl Engine {
    /// Whether this build can execute PJRT artifacts at all.
    pub fn pjrt_enabled() -> bool {
        cfg!(feature = "pjrt")
    }

    /// Load the artifact directory (default `artifacts/` at the crate
    /// root). Falls back to the native backend — with the on-disk
    /// manifest if present, the built-in one otherwise — whenever PJRT is
    /// unavailable.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest_path = dir.join("manifest.json");
        #[cfg(feature = "pjrt")]
        {
            if manifest_path.exists() {
                let text = std::fs::read_to_string(&manifest_path)
                    .map_err(|e| anyhow!("reading manifest in {dir:?}: {e}"))?;
                let manifest = Manifest::parse(&text)?;
                let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;
                return Ok(Engine {
                    manifest,
                    dir: dir.to_path_buf(),
                    inner: Inner::Pjrt(PjrtState { client, cache: HashMap::new() }),
                    exec_counts: HashMap::new(),
                });
            }
        }
        let manifest = if manifest_path.exists() {
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading manifest in {dir:?}"))?;
            Manifest::parse(&text)?
        } else {
            Manifest::builtin()
        };
        Ok(Engine {
            manifest,
            dir: dir.to_path_buf(),
            inner: Inner::Native,
            exec_counts: HashMap::new(),
        })
    }

    /// Default artifact directory relative to the crate root.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    pub fn load_default() -> Result<Engine> {
        Engine::load(&Engine::default_dir())
    }

    /// True when compute runs through the native Rust implementation
    /// (the path the threaded cluster backend parallelizes).
    pub fn is_native(&self) -> bool {
        matches!(self.inner, Inner::Native)
    }

    pub fn backend_name(&self) -> &'static str {
        match self.inner {
            Inner::Native => "native",
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(_) => "pjrt",
        }
    }

    fn count(&mut self, name: &str) {
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
    }

    #[cfg(feature = "pjrt")]
    fn exec_pjrt(&mut self, name: &str, inputs: &[In]) -> Result<Vec<Vec<f32>>> {
        let sig = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let (n_inputs, n_outputs, file) = (sig.n_inputs, sig.n_outputs, sig.file.clone());
        if inputs.len() != n_inputs {
            bail!("{name}: {} inputs given, {n_inputs} expected", inputs.len());
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|i| i.literal()).collect::<Result<_>>()?;
        let dir = self.dir.clone();
        let Inner::Pjrt(st) = &mut self.inner else {
            bail!("exec requires the PJRT backend");
        };
        if !st.cache.contains_key(name) {
            let path = dir.join(&file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = st
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            st.cache.insert(name.to_string(), exe);
        }
        let exe = &st.cache[name];
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync {name}: {e:?}"))?;
        self.count(name);
        let items = result.to_tuple().map_err(|e| anyhow!("tuple {name}: {e:?}"))?;
        if items.len() != n_outputs {
            bail!("{name}: {} outputs, expected {n_outputs}", items.len());
        }
        items
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Execute a raw artifact; outputs are the flattened f32 tuple
    /// members. PJRT-only — the native backend has no generic HLO
    /// interpreter, only the typed wrappers below.
    pub fn exec(&mut self, name: &str, inputs: &[In]) -> Result<Vec<Vec<f32>>> {
        match self.inner {
            Inner::Native => {
                let _ = (name, inputs);
                bail!(
                    "exec('{name}') requires the PJRT backend \
                     (build with --features pjrt and run `make artifacts`)"
                )
            }
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(_) => self.exec_pjrt(name, inputs),
        }
    }

    fn config(&self, config: &str) -> Result<ModelCfg> {
        self.manifest
            .configs
            .get(config)
            .cloned()
            .ok_or_else(|| anyhow!("unknown config '{config}'"))
    }

    /// Run the model train step: returns (loss, grads in ABI order).
    pub fn train_step(
        &mut self,
        config: &str,
        params: &[Vec<f32>],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        match self.inner {
            Inner::Native => {
                let cfg = self.config(config)?;
                let out = native::train_step(&cfg, params, tokens, targets)?;
                self.count(&format!("train_step_{config}"));
                Ok(out)
            }
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(_) => {
                let cfg = self.config(config)?;
                if params.len() != cfg.params.len() {
                    bail!("param count {} != ABI {}", params.len(), cfg.params.len());
                }
                let mut inputs: Vec<In> = Vec::with_capacity(params.len() + 2);
                for (p, (_, shape)) in params.iter().zip(&cfg.params) {
                    inputs.push(In::F32(p, shape.iter().map(|&s| s as i64).collect()));
                }
                let tok_shape = vec![cfg.batch as i64, cfg.seq as i64];
                inputs.push(In::I32(tokens, tok_shape.clone()));
                inputs.push(In::I32(targets, tok_shape));
                let mut out = self.exec(&format!("train_step_{config}"), &inputs)?;
                let grads = out.split_off(1);
                Ok((out[0][0], grads))
            }
        }
    }

    /// Shared-reference train step for concurrent per-rank execution
    /// under `Cluster::run_spmd`. Native-only: the PJRT executable cache
    /// needs `&mut self`, so threaded compute requires the native backend
    /// (threaded *collectives* work with either).
    pub fn train_step_shared(
        &self,
        config: &str,
        params: &[Vec<f32>],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        match self.inner {
            Inner::Native => {
                let cfg = self.config(config)?;
                native::train_step(&cfg, params, tokens, targets)
            }
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(_) => bail!(
                "train_step_shared requires the native backend; \
                 PJRT compute runs serially via train_step"
            ),
        }
    }

    /// Evaluation loss only.
    pub fn eval_loss(
        &mut self,
        config: &str,
        params: &[Vec<f32>],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f32> {
        match self.inner {
            Inner::Native => {
                let cfg = self.config(config)?;
                let out = native::eval_loss(&cfg, params, tokens, targets)?;
                self.count(&format!("eval_loss_{config}"));
                Ok(out)
            }
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(_) => {
                let cfg = self.config(config)?;
                let mut inputs: Vec<In> = Vec::with_capacity(params.len() + 2);
                for (p, (_, shape)) in params.iter().zip(&cfg.params) {
                    inputs.push(In::F32(p, shape.iter().map(|&s| s as i64).collect()));
                }
                let tok_shape = vec![cfg.batch as i64, cfg.seq as i64];
                inputs.push(In::I32(tokens, tok_shape.clone()));
                inputs.push(In::I32(targets, tok_shape));
                let out = self.exec(&format!("eval_loss_{config}"), &inputs)?;
                Ok(out[0][0])
            }
        }
    }

    /// Fused AdamW over one padded chunk. `h = [t, lr, b1, b2, eps, wd]`.
    /// Slices shorter than the chunk are zero-padded (zero grad = pure
    /// decay on padding, which is discarded).
    pub fn adamw_chunk(
        &mut self,
        h: &[f32; 6],
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
    ) -> Result<()> {
        match self.inner {
            Inner::Native => {
                // padding is a no-op for the host implementation
                let hyper = crate::optim::AdamHyper {
                    lr: h[1],
                    beta1: h[2],
                    beta2: h[3],
                    eps: h[4],
                    wd: h[5],
                };
                crate::optim::AdamW::apply(&hyper, h[0] as u64, p, g, m, v);
                self.count("adamw_chunk");
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(_) => {
                let chunk = self.manifest.chunk;
                let n = p.len();
                let mut pp = pad(p, chunk);
                let gp = pad(g, chunk);
                let mut mp = pad(m, chunk);
                let mut vp = pad(v, chunk);
                for c in 0..pp.len() / chunk {
                    let r = c * chunk..(c + 1) * chunk;
                    let out = self.exec(
                        "adamw_chunk",
                        &[
                            In::F32(h, vec![6]),
                            In::F32(&pp[r.clone()], vec![chunk as i64]),
                            In::F32(&gp[r.clone()], vec![chunk as i64]),
                            In::F32(&mp[r.clone()], vec![chunk as i64]),
                            In::F32(&vp[r.clone()], vec![chunk as i64]),
                        ],
                    )?;
                    pp[r.clone()].copy_from_slice(&out[0]);
                    mp[r.clone()].copy_from_slice(&out[1]);
                    vp[r].copy_from_slice(&out[2]);
                }
                p.copy_from_slice(&pp[..n]);
                m.copy_from_slice(&mp[..n]);
                v.copy_from_slice(&vp[..n]);
                Ok(())
            }
        }
    }

    /// Newton-Schulz on a (r x c) matrix. Native: host implementation;
    /// PJRT: the per-shape artifact.
    pub fn newton_schulz(&mut self, r: usize, c: usize, g: &[f32]) -> Result<Vec<f32>> {
        match self.inner {
            Inner::Native => {
                let t = crate::tensor::HostTensor::from_f32(&[r, c], g.to_vec());
                let o = crate::optim::muon::newton_schulz(&t, crate::optim::muon::NS_STEPS)?;
                self.count(&format!("newton_schulz_{r}x{c}"));
                Ok(o.as_f32().to_vec())
            }
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(_) => {
                let name = format!("newton_schulz_{r}x{c}");
                let out = self.exec(&name, &[In::F32(g, vec![r as i64, c as i64])])?;
                Ok(out.into_iter().next().unwrap())
            }
        }
    }

    /// Block-wise quantization (codes as f32 carriers; storage stays int8
    /// on the Rust side).
    pub fn quant_chunk(&mut self, x: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let chunk = self.manifest.chunk;
        if x.len() != chunk {
            bail!("quant_chunk wants exactly {chunk} elements");
        }
        match self.inner {
            Inner::Native => {
                let block = self.manifest.qblock;
                let mut codes = vec![0.0f32; chunk];
                let mut scales = Vec::with_capacity(chunk / block);
                let mut q = vec![0i8; block];
                for b in 0..chunk / block {
                    let s = crate::optim::adam8bit::quant_block(&x[b * block..(b + 1) * block], &mut q);
                    scales.push(s);
                    for (i, &code) in q.iter().enumerate() {
                        codes[b * block + i] = code as f32;
                    }
                }
                self.count("quant_chunk");
                Ok((codes, scales))
            }
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(_) => {
                let mut out = self.exec("quant_chunk", &[In::F32(x, vec![chunk as i64])])?;
                let scales = out.pop().unwrap();
                let codes = out.pop().unwrap();
                Ok((codes, scales))
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn pad(x: &[f32], chunk: usize) -> Vec<f32> {
    let n = x.len().div_ceil(chunk).max(1) * chunk;
    let mut out = x.to_vec();
    out.resize(n, 0.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "chunk": 65536, "qblock": 1024, "hyper_len": 6,
      "configs": {"tiny": {"vocab": 512, "d_model": 128, "n_layers": 2,
        "n_heads": 4, "d_ff": 512, "seq": 64, "batch": 4,
        "params": [{"name": "embed.weight", "shape": [512, 128]}]}},
      "artifacts": [{"name": "adamw_chunk", "file": "adamw_chunk.hlo.txt",
        "inputs": [{"shape": [6], "dtype": "float32"}],
        "outputs": [{"shape": [65536], "dtype": "float32"}]}]
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.chunk, 65536);
        assert_eq!(m.configs["tiny"].vocab, 512);
        assert_eq!(m.configs["tiny"].params[0].0, "embed.weight");
        assert_eq!(m.artifact("adamw_chunk").unwrap().n_inputs, 1);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn builtin_manifest_mirrors_python_configs() {
        let m = Manifest::builtin();
        for name in ["tiny", "small", "mid100m"] {
            assert!(m.configs.contains_key(name), "missing {name}");
        }
        let tiny = &m.configs["tiny"];
        assert_eq!((tiny.vocab, tiny.d_model, tiny.n_layers), (512, 128, 2));
        // ABI: embed + 8/layer + final_ln + head
        assert_eq!(tiny.params.len(), 3 + 8 * tiny.n_layers);
        assert_eq!(tiny.params[0].0, "embed.weight");
        assert_eq!(tiny.params.last().unwrap().0, "head.weight");
        assert_eq!(tiny.params[1].0, "layers.0.ln1.scale");
        // 32-row granularity blocks divide the qblock for every matrix
        assert_eq!((32 * tiny.d_model) % m.qblock, 0);
    }

    #[test]
    fn native_engine_runs_tiny_train_step() {
        // force the native path regardless of artifacts on disk
        let mut e = Engine {
            manifest: Manifest::builtin(),
            dir: Engine::default_dir(),
            inner: Inner::Native,
            exec_counts: HashMap::new(),
        };
        assert!(e.is_native());
        assert_eq!(e.backend_name(), "native");
        let cfg = e.manifest.configs["tiny"].clone();
        let params = crate::train::init_full_params(&cfg.params, 0);
        let mut corpus = crate::train::Corpus::new(cfg.vocab, 1);
        let (tokens, targets) = corpus.batch(cfg.batch, cfg.seq);
        let (loss, grads) = e.train_step("tiny", &params, &tokens, &targets).unwrap();
        assert!((loss - (cfg.vocab as f32).ln()).abs() < 1.0, "loss {loss}");
        assert_eq!(grads.len(), params.len());
        // shared-reference path gives the same result
        let (loss2, _) = e.train_step_shared("tiny", &params, &tokens, &targets).unwrap();
        assert_eq!(loss.to_bits(), loss2.to_bits());
        // eval agrees with the train-step loss
        let le = e.eval_loss("tiny", &params, &tokens, &targets).unwrap();
        assert!((loss - le).abs() < 1e-6);
        assert_eq!(e.exec_counts["train_step_tiny"], 1);
        // raw HLO exec is PJRT-only
        assert!(e.exec("train_step_tiny", &[]).is_err());
    }

    #[test]
    fn native_adamw_chunk_matches_host_optimizer() {
        let mut e = Engine {
            manifest: Manifest::builtin(),
            dir: Engine::default_dir(),
            inner: Inner::Native,
            exec_counts: HashMap::new(),
        };
        let h = [3.0f32, 1e-3, 0.9, 0.999, 1e-8, 0.01];
        let hyper = crate::optim::AdamHyper {
            lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.01,
        };
        let mut rng = crate::util::Rng::new(0);
        let n = 100;
        let mut p: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let (mut m, mut v) = (vec![0.1f32; n], vec![0.01f32; n]);
        let (mut ph, mut mh, mut vh) = (p.clone(), m.clone(), v.clone());
        e.adamw_chunk(&h, &mut p, &g, &mut m, &mut v).unwrap();
        crate::optim::AdamW::apply(&hyper, 3, &mut ph, &g, &mut mh, &mut vh);
        for i in 0..n {
            assert_eq!(p[i].to_bits(), ph[i].to_bits());
        }
    }
}
