//! Exponential exact solver — the test oracle for Algorithm 1.
//!
//! Tries every permutation of the tensors and every candidate shard size
//! (in units of the collective alignment), returning the true minimal S.
//! Only usable for small instances (n <= 7, small element counts); the
//! property tests compare the polynomial heuristic against this.

use super::{check_valid_shard, TensorDecl};
use crate::util::ceil_div;

fn permutations(n: usize) -> Vec<Vec<usize>> {
    if n == 0 {
        return vec![vec![]];
    }
    let mut out = Vec::new();
    let mut idx: Vec<usize> = (0..n).collect();
    heap_permute(&mut idx, n, &mut out);
    out
}

fn heap_permute(a: &mut Vec<usize>, k: usize, out: &mut Vec<Vec<usize>>) {
    if k == 1 {
        out.push(a.clone());
        return;
    }
    for i in 0..k {
        heap_permute(a, k - 1, out);
        if k % 2 == 0 {
            a.swap(i, k - 1);
        } else {
            a.swap(0, k - 1);
        }
    }
}

/// True minimum S over *all* permutations and all S that are multiples of
/// `g_coll`, by linear scan from the pigeonhole lower bound. Returns None
/// if nothing feasible up to S = sum(e) rounded up (which is always
/// feasible when every granularity divides some S; in pathological cases
/// the scan extends to the LCM bound).
pub fn solve_exact(tensors: &[TensorDecl], m: usize, g_coll: u64) -> Option<u64> {
    assert!(tensors.len() <= 7, "exact solver is exponential");
    if tensors.is_empty() {
        return Some(0);
    }
    let sum_e: u64 = tensors.iter().map(|t| t.numel).sum();
    let g = g_coll.max(1);
    let perms = permutations(tensors.len());
    // upper bound: everything in one shard, aligned
    let s_hi = ceil_div(sum_e, g) * g;
    // extend past s_hi a little: alignment of case-3 tensors may require
    // S slightly larger than sum_e
    let max_g = tensors.iter().map(|t| t.granularity).max().unwrap();
    let limit = s_hi + max_g * g;
    let mut s = ceil_div(sum_e, m as u64 * g).max(1) * g;
    while s <= limit {
        for perm in &perms {
            let ordered: Vec<&TensorDecl> =
                perm.iter().map(|&i| &tensors[i]).collect();
            if check_valid_shard(&ordered, m, s, None).is_some() {
                return Some(s);
            }
        }
        s += g;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(name: &str, e: u64, g: u64) -> TensorDecl {
        TensorDecl::new(name, e, g)
    }

    #[test]
    fn exact_matches_hand_computation() {
        // two tensors of 6 elems, g=1, over 2 devices: S=6
        let ts = vec![t("a", 6, 1), t("b", 6, 1)];
        assert_eq!(solve_exact(&ts, 2, 1), Some(6));
    }

    #[test]
    fn exact_block_constraint() {
        // 10 elems g=4 over 2 devices: boundary inside must be at 4 or 8.
        // S=5: boundary at 5 -> splits. S=6: boundary at 6 -> splits.
        // S=7: tensor in [0,10): boundary 7 splits. ... with offset
        // freedom: S=6, start at 2: boundary 6 is 4 into tensor ✓ and
        // 10 fits by 12. So exact should find 6 (or even 5 with start 1?
        // boundary 5 at 4 into tensor ✓, end 11 > 10 = m*S -> infeasible).
        let ts = vec![t("a", 10, 4)];
        assert_eq!(solve_exact(&ts, 2, 1), Some(6));
    }

    #[test]
    fn exact_permutation_matters() {
        // tensors where a bad order forces padding
        let ts = vec![t("a", 3, 1), t("b", 4, 4), t("c", 1, 1)];
        let s = solve_exact(&ts, 2, 1).unwrap();
        assert_eq!(s, 4); // e.g. [b | a c] -> shard 4: b fills dev0; a+c dev1
    }

    #[test]
    fn permutations_count() {
        assert_eq!(permutations(4).len(), 24);
        assert_eq!(permutations(0).len(), 1);
    }
}
