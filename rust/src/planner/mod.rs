//! Structure-aware planning for grouped RaggedShard DTensors (paper §5,
//! Algorithm 1).
//!
//! Given an ordered list of tensors, each with a sharding granularity
//! (atomic block size) `g_t` and element count `e_t`, find the minimal
//! uniform per-device buffer size `S` and contiguous intervals
//! `[l_t, l_t + e_t)` in the global buffer of size `m*S` such that:
//!
//! 1. **Non-sharded block** — every device boundary `k*S` that falls inside
//!    a tensor lands on a multiple of `g_t` from the tensor start;
//! 2. **Contiguous tensor memory** — tensors are contiguous; padding goes
//!    *between* tensors, never inside them;
//! 3. **Balanced load** — all devices own exactly `S` elements.
//!
//! The general problem is NP-hard (reduction from Partition); Algorithm 1
//! is the paper's polynomial heuristic: a feasibility check per candidate
//! `S`, swept over multiples of a growing LCM of granularities (prefixes of
//! the sorted granularity list cover the case-(3) sets, a 2-approximation),
//! with binary search over the multiple.
//!
//! **Feasibility check.** The paper formulates `dp(t, i; S)` = min shards
//! to place all tensors before `t` plus the first `i` blocks of `t`, and
//! skips runs of equal dp values. Because padding is only legal *between*
//! tensors, a tensor's placement is fully determined by its start offset,
//! and an exchange argument shows the earliest valid start is always
//! optimal (any layout can be left-shifted tensor by tensor). Our
//! `check_valid_shard` therefore computes each tensor's earliest valid
//! start in O(1) via the paper's three-case modular analysis — the exact
//! closed form of the dp recurrence (the "segments" of Alg 1 lines 10-13
//! collapse to one arithmetic step per case). The dp values themselves are
//! still exposed (`dp_trace`) and property-tested for the paper's
//! monotonicity claim.

pub mod exact;

use anyhow::{bail, Result};

use crate::analysis::diag::{codes, rt};
use crate::comm::Fabric;
use crate::util::{ceil_div, gcd, lcm};

/// Planner input: one tensor to be placed in the grouped buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorDecl {
    pub name: String,
    /// Total elements e_t.
    pub numel: u64,
    /// Sharding granularity g_t (elements per atomic block).
    pub granularity: u64,
}

impl TensorDecl {
    pub fn new(name: &str, numel: u64, granularity: u64) -> TensorDecl {
        TensorDecl { name: name.to_string(), numel, granularity }
    }

    /// u_t = number of sharding blocks (last may be a tail).
    pub fn num_blocks(&self) -> u64 {
        ceil_div(self.numel, self.granularity)
    }
}

/// Tensor permutation heuristics (paper §5: transformer regularity makes
/// all three near-optimal; default order is used in production for
/// debuggability).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ordering {
    /// Model definition order.
    Default,
    /// Sort by sharding block size (granularity), descending.
    ByGranularity,
    /// Sort by tensor size (elements), descending.
    BySize,
}

/// A planned layout of the grouped communication buffer.
#[derive(Debug, Clone)]
pub struct Layout {
    /// Uniform per-device buffer size S (elements).
    pub shard_size: u64,
    /// Devices m.
    pub num_devices: usize,
    /// Tensor start offsets in the global buffer, in *input* order.
    pub offsets: Vec<u64>,
    /// Input tensors (in input order).
    pub tensors: Vec<TensorDecl>,
    /// Permutation applied (position p in placement order -> input index).
    pub perm: Vec<usize>,
    pub ordering: Ordering,
}

impl Layout {
    /// Total buffer size m*S.
    pub fn total(&self) -> u64 {
        self.shard_size * self.num_devices as u64
    }

    /// Padding overhead: extra elements over total parameter size.
    pub fn padding(&self) -> u64 {
        self.total() - self.tensors.iter().map(|t| t.numel).sum::<u64>()
    }

    pub fn padding_ratio(&self) -> f64 {
        let total_param: u64 = self.tensors.iter().map(|t| t.numel).sum();
        if total_param == 0 {
            0.0
        } else {
            self.padding() as f64 / total_param as f64
        }
    }

    /// Element range of tensor `idx` (input order) on device `rank`:
    /// intersection of [offset, offset+numel) with [rank*S, (rank+1)*S),
    /// returned tensor-relative.
    pub fn local_slice(&self, idx: usize, rank: usize) -> Option<(u64, u64)> {
        let t = &self.tensors[idx];
        let (lo, hi) = (self.offsets[idx], self.offsets[idx] + t.numel);
        let (slo, shi) = (
            rank as u64 * self.shard_size,
            (rank as u64 + 1) * self.shard_size,
        );
        let a = lo.max(slo);
        let b = hi.min(shi);
        if a < b {
            Some((a - lo, b - lo))
        } else {
            None
        }
    }

    /// The RaggedSpec this layout induces for tensor `idx`: how many whole
    /// blocks of it each device owns.
    pub fn ragged_spec(&self, idx: usize) -> crate::placement::RaggedSpec {
        let t = &self.tensors[idx];
        let mut blocks = vec![0u64; self.num_devices];
        for (rank, b) in blocks.iter_mut().enumerate() {
            if let Some((lo, hi)) = self.local_slice(idx, rank) {
                let first = ceil_div(lo, t.granularity);
                let last = ceil_div(hi, t.granularity);
                *b = last - first;
            }
        }
        crate::placement::RaggedSpec {
            granularity: t.granularity,
            blocks_per_device: blocks,
        }
    }

    /// Check the three constraints hold (used by tests and debug builds).
    pub fn verify(&self) -> Result<()> {
        let m = self.num_devices as u64;
        let s = self.shard_size;
        // non-overlap + in-buffer + contiguity
        let mut iv: Vec<(u64, u64, usize)> = self
            .offsets
            .iter()
            .enumerate()
            .map(|(i, &o)| (o, o + self.tensors[i].numel, i))
            .collect();
        iv.sort();
        for w in iv.windows(2) {
            if w[0].1 > w[1].0 {
                bail!("tensors {} and {} overlap", w[0].2, w[1].2);
            }
        }
        if let Some(last) = iv.last() {
            if last.1 > m * s {
                bail!("layout exceeds buffer: {} > {}", last.1, m * s);
            }
        }
        // block-boundary constraint
        for (i, t) in self.tensors.iter().enumerate() {
            let (lo, hi) = (self.offsets[i], self.offsets[i] + t.numel);
            let k0 = ceil_div(lo + 1, s); // first boundary strictly inside
            let mut k = k0 * s;
            while k < hi {
                if (k - lo) % t.granularity != 0 {
                    bail!(
                        "boundary {k} splits a block of '{}' (lo={lo}, g={})",
                        t.name,
                        t.granularity
                    );
                }
                k += s;
            }
        }
        Ok(())
    }
}

/// Earliest valid start >= `p` for a tensor (e elements, granularity g)
/// under shard size `s`. Returns None if no valid start exists in any
/// shard (only possible for case-3 tensors when s % g != 0).
///
/// This is the closed form of the paper's case analysis:
///   case 1 — fits in one shard: no alignment constraint;
///   case 2 — straddles exactly one boundary: start offset o must satisfy
///            (s - o) % g == 0;
///   case 3 — contains >= 1 full shard: s % g == 0 and o % g == 0.
fn min_start(p: u64, s: u64, e: u64, g: u64) -> Option<u64> {
    debug_assert!(
        e > 0 && g > 0 && s > 0,
        "{}",
        rt(codes::LAYOUT_INVALID, format_args!("degenerate extent (e={e} g={g} s={s})"))
    );
    let mut best: Option<u64> = None;
    let mut consider = |q: u64| {
        if best.map(|b| q < b).unwrap_or(true) {
            best = Some(q);
        }
    };

    let o = p % s;
    let shard_base = p - o;

    if e <= s {
        // case 1: first position q >= p with (q % s) + e <= s
        if o + e <= s {
            consider(p);
        } else {
            consider(shard_base + s); // start of next shard (offset 0)
        }
    }

    // case 2: straddle exactly one boundary. offset o2 must satisfy
    // o2 > s - e (crosses), o2 + e <= 2s (only one), (s - o2) % g == 0.
    if e <= 2 * s {
        // smallest o2 >= max(o_min_exclusive+1, given) with o2 ≡ s (mod g)
        let lo_off = (s + 1).saturating_sub(e); // o2 >= lo_off, o2 <= s-1... o2 in [lo_off, s-1]; also o2+e<=2s -> o2 <= 2s-e
        let hi_off = (2 * s).saturating_sub(e).min(s - 1);
        if lo_off <= hi_off {
            // candidates in this shard (q >= p) and in the next shard
            for base in [shard_base, shard_base + s] {
                // smallest o2 in [lo_off, hi_off] with o2 ≡ s mod g and
                // base + o2 >= p
                let min_o = if base >= p { lo_off } else { lo_off.max(o) };
                // align min_o up to ≡ s (mod g)
                let r = s % g;
                let cur = min_o % g;
                let o2 = if cur <= r {
                    min_o + (r - cur)
                } else {
                    min_o + (g - cur + r)
                };
                if o2 <= hi_off && base + o2 >= p {
                    consider(base + o2);
                }
            }
        }
    }

    // case 3: contains a full shard — needs s % g == 0, o % g == 0.
    if s % g == 0 {
        let q = p.next_multiple_of(g);
        consider(q);
    }

    best
}

/// Feasibility check for shard size `s` over `m` devices. Returns the
/// start offsets (placement order) if feasible. This is CheckValidShard
/// of Algorithm 1 in closed form; `dp_trace`, if provided, receives the
/// dp(t, u_t) values (shards consumed after each tensor).
pub fn check_valid_shard(
    tensors: &[&TensorDecl],
    m: usize,
    s: u64,
    mut dp_trace: Option<&mut Vec<u64>>,
) -> Option<Vec<u64>> {
    let mut p = 0u64; // earliest free position
    let mut offsets = Vec::with_capacity(tensors.len());
    for t in tensors {
        let q = min_start(p, s, t.numel, t.granularity)?;
        offsets.push(q);
        p = q + t.numel;
        if let Some(tr) = dp_trace.as_deref_mut() {
            tr.push(ceil_div(p, s));
        }
        if p > m as u64 * s {
            return None;
        }
    }
    Some(offsets)
}

/// Algorithm 1: minimal uniform per-device shard size via the LCM sweep +
/// binary search. `g_coll` is the collective's preferred unit (NCCL-style
/// alignment; elements).
pub fn solve_min_shard(
    tensors: &[&TensorDecl],
    m: usize,
    g_coll: u64,
) -> Option<(u64, Vec<u64>)> {
    if tensors.is_empty() {
        return Some((0, vec![]));
    }
    let sum_e: u64 = tensors.iter().map(|t| t.numel).sum();
    let mut grans: Vec<u64> = tensors.iter().map(|t| t.granularity).collect();
    grans.sort_unstable();
    grans.dedup();

    let mut best: Option<(u64, Vec<u64>)> = None;
    let mut g = g_coll.max(1);
    let try_g = |g: u64, best: &mut Option<(u64, Vec<u64>)>| {
        // binary search minimal feasible k*g (feasibility monotone in k —
        // the extra Δ=g is absorbed as inter-tensor padding, paper §5)
        let lo_k = ceil_div(sum_e, m as u64 * g).max(1);
        let mut hi_k = ceil_div(sum_e, g).max(lo_k);
        // ensure hi feasible (everything in shard 0); widen if not
        while check_valid_shard(tensors, m, hi_k * g, None).is_none() {
            hi_k *= 2;
            if hi_k > ceil_div(sum_e, g).saturating_mul(64) {
                return; // no feasible S for this g
            }
        }
        let (mut lo, mut hi) = (lo_k, hi_k);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if check_valid_shard(tensors, m, mid * g, None).is_some() {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        let s = lo * g;
        if best.as_ref().map(|(bs, _)| s < *bs).unwrap_or(true) {
            let offsets = check_valid_shard(tensors, m, s, None).unwrap();
            *best = Some((s, offsets));
        }
    };

    try_g(g, &mut best); // pure collective alignment (no case-3 tensors)
    let mut last_tried = g;
    for &gp in &grans {
        g = lcm(g, gp);
        if g == 0 || g > sum_e.saturating_mul(2).max(g_coll) {
            break; // LCM blew up past any useful shard size
        }
        if g == last_tried {
            continue; // absorbing this granularity changed nothing
        }
        try_g(g, &mut best);
        last_tried = g;
    }
    best
}

/// Apply an ordering heuristic; returns permutation (placement pos ->
/// input index). Sorts are stable so the default order breaks ties.
pub fn permutation(tensors: &[TensorDecl], ord: Ordering) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..tensors.len()).collect();
    match ord {
        Ordering::Default => {}
        Ordering::ByGranularity => {
            idx.sort_by_key(|&i| std::cmp::Reverse(tensors[i].granularity));
        }
        Ordering::BySize => {
            idx.sort_by_key(|&i| std::cmp::Reverse(tensors[i].numel));
        }
    }
    idx
}

/// Plan one ordering.
pub fn plan_with_ordering(
    tensors: &[TensorDecl],
    m: usize,
    g_coll: u64,
    ord: Ordering,
) -> Result<Layout> {
    let perm = permutation(tensors, ord);
    let ordered: Vec<&TensorDecl> = perm.iter().map(|&i| &tensors[i]).collect();
    let (s, offs) = solve_min_shard(&ordered, m, g_coll)
        .ok_or_else(|| anyhow::anyhow!("no feasible layout"))?;
    let mut offsets = vec![0u64; tensors.len()];
    for (pos, &i) in perm.iter().enumerate() {
        offsets[i] = offs[pos];
    }
    let layout = Layout {
        shard_size: s,
        num_devices: m,
        offsets,
        tensors: tensors.to_vec(),
        perm,
        ordering: ord,
    };
    debug_assert!(
        layout.verify().is_ok(),
        "{}",
        rt(codes::LAYOUT_INVALID, format_args!("{:?}", layout.verify()))
    );
    Ok(layout)
}

/// Full planner: try the three heuristic orders, keep the best (paper
/// adopts Default in production for debuggability; we report the best and
/// record which ordering won). Stops early once an ordering reaches the
/// pigeonhole lower bound — on transformer workloads the Default order
/// almost always does, which is what keeps planning under the paper's
/// 0.3 s budget (§6.4).
pub fn plan(tensors: &[TensorDecl], m: usize, g_coll: u64) -> Result<Layout> {
    let sum_e: u64 = tensors.iter().map(|t| t.numel).sum();
    let lower_bound = ceil_div(sum_e, m as u64 * g_coll.max(1)) * g_coll.max(1);
    let mut best: Option<Layout> = None;
    for ord in [Ordering::Default, Ordering::ByGranularity, Ordering::BySize] {
        if let Ok(l) = plan_with_ordering(tensors, m, g_coll, ord) {
            let optimal = l.shard_size <= lower_bound;
            if best
                .as_ref()
                .map(|b| l.shard_size < b.shard_size)
                .unwrap_or(true)
            {
                best = Some(l);
            }
            if optimal {
                break; // cannot do better than the pigeonhole bound
            }
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("no feasible layout in any ordering"))
}

/// Naive grouping baseline (Fig 6a): concatenate in order, pad the total
/// to m*ceil(sum/m/g_coll)*g_coll; blocks may straddle boundaries. Used by
/// the ablation bench ("disable planning").
pub fn naive_concat_shard(tensors: &[TensorDecl], m: usize, g_coll: u64) -> Layout {
    let mut offsets = Vec::with_capacity(tensors.len());
    let mut p = 0u64;
    for t in tensors {
        offsets.push(p);
        p += t.numel;
    }
    let s = ceil_div(p, m as u64).next_multiple_of(g_coll.max(1));
    Layout {
        shard_size: s,
        num_devices: m,
        offsets,
        tensors: tensors.to_vec(),
        perm: (0..tensors.len()).collect(),
        ordering: Ordering::Default,
    }
}

/// Count quant blocks split across device boundaries in a layout (the
/// inefficiency the planner eliminates; drives the ablation cost model).
pub fn split_blocks(layout: &Layout) -> u64 {
    let s = layout.shard_size;
    let mut split = 0;
    for (i, t) in layout.tensors.iter().enumerate() {
        let (lo, hi) = (layout.offsets[i], layout.offsets[i] + t.numel);
        let mut k = ceil_div(lo + 1, s) * s;
        while k < hi {
            if (k - lo) % t.granularity != 0 {
                split += 1;
            }
            k += s;
        }
    }
    split
}

pub use exact::solve_exact;

/// Smallest bucket (f32 elements) worth shipping as its own collective on
/// `fabric` when the `m`-rank group dispatches hierarchically: the size at
/// which the inter-host wire time amortizes the inter-host launch latency
/// to a <= 1% overhead (`bytes = 100 * inter_launch * inter_bw`). Below
/// this floor a bucket's step time is launch-dominated, so the simulator's
/// bucket splitter merges trailing sub-buckets up to it. Flat topologies
/// return 0 — single-tier launch latency is already folded into the cost
/// model, and flat bucket sizing must stay bit-stable.
pub fn latency_bucket_floor(fabric: &Fabric, m: usize) -> u64 {
    if m <= 1 || !fabric.topology.is_hierarchical() {
        return 0;
    }
    (100.0 * fabric.inter_launch * fabric.inter_bw / 4.0) as u64
}

/// Helper: gcd over all granularities (alignment unit of a tensor set).
pub fn granularity_gcd(tensors: &[TensorDecl]) -> u64 {
    tensors.iter().fold(0, |acc, t| gcd(acc, t.granularity))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(name: &str, e: u64, g: u64) -> TensorDecl {
        TensorDecl::new(name, e, g)
    }

    #[test]
    fn min_start_case1_fits() {
        // e=4 fits in shard of 10 at p=3
        assert_eq!(min_start(3, 10, 4, 3), Some(3));
        // e=4 at offset 8 would cross; case-2 offset must satisfy
        // (10 - o) % 3 == 0 -> o in {7}: but 7 < 8... next: o=7+... within
        // next shard base 10: case1 offset0 -> q=10. case2 o=7 base10 -> 17.
        assert_eq!(min_start(8, 10, 4, 3), Some(10));
    }

    #[test]
    fn min_start_case2_straddle() {
        // e=8, s=10, g=4: case2 needs o ≡ 10 (mod 4) ≡ 2, o in (2, 9]:
        // o=6 -> boundary at 4 elements into tensor (multiple of 4) ✓
        // from p=5: o=6 gives q=6
        assert_eq!(min_start(5, 10, 8, 4), Some(6));
        let q = min_start(5, 10, 8, 4).unwrap();
        let boundary = 10u64;
        assert_eq!((boundary - q) % 4, 0);
    }

    #[test]
    fn min_start_case3_contains_shard() {
        // e=25 > 2*s=20: must contain a shard; s=10 % g=5 == 0, o%5==0
        assert_eq!(min_start(3, 10, 25, 5), Some(5));
        // g does not divide s -> infeasible in every shard
        assert_eq!(min_start(0, 10, 25, 4), None);
    }

    #[test]
    fn check_valid_simple() {
        // S=8 is infeasible for these two tensors (a is pinned to offset 3
        // by the straddle constraint, leaving no contiguous room for b);
        // the solver must find the true minimum and produce a valid layout.
        let a = t("a", 10, 5);
        let b = t("b", 6, 3);
        assert!(check_valid_shard(&[&a, &b], 2, 8, None).is_none());
        let (s, offs) = solve_min_shard(&[&a, &b], 2, 1).unwrap();
        let l = Layout {
            shard_size: s,
            num_devices: 2,
            offsets: offs,
            tensors: vec![a.clone(), b.clone()],
            perm: vec![0, 1],
            ordering: Ordering::Default,
        };
        l.verify().unwrap();
        // exact oracle agrees on this ordering-insensitive instance
        let exact = solve_exact(&[a, b], 2, 1).unwrap();
        assert!(s <= 2 * exact, "heuristic {s} vs exact {exact}");
    }

    #[test]
    fn dp_trace_monotone() {
        let ts: Vec<TensorDecl> = (0..8usize)
            .map(|i| t(&format!("t{i}"), 50 + i as u64 * 7, [1, 4, 8][i % 3]))
            .collect();
        let refs: Vec<&TensorDecl> = ts.iter().collect();
        let mut trace = Vec::new();
        if check_valid_shard(&refs, 4, 128, Some(&mut trace)).is_some() {
            for w in trace.windows(2) {
                assert!(w[0] <= w[1], "dp not monotone: {trace:?}");
            }
        }
    }

    #[test]
    fn solve_even_case() {
        // 4 tensors of 64, g=1, 4 devices: S = 64 exactly, zero padding
        let ts: Vec<TensorDecl> = (0..4).map(|i| t(&format!("t{i}"), 64, 1)).collect();
        let l = plan(&ts, 4, 1).unwrap();
        assert_eq!(l.shard_size, 64);
        assert_eq!(l.padding(), 0);
        l.verify().unwrap();
    }

    #[test]
    fn solve_respects_blocks() {
        // one tensor of 100 elements with g=32 over 2 devices: boundary
        // must land on a multiple of 32 -> S in {64,...}: S=64 puts
        // boundary at 64 (2 blocks on dev0), tensor end 100 <= 128 ✓
        let ts = vec![t("w", 100, 32)];
        let l = plan(&ts, 2, 1).unwrap();
        l.verify().unwrap();
        assert!(l.shard_size >= 50);
        assert_eq!(split_blocks(&l), 0);
    }

    #[test]
    fn solve_with_coll_alignment() {
        let ts = vec![t("a", 100, 1), t("b", 60, 1)];
        let l = plan(&ts, 2, 16).unwrap();
        assert_eq!(l.shard_size % 16, 0);
        l.verify().unwrap();
    }

    #[test]
    fn naive_splits_blocks_planner_does_not() {
        // crafted so naive concat splits quant blocks
        let ts = vec![t("a", 96, 32), t("b", 96, 32), t("c", 64, 32)];
        let m = 4;
        let _naive = naive_concat_shard(&ts, m, 1);
        let planned = plan(&ts, m, 1).unwrap();
        assert_eq!(split_blocks(&planned), 0);
        assert!(planned.verify().is_ok());
        // naive S=64: boundary at 64 hits 64 into 'a'? 64%32==0 fine;
        // boundary 128 is 32 into 'b' fine; 192 is 0 into 'c'... make it
        // actually split by odd sizes:
        let ts2 = vec![t("a", 100, 32), t("b", 100, 32)];
        let naive2 = naive_concat_shard(&ts2, 4, 1);
        assert!(split_blocks(&naive2) > 0);
        let planned2 = plan(&ts2, 4, 1).unwrap();
        assert_eq!(split_blocks(&planned2), 0);
    }

    #[test]
    fn ragged_spec_from_layout() {
        let ts = vec![t("w", 100, 32)];
        let l = plan(&ts, 2, 1).unwrap();
        let spec = l.ragged_spec(0);
        assert_eq!(spec.granularity, 32);
        assert_eq!(spec.blocks_per_device.iter().sum::<u64>(), 4);
        spec.validate(100).unwrap();
    }

    #[test]
    fn transformer_like_padding_small() {
        // 16 "layers" x (attn 4096x4096-ish scaled down + mlp) with row
        // granularity — padding should be far under 3% (paper Fig 11)
        let mut ts = Vec::new();
        for i in 0..16 {
            ts.push(t(&format!("l{i}.attn"), 256 * 256, 256));
            ts.push(t(&format!("l{i}.w1"), 256 * 1024, 256));
            ts.push(t(&format!("l{i}.w2"), 1024 * 256, 1024));
        }
        let l = plan(&ts, 8, 1).unwrap();
        l.verify().unwrap();
        assert!(l.padding_ratio() < 0.03, "ratio {}", l.padding_ratio());
    }

    #[test]
    fn empty_input() {
        let l = plan(&[], 4, 1);
        assert!(l.is_ok());
        assert_eq!(l.unwrap().shard_size, 0);
    }

    #[test]
    fn latency_floor_only_on_hierarchical_fabrics() {
        let flat = Fabric::h800();
        assert_eq!(latency_bucket_floor(&flat, 64), 0);
        let hier = Fabric::by_name("h800:8x8").unwrap();
        let floor = latency_bucket_floor(&hier, 64);
        // h800: 100 * 20us * 145 GB/s / 4 B ≈ 72.5M elems
        assert!(floor > 10_000_000, "floor {floor}");
        // degenerate group sizes never impose a floor
        assert_eq!(latency_bucket_floor(&hier, 1), 0);
    }

    #[test]
    fn orderings_are_permutations() {
        let ts = vec![t("a", 10, 2), t("b", 99, 3), t("c", 5, 5)];
        for ord in [Ordering::Default, Ordering::ByGranularity, Ordering::BySize] {
            let mut p = permutation(&ts, ord);
            p.sort();
            assert_eq!(p, vec![0, 1, 2]);
        }
    }
}
