//! Per-rank span tracer, metrics registry, and Chrome-trace export.
//!
//! One clock, one sink, threaded through every layer of the stack: the
//! executor (`fsdp/exec`), both communicator backends (`cluster/serial`,
//! `cluster/threaded`), the DBuffer gather/reduce paths, the quantized
//! wire codecs, and the per-group optimizer steps all record begin/end
//! spans into a shared [`Tracer`]. At session end the spans are merged
//! rank-ordered and exported as Chrome trace-event JSON — one *pid* per
//! rank (plus a `fabric` pid for the transport layer), compute vs comm
//! lanes as *tids* — loadable directly in Perfetto (`ui.perfetto.dev`)
//! or `chrome://tracing`, alongside a machine-readable [`TraceSummary`]
//! (per-bucket exposed-comm attribution, overlap efficiency, per-rank
//! skew, and measured-vs-`fsdp::sim` time per collective).
//!
//! **Cheap when disabled.** The tracer is always compiled and always
//! consulted, but with [`TraceLevel::Off`] every instrumentation site
//! reduces to the `Instant::now()/elapsed` pair the executor already
//! paid for its exposed-comm accounting (the span record is built inside
//! a closure that is never called), so a disabled run does the same work
//! as an uninstrumented one: no allocation, no locking, no formatting.
//! Training math is never touched — tracing on or off produces
//! bit-identical trajectories (`tests/trace_validity.rs`).
//!
//! Levels: `off` records nothing; `comm` records collective + wire spans
//! ([`Cat::Comm`]) and counter tracks; `full` adds per-rank compute
//! spans (`fwd`/`bwd`/`optim`) and allocator waits.

pub mod check;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::comm::CommStats;
use crate::util::json::Json;

/// How much the tracer records.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceLevel {
    /// Record nothing; every site costs one `Instant::now()/elapsed`.
    #[default]
    Off,
    /// Collective/wire spans and counter tracks only.
    Comm,
    /// Everything: comm spans plus compute and allocator spans.
    Full,
}

impl TraceLevel {
    pub fn name(&self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Comm => "comm",
            TraceLevel::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Option<TraceLevel> {
        Some(match s.to_ascii_lowercase().as_str() {
            "off" => TraceLevel::Off,
            "comm" => TraceLevel::Comm,
            "full" => TraceLevel::Full,
            _ => return None,
        })
    }
}

/// Gating category of a span: which [`TraceLevel`] records it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cat {
    /// Collectives, wire codecs, transport — recorded at `comm` and up.
    Comm,
    /// Compute, optimizer, allocator waits — recorded at `full` only.
    Compute,
}

impl Cat {
    fn name(&self) -> &'static str {
        match self {
            Cat::Comm => "comm",
            Cat::Compute => "compute",
        }
    }
}

/// Which timeline lane (tid) a span renders on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    Compute,
    Comm,
}

/// Which process row(s) (pid) a span renders on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankScope {
    /// One rank's lane.
    One(usize),
    /// A god-view span covering every rank (expanded per-pid at export).
    All,
    /// The transport layer's own pid (`fabric`).
    Fabric,
}

/// Builder for one span record. Constructed lazily inside
/// [`Tracer::finish_with`]'s closure so disabled runs never build it.
#[derive(Debug, Clone)]
pub struct Span {
    name: &'static str,
    scope: RankScope,
    lane: Lane,
    exposed: bool,
    bucket: Option<String>,
    bytes: Option<u64>,
    attrs: Vec<(&'static str, String)>,
}

impl Span {
    pub fn new(name: &'static str) -> Span {
        Span {
            name,
            scope: RankScope::All,
            lane: Lane::Comm,
            exposed: false,
            bucket: None,
            bytes: None,
            attrs: Vec::new(),
        }
    }

    /// Restrict the span to one rank's timeline.
    pub fn rank(mut self, r: usize) -> Span {
        self.scope = RankScope::One(r);
        self
    }

    /// Place the span on the transport (`fabric`) pid.
    pub fn fabric(mut self) -> Span {
        self.scope = RankScope::Fabric;
        self
    }

    /// Render on the compute lane instead of the comm lane.
    pub fn lane_compute(mut self) -> Span {
        self.lane = Lane::Compute;
        self
    }

    /// Flag the span's wall time as *exposed* communication: time the
    /// step schedule spent blocked on a collective. The sum of exposed
    /// span durations is `ExecReport::exposed_comm_s` by construction.
    pub fn exposed(mut self) -> Span {
        self.exposed = true;
        self
    }

    pub fn bucket(mut self, name: &str) -> Span {
        self.bucket = Some(name.to_string());
        self
    }

    pub fn bytes(mut self, b: u64) -> Span {
        self.bytes = Some(b);
        self
    }

    pub fn attr<V: Into<String>>(mut self, key: &'static str, value: V) -> Span {
        self.attrs.push((key, value.into()));
        self
    }
}

/// Started span clock. Always created (it is just an `Instant`), so
/// call sites can use the returned elapsed seconds for accounting even
/// when tracing is off.
#[derive(Debug)]
pub struct SpanTimer {
    t0: Instant,
}

impl SpanTimer {
    /// Seconds since the timer started (does not consume the timer).
    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

#[derive(Debug, Clone)]
struct SpanEvent {
    name: &'static str,
    cat: Cat,
    scope: RankScope,
    lane: Lane,
    t0_ns: u64,
    dur_ns: u64,
    step: u64,
    exposed: bool,
    bucket: Option<String>,
    bytes: Option<u64>,
    attrs: Vec<(&'static str, String)>,
}

#[derive(Debug, Clone)]
struct CounterEvent {
    name: &'static str,
    t_ns: u64,
    step: u64,
    value: f64,
}

#[derive(Debug)]
struct TracerInner {
    level: TraceLevel,
    origin: Instant,
    ranks: usize,
    step: AtomicU64,
    spans: Mutex<Vec<SpanEvent>>,
    counters: Mutex<Vec<CounterEvent>>,
    /// `"{hosts}x{gpus_per_host}"` label when the run used hierarchical
    /// collectives; recorded in the exported `metadata` block so
    /// `trace::check` can demand per-tier span attribution.
    topology: Mutex<Option<String>>,
}

/// Lock a tracer mutex even when a panicking thread poisoned it. The
/// panic-hook postmortem dump and exit-path exports still need to read
/// the sink after a worker died; the protected data is a plain record
/// vector with no cross-field invariant a mid-push panic could break,
/// so recovering the guard is safe.
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Shared per-session trace sink. Cloning is an `Arc` bump; every layer
/// (engine, DBuffers, communicators, executor) holds a clone of the same
/// tracer so all spans land on one clock.
#[derive(Debug, Clone)]
pub struct Tracer {
    inner: Arc<TracerInner>,
}

impl Default for Tracer {
    fn default() -> Tracer {
        Tracer::off()
    }
}

impl Tracer {
    pub fn new(level: TraceLevel, ranks: usize) -> Tracer {
        Tracer {
            inner: Arc::new(TracerInner {
                level,
                origin: Instant::now(),
                ranks,
                step: AtomicU64::new(0),
                spans: Mutex::new(Vec::new()),
                counters: Mutex::new(Vec::new()),
                topology: Mutex::new(None),
            }),
        }
    }

    /// A disabled tracer: records nothing, costs (almost) nothing.
    pub fn off() -> Tracer {
        Tracer::new(TraceLevel::Off, 0)
    }

    pub fn level(&self) -> TraceLevel {
        self.inner.level
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.level != TraceLevel::Off
    }

    /// Does the current level record spans of this category?
    pub fn enabled(&self, cat: Cat) -> bool {
        match self.inner.level {
            TraceLevel::Off => false,
            TraceLevel::Comm => cat == Cat::Comm,
            TraceLevel::Full => true,
        }
    }

    /// Tag subsequent spans/counters with the (1-based) training step.
    pub fn set_step(&self, step: u64) {
        self.inner.step.store(step, Ordering::Relaxed);
    }

    /// Record the device topology label (`"4x8"`) for the exported
    /// `metadata` block. Sessions call this only for hierarchical
    /// topologies; flat runs leave it unset.
    pub fn set_topology(&self, label: &str) {
        *relock(&self.inner.topology) = Some(label.to_string());
    }

    pub fn topology(&self) -> Option<String> {
        relock(&self.inner.topology).clone()
    }

    /// Start a span clock. Always cheap; pair with [`Tracer::finish_with`].
    pub fn timer(&self) -> SpanTimer {
        SpanTimer { t0: Instant::now() }
    }

    /// Stop the clock and return the elapsed seconds. If the level
    /// records `cat`, the closure builds the span record and it is
    /// pushed to the sink; otherwise the closure is never called and
    /// this is exactly an `Instant::elapsed`.
    pub fn finish_with<F: FnOnce() -> Span>(&self, timer: SpanTimer, cat: Cat, f: F) -> f64 {
        let dur = timer.t0.elapsed();
        if self.enabled(cat) {
            let span = f();
            let ev = SpanEvent {
                name: span.name,
                cat,
                scope: span.scope,
                lane: span.lane,
                t0_ns: timer.t0.duration_since(self.inner.origin).as_nanos() as u64,
                dur_ns: dur.as_nanos() as u64,
                step: self.inner.step.load(Ordering::Relaxed),
                exposed: span.exposed,
                bucket: span.bucket,
                bytes: span.bytes,
                attrs: span.attrs,
            };
            relock(&self.inner.spans).push(ev);
        }
        dur.as_secs_f64()
    }

    /// Push a span covering an explicit sub-interval of a (still live)
    /// timer: `[t0 + offset_s, t0 + offset_s + dur_s)`. The hierarchical
    /// transport path uses this to split one measured rendezvous into
    /// adjacent per-tier (`intra`/`inter`) spans that still sum to the
    /// measured wall interval — `finish_with` can only stamp "now" as
    /// the end, which would double-count the interval across two spans.
    pub fn push_window<F: FnOnce() -> Span>(
        &self,
        timer: &SpanTimer,
        offset_s: f64,
        dur_s: f64,
        cat: Cat,
        f: F,
    ) {
        if self.enabled(cat) {
            let span = f();
            let base_ns = timer.t0.duration_since(self.inner.origin).as_nanos() as u64;
            let ev = SpanEvent {
                name: span.name,
                cat,
                scope: span.scope,
                lane: span.lane,
                t0_ns: base_ns + (offset_s.max(0.0) * 1e9) as u64,
                dur_ns: (dur_s.max(0.0) * 1e9) as u64,
                step: self.inner.step.load(Ordering::Relaxed),
                exposed: span.exposed,
                bucket: span.bucket,
                bytes: span.bytes,
                attrs: span.attrs,
            };
            relock(&self.inner.spans).push(ev);
        }
    }

    /// Record a counter sample (rendered as a Perfetto counter track on
    /// the `fabric` pid). No-op when disabled.
    pub fn counter(&self, name: &'static str, value: f64) {
        if !self.is_enabled() {
            return;
        }
        let ev = CounterEvent {
            name,
            t_ns: self.inner.origin.elapsed().as_nanos() as u64,
            step: self.inner.step.load(Ordering::Relaxed),
            value,
        };
        relock(&self.inner.counters).push(ev);
    }

    /// Number of recorded spans (test/diagnostic hook).
    pub fn span_count(&self) -> usize {
        relock(&self.inner.spans).len()
    }

    /// Multiset of `(name, bucket, bytes)` identities of recorded spans,
    /// sorted — used to check backend-independent span parity.
    pub fn span_identities(&self) -> Vec<(String, String, u64)> {
        let spans = relock(&self.inner.spans);
        let mut out: Vec<(String, String, u64)> = spans
            .iter()
            .map(|s| {
                (
                    s.name.to_string(),
                    s.bucket.clone().unwrap_or_default(),
                    s.bytes.unwrap_or(0),
                )
            })
            .collect();
        out.sort();
        out
    }

    /// The recorded logical collective spans in push order:
    /// `(step, name, bucket, phase, bytes)` for every `ag`/`rs` span.
    /// This is the dynamic side of the static/trace cross-validation —
    /// `analysis::AnalysisReport::expected_subsequence` predicts the
    /// per-(name, phase) subsequences this must contain for each step.
    pub fn collective_sequence(&self) -> Vec<(u64, String, String, String, u64)> {
        let spans = relock(&self.inner.spans);
        spans
            .iter()
            .filter(|s| s.name == "ag" || s.name == "rs")
            .map(|s| {
                let phase = s
                    .attrs
                    .iter()
                    .find(|(k, _)| *k == "phase")
                    .map(|(_, v)| v.clone())
                    .unwrap_or_default();
                (
                    s.step,
                    s.name.to_string(),
                    s.bucket.clone().unwrap_or_default(),
                    phase,
                    s.bytes.unwrap_or(0),
                )
            })
            .collect()
    }

    /// Sum of exposed-flagged span durations in seconds (the span-side
    /// view of `ExecReport::exposed_comm_s`).
    pub fn exposed_total_s(&self) -> f64 {
        let spans = relock(&self.inner.spans);
        spans.iter().filter(|s| s.exposed).map(|s| s.dur_ns as f64 / 1e9).sum()
    }

    fn fabric_pid(&self) -> usize {
        self.inner.ranks
    }

    /// Merge all recorded spans/counters, rank-ordered, into a Chrome
    /// trace-event JSON document (plus a `summary` key Perfetto ignores).
    pub fn export(&self, stats: &CommStats) -> Json {
        let spans = relock(&self.inner.spans).clone();
        let counters = relock(&self.inner.counters).clone();
        let ranks = self.inner.ranks.max(1);
        let fabric_pid = ranks;

        // Fabric transport spans may genuinely overlap (async collectives
        // in flight on comm threads), so assign each an interval-disjoint
        // lane (tid) greedily; rank-pid spans keep the fixed lanes.
        // Spans tagged with a `tier` attr (hierarchical runs) are packed
        // into separate intra/inter lane blocks so the two wire tiers
        // render as distinct thread groups in Perfetto.
        let mut fabric: Vec<&SpanEvent> =
            spans.iter().filter(|s| s.scope == RankScope::Fabric).collect();
        fabric.sort_by_key(|s| (s.t0_ns, u64::MAX - s.dur_ns));
        let tier_group = |s: &SpanEvent| -> usize {
            match s.attrs.iter().find(|(k, _)| *k == "tier").map(|(_, v)| v.as_str()) {
                Some("intra") => 1,
                Some("inter") => 2,
                _ => 0,
            }
        };
        let mut lane_end: [Vec<u64>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        let mut fabric_lane: Vec<(usize, usize)> = Vec::new(); // (tier group, lane)
        for s in &fabric {
            let g = tier_group(s);
            let ends = &mut lane_end[g];
            let lane = match ends.iter().position(|&e| e <= s.t0_ns) {
                Some(i) => i,
                None => {
                    ends.push(0);
                    ends.len() - 1
                }
            };
            ends[lane] = s.t0_ns + s.dur_ns;
            fabric_lane.push((g, lane));
        }
        // Untiered lanes claim tids from 2 (at least one, so an
        // all-flat trace keeps its `wire0` thread), then the intra and
        // inter blocks follow contiguously.
        let group_lanes =
            [lane_end[0].len().max(1), lane_end[1].len(), lane_end[2].len()];
        let group_base = [
            2usize,
            2 + group_lanes[0],
            2 + group_lanes[0] + group_lanes[1],
        ];

        let mut events: Vec<Json> = Vec::new();
        // Process/thread metadata: pid 0..ranks are ranks, pid `ranks` is
        // the transport fabric.
        for pid in 0..ranks {
            events.push(meta_event(pid, 0, "process_name", &format!("rank{pid}")));
            events.push(meta_event(pid, 1, "thread_name", "compute"));
            events.push(meta_event(pid, 2, "thread_name", "comm"));
        }
        events.push(meta_event(fabric_pid, 0, "process_name", "fabric"));
        for (g, prefix) in [(0usize, "wire"), (1, "wire.intra"), (2, "wire.inter")] {
            for lane in 0..group_lanes[g] {
                events.push(meta_event(
                    fabric_pid,
                    group_base[g] + lane,
                    "thread_name",
                    &format!("{prefix}{lane}"),
                ));
            }
        }

        let mut fi = 0usize;
        // Emit in a stable order: fabric spans (already time-sorted),
        // then rank spans time-sorted.
        for s in &fabric {
            let (g, lane) = fabric_lane[fi];
            fi += 1;
            events.push(span_event(s, fabric_pid, group_base[g] + lane));
        }
        let mut rank_spans: Vec<&SpanEvent> =
            spans.iter().filter(|s| s.scope != RankScope::Fabric).collect();
        rank_spans.sort_by_key(|s| (s.t0_ns, u64::MAX - s.dur_ns));
        for s in rank_spans {
            let tid = match s.lane {
                Lane::Compute => 1,
                Lane::Comm => 2,
            };
            match s.scope {
                RankScope::One(r) => events.push(span_event(s, r, tid)),
                RankScope::All => {
                    for pid in 0..ranks {
                        events.push(span_event(s, pid, tid));
                    }
                }
                RankScope::Fabric => unreachable!("filtered above"),
            }
        }
        for c in &counters {
            events.push(Json::obj(vec![
                ("ph", Json::str("C")),
                ("pid", Json::num(fabric_pid as f64)),
                ("tid", Json::num(0.0)),
                ("ts", Json::num(c.t_ns as f64 / 1e3)),
                ("name", Json::str(c.name)),
                (
                    "args",
                    Json::obj(vec![
                        ("value", Json::num(c.value)),
                        ("step", Json::num(c.step as f64)),
                    ]),
                ),
            ]));
        }

        let mut metadata = vec![
            ("ranks", Json::num(ranks as f64)),
            ("trace_level", Json::str(self.inner.level.name())),
        ];
        if let Some(topo) = self.topology() {
            metadata.push(("topology", Json::str(&topo)));
        }

        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            ("metadata", Json::obj(metadata)),
            ("summary", self.summary(stats).to_json()),
        ])
    }

    /// Aggregate the recorded spans into the machine-readable summary.
    pub fn summary(&self, stats: &CommStats) -> TraceSummary {
        let spans = relock(&self.inner.spans);
        let ranks = self.inner.ranks.max(1);

        let total_comm_s: f64 = spans
            .iter()
            .filter(|s| s.scope == RankScope::Fabric)
            .map(|s| s.dur_ns as f64 / 1e9)
            .sum();
        let exposed_comm_s: f64 =
            spans.iter().filter(|s| s.exposed).map(|s| s.dur_ns as f64 / 1e9).sum();
        let hidden_comm_s = (total_comm_s - exposed_comm_s).max(0.0);
        let overlap_efficiency = if total_comm_s > 0.0 {
            hidden_comm_s / total_comm_s
        } else {
            0.0
        };

        let mut per_bucket: Vec<(String, f64)> = Vec::new();
        for s in spans.iter().filter(|s| s.exposed) {
            let key = s.bucket.clone().unwrap_or_else(|| "*".to_string());
            match per_bucket.iter_mut().find(|(k, _)| *k == key) {
                Some((_, acc)) => *acc += s.dur_ns as f64 / 1e9,
                None => per_bucket.push((key, s.dur_ns as f64 / 1e9)),
            }
        }
        per_bucket.sort_by(|a, b| b.1.total_cmp(&a.1));

        let mut per_rank_compute_s = vec![0.0f64; ranks];
        for s in spans.iter().filter(|s| s.lane == Lane::Compute) {
            match s.scope {
                RankScope::One(r) if r < ranks => {
                    per_rank_compute_s[r] += s.dur_ns as f64 / 1e9;
                }
                RankScope::All => {
                    for acc in per_rank_compute_s.iter_mut() {
                        *acc += s.dur_ns as f64 / 1e9;
                    }
                }
                _ => {}
            }
        }
        let max_c = per_rank_compute_s.iter().cloned().fold(0.0f64, f64::max);
        let min_c = per_rank_compute_s.iter().cloned().fold(f64::INFINITY, f64::min);
        let rank_skew_s = if min_c.is_finite() {
            (max_c - min_c).max(0.0)
        } else {
            0.0
        };

        // Measured transport seconds per collective vs the fabric cost
        // model's prediction for the same record stream. Note: the HSDP
        // replica AllReduce is simulated only (no real transfer), so its
        // measured time is 0 while sim time is > 0 — the delta is the
        // point of reporting both.
        let mut per_op: Vec<OpTiming> = Vec::new();
        for s in spans.iter().filter(|s| s.scope == RankScope::Fabric) {
            let dur_s = s.dur_ns as f64 / 1e9;
            let tier = s.attrs.iter().find(|(k, _)| *k == "tier").map(|(_, v)| v.as_str());
            let o = match per_op.iter_mut().position(|o| o.op == s.name) {
                Some(i) => {
                    let o = &mut per_op[i];
                    o.measured_s += dur_s;
                    o.count += 1;
                    o
                }
                None => {
                    per_op.push(OpTiming {
                        op: s.name,
                        measured_s: dur_s,
                        sim_s: 0.0,
                        measured_intra_s: 0.0,
                        measured_inter_s: 0.0,
                        sim_intra_s: 0.0,
                        sim_inter_s: 0.0,
                        count: 1,
                    });
                    per_op.last_mut().unwrap()
                }
            };
            match tier {
                Some("intra") => o.measured_intra_s += dur_s,
                Some("inter") => o.measured_inter_s += dur_s,
                _ => {}
            }
        }
        for op in ["all_gather", "reduce_scatter", "all_reduce", "broadcast", "all_to_all"] {
            let sim = stats.time_of(op);
            let (sim_i, sim_e) = stats.tier_time_of(op);
            match per_op.iter_mut().find(|o| o.op == op) {
                Some(o) => {
                    o.sim_s = sim;
                    o.sim_intra_s = sim_i;
                    o.sim_inter_s = sim_e;
                }
                None if sim > 0.0 => per_op.push(OpTiming {
                    op,
                    measured_s: 0.0,
                    sim_s: sim,
                    measured_intra_s: 0.0,
                    measured_inter_s: 0.0,
                    sim_intra_s: sim_i,
                    sim_inter_s: sim_e,
                    count: 0,
                }),
                None => {}
            }
        }
        per_op.sort_by(|a, b| a.op.cmp(b.op));

        TraceSummary {
            total_comm_s,
            exposed_comm_s,
            hidden_comm_s,
            overlap_efficiency,
            per_bucket_exposed_s: per_bucket,
            per_rank_compute_s,
            rank_skew_s,
            per_op,
        }
    }
}

fn meta_event(pid: usize, tid: usize, kind: &'static str, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("name", Json::str(kind)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

fn span_event(s: &SpanEvent, pid: usize, tid: usize) -> Json {
    let mut args = vec![
        ("step", Json::num(s.step as f64)),
        ("exposed", Json::Bool(s.exposed)),
    ];
    if let Some(b) = &s.bucket {
        args.push(("bucket", Json::str(b)));
    }
    if let Some(n) = s.bytes {
        args.push(("bytes", Json::num(n as f64)));
    }
    for (k, v) in &s.attrs {
        args.push((k, Json::str(v)));
    }
    Json::obj(vec![
        ("ph", Json::str("X")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("ts", Json::num(s.t0_ns as f64 / 1e3)),
        ("dur", Json::num(s.dur_ns as f64 / 1e3)),
        ("name", Json::str(s.name)),
        ("cat", Json::str(s.cat.name())),
        ("args", Json::obj(args)),
    ])
}

/// Per-collective measured-vs-model timing.
#[derive(Debug, Clone)]
pub struct OpTiming {
    pub op: &'static str,
    /// Wall seconds the transport layer actually spent in this op.
    pub measured_s: f64,
    /// `fsdp::sim` fabric-model seconds for the same record stream.
    pub sim_s: f64,
    /// Measured seconds attributed to the intra-host (NVLink) tier —
    /// the sum of fabric spans tagged `tier: intra`. Zero on flat runs.
    pub measured_intra_s: f64,
    /// Measured seconds attributed to the inter-host (IB) tier.
    pub measured_inter_s: f64,
    /// Cost-model seconds for the intra-host tier (serialized, with its
    /// tier launch overhead — the two tiers overlap under pipelining,
    /// so `sim_intra + sim_inter >= sim_s` by design).
    pub sim_intra_s: f64,
    /// Cost-model seconds for the inter-host tier.
    pub sim_inter_s: f64,
    pub count: usize,
}

/// Machine-readable roll-up of one traced run.
#[derive(Debug, Clone)]
pub struct TraceSummary {
    /// Total transport-layer seconds (all fabric spans).
    pub total_comm_s: f64,
    /// Seconds the step schedule spent blocked on collectives.
    pub exposed_comm_s: f64,
    /// Comm time hidden under compute: `max(0, total - exposed)`.
    pub hidden_comm_s: f64,
    /// `hidden / total` — 1.0 means every wire byte was overlapped.
    pub overlap_efficiency: f64,
    /// Exposed seconds attributed per bucket, largest first.
    pub per_bucket_exposed_s: Vec<(String, f64)>,
    pub per_rank_compute_s: Vec<f64>,
    /// Straggler gap: max minus min per-rank compute seconds.
    pub rank_skew_s: f64,
    pub per_op: Vec<OpTiming>,
}

impl TraceSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_comm_s", Json::num(self.total_comm_s)),
            ("exposed_comm_s", Json::num(self.exposed_comm_s)),
            ("hidden_comm_s", Json::num(self.hidden_comm_s)),
            ("overlap_efficiency", Json::num(self.overlap_efficiency)),
            (
                "per_bucket_exposed_s",
                Json::Arr(
                    self.per_bucket_exposed_s
                        .iter()
                        .map(|(k, v)| {
                            Json::obj(vec![
                                ("bucket", Json::str(k)),
                                ("exposed_s", Json::num(*v)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "per_rank_compute_s",
                Json::Arr(self.per_rank_compute_s.iter().map(|&v| Json::num(v)).collect()),
            ),
            ("rank_skew_s", Json::num(self.rank_skew_s)),
            (
                "per_op",
                Json::Arr(
                    self.per_op
                        .iter()
                        .map(|o| {
                            Json::obj(vec![
                                ("op", Json::str(o.op)),
                                ("measured_s", Json::num(o.measured_s)),
                                ("sim_s", Json::num(o.sim_s)),
                                ("measured_intra_s", Json::num(o.measured_intra_s)),
                                ("measured_inter_s", Json::num(o.measured_inter_s)),
                                ("sim_intra_s", Json::num(o.sim_intra_s)),
                                ("sim_inter_s", Json::num(o.sim_inter_s)),
                                ("count", Json::num(o.count as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_records_nothing_and_still_times() {
        let t = Tracer::off();
        let timer = t.timer();
        std::thread::sleep(std::time::Duration::from_millis(1));
        let secs = t.finish_with(timer, Cat::Comm, || panic!("must not build span"));
        assert!(secs > 0.0);
        assert_eq!(t.span_count(), 0);
        t.counter("mem.reserved", 1.0);
        assert_eq!(t.inner.counters.lock().unwrap().len(), 0);
    }

    #[test]
    fn comm_level_gates_compute_spans() {
        let t = Tracer::new(TraceLevel::Comm, 2);
        let a = t.timer();
        t.finish_with(a, Cat::Comm, || Span::new("ag").bucket("b0").bytes(4));
        let b = t.timer();
        t.finish_with(b, Cat::Compute, || Span::new("fwd").rank(0).lane_compute());
        assert_eq!(t.span_count(), 1);
        let full = Tracer::new(TraceLevel::Full, 2);
        let c = full.timer();
        full.finish_with(c, Cat::Compute, || Span::new("fwd").rank(0).lane_compute());
        assert_eq!(full.span_count(), 1);
    }

    #[test]
    fn export_roundtrips_and_validates() {
        let t = Tracer::new(TraceLevel::Full, 2);
        let outer = t.timer();
        let inner = t.timer();
        t.finish_with(inner, Cat::Comm, || {
            Span::new("quant_encode").bucket("embed").bytes(64)
        });
        t.finish_with(outer, Cat::Comm, || {
            Span::new("ag").exposed().bucket("embed").bytes(128).attr("phase", "issue")
        });
        let f = t.timer();
        t.finish_with(f, Cat::Comm, || Span::new("all_gather").fabric().bytes(128));
        t.counter("mem.reserved", 1024.0);
        let json = t.export(&CommStats::default());
        let text = json.to_string();
        let parsed = Json::parse(&text).unwrap();
        check::validate(&parsed).unwrap();
        // the All-scope spans fan out to both rank pids
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let ag_events = events
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("ag"))
            .count();
        assert_eq!(ag_events, 2);
    }

    #[test]
    fn poisoned_sink_still_exports() {
        let t = Tracer::new(TraceLevel::Comm, 1);
        let timer = t.timer();
        t.finish_with(timer, Cat::Comm, || Span::new("ag").bucket("b").bytes(4));
        // Poison the span mutex the way a crashed worker would: panic
        // while holding it. Exit-path exports must keep working.
        let t2 = t.clone();
        let _ = std::thread::spawn(move || {
            let _guard = t2.inner.spans.lock().unwrap();
            panic!("poison the sink");
        })
        .join();
        assert!(t.inner.spans.is_poisoned());
        assert_eq!(t.span_count(), 1);
        let json = t.export(&CommStats::default());
        check::validate(&json).unwrap();
    }

    #[test]
    fn overlapping_fabric_spans_get_disjoint_lanes() {
        let t = Tracer::new(TraceLevel::Comm, 1);
        // forge two overlapping transport spans by pushing directly
        for (t0, dur) in [(0u64, 100u64), (50, 100)] {
            t.inner.spans.lock().unwrap().push(SpanEvent {
                name: "all_gather",
                cat: Cat::Comm,
                scope: RankScope::Fabric,
                lane: Lane::Comm,
                t0_ns: t0,
                dur_ns: dur,
                step: 1,
                exposed: false,
                bucket: None,
                bytes: Some(8),
                attrs: Vec::new(),
            });
        }
        let json = t.export(&CommStats::default());
        check::validate(&json).unwrap();
        let events = json.get("traceEvents").unwrap().as_arr().unwrap();
        let tids: Vec<usize> = events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(Json::as_str) == Some("X")
                    && e.get("name").and_then(Json::as_str) == Some("all_gather")
            })
            .map(|e| e.get("tid").unwrap().as_usize().unwrap())
            .collect();
        assert_eq!(tids.len(), 2);
        assert_ne!(tids[0], tids[1], "overlapping spans must not share a lane");
    }

    #[test]
    fn tiered_spans_get_separate_wire_lanes_and_metadata() {
        let t = Tracer::new(TraceLevel::Comm, 2);
        t.set_topology("2x4");
        let timer = t.timer();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let dur = timer.elapsed_s();
        t.push_window(&timer, 0.0, dur * 0.5, Cat::Comm, || {
            Span::new("all_gather").fabric().bytes(96).attr("tier", "intra")
        });
        t.push_window(&timer, dur * 0.5, dur * 0.5, Cat::Comm, || {
            Span::new("all_gather").fabric().bytes(128).attr("tier", "inter")
        });
        let json = t.export(&CommStats::default());
        check::validate(&json).unwrap();
        let text = json.to_string();
        assert!(text.contains("wire.intra0"), "missing intra wire lane: {text}");
        assert!(text.contains("wire.inter0"), "missing inter wire lane: {text}");
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("metadata").unwrap().get("topology").and_then(Json::as_str),
            Some("2x4")
        );
        // Tier groups own disjoint lane blocks, so the adjacent
        // (non-overlapping) windows still land on different tids.
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let tids: Vec<usize> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| e.get("tid").unwrap().as_usize().unwrap())
            .collect();
        assert_eq!(tids.len(), 2);
        assert_ne!(tids[0], tids[1], "intra/inter spans must not share a lane");
    }

    #[test]
    fn summary_splits_measured_time_by_tier() {
        let t = Tracer::new(TraceLevel::Comm, 2);
        for (t0, dur, tier) in
            [(0u64, 3_000_000_000u64, "intra"), (3_000_000_000, 1_000_000_000, "inter")]
        {
            t.inner.spans.lock().unwrap().push(SpanEvent {
                name: "all_gather",
                cat: Cat::Comm,
                scope: RankScope::Fabric,
                lane: Lane::Comm,
                t0_ns: t0,
                dur_ns: dur,
                step: 1,
                exposed: false,
                bucket: None,
                bytes: Some(8),
                attrs: vec![("tier", tier.to_string())],
            });
        }
        let s = t.summary(&CommStats::default());
        let ag = s.per_op.iter().find(|o| o.op == "all_gather").unwrap();
        assert!((ag.measured_s - 4.0).abs() < 1e-9);
        assert!((ag.measured_intra_s - 3.0).abs() < 1e-9);
        assert!((ag.measured_inter_s - 1.0).abs() < 1e-9);
        assert_eq!(ag.count, 2);
    }

    #[test]
    fn summary_attributes_exposed_and_overlap() {
        let t = Tracer::new(TraceLevel::Comm, 2);
        t.inner.spans.lock().unwrap().extend([
            SpanEvent {
                name: "all_gather",
                cat: Cat::Comm,
                scope: RankScope::Fabric,
                lane: Lane::Comm,
                t0_ns: 0,
                dur_ns: 4_000_000_000,
                step: 1,
                exposed: false,
                bucket: None,
                bytes: Some(8),
                attrs: Vec::new(),
            },
            SpanEvent {
                name: "ag",
                cat: Cat::Comm,
                scope: RankScope::All,
                lane: Lane::Comm,
                t0_ns: 0,
                dur_ns: 1_000_000_000,
                step: 1,
                exposed: true,
                bucket: Some("embed".into()),
                bytes: Some(8),
                attrs: Vec::new(),
            },
        ]);
        let s = t.summary(&CommStats::default());
        assert!((s.total_comm_s - 4.0).abs() < 1e-9);
        assert!((s.exposed_comm_s - 1.0).abs() < 1e-9);
        assert!((s.overlap_efficiency - 0.75).abs() < 1e-9);
        assert_eq!(s.per_bucket_exposed_s[0].0, "embed");
        assert!((t.exposed_total_s() - 1.0).abs() < 1e-9);
    }
}
