//! Structural validator for exported Chrome trace-event JSON: schema of
//! every event, strict nesting of duration spans per `(pid, tid)` lane,
//! and required `bucket`/`bytes` attributes on collective spans. Shared
//! by the `trace-check` CLI binary (CI runs it on the smoke traces) and
//! `tests/trace_validity.rs`.

use crate::util::json::Json;

/// Collective spans that must carry both a `bucket` and a `bytes` arg.
const LOGICAL_COLLECTIVES: [&str; 2] = ["ag", "rs"];
/// Transport spans that must carry a `bytes` arg.
const TRANSPORT_OPS: [&str; 5] =
    ["all_gather", "reduce_scatter", "all_reduce", "broadcast", "all_to_all"];

/// Validate a parsed trace document. Returns `Err(reason)` on the first
/// structural violation.
pub fn validate(doc: &Json) -> Result<(), String> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("missing traceEvents array")?;
    if events.is_empty() {
        return Err("traceEvents is empty".into());
    }

    // Hierarchical runs (`metadata.topology = "HxG"` with H > 1) must
    // tag every transport span with its wire tier (`intra`/`inter`).
    let hierarchical = doc
        .get("metadata")
        .and_then(|m| m.get("topology"))
        .and_then(Json::as_str)
        .and_then(|t| t.split('x').next().and_then(|h| h.parse::<u64>().ok()))
        .map_or(false, |h| h > 1);

    // (pid, tid) -> [(ts, dur, name)]
    let mut lanes: Vec<((u64, u64), Vec<(f64, f64, String)>)> = Vec::new();
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        match ph {
            "M" => {
                if e.get("name").and_then(Json::as_str).is_none() {
                    return Err(format!("event {i}: metadata without name"));
                }
            }
            "C" => {
                require_num(e, i, "ts")?;
                let args =
                    e.get("args").ok_or_else(|| format!("event {i}: counter without args"))?;
                if args.get("value").and_then(Json::as_f64).is_none() {
                    return Err(format!("event {i}: counter without args.value"));
                }
            }
            "X" => {
                let pid = require_num(e, i, "pid")? as u64;
                let tid = require_num(e, i, "tid")? as u64;
                let ts = require_num(e, i, "ts")?;
                let dur = require_num(e, i, "dur")?;
                let name = e
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("event {i}: span without name"))?;
                if e.get("cat").and_then(Json::as_str).is_none() {
                    return Err(format!("event {i}: span without cat"));
                }
                let args = e.get("args");
                let has = |key: &str| args.and_then(|a| a.get(key)).is_some();
                if LOGICAL_COLLECTIVES.contains(&name) && (!has("bucket") || !has("bytes")) {
                    return Err(format!(
                        "event {i}: collective span '{name}' missing bucket/bytes args"
                    ));
                }
                if TRANSPORT_OPS.contains(&name) && !has("bytes") {
                    return Err(format!(
                        "event {i}: transport span '{name}' missing bytes arg"
                    ));
                }
                if hierarchical && TRANSPORT_OPS.contains(&name) && !has("tier") {
                    return Err(format!(
                        "event {i}: transport span '{name}' missing tier arg \
                         on hierarchical-topology run"
                    ));
                }
                let key = (pid, tid);
                match lanes.iter_mut().find(|(k, _)| *k == key) {
                    Some((_, v)) => v.push((ts, dur, name.to_string())),
                    None => lanes.push((key, vec![(ts, dur, name.to_string())])),
                }
            }
            other => return Err(format!("event {i}: unknown ph '{other}'")),
        }
    }

    // Strict nesting per lane: after sorting by (start asc, dur desc),
    // every span must be fully contained in (or disjoint from) the
    // enclosing span on the stack.
    const EPS: f64 = 1e-3; // microseconds; absorbs ns -> us rounding
    for ((pid, tid), mut v) in lanes {
        v.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1)));
        let mut stack: Vec<(f64, f64, String)> = Vec::new(); // (start, end, name)
        for (ts, dur, name) in v {
            let end = ts + dur;
            while let Some(top) = stack.last() {
                if top.1 <= ts + EPS {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(top) = stack.last() {
                if end > top.1 + EPS {
                    return Err(format!(
                        "lane ({pid},{tid}): span '{name}' [{ts:.3},{end:.3}] \
                         overlaps '{}' ending at {:.3} without nesting",
                        top.2, top.1
                    ));
                }
            }
            stack.push((ts, end, name));
        }
    }
    Ok(())
}

fn require_num(e: &Json, i: usize, key: &str) -> Result<f64, String> {
    e.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("event {i}: missing numeric '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(pid: u64, tid: u64, ts: f64, dur: f64, name: &str) -> Json {
        Json::obj(vec![
            ("ph", Json::str("X")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("ts", Json::num(ts)),
            ("dur", Json::num(dur)),
            ("name", Json::str(name)),
            ("cat", Json::str("comm")),
            ("args", Json::obj(vec![("bytes", Json::num(8.0))])),
        ])
    }

    fn doc(events: Vec<Json>) -> Json {
        Json::obj(vec![("traceEvents", Json::Arr(events))])
    }

    #[test]
    fn accepts_nested_and_sequential() {
        let d = doc(vec![
            span(0, 2, 0.0, 100.0, "outer"),
            span(0, 2, 10.0, 20.0, "inner"),
            span(0, 2, 200.0, 50.0, "later"),
            span(1, 2, 5.0, 500.0, "other-lane"),
        ]);
        validate(&d).unwrap();
    }

    #[test]
    fn rejects_partial_overlap() {
        let d = doc(vec![
            span(0, 2, 0.0, 100.0, "a"),
            span(0, 2, 50.0, 100.0, "b"),
        ]);
        assert!(validate(&d).is_err());
    }

    #[test]
    fn rejects_collective_without_bucket() {
        let d = doc(vec![span(0, 2, 0.0, 1.0, "ag")]);
        let err = validate(&d).unwrap_err();
        assert!(err.contains("bucket"), "{err}");
    }

    #[test]
    fn hierarchical_topology_demands_tier_attr() {
        // The same untagged transport span passes on a flat doc...
        let flat = doc(vec![span(0, 2, 0.0, 1.0, "all_gather")]);
        validate(&flat).unwrap();
        // ...but fails once metadata declares a multi-host topology.
        let hier = Json::obj(vec![
            ("traceEvents", Json::Arr(vec![span(0, 2, 0.0, 1.0, "all_gather")])),
            ("metadata", Json::obj(vec![("topology", Json::str("2x4"))])),
        ]);
        let err = validate(&hier).unwrap_err();
        assert!(err.contains("tier"), "{err}");
        // A single-host topology ("1x8") stays exempt.
        let single = Json::obj(vec![
            ("traceEvents", Json::Arr(vec![span(0, 2, 0.0, 1.0, "all_gather")])),
            ("metadata", Json::obj(vec![("topology", Json::str("1x8"))])),
        ]);
        validate(&single).unwrap();
        // Tagged spans satisfy the hierarchical requirement.
        let tagged = Json::obj(vec![
            ("ph", Json::str("X")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(2.0)),
            ("ts", Json::num(0.0)),
            ("dur", Json::num(1.0)),
            ("name", Json::str("all_gather")),
            ("cat", Json::str("comm")),
            (
                "args",
                Json::obj(vec![
                    ("bytes", Json::num(8.0)),
                    ("tier", Json::str("intra")),
                ]),
            ),
        ]);
        let ok = Json::obj(vec![
            ("traceEvents", Json::Arr(vec![tagged])),
            ("metadata", Json::obj(vec![("topology", Json::str("2x4"))])),
        ]);
        validate(&ok).unwrap();
    }

    #[test]
    fn rejects_empty_and_malformed() {
        assert!(validate(&doc(vec![])).is_err());
        assert!(validate(&Json::obj(vec![])).is_err());
        let no_ph = Json::obj(vec![("name", Json::str("x"))]);
        assert!(validate(&doc(vec![no_ph])).is_err());
    }
}
