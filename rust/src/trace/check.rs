//! Structural validator for exported Chrome trace-event JSON: schema of
//! every event, strict nesting of duration spans per `(pid, tid)` lane,
//! and required `bucket`/`bytes` attributes on collective spans. Shared
//! by the `trace-check` CLI binary (CI runs it on the smoke traces) and
//! `tests/trace_validity.rs`.
//!
//! Findings are [`Diagnostic`]s on the shared `analysis::diag` catalog:
//! `FS201` (malformed document), `FS202` (span missing required args),
//! `FS203` (partial overlap without nesting), `FS205` (counter-track
//! invariant: cumulative `wire.*` tracks must be non-decreasing over
//! time, and `mem.reserved`/`mem.allocated` samples must never go
//! negative). [`validate`] remains the fail-fast `Result` façade;
//! [`diagnostics`] accumulates every finding for the `--json` artifact
//! path.

use crate::analysis::diag::{codes, Diagnostic};
use crate::util::json::Json;

/// Collective spans that must carry both a `bucket` and a `bytes` arg.
const LOGICAL_COLLECTIVES: [&str; 2] = ["ag", "rs"];
/// Transport spans that must carry a `bytes` arg.
const TRANSPORT_OPS: [&str; 5] =
    ["all_gather", "reduce_scatter", "all_reduce", "broadcast", "all_to_all"];

/// Validate a parsed trace document. Returns `Err(reason)` on the first
/// structural violation (thin façade over [`diagnostics`]).
pub fn validate(doc: &Json) -> Result<(), String> {
    match diagnostics(doc).into_iter().next() {
        None => Ok(()),
        Some(d) => Err(d.message),
    }
}

/// Validate a parsed trace document, accumulating every structural
/// violation as a typed diagnostic. A malformed document (`FS201`)
/// short-circuits — nothing after it is trustworthy.
pub fn diagnostics(doc: &Json) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let Some(events) = doc.get("traceEvents").and_then(Json::as_arr) else {
        out.push(Diagnostic::error(
            codes::TRACE_MALFORMED,
            "document",
            "missing traceEvents array",
        ));
        return out;
    };
    if events.is_empty() {
        out.push(Diagnostic::error(
            codes::TRACE_MALFORMED,
            "document",
            "traceEvents is empty",
        ));
        return out;
    }

    // Hierarchical runs (`metadata.topology = "HxG"` with H > 1) must
    // tag every transport span with its wire tier (`intra`/`inter`).
    let hierarchical = doc
        .get("metadata")
        .and_then(|m| m.get("topology"))
        .and_then(Json::as_str)
        .and_then(|t| t.split('x').next().and_then(|h| h.parse::<u64>().ok()))
        .is_some_and(|h| h > 1);

    // (pid, tid) -> [(ts, dur, name)]
    let mut lanes: Vec<((u64, u64), Vec<(f64, f64, String)>)> = Vec::new();
    // (pid, counter name) -> [(ts, value)]
    let mut tracks: Vec<((u64, String), Vec<(f64, f64)>)> = Vec::new();
    for (i, e) in events.iter().enumerate() {
        let subject = format!("event {i}");
        let Some(ph) = e.get("ph").and_then(Json::as_str) else {
            out.push(Diagnostic::error(
                codes::TRACE_MALFORMED,
                subject,
                format!("event {i}: missing ph"),
            ));
            return out;
        };
        match ph {
            "M" => {
                if e.get("name").and_then(Json::as_str).is_none() {
                    out.push(Diagnostic::error(
                        codes::TRACE_MALFORMED,
                        subject,
                        format!("event {i}: metadata without name"),
                    ));
                    return out;
                }
            }
            "C" => {
                let ts = match require_num(e, i, "ts") {
                    Ok(t) => t,
                    Err(d) => {
                        out.push(d);
                        return out;
                    }
                };
                let Some(value) = e
                    .get("args")
                    .and_then(|a| a.get("value"))
                    .and_then(Json::as_f64)
                else {
                    out.push(Diagnostic::error(
                        codes::TRACE_MALFORMED,
                        subject,
                        format!("event {i}: counter without args.value"),
                    ));
                    return out;
                };
                let Some(name) = e.get("name").and_then(Json::as_str) else {
                    out.push(Diagnostic::error(
                        codes::TRACE_MALFORMED,
                        subject,
                        format!("event {i}: counter without name"),
                    ));
                    return out;
                };
                if matches!(name, "mem.reserved" | "mem.allocated") && value < 0.0 {
                    out.push(Diagnostic::error(
                        codes::COUNTER_TRACK,
                        name,
                        format!(
                            "event {i}: counter '{name}' sample {value} is negative"
                        ),
                    ));
                }
                let pid =
                    e.get("pid").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                let key = (pid, name.to_string());
                match tracks.iter_mut().find(|(k, _)| *k == key) {
                    Some((_, v)) => v.push((ts, value)),
                    None => tracks.push((key, vec![(ts, value)])),
                }
            }
            "X" => {
                let nums = (
                    require_num(e, i, "pid"),
                    require_num(e, i, "tid"),
                    require_num(e, i, "ts"),
                    require_num(e, i, "dur"),
                );
                let (pid, tid, ts, dur) = match nums {
                    (Ok(p), Ok(t), Ok(ts), Ok(d)) => (p as u64, t as u64, ts, d),
                    (Err(d), ..) | (_, Err(d), ..) | (_, _, Err(d), _) | (.., Err(d)) => {
                        out.push(d);
                        return out;
                    }
                };
                let Some(name) = e.get("name").and_then(Json::as_str) else {
                    out.push(Diagnostic::error(
                        codes::TRACE_MALFORMED,
                        subject,
                        format!("event {i}: span without name"),
                    ));
                    return out;
                };
                if e.get("cat").and_then(Json::as_str).is_none() {
                    out.push(Diagnostic::error(
                        codes::TRACE_MALFORMED,
                        subject,
                        format!("event {i}: span without cat"),
                    ));
                    return out;
                }
                let args = e.get("args");
                let has = |key: &str| args.and_then(|a| a.get(key)).is_some();
                if LOGICAL_COLLECTIVES.contains(&name) && (!has("bucket") || !has("bytes")) {
                    out.push(Diagnostic::error(
                        codes::TRACE_SPAN_ARGS,
                        name,
                        format!(
                            "event {i}: collective span '{name}' missing bucket/bytes args"
                        ),
                    ));
                }
                if TRANSPORT_OPS.contains(&name) && !has("bytes") {
                    out.push(Diagnostic::error(
                        codes::TRACE_SPAN_ARGS,
                        name,
                        format!("event {i}: transport span '{name}' missing bytes arg"),
                    ));
                }
                if hierarchical && TRANSPORT_OPS.contains(&name) && !has("tier") {
                    out.push(Diagnostic::error(
                        codes::TRACE_SPAN_ARGS,
                        name,
                        format!(
                            "event {i}: transport span '{name}' missing tier arg \
                             on hierarchical-topology run"
                        ),
                    ));
                }
                let key = (pid, tid);
                match lanes.iter_mut().find(|(k, _)| *k == key) {
                    Some((_, v)) => v.push((ts, dur, name.to_string())),
                    None => lanes.push((key, vec![(ts, dur, name.to_string())])),
                }
            }
            other => {
                out.push(Diagnostic::error(
                    codes::TRACE_MALFORMED,
                    subject,
                    format!("event {i}: unknown ph '{other}'"),
                ));
                return out;
            }
        }
    }

    // Cumulative counter tracks (`wire.*` running byte totals) must be
    // non-decreasing over time; a drop means samples were lost,
    // reordered across the shared clock, or double-reset.
    for ((pid, name), mut samples) in tracks {
        if !name.starts_with("wire.") {
            continue;
        }
        samples.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in samples.windows(2) {
            if w[1].1 < w[0].1 {
                out.push(Diagnostic::error(
                    codes::COUNTER_TRACK,
                    name.clone(),
                    format!(
                        "counter '{name}' (pid {pid}): value {} at ts {:.3} \
                         drops below {} — cumulative tracks must be \
                         non-decreasing",
                        w[1].1, w[1].0, w[0].1
                    ),
                ));
                break;
            }
        }
    }

    // Strict nesting per lane: after sorting by (start asc, dur desc),
    // every span must be fully contained in (or disjoint from) the
    // enclosing span on the stack.
    const EPS: f64 = 1e-3; // microseconds; absorbs ns -> us rounding
    for ((pid, tid), mut v) in lanes {
        v.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1)));
        let mut stack: Vec<(f64, f64, String)> = Vec::new(); // (start, end, name)
        for (ts, dur, name) in v {
            let end = ts + dur;
            while let Some(top) = stack.last() {
                if top.1 <= ts + EPS {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(top) = stack.last() {
                if end > top.1 + EPS {
                    out.push(Diagnostic::error(
                        codes::TRACE_OVERLAP,
                        format!("lane ({pid},{tid})"),
                        format!(
                            "lane ({pid},{tid}): span '{name}' [{ts:.3},{end:.3}] \
                             overlaps '{}' ending at {:.3} without nesting",
                            top.2, top.1
                        ),
                    ));
                }
            }
            stack.push((ts, end, name));
        }
    }
    out
}

fn require_num(e: &Json, i: usize, key: &str) -> Result<f64, Diagnostic> {
    e.get(key).and_then(Json::as_f64).ok_or_else(|| {
        Diagnostic::error(
            codes::TRACE_MALFORMED,
            format!("event {i}"),
            format!("event {i}: missing numeric '{key}'"),
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(pid: u64, tid: u64, ts: f64, dur: f64, name: &str) -> Json {
        Json::obj(vec![
            ("ph", Json::str("X")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("ts", Json::num(ts)),
            ("dur", Json::num(dur)),
            ("name", Json::str(name)),
            ("cat", Json::str("comm")),
            ("args", Json::obj(vec![("bytes", Json::num(8.0))])),
        ])
    }

    fn doc(events: Vec<Json>) -> Json {
        Json::obj(vec![("traceEvents", Json::Arr(events))])
    }

    fn counter(ts: f64, name: &str, value: f64) -> Json {
        Json::obj(vec![
            ("ph", Json::str("C")),
            ("pid", Json::num(4.0)),
            ("tid", Json::num(0.0)),
            ("ts", Json::num(ts)),
            ("name", Json::str(name)),
            ("args", Json::obj(vec![("value", Json::num(value))])),
        ])
    }

    #[test]
    fn accepts_nested_and_sequential() {
        let d = doc(vec![
            span(0, 2, 0.0, 100.0, "outer"),
            span(0, 2, 10.0, 20.0, "inner"),
            span(0, 2, 200.0, 50.0, "later"),
            span(1, 2, 5.0, 500.0, "other-lane"),
        ]);
        validate(&d).unwrap();
        assert!(diagnostics(&d).is_empty());
    }

    #[test]
    fn rejects_partial_overlap() {
        let d = doc(vec![
            span(0, 2, 0.0, 100.0, "a"),
            span(0, 2, 50.0, 100.0, "b"),
        ]);
        assert!(validate(&d).is_err());
        let ds = diagnostics(&d);
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].code, codes::TRACE_OVERLAP);
    }

    #[test]
    fn rejects_collective_without_bucket() {
        let d = doc(vec![span(0, 2, 0.0, 1.0, "ag")]);
        let err = validate(&d).unwrap_err();
        assert!(err.contains("bucket"), "{err}");
        assert_eq!(diagnostics(&d)[0].code, codes::TRACE_SPAN_ARGS);
    }

    #[test]
    fn hierarchical_topology_demands_tier_attr() {
        // The same untagged transport span passes on a flat doc...
        let flat = doc(vec![span(0, 2, 0.0, 1.0, "all_gather")]);
        validate(&flat).unwrap();
        // ...but fails once metadata declares a multi-host topology.
        let hier = Json::obj(vec![
            ("traceEvents", Json::Arr(vec![span(0, 2, 0.0, 1.0, "all_gather")])),
            ("metadata", Json::obj(vec![("topology", Json::str("2x4"))])),
        ]);
        let err = validate(&hier).unwrap_err();
        assert!(err.contains("tier"), "{err}");
        assert_eq!(diagnostics(&hier)[0].code, codes::TRACE_SPAN_ARGS);
        // A single-host topology ("1x8") stays exempt.
        let single = Json::obj(vec![
            ("traceEvents", Json::Arr(vec![span(0, 2, 0.0, 1.0, "all_gather")])),
            ("metadata", Json::obj(vec![("topology", Json::str("1x8"))])),
        ]);
        validate(&single).unwrap();
        // Tagged spans satisfy the hierarchical requirement.
        let tagged = Json::obj(vec![
            ("ph", Json::str("X")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(2.0)),
            ("ts", Json::num(0.0)),
            ("dur", Json::num(1.0)),
            ("name", Json::str("all_gather")),
            ("cat", Json::str("comm")),
            (
                "args",
                Json::obj(vec![
                    ("bytes", Json::num(8.0)),
                    ("tier", Json::str("intra")),
                ]),
            ),
        ]);
        let ok = Json::obj(vec![
            ("traceEvents", Json::Arr(vec![tagged])),
            ("metadata", Json::obj(vec![("topology", Json::str("2x4"))])),
        ]);
        validate(&ok).unwrap();
    }

    #[test]
    fn accepts_monotonic_wire_and_shrinking_memory() {
        // wire.* totals climb; mem gauges may shrink (frees) but not
        // go negative.
        let d = doc(vec![
            counter(0.0, "wire.payload", 0.0),
            counter(10.0, "wire.payload", 1024.0),
            counter(20.0, "wire.payload", 1024.0),
            counter(0.0, "mem.reserved", 4096.0),
            counter(10.0, "mem.reserved", 512.0),
        ]);
        validate(&d).unwrap();
        assert!(diagnostics(&d).is_empty());
    }

    #[test]
    fn rejects_nonmonotonic_wire_counter() {
        // Samples arrive out of value order even after ts sorting.
        let d = doc(vec![
            counter(0.0, "wire.payload", 2048.0),
            counter(10.0, "wire.payload", 1024.0),
        ]);
        let err = validate(&d).unwrap_err();
        assert!(err.contains("non-decreasing"), "{err}");
        let ds = diagnostics(&d);
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].code, codes::COUNTER_TRACK);
        // Tracks on different pids are independent: the same values on
        // two pids are two (trivially monotonic) one-sample tracks.
        let split = doc(vec![
            Json::obj(vec![
                ("ph", Json::str("C")),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(0.0)),
                ("ts", Json::num(0.0)),
                ("name", Json::str("wire.payload")),
                ("args", Json::obj(vec![("value", Json::num(2048.0))])),
            ]),
            counter(10.0, "wire.payload", 1024.0),
        ]);
        validate(&split).unwrap();
    }

    #[test]
    fn rejects_negative_memory_sample() {
        let d = doc(vec![
            counter(0.0, "mem.reserved", 1024.0),
            counter(10.0, "mem.allocated", -64.0),
        ]);
        let err = validate(&d).unwrap_err();
        assert!(err.contains("negative"), "{err}");
        let ds = diagnostics(&d);
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].code, codes::COUNTER_TRACK);
    }

    #[test]
    fn rejects_empty_and_malformed() {
        assert!(validate(&doc(vec![])).is_err());
        assert!(validate(&Json::obj(vec![])).is_err());
        let no_ph = Json::obj(vec![("name", Json::str("x"))]);
        assert!(validate(&doc(vec![no_ph])).is_err());
        for d in [doc(vec![]), Json::obj(vec![])] {
            assert_eq!(diagnostics(&d)[0].code, codes::TRACE_MALFORMED);
        }
    }
}
