//! Symbolic one-iteration simulator: replays an FSDP training step of a
//! model preset through the sharding format, fabric cost model, and
//! caching-allocator simulator of a given system. Every Fig-8/Fig-9 row
//! and both tables are produced by this function — the differences between
//! systems *emerge* from their sharding formats and execution behaviors,
//! none of the headline numbers are hard-coded.
//!
//! Timeline model (per direction):
//! communication for bucket l+1 prefetches during compute of bucket l
//! (the standard FSDP overlap); copies that a system requires serialize
//! with its collective on the comm stream; FSDP1-style blocking copies
//! stall both streams (the "communication bubble" of §6.1). Exposed comm
//! is whatever the compute of the neighboring bucket could not hide.

use anyhow::Result;

use crate::comm::{CopyKind, Fabric};
use crate::config::presets::{ModelPreset, ParamGroup};
use crate::config::{OptimKind, ParallelConfig};
use crate::memory::{CachingAllocator, FreePolicy};
use crate::planner::{self, TensorDecl};
use crate::quant::CommPrecision;
use crate::util::round_up;

/// GPU under simulation (paper: H800).
#[derive(Debug, Clone)]
pub struct GpuSpec {
    /// Peak dense bf16 FLOP/s.
    pub flops: f64,
    /// Achievable MFU for dense transformer layers.
    pub mfu_dense: f64,
    /// Achievable MFU for sparse (MoE) layers (token imbalance, small
    /// per-expert GEMMs).
    pub mfu_moe: f64,
    /// HBM capacity (bytes).
    pub hbm: u64,
    /// HBM bandwidth (bytes/s) — bounds element-wise optimizer steps.
    pub hbm_bw: f64,
}

impl GpuSpec {
    pub fn h800() -> GpuSpec {
        GpuSpec {
            flops: 979e12,
            mfu_dense: 0.42,
            mfu_moe: 0.27,
            hbm: 80 * (1 << 30),
            hbm_bw: 3.35e12,
        }
    }
}

/// How a system lays out a communication bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardingFormat {
    /// Concatenate then split element-wise at exact m-way boundaries
    /// (DeepSpeed / FSDP1). No padding, but boundaries fall anywhere.
    ElementWiseConcat,
    /// Per-parameter Shard(0) DTensors (FSDP2): each tensor's dim-0 is
    /// padded up to a multiple of m.
    PerParamShard0,
    /// Concatenated buffer with per-tensor row padding so shards fall on
    /// row boundaries (Megatron-FSDP): same padding arithmetic as
    /// PerParamShard0, zero-copy access.
    ConcatPadRows,
    /// veScale: planner-assigned layout at the requested granularity.
    Planned,
}

/// Execution behavior of one FSDP system (see `baselines/`).
#[derive(Debug, Clone)]
pub struct SystemBehavior {
    pub name: &'static str,
    pub format: ShardingFormat,
    /// NCCL buffer alignment enforced?
    pub aligned: bool,
    /// One collective per parameter (DeepSpeed) vs per bucket.
    pub per_param_collectives: bool,
    /// Interleaved Copy-Out after AG / Copy-In before RS (FSDP2).
    pub copy_in_out: bool,
    /// Copies stall the comm stream (FSDP1 bubbles).
    pub copy_blocks_comm: bool,
    /// record_stream-style deferred frees vs deterministic.
    pub free_policy: FreePolicy,
    /// Batched segment allocation (DBuffer) vs per-buffer eager alloc.
    pub batched_alloc: bool,
    /// Keep low-precision (bf16) param buffers resident across iterations
    /// (Megatron's mixed-precision design, +24% on LLaMA-3 per §6.1).
    pub persist_lp_buffers: bool,
    /// RaggedShard granularity (elements) when format == Planned.
    pub granularity: u64,
    /// Wire dtype of parameter/gradient collectives: bf16 is the
    /// production default every baseline ships; `Q8` additionally pays
    /// the per-block scale + packing overhead — so the predicted comm
    /// time matches what the numeric engine's quantized path measures.
    pub comm_precision: CommPrecision,
}

/// Result of simulating one training iteration on one device.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub system: &'static str,
    /// Seconds per iteration (simulated).
    pub step_time: f64,
    /// Compute seconds (fwd+bwd) per iteration.
    pub compute_time: f64,
    /// Collective seconds, total (before overlap).
    pub comm_time: f64,
    /// Serialized collective seconds attributed to the intra-host
    /// (NVLink) tier. On hierarchical fabrics the two tiers pipeline, so
    /// `intra + inter >= comm_time` by design; on flat fabrics every op
    /// lands on exactly one tier and the pair partitions `comm_time`.
    pub intra_comm_s: f64,
    /// Serialized collective seconds attributed to the inter-host (IB)
    /// tier — the term hierarchy shrinks g-fold for ReduceScatter.
    pub inter_comm_s: f64,
    /// Comm seconds not hidden by compute.
    pub exposed_comm: f64,
    /// Copy seconds (interleaved copy-in/out, blocking copies).
    pub copy_time: f64,
    /// Optimizer seconds.
    pub optim_time: f64,
    /// Peak reserved bytes on the device.
    pub peak_reserved: u64,
    /// Peak allocated bytes.
    pub peak_allocated: u64,
    /// Ran out of memory?
    pub oom: bool,
    /// Padding overhead ratio (extra elements / real elements).
    pub padding_ratio: f64,
    /// Aggregate tokens/s across all devices.
    pub tokens_per_sec: f64,
    /// Model FLOPs utilization.
    pub mfu: f64,
    /// Planner wall-clock (s), veScale only.
    pub plan_time: f64,
}

/// Padded per-device shard elements for one bucket under a format.
fn padded_shard_elems(
    group: &ParamGroup,
    m: usize,
    format: ShardingFormat,
    granularity: u64,
) -> (u64, f64) {
    let real: u64 = group.numel();
    match format {
        ShardingFormat::ElementWiseConcat => {
            let s = real.div_ceil(m as u64);
            (s, 0.0)
        }
        ShardingFormat::PerParamShard0 | ShardingFormat::ConcatPadRows => {
            // pad each tensor's dim-0 to a multiple of m
            let mut total = 0u64;
            for p in &group.params {
                let rows = p.shape[0] as u64;
                let row = p.row_size();
                total += round_up(rows, m as u64) * row;
            }
            let s = total / m as u64;
            (s, (total - real) as f64 / real as f64)
        }
        ShardingFormat::Planned => {
            let decls: Vec<TensorDecl> = group
                .params
                .iter()
                .map(|p| {
                    // granularity never exceeds the tensor (tiny tensors
                    // shard whole)
                    let g = granularity.min(p.numel()).max(1);
                    TensorDecl::new(&p.name, p.numel(), g)
                })
                .collect();
            match planner::plan(&decls, m, 4) {
                Ok(layout) => {
                    let s = layout.shard_size;
                    (s, layout.padding_ratio())
                }
                Err(_) => (real.div_ceil(m as u64), 0.0),
            }
        }
    }
}

/// Simulate one training iteration. `tokens_per_dev` is the per-device
/// batch in tokens (paper weak scaling: constant per device).
pub fn simulate_step(
    preset: &ModelPreset,
    parallel: &ParallelConfig,
    optim: OptimKind,
    tokens_per_dev: u64,
    fabric: &Fabric,
    gpu: &GpuSpec,
    sys: &SystemBehavior,
) -> Result<StepReport> {
    let m = parallel.fsdp;
    let ep = parallel.ep.max(1);
    let plan_t0 = std::time::Instant::now();

    // ---- FSDP wrapping: split huge layers into sub-buckets ----
    // Production wrapping never gathers a 12B-parameter MoE layer whole;
    // each expert (or a slice of experts) is its own fully_shard unit.
    // Cap the gathered working set per bucket.
    const MAX_BUCKET_ELEMS: u64 = 256 << 20; // 512 MiB bf16 gathered
    // Hierarchical fabrics: a sub-bucket below this floor is inter-host
    // launch-dominated (planner::latency_bucket_floor), so the splitter
    // folds a trailing runt into its predecessor — the bucket may then
    // exceed MAX_BUCKET_ELEMS by up to the floor, a deliberate trade of
    // working set for one fewer NIC doorbell. Flat fabrics get floor 0
    // and the historical split, bit-stable.
    let latency_floor = planner::latency_bucket_floor(fabric, m);
    let mut groups: Vec<ParamGroup> = Vec::new();
    let mut compute_elems: Vec<u64> = Vec::new(); // pre-EP numel (FLOPs basis)
    for g in &preset.groups {
        // EP shards expert parameters across the ep group *before* FSDP
        // (Fig 5 composition); model by dividing expert tensor rows by ep.
        // EP moves *parameters* (and their FSDP comm) off-device, but the
        // routed tokens keep per-device FLOPs constant — so compute is
        // accounted at the pre-EP size.
        let orig_numel = g.numel();
        let g = if ep > 1 { shrink_experts(g, ep) } else { g.clone() };
        let comp_scale = orig_numel as f64 / g.numel().max(1) as f64;
        if g.numel() <= MAX_BUCKET_ELEMS || g.params.len() == 1 {
            compute_elems.push((g.numel() as f64 * comp_scale) as u64);
            groups.push(g);
            continue;
        }
        let split_start = groups.len();
        let mut cur = ParamGroup { name: g.name.clone(), params: Vec::new() };
        for p in g.params {
            if cur.numel() + p.numel() > MAX_BUCKET_ELEMS && !cur.params.is_empty() {
                compute_elems.push((cur.numel() as f64 * comp_scale) as u64);
                groups.push(std::mem::replace(
                    &mut cur,
                    ParamGroup { name: g.name.clone(), params: Vec::new() },
                ));
            }
            cur.params.push(p);
        }
        if !cur.params.is_empty() {
            let tail_elems = (cur.numel() as f64 * comp_scale) as u64;
            if cur.numel() < latency_floor && groups.len() > split_start {
                // launch-dominated tail: fold into the previous sub-bucket
                groups.last_mut().unwrap().params.append(&mut cur.params);
                *compute_elems.last_mut().unwrap() += tail_elems;
            } else {
                compute_elems.push(tail_elems);
                groups.push(cur);
            }
        }
    }

    // ---- per-bucket shard sizes and padding ----
    let mut shard_elems: Vec<u64> = Vec::with_capacity(groups.len());
    let mut real_elems: Vec<u64> = Vec::with_capacity(groups.len());
    let mut pad_total = 0.0f64;
    let mut real_total = 0u64;
    for group in &groups {
        let (s, _ratio) = padded_shard_elems(group, m, sys.format, sys.granularity);
        shard_elems.push(s);
        real_elems.push(group.numel());
        real_total += group.numel();
        pad_total += (s * m as u64) as f64 - group.numel() as f64;
    }
    let padding_ratio = pad_total / real_total as f64;
    let plan_time = plan_t0.elapsed().as_secs_f64();

    // ---- per-bucket times ----
    let moe = preset.moe.is_some();
    let mfu = if moe { gpu.mfu_moe } else { gpu.mfu_dense };
    let active_frac = preset.active_params() / preset.total_params() as f64;
    let n_groups = groups.len();
    let mut ag = vec![0.0f64; n_groups]; // forward AllGather chain (incl. serialized copies)
    let mut rs = vec![0.0f64; n_groups]; // backward ReduceScatter chain
    let mut fwd_compute = vec![0.0f64; n_groups];
    let mut copy_time = 0.0f64;
    let mut comm_time = 0.0f64;
    let mut intra_comm_s = 0.0f64;
    let mut inter_comm_s = 0.0f64;

    for (i, g) in groups.iter().enumerate() {
        // wire bytes follow the system's comm precision (payload + quant
        // scales + packing pad), not a hardcoded bf16 assumption
        let bytes = sys.comm_precision.wire_volume(shard_elems[i]).total();
        let (ag_t, rs_t) = if sys.per_param_collectives {
            // DeepSpeed: one (unaligned) collective per parameter
            let n = g.params.len() as u64;
            let per = bytes / n.max(1);
            (
                g.params.len() as f64 * fabric.all_gather_time(m, per, sys.aligned),
                g.params.len() as f64 * fabric.reduce_scatter_time(m, per, sys.aligned),
            )
        } else {
            (
                fabric.all_gather_time(m, bytes, sys.aligned),
                fabric.reduce_scatter_time(m, bytes, sys.aligned),
            )
        };
        comm_time += ag_t + rs_t;

        // two-tier attribution of the same collectives (per-param systems
        // pay the tier launches once per parameter, like their headline)
        let (n_coll, per) = if sys.per_param_collectives {
            let n = g.params.len().max(1) as u64;
            (n as f64, bytes / n)
        } else {
            (1.0, bytes)
        };
        let (agi, age) = fabric.tier_times("all_gather", m, per, sys.aligned);
        let (rsi, rse) = fabric.tier_times("reduce_scatter", m, per, sys.aligned);
        intra_comm_s += n_coll * (agi + rsi);
        inter_comm_s += n_coll * (age + rse);

        // copies
        let full_bytes = shard_elems[i] * m as u64 * 2;
        let (mut ag_chain, mut rs_chain) = (ag_t, rs_t);
        if sys.copy_in_out {
            // FSDP2: interleaved Copy-Out after AG, Copy-In before RS.
            // Shard(0) params copy at row-interleave speed; a system would
            // use Shard(1) only to dodge padding (Table 1's worse column).
            let out_t = fabric.copy_time(full_bytes, CopyKind::InterleavedRows);
            let in_t = fabric.copy_time(full_bytes, CopyKind::InterleavedRows);
            copy_time += out_t + in_t;
            ag_chain += out_t;
            rs_chain += in_t;
        }
        if sys.copy_blocks_comm {
            // FSDP1: flat-param copies stall NCCL progress (bubble)
            let b = fabric.copy_time(full_bytes, CopyKind::Contiguous);
            copy_time += 2.0 * b;
            ag_chain += b;
            rs_chain += b;
        }
        ag[i] = ag_chain;
        rs[i] = rs_chain;

        // per-bucket forward compute: proportional to the bucket's share
        // of *active* parameters
        let active_params = compute_elems[i] as f64 * active_frac;
        let flops = 2.0 * tokens_per_dev as f64 * active_params;
        fwd_compute[i] = flops / (gpu.flops * mfu);
    }

    // EP all-to-all (token exchange) per MoE layer, fwd + bwd
    let mut a2a_time = 0.0;
    if ep > 1 && moe {
        let d = preset.d_model as u64;
        let topk = preset.moe.as_ref().map(|x| x.top_k as u64).unwrap_or(1);
        let bytes = tokens_per_dev * d * 2 * topk;
        a2a_time = 4.0 * preset.n_layers as f64 * fabric.all_to_all_time(ep, bytes);
        comm_time += a2a_time;
        let (a2a_i, a2a_e) = fabric.tier_times("all_to_all", ep, bytes, true);
        intra_comm_s += 4.0 * preset.n_layers as f64 * a2a_i;
        inter_comm_s += 4.0 * preset.n_layers as f64 * a2a_e;
    }

    // ---- overlap timeline ----
    // forward: AG_0 exposed; then per bucket, comm for the next bucket
    // hides under this bucket's compute.
    let mut fwd = ag[0];
    for i in 0..n_groups {
        let next_comm = if i + 1 < n_groups { ag[i + 1] } else { 0.0 };
        fwd += fwd_compute[i].max(next_comm);
    }
    // backward: compute is ~2x fwd per bucket; RS of bucket i hides under
    // compute of bucket i-1 (reverse order); the last RS is exposed.
    let mut bwd = 0.0;
    for i in (0..n_groups).rev() {
        let prev_comm = if i > 0 { rs[i] } else { 0.0 };
        bwd += (2.0 * fwd_compute[i]).max(prev_comm);
    }
    bwd += rs[0];
    let compute_time: f64 = fwd_compute.iter().sum::<f64>() * 3.0;
    let exposed_comm = (fwd + bwd - compute_time - a2a_time).max(0.0);

    // optimizer: element-wise pass over master + states (HBM-bound) or
    // Muon's NS + redistributes
    let shard_total: u64 = shard_elems.iter().sum();
    let optim_bytes =
        shard_total as f64 * (4.0 + 4.0 + optim.state_bytes_per_param());
    let mut optim_time = optim_bytes / gpu.hbm_bw;
    if optim == OptimKind::Muon {
        // gather/scatter each 2-D matrix across the group, amortized via
        // round-robin roots: ~2x param bytes over the wire per step / m
        let bytes = (real_total / m as u64) * 4 * 2;
        optim_time += fabric.all_gather_time(m, bytes, true);
        // NS flops: 15 matmuls of d^3-ish per matrix — bounded by compute
        let ns_flops = 15.0 * (preset.d_model as f64).powi(3) * preset.n_layers as f64;
        optim_time += ns_flops / (gpu.flops * 0.3) / m as f64;
    }

    if sys.persist_lp_buffers {
        // Megatron keeps bf16 buffers resident; syncing them with the
        // fp32 master costs an extra contiguous copy pass each step —
        // the "slightly ahead" dense margin of §6.1.
        optim_time += (shard_total * 2) as f64 / gpu.hbm_bw
            + fabric.copy_time(shard_total * 2, CopyKind::Contiguous);
    }

    // device-free stalls under memory pressure are added after the memory
    // replay below.
    let mut step_time = fwd + bwd + a2a_time + optim_time;

    // ---- memory replay ----
    let mut alloc = CachingAllocator::new(sys.free_policy, gpu.hbm);
    let mut oom = false;
    let groups = &groups;
    let replay = |alloc: &mut CachingAllocator| -> Result<()> {
        // persistent state: fp32 master shard + optimizer states (+ bf16
        // persistent buffers for Megatron)
        let master: Vec<u64> = shard_elems.iter().map(|&s| s * 4).collect();
        let opt_bytes: Vec<u64> = shard_elems
            .iter()
            .map(|&s| ((s as f64 * optim.state_bytes_per_param()) as u64).max(1))
            .collect();
        if sys.batched_alloc {
            alloc.alloc_batch(&master)?;
            alloc.alloc_batch(&opt_bytes)?;
        } else {
            for &b in &master {
                alloc.alloc(b)?;
            }
            for &b in &opt_bytes {
                alloc.alloc(b)?;
            }
        }
        if sys.persist_lp_buffers {
            // resident bf16 param + grad shards
            let lp: Vec<u64> = shard_elems.iter().map(|&s| s * 2 * 2).collect();
            alloc.alloc_batch(&lp)?;
        }

        // transient bucket working set: gathered bf16 params (+ FSDP2's
        // copy-out target tensors, + backward grad buffers). A prefetch
        // window of 2 buckets is live at any time.
        let gather_bucket = |alloc: &mut CachingAllocator,
                             i: usize,
                             with_grads: bool|
         -> Result<Vec<crate::memory::BlockId>> {
            let full = shard_elems[i] * m as u64 * 2; // bf16 gathered bucket
            let mut ids = vec![alloc.alloc(full)?];
            if sys.copy_in_out {
                // FSDP2: interleaved copy-out materializes each parameter
                // as its own eagerly-allocated full tensor — a second
                // full-bucket working set
                for p in &groups[i].params {
                    ids.push(alloc.alloc(p.numel() * 2)?);
                }
            }
            if with_grads {
                ids.push(alloc.alloc(full)?); // full gradient buffer
            }
            Ok(ids)
        };
        let free_all = |alloc: &mut CachingAllocator,
                        ids: Vec<crate::memory::BlockId>|
         -> Result<()> {
            for id in ids {
                alloc.free(id)?;
            }
            Ok(())
        };

        // activations: one checkpointed input per layer (full activation
        // checkpointing — standard at these scales), bf16; spread evenly
        // over the buckets so the total is layer-count-invariant. Large
        // per-device batches run as gradient-accumulation microbatches
        // (<= 16K tokens live at once), as production training does.
        let mb_tokens = tokens_per_dev.min(16384);
        let act_total = mb_tokens * preset.d_model as u64 * 2 * preset.n_layers as u64;
        let act_per_layer = (act_total / n_groups as u64).max(1);
        // record_stream hazard: deferred frees become reusable only when
        // the comm stream's events complete — a few buckets later, not at
        // iteration end. Model the lag as one event-sync every 4 buckets
        // (deterministic policies are unaffected; sync is then a no-op).
        const EVENT_LAG: usize = 4;
        let mut act_blocks = Vec::new();
        let mut window: Vec<Vec<crate::memory::BlockId>> = Vec::new();
        for i in 0..n_groups {
            window.push(gather_bucket(alloc, i, false)?);
            act_blocks.push(alloc.alloc(act_per_layer)?);
            if window.len() > 2 {
                free_all(alloc, window.remove(0))?; // reshard-after-forward
            }
            if i % EVENT_LAG == EVENT_LAG - 1 {
                alloc.sync();
            }
        }
        while let Some(ids) = window.pop() {
            free_all(alloc, ids)?;
        }
        // backward (reverse order), with full gradient buffers
        for i in (0..n_groups).rev() {
            window.push(gather_bucket(alloc, i, true)?);
            alloc.free(act_blocks[i])?;
            if window.len() > 2 {
                free_all(alloc, window.remove(0))?;
            }
            if i % EVENT_LAG == 0 {
                alloc.sync();
            }
        }
        while let Some(ids) = window.pop() {
            free_all(alloc, ids)?;
        }
        alloc.sync();
        Ok(())
    };
    // two iterations: steady-state peak (first iteration warms the cache)
    for _ in 0..2 {
        if replay(&mut alloc).is_err() {
            oom = true;
            break;
        }
    }
    // device frees stall the device (§6.1: "device frees that synchronize
    // with the driver and stall training")
    step_time += alloc.device_frees as f64 * 3e-3;

    let total_tokens = (tokens_per_dev * parallel.total_devices() as u64) as f64;
    let tokens_per_sec = if oom { 0.0 } else { total_tokens / step_time };
    let mfu_measured = if oom {
        0.0
    } else {
        preset.flops_per_token() * tokens_per_dev as f64 / (step_time * gpu.flops)
    };

    Ok(StepReport {
        system: sys.name,
        step_time,
        compute_time,
        comm_time,
        intra_comm_s,
        inter_comm_s,
        exposed_comm,
        copy_time,
        optim_time,
        peak_reserved: alloc.peak_reserved,
        peak_allocated: alloc.peak_allocated,
        oom,
        padding_ratio,
        tokens_per_sec,
        mfu: mfu_measured,
        plan_time,
    })
}

/// EP composition: expert tensors are Shard(0)-sharded over the EP group
/// before FSDP sees them (Fig 5) — divide the expert dim by ep.
fn shrink_experts(group: &ParamGroup, ep: usize) -> ParamGroup {
    let mut g = group.clone();
    for p in g.params.iter_mut() {
        if p.name.contains("expert") && p.shape[0] >= ep {
            p.shape[0] /= ep;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::config::presets;

    fn quick(preset: &ModelPreset, sys: &SystemBehavior, m: usize) -> StepReport {
        simulate_step(
            preset,
            &ParallelConfig::fsdp_only(m),
            OptimKind::AdamW,
            4096,
            &Fabric::h800(),
            &GpuSpec::h800(),
            sys,
        )
        .unwrap()
    }

    #[test]
    fn vescale_beats_fsdp2_on_moe() {
        let preset = presets::gptoss120b();
        let ve = quick(&preset, &baselines::vescale(1), 128);
        let f2 = quick(&preset, &baselines::fsdp2(), 128);
        assert!(!ve.oom);
        assert!(ve.tokens_per_sec > f2.tokens_per_sec,
                "ve {} vs fsdp2 {}", ve.tokens_per_sec, f2.tokens_per_sec);
        assert!(ve.peak_reserved < f2.peak_reserved);
    }

    #[test]
    fn fsdp2_ooms_gptoss_at_256() {
        // paper §6.1: 128 experts over 256 devices double the AG buffer
        let preset = presets::gptoss120b();
        let f2 = quick(&preset, &baselines::fsdp2(), 256);
        let ve = quick(&preset, &baselines::vescale(1), 256);
        assert!(f2.padding_ratio > 0.3, "padding {}", f2.padding_ratio);
        assert!(!ve.oom, "veScale must not OOM");
        assert!(
            f2.oom || f2.peak_reserved > ve.peak_reserved * 3 / 2,
            "fsdp2 reserved {} ve {}",
            f2.peak_reserved,
            ve.peak_reserved
        );
    }

    #[test]
    fn megatron_padding_inflation_on_fused_moe() {
        let preset = presets::gptoss120b();
        let mg = quick(&preset, &baselines::megatron(), 256);
        let ve = quick(&preset, &baselines::vescale(1), 256);
        assert!(mg.padding_ratio > ve.padding_ratio + 0.2,
                "mega {} ve {}", mg.padding_ratio, ve.padding_ratio);
    }

    #[test]
    fn copy_overhead_only_fsdp2() {
        let preset = presets::llama70b();
        let f2 = quick(&preset, &baselines::fsdp2(), 128);
        let ve = quick(&preset, &baselines::vescale(1), 128);
        assert!(f2.copy_time > 0.0);
        assert_eq!(ve.copy_time, 0.0);
    }

    #[test]
    fn deepspeed_fragmentation_slows_comm() {
        let preset = presets::llama70b();
        let ds = quick(&preset, &baselines::deepspeed(), 128);
        let ve = quick(&preset, &baselines::vescale(1), 128);
        assert!(ds.comm_time > ve.comm_time, "ds {} ve {}", ds.comm_time, ve.comm_time);
    }

    #[test]
    fn dense_margin_smaller_than_moe_margin() {
        // paper: 5% on LLaMA (slightly ahead of Megatron) vs 11-66% on MoE
        let dense = presets::llama70b();
        let moe = presets::gptoss120b();
        let margin = |preset: &ModelPreset| {
            let ve = quick(preset, &baselines::vescale(1), 128);
            assert!(!ve.oom);
            let best_base = baselines::all_baselines()
                .iter()
                .map(|b| quick(preset, b, 128).tokens_per_sec)
                .fold(0.0f64, f64::max);
            ve.tokens_per_sec / best_base
        };
        let md = margin(&dense);
        let mm = margin(&moe);
        assert!(md >= 1.0, "veScale must win or tie on dense ({md})");
        assert!(mm > md, "MoE margin {mm} should exceed dense {md}");
        // vs the non-zero-copy baselines the dense margin is several %
        let ve = quick(&dense, &baselines::vescale(1), 128);
        let f2 = quick(&dense, &baselines::fsdp2(), 128);
        assert!(ve.tokens_per_sec > f2.tokens_per_sec * 1.02,
                "ve {} f2 {}", ve.tokens_per_sec, f2.tokens_per_sec);
    }

    #[test]
    fn wire_precision_drives_comm_time() {
        let preset = presets::llama70b();
        let mk = |prec: CommPrecision| {
            let mut sys = baselines::vescale(1);
            sys.comm_precision = prec;
            quick(&preset, &sys, 128)
        };
        let full = mk(CommPrecision::F32);
        let bf = mk(CommPrecision::Bf16);
        let q8 = mk(CommPrecision::Q8 { block: 64 });
        assert!(
            full.comm_time > bf.comm_time * 1.8,
            "f32 {} bf16 {}",
            full.comm_time,
            bf.comm_time
        );
        assert!(bf.comm_time > q8.comm_time * 1.5, "bf16 {} q8 {}", bf.comm_time, q8.comm_time);
        // the per-block scale overhead is accounted: coarser blocks ship
        // fewer scale bytes
        let q8_coarse = mk(CommPrecision::Q8 { block: 1024 });
        assert!(q8.comm_time > q8_coarse.comm_time);
    }

    #[test]
    fn hierarchical_fabric_shrinks_inter_comm() {
        let preset = presets::llama70b();
        let run = |f: &Fabric| {
            simulate_step(
                &preset,
                &ParallelConfig::fsdp_only(128),
                OptimKind::AdamW,
                4096,
                f,
                &GpuSpec::h800(),
                &baselines::vescale(1),
            )
            .unwrap()
        };
        let rf = run(&Fabric::h800());
        let rh = run(&Fabric::by_name("h800:16x8").unwrap());
        // flat 128-rank groups charge every second to the inter tier
        assert_eq!(rf.intra_comm_s, 0.0);
        assert!(rf.inter_comm_s > 0.0);
        // the intra-host pre-reduce collapses 8 contributions before the
        // NIC, so hierarchy's inter-tier seconds shrink vs the flat ring
        assert!(
            rh.inter_comm_s < rf.inter_comm_s * 0.7,
            "hier inter {} flat inter {}",
            rh.inter_comm_s,
            rf.inter_comm_s
        );
        assert!(rh.intra_comm_s > 0.0);
        // and the headline step gets faster, not slower
        assert!(rh.step_time <= rf.step_time * 1.001);
    }

    #[test]
    fn weak_scaling_flat() {
        // step time ~constant as devices grow with fixed tokens/device
        let preset = presets::moe_internal(800.0);
        let t1 = quick(&preset, &baselines::vescale(1), 1024).step_time;
        let t2 = quick(&preset, &baselines::vescale(1), 2048).step_time;
        assert!((t2 - t1).abs() / t1 < 0.15, "weak scaling broke: {t1} vs {t2}");
    }

    #[test]
    fn ep_reduces_fsdp_comm() {
        let preset = presets::moe_internal(800.0);
        let no_ep = simulate_step(
            &preset,
            &ParallelConfig { fsdp: 1024, replicas: 1, ep: 1 },
            OptimKind::AdamW,
            2048,
            &Fabric::h800(),
            &GpuSpec::h800(),
            &baselines::vescale(1),
        )
        .unwrap();
        let with_ep = simulate_step(
            &preset,
            &ParallelConfig { fsdp: 1024, replicas: 1, ep: 8 },
            OptimKind::AdamW,
            2048,
            &Fabric::h800(),
            &GpuSpec::h800(),
            &baselines::vescale(1),
        )
        .unwrap();
        assert!(with_ep.exposed_comm < no_ep.exposed_comm,
                "ep {} vs {}", with_ep.exposed_comm, no_ep.exposed_comm);
    }
}
