//! The FSDP engine layer.
//!
//! [`spec`] is the front door: the declarative `fully_shard`-style
//! [`ModelSpec`] wrap graph with per-group policies, optimizer bindings,
//! and mesh/fabric choices, consumed by [`engine::FsdpEngine::from_spec`].
//!
//! Two engines, one abstraction:
//!
//! * [`sim`] — the *symbolic* engine: replays one training iteration of a
//!   model preset over the fabric + allocator models and returns the
//!   step-time / memory / padding report. All Fig-8/9 and Table-1/2
//!   numbers come from here; each baseline system is a
//!   [`sim::SystemBehavior`] (see `baselines/`).
//! * [`engine`] — the *numeric* engine: real parameter shards in DBuffers,
//!   real collectives, real optimizer math, compute supplied by the PJRT
//!   runtime (or any closure). The e2e example and Fig-10 convergence runs
//!   use this.
//!
//! [`exec`] bridges the two: it drives the numeric engine through the
//! same bucket-pipelined overlap schedule the symbolic engine models
//! (prefetched AllGathers, reshard-after-forward, ReduceScatter under
//! backward compute) and measures the real timeline, so the simulator's
//! exposed-comm and peak-memory claims can be checked against an
//! executed step (`benches/overlap_pipeline.rs`).

pub mod engine;
pub mod exec;
pub mod sim;
pub mod spec;

pub use engine::{FsdpEngine, ShardingPolicy, DEVICE_MEM_LIMIT};
pub use exec::{ExecMode, ExecReport, StepOutcome};
pub use sim::{simulate_step, GpuSpec, ShardingFormat, StepReport, SystemBehavior};
pub use spec::{GroupFilter, ModelSpec, OptimBinding, ShardGroupSpec};
