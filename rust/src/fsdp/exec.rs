//! Bucket-pipelined overlap executor: the real (numeric) counterpart of
//! the `sim.rs` timeline model.
//!
//! A training step is driven by an [`ExecMode`] schedule over the
//! engine's FSDP buckets (embed | layer 0..L-1 | final-norm+head for the
//! native L2 transformer):
//!
//! * **Sequential** — the seed behavior: AllGather *every* bucket, run
//!   the monolithic fwd/bwd per rank, reshard, ReduceScatter every
//!   bucket. All parameters are live at once and every collective is
//!   exposed.
//! * **Pipelined** (`--prefetch N`) — the paper's overlap schedule
//!   (§5–6): bucket l+1's AllGather is issued on the comm backend's
//!   background threads *during* bucket l's forward compute
//!   (prefetching, up to N gathers in flight), each bucket is resharded
//!   immediately after its forward (reshard-after-forward, re-gathered
//!   in backward with the same prefetch window), and bucket l's
//!   ReduceScatter overlaps bucket l-1's backward compute. At most
//!   N+1 full buckets are live at any point, and every full-buffer
//!   acquire/release goes through the engine's [`CachingAllocator`]
//!   account — so the memory claim is *measured*, not asserted.
//!
//! Both schedules execute the identical float operations in the
//! identical order (the native runtime's monolithic `train_step` is a
//! composition of the same layer-wise functions the pipelined path
//! drives, and the async collectives run the same algorithms as their
//! blocking forms), so loss trajectories are **bit-identical** across
//! {serial, threaded} x {sequential, pipelined} x any prefetch depth.
//!
//! The executor also measures its own timeline: wall seconds spent
//! blocked on collectives (`exposed_comm_s` — what compute could not
//! hide) next to the fabric model's simulated comm seconds, which is
//! what `benches/overlap_pipeline.rs` compares against the `sim.rs`
//! prediction for the same preset.
//!
//! [`CachingAllocator`]: crate::memory::CachingAllocator

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::analysis::diag::{codes, rt};
use crate::cluster::launch::{rs_decode, rs_encode};
use crate::cluster::{Cluster, CommBackend, LaunchOp, PendingOp};
use crate::fsdp::engine::Bucket;
use crate::fsdp::FsdpEngine;
use crate::memory::BlockId;
use crate::runtime::native::{self, LayerCache, LayerParams};
use crate::runtime::{Engine as ComputeEngine, ModelCfg};
use crate::trace::{Cat, Span};

/// How the step loop drives buckets (`--prefetch` flag: 0 = sequential,
/// N >= 1 = pipelined with at most N gathers in flight).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Gather all buckets, compute monolithically, reduce all buckets.
    Sequential,
    /// Layer-wise schedule with `prefetch` in-flight bucket collectives.
    Pipelined { prefetch: usize },
}

impl ExecMode {
    /// `--prefetch N` semantics: 0 selects the sequential path.
    pub fn from_prefetch(n: usize) -> ExecMode {
        if n == 0 {
            ExecMode::Sequential
        } else {
            ExecMode::Pipelined { prefetch: n }
        }
    }

    pub fn prefetch(&self) -> usize {
        match self {
            ExecMode::Sequential => 0,
            ExecMode::Pipelined { prefetch } => *prefetch,
        }
    }

    pub fn name(&self) -> String {
        match self {
            ExecMode::Sequential => "sequential".to_string(),
            ExecMode::Pipelined { prefetch } => format!("pipelined{prefetch}"),
        }
    }
}

/// Total wire bytes one bucket's gather/reduce collective moves
/// (per-rank encoded bytes x group size) at its wire precision.
fn bucket_wire_bytes(b: &Bucket) -> u64 {
    b.comm_precision.wire_volume(b.dbuffer.layout.shard_size).total()
        * b.dbuffer.num_devices() as u64
}

/// Measured timeline of one executed step.
#[derive(Debug, Clone, Default)]
pub struct ExecReport {
    /// Wall-clock seconds for the whole step.
    pub wall_s: f64,
    /// Wall seconds the step spent *blocked* on collectives — the
    /// measured exposed-communication time (compute hid the rest).
    /// Every contribution is the duration of one `exposed()` tracer span
    /// ([`crate::trace::Tracer::finish_with`] returns the elapsed seconds
    /// it records), so this figure *is* the sum of the step's exposed
    /// comm spans — the accounting cannot drift from the trace.
    pub exposed_comm_s: f64,
    /// Fabric-model (simulated H800) comm seconds recorded this step.
    pub sim_comm_s: f64,
    /// Allocator peak reserved bytes on the simulated device (cumulative
    /// over the run — steady after the first step).
    pub peak_reserved: u64,
    /// Allocator peak allocated bytes.
    pub peak_allocated: u64,
}

/// Result of one executed training step.
pub struct StepOutcome {
    /// Per-rank losses (rank order).
    pub losses: Vec<f32>,
    pub report: ExecReport,
}

/// Execute one training step of `engine` under `mode`. `batches[rank]`
/// is that rank's (tokens, targets) microbatch. The pipelined mode
/// requires the native runtime (compute must be drivable per layer);
/// sequential works with any runtime.
pub fn run_step(
    engine: &mut FsdpEngine,
    runtime: &mut ComputeEngine,
    config: &str,
    batches: &[(Vec<i32>, Vec<i32>)],
    mode: ExecMode,
) -> Result<StepOutcome> {
    let cfg = runtime
        .manifest
        .configs
        .get(config)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("config '{config}' not in manifest"))?;
    if batches.len() != engine.num_devices() {
        bail!(
            "run_step: {} batches for {} devices",
            batches.len(),
            engine.num_devices()
        );
    }
    let t0 = Instant::now();
    let comm_before = engine.comm.sim_time();
    let mut exposed = 0.0f64;
    let losses = match mode {
        ExecMode::Sequential => {
            run_sequential(engine, runtime, config, &cfg, batches, &mut exposed)?
        }
        ExecMode::Pipelined { prefetch } => {
            if !runtime.is_native() {
                bail!(
                    "the pipelined executor drives compute layer-wise and \
                     requires the native runtime"
                );
            }
            run_pipelined(engine, &cfg, batches, prefetch.max(1), &mut exposed)?
        }
    };
    let (peak_reserved, peak_allocated) = engine.memory_stats();
    Ok(StepOutcome {
        losses,
        report: ExecReport {
            wall_s: t0.elapsed().as_secs_f64(),
            exposed_comm_s: exposed,
            sim_comm_s: engine.comm.sim_time() - comm_before,
            peak_reserved,
            peak_allocated,
        },
    })
}

// ---- sequential schedule (the seed step loop) ---------------------------

fn run_sequential(
    engine: &mut FsdpEngine,
    runtime: &mut ComputeEngine,
    config: &str,
    cfg: &ModelCfg,
    batches: &[(Vec<i32>, Vec<i32>)],
    exposed: &mut f64,
) -> Result<Vec<f32>> {
    let m = engine.num_devices();
    let tracer = engine.tracer.clone();
    let obs = engine.obs.clone();
    // every collective in this schedule is exposed: nothing computes
    // while the gathers / reductions run. One logical "ag"/"rs" span
    // covers all buckets (bucket "*"), bytes summed across them.
    let ag_bytes: u64 = engine.buckets.iter().map(bucket_wire_bytes).sum();
    let tg = tracer.timer();
    obs.set_phase("gather");
    engine.gather_params()?;
    *exposed += tracer.finish_with(tg, Cat::Comm, || {
        Span::new("ag").exposed().bucket("*").bytes(ag_bytes).attr("phase", "sync")
    });
    obs.set_phase("compute");
    let mut losses = Vec::with_capacity(m);
    let mut all_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(m);
    if engine.comm.backend() == CommBackend::Threaded && runtime.is_native() {
        // SPMD fan-out: each rank materializes its parameters and runs
        // fwd/bwd on its own thread. native::train_step is called
        // directly (not through Engine::train_step) so the closure never
        // captures &Engine — under the pjrt feature the xla handles
        // inside Engine are not Sync.
        let eng = &*engine;
        let (outs, _) = Cluster::run_spmd(m, |rank, _ctx| {
            let tc = tracer.timer();
            let params = eng.device_params(rank);
            let (tokens, targets) = &batches[rank];
            let out = native::train_step(cfg, &params, tokens, targets);
            tracer.finish_with(tc, Cat::Compute, || {
                Span::new("fwd_bwd").rank(rank).lane_compute()
            });
            out
        });
        for out in outs {
            let (loss, grads) = out?;
            losses.push(loss);
            all_grads.push(grads);
        }
    } else {
        for (rank, (tokens, targets)) in batches.iter().enumerate() {
            let tc = tracer.timer();
            let params = engine.device_params(rank);
            let (loss, grads) = runtime.train_step(config, &params, tokens, targets)?;
            tracer.finish_with(tc, Cat::Compute, || {
                Span::new("fwd_bwd").rank(rank).lane_compute()
            });
            losses.push(loss);
            all_grads.push(grads);
        }
    }
    engine.release_params();
    let rs_bytes: u64 = engine.buckets.iter().map(bucket_wire_bytes).sum();
    let tr = tracer.timer();
    obs.set_phase("reduce");
    engine.reduce_grads(&all_grads)?;
    *exposed += tracer.finish_with(tr, Cat::Comm, || {
        Span::new("rs").exposed().bucket("*").bytes(rs_bytes).attr("phase", "sync")
    });
    obs.set_phase("idle");
    Ok(losses)
}

// ---- pipelined schedule -------------------------------------------------

/// Per-rank compute state threaded through the bucket schedule.
#[derive(Default)]
struct RankState {
    /// Running activation (b*t, d).
    x: Vec<f32>,
    /// Per-layer backward caches, forward order.
    caches: Vec<LayerCache>,
    nf: Vec<f32>,
    rf: Vec<f32>,
    dlogits: Vec<f32>,
    /// Running activation gradient during backward.
    dx: Vec<f32>,
    loss: f32,
    /// Scratch: the current bucket's parameter grads (bucket-pos order).
    bucket_grads: Vec<Vec<f32>>,
}

/// The pipelined executor assumes the trainers' wrapping policy:
/// bucket 0 = embed, bucket 1+l = layer l, last bucket = final_ln + head.
fn check_wrapping(engine: &FsdpEngine, cfg: &ModelCfg) -> Result<()> {
    let nl = cfg.n_layers;
    if engine.buckets.len() != nl + 2 {
        bail!(
            "{}",
            rt(
                codes::WRAPPING_ABI,
                format_args!(
                    "pipelined executor expects embed|layer|head wrapping: \
                     {} buckets for {} layers",
                    engine.buckets.len(),
                    nl
                )
            )
        );
    }
    if engine.params.len() != 3 + 8 * nl {
        bail!(
            "{}",
            rt(
                codes::WRAPPING_ABI,
                format_args!("parameter ABI mismatch: {} params", engine.params.len())
            )
        );
    }
    let expect = |i: usize, bucket: usize| -> Result<()> {
        if engine.param_loc(i).bucket != bucket {
            bail!(
                "{}",
                rt(
                    codes::WRAPPING_ABI,
                    format_args!("param {i} not in bucket {bucket} — custom wrapping unsupported")
                )
            );
        }
        Ok(())
    };
    expect(0, 0)?;
    for l in 0..nl {
        for k in 0..8 {
            expect(1 + 8 * l + k, 1 + l)?;
        }
    }
    expect(1 + 8 * nl, nl + 1)?;
    expect(2 + 8 * nl, nl + 1)?;
    Ok(())
}

fn validate_batches(cfg: &ModelCfg, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<()> {
    let n = cfg.batch * cfg.seq;
    for (tokens, targets) in batches {
        if tokens.len() != n || targets.len() != n {
            bail!("tokens/targets must be batch*seq = {n} elements");
        }
        for &tok in tokens.iter().chain(targets) {
            if tok < 0 || tok as usize >= cfg.vocab {
                bail!("token {tok} out of vocab {}", cfg.vocab);
            }
        }
    }
    Ok(())
}

/// Below this many activation elements per rank (tokens x d_model) a
/// per-bucket thread fan-out costs more than the compute it
/// parallelizes — run ranks serially instead (identical math; mirrors
/// `ThreadedComm`'s `hier_threshold` serial fallback for collectives).
const MIN_PARALLEL_ACT_ELEMS: usize = 1 << 15;

/// Run `f(rank, state)` for every rank — on its own OS thread under the
/// threaded backend (the compute fan-out), serially otherwise. Identical
/// math either way.
fn par_ranks<F>(states: &mut [RankState], threaded: bool, f: F)
where
    F: Fn(usize, &mut RankState) + Sync,
{
    if !threaded || states.len() <= 1 {
        for (rank, st) in states.iter_mut().enumerate() {
            f(rank, st);
        }
    } else {
        std::thread::scope(|s| {
            for (rank, st) in states.iter_mut().enumerate() {
                let f = &f;
                s.spawn(move || f(rank, st));
            }
        });
    }
}

/// Layer `l`'s parameters as zero-copy views into `rank`'s gathered
/// bucket (no `device_params` materialization).
fn layer_view(engine: &FsdpEngine, rank: usize, l: usize) -> LayerParams<'_> {
    let base = 1 + 8 * l;
    LayerParams {
        ln1: engine.full_param_view(rank, base),
        wq: engine.full_param_view(rank, base + 1),
        wk: engine.full_param_view(rank, base + 2),
        wv: engine.full_param_view(rank, base + 3),
        wo: engine.full_param_view(rank, base + 4),
        ln2: engine.full_param_view(rank, base + 5),
        w1: engine.full_param_view(rank, base + 6),
        w2: engine.full_param_view(rank, base + 7),
    }
}

/// Issue bucket gathers in `order` until `cap` are in flight. Issue time
/// counts as exposed comm: on an eager (serial) backend the collective
/// runs right here, and on the threaded backend it is only the spawn
/// cost.
fn issue_gathers(
    engine: &mut FsdpEngine,
    inflight: &mut VecDeque<(usize, PendingOp)>,
    order: &mut dyn Iterator<Item = usize>,
    cap: usize,
    exposed: &mut f64,
) -> Result<()> {
    let tracer = engine.tracer.clone();
    let obs = engine.obs.clone();
    while inflight.len() < cap {
        let Some(b) = order.next() else {
            return Ok(());
        };
        obs.set_bucket(&engine.buckets[b].name);
        obs.flight_all("sched", "ag_issue", b as u64, inflight.len() as u64);
        let comm = engine.comm.clone();
        let prec = engine.buckets[b].comm_precision;
        let t0 = tracer.timer();
        // cast-before-comm: the encode (quant kernel) runs at issue time,
        // so it is charged as exposed alongside the issue cost
        let op = engine.buckets[b].dbuffer.begin_gather(comm.as_ref(), prec)?;
        *exposed += tracer.finish_with(t0, Cat::Comm, || {
            Span::new("ag")
                .exposed()
                .bucket(&engine.buckets[b].name)
                .bytes(bucket_wire_bytes(&engine.buckets[b]))
                .attr("phase", "issue")
                .attr("prec", prec.name())
        });
        inflight.push_back((b, op));
    }
    Ok(())
}

/// Block until bucket `b`'s gather completes (finishing any earlier
/// in-flight gathers along the way); the block time is exposed comm.
fn wait_gather(
    engine: &mut FsdpEngine,
    inflight: &mut VecDeque<(usize, PendingOp)>,
    b: usize,
    exposed: &mut f64,
) -> Result<()> {
    if engine.buckets[b].dbuffer.gathered {
        return Ok(());
    }
    let comm = engine.comm.clone();
    let tracer = engine.tracer.clone();
    let obs = engine.obs.clone();
    while let Some((bucket, op)) = inflight.pop_front() {
        obs.set_bucket(&engine.buckets[bucket].name);
        obs.flight_all("sched", "ag_wait", bucket as u64, inflight.len() as u64);
        let t0 = tracer.timer();
        // each bucket's collective is timed on its own (group-local)
        // fabric and decoded at its own wire precision; the dequant of an
        // earlier bucket overlaps later buckets' in-flight gathers
        let fabric = engine.buckets[bucket].fabric.clone();
        let prec = engine.buckets[bucket].comm_precision;
        engine.buckets[bucket]
            .dbuffer
            .finish_gather(op, comm.as_ref(), &fabric, prec)?;
        *exposed += tracer.finish_with(t0, Cat::Comm, || {
            Span::new("ag")
                .exposed()
                .bucket(&engine.buckets[bucket].name)
                .bytes(bucket_wire_bytes(&engine.buckets[bucket]))
                .attr("phase", "wait")
                .attr("prec", prec.name())
        });
        if bucket == b {
            return Ok(());
        }
    }
    bail!("{}", rt(codes::HANDLE_DISCIPLINE, format_args!("bucket {b} gather was never issued")));
}

/// One in-flight gradient reduction. For the dense (F32) path the staged
/// gradient buffers travel inside the op; for a quantized precision only
/// the encoded wire buffers do, and the (residual-injected) staged
/// originals are kept here so `finish_reduce` can update the
/// error-feedback residuals and write the reduced chunks.
struct PendingReduce {
    bucket: usize,
    op: PendingOp,
    /// Staged originals — `Some` only on the quantized path.
    staged: Option<Vec<Vec<f32>>>,
    /// Allocator claim for the staged full-size gradient buffers.
    staged_block: BlockId,
    /// Allocator claim for the encoded wire buffers (quantized path).
    wire_block: Option<BlockId>,
}

/// Stage bucket `b`'s per-rank gradients at layout offsets (via the same
/// `stage_bucket_grads` the sequential reduction uses) and issue its
/// ReduceScatter on the comm backend (overlaps the next bucket's
/// backward). One [`CollectiveLaunch`] descriptor drives both shapes:
/// the dense nonblocking launch for `F32`, or the codec stage
/// ([`rs_encode`]) followed by the descriptor's transport lowering (an
/// encoded all-to-all) for `Bf16`/`Q8`. The staged full-size gradient
/// buffer is transient device memory — claimed from the allocator until
/// `finish_reduce` frees it.
///
/// [`CollectiveLaunch`]: crate::cluster::CollectiveLaunch
fn begin_reduce(
    engine: &mut FsdpEngine,
    states: &mut [RankState],
    b: usize,
    exposed: &mut f64,
) -> Result<PendingReduce> {
    let m = engine.num_devices();
    let s = engine.buckets[b].dbuffer.shard_elems();
    let obs = engine.obs.clone();
    obs.set_phase("reduce");
    obs.set_bucket(&engine.buckets[b].name);
    let (mut bufs, block) = crate::fsdp::engine::stage_bucket_grads(
        &engine.buckets[b],
        m,
        &engine.alloc,
        &|rank, pos| &states[rank].bucket_grads[pos][..],
    )?;
    obs.flight_all("alloc", "staged_grads", b as u64, (m * s * 4) as u64);
    for st in states.iter_mut() {
        st.bucket_grads.clear();
    }
    let scale = engine.buckets[b].dbuffer.reduce_scale(&engine.buckets[b].mesh);
    let prec = engine.buckets[b].comm_precision;
    let tracer = engine.tracer.clone();
    let l = engine
        .comm
        .describe(LaunchOp::ReduceScatter, m, s)
        .scaled(scale)
        .with_precision(prec)
        .asynchronous();
    if prec.is_f32() {
        let t0 = tracer.timer();
        obs.flight_all("sched", "rs_issue", b as u64, 0);
        let op = engine.comm.launch_async(&l, bufs);
        *exposed += tracer.finish_with(t0, Cat::Comm, || {
            Span::new("rs")
                .exposed()
                .bucket(&engine.buckets[b].name)
                .bytes(bucket_wire_bytes(&engine.buckets[b]))
                .attr("phase", "issue")
        });
        return Ok(PendingReduce {
            bucket: b,
            op,
            staged: None,
            staged_block: block,
            wire_block: None,
        });
    }
    // cast-before-comm: the encode (quant kernel) and wire claim happen
    // at issue time and count as exposed, mirroring the gather path
    let t0 = tracer.timer();
    let wire = rs_encode(prec, &mut bufs, s, &mut engine.buckets[b].ef)?;
    let transport = l.transport();
    let wire_bytes = l.wire_claim_bytes();
    let ta = tracer.timer();
    let wire_block = engine.alloc.lock().unwrap().alloc(wire_bytes)?;
    tracer.finish_with(ta, Cat::Compute, || {
        Span::new("alloc_wait").bucket(&engine.buckets[b].name).bytes(wire_bytes)
    });
    obs.flight_all("alloc", "wire", b as u64, wire_bytes);
    obs.flight_all("sched", "rs_issue", b as u64, 0);
    let op = engine.comm.launch_async(&transport, wire);
    *exposed += tracer.finish_with(t0, Cat::Comm, || {
        Span::new("rs")
            .exposed()
            .bucket(&engine.buckets[b].name)
            .bytes(bucket_wire_bytes(&engine.buckets[b]))
            .attr("phase", "issue")
            .attr("prec", prec.name())
    });
    Ok(PendingReduce {
        bucket: b,
        op,
        staged: Some(bufs),
        staged_block: block,
        wire_block: Some(wire_block),
    })
}

/// Complete an in-flight ReduceScatter: (for quantized precisions,
/// dequantize-and-sum the exchanged chunks in rank order and update the
/// error-feedback residuals first — the same codec stage ([`rs_decode`])
/// the sequential launch pipeline composes, so the bits match), then
/// copy the reduced shard regions into the bucket's grad shards (plus
/// the HSDP replica AllReduce) and release the staged gradient / wire
/// buffers.
fn finish_reduce(engine: &mut FsdpEngine, pending: PendingReduce, exposed: &mut f64) -> Result<()> {
    let PendingReduce { bucket: b, op, staged, staged_block, wire_block } = pending;
    let tracer = engine.tracer.clone();
    let obs = engine.obs.clone();
    let bname = engine.buckets[b].name.clone();
    let bytes = bucket_wire_bytes(&engine.buckets[b]);
    obs.set_bucket(&bname);
    obs.flight_all("sched", "rs_wait", b as u64, 0);
    let t0 = tracer.timer();
    let returned = op.wait()?;
    *exposed += tracer.finish_with(t0, Cat::Comm, || {
        Span::new("rs").exposed().bucket(&bname).bytes(bytes).attr("phase", "wait")
    });
    let comm = engine.comm.clone();
    let Bucket { dbuffer, grad_shards, mesh, fabric, comm_precision, ef, .. } =
        &mut engine.buckets[b];
    match staged {
        None => {
            dbuffer.reduce_gradients_finish(
                &returned,
                grad_shards,
                mesh,
                comm.as_ref(),
                fabric,
                *comm_precision,
            )?;
        }
        Some(mut bufs) => {
            let s = dbuffer.shard_elems();
            let scale = dbuffer.reduce_scale(mesh);
            let prec = *comm_precision;
            // the dequant-reduce is wall time the step cannot hide —
            // exposed, like finish_gather's decode
            let t1 = tracer.timer();
            rs_decode(prec, &returned, &mut bufs, s, scale, ef)?;
            *exposed += tracer.finish_with(t1, Cat::Comm, || {
                Span::new("quant_decode")
                    .exposed()
                    .bucket(&bname)
                    .bytes(bytes)
                    .attr("prec", prec.name())
            });
            dbuffer.reduce_gradients_finish(
                &bufs,
                grad_shards,
                mesh,
                comm.as_ref(),
                fabric,
                *comm_precision,
            )?;
        }
    }
    let mut alloc = engine.alloc.lock().unwrap();
    alloc.free(staged_block)?;
    if let Some(wb) = wire_block {
        alloc.free(wb)?;
    }
    drop(alloc);
    obs.flight_all("alloc", "free_staged", b as u64, 0);
    Ok(())
}

fn run_pipelined(
    engine: &mut FsdpEngine,
    cfg: &ModelCfg,
    batches: &[(Vec<i32>, Vec<i32>)],
    prefetch: usize,
    exposed: &mut f64,
) -> Result<Vec<f32>> {
    check_wrapping(engine, cfg)?;
    validate_batches(cfg, batches)?;
    let m = engine.num_devices();
    let nb = engine.buckets.len();
    let nl = cfg.n_layers;
    let threaded = engine.comm.backend() == CommBackend::Threaded
        && cfg.batch * cfg.seq * cfg.d_model >= MIN_PARALLEL_ACT_ELEMS;
    let tracer = engine.tracer.clone();
    let obs = engine.obs.clone();
    let mut states: Vec<RankState> = (0..m).map(|_| RankState::default()).collect();

    // ---- forward: prefetch AG(l+1..) under compute of bucket l ----
    let mut inflight: VecDeque<(usize, PendingOp)> = VecDeque::new();
    let mut fwd_order = 0..nb;
    for l in 0..nb {
        obs.set_phase("gather");
        issue_gathers(engine, &mut inflight, &mut fwd_order, prefetch, exposed)?;
        wait_gather(engine, &mut inflight, l, exposed)?;
        issue_gathers(engine, &mut inflight, &mut fwd_order, prefetch, exposed)?;
        obs.set_phase("compute");
        obs.set_bucket(&engine.buckets[l].name);
        par_ranks(&mut states, threaded, |rank, st| {
            let tc = tracer.timer();
            if l == 0 {
                st.x = native::embed_fwd(cfg, engine.full_param_view(rank, 0), &batches[rank].0);
            } else if l <= nl {
                let lp = layer_view(engine, rank, l - 1);
                st.caches.push(native::layer_fwd(cfg, &lp, &mut st.x));
            } else {
                let final_ln = engine.full_param_view(rank, 1 + 8 * nl);
                let head = engine.full_param_view(rank, 2 + 8 * nl);
                let (nf, rf, logits) = native::head_fwd(cfg, final_ln, head, &st.x);
                let (loss, dlogits) = native::loss_grad(cfg, &logits, &batches[rank].1);
                st.nf = nf;
                st.rf = rf;
                st.loss = loss;
                st.dlogits = dlogits;
            }
            tracer.finish_with(tc, Cat::Compute, || {
                Span::new("fwd").rank(rank).lane_compute().bucket(&engine.buckets[l].name)
            });
        });
        // reshard-after-forward: drop the full bucket so backward
        // re-gathers it through the same prefetch window — unless the
        // group's spec opted out, in which case it stays live (more
        // memory, one less backward AllGather)
        if engine.buckets[l].reshard_after_forward {
            engine.buckets[l].dbuffer.release_full();
        }
    }
    debug_assert!(inflight.is_empty());

    // ---- backward: re-gather in reverse with prefetch; RS of bucket b
    // overlaps backward compute of bucket b-1. Groups kept live through
    // forward need no re-gather and are skipped by the issue order. ----
    let bwd_regather: Vec<usize> = (0..nb)
        .rev()
        .filter(|&b| !engine.buckets[b].dbuffer.gathered)
        .collect();
    let mut bwd_order = bwd_regather.into_iter();
    let mut rs_pending: VecDeque<PendingReduce> = VecDeque::new();
    for b in (0..nb).rev() {
        obs.set_phase("gather");
        issue_gathers(engine, &mut inflight, &mut bwd_order, prefetch, exposed)?;
        wait_gather(engine, &mut inflight, b, exposed)?;
        issue_gathers(engine, &mut inflight, &mut bwd_order, prefetch, exposed)?;
        obs.set_phase("compute");
        obs.set_bucket(&engine.buckets[b].name);
        par_ranks(&mut states, threaded, |rank, st| {
            let tc = tracer.timer();
            if b == nb - 1 {
                let final_ln = engine.full_param_view(rank, 1 + 8 * nl);
                let head = engine.full_param_view(rank, 2 + 8 * nl);
                let (d_ln, d_head, dx) =
                    native::head_bwd(cfg, &st.dlogits, &st.x, &st.nf, &st.rf, final_ln, head);
                st.dx = dx;
                st.bucket_grads = vec![d_ln, d_head];
            } else if b >= 1 {
                let lp = layer_view(engine, rank, b - 1);
                let grads = native::layer_bwd(cfg, &lp, &st.caches[b - 1], &mut st.dx);
                st.bucket_grads = grads.into_iter().collect();
            } else {
                let d_embed = native::embed_bwd(cfg, &batches[rank].0, &st.dx);
                st.bucket_grads = vec![d_embed];
            }
            tracer.finish_with(tc, Cat::Compute, || {
                Span::new("bwd").rank(rank).lane_compute().bucket(&engine.buckets[b].name)
            });
        });
        engine.buckets[b].dbuffer.release_full();
        let pending = begin_reduce(engine, &mut states, b, exposed)?;
        rs_pending.push_back(pending);
        // opportunistically retire reductions that already completed
        while rs_pending.front().is_some_and(|p| p.op.is_done()) {
            let p = rs_pending.pop_front().unwrap();
            finish_reduce(engine, p, exposed)?;
        }
        // bound the in-flight reductions (live staged-grad memory)
        while rs_pending.len() > prefetch {
            let p = rs_pending.pop_front().unwrap();
            finish_reduce(engine, p, exposed)?;
        }
    }
    while let Some(p) = rs_pending.pop_front() {
        finish_reduce(engine, p, exposed)?;
    }
    obs.set_phase("idle");
    obs.clear_bucket();
    Ok(states.iter().map(|s| s.loss).collect())
}
