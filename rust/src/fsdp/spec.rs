//! Declarative `fully_shard`-style sharding spec: the user-facing wrap
//! graph the engine plans from (`FsdpEngine::from_spec`).
//!
//! A [`ModelSpec`] is an ordered list of [`ShardGroupSpec`] wrap units.
//! Each group declares, independently of every other group:
//!
//! * **which parameters it wraps** — a validated [`GroupFilter`]
//!   (prefixes / exact names / explicit indices / the rest), replacing
//!   the old panicking name-prefix parse: a parameter no group claims is
//!   a `Result` error naming the parameter, not an `unwrap` panic;
//! * **its sharding policy** — the group-local `orig_param_policy`
//!   granularity the planner lays that bucket out with (so a quantized
//!   group can demand 32-row blocks while a dense group shards
//!   element-wise);
//! * **its optimizer binding** — [`OptimBinding`], so one run can train
//!   Muon on layer matrices next to AdamW on embeddings and 8-bit Adam on
//!   an MoE block, each with an optional group-local hyper override;
//! * **reshard-after-forward** — whether the pipelined executor drops the
//!   gathered parameters after the group's forward (re-gathering in
//!   backward) or keeps them live through the step;
//! * **its mesh and fabric** — optional per-group overrides (the fsdp dim
//!   must match the session's; a group may add a replica dim or sit on a
//!   different fabric tier).
//!
//! # Worked example: mixed per-group optimizers
//!
//! The paper's flexibility claim (§6.3) is exactly this configuration —
//! Muon on the 2-D transformer matrices, AdamW on embeddings / head /
//! norms, chosen *per wrap unit* rather than globally:
//!
//! ```no_run
//! use vescale_fsdp::fsdp::spec::{GroupFilter, ModelSpec, OptimBinding, ShardGroupSpec};
//! use vescale_fsdp::fsdp::ShardingPolicy;
//! use vescale_fsdp::optim::AdamHyper;
//!
//! let n_layers = 2;
//! let mut spec = ModelSpec::new()
//!     .group(ShardGroupSpec::new("embed", GroupFilter::prefix("embed"))
//!         .optim(OptimBinding::AdamW));
//! for i in 0..n_layers {
//!     spec = spec.group(
//!         ShardGroupSpec::new(format!("layer{i}"), GroupFilter::prefix(format!("layers.{i}.")))
//!             .optim(OptimBinding::Muon)
//!             .hyper(AdamHyper { lr: 0.02, wd: 0.0, ..AdamHyper::default() }),
//!     );
//! }
//! let spec = spec.group(
//!     ShardGroupSpec::new("head", GroupFilter::Prefixes(vec!["final_ln".into(), "head".into()]))
//!         .optim(OptimBinding::AdamW)
//!         .policy(ShardingPolicy::element_wise()),
//! );
//! # let _ = spec;
//! ```
//!
//! The same spec comes out of `ModelSpec::layerwise(n_layers)` +
//! per-group edits, out of `TrainSession::builder(..)` group overrides,
//! or out of a config file's `[group.*]` sections — one graph, three
//! front doors.

use anyhow::{bail, Result};

use crate::comm::Fabric;
use crate::config::OptimKind;
use crate::mesh::DeviceMesh;
use crate::optim::{
    Adam8bitGroup, AdamHyper, AdamW, FlatGroup, GroupOptimizer, Muon, MuonGroup, Sgd,
};
use crate::quant::CommPrecision;

use super::engine::ShardingPolicy;

/// Which optimizer a shard group trains with. The binding is resolved to
/// a [`GroupOptimizer`] per group at session build time, so every group
/// dispatches uniformly — no special-cased optimizer fields.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimBinding {
    /// SGD with momentum 0.9 on flat shards.
    Sgd,
    /// fp32 AdamW on flat shards.
    AdamW,
    /// Block-wise 8-bit Adam on >=2-D parameters (fp32 AdamW on 1-D).
    /// Pair with a row-granularity sharding policy that preserves quant
    /// blocks.
    Adam8bit,
    /// Muon (Alg 2) on the group's 2-D hidden matrices, AdamW fallback on
    /// embeddings / head / 1-D parameters inside the group.
    Muon,
}

impl OptimBinding {
    pub fn name(&self) -> &'static str {
        match self {
            OptimBinding::Sgd => "sgd",
            OptimBinding::AdamW => "adamw",
            OptimBinding::Adam8bit => "adam8bit",
            OptimBinding::Muon => "muon",
        }
    }

    pub fn parse(s: &str) -> Option<OptimBinding> {
        OptimKind::parse(s).map(OptimBinding::from_kind)
    }

    /// The binding matching a legacy global [`OptimKind`] selection.
    pub fn from_kind(kind: OptimKind) -> OptimBinding {
        match kind {
            OptimKind::Sgd => OptimBinding::Sgd,
            OptimKind::AdamW => OptimBinding::AdamW,
            OptimKind::Adam8bit => OptimBinding::Adam8bit,
            OptimKind::Muon => OptimBinding::Muon,
        }
    }

    /// Build the group optimizer for a group of `n_params` tensors
    /// sharded over `ranks` devices. `qblock` is the quantization block
    /// for 8-bit Adam state.
    pub fn build(
        &self,
        hyper: AdamHyper,
        qblock: usize,
        n_params: usize,
        ranks: usize,
    ) -> Box<dyn GroupOptimizer> {
        match self {
            OptimBinding::Sgd => {
                Box::new(FlatGroup::new(Box::new(Sgd::new(hyper.lr, 0.9, ranks)), ranks))
            }
            OptimBinding::AdamW => {
                Box::new(FlatGroup::new(Box::new(AdamW::new(hyper, ranks)), ranks))
            }
            OptimBinding::Adam8bit => {
                Box::new(Adam8bitGroup::new(hyper, qblock, n_params, ranks))
            }
            OptimBinding::Muon => Box::new(MuonGroup::new(
                Muon::new(hyper.lr, 0.95, hyper.wd),
                Box::new(AdamW::new(hyper, ranks)),
                ranks,
            )),
        }
    }
}

/// How a shard group claims parameters. Groups claim in declaration
/// order; a parameter already claimed by an earlier group is skipped by
/// later prefix filters and is an error for explicit index filters.
#[derive(Debug, Clone)]
pub enum GroupFilter {
    /// Parameters whose name starts with any of these prefixes.
    Prefixes(Vec<String>),
    /// Parameters with exactly these names.
    Names(Vec<String>),
    /// Explicit global parameter indices.
    Indices(Vec<usize>),
    /// Every parameter not claimed by an earlier group.
    Rest,
}

impl GroupFilter {
    /// Single-prefix convenience.
    pub fn prefix(p: impl Into<String>) -> GroupFilter {
        GroupFilter::Prefixes(vec![p.into()])
    }
}

/// One `fully_shard` wrap unit and all of its per-group choices.
#[derive(Debug, Clone)]
pub struct ShardGroupSpec {
    pub name: String,
    pub filter: GroupFilter,
    /// Group-local sharding granularity (`orig_param_policy`).
    pub policy: ShardingPolicy,
    pub optim: OptimBinding,
    /// Group-local hyper override (session hyper when `None`).
    pub hyper: Option<AdamHyper>,
    /// Drop the gathered parameters right after this group's forward
    /// (re-gather in backward). `false` keeps them live through the step
    /// — more memory, one less AllGather.
    pub reshard_after_forward: bool,
    /// Mesh override; must keep the session's fsdp dim size. `None`
    /// inherits the session mesh.
    pub mesh: Option<DeviceMesh>,
    /// Fabric override; `None` inherits the session fabric.
    pub fabric: Option<Fabric>,
    /// Wire precision of this group's parameter AllGather / gradient
    /// ReduceScatter: full f32 (default, bit-identical legacy path),
    /// cast-before-comm bf16, or block-wise int8 with shard-held
    /// error-feedback on gradients. Choosing `Q8` feeds its block into
    /// the planner granularity so quant blocks and scales never straddle
    /// devices.
    pub comm_precision: CommPrecision,
}

impl ShardGroupSpec {
    pub fn new(name: impl Into<String>, filter: GroupFilter) -> ShardGroupSpec {
        ShardGroupSpec {
            name: name.into(),
            filter,
            policy: ShardingPolicy::element_wise(),
            optim: OptimBinding::AdamW,
            hyper: None,
            reshard_after_forward: true,
            mesh: None,
            fabric: None,
            comm_precision: CommPrecision::F32,
        }
    }

    pub fn policy(mut self, policy: ShardingPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn optim(mut self, optim: OptimBinding) -> Self {
        self.optim = optim;
        self
    }

    pub fn hyper(mut self, hyper: AdamHyper) -> Self {
        self.hyper = Some(hyper);
        self
    }

    pub fn reshard_after_forward(mut self, reshard: bool) -> Self {
        self.reshard_after_forward = reshard;
        self
    }

    pub fn mesh(mut self, mesh: DeviceMesh) -> Self {
        self.mesh = Some(mesh);
        self
    }

    pub fn fabric(mut self, fabric: Fabric) -> Self {
        self.fabric = Some(fabric);
        self
    }

    pub fn comm_precision(mut self, prec: CommPrecision) -> Self {
        self.comm_precision = prec;
        self
    }
}

/// The whole model's wrap graph: an ordered list of shard groups. Group
/// declaration order is bucket order (the executor's schedule order).
#[derive(Debug, Clone, Default)]
pub struct ModelSpec {
    pub groups: Vec<ShardGroupSpec>,
}

impl ModelSpec {
    pub fn new() -> ModelSpec {
        ModelSpec::default()
    }

    /// Append a wrap unit (builder style).
    pub fn group(mut self, g: ShardGroupSpec) -> Self {
        self.groups.push(g);
        self
    }

    /// The canonical transformer wrapping: embed | layer 0..n-1 | head
    /// (final norm + output head), every group with default policy and
    /// AdamW. Matches the trainers' legacy name-prefix bucketing, but
    /// validated: a parameter outside the ABI is an error, not a panic.
    pub fn layerwise(n_layers: usize) -> ModelSpec {
        let mut spec = ModelSpec::new()
            .group(ShardGroupSpec::new("embed", GroupFilter::prefix("embed")));
        for i in 0..n_layers {
            spec = spec.group(ShardGroupSpec::new(
                format!("layer{i}"),
                GroupFilter::prefix(format!("layers.{i}.")),
            ));
        }
        spec.group(ShardGroupSpec::new(
            "head",
            GroupFilter::Prefixes(vec!["final_ln".into(), "head".into()]),
        ))
    }

    /// The §6.3 mixed-optimizer wrapping: Muon on every layer group's
    /// matrices, AdamW on embed / head (and, via Muon's fallback, on the
    /// norm scales inside layer groups). `muon_hyper` applies to the
    /// layer groups; the session hyper covers embed/head.
    pub fn layerwise_mixed_muon(n_layers: usize, muon_hyper: AdamHyper) -> ModelSpec {
        let mut spec = ModelSpec::layerwise(n_layers);
        for g in spec.groups.iter_mut() {
            if g.name.starts_with("layer") {
                g.optim = OptimBinding::Muon;
                g.hyper = Some(muon_hyper);
            }
        }
        spec
    }

    /// Look a group up by name.
    pub fn group_named(&self, name: &str) -> Option<&ShardGroupSpec> {
        self.groups.iter().find(|g| g.name == name)
    }

    pub fn group_named_mut(&mut self, name: &str) -> Option<&mut ShardGroupSpec> {
        self.groups.iter_mut().find(|g| g.name == name)
    }

    /// Assign every parameter to a group: `group_of[i]` is the bucket
    /// index of parameter `i`. Errors (instead of panicking) on
    /// parameters no group claims, on groups that claim nothing, and on
    /// double claims — each error names the offending parameter or group.
    pub fn assign(&self, params: &[(String, Vec<usize>)]) -> Result<Vec<usize>> {
        const UNCLAIMED: usize = usize::MAX;
        let mut group_of = vec![UNCLAIMED; params.len()];
        for (gi, g) in self.groups.iter().enumerate() {
            match &g.filter {
                GroupFilter::Indices(ids) => {
                    for &i in ids {
                        if i >= params.len() {
                            bail!(
                                "shard group '{}' claims parameter index {i}, \
                                 but the model has {} parameters",
                                g.name,
                                params.len()
                            );
                        }
                        if group_of[i] != UNCLAIMED {
                            bail!(
                                "parameter '{}' claimed by both shard group '{}' and '{}'",
                                params[i].0,
                                self.groups[group_of[i]].name,
                                g.name
                            );
                        }
                        group_of[i] = gi;
                    }
                }
                GroupFilter::Prefixes(ps) => {
                    let mut hit = false;
                    for (i, (name, _)) in params.iter().enumerate() {
                        if group_of[i] == UNCLAIMED
                            && ps.iter().any(|p| name.starts_with(p.as_str()))
                        {
                            group_of[i] = gi;
                            hit = true;
                        }
                    }
                    if !hit {
                        bail!(
                            "shard group '{}' matched no parameters (prefixes {ps:?})",
                            g.name
                        );
                    }
                }
                GroupFilter::Names(ns) => {
                    for n in ns {
                        let Some(i) = params.iter().position(|(name, _)| name == n) else {
                            bail!(
                                "shard group '{}' names parameter '{n}', \
                                 which the model does not have",
                                g.name
                            );
                        };
                        if group_of[i] != UNCLAIMED {
                            bail!(
                                "parameter '{n}' claimed by both shard group '{}' and '{}'",
                                self.groups[group_of[i]].name,
                                g.name
                            );
                        }
                        group_of[i] = gi;
                    }
                }
                GroupFilter::Rest => {
                    let mut hit = false;
                    for x in group_of.iter_mut() {
                        if *x == UNCLAIMED {
                            *x = gi;
                            hit = true;
                        }
                    }
                    if !hit {
                        bail!("shard group '{}' (rest) matched no parameters", g.name);
                    }
                }
            }
        }
        if let Some((i, _)) = group_of.iter().enumerate().find(|(_, &g)| g == UNCLAIMED) {
            let names: Vec<&str> = self.groups.iter().map(|g| g.name.as_str()).collect();
            bail!(
                "parameter '{}' matched no shard group — declare a group for it \
                 (groups: {names:?})",
                params[i].0
            );
        }
        Ok(group_of)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn abi() -> Vec<(String, Vec<usize>)> {
        crate::runtime::ModelCfg::with_abi(64, 16, 2, 2, 32, 8, 2).params
    }

    #[test]
    fn layerwise_matches_legacy_prefix_bucketing() {
        let params = abi();
        let spec = ModelSpec::layerwise(2);
        let group_of = spec.assign(&params).unwrap();
        // legacy rule: embed -> 0, layers.i -> 1+i, rest -> n_layers+1
        for (i, (name, _)) in params.iter().enumerate() {
            let expect = if name.starts_with("embed") {
                0
            } else if let Some(rest) = name.strip_prefix("layers.") {
                1 + rest.split('.').next().unwrap().parse::<usize>().unwrap()
            } else {
                3
            };
            assert_eq!(group_of[i], expect, "{name}");
        }
    }

    #[test]
    fn unclaimed_parameter_is_named_in_error() {
        let mut params = abi();
        params.push(("layers.banana.w".into(), vec![4, 4]));
        let err = ModelSpec::layerwise(2).assign(&params).unwrap_err();
        assert!(
            err.to_string().contains("layers.banana.w"),
            "error must name the parameter: {err}"
        );
    }

    #[test]
    fn empty_prefix_group_is_an_error() {
        let err = ModelSpec::layerwise(5).assign(&abi()).unwrap_err();
        // layers 2..4 match nothing in a 2-layer ABI
        assert!(err.to_string().contains("layer2"), "{err}");
    }

    #[test]
    fn double_claim_is_an_error() {
        let params = abi();
        let spec = ModelSpec::new()
            .group(ShardGroupSpec::new("a", GroupFilter::Indices(vec![0, 1])))
            .group(ShardGroupSpec::new("b", GroupFilter::Indices(vec![1])));
        let err = spec.assign(&params).unwrap_err();
        assert!(err.to_string().contains("claimed by both"), "{err}");
    }

    #[test]
    fn rest_claims_leftovers_in_order() {
        let params = abi();
        let spec = ModelSpec::new()
            .group(ShardGroupSpec::new("embed", GroupFilter::prefix("embed")))
            .group(ShardGroupSpec::new("rest", GroupFilter::Rest));
        let group_of = spec.assign(&params).unwrap();
        assert_eq!(group_of[0], 0);
        assert!(group_of[1..].iter().all(|&g| g == 1));
    }

    #[test]
    fn names_filter_exact_match() {
        let params = abi();
        let spec = ModelSpec::new()
            .group(ShardGroupSpec::new(
                "special",
                GroupFilter::Names(vec!["head.weight".into()]),
            ))
            .group(ShardGroupSpec::new("rest", GroupFilter::Rest));
        let group_of = spec.assign(&params).unwrap();
        let head = params.iter().position(|(n, _)| n == "head.weight").unwrap();
        assert_eq!(group_of[head], 0);
        let bad = ModelSpec::new().group(ShardGroupSpec::new(
            "x",
            GroupFilter::Names(vec!["nope".into()]),
        ));
        assert!(bad.assign(&params).is_err());
    }

    #[test]
    fn mixed_muon_spec_binds_per_group() {
        let spec = ModelSpec::layerwise_mixed_muon(2, AdamHyper::default());
        assert_eq!(spec.group_named("embed").unwrap().optim, OptimBinding::AdamW);
        assert_eq!(spec.group_named("layer0").unwrap().optim, OptimBinding::Muon);
        assert_eq!(spec.group_named("layer1").unwrap().optim, OptimBinding::Muon);
        assert_eq!(spec.group_named("head").unwrap().optim, OptimBinding::AdamW);
        assert!(spec.group_named("layer0").unwrap().hyper.is_some());
    }

    #[test]
    fn comm_precision_defaults_f32_and_overrides() {
        let g = ShardGroupSpec::new("g", GroupFilter::Rest);
        assert!(g.comm_precision.is_f32());
        let g = g.comm_precision(CommPrecision::Q8 { block: 32 });
        assert_eq!(g.comm_precision, CommPrecision::Q8 { block: 32 });
    }

    #[test]
    fn binding_roundtrip_and_build() {
        for kind in [OptimKind::Sgd, OptimKind::AdamW, OptimKind::Adam8bit, OptimKind::Muon] {
            let b = OptimBinding::from_kind(kind);
            assert_eq!(b.name(), kind.name());
            assert_eq!(OptimBinding::parse(b.name()), Some(b));
            let opt = b.build(AdamHyper::default(), 64, 3, 2);
            assert_eq!(opt.name(), kind.name());
        }
    }
}
