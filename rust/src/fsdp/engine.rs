//! Numeric FSDP engine: the `fully_shard` execution path with real data.
//!
//! Parameters live sharded in per-bucket DBuffers (planner-laid-out
//! RaggedShard). A training step is:
//!
//! 1. `gather_params` — in-place AllGather per bucket (zero-copy views);
//! 2. compute — caller runs fwd/bwd per device (PJRT runtime or closure)
//!    on the materialized parameters;
//! 3. `reduce_grads` — per-bucket ReduceScatter into gradient shards
//!    (+ replica AllReduce under HSDP);
//! 4. `optimizer_step` — sharded update (AdamW / SGD / 8-bit Adam on flat
//!    shards; Muon per 2-D matrix via RaggedShard redistribute).
//!
//! The `ShardingPolicy` is the paper's `orig_param_policy`: per-parameter
//! sharding granularity (e.g. 32-row blocks for 8-bit Adam's 32x32 quant
//! tiles) consumed by the planner.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::analysis::diag::{codes, rt};
use crate::cluster::{CommBackend, CommBuilder, Communicator};
use crate::comm::{CommStats, Fabric};
use crate::dbuffer::DBuffer;
use crate::memory::{shared_allocator, BlockId, FreePolicy, SharedAllocator};
use crate::mesh::DeviceMesh;
use crate::optim::group::{self as optim_group, GroupEnv};
use crate::optim::{GroupOptimizer, Muon, ShardOptimizer};
use crate::planner::{self, TensorDecl};
use crate::quant::CommPrecision;
use crate::trace::{Cat, Span, Tracer};
use crate::util::lcm;

use super::spec::{GroupFilter, ModelSpec, ShardGroupSpec};

/// Simulated per-device memory limit for the engine's allocator account
/// (generous: the numeric models are tiny; the limit only exists so the
/// allocator's pressure path stays reachable in tests). Public so the
/// static analyzer (`analysis::lint`) checks its replayed claim ledger
/// against the same budget the live engine runs under.
pub const DEVICE_MEM_LIMIT: u64 = 1 << 40;

/// Per-parameter sharding granularity policy (`orig_param_policy`).
#[derive(Debug, Clone)]
pub struct ShardingPolicy {
    /// Default granularity in elements (1 = element-wise).
    pub default_granularity: u64,
    /// Per-parameter override: name -> granularity in *rows* (multiplied
    /// by the row stride), e.g. 32 for 32x32 quant blocks on matrices.
    pub row_granularity: BTreeMap<String, u64>,
}

impl ShardingPolicy {
    pub fn element_wise() -> ShardingPolicy {
        ShardingPolicy { default_granularity: 1, row_granularity: BTreeMap::new() }
    }

    /// Uniform row granularity for every >=2-D parameter (the 8-bit Adam
    /// setup: 32-row blocks).
    pub fn uniform_rows(rows: u64) -> ShardingPolicy {
        let mut p = ShardingPolicy::element_wise();
        p.row_granularity.insert("*".into(), rows);
        p
    }

    pub fn granularity_of(&self, name: &str, shape: &[usize]) -> u64 {
        let row: u64 = shape[1..].iter().map(|&s| s as u64).product::<u64>().max(1);
        let rows_override = self
            .row_granularity
            .get(name)
            .or_else(|| self.row_granularity.get("*"));
        match rows_override {
            Some(&r) if shape.len() >= 2 => r * row,
            _ => self.default_granularity,
        }
    }
}

/// One parameter's location: which bucket, which tensor index inside it.
#[derive(Debug, Clone, Copy)]
pub struct ParamLoc {
    pub bucket: usize,
    pub idx: usize,
}

/// One shard group's runtime state: the planned DBuffer plus the
/// group-local choices the spec declared for it (mesh, fabric,
/// reshard-after-forward). Collectives on this bucket run on *its* mesh
/// and fabric, so groups can differ (the HSDP-per-group and multi-tier
/// directions later PRs build on).
pub struct Bucket {
    /// Wrap-unit name from the spec (`g<N>` for legacy flat-array
    /// construction).
    pub name: String,
    pub dbuffer: DBuffer,
    /// Gradient shards (m x S), filled by `reduce_grads`.
    pub grad_shards: Vec<Vec<f32>>,
    /// Global parameter indices of the tensors in this bucket.
    pub param_ids: Vec<usize>,
    /// (name, shape) per tensor, bucket-position order (mirrors
    /// `param_ids` into the engine's global parameter table).
    pub param_meta: Vec<(String, Vec<usize>)>,
    /// Group-local mesh (same fsdp dim as the session; may add replica).
    pub mesh: DeviceMesh,
    /// Group-local fabric model.
    pub fabric: Fabric,
    /// Whether the pipelined executor reshards this group right after its
    /// forward (`true` = the paper's default schedule).
    pub reshard_after_forward: bool,
    /// Wire precision of this group's collectives (from the spec).
    pub comm_precision: CommPrecision,
    /// Per-rank error-feedback residuals (one `S`-element f32 vector per
    /// rank) for the quantized gradient ReduceScatter — the aggregate
    /// quantization error of each owned chunk, re-injected next step.
    /// Empty until the first `Q8` reduction.
    pub ef: Vec<Vec<f32>>,
}

/// Borrow one bucket's state as a [`GroupEnv`] for a group-optimizer
/// step (split field borrows — no clones).
fn bucket_env<'a>(bucket: &'a mut Bucket, comm: &'a dyn Communicator) -> GroupEnv<'a> {
    GroupEnv {
        params: &bucket.param_meta,
        dbuffer: &mut bucket.dbuffer,
        grad_shards: &bucket.grad_shards,
        mesh: &bucket.mesh,
        fabric: &bucket.fabric,
        comm,
    }
}

/// Stage one bucket's per-rank gradient slices into full-buffer-sized
/// buffers at the bucket's layout offsets, charging the transient
/// staging storage to `alloc` until the caller frees the returned block.
/// `grad_of(rank, pos)` yields rank's gradient for the bucket's pos-th
/// tensor. Shared by the sequential reduction (`FsdpEngine::reduce_grads`)
/// and the pipelined executor's async reduction, so the staging
/// convention — and its memory accounting — cannot diverge between
/// schedules.
pub(crate) fn stage_bucket_grads<'g>(
    bucket: &Bucket,
    m: usize,
    alloc: &SharedAllocator,
    grad_of: &dyn Fn(usize, usize) -> &'g [f32],
) -> Result<(Vec<Vec<f32>>, BlockId)> {
    let s = bucket.dbuffer.shard_elems();
    let total = s * m;
    let block = alloc.lock().unwrap().alloc(((total * 4) as u64).max(1))?;
    let mut bufs: Vec<Vec<f32>> = vec![vec![0.0; total]; m];
    for pos in 0..bucket.param_ids.len() {
        let off = bucket.dbuffer.layout.offsets[pos] as usize;
        for (rank, buf) in bufs.iter_mut().enumerate() {
            let g = grad_of(rank, pos);
            buf[off..off + g.len()].copy_from_slice(g);
        }
    }
    Ok((bufs, block))
}

pub struct FsdpEngine {
    /// Session-default mesh (each bucket may carry its own via the spec).
    pub mesh: DeviceMesh,
    /// Session-default fabric (each bucket may carry its own via the spec).
    pub fabric: Fabric,
    /// Cluster backend every collective (and its stats) goes through.
    pub comm: Arc<dyn Communicator>,
    pub buckets: Vec<Bucket>,
    /// name + shape per global parameter index.
    pub params: Vec<(String, Vec<usize>)>,
    /// Caching allocator accounting one device's memory: persistent
    /// shard/grad storage is claimed batched at construction; the
    /// executor's gather/reshard cycles alloc and deterministically free
    /// full buffers through it, so `memory_stats` reports a *measured*
    /// peak.
    pub alloc: SharedAllocator,
    /// Trace sink shared by the executor, the buckets' DBuffers, and the
    /// optimizer dispatch (off unless [`FsdpEngine::set_tracer`] ran).
    pub tracer: Tracer,
    /// Health monitor shared with the executor (off — one branch per
    /// event — unless [`FsdpEngine::set_observer`] ran).
    pub obs: crate::obs::Observer,
    locs: Vec<ParamLoc>,
    m: usize,
}

impl FsdpEngine {
    /// `group_of[i]` assigns parameter i to a bucket (FSDP wrapping unit).
    /// Collectives run on the serial backend; use [`FsdpEngine::new_with_comm`]
    /// to select another.
    pub fn new(
        params: Vec<(String, Vec<usize>)>,
        group_of: &[usize],
        mesh: DeviceMesh,
        policy: &ShardingPolicy,
        fabric: Fabric,
    ) -> Result<FsdpEngine> {
        let comm = CommBuilder::new(CommBackend::Serial).build();
        FsdpEngine::new_with_comm(params, group_of, mesh, policy, fabric, comm)
    }

    /// Legacy flat-array constructor: a thin shim that lifts `group_of`
    /// + the single global policy into a uniform [`ModelSpec`] (groups
    /// `g0..gN`, every group with the same policy, mesh, and fabric) and
    /// plans through [`FsdpEngine::from_spec`]. Bit-identical to the
    /// pre-spec construction.
    pub fn new_with_comm(
        params: Vec<(String, Vec<usize>)>,
        group_of: &[usize],
        mesh: DeviceMesh,
        policy: &ShardingPolicy,
        fabric: Fabric,
        comm: Arc<dyn Communicator>,
    ) -> Result<FsdpEngine> {
        if params.len() != group_of.len() {
            bail!("group_of length mismatch");
        }
        let n_buckets = group_of.iter().max().map(|&g| g + 1).unwrap_or(0);
        let mut spec = ModelSpec::new();
        for b in 0..n_buckets {
            let ids: Vec<usize> =
                (0..params.len()).filter(|&i| group_of[i] == b).collect();
            spec = spec.group(
                ShardGroupSpec::new(format!("g{b}"), GroupFilter::Indices(ids))
                    .policy(policy.clone()),
            );
        }
        FsdpEngine::from_spec(params, &spec, mesh, fabric, comm)
    }

    /// Plan an engine from a declarative [`ModelSpec`]: each shard group
    /// becomes one bucket, laid out by the planner under its *group-local*
    /// sharding policy, carrying its group-local mesh / fabric /
    /// reshard-after-forward choices. `mesh` and `fabric` are the session
    /// defaults groups inherit when they declare no override; a group
    /// mesh must keep the session's fsdp dim size.
    pub fn from_spec(
        params: Vec<(String, Vec<usize>)>,
        spec: &ModelSpec,
        mesh: DeviceMesh,
        fabric: Fabric,
        comm: Arc<dyn Communicator>,
    ) -> Result<FsdpEngine> {
        let m = mesh
            .dim_size("fsdp")
            .context("mesh needs an 'fsdp' dim")?;
        let group_of = spec.assign(&params)?;
        let mut locs = vec![ParamLoc { bucket: 0, idx: 0 }; params.len()];
        let mut buckets = Vec::with_capacity(spec.groups.len());
        let alloc = shared_allocator(FreePolicy::Deterministic, DEVICE_MEM_LIMIT);
        for (b, g) in spec.groups.iter().enumerate() {
            let ids: Vec<usize> =
                (0..params.len()).filter(|&i| group_of[i] == b).collect();
            let g_mesh = match &g.mesh {
                Some(gm) => {
                    if gm.dim_size("fsdp") != Some(m) {
                        bail!(
                            "{}",
                            rt(
                                codes::BAD_TOPOLOGY,
                                format_args!(
                                    "shard group '{}': mesh fsdp dim {:?} must match the \
                                     session's fsdp dim {m}",
                                    g.name,
                                    gm.dim_size("fsdp")
                                )
                            )
                        );
                    }
                    gm.clone()
                }
                None => mesh.clone(),
            };
            let g_fabric = g.fabric.clone().unwrap_or_else(|| fabric.clone());
            // a Q8 wire precision feeds its quant block into the planner:
            // tensor granularities are lcm'd with the block (so device
            // boundaries inside tensors respect it) and the collective
            // alignment forces the shard size to a whole number of blocks
            // — every quant block and its scale live on exactly one device
            let prec_align = g.comm_precision.align_elems();
            let decls: Vec<TensorDecl> = ids
                .iter()
                .map(|&i| {
                    let (name, shape) = &params[i];
                    let numel: u64 = shape.iter().map(|&s| s as u64).product();
                    let base = g.policy.granularity_of(name, shape).max(1);
                    let gran = lcm(base, prec_align).min(numel).max(1);
                    TensorDecl::new(name, numel, gran)
                })
                .collect();
            let layout = planner::plan(&decls, m, lcm(4, prec_align))
                .with_context(|| format!("planning shard group '{}'", g.name))?;
            for (pos, &i) in ids.iter().enumerate() {
                locs[i] = ParamLoc { bucket: b, idx: pos };
            }
            let s = layout.shard_size as usize;
            let param_meta: Vec<(String, Vec<usize>)> =
                ids.iter().map(|&i| params[i].clone()).collect();
            buckets.push(Bucket {
                name: g.name.clone(),
                dbuffer: DBuffer::with_allocator(layout, alloc.clone())
                    .with_context(|| format!("allocating shard group '{}'", g.name))?,
                grad_shards: vec![vec![0.0; s]; m],
                param_ids: ids,
                param_meta,
                mesh: g_mesh,
                fabric: g_fabric,
                reshard_after_forward: g.reshard_after_forward,
                comm_precision: g.comm_precision,
                ef: Vec::new(),
            });
        }
        // persistent gradient-shard storage, claimed in one batched call
        // (a single segment, no inter-bucket fragmentation)
        let grad_sizes: Vec<u64> = buckets
            .iter()
            .map(|b| b.dbuffer.shard_bytes().max(1))
            .collect();
        if !grad_sizes.is_empty() {
            let _grad_blocks = alloc.lock().unwrap().alloc_batch(&grad_sizes)?;
        }
        Ok(FsdpEngine {
            mesh,
            fabric,
            comm,
            buckets,
            params,
            alloc,
            tracer: Tracer::off(),
            obs: crate::obs::Observer::off(),
            locs,
            m,
        })
    }

    /// Attach a trace sink, propagated to every bucket's DBuffer (whose
    /// quant-codec and allocator-wait spans then carry the bucket name).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        for b in &mut self.buckets {
            b.dbuffer.set_tracer(tracer.clone(), &b.name);
        }
        self.tracer = tracer;
    }

    /// Attach a health monitor: the executor publishes step phases,
    /// bucket context, and flight-recorder events through it. The comm
    /// backend carries its own clone (see
    /// [`CommBuilder::observer`](crate::cluster::CommBuilder::observer)),
    /// so call this with the same observer the communicator was built
    /// with.
    pub fn set_observer(&mut self, obs: crate::obs::Observer) {
        self.obs = obs;
    }

    pub fn num_devices(&self) -> usize {
        self.m
    }

    /// Snapshot of the accumulated comm statistics (thread-safe; owned by
    /// the cluster backend).
    pub fn stats(&self) -> CommStats {
        self.comm.stats()
    }

    /// Where parameter `i` lives (bucket + tensor index inside it).
    pub fn param_loc(&self, i: usize) -> ParamLoc {
        self.locs[i]
    }

    /// Zero-copy view of parameter `i`'s full tensor in `rank`'s gathered
    /// buffer (bucket must be gathered). This is what the pipelined
    /// executor feeds compute with — no `device_params` copies.
    pub fn full_param_view(&self, rank: usize, i: usize) -> &[f32] {
        let loc = self.locs[i];
        self.buckets[loc.bucket].dbuffer.full_view(rank, loc.idx)
    }

    /// Measured allocator peaks: (peak reserved, peak allocated) bytes on
    /// the simulated device.
    pub fn memory_stats(&self) -> (u64, u64) {
        let a = self.alloc.lock().unwrap();
        (a.peak_reserved, a.peak_allocated)
    }

    /// Total padded elements per device (memory accounting).
    pub fn shard_elems(&self) -> u64 {
        self.buckets.iter().map(|b| b.dbuffer.layout.shard_size).sum()
    }

    pub fn padding_ratio(&self) -> f64 {
        let pad: u64 = self.buckets.iter().map(|b| b.dbuffer.layout.padding()).sum();
        let real: u64 = self
            .buckets
            .iter()
            .map(|b| b.dbuffer.layout.tensors.iter().map(|t| t.numel).sum::<u64>())
            .sum();
        pad as f64 / real as f64
    }

    /// Load initial full parameters (global order).
    pub fn init_params(&mut self, full: &[Vec<f32>]) -> Result<()> {
        if full.len() != self.params.len() {
            bail!("init_params arity mismatch");
        }
        for (i, data) in full.iter().enumerate() {
            let loc = self.locs[i];
            self.buckets[loc.bucket].dbuffer.write_tensor(loc.idx, data)?;
        }
        Ok(())
    }

    /// AllGather every bucket (in-place, zero-copy views afterwards).
    /// Each bucket's collective is timed on its own fabric and shipped at
    /// its own wire precision (cast-before-comm for `Bf16`/`Q8`).
    pub fn gather_params(&mut self) -> Result<()> {
        for b in &mut self.buckets {
            b.dbuffer
                .all_gather_params(self.comm.as_ref(), &b.fabric, b.comm_precision)?;
        }
        Ok(())
    }

    /// Materialized full parameters for one device (global order). The
    /// copies here feed the PJRT executable's input literals; inside the
    /// engine all access is zero-copy views.
    pub fn device_params(&self, rank: usize) -> Vec<Vec<f32>> {
        (0..self.params.len())
            .map(|i| {
                let loc = self.locs[i];
                self.buckets[loc.bucket].dbuffer.full_view(rank, loc.idx).to_vec()
            })
            .collect()
    }

    /// Read one parameter's full value from the shards (no gather needed).
    pub fn read_param(&self, i: usize) -> Vec<f32> {
        let loc = self.locs[i];
        self.buckets[loc.bucket].dbuffer.read_tensor(loc.idx)
    }

    /// Reshard after forward/backward (drop gathered buffers).
    pub fn release_params(&mut self) {
        for b in &mut self.buckets {
            b.dbuffer.release_full();
        }
    }

    /// ReduceScatter per-device per-parameter gradients into shards,
    /// through the DBuffer reduction path — so HSDP meshes (`replica`
    /// dim > 1) get the cross-replica AllReduce and the alignment
    /// accounting comes from the fabric check, same as every other
    /// collective.
    pub fn reduce_grads(&mut self, grads: &[Vec<Vec<f32>>]) -> Result<()> {
        if grads.len() != self.m {
            bail!("need grads for all {} devices", self.m);
        }
        for bucket in self.buckets.iter_mut() {
            let (mut bufs, block) =
                stage_bucket_grads(bucket, self.m, &self.alloc, &|rank, pos| {
                    &grads[rank][bucket.param_ids[pos]][..]
                })?;
            let Bucket { dbuffer, grad_shards, mesh, fabric, comm_precision, ef, .. } = bucket;
            dbuffer.reduce_gradients_core(
                &mut bufs,
                grad_shards,
                mesh,
                self.comm.as_ref(),
                fabric,
                *comm_precision,
                ef,
            )?;
            self.alloc.lock().unwrap().free(block)?;
        }
        Ok(())
    }

    /// Uniform per-group optimizer dispatch: `opts[bucket]` is that shard
    /// group's [`GroupOptimizer`] (bound from the spec's `OptimBinding`),
    /// so a single run can step Muon matrices next to AdamW embeddings —
    /// no special-cased optimizer paths.
    pub fn optimizer_step_groups(
        &mut self,
        opts: &mut [Box<dyn GroupOptimizer>],
        t: u64,
    ) -> Result<()> {
        if opts.len() != self.buckets.len() {
            bail!(
                "need one group optimizer per shard group ({} given, {} groups)",
                opts.len(),
                self.buckets.len()
            );
        }
        let comm = self.comm.clone();
        for (bucket, opt) in self.buckets.iter_mut().zip(opts.iter_mut()) {
            let timer = self.tracer.timer();
            opt.step_group(bucket_env(bucket, comm.as_ref()), t)?;
            self.tracer.finish_with(timer, Cat::Compute, || {
                Span::new("optim")
                    .lane_compute()
                    .bucket(&bucket.name)
                    .attr("opt", opt.name())
            });
        }
        Ok(())
    }

    /// Flat-shard optimizer step over every bucket. `opts[bucket]` holds
    /// that bucket's optimizer (state is per bucket x rank). Legacy
    /// interface — runs the same per-bucket code as a
    /// [`crate::optim::FlatGroup`] binding.
    pub fn optimizer_step(
        &mut self,
        opts: &mut [Box<dyn ShardOptimizer>],
        t: u64,
    ) -> Result<()> {
        if opts.len() != self.buckets.len() {
            bail!("need one optimizer per bucket");
        }
        let comm = self.comm.clone();
        for (bucket, opt) in self.buckets.iter_mut().zip(opts.iter_mut()) {
            optim_group::flat_bucket_step(opt.as_mut(), bucket_env(bucket, comm.as_ref()), t)?;
        }
        Ok(())
    }

    /// 8-bit Adam step (paper §6.3): quantized state on >=2-D parameters
    /// whose RaggedShard granularity keeps every quant block local
    /// (`lo % block == 0 && len % block == 0` — guaranteed when the
    /// sharding policy assigns 32-row granularity and 32*row % block == 0);
    /// 1-D parameters (norm scales) use the fp32 fallback, as in practice.
    /// State slots are keyed per (parameter, rank).
    pub fn adam8bit_step(
        &mut self,
        a8: &mut crate::optim::Adam8bit,
        fallback: &mut crate::optim::AdamW,
        t: u64,
    ) -> Result<()> {
        let m = self.m;
        let comm = self.comm.clone();
        for bucket in self.buckets.iter_mut() {
            // legacy state keying: slot = global param id * m + rank
            let slot_base: Vec<usize> =
                bucket.param_ids.iter().map(|&pid| pid * m).collect();
            optim_group::adam8bit_bucket_step(
                a8,
                fallback,
                bucket_env(bucket, comm.as_ref()),
                &slot_base,
                t,
            )?;
        }
        Ok(())
    }

    /// Muon step: 2-D parameters go through Alg 2 (redistribute-to-root +
    /// Newton-Schulz); others through the provided fallback optimizer.
    pub fn muon_step(
        &mut self,
        muon: &mut Muon,
        fallback: &mut [Box<dyn ShardOptimizer>],
        t: u64,
    ) -> Result<()> {
        if fallback.len() != self.buckets.len() {
            bail!("need one fallback optimizer per bucket");
        }
        let comm = self.comm.clone();
        for (bucket, fb) in self.buckets.iter_mut().zip(fallback.iter_mut()) {
            optim_group::muon_bucket_step(
                muon,
                fb.as_mut(),
                bucket_env(bucket, comm.as_ref()),
                t,
            )?;
        }
        Ok(())
    }

    /// Per-device bytes of sharded state (params fp32).
    pub fn param_shard_bytes(&self) -> u64 {
        self.shard_elems() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SerialComm;
    use crate::optim::{AdamHyper, AdamW};
    use crate::util::Rng;

    fn tiny_params() -> Vec<(String, Vec<usize>)> {
        vec![
            ("embed".into(), vec![32, 8]),
            ("l0.w".into(), vec![8, 8]),
            ("l0.norm".into(), vec![8]),
            ("l1.w".into(), vec![8, 8]),
            ("l1.norm".into(), vec![8]),
            ("head".into(), vec![8, 32]),
        ]
    }

    fn engine(m: usize) -> FsdpEngine {
        let params = tiny_params();
        let groups = vec![0, 1, 1, 2, 2, 3];
        FsdpEngine::new(
            params,
            &groups,
            DeviceMesh::flat("fsdp", m),
            &ShardingPolicy::element_wise(),
            Fabric::h800(),
        )
        .unwrap()
    }

    fn rand_full(seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        tiny_params()
            .iter()
            .map(|(_, s)| {
                let n: usize = s.iter().product();
                (0..n).map(|_| rng.normal_f32()).collect()
            })
            .collect()
    }

    #[test]
    fn init_gather_roundtrip() {
        let mut e = engine(4);
        let full = rand_full(1);
        e.init_params(&full).unwrap();
        e.gather_params().unwrap();
        for rank in 0..4 {
            let dp = e.device_params(rank);
            assert_eq!(dp.len(), full.len());
            for (a, b) in dp.iter().zip(&full) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn read_param_without_gather() {
        let mut e = engine(2);
        let full = rand_full(2);
        e.init_params(&full).unwrap();
        for i in 0..full.len() {
            assert_eq!(e.read_param(i), full[i]);
        }
    }

    #[test]
    fn reduce_grads_averages_across_devices() {
        let mut e = engine(2);
        let full = rand_full(3);
        e.init_params(&full).unwrap();
        // device r's grad = (r+1) everywhere -> mean 1.5
        let grads: Vec<Vec<Vec<f32>>> = (0..2)
            .map(|r| {
                full.iter()
                    .map(|p| vec![(r + 1) as f32; p.len()])
                    .collect()
            })
            .collect();
        e.reduce_grads(&grads).unwrap();
        for b in &e.buckets {
            for rank in 0..2 {
                // grad shards hold 1.5 wherever a tensor lives; padding
                // regions stay 0
                for &g in &b.grad_shards[rank] {
                    assert!(g == 0.0 || (g - 1.5).abs() < 1e-6, "{g}");
                }
            }
        }
    }

    #[test]
    fn sgd_like_step_moves_params_consistently() {
        // FSDP step must equal single-device update
        let mut e = engine(4);
        let full = rand_full(4);
        e.init_params(&full).unwrap();
        let grads: Vec<Vec<Vec<f32>>> = (0..4)
            .map(|_| full.iter().map(|p| vec![0.5f32; p.len()]).collect())
            .collect();
        e.reduce_grads(&grads).unwrap();
        let mut opts: Vec<Box<dyn ShardOptimizer>> = (0..e.buckets.len())
            .map(|_| {
                Box::new(AdamW::new(AdamHyper { wd: 0.0, ..Default::default() }, 4))
                    as Box<dyn ShardOptimizer>
            })
            .collect();
        e.optimizer_step(&mut opts, 1).unwrap();
        // reference: single-rank AdamW on the full tensors (fresh state
        // per tensor — each tensor is an independent optimization problem)
        for (i, p0) in full.iter().enumerate() {
            let mut h = AdamW::new(AdamHyper { wd: 0.0, ..Default::default() }, 1);
            let mut expect = p0.clone();
            let g = vec![0.5f32; p0.len()];
            h.step(0, 1, &mut expect, &g);
            let got = e.read_param(i);
            for (a, b) in got.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-6, "param {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn muon_step_runs_and_changes_matrices() {
        let mut e = engine(2);
        let full = rand_full(5);
        e.init_params(&full).unwrap();
        let grads: Vec<Vec<Vec<f32>>> = (0..2)
            .map(|_| full.iter().map(|p| vec![0.1f32; p.len()]).collect())
            .collect();
        e.reduce_grads(&grads).unwrap();
        let mut muon = Muon::new(0.02, 0.95, 0.0);
        let mut fb: Vec<Box<dyn ShardOptimizer>> = (0..e.buckets.len())
            .map(|_| Box::new(AdamW::new(AdamHyper::default(), 2)) as Box<dyn ShardOptimizer>)
            .collect();
        e.muon_step(&mut muon, &mut fb, 1).unwrap();
        // hidden matrices changed
        let w = e.read_param(1);
        assert!(w.iter().zip(&full[1]).any(|(a, b)| (a - b).abs() > 1e-6));
        // embed (non-hidden) also changed via fallback
        let emb = e.read_param(0);
        assert!(emb.iter().zip(&full[0]).any(|(a, b)| (a - b).abs() > 1e-6));
    }

    #[test]
    fn hsdp_reduce_grads_runs_replica_allreduce() {
        // regression: the engine used to reimplement reduction without the
        // cross-replica AllReduce that DBuffer::reduce_gradients performs
        let params = tiny_params();
        let groups = vec![0, 1, 1, 2, 2, 3];
        let mut e = FsdpEngine::new(
            params,
            &groups,
            DeviceMesh::new(&[("replica", 2), ("fsdp", 2)]).unwrap(),
            &ShardingPolicy::element_wise(),
            Fabric::h800(),
        )
        .unwrap();
        let full = rand_full(6);
        e.init_params(&full).unwrap();
        // fsdp rank r contributes grad (r+1) everywhere -> fsdp mean 1.5,
        // preserved through the replica AllReduce
        let grads: Vec<Vec<Vec<f32>>> = (0..2)
            .map(|r| {
                full.iter()
                    .map(|p| vec![(r + 1) as f32; p.len()])
                    .collect()
            })
            .collect();
        e.reduce_grads(&grads).unwrap();
        for b in &e.buckets {
            for rank in 0..2 {
                for &g in &b.grad_shards[rank] {
                    assert!(g == 0.0 || (g - 1.5).abs() < 1e-6, "{g}");
                }
            }
        }
        let stats = e.stats();
        assert_eq!(stats.count("all_reduce"), e.buckets.len());
        assert_eq!(stats.count("reduce_scatter"), e.buckets.len());
    }

    #[test]
    fn allocator_accounts_shard_and_gather_storage() {
        let mut e = engine(4);
        let (_, peak_alloc_0) = e.memory_stats();
        assert!(peak_alloc_0 > 0, "persistent shard claims missing");
        let before = e.alloc.lock().unwrap().allocated;
        e.gather_params().unwrap();
        let during = e.alloc.lock().unwrap().allocated;
        assert!(during > before, "gather must claim full buffers");
        e.release_params();
        assert_eq!(e.alloc.lock().unwrap().allocated, before, "reshard frees");
        let (peak_res, peak_alloc) = e.memory_stats();
        assert!(peak_res >= peak_alloc && peak_alloc >= during);
    }

    #[test]
    fn policy_row_granularity_preserves_blocks() {
        let params = vec![("w".into(), vec![64, 16])];
        let policy = ShardingPolicy::uniform_rows(8); // 8x16=128-elem blocks
        let e = FsdpEngine::new(
            params,
            &[0],
            DeviceMesh::flat("fsdp", 4),
            &policy,
            Fabric::h800(),
        )
        .unwrap();
        let spec = e.buckets[0].dbuffer.layout.ragged_spec(0);
        assert_eq!(spec.granularity, 128);
        // every device's share is a whole number of blocks
        for rank in 0..4 {
            assert_eq!(spec.local_numel(rank, 1024) % 128, 0);
        }
    }

    #[test]
    fn padding_small_for_tiny_model() {
        let e = engine(4);
        assert!(e.padding_ratio() < 0.2, "padding {}", e.padding_ratio());
    }

    #[test]
    fn from_spec_plans_group_local_policies() {
        let params = vec![
            ("embed".to_string(), vec![32, 8]),
            ("l0.w".to_string(), vec![64, 16]),
        ];
        let spec = ModelSpec::new()
            .group(ShardGroupSpec::new("embed", GroupFilter::prefix("embed")))
            .group(
                ShardGroupSpec::new("quant", GroupFilter::prefix("l0"))
                    .policy(ShardingPolicy::uniform_rows(8)),
            );
        let e = FsdpEngine::from_spec(
            params,
            &spec,
            DeviceMesh::flat("fsdp", 4),
            Fabric::h800(),
            Arc::new(SerialComm::new()),
        )
        .unwrap();
        assert_eq!(e.buckets[0].name, "embed");
        assert_eq!(e.buckets[1].name, "quant");
        // the 8-row policy applies only to its own group
        assert_eq!(e.buckets[1].dbuffer.layout.ragged_spec(0).granularity, 128);
        assert_eq!(e.buckets[0].dbuffer.layout.ragged_spec(0).granularity, 1);
        assert_eq!(e.buckets[0].param_meta[0].0, "embed");
    }

    #[test]
    fn q8_precision_aligns_planner_to_quant_blocks() {
        let params = vec![
            ("w".to_string(), vec![25, 7]), // 175 elems, deliberately ragged
            ("b".to_string(), vec![13]),
        ];
        let spec = ModelSpec::new().group(
            ShardGroupSpec::new("all", GroupFilter::Rest)
                .comm_precision(CommPrecision::Q8 { block: 32 }),
        );
        let e = FsdpEngine::from_spec(
            params,
            &spec,
            DeviceMesh::flat("fsdp", 4),
            Fabric::h800(),
            Arc::new(SerialComm::new()),
        )
        .unwrap();
        let layout = &e.buckets[0].dbuffer.layout;
        // the shard size is a whole number of quant blocks, so per-rank
        // shard quantization never straddles a device boundary
        assert_eq!(layout.shard_size % 32, 0);
        // tensor granularity is lcm'd with the block (tensors smaller
        // than a block shard whole)
        assert_eq!(layout.tensors[0].granularity, 32);
        assert_eq!(layout.tensors[1].granularity, 13);
        assert_eq!(e.buckets[0].comm_precision, CommPrecision::Q8 { block: 32 });
    }

    #[test]
    fn from_spec_rejects_mismatched_group_mesh() {
        let params = vec![("w".to_string(), vec![16, 16])];
        let spec = ModelSpec::new().group(
            ShardGroupSpec::new("w", GroupFilter::prefix("w"))
                .mesh(DeviceMesh::flat("fsdp", 8)),
        );
        let err = FsdpEngine::from_spec(
            params,
            &spec,
            DeviceMesh::flat("fsdp", 4),
            Fabric::h800(),
            Arc::new(SerialComm::new()),
        )
        .unwrap_err();
        assert!(err.to_string().contains("fsdp dim"), "{err}");
    }

    #[test]
    fn from_spec_group_fabric_and_hsdp_mesh_override() {
        let params = vec![
            ("a.w".to_string(), vec![16, 16]),
            ("b.w".to_string(), vec![16, 16]),
        ];
        let spec = ModelSpec::new()
            .group(
                ShardGroupSpec::new("a", GroupFilter::prefix("a"))
                    .fabric(Fabric::a100())
                    .mesh(DeviceMesh::new(&[("replica", 2), ("fsdp", 2)]).unwrap()),
            )
            .group(ShardGroupSpec::new("b", GroupFilter::prefix("b")));
        let mut e = FsdpEngine::from_spec(
            params,
            &spec,
            DeviceMesh::flat("fsdp", 2),
            Fabric::h800(),
            Arc::new(SerialComm::new()),
        )
        .unwrap();
        assert_eq!(e.buckets[0].fabric.name, "a100");
        assert_eq!(e.buckets[1].fabric.name, "h800");
        let full = vec![vec![0.5f32; 256], vec![0.25f32; 256]];
        e.init_params(&full).unwrap();
        let grads: Vec<Vec<Vec<f32>>> =
            (0..2).map(|_| vec![vec![1.0f32; 256], vec![1.0f32; 256]]).collect();
        e.reduce_grads(&grads).unwrap();
        // only group 'a' has a replica dim: exactly one AllReduce per step
        assert_eq!(e.stats().count("all_reduce"), 1);
        assert_eq!(e.stats().count("reduce_scatter"), 2);
    }
}
