//! Numeric FSDP engine: the `fully_shard` execution path with real data.
//!
//! Parameters live sharded in per-bucket DBuffers (planner-laid-out
//! RaggedShard). A training step is:
//!
//! 1. `gather_params` — in-place AllGather per bucket (zero-copy views);
//! 2. compute — caller runs fwd/bwd per device (PJRT runtime or closure)
//!    on the materialized parameters;
//! 3. `reduce_grads` — per-bucket ReduceScatter into gradient shards
//!    (+ replica AllReduce under HSDP);
//! 4. `optimizer_step` — sharded update (AdamW / SGD / 8-bit Adam on flat
//!    shards; Muon per 2-D matrix via RaggedShard redistribute).
//!
//! The `ShardingPolicy` is the paper's `orig_param_policy`: per-parameter
//! sharding granularity (e.g. 32-row blocks for 8-bit Adam's 32x32 quant
//! tiles) consumed by the planner.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::cluster::{Communicator, SerialComm};
use crate::comm::{CommStats, Fabric};
use crate::dbuffer::DBuffer;
use crate::dtensor::DTensor;
use crate::memory::{shared_allocator, BlockId, FreePolicy, SharedAllocator};
use crate::mesh::DeviceMesh;
use crate::optim::{Muon, ShardOptimizer};
use crate::placement::Placement;
use crate::planner::{self, TensorDecl};

/// Simulated per-device memory limit for the engine's allocator account
/// (generous: the numeric models are tiny; the limit only exists so the
/// allocator's pressure path stays reachable in tests).
const DEVICE_MEM_LIMIT: u64 = 1 << 40;

/// Per-parameter sharding granularity policy (`orig_param_policy`).
#[derive(Debug, Clone)]
pub struct ShardingPolicy {
    /// Default granularity in elements (1 = element-wise).
    pub default_granularity: u64,
    /// Per-parameter override: name -> granularity in *rows* (multiplied
    /// by the row stride), e.g. 32 for 32x32 quant blocks on matrices.
    pub row_granularity: BTreeMap<String, u64>,
}

impl ShardingPolicy {
    pub fn element_wise() -> ShardingPolicy {
        ShardingPolicy { default_granularity: 1, row_granularity: BTreeMap::new() }
    }

    /// Uniform row granularity for every >=2-D parameter (the 8-bit Adam
    /// setup: 32-row blocks).
    pub fn uniform_rows(rows: u64) -> ShardingPolicy {
        let mut p = ShardingPolicy::element_wise();
        p.row_granularity.insert("*".into(), rows);
        p
    }

    pub fn granularity_of(&self, name: &str, shape: &[usize]) -> u64 {
        let row: u64 = shape[1..].iter().map(|&s| s as u64).product::<u64>().max(1);
        let rows_override = self
            .row_granularity
            .get(name)
            .or_else(|| self.row_granularity.get("*"));
        match rows_override {
            Some(&r) if shape.len() >= 2 => r * row,
            _ => self.default_granularity,
        }
    }
}

/// One parameter's location: which bucket, which tensor index inside it.
#[derive(Debug, Clone, Copy)]
pub struct ParamLoc {
    pub bucket: usize,
    pub idx: usize,
}

pub struct Bucket {
    pub dbuffer: DBuffer,
    /// Gradient shards (m x S), filled by `reduce_grads`.
    pub grad_shards: Vec<Vec<f32>>,
    /// Global parameter indices of the tensors in this bucket.
    pub param_ids: Vec<usize>,
}

/// Stage one bucket's per-rank gradient slices into full-buffer-sized
/// buffers at the bucket's layout offsets, charging the transient
/// staging storage to `alloc` until the caller frees the returned block.
/// `grad_of(rank, pos)` yields rank's gradient for the bucket's pos-th
/// tensor. Shared by the sequential reduction (`FsdpEngine::reduce_grads`)
/// and the pipelined executor's async reduction, so the staging
/// convention — and its memory accounting — cannot diverge between
/// schedules.
pub(crate) fn stage_bucket_grads<'g>(
    bucket: &Bucket,
    m: usize,
    alloc: &SharedAllocator,
    grad_of: &dyn Fn(usize, usize) -> &'g [f32],
) -> Result<(Vec<Vec<f32>>, BlockId)> {
    let s = bucket.dbuffer.shard_elems();
    let total = s * m;
    let block = alloc.lock().unwrap().alloc(((total * 4) as u64).max(1))?;
    let mut bufs: Vec<Vec<f32>> = vec![vec![0.0; total]; m];
    for pos in 0..bucket.param_ids.len() {
        let off = bucket.dbuffer.layout.offsets[pos] as usize;
        for (rank, buf) in bufs.iter_mut().enumerate() {
            let g = grad_of(rank, pos);
            buf[off..off + g.len()].copy_from_slice(g);
        }
    }
    Ok((bufs, block))
}

pub struct FsdpEngine {
    pub mesh: DeviceMesh,
    pub fabric: Fabric,
    /// Cluster backend every collective (and its stats) goes through.
    pub comm: Arc<dyn Communicator>,
    pub buckets: Vec<Bucket>,
    /// name + shape per global parameter index.
    pub params: Vec<(String, Vec<usize>)>,
    /// Caching allocator accounting one device's memory: persistent
    /// shard/grad storage is claimed batched at construction; the
    /// executor's gather/reshard cycles alloc and deterministically free
    /// full buffers through it, so `memory_stats` reports a *measured*
    /// peak.
    pub alloc: SharedAllocator,
    locs: Vec<ParamLoc>,
    m: usize,
}

impl FsdpEngine {
    /// `group_of[i]` assigns parameter i to a bucket (FSDP wrapping unit).
    /// Collectives run on the serial backend; use [`FsdpEngine::new_with_comm`]
    /// to select another.
    pub fn new(
        params: Vec<(String, Vec<usize>)>,
        group_of: &[usize],
        mesh: DeviceMesh,
        policy: &ShardingPolicy,
        fabric: Fabric,
    ) -> Result<FsdpEngine> {
        FsdpEngine::new_with_comm(params, group_of, mesh, policy, fabric, Arc::new(SerialComm::new()))
    }

    pub fn new_with_comm(
        params: Vec<(String, Vec<usize>)>,
        group_of: &[usize],
        mesh: DeviceMesh,
        policy: &ShardingPolicy,
        fabric: Fabric,
        comm: Arc<dyn Communicator>,
    ) -> Result<FsdpEngine> {
        if params.len() != group_of.len() {
            bail!("group_of length mismatch");
        }
        let m = mesh
            .dim_size("fsdp")
            .context("mesh needs an 'fsdp' dim")?;
        let n_buckets = group_of.iter().max().map(|&g| g + 1).unwrap_or(0);
        let mut locs = vec![ParamLoc { bucket: 0, idx: 0 }; params.len()];
        let mut buckets = Vec::with_capacity(n_buckets);
        let alloc = shared_allocator(FreePolicy::Deterministic, DEVICE_MEM_LIMIT);
        for b in 0..n_buckets {
            let ids: Vec<usize> = (0..params.len()).filter(|&i| group_of[i] == b).collect();
            let decls: Vec<TensorDecl> = ids
                .iter()
                .map(|&i| {
                    let (name, shape) = &params[i];
                    let numel: u64 = shape.iter().map(|&s| s as u64).product();
                    let g = policy.granularity_of(name, shape).min(numel).max(1);
                    TensorDecl::new(name, numel, g)
                })
                .collect();
            let layout = planner::plan(&decls, m, 4)
                .with_context(|| format!("planning bucket {b}"))?;
            for (pos, &i) in ids.iter().enumerate() {
                locs[i] = ParamLoc { bucket: b, idx: pos };
            }
            let s = layout.shard_size as usize;
            buckets.push(Bucket {
                dbuffer: DBuffer::with_allocator(layout, alloc.clone())
                    .with_context(|| format!("allocating bucket {b}"))?,
                grad_shards: vec![vec![0.0; s]; m],
                param_ids: ids,
            });
        }
        // persistent gradient-shard storage, claimed in one batched call
        // (a single segment, no inter-bucket fragmentation)
        let grad_sizes: Vec<u64> = buckets
            .iter()
            .map(|b| b.dbuffer.shard_bytes().max(1))
            .collect();
        if !grad_sizes.is_empty() {
            let _grad_blocks = alloc.lock().unwrap().alloc_batch(&grad_sizes)?;
        }
        Ok(FsdpEngine { mesh, fabric, comm, buckets, params, alloc, locs, m })
    }

    pub fn num_devices(&self) -> usize {
        self.m
    }

    /// Snapshot of the accumulated comm statistics (thread-safe; owned by
    /// the cluster backend).
    pub fn stats(&self) -> CommStats {
        self.comm.stats()
    }

    /// Where parameter `i` lives (bucket + tensor index inside it).
    pub fn param_loc(&self, i: usize) -> ParamLoc {
        self.locs[i]
    }

    /// Zero-copy view of parameter `i`'s full tensor in `rank`'s gathered
    /// buffer (bucket must be gathered). This is what the pipelined
    /// executor feeds compute with — no `device_params` copies.
    pub fn full_param_view(&self, rank: usize, i: usize) -> &[f32] {
        let loc = self.locs[i];
        self.buckets[loc.bucket].dbuffer.full_view(rank, loc.idx)
    }

    /// Measured allocator peaks: (peak reserved, peak allocated) bytes on
    /// the simulated device.
    pub fn memory_stats(&self) -> (u64, u64) {
        let a = self.alloc.lock().unwrap();
        (a.peak_reserved, a.peak_allocated)
    }

    /// Total padded elements per device (memory accounting).
    pub fn shard_elems(&self) -> u64 {
        self.buckets.iter().map(|b| b.dbuffer.layout.shard_size).sum()
    }

    pub fn padding_ratio(&self) -> f64 {
        let pad: u64 = self.buckets.iter().map(|b| b.dbuffer.layout.padding()).sum();
        let real: u64 = self
            .buckets
            .iter()
            .map(|b| b.dbuffer.layout.tensors.iter().map(|t| t.numel).sum::<u64>())
            .sum();
        pad as f64 / real as f64
    }

    /// Load initial full parameters (global order).
    pub fn init_params(&mut self, full: &[Vec<f32>]) -> Result<()> {
        if full.len() != self.params.len() {
            bail!("init_params arity mismatch");
        }
        for (i, data) in full.iter().enumerate() {
            let loc = self.locs[i];
            self.buckets[loc.bucket].dbuffer.write_tensor(loc.idx, data)?;
        }
        Ok(())
    }

    /// AllGather every bucket (in-place, zero-copy views afterwards).
    pub fn gather_params(&mut self) -> Result<()> {
        for b in &mut self.buckets {
            b.dbuffer.all_gather_params(self.comm.as_ref(), &self.fabric)?;
        }
        Ok(())
    }

    /// Materialized full parameters for one device (global order). The
    /// copies here feed the PJRT executable's input literals; inside the
    /// engine all access is zero-copy views.
    pub fn device_params(&self, rank: usize) -> Vec<Vec<f32>> {
        (0..self.params.len())
            .map(|i| {
                let loc = self.locs[i];
                self.buckets[loc.bucket].dbuffer.full_view(rank, loc.idx).to_vec()
            })
            .collect()
    }

    /// Read one parameter's full value from the shards (no gather needed).
    pub fn read_param(&self, i: usize) -> Vec<f32> {
        let loc = self.locs[i];
        self.buckets[loc.bucket].dbuffer.read_tensor(loc.idx)
    }

    /// Reshard after forward/backward (drop gathered buffers).
    pub fn release_params(&mut self) {
        for b in &mut self.buckets {
            b.dbuffer.release_full();
        }
    }

    /// ReduceScatter per-device per-parameter gradients into shards,
    /// through the DBuffer reduction path — so HSDP meshes (`replica`
    /// dim > 1) get the cross-replica AllReduce and the alignment
    /// accounting comes from the fabric check, same as every other
    /// collective.
    pub fn reduce_grads(&mut self, grads: &[Vec<Vec<f32>>]) -> Result<()> {
        if grads.len() != self.m {
            bail!("need grads for all {} devices", self.m);
        }
        for bucket in self.buckets.iter_mut() {
            let (mut bufs, block) =
                stage_bucket_grads(bucket, self.m, &self.alloc, &|rank, pos| {
                    &grads[rank][bucket.param_ids[pos]][..]
                })?;
            bucket.dbuffer.reduce_gradients_core(
                &mut bufs,
                &mut bucket.grad_shards,
                &self.mesh,
                self.comm.as_ref(),
                &self.fabric,
            )?;
            self.alloc.lock().unwrap().free(block)?;
        }
        Ok(())
    }

    /// Flat-shard optimizer step over every bucket. `opts[bucket]` holds
    /// that bucket's optimizer (state is per bucket x rank).
    pub fn optimizer_step(
        &mut self,
        opts: &mut [Box<dyn ShardOptimizer>],
        t: u64,
    ) -> Result<()> {
        if opts.len() != self.buckets.len() {
            bail!("need one optimizer per bucket");
        }
        for (bucket, opt) in self.buckets.iter_mut().zip(opts.iter_mut()) {
            // split borrow: param shards (mut) and grad shards (shared)
            // are disjoint fields — no per-step gradient clone
            let Bucket { dbuffer, grad_shards, .. } = bucket;
            for rank in 0..self.m {
                opt.step(rank, t, &mut dbuffer.shards[rank], &grad_shards[rank]);
            }
        }
        Ok(())
    }

    /// 8-bit Adam step (paper §6.3): quantized state on >=2-D parameters
    /// whose RaggedShard granularity keeps every quant block local
    /// (`lo % block == 0 && len % block == 0` — guaranteed when the
    /// sharding policy assigns 32-row granularity and 32*row % block == 0);
    /// 1-D parameters (norm scales) use the fp32 fallback, as in practice.
    /// State slots are keyed per (parameter, rank).
    pub fn adam8bit_step(
        &mut self,
        a8: &mut crate::optim::Adam8bit,
        fallback: &mut crate::optim::AdamW,
        t: u64,
    ) -> Result<()> {
        use crate::optim::ShardOptimizer;
        let m = self.m;
        let block = a8.block as u64;
        for b_idx in 0..self.buckets.len() {
            for pos in 0..self.buckets[b_idx].param_ids.len() {
                let pid = self.buckets[b_idx].param_ids[pos];
                let shape = self.params[pid].1.clone();
                // split borrow: grads read-only alongside mutable params
                let Bucket { dbuffer, grad_shards, .. } = &mut self.buckets[b_idx];
                for rank in 0..m {
                    let Some((lo, hi)) = dbuffer.layout.local_slice(pos, rank) else {
                        continue;
                    };
                    let off = dbuffer.layout.offsets[pos];
                    let s = dbuffer.layout.shard_size;
                    let a = (off + lo - rank as u64 * s) as usize;
                    let len = (hi - lo) as usize;
                    let grad = &grad_shards[rank][a..a + len];
                    let slice = &mut dbuffer.shards[rank][a..a + len];
                    let slot = pid * m + rank;
                    let blocks_ok = lo % block == 0 && (len as u64) % block == 0;
                    if shape.len() >= 2 && blocks_ok {
                        a8.step(slot, t, slice, grad);
                    } else {
                        fallback.step(slot, t, slice, grad);
                    }
                }
            }
        }
        Ok(())
    }

    /// Muon step: 2-D parameters go through Alg 2 (redistribute-to-root +
    /// Newton-Schulz); others through the provided fallback optimizer.
    pub fn muon_step(
        &mut self,
        muon: &mut Muon,
        fallback: &mut [Box<dyn ShardOptimizer>],
        t: u64,
    ) -> Result<()> {
        for b_idx in 0..self.buckets.len() {
            for pos in 0..self.buckets[b_idx].param_ids.len() {
                let pid = self.buckets[b_idx].param_ids[pos];
                let (name, shape) = self.params[pid].clone();
                let is_hidden_matrix = shape.len() == 2
                    && !name.contains("embed")
                    && !name.contains("head");
                if is_hidden_matrix {
                    let spec = self.buckets[b_idx].dbuffer.layout.ragged_spec(pos);
                    let numel: u64 = shape.iter().map(|&s| s as u64).product();
                    spec.validate(numel)?;
                    let bucket = &self.buckets[b_idx];
                    let collect = |src: &dyn Fn(usize) -> Vec<f32>| -> Vec<Vec<f32>> {
                        (0..self.m).map(src).collect()
                    };
                    let p_locals = collect(&|rank| {
                        bucket
                            .dbuffer
                            .local_view(rank, pos)
                            .map(|(_, v)| v.to_vec())
                            .unwrap_or_default()
                    });
                    let g_locals = collect(&|rank| {
                        bucket
                            .dbuffer
                            .local_view(rank, pos)
                            .map(|((lo, hi), _)| {
                                let off = bucket.dbuffer.layout.offsets[pos];
                                let s = bucket.dbuffer.layout.shard_size;
                                let a = (off + lo - rank as u64 * s) as usize;
                                bucket.grad_shards[rank][a..a + (hi - lo) as usize].to_vec()
                            })
                            .unwrap_or_default()
                    });
                    let param = DTensor {
                        global_shape: shape.clone(),
                        placement: Placement::RaggedShard(spec.clone()),
                        locals: p_locals,
                    };
                    let grad = DTensor {
                        global_shape: shape.clone(),
                        placement: Placement::RaggedShard(spec),
                        locals: g_locals,
                    };
                    let updated = muon.step_matrix(
                        &name,
                        (shape[0], shape[1]),
                        &param,
                        &grad,
                        &self.fabric,
                        self.comm.as_ref(),
                    )?;
                    // write updated shards back into the DBuffer
                    let bucket = &mut self.buckets[b_idx];
                    for rank in 0..self.m {
                        if let Some((_, view)) = bucket.dbuffer.local_view_mut(rank, pos) {
                            view.copy_from_slice(&updated.locals[rank]);
                        }
                    }
                } else {
                    // fallback optimizer on this tensor's local slices
                    // (split borrow — no gradient clone)
                    let Bucket { dbuffer, grad_shards, .. } = &mut self.buckets[b_idx];
                    for rank in 0..self.m {
                        if let Some((lo, hi)) = dbuffer.layout.local_slice(pos, rank) {
                            let off = dbuffer.layout.offsets[pos];
                            let s = dbuffer.layout.shard_size;
                            let a = (off + lo - rank as u64 * s) as usize;
                            let len = (hi - lo) as usize;
                            let grad = &grad_shards[rank][a..a + len];
                            let shard = &mut dbuffer.shards[rank][a..a + len];
                            fallback[b_idx].step(rank, t, shard, grad);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Per-device bytes of sharded state (params fp32).
    pub fn param_shard_bytes(&self) -> u64 {
        self.shard_elems() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{AdamHyper, AdamW};
    use crate::util::Rng;

    fn tiny_params() -> Vec<(String, Vec<usize>)> {
        vec![
            ("embed".into(), vec![32, 8]),
            ("l0.w".into(), vec![8, 8]),
            ("l0.norm".into(), vec![8]),
            ("l1.w".into(), vec![8, 8]),
            ("l1.norm".into(), vec![8]),
            ("head".into(), vec![8, 32]),
        ]
    }

    fn engine(m: usize) -> FsdpEngine {
        let params = tiny_params();
        let groups = vec![0, 1, 1, 2, 2, 3];
        FsdpEngine::new(
            params,
            &groups,
            DeviceMesh::flat("fsdp", m),
            &ShardingPolicy::element_wise(),
            Fabric::h800(),
        )
        .unwrap()
    }

    fn rand_full(seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        tiny_params()
            .iter()
            .map(|(_, s)| {
                let n: usize = s.iter().product();
                (0..n).map(|_| rng.normal_f32()).collect()
            })
            .collect()
    }

    #[test]
    fn init_gather_roundtrip() {
        let mut e = engine(4);
        let full = rand_full(1);
        e.init_params(&full).unwrap();
        e.gather_params().unwrap();
        for rank in 0..4 {
            let dp = e.device_params(rank);
            assert_eq!(dp.len(), full.len());
            for (a, b) in dp.iter().zip(&full) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn read_param_without_gather() {
        let mut e = engine(2);
        let full = rand_full(2);
        e.init_params(&full).unwrap();
        for i in 0..full.len() {
            assert_eq!(e.read_param(i), full[i]);
        }
    }

    #[test]
    fn reduce_grads_averages_across_devices() {
        let mut e = engine(2);
        let full = rand_full(3);
        e.init_params(&full).unwrap();
        // device r's grad = (r+1) everywhere -> mean 1.5
        let grads: Vec<Vec<Vec<f32>>> = (0..2)
            .map(|r| {
                full.iter()
                    .map(|p| vec![(r + 1) as f32; p.len()])
                    .collect()
            })
            .collect();
        e.reduce_grads(&grads).unwrap();
        for b in &e.buckets {
            for rank in 0..2 {
                // grad shards hold 1.5 wherever a tensor lives; padding
                // regions stay 0
                for &g in &b.grad_shards[rank] {
                    assert!(g == 0.0 || (g - 1.5).abs() < 1e-6, "{g}");
                }
            }
        }
    }

    #[test]
    fn sgd_like_step_moves_params_consistently() {
        // FSDP step must equal single-device update
        let mut e = engine(4);
        let full = rand_full(4);
        e.init_params(&full).unwrap();
        let grads: Vec<Vec<Vec<f32>>> = (0..4)
            .map(|_| full.iter().map(|p| vec![0.5f32; p.len()]).collect())
            .collect();
        e.reduce_grads(&grads).unwrap();
        let mut opts: Vec<Box<dyn ShardOptimizer>> = (0..e.buckets.len())
            .map(|_| {
                Box::new(AdamW::new(AdamHyper { wd: 0.0, ..Default::default() }, 4))
                    as Box<dyn ShardOptimizer>
            })
            .collect();
        e.optimizer_step(&mut opts, 1).unwrap();
        // reference: single-rank AdamW on the full tensors (fresh state
        // per tensor — each tensor is an independent optimization problem)
        for (i, p0) in full.iter().enumerate() {
            let mut h = AdamW::new(AdamHyper { wd: 0.0, ..Default::default() }, 1);
            let mut expect = p0.clone();
            let g = vec![0.5f32; p0.len()];
            h.step(0, 1, &mut expect, &g);
            let got = e.read_param(i);
            for (a, b) in got.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-6, "param {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn muon_step_runs_and_changes_matrices() {
        let mut e = engine(2);
        let full = rand_full(5);
        e.init_params(&full).unwrap();
        let grads: Vec<Vec<Vec<f32>>> = (0..2)
            .map(|_| full.iter().map(|p| vec![0.1f32; p.len()]).collect())
            .collect();
        e.reduce_grads(&grads).unwrap();
        let mut muon = Muon::new(0.02, 0.95, 0.0);
        let mut fb: Vec<Box<dyn ShardOptimizer>> = (0..e.buckets.len())
            .map(|_| Box::new(AdamW::new(AdamHyper::default(), 2)) as Box<dyn ShardOptimizer>)
            .collect();
        e.muon_step(&mut muon, &mut fb, 1).unwrap();
        // hidden matrices changed
        let w = e.read_param(1);
        assert!(w.iter().zip(&full[1]).any(|(a, b)| (a - b).abs() > 1e-6));
        // embed (non-hidden) also changed via fallback
        let emb = e.read_param(0);
        assert!(emb.iter().zip(&full[0]).any(|(a, b)| (a - b).abs() > 1e-6));
    }

    #[test]
    fn hsdp_reduce_grads_runs_replica_allreduce() {
        // regression: the engine used to reimplement reduction without the
        // cross-replica AllReduce that DBuffer::reduce_gradients performs
        let params = tiny_params();
        let groups = vec![0, 1, 1, 2, 2, 3];
        let mut e = FsdpEngine::new(
            params,
            &groups,
            DeviceMesh::new(&[("replica", 2), ("fsdp", 2)]).unwrap(),
            &ShardingPolicy::element_wise(),
            Fabric::h800(),
        )
        .unwrap();
        let full = rand_full(6);
        e.init_params(&full).unwrap();
        // fsdp rank r contributes grad (r+1) everywhere -> fsdp mean 1.5,
        // preserved through the replica AllReduce
        let grads: Vec<Vec<Vec<f32>>> = (0..2)
            .map(|r| {
                full.iter()
                    .map(|p| vec![(r + 1) as f32; p.len()])
                    .collect()
            })
            .collect();
        e.reduce_grads(&grads).unwrap();
        for b in &e.buckets {
            for rank in 0..2 {
                for &g in &b.grad_shards[rank] {
                    assert!(g == 0.0 || (g - 1.5).abs() < 1e-6, "{g}");
                }
            }
        }
        let stats = e.stats();
        assert_eq!(stats.count("all_reduce"), e.buckets.len());
        assert_eq!(stats.count("reduce_scatter"), e.buckets.len());
    }

    #[test]
    fn allocator_accounts_shard_and_gather_storage() {
        let mut e = engine(4);
        let (_, peak_alloc_0) = e.memory_stats();
        assert!(peak_alloc_0 > 0, "persistent shard claims missing");
        let before = e.alloc.lock().unwrap().allocated;
        e.gather_params().unwrap();
        let during = e.alloc.lock().unwrap().allocated;
        assert!(during > before, "gather must claim full buffers");
        e.release_params();
        assert_eq!(e.alloc.lock().unwrap().allocated, before, "reshard frees");
        let (peak_res, peak_alloc) = e.memory_stats();
        assert!(peak_res >= peak_alloc && peak_alloc >= during);
    }

    #[test]
    fn policy_row_granularity_preserves_blocks() {
        let params = vec![("w".into(), vec![64, 16])];
        let policy = ShardingPolicy::uniform_rows(8); // 8x16=128-elem blocks
        let e = FsdpEngine::new(
            params,
            &[0],
            DeviceMesh::flat("fsdp", 4),
            &policy,
            Fabric::h800(),
        )
        .unwrap();
        let spec = e.buckets[0].dbuffer.layout.ragged_spec(0);
        assert_eq!(spec.granularity, 128);
        // every device's share is a whole number of blocks
        for rank in 0..4 {
            assert_eq!(spec.local_numel(rank, 1024) % 128, 0);
        }
    }

    #[test]
    fn padding_small_for_tiny_model() {
        let e = engine(4);
        assert!(e.padding_ratio() < 0.2, "padding {}", e.padding_ratio());
    }
}
