//! Distributed Buffer (DBuffer) — the paper's high-performance grouped
//! communication primitive (§5, Fig 7).
//!
//! A DBuffer backs one FSDP communication bucket (a group of RaggedShard
//! DTensors laid out by the planner). Key properties reproduced here:
//!
//! * **zero-copy access**: tensors live at planner-assigned offsets of the
//!   global buffer; the sharded state *is* the collective's input and the
//!   gathered buffer *is* the compute's parameter memory — views, not
//!   copies (`local_view`, `full_view`);
//! * **grouped fused ops**: `zero_grads`/`scale_all` touch the whole
//!   buffer in one pass instead of one kernel per tensor;
//! * **in-place collectives**: AllGather fills the same persistent full
//!   buffer; ReduceScatter reduces into the shard region in place — both
//!   available as nonblocking `begin_gather`/`finish_gather` halves over
//!   the cluster backend's [`PendingOp`] handles for the pipelined
//!   executor (`fsdp::exec`);
//! * **allocator-backed storage**: with [`DBuffer::with_allocator`], the
//!   persistent shard storage is claimed through
//!   `CachingAllocator::alloc_batch` and the transient full (gathered)
//!   buffer is acquired at gather and deterministically freed at
//!   reshard-after-forward — so the schedule's peak reserved bytes are
//!   *measured* by the allocator rather than asserted (no record_stream
//!   hazard; freed segments are immediately reusable by the next
//!   bucket's gather).
//!
//! N-D semantics (Fig 7): with an HSDP mesh `[replica, fsdp]`, gradient
//! reduction is ReduceScatter within the fsdp dim followed by AllReduce
//! across the replica dim — `reduce_gradients` implements exactly that,
//! and `reduce_gradients_core`/`reduce_gradients_finish` expose the same
//! path for caller-owned gradient shards (the FSDP engine) and for
//! asynchronously-issued ReduceScatters.

use anyhow::{bail, Result};

use crate::analysis::diag::{codes, rt};
use crate::cluster::launch::{decode_wire, encode_wire, reduce_scatter_launch};
use crate::cluster::{Communicator, LaunchOp, PendingOp};
use crate::comm::{CommRecord, Fabric};
use crate::memory::{BlockId, SharedAllocator};
use crate::mesh::DeviceMesh;
use crate::planner::Layout;
use crate::quant::CommPrecision;
use crate::trace::{Cat, Span, Tracer};

/// Per-bucket distributed buffer over an FSDP group of `m` devices.
#[derive(Debug)]
pub struct DBuffer {
    pub layout: Layout,
    /// Per-device local shard (S elements each) — the persistent sharded
    /// state (fp32 master weights or gradient shards).
    pub shards: Vec<Vec<f32>>,
    /// Per-device full buffer (m*S elements) — unsharded staging for
    /// compute; allocated once, reused in place every iteration.
    pub full: Vec<Vec<f32>>,
    /// Whether `full` currently holds gathered (valid) data.
    pub gathered: bool,
    /// Optional caching-allocator accounting (one simulated device's
    /// memory view; see module docs).
    alloc: Option<SharedAllocator>,
    /// Persistent claim for the shard storage (alloc_batch; never freed).
    _shard_block: Option<BlockId>,
    /// Transient claim for the gathered full buffer (alive while
    /// `gathered` or a gather is in flight).
    full_block: Option<BlockId>,
    /// Transient claim for quantized wire buffers (alive while an encoded
    /// gather is in flight).
    wire_block: Option<BlockId>,
    /// A quantized (wire-encoded) gather is in flight: `full` stays home
    /// but must not be read until `finish_gather` decodes into it.
    wire_inflight: bool,
    /// Trace sink for quant-codec and allocator-wait spans (off by
    /// default — every site then costs one untaken branch).
    tracer: Tracer,
    /// Bucket label attached to this buffer's spans.
    label: String,
}

impl DBuffer {
    pub fn new(layout: Layout) -> DBuffer {
        let m = layout.num_devices;
        let s = layout.shard_size as usize;
        DBuffer {
            shards: vec![vec![0.0; s]; m],
            full: vec![vec![0.0; m * s]; m],
            layout,
            gathered: false,
            alloc: None,
            _shard_block: None,
            full_block: None,
            wire_block: None,
            wire_inflight: false,
            tracer: Tracer::off(),
            label: String::new(),
        }
    }

    /// Attach a trace sink; this buffer's spans carry `label` as their
    /// `bucket` attribute.
    pub fn set_tracer(&mut self, tracer: Tracer, label: &str) {
        self.tracer = tracer;
        self.label = label.to_string();
    }

    /// Like [`DBuffer::new`], but every byte of storage is accounted
    /// against `alloc`: the persistent per-device shard is claimed up
    /// front via `alloc_batch`, and the full buffer is acquired/freed
    /// around each gather/reshard cycle so the allocator's peak-reserved
    /// counter measures the executor's real memory schedule.
    pub fn with_allocator(layout: Layout, alloc: SharedAllocator) -> Result<DBuffer> {
        let mut db = DBuffer::new(layout);
        let bytes = db.shard_bytes().max(1);
        let ids = alloc.lock().unwrap().alloc_batch(&[bytes])?;
        db._shard_block = ids.into_iter().next();
        db.alloc = Some(alloc);
        Ok(db)
    }

    /// Bytes of one device's full (gathered) buffer.
    pub fn full_bytes(&self) -> u64 {
        self.layout.shard_size * self.layout.num_devices as u64 * 4
    }

    /// Claim the transient full-buffer storage (no-op when already held
    /// or when no allocator is attached).
    fn acquire_full(&mut self) -> Result<()> {
        if let Some(alloc) = &self.alloc {
            if self.full_block.is_none() {
                let bytes = self.full_bytes().max(1);
                let t = self.tracer.timer();
                self.full_block = Some(alloc.lock().unwrap().alloc(bytes)?);
                self.tracer.finish_with(t, Cat::Compute, || {
                    Span::new("alloc_wait").bucket(&self.label).bytes(bytes)
                });
            }
        }
        Ok(())
    }

    pub fn num_devices(&self) -> usize {
        self.layout.num_devices
    }

    pub fn shard_elems(&self) -> usize {
        self.layout.shard_size as usize
    }

    /// Bytes of one device's sharded state.
    pub fn shard_bytes(&self) -> u64 {
        self.layout.shard_size * 4
    }

    /// Scatter a global tensor's data into the owning shards (init path).
    pub fn write_tensor(&mut self, idx: usize, data: &[f32]) -> Result<()> {
        let t = &self.layout.tensors[idx];
        if data.len() as u64 != t.numel {
            bail!("write_tensor: {} != {}", data.len(), t.numel);
        }
        let s = self.layout.shard_size;
        let off = self.layout.offsets[idx];
        for rank in 0..self.num_devices() {
            if let Some((lo, hi)) = self.layout.local_slice(idx, rank) {
                let dst_lo = (off + lo - rank as u64 * s) as usize;
                self.shards[rank][dst_lo..dst_lo + (hi - lo) as usize]
                    .copy_from_slice(&data[lo as usize..hi as usize]);
            }
        }
        Ok(())
    }

    /// Read a tensor back from the shards (checkpoint path).
    pub fn read_tensor(&self, idx: usize) -> Vec<f32> {
        let t = &self.layout.tensors[idx];
        let s = self.layout.shard_size;
        let off = self.layout.offsets[idx];
        let mut out = vec![0.0f32; t.numel as usize];
        for rank in 0..self.num_devices() {
            if let Some((lo, hi)) = self.layout.local_slice(idx, rank) {
                let src_lo = (off + lo - rank as u64 * s) as usize;
                out[lo as usize..hi as usize].copy_from_slice(
                    &self.shards[rank][src_lo..src_lo + (hi - lo) as usize],
                );
            }
        }
        out
    }

    /// Zero-copy view of tensor `idx`'s slice living on `rank`'s shard.
    /// Returns (tensor-relative range, slice into the shard).
    pub fn local_view(&self, rank: usize, idx: usize) -> Option<((u64, u64), &[f32])> {
        let (lo, hi) = self.layout.local_slice(idx, rank)?;
        let off = self.layout.offsets[idx];
        let s = self.layout.shard_size;
        let a = (off + lo - rank as u64 * s) as usize;
        Some(((lo, hi), &self.shards[rank][a..a + (hi - lo) as usize]))
    }

    pub fn local_view_mut(
        &mut self,
        rank: usize,
        idx: usize,
    ) -> Option<((u64, u64), &mut [f32])> {
        let (lo, hi) = self.layout.local_slice(idx, rank)?;
        let off = self.layout.offsets[idx];
        let s = self.layout.shard_size;
        let a = (off + lo - rank as u64 * s) as usize;
        Some(((lo, hi), &mut self.shards[rank][a..a + (hi - lo) as usize]))
    }

    /// Zero-copy view of the *whole* tensor `idx` in `rank`'s gathered
    /// full buffer (valid after `all_gather_params`). This is the paper's
    /// zero-copy claim: the tensor is contiguous at a planner-known offset.
    pub fn full_view(&self, rank: usize, idx: usize) -> &[f32] {
        debug_assert!(
            self.gathered,
            "{}",
            rt(codes::READ_BEFORE_GATHER, "full buffer not gathered")
        );
        let off = self.layout.offsets[idx] as usize;
        let n = self.layout.tensors[idx].numel as usize;
        &self.full[rank][off..off + n]
    }

    pub fn full_view_mut(&mut self, rank: usize, idx: usize) -> &mut [f32] {
        let off = self.layout.offsets[idx] as usize;
        let n = self.layout.tensors[idx].numel as usize;
        &mut self.full[rank][off..off + n]
    }

    /// In-place precision-aware parameter AllGather, one descriptor end
    /// to end: each rank's shard is published into every rank's
    /// persistent full buffer. `F32` runs the collective on `full`
    /// directly (zero-copy on both ends: the shard region of `full` is
    /// first filled from `shards`, simulating that they alias; one
    /// memcpy models the aliased write). `Bf16`/`Q8` encode each shard,
    /// ship the packed wire buffers through the descriptor's transport
    /// lowering, and dequantize on arrival. Wire-byte accounting (true
    /// payload + scale + pad) comes from the descriptor's measured wire
    /// volume in both cases.
    pub fn all_gather_params(
        &mut self,
        comm: &dyn Communicator,
        fabric: &Fabric,
        prec: CommPrecision,
    ) -> Result<()> {
        let m = self.num_devices();
        let s = self.shard_elems();
        let l = comm.describe(LaunchOp::AllGather, m, s).with_precision(prec);
        if prec.is_f32() {
            if self.full.len() != m {
                bail!("all_gather_params: an async gather is in flight");
            }
            self.acquire_full()?;
            // split borrow: full (mut) and shards (shared) are disjoint
            // fields, so no defensive copy is needed
            for (rank, (full, shard)) in self.full.iter_mut().zip(&self.shards).enumerate() {
                full[rank * s..(rank + 1) * s].copy_from_slice(shard);
            }
            comm.launch(&l, &mut self.full)?;
        } else {
            if self.wire_inflight {
                bail!("all_gather_params: an encoded gather is in flight");
            }
            self.acquire_full()?;
            let t = l.transport();
            self.acquire_wire(m * t.elems)?;
            let mut wire = self.encode_shard_wire(prec);
            comm.launch(&t, &mut wire)?;
            self.decode_full_from_wire(prec, &wire);
            self.release_wire();
        }
        self.gathered = true;
        comm.record(l.comm_record(fabric));
        Ok(())
    }

    /// Claim transient allocator storage for quantized wire buffers.
    fn acquire_wire(&mut self, words: usize) -> Result<()> {
        if let Some(alloc) = &self.alloc {
            if self.wire_block.is_none() {
                let bytes = ((words * 4) as u64).max(1);
                let t = self.tracer.timer();
                self.wire_block = Some(alloc.lock().unwrap().alloc(bytes)?);
                self.tracer.finish_with(t, Cat::Compute, || {
                    Span::new("alloc_wait").bucket(&self.label).bytes(bytes)
                });
            }
        }
        Ok(())
    }

    fn release_wire(&mut self) {
        if let (Some(alloc), Some(id)) = (&self.alloc, self.wire_block.take()) {
            alloc.lock().unwrap().free(id).expect("wire block double-freed");
        }
    }

    /// Encode every rank's local shard into its slot of a packed wire
    /// buffer set (rank k owns `wire[k][k*w..(k+1)*w]`) — the
    /// cast-before-comm half of the quantized AllGather.
    fn encode_shard_wire(&self, prec: CommPrecision) -> Vec<Vec<f32>> {
        let m = self.num_devices();
        let w = prec.wire_words(self.shard_elems());
        let t = self.tracer.timer();
        let mut wire: Vec<Vec<f32>> = vec![vec![0.0; m * w]; m];
        for (rank, (wb, shard)) in wire.iter_mut().zip(&self.shards).enumerate() {
            encode_wire(prec, shard, &mut wb[rank * w..(rank + 1) * w]);
        }
        self.tracer.finish_with(t, Cat::Comm, || {
            Span::new("quant_encode")
                .bucket(&self.label)
                .bytes((w * 4) as u64)
                .attr("prec", prec.name())
        });
        wire
    }

    /// Decode every gathered wire slot into the persistent full buffers.
    /// Every rank — the shard owner included — receives the *dequantized*
    /// values, so all ranks compute on identical parameters while the
    /// fp32 master shards stay exact.
    fn decode_full_from_wire(&mut self, prec: CommPrecision, wire: &[Vec<f32>]) {
        let m = self.num_devices();
        let s = self.shard_elems();
        let w = prec.wire_words(s);
        let t = self.tracer.timer();
        for (rank, full) in self.full.iter_mut().enumerate() {
            for k in 0..m {
                decode_wire(
                    prec,
                    &wire[rank][k * w..(k + 1) * w],
                    &mut full[k * s..(k + 1) * s],
                );
            }
        }
        self.tracer.finish_with(t, Cat::Comm, || {
            Span::new("quant_decode")
                .bucket(&self.label)
                .bytes((m * w * 4) as u64)
                .attr("prec", prec.name())
        });
    }

    /// Begin a nonblocking precision-aware gather. For `F32` the full
    /// buffers move into the returned [`PendingOp`] (their shard regions
    /// pre-filled from the local shards) and come back via
    /// [`DBuffer::finish_gather`]; until then `full` is empty. For
    /// `Bf16`/`Q8` the *encoded wire buffers* travel in the returned op
    /// while `full` stays home, and [`DBuffer::finish_gather`] decodes
    /// on completion — which is how the pipelined executor overlaps
    /// bucket *l*'s dequant with bucket *l+1*'s in-flight quantized
    /// AllGather. Either way `gathered` stays false until completion.
    pub fn begin_gather(
        &mut self,
        comm: &dyn Communicator,
        prec: CommPrecision,
    ) -> Result<PendingOp> {
        if self.gathered {
            bail!("{}", rt(codes::HANDLE_DISCIPLINE, "begin_gather: buffer already gathered"));
        }
        let m = self.num_devices();
        let s = self.shard_elems();
        let l = comm
            .describe(LaunchOp::AllGather, m, s)
            .with_precision(prec)
            .asynchronous();
        if prec.is_f32() {
            if self.full.len() != m {
                bail!(
                    "{}",
                    rt(codes::HANDLE_DISCIPLINE, "begin_gather: a gather is already in flight")
                );
            }
            self.acquire_full()?;
            for (rank, (full, shard)) in self.full.iter_mut().zip(&self.shards).enumerate() {
                full[rank * s..(rank + 1) * s].copy_from_slice(shard);
            }
            let bufs = std::mem::take(&mut self.full);
            return Ok(comm.launch_async(&l, bufs));
        }
        if self.wire_inflight {
            bail!(
                "{}",
                rt(codes::HANDLE_DISCIPLINE, "begin_gather: a gather is already in flight")
            );
        }
        self.acquire_full()?;
        let t = l.transport();
        self.acquire_wire(m * t.elems)?;
        let wire = self.encode_shard_wire(prec);
        self.wire_inflight = true;
        Ok(comm.launch_async(&t, wire))
    }

    /// Complete a gather started with [`DBuffer::begin_gather`]: blocks
    /// until the exchange finishes, decodes encoded wire slots into the
    /// full buffers (quantized precisions), takes dense buffers back
    /// (`F32`), and records the op with the descriptor's measured wire
    /// bytes on the fabric model.
    pub fn finish_gather(
        &mut self,
        op: PendingOp,
        comm: &dyn Communicator,
        fabric: &Fabric,
        prec: CommPrecision,
    ) -> Result<()> {
        let m = self.num_devices();
        let s = self.shard_elems();
        let l = comm.describe(LaunchOp::AllGather, m, s).with_precision(prec);
        if prec.is_f32() {
            return match op.wait() {
                Ok(bufs) => {
                    self.full = bufs;
                    self.gathered = true;
                    comm.record(l.comm_record(fabric));
                    Ok(())
                }
                Err(e) => {
                    // restore a usable (ungathered) state: fresh full
                    // storage and the transient allocator claim released
                    self.full = vec![vec![0.0; m * s]; m];
                    self.release_full();
                    Err(e)
                }
            };
        }
        if !self.wire_inflight {
            bail!("{}", rt(codes::HANDLE_DISCIPLINE, "finish_gather: no encoded gather in flight"));
        }
        self.wire_inflight = false;
        match op.wait() {
            Ok(wire) => {
                self.decode_full_from_wire(prec, &wire);
                self.release_wire();
                self.gathered = true;
                comm.record(l.comm_record(fabric));
                Ok(())
            }
            Err(e) => {
                // restore a usable (ungathered) state and release the
                // transient claims
                self.release_wire();
                self.release_full();
                Err(e)
            }
        }
    }

    /// Release the gathered full buffers (FSDP reshard-after-forward).
    /// The host storage persists (in-place reuse), but the allocator —
    /// when attached — sees a deterministic free, so the next bucket's
    /// gather can reuse the segment immediately.
    pub fn release_full(&mut self) {
        self.gathered = false;
        if self.wire_inflight {
            // an encoded gather still owns the wire storage — keep the
            // claims; finish_gather (or its error path) releases them
            debug_assert!(
                false,
                "{}",
                rt(codes::LIFETIME_IMBALANCE, "release_full during in-flight encoded gather")
            );
            return;
        }
        if self.full.len() != self.num_devices() {
            // an async gather still owns the storage — keep the allocator
            // claim; finish_gather (or its error path) releases it
            debug_assert!(
                false,
                "{}",
                rt(codes::LIFETIME_IMBALANCE, "release_full during in-flight gather")
            );
            return;
        }
        if let (Some(alloc), Some(id)) = (&self.alloc, self.full_block.take()) {
            alloc
                .lock()
                .unwrap()
                .free(id)
                .expect("full-buffer block double-freed");
        }
    }

    /// ReduceScatter scale for a reduction over `mesh`: mean over the
    /// fsdp dim *and* the replica dim (the cross-replica AllReduce in
    /// `reduce_gradients_finish` restores the replica factor).
    pub fn reduce_scale(&self, mesh: &DeviceMesh) -> f32 {
        let replicas = mesh.dim_size("replica").unwrap_or(1);
        1.0 / (self.num_devices() * replicas) as f32
    }

    /// In-place gradient ReduceScatter over the fsdp dim, then (if the
    /// mesh has a replica dim) AllReduce of the shard across replicas —
    /// the Fig-7 (Partial, Partial) -> (Replicate, Shard) redistribution.
    /// `grads[r]` is rank r's full-buffer-sized gradient (m*S elements).
    /// On return, `self.shards` holds the averaged gradient shards.
    pub fn reduce_gradients(
        &mut self,
        grads: &mut [Vec<f32>],
        mesh: &DeviceMesh,
        comm: &dyn Communicator,
        fabric: &Fabric,
    ) -> Result<()> {
        let mut dst = std::mem::take(&mut self.shards);
        let mut ef = Vec::new();
        let r = self.reduce_gradients_core(
            grads,
            &mut dst,
            mesh,
            comm,
            fabric,
            CommPrecision::F32,
            &mut ef,
        );
        self.shards = dst;
        r
    }

    /// The full precision-aware reduction path into caller-owned shard
    /// buffers `dst` (m x S) — the FSDP engine's gradient shards live
    /// outside the DBuffer, but must go through the identical HSDP-aware
    /// reduction. `F32` launches the dense descriptor directly;
    /// `Bf16`/`Q8` run the codec pipeline
    /// ([`reduce_scatter_launch`] — encoded all-to-all + rank-ordered
    /// dequant-sum), with `Q8` maintaining the shard-held error-feedback
    /// residuals in `ef`.
    #[allow(clippy::too_many_arguments)]
    pub fn reduce_gradients_core(
        &self,
        grads: &mut [Vec<f32>],
        dst: &mut [Vec<f32>],
        mesh: &DeviceMesh,
        comm: &dyn Communicator,
        fabric: &Fabric,
        prec: CommPrecision,
        ef: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        let m = self.num_devices();
        if grads.len() != m {
            bail!("reduce_gradients: {} grad buffers != {m}", grads.len());
        }
        let l = comm
            .describe(LaunchOp::ReduceScatter, m, self.shard_elems())
            .scaled(self.reduce_scale(mesh))
            .with_precision(prec);
        if prec.is_f32() {
            comm.launch(&l, grads)?;
        } else {
            // transient wire claim: one device's encoded buffers, charged
            // for the duration of the exchange — the same accounting the
            // pipelined executor applies to its async wire buffers
            let wire_claim = match &self.alloc {
                Some(a) => Some(a.lock().unwrap().alloc(l.wire_claim_bytes())?),
                None => None,
            };
            let result = reduce_scatter_launch(comm, &l, grads, ef);
            if let (Some(a), Some(id)) = (&self.alloc, wire_claim) {
                a.lock().unwrap().free(id)?;
            }
            result?;
        }
        self.reduce_gradients_finish(grads, dst, mesh, comm, fabric, prec)
    }

    /// Completion half of a precision-aware gradient reduction whose
    /// ReduceScatter already ran (synchronously, or via the async launch
    /// path — the pipelined executor's overlap): copies the reduced
    /// shard regions into `dst`, performs the cross-replica AllReduce
    /// under HSDP (always dense f32 — replicas exchange already-reduced
    /// shards), and records the ReduceScatter with the wire bytes the
    /// descriptor's precision actually shipped.
    pub fn reduce_gradients_finish(
        &self,
        reduced: &[Vec<f32>],
        dst: &mut [Vec<f32>],
        mesh: &DeviceMesh,
        comm: &dyn Communicator,
        fabric: &Fabric,
        prec: CommPrecision,
    ) -> Result<()> {
        let m = self.num_devices();
        let s = self.shard_elems();
        if reduced.len() != m || dst.len() != m {
            bail!("reduce_gradients_finish: want {m} buffers");
        }
        for (rank, (dst_shard, buf)) in dst.iter_mut().zip(reduced).enumerate() {
            dst_shard.copy_from_slice(&buf[rank * s..(rank + 1) * s]);
        }
        let l = comm.describe(LaunchOp::ReduceScatter, m, s).with_precision(prec);
        comm.record(l.comm_record(fabric));
        let replicas = mesh.dim_size("replica").unwrap_or(1);
        if replicas > 1 {
            // cross-replica AllReduce of the already-scaled shard. In the
            // simulation each replica computed the same reduced value, so
            // data is already correct; we multiply by `replicas` to undo
            // the extra scale and account the collective.
            for shard in dst.iter_mut() {
                for x in shard.iter_mut() {
                    *x *= replicas as f32;
                }
            }
            let aligned = fabric.is_aligned(0, self.shard_bytes());
            comm.record(CommRecord::dense(
                "all_reduce",
                self.shard_bytes(),
                replicas,
                fabric.all_reduce_time(replicas, self.shard_bytes(), aligned),
            ));
        }
        Ok(())
    }

    /// Grouped fused op: zero every tensor's gradient region in one pass
    /// (one "kernel" for the whole bucket instead of one per tensor).
    pub fn zero_all(bufs: &mut [Vec<f32>]) {
        for b in bufs.iter_mut() {
            for x in b.iter_mut() {
                *x = 0.0;
            }
        }
    }

    /// Grouped fused scale over all shards.
    pub fn scale_all(&mut self, s: f32) {
        for shard in self.shards.iter_mut() {
            for x in shard.iter_mut() {
                *x *= s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SerialComm, ThreadedComm};
    use crate::planner::{plan, TensorDecl};
    use crate::util::Rng;

    fn demo_buffer(m: usize) -> (DBuffer, Vec<Vec<f32>>) {
        let ts = vec![
            TensorDecl::new("a", 96, 32),
            TensorDecl::new("b", 100, 1),
            TensorDecl::new("c", 64, 16),
        ];
        let layout = plan(&ts, m, 1).unwrap();
        let mut rng = Rng::new(7);
        let datas: Vec<Vec<f32>> = ts
            .iter()
            .map(|t| (0..t.numel).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut db = DBuffer::new(layout);
        for (i, d) in datas.iter().enumerate() {
            db.write_tensor(i, d).unwrap();
        }
        (db, datas)
    }

    #[test]
    fn write_read_roundtrip() {
        let (db, datas) = demo_buffer(4);
        for (i, d) in datas.iter().enumerate() {
            assert_eq!(&db.read_tensor(i), d, "tensor {i}");
        }
    }

    #[test]
    fn gather_materializes_full_tensors() {
        let (mut db, datas) = demo_buffer(4);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        db.all_gather_params(&comm, &fabric, CommPrecision::F32).unwrap();
        for rank in 0..4 {
            for (i, d) in datas.iter().enumerate() {
                assert_eq!(db.full_view(rank, i), &d[..], "rank {rank} tensor {i}");
            }
        }
        let stats = comm.stats();
        assert_eq!(stats.count("all_gather"), 1);
        assert!(stats.total_time() > 0.0);
    }

    #[test]
    fn gather_identical_across_backends() {
        let (mut serial_db, _) = demo_buffer(4);
        let (mut thr_db, _) = demo_buffer(4);
        let fabric = Fabric::h800();
        serial_db
            .all_gather_params(&SerialComm::new(), &fabric, CommPrecision::F32)
            .unwrap();
        // threshold 0 forces the rendezvous ring even on this small buffer
        thr_db
            .all_gather_params(
                &ThreadedComm::with_min_parallel_elems(0),
                &fabric,
                CommPrecision::F32,
            )
            .unwrap();
        for rank in 0..4 {
            for (a, b) in serial_db.full[rank].iter().zip(&thr_db.full[rank]) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn local_views_are_zero_copy_consistent() {
        let (db, datas) = demo_buffer(4);
        for rank in 0..4 {
            for i in 0..datas.len() {
                if let Some(((lo, hi), view)) = db.local_view(rank, i) {
                    assert_eq!(view, &datas[i][lo as usize..hi as usize]);
                }
            }
        }
    }

    #[test]
    fn local_views_partition_each_tensor() {
        let (db, datas) = demo_buffer(4);
        for i in 0..datas.len() {
            let mut covered = 0u64;
            for rank in 0..4 {
                if let Some(((lo, hi), _)) = db.local_view(rank, i) {
                    assert_eq!(lo, covered);
                    covered = hi;
                }
            }
            assert_eq!(covered, datas[i].len() as u64);
        }
    }

    #[test]
    fn reduce_gradients_averages() {
        let (mut db, _) = demo_buffer(4);
        let m = 4;
        let n = m * db.shard_elems();
        // rank r contributes grad value (r+1) everywhere -> mean 2.5
        let mut grads: Vec<Vec<f32>> =
            (0..m).map(|r| vec![(r + 1) as f32; n]).collect();
        let mesh = DeviceMesh::flat("fsdp", m);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        db.reduce_gradients(&mut grads, &mesh, &comm, &fabric).unwrap();
        for rank in 0..m {
            for &g in &db.shards[rank] {
                assert!((g - 2.5).abs() < 1e-6);
            }
        }
        assert_eq!(comm.stats().count("reduce_scatter"), 1);
        assert_eq!(comm.stats().count("all_reduce"), 0);
    }

    #[test]
    fn hsdp_reduction_adds_allreduce() {
        let (mut db, _) = demo_buffer(4);
        let n = 4 * db.shard_elems();
        let mut grads: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; n]).collect();
        let mesh = DeviceMesh::new(&[("replica", 2), ("fsdp", 4)]).unwrap();
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        db.reduce_gradients(&mut grads, &mesh, &comm, &fabric).unwrap();
        assert_eq!(comm.stats().count("all_reduce"), 1);
        // value: mean over fsdp(=1.0) — replica AR preserves the mean
        for rank in 0..4 {
            for &g in &db.shards[rank] {
                assert!((g - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn release_and_regather() {
        let (mut db, datas) = demo_buffer(2);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        db.all_gather_params(&comm, &fabric, CommPrecision::F32).unwrap();
        db.release_full();
        assert!(!db.gathered);
        db.all_gather_params(&comm, &fabric, CommPrecision::F32).unwrap();
        assert_eq!(db.full_view(0, 0), &datas[0][..]);
    }

    #[test]
    fn split_gather_matches_sync_gather() {
        // begin_gather/finish_gather must be bit-identical to
        // all_gather_params on both backends
        let fabric = Fabric::h800();
        for forced_threaded in [false, true] {
            let comm: Box<dyn Communicator> = if forced_threaded {
                Box::new(ThreadedComm::with_min_parallel_elems(0))
            } else {
                Box::new(SerialComm::new())
            };
            let (mut sync_db, _) = demo_buffer(4);
            let (mut async_db, _) = demo_buffer(4);
            sync_db.all_gather_params(comm.as_ref(), &fabric, CommPrecision::F32).unwrap();
            let op = async_db.begin_gather(comm.as_ref(), CommPrecision::F32).unwrap();
            assert!(!async_db.gathered);
            async_db
                .finish_gather(op, comm.as_ref(), &fabric, CommPrecision::F32)
                .unwrap();
            assert!(async_db.gathered);
            for rank in 0..4 {
                for (a, b) in sync_db.full[rank].iter().zip(&async_db.full[rank]) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            // double-begin is rejected
            assert!(async_db.begin_gather(comm.as_ref(), CommPrecision::F32).is_err());
        }
    }

    #[test]
    fn allocator_measures_gather_lifecycle() {
        use crate::memory::{shared_allocator, FreePolicy};
        let ts = vec![TensorDecl::new("a", 96, 32), TensorDecl::new("b", 100, 1)];
        let layout = plan(&ts, 4, 1).unwrap();
        let alloc = shared_allocator(FreePolicy::Deterministic, 1 << 30);
        let mut db = DBuffer::with_allocator(layout, alloc.clone()).unwrap();
        let base = alloc.lock().unwrap().allocated;
        assert!(base > 0, "persistent shard claim missing");
        let comm = SerialComm::new();
        let fabric = Fabric::h800();
        db.all_gather_params(&comm, &fabric, CommPrecision::F32).unwrap();
        let gathered = alloc.lock().unwrap().allocated;
        assert!(gathered > base, "gather must claim the full buffer");
        db.release_full();
        assert_eq!(alloc.lock().unwrap().allocated, base, "reshard must free");
        // regather reuses the freed segment: reserved stays flat
        let reserved = alloc.lock().unwrap().reserved;
        let op = db.begin_gather(&comm, CommPrecision::F32).unwrap();
        db.finish_gather(op, &comm, &fabric, CommPrecision::F32).unwrap();
        assert_eq!(alloc.lock().unwrap().reserved, reserved, "no segment growth");
        db.release_full();
    }

    #[test]
    fn quantized_gather_bit_identical_across_backends_and_halves() {
        let prec = CommPrecision::Q8 { block: 16 };
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        let (mut serial_db, _) = demo_buffer(4);
        serial_db.all_gather_params(&comm, &fabric, prec).unwrap();
        let (mut thr_db, _) = demo_buffer(4);
        thr_db
            .all_gather_params(&ThreadedComm::with_min_parallel_elems(0), &fabric, prec)
            .unwrap();
        let (mut split_db, _) = demo_buffer(4);
        let op = split_db.begin_gather(&comm, prec).unwrap();
        assert!(!split_db.gathered);
        split_db.finish_gather(op, &comm, &fabric, prec).unwrap();
        assert!(split_db.gathered);
        for rank in 0..4 {
            for ((a, b), c) in serial_db.full[rank]
                .iter()
                .zip(&thr_db.full[rank])
                .zip(&split_db.full[rank])
            {
                assert_eq!(a.to_bits(), b.to_bits(), "threaded diverged");
                assert_eq!(a.to_bits(), c.to_bits(), "split halves diverged");
            }
        }
        // every rank — the owner included — sees the *dequantized* shard
        let s = serial_db.shard_elems();
        for k in 0..4 {
            let expect =
                crate::quant::QBlockTensor::quantize(&serial_db.shards[k], 16).dequantize();
            for (a, b) in serial_db.full[0][k * s..(k + 1) * s].iter().zip(&expect) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // the record carries the measured, reduced wire bytes + scales
        let stats = comm.stats();
        let rec = stats.records.iter().find(|r| r.op == "all_gather").unwrap();
        assert!(rec.bytes_per_rank < serial_db.shard_bytes() / 3);
        assert!(rec.scale_bytes > 0);
        assert_eq!(
            rec.bytes_per_rank,
            prec.wire_volume(serial_db.layout.shard_size).total()
        );
    }

    #[test]
    fn quantized_gather_allocator_lifecycle() {
        use crate::memory::{shared_allocator, FreePolicy};
        let prec = CommPrecision::Q8 { block: 8 };
        let ts = vec![TensorDecl::new("a", 96, 32), TensorDecl::new("b", 100, 1)];
        let layout = plan(&ts, 4, 1).unwrap();
        let alloc = shared_allocator(FreePolicy::Deterministic, 1 << 30);
        let mut db = DBuffer::with_allocator(layout, alloc.clone()).unwrap();
        let base = alloc.lock().unwrap().allocated;
        let comm = SerialComm::new();
        let fabric = Fabric::h800();
        // sync path frees the wire claim before returning
        db.all_gather_params(&comm, &fabric, prec).unwrap();
        let gathered = alloc.lock().unwrap().allocated;
        assert_eq!(gathered, base + db.full_bytes(), "wire claim must be transient");
        db.release_full();
        assert_eq!(alloc.lock().unwrap().allocated, base);
        // split path holds the wire claim only while the op is in flight
        let op = db.begin_gather(&comm, prec).unwrap();
        let inflight = alloc.lock().unwrap().allocated;
        assert!(inflight > base + db.full_bytes(), "wire claim missing in flight");
        db.finish_gather(op, &comm, &fabric, prec).unwrap();
        assert_eq!(alloc.lock().unwrap().allocated, base + db.full_bytes());
        db.release_full();
        assert_eq!(alloc.lock().unwrap().allocated, base);
    }

    #[test]
    fn quantized_reduce_close_to_dense_and_replica_ar_preserved() {
        let (db, _) = demo_buffer(4);
        let m = 4;
        let n = m * db.shard_elems();
        let mk = || -> Vec<Vec<f32>> {
            let mut rng = Rng::new(21);
            (0..m)
                .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
                .collect()
        };
        let mesh = DeviceMesh::new(&[("replica", 2), ("fsdp", 4)]).unwrap();
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        let mut dense = mk();
        let mut dst_dense = vec![vec![0.0f32; db.shard_elems()]; m];
        let f32p = CommPrecision::F32;
        db.reduce_gradients_core(
            &mut dense,
            &mut dst_dense,
            &mesh,
            &comm,
            &fabric,
            f32p,
            &mut Vec::new(),
        )
        .unwrap();
        let prec = CommPrecision::Q8 { block: 8 };
        let mut q = mk();
        let mut dst_q = vec![vec![0.0f32; db.shard_elems()]; m];
        let mut ef = Vec::new();
        db.reduce_gradients_core(&mut q, &mut dst_q, &mesh, &comm, &fabric, prec, &mut ef)
            .expect("quantized reduce");
        assert_eq!(ef.len(), m);
        for (a, b) in dst_dense.iter().flatten().zip(dst_q.iter().flatten()) {
            // 4 contributions x half a quant step each, replica-rescaled
            assert!((a - b).abs() < 4.0 * 4.0 / 127.0, "{a} vs {b}");
        }
        // both paths account the RS + the cross-replica AR
        assert_eq!(comm.stats().count("reduce_scatter"), 2);
        assert_eq!(comm.stats().count("all_reduce"), 2);
    }

    #[test]
    fn reduce_core_into_external_shards_matches_inplace() {
        let (mut db_a, _) = demo_buffer(4);
        let (db_b, _) = demo_buffer(4);
        let m = 4;
        let n = m * db_a.shard_elems();
        let mk = || -> Vec<Vec<f32>> {
            let mut rng = Rng::new(11);
            (0..m)
                .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
                .collect()
        };
        let mesh = DeviceMesh::flat("fsdp", m);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        let mut g1 = mk();
        db_a.reduce_gradients(&mut g1, &mesh, &comm, &fabric).unwrap();
        let mut g2 = mk();
        let mut dst = vec![vec![0.0f32; db_b.shard_elems()]; m];
        let f32p = CommPrecision::F32;
        db_b.reduce_gradients_core(&mut g2, &mut dst, &mesh, &comm, &fabric, f32p, &mut Vec::new())
            .unwrap();
        for (a, b) in db_a.shards.iter().flatten().zip(dst.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn padding_regions_never_alias_tensors() {
        let (mut db, datas) = demo_buffer(4);
        // poison padding in shards, verify tensors unaffected
        let owned: Vec<Vec<bool>> = (0..4)
            .map(|rank| {
                let mut mask = vec![false; db.shard_elems()];
                for i in 0..datas.len() {
                    if let Some((lo, hi)) = db.layout.local_slice(i, rank) {
                        let off = db.layout.offsets[i];
                        let a = (off + lo - rank as u64 * db.layout.shard_size) as usize;
                        for x in mask.iter_mut().skip(a).take((hi - lo) as usize) {
                            *x = true;
                        }
                    }
                }
                mask
            })
            .collect();
        for rank in 0..4 {
            for (j, owned_j) in owned[rank].iter().enumerate() {
                if !owned_j {
                    db.shards[rank][j] = f32::NAN;
                }
            }
        }
        for (i, d) in datas.iter().enumerate() {
            assert_eq!(&db.read_tensor(i), d);
        }
    }
}
