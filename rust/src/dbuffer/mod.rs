//! Distributed Buffer (DBuffer) — the paper's high-performance grouped
//! communication primitive (§5, Fig 7).
//!
//! A DBuffer backs one FSDP communication bucket (a group of RaggedShard
//! DTensors laid out by the planner). Key properties reproduced here:
//!
//! * **zero-copy access**: tensors live at planner-assigned offsets of the
//!   global buffer; the sharded state *is* the collective's input and the
//!   gathered buffer *is* the compute's parameter memory — views, not
//!   copies (`local_view`, `full_view`);
//! * **grouped fused ops**: `zero_grads`/`scale_all` touch the whole
//!   buffer in one pass instead of one kernel per tensor;
//! * **in-place collectives**: AllGather fills the same persistent full
//!   buffer; ReduceScatter reduces into the shard region in place;
//! * **batched allocation**: shard + full storage is carved from single
//!   segments via `CachingAllocator::alloc_batch`, with deterministic
//!   frees (no record_stream hazard).
//!
//! N-D semantics (Fig 7): with an HSDP mesh `[replica, fsdp]`, gradient
//! reduction is ReduceScatter within the fsdp dim followed by AllReduce
//! across the replica dim — `reduce_gradients` implements exactly that.

use anyhow::{bail, Result};

use crate::cluster::Communicator;
use crate::comm::{CommRecord, Fabric};
use crate::mesh::DeviceMesh;
use crate::planner::Layout;

/// Per-bucket distributed buffer over an FSDP group of `m` devices.
#[derive(Debug)]
pub struct DBuffer {
    pub layout: Layout,
    /// Per-device local shard (S elements each) — the persistent sharded
    /// state (fp32 master weights or gradient shards).
    pub shards: Vec<Vec<f32>>,
    /// Per-device full buffer (m*S elements) — unsharded staging for
    /// compute; allocated once, reused in place every iteration.
    pub full: Vec<Vec<f32>>,
    /// Whether `full` currently holds gathered (valid) data.
    pub gathered: bool,
}

impl DBuffer {
    pub fn new(layout: Layout) -> DBuffer {
        let m = layout.num_devices;
        let s = layout.shard_size as usize;
        DBuffer {
            shards: vec![vec![0.0; s]; m],
            full: vec![vec![0.0; m * s]; m],
            layout,
            gathered: false,
        }
    }

    pub fn num_devices(&self) -> usize {
        self.layout.num_devices
    }

    pub fn shard_elems(&self) -> usize {
        self.layout.shard_size as usize
    }

    /// Bytes of one device's sharded state.
    pub fn shard_bytes(&self) -> u64 {
        self.layout.shard_size * 4
    }

    /// Scatter a global tensor's data into the owning shards (init path).
    pub fn write_tensor(&mut self, idx: usize, data: &[f32]) -> Result<()> {
        let t = &self.layout.tensors[idx];
        if data.len() as u64 != t.numel {
            bail!("write_tensor: {} != {}", data.len(), t.numel);
        }
        let s = self.layout.shard_size;
        let off = self.layout.offsets[idx];
        for rank in 0..self.num_devices() {
            if let Some((lo, hi)) = self.layout.local_slice(idx, rank) {
                let dst_lo = (off + lo - rank as u64 * s) as usize;
                self.shards[rank][dst_lo..dst_lo + (hi - lo) as usize]
                    .copy_from_slice(&data[lo as usize..hi as usize]);
            }
        }
        Ok(())
    }

    /// Read a tensor back from the shards (checkpoint path).
    pub fn read_tensor(&self, idx: usize) -> Vec<f32> {
        let t = &self.layout.tensors[idx];
        let s = self.layout.shard_size;
        let off = self.layout.offsets[idx];
        let mut out = vec![0.0f32; t.numel as usize];
        for rank in 0..self.num_devices() {
            if let Some((lo, hi)) = self.layout.local_slice(idx, rank) {
                let src_lo = (off + lo - rank as u64 * s) as usize;
                out[lo as usize..hi as usize].copy_from_slice(
                    &self.shards[rank][src_lo..src_lo + (hi - lo) as usize],
                );
            }
        }
        out
    }

    /// Zero-copy view of tensor `idx`'s slice living on `rank`'s shard.
    /// Returns (tensor-relative range, slice into the shard).
    pub fn local_view(&self, rank: usize, idx: usize) -> Option<((u64, u64), &[f32])> {
        let (lo, hi) = self.layout.local_slice(idx, rank)?;
        let off = self.layout.offsets[idx];
        let s = self.layout.shard_size;
        let a = (off + lo - rank as u64 * s) as usize;
        Some(((lo, hi), &self.shards[rank][a..a + (hi - lo) as usize]))
    }

    pub fn local_view_mut(
        &mut self,
        rank: usize,
        idx: usize,
    ) -> Option<((u64, u64), &mut [f32])> {
        let (lo, hi) = self.layout.local_slice(idx, rank)?;
        let off = self.layout.offsets[idx];
        let s = self.layout.shard_size;
        let a = (off + lo - rank as u64 * s) as usize;
        Some(((lo, hi), &mut self.shards[rank][a..a + (hi - lo) as usize]))
    }

    /// Zero-copy view of the *whole* tensor `idx` in `rank`'s gathered
    /// full buffer (valid after `all_gather_params`). This is the paper's
    /// zero-copy claim: the tensor is contiguous at a planner-known offset.
    pub fn full_view(&self, rank: usize, idx: usize) -> &[f32] {
        debug_assert!(self.gathered, "full buffer not gathered");
        let off = self.layout.offsets[idx] as usize;
        let n = self.layout.tensors[idx].numel as usize;
        &self.full[rank][off..off + n]
    }

    pub fn full_view_mut(&mut self, rank: usize, idx: usize) -> &mut [f32] {
        let off = self.layout.offsets[idx] as usize;
        let n = self.layout.tensors[idx].numel as usize;
        &mut self.full[rank][off..off + n]
    }

    /// In-place parameter AllGather: each rank's shard is published into
    /// every rank's persistent full buffer. Zero-copy on both ends: the
    /// shard region of `full` is first filled from `shards` (simulating
    /// that they alias; one memcpy models the aliased write) and the
    /// collective runs on `full` directly, through whichever cluster
    /// backend `comm` selects.
    pub fn all_gather_params(&mut self, comm: &dyn Communicator, fabric: &Fabric) -> Result<()> {
        let m = self.num_devices();
        let s = self.shard_elems();
        for rank in 0..m {
            let shard = self.shards[rank].clone();
            self.full[rank][rank * s..(rank + 1) * s].copy_from_slice(&shard);
        }
        comm.all_gather(&mut self.full, s)?;
        self.gathered = true;
        let aligned = fabric.is_aligned(0, self.shard_bytes());
        comm.record(CommRecord {
            op: "all_gather",
            bytes_per_rank: self.shard_bytes(),
            group_size: m,
            sim_time: fabric.all_gather_time(m, self.shard_bytes(), aligned),
        });
        Ok(())
    }

    /// Release the gathered full buffers (FSDP reshard-after-forward).
    /// The storage persists (in-place reuse); only validity is dropped.
    pub fn release_full(&mut self) {
        self.gathered = false;
    }

    /// In-place gradient ReduceScatter over the fsdp dim, then (if the
    /// mesh has a replica dim) AllReduce of the shard across replicas —
    /// the Fig-7 (Partial, Partial) -> (Replicate, Shard) redistribution.
    /// `grads[r]` is rank r's full-buffer-sized gradient (m*S elements).
    /// On return, `self.shards` holds the averaged gradient shards.
    pub fn reduce_gradients(
        &mut self,
        grads: &mut [Vec<f32>],
        mesh: &DeviceMesh,
        comm: &dyn Communicator,
        fabric: &Fabric,
    ) -> Result<()> {
        let m = self.num_devices();
        let s = self.shard_elems();
        if grads.len() != m {
            bail!("reduce_gradients: {} grad buffers != {m}", grads.len());
        }
        let replicas = mesh.dim_size("replica").unwrap_or(1);
        let scale = 1.0 / (m * replicas) as f32;
        comm.reduce_scatter(grads, s, scale)?;
        for rank in 0..m {
            self.shards[rank].copy_from_slice(&grads[rank][rank * s..(rank + 1) * s]);
        }
        let aligned = fabric.is_aligned(0, self.shard_bytes());
        comm.record(CommRecord {
            op: "reduce_scatter",
            bytes_per_rank: self.shard_bytes(),
            group_size: m,
            sim_time: fabric.reduce_scatter_time(m, self.shard_bytes(), aligned),
        });
        if replicas > 1 {
            // cross-replica AllReduce of the already-scaled shard. In the
            // simulation each replica computed the same reduced value, so
            // data is already correct; we multiply by `replicas` to undo
            // the extra scale and account the collective.
            for rank in 0..m {
                for x in self.shards[rank].iter_mut() {
                    *x *= replicas as f32;
                }
            }
            comm.record(CommRecord {
                op: "all_reduce",
                bytes_per_rank: self.shard_bytes(),
                group_size: replicas,
                sim_time: fabric.all_reduce_time(replicas, self.shard_bytes(), true),
            });
        }
        Ok(())
    }

    /// Grouped fused op: zero every tensor's gradient region in one pass
    /// (one "kernel" for the whole bucket instead of one per tensor).
    pub fn zero_all(bufs: &mut [Vec<f32>]) {
        for b in bufs.iter_mut() {
            for x in b.iter_mut() {
                *x = 0.0;
            }
        }
    }

    /// Grouped fused scale over all shards.
    pub fn scale_all(&mut self, s: f32) {
        for shard in self.shards.iter_mut() {
            for x in shard.iter_mut() {
                *x *= s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SerialComm, ThreadedComm};
    use crate::planner::{plan, TensorDecl};
    use crate::util::Rng;

    fn demo_buffer(m: usize) -> (DBuffer, Vec<Vec<f32>>) {
        let ts = vec![
            TensorDecl::new("a", 96, 32),
            TensorDecl::new("b", 100, 1),
            TensorDecl::new("c", 64, 16),
        ];
        let layout = plan(&ts, m, 1).unwrap();
        let mut rng = Rng::new(7);
        let datas: Vec<Vec<f32>> = ts
            .iter()
            .map(|t| (0..t.numel).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut db = DBuffer::new(layout);
        for (i, d) in datas.iter().enumerate() {
            db.write_tensor(i, d).unwrap();
        }
        (db, datas)
    }

    #[test]
    fn write_read_roundtrip() {
        let (db, datas) = demo_buffer(4);
        for (i, d) in datas.iter().enumerate() {
            assert_eq!(&db.read_tensor(i), d, "tensor {i}");
        }
    }

    #[test]
    fn gather_materializes_full_tensors() {
        let (mut db, datas) = demo_buffer(4);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        db.all_gather_params(&comm, &fabric).unwrap();
        for rank in 0..4 {
            for (i, d) in datas.iter().enumerate() {
                assert_eq!(db.full_view(rank, i), &d[..], "rank {rank} tensor {i}");
            }
        }
        let stats = comm.stats();
        assert_eq!(stats.count("all_gather"), 1);
        assert!(stats.total_time() > 0.0);
    }

    #[test]
    fn gather_identical_across_backends() {
        let (mut serial_db, _) = demo_buffer(4);
        let (mut thr_db, _) = demo_buffer(4);
        let fabric = Fabric::h800();
        serial_db.all_gather_params(&SerialComm::new(), &fabric).unwrap();
        // threshold 0 forces the rendezvous ring even on this small buffer
        thr_db
            .all_gather_params(&ThreadedComm::with_min_parallel_elems(0), &fabric)
            .unwrap();
        for rank in 0..4 {
            for (a, b) in serial_db.full[rank].iter().zip(&thr_db.full[rank]) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn local_views_are_zero_copy_consistent() {
        let (db, datas) = demo_buffer(4);
        for rank in 0..4 {
            for i in 0..datas.len() {
                if let Some(((lo, hi), view)) = db.local_view(rank, i) {
                    assert_eq!(view, &datas[i][lo as usize..hi as usize]);
                }
            }
        }
    }

    #[test]
    fn local_views_partition_each_tensor() {
        let (db, datas) = demo_buffer(4);
        for i in 0..datas.len() {
            let mut covered = 0u64;
            for rank in 0..4 {
                if let Some(((lo, hi), _)) = db.local_view(rank, i) {
                    assert_eq!(lo, covered);
                    covered = hi;
                }
            }
            assert_eq!(covered, datas[i].len() as u64);
        }
    }

    #[test]
    fn reduce_gradients_averages() {
        let (mut db, _) = demo_buffer(4);
        let m = 4;
        let n = m * db.shard_elems();
        // rank r contributes grad value (r+1) everywhere -> mean 2.5
        let mut grads: Vec<Vec<f32>> =
            (0..m).map(|r| vec![(r + 1) as f32; n]).collect();
        let mesh = DeviceMesh::flat("fsdp", m);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        db.reduce_gradients(&mut grads, &mesh, &comm, &fabric).unwrap();
        for rank in 0..m {
            for &g in &db.shards[rank] {
                assert!((g - 2.5).abs() < 1e-6);
            }
        }
        assert_eq!(comm.stats().count("reduce_scatter"), 1);
        assert_eq!(comm.stats().count("all_reduce"), 0);
    }

    #[test]
    fn hsdp_reduction_adds_allreduce() {
        let (mut db, _) = demo_buffer(4);
        let n = 4 * db.shard_elems();
        let mut grads: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; n]).collect();
        let mesh = DeviceMesh::new(&[("replica", 2), ("fsdp", 4)]).unwrap();
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        db.reduce_gradients(&mut grads, &mesh, &comm, &fabric).unwrap();
        assert_eq!(comm.stats().count("all_reduce"), 1);
        // value: mean over fsdp(=1.0) — replica AR preserves the mean
        for rank in 0..4 {
            for &g in &db.shards[rank] {
                assert!((g - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn release_and_regather() {
        let (mut db, datas) = demo_buffer(2);
        let fabric = Fabric::h800();
        let comm = SerialComm::new();
        db.all_gather_params(&comm, &fabric).unwrap();
        db.release_full();
        assert!(!db.gathered);
        db.all_gather_params(&comm, &fabric).unwrap();
        assert_eq!(db.full_view(0, 0), &datas[0][..]);
    }

    #[test]
    fn padding_regions_never_alias_tensors() {
        let (mut db, datas) = demo_buffer(4);
        // poison padding in shards, verify tensors unaffected
        let owned: Vec<Vec<bool>> = (0..4)
            .map(|rank| {
                let mut mask = vec![false; db.shard_elems()];
                for i in 0..datas.len() {
                    if let Some((lo, hi)) = db.layout.local_slice(i, rank) {
                        let off = db.layout.offsets[i];
                        let a = (off + lo - rank as u64 * db.layout.shard_size) as usize;
                        for x in mask.iter_mut().skip(a).take((hi - lo) as usize) {
                            *x = true;
                        }
                    }
                }
                mask
            })
            .collect();
        for rank in 0..4 {
            for (j, owned_j) in owned[rank].iter().enumerate() {
                if !owned_j {
                    db.shards[rank][j] = f32::NAN;
                }
            }
        }
        for (i, d) in datas.iter().enumerate() {
            assert_eq!(&db.read_tensor(i), d);
        }
    }
}
