//! N-dimensional device mesh (the substrate under DTensor / DBuffer).
//!
//! A mesh names its dimensions, e.g. `[("replica", 4), ("fsdp", 256)]` for
//! HSDP or `[("fsdp", 64), ("ep", 16)]` for FSDP x Expert Parallelism.
//! Ranks are laid out row-major over the dims (last dim fastest), matching
//! PyTorch's DeviceMesh convention.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceMesh {
    dims: Vec<(String, usize)>,
}

impl DeviceMesh {
    pub fn new(dims: &[(&str, usize)]) -> Result<DeviceMesh> {
        if dims.is_empty() {
            bail!("mesh needs at least one dim");
        }
        for (name, n) in dims {
            if *n == 0 {
                bail!("mesh dim '{name}' has size 0");
            }
        }
        let mut names: Vec<&str> = dims.iter().map(|(n, _)| *n).collect();
        names.sort();
        names.dedup();
        if names.len() != dims.len() {
            bail!("duplicate mesh dim names");
        }
        Ok(DeviceMesh {
            dims: dims.iter().map(|(n, s)| (n.to_string(), *s)).collect(),
        })
    }

    /// 1-D mesh, the plain-FSDP case.
    pub fn flat(name: &str, n: usize) -> DeviceMesh {
        DeviceMesh::new(&[(name, n)]).unwrap()
    }

    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    pub fn num_devices(&self) -> usize {
        self.dims.iter().map(|(_, s)| s).product()
    }

    pub fn dim_names(&self) -> Vec<&str> {
        self.dims.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn dim_index(&self, name: &str) -> Option<usize> {
        self.dims.iter().position(|(n, _)| n == name)
    }

    pub fn dim_size(&self, name: &str) -> Option<usize> {
        self.dims.iter().find(|(n, _)| n == name).map(|(_, s)| *s)
    }

    pub fn sizes(&self) -> Vec<usize> {
        self.dims.iter().map(|(_, s)| *s).collect()
    }

    /// Coordinates of a global rank (row-major, last dim fastest).
    pub fn coords(&self, rank: usize) -> Vec<usize> {
        assert!(rank < self.num_devices());
        let mut rem = rank;
        let mut out = vec![0; self.ndim()];
        for i in (0..self.ndim()).rev() {
            out[i] = rem % self.dims[i].1;
            rem /= self.dims[i].1;
        }
        out
    }

    /// Global rank of a coordinate vector.
    pub fn rank_of(&self, coords: &[usize]) -> usize {
        assert_eq!(coords.len(), self.ndim());
        let mut rank = 0;
        for (i, &c) in coords.iter().enumerate() {
            assert!(c < self.dims[i].1);
            rank = rank * self.dims[i].1 + c;
        }
        rank
    }

    /// Process groups along one dim: all rank-lists that vary only in that
    /// dim (each is a collective group, e.g. the FSDP shard group).
    pub fn groups_along(&self, dim_name: &str) -> Vec<Vec<usize>> {
        let d = self.dim_index(dim_name).expect("unknown mesh dim");
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let n = self.num_devices();
        let mut seen = vec![false; n];
        for r in 0..n {
            if seen[r] {
                continue;
            }
            let mut coords = self.coords(r);
            let mut g = Vec::with_capacity(self.dims[d].1);
            for k in 0..self.dims[d].1 {
                coords[d] = k;
                let rr = self.rank_of(&coords);
                seen[rr] = true;
                g.push(rr);
            }
            groups.push(g);
        }
        groups
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_mesh() {
        let m = DeviceMesh::flat("fsdp", 8);
        assert_eq!(m.num_devices(), 8);
        assert_eq!(m.coords(5), vec![5]);
        assert_eq!(m.rank_of(&[5]), 5);
    }

    #[test]
    fn coords_roundtrip_2d() {
        let m = DeviceMesh::new(&[("replica", 2), ("fsdp", 3)]).unwrap();
        for r in 0..6 {
            assert_eq!(m.rank_of(&m.coords(r)), r);
        }
        // last dim fastest
        assert_eq!(m.coords(1), vec![0, 1]);
        assert_eq!(m.coords(3), vec![1, 0]);
    }

    #[test]
    fn groups_along_dims() {
        let m = DeviceMesh::new(&[("replica", 2), ("fsdp", 3)]).unwrap();
        let fsdp = m.groups_along("fsdp");
        assert_eq!(fsdp, vec![vec![0, 1, 2], vec![3, 4, 5]]);
        let rep = m.groups_along("replica");
        assert_eq!(rep, vec![vec![0, 3], vec![1, 4], vec![2, 5]]);
    }

    #[test]
    fn rejects_bad_meshes() {
        assert!(DeviceMesh::new(&[]).is_err());
        assert!(DeviceMesh::new(&[("a", 0)]).is_err());
        assert!(DeviceMesh::new(&[("a", 2), ("a", 3)]).is_err());
    }

    #[test]
    fn hsdp_mesh_shape() {
        // paper Fig 8: HSDP with 4-way replication over 256-way FSDP
        let m = DeviceMesh::new(&[("replica", 4), ("fsdp", 256)]).unwrap();
        assert_eq!(m.num_devices(), 1024);
        assert_eq!(m.groups_along("fsdp").len(), 4);
        assert_eq!(m.groups_along("replica").len(), 256);
    }
}
