//! Sharded checkpointing over RaggedShard DTensors (paper §4: RaggedShard
//! reuses the DTensor checkpointing stack, including communication-free
//! sharded save/load and resharding on recovery).
//!
//! Format: one binary shard file per rank (`rank_<k>.bin`, little-endian
//! f32 of that rank's local slices, bucket-major) plus `meta.json`
//! describing the layout so a load with a *different* mesh size can
//! reshard: each tensor is reconstructed from the ragged slices and
//! re-split under the new layout — all without gathering the full model
//! in one place at once (tensor-at-a-time streaming).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::fsdp::FsdpEngine;
use crate::util::json::Json;

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Save the engine's sharded parameters (communication-free: every rank
/// writes only its own shard).
pub fn save(engine: &FsdpEngine, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let m = engine.num_devices();
    for rank in 0..m {
        let mut bytes = Vec::new();
        for bucket in &engine.buckets {
            bytes.extend(f32s_to_bytes(&bucket.dbuffer.shards[rank]));
        }
        std::fs::write(dir.join(format!("rank_{rank}.bin")), bytes)?;
    }
    let meta = Json::obj(vec![
        // v2: buckets additionally record their shard-group name (the
        // spec's wrap-unit identity); v1 checkpoints load fine without it
        ("version", Json::num(2)),
        ("mesh", Json::num(m as f64)),
        (
            "params",
            Json::arr(engine.params.iter().map(|(name, shape)| {
                Json::obj(vec![
                    ("name", Json::str(name)),
                    ("shape", Json::arr(shape.iter().map(|&s| Json::num(s as f64)))),
                ])
            })),
        ),
        (
            "buckets",
            Json::arr(engine.buckets.iter().map(|b| {
                Json::obj(vec![
                    ("name", Json::str(&b.name)),
                    ("shard_size", Json::num(b.dbuffer.layout.shard_size as f64)),
                    ("param_ids", Json::arr(b.param_ids.iter().map(|&i| Json::num(i as f64)))),
                    // planner-assigned offsets in the bucket's global
                    // buffer — load() needs them to slice tensors out
                    (
                        "offsets",
                        Json::arr(
                            b.dbuffer.layout.offsets.iter().map(|&o| Json::num(o as f64)),
                        ),
                    ),
                ])
            })),
        ),
    ]);
    std::fs::write(dir.join("meta.json"), meta.to_string())?;
    Ok(())
}

/// Checkpoint metadata.
pub struct Meta {
    pub mesh: usize,
    pub params: Vec<(String, Vec<usize>)>,
    /// Shard-group (wrap unit) names, bucket order. Empty for v1
    /// checkpoints, which predate the spec API.
    pub groups: Vec<String>,
}

pub fn read_meta(dir: &Path) -> Result<Meta> {
    let text = std::fs::read_to_string(dir.join("meta.json"))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
    let mesh = j.get("mesh").and_then(|v| v.as_usize()).context("mesh")?;
    let params = j
        .get("params")
        .and_then(|p| p.as_arr())
        .context("params")?
        .iter()
        .map(|p| {
            let name = p.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
            let shape = p
                .get("shape")
                .and_then(|s| s.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default();
            (name, shape)
        })
        .collect();
    let groups = j
        .get("buckets")
        .and_then(|b| b.as_arr())
        .map(|bs| {
            bs.iter()
                .filter_map(|b| b.get("name").and_then(|n| n.as_str()))
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    Ok(Meta { mesh, params, groups })
}

/// Load a checkpoint into an engine. The engine's mesh size may differ
/// from the checkpoint's (resharding): tensors are reconstructed from the
/// saved shards one at a time and re-split under the engine's layout.
pub fn load(engine: &mut FsdpEngine, dir: &Path) -> Result<()> {
    let meta = read_meta(dir)?;
    if meta.params.len() != engine.params.len() {
        bail!(
            "checkpoint has {} params, engine {}",
            meta.params.len(),
            engine.params.len()
        );
    }
    for ((cn, cs), (en, es)) in meta.params.iter().zip(&engine.params) {
        if cn != en || cs != es {
            bail!("param mismatch: ckpt {cn}{cs:?} vs engine {en}{es:?}");
        }
    }
    // Reconstruct each rank's flat shard stream, then each tensor.
    // To reshard we need the *saving* engine's layout; rebuild it by
    // constructing an engine-shaped view: simplest faithful route is to
    // read all rank files and use the saved bucket shard sizes to locate
    // slices. We reconstruct full tensors bucket by bucket.
    let text = std::fs::read_to_string(dir.join("meta.json"))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
    let buckets = j.get("buckets").and_then(|b| b.as_arr()).context("buckets")?;
    let rank_data: Vec<Vec<f32>> = (0..meta.mesh)
        .map(|k| -> Result<Vec<f32>> {
            Ok(bytes_to_f32s(&std::fs::read(dir.join(format!("rank_{k}.bin")))?))
        })
        .collect::<Result<_>>()?;

    // the save wrote buckets in order; rebuild each bucket's global buffer
    let mut full_params: Vec<Option<Vec<f32>>> = vec![None; engine.params.len()];
    let mut offset_per_rank = vec![0usize; meta.mesh];
    for b in buckets {
        let s = b.get("shard_size").and_then(|v| v.as_usize()).context("shard_size")?;
        let param_ids: Vec<usize> = b
            .get("param_ids")
            .and_then(|v| v.as_arr())
            .context("param_ids")?
            .iter()
            .filter_map(|x| x.as_usize())
            .collect();
        let mut global = vec![0.0f32; s * meta.mesh];
        for (k, off) in offset_per_rank.iter_mut().enumerate() {
            if *off + s > rank_data[k].len() {
                bail!(
                    "shard file rank_{k}.bin truncated: needs {} f32s, has {}",
                    *off + s,
                    rank_data[k].len()
                );
            }
            global[k * s..(k + 1) * s].copy_from_slice(&rank_data[k][*off..*off + s]);
            *off += s;
        }
        // the saving engine recorded its planner-assigned offsets
        let offsets: Vec<u64> = b
            .get("offsets")
            .and_then(|v| v.as_arr())
            .context("offsets")?
            .iter()
            .filter_map(|x| x.as_f64().map(|f| f as u64))
            .collect();
        if offsets.len() != param_ids.len() {
            bail!("offsets/param_ids arity mismatch in meta.json");
        }
        for (pos, &pid) in param_ids.iter().enumerate() {
            let numel: usize = engine.params[pid].1.iter().product();
            let off = offsets[pos] as usize;
            full_params[pid] = Some(global[off..off + numel].to_vec());
        }
    }
    let full: Vec<Vec<f32>> = full_params
        .into_iter()
        .enumerate()
        .map(|(i, p)| p.ok_or_else(|| anyhow!("param {i} missing from checkpoint")))
        .collect::<Result<_>>()?;
    engine.init_params(&full)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Fabric;
    use crate::fsdp::ShardingPolicy;
    use crate::mesh::DeviceMesh;
    use crate::util::Rng;

    fn make_engine(m: usize) -> FsdpEngine {
        let params = vec![
            ("embed".to_string(), vec![32, 16]),
            ("w1".to_string(), vec![16, 16]),
            ("norm".to_string(), vec![16]),
        ];
        FsdpEngine::new(
            params,
            &[0, 1, 1],
            DeviceMesh::flat("fsdp", m),
            &ShardingPolicy::element_wise(),
            Fabric::h800(),
        )
        .unwrap()
    }

    fn rand_params(seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        vec![
            (0..512).map(|_| rng.normal_f32()).collect(),
            (0..256).map(|_| rng.normal_f32()).collect(),
            (0..16).map(|_| rng.normal_f32()).collect(),
        ]
    }

    #[test]
    fn save_load_roundtrip_same_mesh() {
        let dir = std::env::temp_dir().join("vescale_ckpt_same");
        let _ = std::fs::remove_dir_all(&dir);
        let mut e = make_engine(4);
        let full = rand_params(1);
        e.init_params(&full).unwrap();
        save(&e, &dir).unwrap();
        let mut e2 = make_engine(4);
        load(&mut e2, &dir).unwrap();
        for i in 0..full.len() {
            assert_eq!(e2.read_param(i), full[i], "param {i}");
        }
    }

    #[test]
    fn reshard_to_different_mesh() {
        let dir = std::env::temp_dir().join("vescale_ckpt_reshard");
        let _ = std::fs::remove_dir_all(&dir);
        let mut e = make_engine(4);
        let full = rand_params(2);
        e.init_params(&full).unwrap();
        save(&e, &dir).unwrap();
        // recover onto a 2-device mesh
        let mut e2 = make_engine(2);
        load(&mut e2, &dir).unwrap();
        for i in 0..full.len() {
            assert_eq!(e2.read_param(i), full[i], "param {i}");
        }
    }

    #[test]
    fn load_rejects_mismatched_model() {
        let dir = std::env::temp_dir().join("vescale_ckpt_mismatch");
        let _ = std::fs::remove_dir_all(&dir);
        let mut e = make_engine(2);
        e.init_params(&rand_params(3)).unwrap();
        save(&e, &dir).unwrap();
        let params = vec![("other".to_string(), vec![8, 8])];
        let mut wrong = FsdpEngine::new(
            params,
            &[0],
            DeviceMesh::flat("fsdp", 2),
            &ShardingPolicy::element_wise(),
            Fabric::h800(),
        )
        .unwrap();
        assert!(load(&mut wrong, &dir).is_err());
    }

    #[test]
    fn meta_readable() {
        let dir = std::env::temp_dir().join("vescale_ckpt_meta");
        let _ = std::fs::remove_dir_all(&dir);
        let mut e = make_engine(2);
        e.init_params(&rand_params(4)).unwrap();
        save(&e, &dir).unwrap();
        let meta = read_meta(&dir).unwrap();
        assert_eq!(meta.mesh, 2);
        assert_eq!(meta.params.len(), 3);
        assert_eq!(meta.params[0].0, "embed");
        // legacy flat-array construction records g<N> wrap-unit names
        assert_eq!(meta.groups, vec!["g0".to_string(), "g1".to_string()]);
    }

    #[test]
    fn spec_engine_checkpoint_records_group_names_and_reshards() {
        use crate::cluster::SerialComm;
        use crate::fsdp::spec::{GroupFilter, ModelSpec, ShardGroupSpec};
        use std::sync::Arc;
        let params = vec![
            ("embed".to_string(), vec![32, 16]),
            ("w1".to_string(), vec![16, 16]),
            ("norm".to_string(), vec![16]),
        ];
        let spec = ModelSpec::new()
            .group(ShardGroupSpec::new("embed", GroupFilter::prefix("embed")))
            .group(
                ShardGroupSpec::new("body", GroupFilter::Rest)
                    .policy(crate::fsdp::ShardingPolicy::uniform_rows(4)),
            );
        let build = |m: usize| {
            FsdpEngine::from_spec(
                params.clone(),
                &spec,
                DeviceMesh::flat("fsdp", m),
                Fabric::h800(),
                Arc::new(SerialComm::new()),
            )
            .unwrap()
        };
        let dir = std::env::temp_dir().join("vescale_ckpt_spec_groups");
        let _ = std::fs::remove_dir_all(&dir);
        let mut e = build(4);
        let full = rand_params(9);
        e.init_params(&full).unwrap();
        save(&e, &dir).unwrap();
        let meta = read_meta(&dir).unwrap();
        assert_eq!(meta.groups, vec!["embed".to_string(), "body".to_string()]);
        // reshard onto a different mesh size through the same spec
        let mut e2 = build(2);
        load(&mut e2, &dir).unwrap();
        for i in 0..full.len() {
            assert_eq!(e2.read_param(i), full[i], "param {i}");
        }
    }
}
