//! Figure 10: training convergence with veScale-FSDP — (a) 8-bit Adam,
//! DDP vs FSDP (curves track closely); (b) Muon vs AdamW (Muon converges
//! faster). Real training on the tiny model — through the PJRT artifacts
//! when available, the native Rust compute path otherwise. Pass --steps
//! to lengthen the runs and --backend serial|threaded to pick the
//! cluster backend (the trajectory is bit-identical either way).

use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::ShardingPolicy;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::{save_log, DdpTrainer, Trainer};
use vescale_fsdp::util::args::Args;
use vescale_fsdp::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 60);
    let backend = CommBackend::parse(&args.str_or("backend", "threaded"))
        .ok_or_else(|| anyhow::anyhow!("unknown --backend"))?;
    let mesh = 4usize;

    // ---- (a) 8-bit Adam: FSDP vs DDP ----
    let h8 = AdamHyper { lr: 5e-4, ..AdamHyper::default() };
    let mut fsdp8 = Trainer::with_backend("tiny", mesh, OptimKind::Adam8bit,
                                          &ShardingPolicy::uniform_rows(32), h8, 42, backend)?;
    println!("fig10: compute={} cluster-backend={}",
             fsdp8.runtime.backend_name(), backend.name());
    let flog = fsdp8.run(steps)?;
    save_log("fig10a_fsdp_adam8bit", &flog)?;
    let mut ddp8 = DdpTrainer::with_backend("tiny", mesh, OptimKind::Adam8bit, h8, 42, backend)?;
    let dlog = ddp8.run(steps)?;
    save_log("fig10a_ddp_adam8bit", &dlog)?;

    let mut ta = Table::new(
        "Fig 10a — 8-bit Adam convergence (loss)",
        &["step", "veScale-FSDP", "DDP", "|gap|"],
    );
    for i in (0..steps).step_by((steps / 6).max(1)) {
        ta.rowv(vec![
            format!("{}", flog[i].step),
            format!("{:.4}", flog[i].loss),
            format!("{:.4}", dlog[i].loss),
            format!("{:.4}", (flog[i].loss - dlog[i].loss).abs()),
        ]);
    }
    ta.print();

    // ---- (b) Muon vs AdamW ----
    let mut adamw = Trainer::with_backend("tiny", mesh, OptimKind::AdamW,
                                          &ShardingPolicy::element_wise(),
                                          AdamHyper { lr: 1e-3, wd: 0.0, ..AdamHyper::default() },
                                          42, backend)?;
    let alog = adamw.run(steps)?;
    save_log("fig10b_adamw", &alog)?;
    let mut muon = Trainer::with_backend("tiny", mesh, OptimKind::Muon,
                                         &ShardingPolicy::element_wise(),
                                         AdamHyper { lr: 0.02, wd: 0.0, ..AdamHyper::default() },
                                         42, backend)?;
    let mlog = muon.run(steps)?;
    save_log("fig10b_muon", &mlog)?;

    let mut tb = Table::new(
        "Fig 10b — Muon vs AdamW convergence (loss)",
        &["step", "AdamW", "Muon", "Muon lead"],
    );
    for i in (0..steps).step_by((steps / 6).max(1)) {
        tb.rowv(vec![
            format!("{}", alog[i].step),
            format!("{:.4}", alog[i].loss),
            format!("{:.4}", mlog[i].loss),
            format!("{:+.4}", alog[i].loss - mlog[i].loss),
        ]);
    }
    tb.print();
    let tail = |log: &[vescale_fsdp::train::StepLog]| {
        let t: Vec<f32> = log.iter().rev().take(10).map(|l| l.loss).collect();
        t.iter().sum::<f32>() / t.len() as f32
    };
    println!("final (avg last 10): FSDP-8bit {:.4} vs DDP-8bit {:.4};",
             tail(&flog), tail(&dlog));
    println!("                     AdamW {:.4} vs Muon {:.4}", tail(&alog), tail(&mlog));
    println!("expected shape (paper): 8-bit curves track closely; Muon below AdamW.");
    Ok(())
}
