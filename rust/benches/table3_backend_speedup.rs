//! Table 3 (repo-specific): threaded vs serial SPMD backend — real step
//! wall-clock on this host at mesh sizes 2/4/8, plus a bit-identity check
//! of the two loss trajectories. Unlike the fig-8/9 harnesses (which
//! report the *modeled* H800 fabric), this one measures actual elapsed
//! time of the cluster runtime: per-rank fwd/bwd fans out across OS
//! threads and collectives run as rendezvous operations.
//!
//!     cargo bench --bench table3_backend_speedup [-- --steps 8 --warmup 2]
//!
//! Emits `BENCH_backend.json` at the crate root.

use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::ShardingPolicy;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::Trainer;
use vescale_fsdp::util::args::Args;
use vescale_fsdp::util::json::Json;
use vescale_fsdp::util::table::Table;

fn run(m: usize, backend: CommBackend, warmup: usize, steps: usize) -> anyhow::Result<(f64, Vec<f32>)> {
    let mut t = Trainer::with_backend(
        "tiny",
        m,
        OptimKind::AdamW,
        &ShardingPolicy::element_wise(),
        AdamHyper { lr: 1e-3, ..AdamHyper::default() },
        42,
        backend,
    )?;
    let mut losses = Vec::with_capacity(warmup + steps);
    for _ in 0..warmup {
        losses.push(t.train_step()?);
    }
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        losses.push(t.train_step()?);
    }
    Ok((t0.elapsed().as_secs_f64() / steps as f64, losses))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 8);
    let warmup = args.usize_or("warmup", 2);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host cores: {cores}; steps/point: {steps} (+{warmup} warmup)\n");

    let mut table = Table::new(
        "Table 3 — threaded vs serial backend, real step wall-clock (tiny model)",
        &["mesh", "serial s/step", "threaded s/step", "speedup", "bit-identical"],
    );
    let mut rows = Vec::new();
    for &m in &[2usize, 4, 8] {
        let (serial_s, serial_l) = run(m, CommBackend::Serial, warmup, steps)?;
        let (thr_s, thr_l) = run(m, CommBackend::Threaded, warmup, steps)?;
        let identical = serial_l
            .iter()
            .zip(&thr_l)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        let speedup = serial_s / thr_s;
        table.rowv(vec![
            format!("{m}"),
            format!("{serial_s:.4}"),
            format!("{thr_s:.4}"),
            format!("{speedup:.2}x"),
            format!("{identical}"),
        ]);
        rows.push(Json::obj(vec![
            ("m", Json::num(m as f64)),
            ("serial_s_per_step", Json::num(serial_s)),
            ("threaded_s_per_step", Json::num(thr_s)),
            ("speedup", Json::num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
    }
    table.print();
    println!("expected shape: speedup approaches min(m, cores) as compute dominates;");
    println!("tiny buffers keep collectives cheap, so fwd/bwd fan-out is the win.");

    let out = Json::obj(vec![
        ("bench", Json::str("backend_speedup")),
        ("model", Json::str("tiny")),
        ("steps", Json::num(steps as f64)),
        ("host_cores", Json::num(cores as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_backend.json");
    std::fs::write(path, out.to_string())?;
    println!("wrote {path}");
    Ok(())
}
